//! Inference-path properties: the forward split and the serving engine.
//!
//!   * **one forward implementation** — `InferModel::predict` (forward-
//!     only retention, pooled activations recycled per layer) must be
//!     bit-identical to the training path's `DistModel::forward`
//!     prediction on every mesh shape, in f32; bf16 stays within the
//!     established 1e-4 fabric tolerance (it is in fact bit-identical
//!     too — same core, same quantization points — but the pin matches
//!     the precision contract the rest of the suite uses);
//!   * **trajectory cache** — repeated queries return the same cached
//!     state (no recompute), and regional answers are exact windows of
//!     the cached global state;
//!   * **steady-state allocation** — once the cache is warm, answering
//!     cached regional queries performs zero pool takes: an O(1) view
//!     of an assembled state, not a tensor op.
//!
//! Engine-running tests serialize on a file-local mutex: the buffer
//! pool's hit/miss counters are process-global, and the allocation
//! assertion needs a quiet pool.

use std::sync::{Arc, Mutex};
use std::thread;

use jigsaw::comm::Network;
use jigsaw::config::ModelConfig;
use jigsaw::jigsaw::{Ctx, Mesh};
use jigsaw::model::dist::DistModel;
use jigsaw::model::params::shard_params;
use jigsaw::model::{init_global_params, InferModel};
use jigsaw::runtime::native::NativeBackend;
use jigsaw::runtime::Backend;
use jigsaw::serve::{RegionQuery, RolloutEngine, ServeEngine};
use jigsaw::tensor::{pool, Precision, Tensor};
use jigsaw::trainer::oracle::sample_shard;
use jigsaw::util::rng::Rng;

/// Serializes every test that spins rank threads (shared process-global
/// pool statistics). A poisoned lock (a failed sibling test) must not
/// cascade.
static ENGINE_GATE: Mutex<()> = Mutex::new(());

fn gate() -> std::sync::MutexGuard<'static, ()> {
    ENGINE_GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "infer-props".into(),
        lat: 8,
        lon: 16,
        channels: 6,
        channels_padded: 8,
        patch: 2,
        d_emb: 32,
        d_tok: 48,
        d_ch: 32,
        blocks: 2,
        tokens: 32,
        patch_dim: 32,
        param_count: 12904,
        flops_forward: 0,
        channel_weights: vec![1.0; 6],
    }
}

fn mk_sample(cfg: &ModelConfig, seed: u64) -> Tensor {
    let mut rng = Rng::seed_from(seed);
    let mut d = vec![0.0; cfg.lat * cfg.lon * cfg.channels_padded];
    rng.fill_normal(&mut d, 1.0);
    Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d)
}

/// Per-rank predictions through the TRAINING forward (cache retained,
/// then dropped).
fn run_train_forward(
    cfg: &ModelConfig,
    mesh: Mesh,
    global: &[(String, Tensor)],
    x: &Tensor,
    rollout: usize,
    precision: Precision,
) -> Vec<Tensor> {
    let net = Network::new(mesh.n());
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
    let mut handles = Vec::new();
    for r in 0..mesh.n() {
        let cfg = cfg.clone();
        let params = shard_params(&cfg, &mesh, r, global).unwrap();
        let mut comm = net.endpoint(r);
        let backend = backend.clone();
        let x = x.clone();
        handles.push(thread::spawn(move || {
            let model = DistModel::new(cfg, &mesh, r, params);
            let (la, _, lc) = model.local_dims();
            let (lat0, ch0) = (model.lat_offset(), model.ch_offset());
            let xl = sample_shard(&x, (lat0, lat0 + la), (ch0, ch0 + lc));
            let mut ctx = Ctx::new(mesh, r, &mut comm, backend.as_ref());
            ctx.precision = precision;
            let (pred, _cache) = model.forward(&mut ctx, &xl, rollout).unwrap();
            pred
        }));
    }
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Per-rank predictions through the INFERENCE forward (no cache, pooled
/// activations recycled per layer).
fn run_infer_forward(
    cfg: &ModelConfig,
    mesh: Mesh,
    global: &[(String, Tensor)],
    x: &Tensor,
    rollout: usize,
    precision: Precision,
) -> Vec<Tensor> {
    let net = Network::new(mesh.n());
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
    let mut handles = Vec::new();
    for r in 0..mesh.n() {
        let cfg = cfg.clone();
        let global = global.to_vec();
        let mut comm = net.endpoint(r);
        let backend = backend.clone();
        let x = x.clone();
        handles.push(thread::spawn(move || {
            let model = InferModel::new(cfg, &mesh, r, &global).unwrap();
            let (la, _, lc) = model.local_dims();
            let (lat0, ch0) = (model.lat_offset(), model.ch_offset());
            let xl = sample_shard(&x, (lat0, lat0 + la), (ch0, ch0 + lc));
            let mut ctx =
                Ctx::infer(mesh, r, &mut comm, backend.as_ref(), precision);
            model.predict(&mut ctx, &xl, rollout).unwrap()
        }));
    }
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

#[test]
fn infer_is_bit_identical_to_train_forward_on_every_mesh() {
    let _g = gate();
    let cfg = cfg();
    let global = init_global_params(&cfg, 0xA11CE);
    let x = mk_sample(&cfg, 7);
    for (t, c) in [(1usize, 1usize), (1, 2), (2, 2), (2, 4)] {
        let mesh = Mesh::new(t, c).unwrap();
        for rollout in [1usize, 2] {
            let train =
                run_train_forward(&cfg, mesh, &global, &x, rollout, Precision::F32);
            let infer =
                run_infer_forward(&cfg, mesh, &global, &x, rollout, Precision::F32);
            for (r, (a, b)) in train.iter().zip(&infer).enumerate() {
                assert_eq!(a.shape, b.shape, "{mesh} rank {r} rollout {rollout}");
                for (i, (va, vb)) in a.data.iter().zip(&b.data).enumerate() {
                    assert_eq!(
                        va.to_bits(),
                        vb.to_bits(),
                        "{mesh} rank {r} rollout {rollout} elem {i}: {va} vs {vb}"
                    );
                }
            }
        }
    }
}

#[test]
fn infer_matches_train_forward_in_bf16() {
    let _g = gate();
    let cfg = cfg();
    let global = init_global_params(&cfg, 0xB16);
    let x = mk_sample(&cfg, 9);
    let mesh = Mesh::new(1, 2).unwrap();
    let train = run_train_forward(&cfg, mesh, &global, &x, 1, Precision::Bf16);
    let infer = run_infer_forward(&cfg, mesh, &global, &x, 1, Precision::Bf16);
    for (r, (a, b)) in train.iter().zip(&infer).enumerate() {
        let err = a.max_abs_diff(b);
        assert!(err <= 1e-4, "bf16 rank {r} err {err}");
    }
}

fn serve_engine(cfg: &ModelConfig, mesh: Mesh, prefetch: bool, cache: usize) -> ServeEngine {
    let global = init_global_params(cfg, 0xD00F);
    let engine = RolloutEngine::new(
        cfg,
        &mesh,
        &global,
        Arc::new(NativeBackend),
        Precision::F32,
        1,
    )
    .unwrap();
    let mut srv = ServeEngine::new(engine, cache, 6, prefetch);
    srv.add_init(0, mk_sample(cfg, 42)).unwrap();
    srv.add_init(1, mk_sample(cfg, 43)).unwrap();
    srv
}

#[test]
fn repeated_queries_share_the_cached_state() {
    let _g = gate();
    let cfg = cfg();
    let mut srv = serve_engine(&cfg, Mesh::new(1, 2).unwrap(), false, 16);
    let a = srv.state(0, 3).unwrap();
    let hits_before = srv.stats().hits;
    let b = srv.state(0, 3).unwrap();
    assert!(Arc::ptr_eq(&a, &b), "hit must return the same cached state");
    assert_eq!(srv.stats().hits, hits_before + 1);
    // intermediate steps were cached on the way to lead 3
    assert!(srv.cache_len() >= 3);
    // a shorter lead on the same trajectory is now also a hit
    let c = srv.state(0, 2).unwrap();
    assert!(!Arc::ptr_eq(&a, &c));
}

#[test]
fn regional_answer_is_an_exact_window_of_the_global_state() {
    let _g = gate();
    let cfg = cfg();
    let mut srv = serve_engine(&cfg, Mesh::new(2, 2).unwrap(), false, 16);
    let q = RegionQuery { init_id: 1, lead: 2, lat: (2, 7), lon: (3, 11) };
    let ans = srv.answer(q).unwrap();
    let state = srv.state(1, 2).unwrap();
    assert!(Arc::ptr_eq(ans.state(), &state));
    let v = ans.view();
    assert_eq!(v.dims(), (5, 8 * cfg.channels_padded));
    for li in 0..5 {
        for lj in 0..8 {
            for ch in 0..cfg.channels_padded {
                let want = state.data
                    [((li + 2) * cfg.lon + lj + 3) * cfg.channels_padded + ch];
                let got = v.at(li, lj * cfg.channels_padded + ch);
                assert_eq!(got.to_bits(), want.to_bits(), "({li},{lj},{ch})");
            }
        }
    }
}

#[test]
fn serve_rollout_matches_manual_infer_rollout() {
    // the engine's scatter/gather roundtrip: a served lead-2 state must
    // bit-match feeding predict's assembled output back in by hand on
    // the same mesh
    let _g = gate();
    let cfg = cfg();
    let mesh = Mesh::new(1, 2).unwrap();
    let global = init_global_params(&cfg, 0xD00F);
    let x0 = mk_sample(&cfg, 42); // == init 0 of serve_engine
    let step1: Vec<Tensor> =
        run_infer_forward(&cfg, mesh, &global, &x0, 1, Precision::F32);
    // reassemble rank locals into the global state by shard offsets
    let mut s1 = Tensor::zeros(&[cfg.lat, cfg.lon, cfg.channels_padded]);
    for (r, local) in step1.iter().enumerate() {
        let (la, lc) = (local.shape[0], local.shape[2]);
        let (lat0, ch0) = (r / 2 * la, r % 2 * lc); // 1x2: ranks split channels
        for li in 0..la {
            for lj in 0..cfg.lon {
                for ci in 0..lc {
                    s1.data[((lat0 + li) * cfg.lon + lj) * cfg.channels_padded
                        + ch0
                        + ci] = local.data[(li * cfg.lon + lj) * lc + ci];
                }
            }
        }
    }
    let step2 = run_infer_forward(&cfg, mesh, &global, &s1, 1, Precision::F32);
    let mut srv = serve_engine(&cfg, mesh, false, 16);
    let served = srv.state(0, 2).unwrap();
    for (r, local) in step2.iter().enumerate() {
        let (la, lc) = (local.shape[0], local.shape[2]);
        let (lat0, ch0) = (r / 2 * la, r % 2 * lc);
        for li in 0..la {
            for lj in 0..cfg.lon {
                for ci in 0..lc {
                    let want = local.data[(li * cfg.lon + lj) * lc + ci];
                    let got = served.data
                        [((lat0 + li) * cfg.lon + lj) * cfg.channels_padded + ch0 + ci];
                    assert_eq!(got.to_bits(), want.to_bits(), "rank {r} ({li},{lj},{ci})");
                }
            }
        }
    }
}

#[test]
fn cached_queries_do_zero_pool_takes() {
    let _g = gate();
    let cfg = cfg();
    let mut srv = serve_engine(&cfg, Mesh::new(1, 2).unwrap(), false, 16);
    // warm: every state the queries below will touch
    for lead in 0..=4 {
        srv.state(0, lead).unwrap();
        srv.state(1, lead).unwrap();
    }
    let (h0, m0) = pool::stats();
    let mut checksum = 0.0f32;
    for lead in 0..=4 {
        for (lat0, lon0) in [(0usize, 0usize), (2, 3), (4, 8)] {
            let ans = srv
                .answer(RegionQuery {
                    init_id: (lead % 2) as u64,
                    lead,
                    lat: (lat0, lat0 + 3),
                    lon: (lon0, lon0 + 4),
                })
                .unwrap();
            checksum += ans.view().at(0, 0);
        }
    }
    let (h1, m1) = pool::stats();
    assert_eq!(
        (h1 - h0) + (m1 - m0),
        0,
        "steady-state cached queries must not take pool buffers (checksum {checksum})"
    );
}

#[test]
fn prefetch_fills_the_next_lead_step() {
    let _g = gate();
    let cfg = cfg();
    let mut srv = serve_engine(&cfg, Mesh::new(1, 2).unwrap(), true, 16);
    srv.state(0, 1).unwrap(); // kicks off a prefetch of (0, 2)
    assert_eq!(srv.stats().prefetches, 1);
    let misses_before = srv.stats().misses;
    srv.state(0, 2).unwrap(); // drained prefetch answers this
    // the lookup itself records hit-or-miss before/after the drain lands
    // the state; what matters is no extra prefetch was wasted and the
    // state is now cached
    assert!(srv.stats().misses <= misses_before + 1);
    let hits_before = srv.stats().hits;
    srv.state(0, 2).unwrap();
    assert_eq!(srv.stats().hits, hits_before + 1, "prefetched state is cached");
}
