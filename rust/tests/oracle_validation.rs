//! THE core correctness gate: the rust jigsaw engine (1/2/4-way, real
//! message passing between rank threads, PJRT-executed Pallas matmul
//! primitives) must reproduce the AOT-exported JAX `loss_and_grad`
//! programs bit-close for identical parameters and samples.

mod common;

use std::sync::Arc;

use jigsaw::jigsaw::Mesh;
use jigsaw::model::init_global_params;
use jigsaw::runtime::engine::PjrtBackend;
use jigsaw::runtime::Backend;
use jigsaw::tensor::Tensor;
use jigsaw::trainer::oracle::{
    run_dist_loss_and_grad, run_oracle_loss_and_grad, sample_shard,
};
use jigsaw::util::rng::Rng;

fn mk_sample(cfg: &jigsaw::config::ModelConfig, seed: u64) -> Tensor {
    let mut rng = Rng::seed_from(seed);
    let mut d = vec![0.0; cfg.lat * cfg.lon * cfg.channels_padded];
    rng.fill_normal(&mut d, 1.0);
    Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d)
}

fn check_way(preset: &str, way: usize, tol: f32) {
    if !common::can_run_programs() {
        eprintln!("skipping {preset}/{way}-way oracle: HLO programs need the pjrt feature");
        return;
    }
    let cfg = common::config(preset);
    let engine = common::engine(preset);
    let backend: Arc<dyn Backend> = Arc::new(PjrtBackend { engine: engine.clone() });
    let params = init_global_params(&cfg, 42);
    let x = mk_sample(&cfg, 1);
    let y = mk_sample(&cfg, 2);
    let mesh = Mesh::from_degree(way).unwrap();
    let (loss_o, grads_o) =
        run_oracle_loss_and_grad(&engine, &cfg, mesh.ch(), &params, &x, &y).unwrap();
    let (loss_d, grads_d) =
        run_dist_loss_and_grad(&cfg, &mesh, &params, &x, &y, backend, 1).unwrap();
    assert!(
        (loss_o - loss_d).abs() <= tol * loss_o.abs().max(1.0),
        "{preset}/{mesh} loss mismatch: {loss_o} vs {loss_d}"
    );
    for ((n, go), (_, gd)) in grads_o.iter().zip(&grads_d) {
        let err = go.max_abs_diff(gd);
        assert!(err <= tol, "{preset}/{mesh} grad '{n}' err {err}");
    }
}

#[test]
fn one_way_matches_oracle_tiny() {
    check_way("tiny", 1, 1e-4);
}

#[test]
fn two_way_matches_oracle_tiny() {
    check_way("tiny", 2, 1e-4);
}

#[test]
fn four_way_matches_oracle_tiny() {
    check_way("tiny", 4, 1e-4);
}

#[test]
fn two_way_matches_oracle_small() {
    check_way("small", 2, 5e-4);
}

#[test]
fn four_way_matches_oracle_small() {
    check_way("small", 4, 5e-4);
}

#[test]
fn forward_rollout_matches_oracle() {
    // rollout=2: the processor applied twice with one encode/decode;
    // compare against the AOT `forward_r2` program (1-way).
    if !common::can_run_programs() {
        eprintln!("skipping rollout oracle: HLO programs need the pjrt feature");
        return;
    }
    let cfg = common::config("tiny");
    let engine = common::engine("tiny");
    let params = init_global_params(&cfg, 7);
    let x = mk_sample(&cfg, 3);
    let mut inputs: Vec<Tensor> = params.iter().map(|(_, t)| t.clone()).collect();
    inputs.push(x.clone());
    let oracle = engine.run_program("forward_r2", inputs).unwrap();

    let backend: Arc<dyn Backend> = Arc::new(PjrtBackend { engine: engine.clone() });
    let net = jigsaw::comm::Network::new(1);
    let mut comm = net.endpoint(0);
    let store =
        jigsaw::model::params::shard_params(&cfg, &Mesh::unit(), 0, &params).unwrap();
    let model =
        jigsaw::model::dist::DistModel::new(cfg.clone(), &Mesh::unit(), 0, store);
    let mut ctx =
        jigsaw::jigsaw::Ctx::new(Mesh::unit(), 0, &mut comm, backend.as_ref());
    let (pred, _) = model.forward(&mut ctx, &x, 2).unwrap();
    let flat = pred.reshape(&[cfg.lat, cfg.lon, cfg.channels_padded]);
    let err = oracle[0].max_abs_diff(&flat);
    assert!(err < 1e-4, "rollout forward err {err}");
}

#[test]
fn dist_loss_identical_between_2way_and_4way() {
    // both use channel-split LN stats, so their losses agree exactly
    let cfg = common::config("tiny");
    let engine = common::engine("tiny");
    let backend: Arc<dyn Backend> = Arc::new(PjrtBackend { engine });
    let params = init_global_params(&cfg, 11);
    let x = mk_sample(&cfg, 5);
    let y = mk_sample(&cfg, 6);
    let m2 = Mesh::from_degree(2).unwrap();
    let m4 = Mesh::from_degree(4).unwrap();
    let (l2, _) =
        run_dist_loss_and_grad(&cfg, &m2, &params, &x, &y, backend.clone(), 1).unwrap();
    let (l4, _) =
        run_dist_loss_and_grad(&cfg, &m4, &params, &x, &y, backend, 1).unwrap();
    assert!((l2 - l4).abs() < 1e-5, "2-way {l2} vs 4-way {l4}");
}

#[test]
fn sample_shard_slices_correctly() {
    let t = Tensor::new(vec![2, 2, 3], (0..12).map(|v| v as f32).collect());
    let s = sample_shard(&t, (1, 2), (1, 3));
    assert_eq!(s.shape, vec![1, 2, 2]);
    assert_eq!(s.data, vec![7.0, 8.0, 10.0, 11.0]);
}
