//! End-to-end training through the full stack: sharded loader -> jigsaw
//! engine over PJRT-executed Pallas primitives -> per-shard Adam -> loss
//! decrease. Covers 1-way, 2-way, 2-way x DP, and rollout fine-tuning.

mod common;

use std::sync::Arc;

use jigsaw::runtime::engine::PjrtBackend;
use jigsaw::runtime::Backend;
use jigsaw::trainer::{train, TrainSpec};

fn backend(preset: &str) -> Arc<dyn Backend> {
    Arc::new(PjrtBackend { engine: common::engine(preset) })
}

#[test]
fn tiny_one_way_pjrt_loss_decreases() {
    let cfg = common::config("tiny");
    let mut spec = TrainSpec::quick(1, 1, 25).unwrap();
    spec.val_every = 25;
    let r = train(&cfg, &spec, backend("tiny")).unwrap();
    let first = r.steps.first().unwrap().loss;
    let last = r.steps.last().unwrap().loss;
    assert!(last < first * 0.8, "loss {first} -> {last}");
    assert!(!r.final_val_rmse.is_empty());
    assert!(r.final_val_rmse.iter().all(|v| v.is_finite()));
}

#[test]
fn tiny_two_way_pjrt_trains() {
    let cfg = common::config("tiny");
    let spec = TrainSpec::quick(2, 1, 20).unwrap();
    let r = train(&cfg, &spec, backend("tiny")).unwrap();
    let first = r.steps.first().unwrap().loss;
    let last = r.steps.last().unwrap().loss;
    assert!(last < first * 0.9, "2-way loss {first} -> {last}");
    assert!(r.comm_bytes > 0, "jigsaw must exchange partial sums");
}

#[test]
fn tiny_two_way_with_dp_trains() {
    let cfg = common::config("tiny");
    let spec = TrainSpec::quick(2, 2, 12).unwrap();
    let r = train(&cfg, &spec, backend("tiny")).unwrap();
    assert_eq!(r.steps.len(), 12);
    let first = r.steps.first().unwrap().loss;
    let last = r.steps.last().unwrap().loss;
    assert!(last < first, "2-way x 2-DP loss {first} -> {last}");
}

#[test]
fn four_way_pjrt_trains() {
    let cfg = common::config("tiny");
    let spec = TrainSpec::quick(4, 1, 12).unwrap();
    let r = train(&cfg, &spec, backend("tiny")).unwrap();
    let first = r.steps.first().unwrap().loss;
    let last = r.steps.last().unwrap().loss;
    assert!(last < first, "4-way loss {first} -> {last}");
}

#[test]
fn rollout_finetune_runs_multi_length() {
    let cfg = common::config("tiny");
    let mut spec = TrainSpec::quick(1, 1, 10).unwrap();
    spec.max_rollout = 3;
    let r = train(&cfg, &spec, backend("tiny")).unwrap();
    let lens: std::collections::BTreeSet<usize> =
        r.steps.iter().map(|s| s.rollout).collect();
    assert!(lens.len() > 1);
    assert!(r.steps.iter().all(|s| s.loss.is_finite()));
}

#[test]
fn final_params_equal_across_mp_ranks_of_dp_groups() {
    // after DP-synchronized training, group-0 reassembled params must be
    // finite and non-trivially updated from init
    let cfg = common::config("tiny");
    let spec = TrainSpec::quick(2, 2, 5).unwrap();
    let r = train(&cfg, &spec, backend("tiny")).unwrap();
    let init = jigsaw::model::init_global_params(&cfg, spec.seed);
    let mut moved = 0usize;
    for ((_, a), (_, b)) in r.final_params.iter().zip(&init) {
        assert!(a.data.iter().all(|v| v.is_finite()));
        if a.max_abs_diff(b) > 1e-6 {
            moved += 1;
        }
    }
    assert!(moved > init.len() / 2, "most params should move: {moved}");
}
