//! Plan coverage: every matmul primitive the jigsaw engine needs for the
//! exported presets must exist in the artifact manifest — no silent
//! native fallbacks on the deployment path.
//!
//! Runs the full 1/2/4-way loss_and_grad with JIGSAW_STRICT_PJRT=1, under
//! which a missing primitive is a hard error. Kept in its own test binary
//! because the env var is process-global.

mod common;

use std::sync::Arc;

use jigsaw::jigsaw::Mesh;
use jigsaw::model::init_global_params;
use jigsaw::runtime::engine::PjrtBackend;
use jigsaw::runtime::Backend;
use jigsaw::tensor::Tensor;
use jigsaw::trainer::oracle::run_dist_loss_and_grad;
use jigsaw::util::rng::Rng;

#[test]
fn all_plan_shapes_have_pjrt_primitives() {
    std::env::set_var("JIGSAW_STRICT_PJRT", "1");
    for preset in ["tiny", "small"] {
        let cfg = common::config(preset);
        let engine = common::engine(preset);
        let backend: Arc<dyn Backend> = Arc::new(PjrtBackend { engine: engine.clone() });
        let params = init_global_params(&cfg, 1);
        let mut rng = Rng::seed_from(2);
        let mut d = vec![0.0; cfg.lat * cfg.lon * cfg.channels_padded];
        rng.fill_normal(&mut d, 1.0);
        let x = Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d.clone());
        let y = Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d);
        for way in [1usize, 2, 4] {
            let mesh = Mesh::from_degree(way).unwrap();
            run_dist_loss_and_grad(&cfg, &mesh, &params, &x, &y, backend.clone(), 1)
                .unwrap_or_else(|e| panic!("{preset}/{mesh} missing primitive: {e}"));
        }
        // Without the 'pjrt' feature the engine executes manifest-covered
        // primitives on the native kernels (counted as fallbacks), so the
        // zero-fallback assert only holds when PJRT actually serves them.
        #[cfg(feature = "pjrt")]
        {
            let stats = engine.stats();
            assert_eq!(
                stats
                    .native_fallbacks
                    .load(std::sync::atomic::Ordering::Relaxed),
                0,
                "{preset}: native fallbacks occurred"
            );
        }
        #[cfg(not(feature = "pjrt"))]
        let _ = &engine;
    }
    std::env::remove_var("JIGSAW_STRICT_PJRT");
}
