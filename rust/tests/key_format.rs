//! The primitive-cache keying contract: `MatmulOp::key` on the rust side
//! must stay format-identical to `mm_key_str` in python/compile/aot.py —
//! the runtime looks HLO primitives up by these strings, so silent drift
//! would send every matmul down the native fallback path.
//!
//! Two layers of defence: (1) a generated manifest of keys round-trips
//! through a rust reimplementation of the python format and back through
//! a parser; (2) the python source itself is scanned for the exact
//! format expression.

use std::path::Path;

use jigsaw::runtime::MatmulOp;
use jigsaw::tensor::Tensor;

/// Rust twin of python `aot.mm_key_str`.
fn mm_key_str(op: &str, xr: usize, xc: usize, wr: usize, wc: usize) -> String {
    format!("{op}_{xr}x{xc}_{wr}x{wc}")
}

/// Parse "<op>_<xr>x<xc>_<wr>x<wc>" back into its parts.
fn parse_key(key: &str) -> Option<(String, usize, usize, usize, usize)> {
    let mut parts = key.split('_');
    let op = parts.next()?.to_string();
    let (xr, xc) = parts.next()?.split_once('x')?;
    let (wr, wc) = parts.next()?.split_once('x')?;
    if parts.next().is_some() {
        return None;
    }
    Some((
        op,
        xr.parse().ok()?,
        xc.parse().ok()?,
        wr.parse().ok()?,
        wc.parse().ok()?,
    ))
}

/// Generate a manifest of conforming (op, shapes) keys the way
/// `aot.primitive_keys` does: every halving combination of a dim set,
/// filtered to executable contractions.
fn generated_manifest() -> Vec<(MatmulOp, usize, usize, usize, usize)> {
    let dims = [8usize, 16, 32, 54, 48, 128, 6];
    let halvings = |d: usize| -> Vec<usize> {
        if d % 2 == 0 {
            vec![d, d / 2]
        } else {
            vec![d]
        }
    };
    let mut keys = Vec::new();
    for &a in &dims {
        for &b in &dims {
            for xr in halvings(a) {
                for xc in halvings(b) {
                    for wr in halvings(a) {
                        for wc in halvings(b) {
                            // contraction conformance per op
                            if xc == wc {
                                keys.push((MatmulOp::NT, xr, xc, wr, wc));
                            }
                            if xc == wr {
                                keys.push((MatmulOp::NN, xr, xc, wr, wc));
                            }
                            if xr == wr {
                                keys.push((MatmulOp::TN, xr, xc, wr, wc));
                            }
                        }
                    }
                }
            }
        }
    }
    keys
}

#[test]
fn generated_manifest_keys_round_trip() {
    let manifest = generated_manifest();
    assert!(manifest.len() > 100, "manifest generator produced too few keys");
    for (op, xr, xc, wr, wc) in manifest {
        let x = Tensor::zeros(&[xr, xc]);
        let w = Tensor::zeros(&[wr, wc]);
        let rust_key = op.key(&x, &w);
        // format-identical to the python mm_key_str
        assert_eq!(rust_key, mm_key_str(op.tag(), xr, xc, wr, wc));
        // and round-trips through a parser (no ambiguity / truncation)
        let (ptag, pxr, pxc, pwr, pwc) =
            parse_key(&rust_key).unwrap_or_else(|| panic!("unparseable key {rust_key}"));
        assert_eq!((ptag.as_str(), pxr, pxc, pwr, pwc), (op.tag(), xr, xc, wr, wc));
    }
}

#[test]
fn python_source_still_uses_the_same_format() {
    // CARGO_MANIFEST_DIR is the repo root; the python exporter lives
    // alongside the rust tree.
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("python/compile/aot.py");
    let src = match std::fs::read_to_string(&path) {
        Ok(s) => s,
        Err(e) => {
            // vendored/packaged builds may omit the python tree; the
            // round-trip test above still guards the rust side
            eprintln!("skipping python drift check: {}: {e}", path.display());
            return;
        }
    };
    assert!(
        src.contains(r#"f"{op}_{xr}x{xc}_{wr}x{wc}""#),
        "python mm_key_str no longer matches MatmulOp::key's format — \
         update rust/src/runtime/mod.rs and this test together"
    );
    assert!(
        src.contains("def mm_key_str"),
        "python/compile/aot.py lost mm_key_str"
    );
}
