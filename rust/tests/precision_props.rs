//! Mixed-precision properties (`--precision bf16`): the bf16
//! storage-and-fabric path against the f32 engine as a tolerance oracle.
//!
//!   * loss_and_grad under bf16 must track the f32 result within a pinned
//!     relative tolerance on every mesh shape (1x1 .. 2x4) — the bf16
//!     generalization of `mesh_props`' 1e-4 f32 pins;
//!   * bf16 end-to-end training (2x2 mesh, dp=2, rollout 2) must decrease
//!     the loss and land within tolerance of the f32 trajectory;
//!   * bf16 runs must ship roughly half the fabric bytes of f32 — the
//!     byte accounting derives from actual payload element size, so the
//!     halving shows up without special-casing;
//!   * the f32 default must stay *bit-identical* to the pre-precision
//!     engine (same fabric, no scaler traffic): pinned here by running
//!     the same spec twice and by the scaler being inert.
//!
//! Pinned tolerances: bf16 carries an 8-bit mantissa (~0.4% per rounding)
//! and the residual stream is quantized at every layer boundary, so a few
//! percent of drift accumulates across blocks and rollout steps. 2e-2 on
//! the loss and 5e-2 on gradients hold with margin; a real regression
//! (double quantization, wrong rounding, divergent DP replicas) blows
//! well past them.

use std::sync::Arc;

use jigsaw::jigsaw::Mesh;
use jigsaw::model::init_global_params;
use jigsaw::runtime::native::NativeBackend;
use jigsaw::runtime::Backend;
use jigsaw::tensor::{Precision, Tensor};
use jigsaw::trainer::oracle::run_dist_loss_and_grad_prec;
use jigsaw::trainer::{train, TrainSpec};
use jigsaw::util::rng::Rng;

const LOSS_TOL: f32 = 2e-2;
const GRAD_TOL: f32 = 5e-2;

fn cfg() -> jigsaw::config::ModelConfig {
    jigsaw::config::ModelConfig {
        name: "precision-props".into(),
        lat: 8,
        lon: 16,
        channels: 6,
        channels_padded: 8,
        patch: 2,
        d_emb: 32,
        d_tok: 48,
        d_ch: 32,
        blocks: 2,
        tokens: 32,
        patch_dim: 32,
        param_count: 12904,
        flops_forward: 0,
        channel_weights: vec![1.0; 6],
    }
}

fn mk_sample(cfg: &jigsaw::config::ModelConfig, seed: u64) -> Tensor {
    let mut rng = Rng::seed_from(seed);
    let mut d = vec![0.0; cfg.lat * cfg.lon * cfg.channels_padded];
    rng.fill_normal(&mut d, 1.0);
    Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d)
}

#[test]
fn bf16_loss_and_grad_tracks_f32_oracle_across_meshes() {
    let cfg = cfg();
    let global = init_global_params(&cfg, 17);
    let x = mk_sample(&cfg, 71);
    let y = mk_sample(&cfg, 72);
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
    for (t, c) in [(1usize, 1usize), (1, 2), (2, 2), (2, 4)] {
        let mesh = Mesh::new(t, c).unwrap();
        let (loss_f32, grads_f32) = run_dist_loss_and_grad_prec(
            &cfg,
            &mesh,
            &global,
            &x,
            &y,
            backend.clone(),
            1,
            Precision::F32,
        )
        .unwrap();
        let (loss_bf, grads_bf) = run_dist_loss_and_grad_prec(
            &cfg,
            &mesh,
            &global,
            &x,
            &y,
            backend.clone(),
            1,
            Precision::Bf16,
        )
        .unwrap();
        assert!(
            (loss_bf - loss_f32).abs() <= LOSS_TOL * loss_f32.abs().max(1.0),
            "{mesh} bf16 loss {loss_bf} vs f32 {loss_f32}"
        );
        let mut any_diff = false;
        for ((n, gf), (_, gb)) in grads_f32.iter().zip(&grads_bf) {
            let scale = gf.data.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1.0);
            let err = gf.max_abs_diff(gb);
            assert!(
                err <= GRAD_TOL * scale,
                "{mesh} grad '{n}' bf16 err {err} (scale {scale})"
            );
            any_diff |= err > 0.0;
        }
        // the bf16 path must actually be live: quantizing the residual
        // stream at every layer boundary cannot leave all grads bitwise
        // equal to f32
        assert!(
            any_diff || loss_bf != loss_f32,
            "{mesh}: bf16 run is bitwise identical to f32 — precision not applied"
        );
    }
}

#[test]
fn bf16_rollout_matches_f32_within_tolerance() {
    // the randomized-rollout path quantizes the residual stream once per
    // unrolled step — drift compounds but stays inside the pinned band
    let cfg = cfg();
    let global = init_global_params(&cfg, 23);
    let x = mk_sample(&cfg, 81);
    let y = mk_sample(&cfg, 82);
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
    let mesh = Mesh::new(2, 2).unwrap();
    let (loss_f32, _) = run_dist_loss_and_grad_prec(
        &cfg, &mesh, &global, &x, &y, backend.clone(), 2, Precision::F32,
    )
    .unwrap();
    let (loss_bf, _) = run_dist_loss_and_grad_prec(
        &cfg, &mesh, &global, &x, &y, backend, 2, Precision::Bf16,
    )
    .unwrap();
    assert!(
        (loss_bf - loss_f32).abs() <= 2.0 * LOSS_TOL * loss_f32.abs().max(1.0),
        "rollout bf16 loss {loss_bf} vs f32 {loss_f32}"
    );
}

fn train_spec(precision: Precision) -> TrainSpec {
    let mut spec = TrainSpec::with_mesh(Mesh::new(2, 2).unwrap(), 2, 12);
    spec.max_rollout = 2;
    spec.seed = 3;
    spec.precision = precision;
    spec
}

#[test]
fn bf16_e2e_training_decreases_loss_and_tracks_f32() {
    let cfg = cfg();
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
    let r_f32 = train(&cfg, &train_spec(Precision::F32), backend.clone()).unwrap();
    let r_bf = train(&cfg, &train_spec(Precision::Bf16), backend).unwrap();

    let first = r_bf.steps.first().unwrap().loss;
    let last = r_bf.steps.last().unwrap().loss;
    assert!(last < first, "bf16 2x2xdp2 loss must decrease: {first} -> {last}");
    assert!(r_bf.steps.iter().all(|s| s.loss.is_finite()));

    // trajectory tolerance: per-step quantization drift compounds over 12
    // optimizer steps, so the band is wider than single-call loss_and_grad
    let lf = r_f32.steps.last().unwrap().loss;
    assert!(
        (last - lf).abs() <= 0.1 * lf.abs().max(1.0),
        "bf16 final loss {last} vs f32 {lf}"
    );
}

#[test]
fn bf16_ships_about_half_the_fabric_bytes() {
    // every bulk payload (jigsaw mobile blocks, partial sums, DP ring
    // chunks) moves as 2-byte elements; only scalar reductions and tiny
    // gather-to-root tensors stay f32. The byte counters read the actual
    // payload size, so the ratio lands just above 0.5.
    let cfg = cfg();
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
    let r_f32 = train(&cfg, &train_spec(Precision::F32), backend.clone()).unwrap();
    let r_bf = train(&cfg, &train_spec(Precision::Bf16), backend).unwrap();
    assert!(r_f32.comm_bytes > 0 && r_bf.comm_bytes > 0);
    let ratio = r_bf.comm_bytes as f64 / r_f32.comm_bytes as f64;
    assert!(
        ratio > 0.45 && ratio < 0.65,
        "bf16/f32 fabric byte ratio {ratio} (bf16 {} vs f32 {})",
        r_bf.comm_bytes,
        r_f32.comm_bytes
    );
}

#[test]
fn f32_default_is_deterministic_with_scaler_inert() {
    // Precision::F32 must keep the pre-precision engine bit-for-bit:
    // the GradScaler is inert (scale 1.0, no overflow probes on the
    // fabric) so two identical runs agree exactly, step by step.
    let cfg = cfg();
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
    let a = train(&cfg, &train_spec(Precision::F32), backend.clone()).unwrap();
    let b = train(&cfg, &train_spec(Precision::F32), backend).unwrap();
    assert_eq!(a.comm_bytes, b.comm_bytes, "no extra fabric traffic under F32");
    for (sa, sb) in a.steps.iter().zip(&b.steps) {
        assert_eq!(
            sa.loss.to_bits(),
            sb.loss.to_bits(),
            "step {} diverged",
            sa.step
        );
    }
}
