//! Mesh/Planner properties on the real engine, artifact-free:
//!
//!   * `dist_matmul` over planner-derived grids for 2x4 and 4x4 meshes
//!     (the 8-/16-way regimes the hand-written layouts never covered)
//!     must match the single-rank matmul oracle;
//!   * the group-reduced loss and every reassembled parameter gradient
//!     must be invariant to the token axis: for a fixed channel split,
//!     meshes 1xc, 2xc, 4xc are the same math distributed differently
//!     (layer-norm statistics depend only on the channel split), so they
//!     agree to fp tolerance — the mesh generalization of the seed's
//!     2-way-vs-4-way equivalence test.

use std::sync::Arc;
use std::thread;

use jigsaw::jigsaw::{dist_matmul, Ctx, DistMat, Mesh, Planner, Site};
use jigsaw::model::init_global_params;
use jigsaw::runtime::native::NativeBackend;
use jigsaw::runtime::{Backend, MatmulOp};
use jigsaw::tensor::{ops, Tensor};
use jigsaw::trainer::oracle::run_dist_loss_and_grad;
use jigsaw::util::rng::Rng;

fn rand_t(rng: &mut Rng, r: usize, c: usize) -> Tensor {
    let mut d = vec![0.0; r * c];
    rng.fill_normal(&mut d, 1.0);
    Tensor::new(vec![r, c], d)
}

fn cfg() -> jigsaw::config::ModelConfig {
    jigsaw::config::ModelConfig {
        name: "mesh-props".into(),
        lat: 8,
        lon: 16,
        channels: 6,
        channels_padded: 8,
        patch: 2,
        d_emb: 32,
        d_tok: 48,
        d_ch: 32,
        blocks: 2,
        tokens: 32,
        patch_dim: 32,
        param_count: 12904,
        flops_forward: 0,
        channel_weights: vec![1.0; 6],
    }
}

fn mk_sample(cfg: &jigsaw::config::ModelConfig, seed: u64) -> Tensor {
    let mut rng = Rng::seed_from(seed);
    let mut d = vec![0.0; cfg.lat * cfg.lon * cfg.channels_padded];
    rng.fill_normal(&mut d, 1.0);
    Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d)
}

/// Run one dist_matmul over `mesh.n()` rank threads on planner grids.
#[allow(clippy::too_many_arguments)]
fn run_planner_matmul(
    mesh: Mesh,
    op: MatmulOp,
    xg: jigsaw::jigsaw::BlockGrid,
    wg: jigsaw::jigsaw::BlockGrid,
    yg: jigsaw::jigsaw::BlockGrid,
    x: &Tensor,
    w: &Tensor,
    site: Site,
) -> Tensor {
    let net = jigsaw::comm::Network::new(mesh.n());
    let mut handles = Vec::new();
    for r in 0..mesh.n() {
        let mut comm = net.endpoint(r);
        let (xg, wg, yg) = (xg.clone(), wg.clone(), yg.clone());
        let (x, w) = (x.clone(), w.clone());
        handles.push(thread::spawn(move || {
            let backend = NativeBackend;
            let mut ctx = Ctx::new(mesh, r, &mut comm, &backend);
            let xd = DistMat::from_global(&x, xg, r);
            let wd = DistMat::from_global(&w, wg, r);
            dist_matmul(&mut ctx, op, &xd, &wd, &yg, site).unwrap()
        }));
    }
    let parts: Vec<DistMat> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let refs: Vec<&DistMat> = parts.iter().collect();
    DistMat::assemble(&refs)
}

#[test]
fn dist_matmul_on_planner_grids_matches_single_rank_oracle() {
    let mut rng = Rng::seed_from(0xE5);
    for (t, c) in [(1usize, 2usize), (2, 2), (2, 4), (4, 4)] {
        let mesh = Mesh::new(t, c).unwrap();
        let p = Planner::new(mesh);
        // dims divisible by every split in play
        let (tok, d, dch, dtok) = (8 * t.max(c), 8 * c, 12 * c, 4 * c);

        // channel-MLP forward: act x W_nt^T -> act (the paper's Eq 1/3)
        let x = rand_t(&mut rng, tok, d);
        let wnt = rand_t(&mut rng, dch, d);
        let got = run_planner_matmul(
            mesh,
            MatmulOp::NT,
            p.act(),
            p.weight_nt(),
            p.act(),
            &x,
            &wnt,
            Site::WOwner,
        );
        let want = ops::matmul_nt(&x, &wnt);
        assert!(
            got.max_abs_diff(&want) < 1e-4,
            "{mesh} NT err {}",
            got.max_abs_diff(&want)
        );

        // token-MLP forward: W1 x act -> tok_hidden (transposed-MLP form)
        let w1 = rand_t(&mut rng, dtok, tok);
        let u = rand_t(&mut rng, tok, d);
        let got = run_planner_matmul(
            mesh,
            MatmulOp::NN,
            p.weight_tok1(),
            p.act(),
            p.tok_hidden(),
            &w1,
            &u,
            Site::XOwner,
        );
        let want = ops::matmul_nn(&w1, &u);
        assert!(
            got.max_abs_diff(&want) < 1e-4,
            "{mesh} NN err {}",
            got.max_abs_diff(&want)
        );

        // weight-gradient form: dY^T x X -> weight_nt grid
        let dy = rand_t(&mut rng, tok, dch);
        let got = run_planner_matmul(
            mesh,
            MatmulOp::TN,
            p.act(),
            p.act(),
            p.weight_nt(),
            &dy,
            &x,
            Site::WOwner,
        );
        let want = ops::matmul_tn(&dy, &x);
        assert!(
            got.max_abs_diff(&want) < 1e-4,
            "{mesh} TN err {}",
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn loss_and_grads_invariant_to_token_axis() {
    // fixed channel split c: meshes 1xc, 2xc, (4xc) must produce the same
    // loss and the same reassembled gradients to 1e-4 — the 8-way (2x4)
    // and 16-way (4x4) acceptance gate against the flat-mesh oracle.
    let cfg = cfg();
    let global = init_global_params(&cfg, 21);
    let x = mk_sample(&cfg, 31);
    let y = mk_sample(&cfg, 32);
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
    for c in [2usize, 4] {
        let (oracle_loss, oracle_grads) = run_dist_loss_and_grad(
            &cfg,
            &Mesh::new(1, c).unwrap(),
            &global,
            &x,
            &y,
            backend.clone(),
            1,
        )
        .unwrap();
        for t in [2usize, 4] {
            if t > c {
                continue; // 4x2 is rejected by construction
            }
            let mesh = Mesh::new(t, c).unwrap();
            let (loss, grads) =
                run_dist_loss_and_grad(&cfg, &mesh, &global, &x, &y, backend.clone(), 1)
                    .unwrap();
            assert!(
                (loss - oracle_loss).abs() <= 1e-4 * oracle_loss.abs().max(1.0),
                "{mesh} loss {loss} vs 1x{c} oracle {oracle_loss}"
            );
            for ((n, go), (_, gd)) in oracle_grads.iter().zip(&grads) {
                let err = go.max_abs_diff(gd);
                assert!(err <= 1e-4, "{mesh} grad '{n}' err {err} vs 1x{c}");
            }
        }
    }
}

#[test]
fn rollout_is_mesh_invariant_too() {
    // the randomized-rollout path reuses the processor on every mesh
    let cfg = cfg();
    let global = init_global_params(&cfg, 5);
    let x = mk_sample(&cfg, 51);
    let y = mk_sample(&cfg, 52);
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
    let (l_flat, g_flat) = run_dist_loss_and_grad(
        &cfg,
        &Mesh::new(1, 4).unwrap(),
        &global,
        &x,
        &y,
        backend.clone(),
        2,
    )
    .unwrap();
    let (l_8, g_8) = run_dist_loss_and_grad(
        &cfg,
        &Mesh::new(2, 4).unwrap(),
        &global,
        &x,
        &y,
        backend,
        2,
    )
    .unwrap();
    assert!((l_flat - l_8).abs() <= 1e-4 * l_flat.abs().max(1.0), "{l_flat} vs {l_8}");
    for ((n, a), (_, b)) in g_flat.iter().zip(&g_8) {
        let err = a.max_abs_diff(b);
        assert!(err <= 2e-4, "rollout grad '{n}' err {err}");
    }
}
