//! Property tests for the comm progress engine: collectives registered
//! with a `ProgressEngine` and driven from *inside the kernel driver*
//! (the `tensor::ops` hook that fires between register-tile row groups,
//! at band barriers, and in blocking-wait dry spots) must reduce
//! bit-identically to the emission-point-only scheduler and to the
//! post-hoc oracle — across mesh shapes, DP degrees, and seeded fabric
//! delays. The engine changes *when* ring hops retire, never what they
//! compute.

use std::time::Duration;

use jigsaw::benchkit::synth_config;
use jigsaw::comm::{FabricSpec, Network, ProgressEngine};
use jigsaw::config::ModelConfig;
use jigsaw::jigsaw::{Ctx, Mesh};
use jigsaw::model::dist::DistModel;
use jigsaw::model::init_global_params;
use jigsaw::model::params::{shard_params, PStore};
use jigsaw::runtime::native::NativeBackend;
use jigsaw::tensor::Tensor;
use jigsaw::trainer::oracle::sample_shard;
use jigsaw::trainer::{dp_allreduce_grads_bucketed, GradReduceScheduler};
use jigsaw::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Sched {
    PostHoc,
    Emission,
    Engine,
}

/// One full loss_and_grad + DP reduce on a `mesh x dp` world; returns
/// every rank's reduced gradient store, in world-rank order.
fn run_world(
    cfg: &ModelConfig,
    mesh: Mesh,
    dp: usize,
    bucket_elems: usize,
    fabric: Option<(FabricSpec, u64)>,
    sched: Sched,
) -> Vec<PStore> {
    let mp = mesh.n();
    let mp_nets: Vec<Network> = (0..dp).map(|_| Network::new(mp)).collect();
    let dp_net = Network::new(mp * dp);
    if let Some((spec, seed)) = fabric {
        dp_net.set_fabric(spec, seed);
    }
    let global = init_global_params(cfg, 7);
    let mut handles = Vec::new();
    for g in 0..dp {
        for r in 0..mp {
            let cfg = cfg.clone();
            let params = shard_params(&cfg, &mesh, r, &global).unwrap();
            let mut mp_comm = mp_nets[g].endpoint(r);
            let mut dp_comm = dp_net.endpoint(g * mp + r);
            handles.push(std::thread::spawn(move || {
                let backend = NativeBackend;
                let model = DistModel::new(cfg.clone(), &mesh, r, params);
                let mut rng = Rng::seed_from(0xD00D ^ g as u64);
                let mut d = vec![0.0; cfg.lat * cfg.lon * cfg.channels_padded];
                rng.fill_normal(&mut d, 1.0);
                let x =
                    Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d.clone());
                rng.fill_normal(&mut d, 1.0);
                let y = Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d);
                let (la, _, lc) = model.local_dims();
                let (lat0, ch0) = (model.lat_offset(), model.ch_offset());
                let xl = sample_shard(&x, (lat0, lat0 + la), (ch0, ch0 + lc));
                let yl = sample_shard(&y, (lat0, lat0 + la), (ch0, ch0 + lc));
                let dp_group = mesh.dp_group(dp, r);
                let mut ctx = Ctx::new(mesh, r, &mut mp_comm, &backend);
                match sched {
                    Sched::PostHoc => {
                        let (_, mut grads) =
                            model.loss_and_grad(&mut ctx, &xl, &yl, 1).unwrap();
                        dp_allreduce_grads_bucketed(
                            &mut grads,
                            &mut dp_comm,
                            &dp_group,
                            bucket_elems,
                        );
                        grads
                    }
                    Sched::Emission | Sched::Engine => {
                        let mut s = if sched == Sched::Engine {
                            GradReduceScheduler::new(
                                &mut dp_comm,
                                &dp_group,
                                bucket_elems,
                            )
                        } else {
                            GradReduceScheduler::new_emission_only(
                                &mut dp_comm,
                                &dp_group,
                                bucket_elems,
                            )
                        };
                        let (_, mut grads) = model
                            .loss_and_grad_with(&mut ctx, &xl, &yl, 1, &mut s)
                            .unwrap();
                        s.finish(&mut grads);
                        grads
                    }
                }
            }));
        }
    }
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

fn assert_stores_bit_equal(a: &PStore, b: &PStore, ctx: &str) {
    assert_eq!(a.mats.len(), b.mats.len(), "{ctx}: mat count");
    for (name, ma) in &a.mats {
        let mb = &b.mats[name];
        for (key, ta) in &ma.blocks {
            let tb = &mb.blocks[key];
            for (i, (va, vb)) in ta.data.iter().zip(&tb.data).enumerate() {
                assert_eq!(
                    va.to_bits(),
                    vb.to_bits(),
                    "{ctx}: mat {name} block {key:?} elem {i}: {va} vs {vb}"
                );
            }
        }
    }
    for (name, va) in &a.vecs {
        let vb = &b.vecs[name];
        for (i, (x, y)) in va.local.data.iter().zip(&vb.local.data).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{ctx}: vec {name} elem {i}: {x} vs {y}"
            );
        }
    }
}

#[test]
fn engine_driven_reduce_bit_identical_across_meshes_and_dp() {
    let cfg = synth_config("progress-props", 32, 48, 2);
    let meshes = [
        Mesh::new(1, 1).unwrap(),
        Mesh::new(1, 2).unwrap(),
        Mesh::new(2, 2).unwrap(),
        Mesh::new(2, 4).unwrap(),
    ];
    for mesh in meshes {
        for dp in [2usize, 4] {
            let ctx = format!("mesh {mesh} dp {dp}");
            let oracle = run_world(&cfg, mesh, dp, 4096, None, Sched::PostHoc);
            let emission = run_world(&cfg, mesh, dp, 4096, None, Sched::Emission);
            let engine = run_world(&cfg, mesh, dp, 4096, None, Sched::Engine);
            for ((a, b), c) in oracle.iter().zip(&emission).zip(&engine) {
                assert_stores_bit_equal(a, b, &format!("{ctx} emission"));
                assert_stores_bit_equal(a, c, &format!("{ctx} engine"));
            }
        }
    }
}

#[test]
fn engine_driven_reduce_bit_identical_under_seeded_delays() {
    // 400us-latency DP fabric scrambles which hook site (kernel row
    // group, band barrier, dry-wait, drain) happens to retire each ring
    // hop; the result must not care
    let cfg = synth_config("progress-props-fab", 32, 48, 2);
    let spec = FabricSpec {
        latency: Duration::from_micros(400),
        jitter: Duration::from_micros(300),
        bytes_per_sec: 5e8,
    };
    let mesh = Mesh::new(2, 2).unwrap();
    let oracle = run_world(&cfg, mesh, 2, 512, None, Sched::PostHoc);
    for seed in [3u64, 77] {
        let engine = run_world(&cfg, mesh, 2, 512, Some((spec, seed)), Sched::Engine);
        for (a, b) in oracle.iter().zip(&engine) {
            assert_stores_bit_equal(a, b, &format!("seed {seed}"));
        }
    }
}

#[test]
fn kernel_driver_ticks_alone_complete_a_registered_collective() {
    // no scheduler, no explicit engine.poll(): the collective is driven
    // exclusively through the kernel driver's callback — exactly what a
    // long matmul does between row groups while a bucket ring is in
    // flight
    let net = Network::new(2);
    let mut handles = Vec::new();
    for r in 0..2usize {
        let mut c = net.endpoint(r);
        handles.push(std::thread::spawn(move || {
            let engine = ProgressEngine::new(&c);
            let _guard = engine.install();
            let t = Tensor::new(vec![64], vec![(r + 1) as f32; 64]);
            let ticket = engine.register(c.allreduce_start(&[0, 1], t));
            while !engine.is_done(&ticket) {
                if !jigsaw::tensor::ops::driver_tick() {
                    std::thread::yield_now();
                }
            }
            engine.try_take(&ticket).unwrap().data
        }));
    }
    for h in handles {
        assert_eq!(h.join().unwrap(), vec![3.0; 64]);
    }
}

#[test]
fn engine_hook_reaches_jigsaw_dry_waits() {
    // an MP-heavy mesh under a *delayed MP fabric* forces dist_matmul
    // into its dry-waits while DP rings are in flight on the other
    // (instantaneous) fabric: the hook must fire there without
    // cross-fabric deadlock and leave the gradients numerically intact.
    // (Tolerance, not bits: delayed MP delivery legitimately reorders
    // dist_matmul's term accumulation within fp noise — the documented
    // ready-queue wobble — so only the DP reduction is order-pinned.)
    let cfg = synth_config("progress-props-mp", 32, 48, 2);
    let mesh = Mesh::new(2, 2).unwrap();
    let mp = mesh.n();
    let dp = 2usize;
    let oracle = run_world(&cfg, mesh, dp, 1024, None, Sched::PostHoc);

    // same world, but with the delay injector on every MP fabric
    let mp_nets: Vec<Network> = (0..dp).map(|_| Network::new(mp)).collect();
    for net in &mp_nets {
        net.set_fabric(
            FabricSpec {
                latency: Duration::from_micros(200),
                jitter: Duration::from_micros(150),
                bytes_per_sec: 1e9,
            },
            11,
        );
    }
    let dp_net = Network::new(mp * dp);
    let global = init_global_params(&cfg, 7);
    let mut handles = Vec::new();
    for g in 0..dp {
        for r in 0..mp {
            let cfg = cfg.clone();
            let params = shard_params(&cfg, &mesh, r, &global).unwrap();
            let mut mp_comm = mp_nets[g].endpoint(r);
            let mut dp_comm = dp_net.endpoint(g * mp + r);
            handles.push(std::thread::spawn(move || {
                let backend = NativeBackend;
                let model = DistModel::new(cfg.clone(), &mesh, r, params);
                let mut rng = Rng::seed_from(0xD00D ^ g as u64);
                let mut d = vec![0.0; cfg.lat * cfg.lon * cfg.channels_padded];
                rng.fill_normal(&mut d, 1.0);
                let x =
                    Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d.clone());
                rng.fill_normal(&mut d, 1.0);
                let y = Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d);
                let (la, _, lc) = model.local_dims();
                let (lat0, ch0) = (model.lat_offset(), model.ch_offset());
                let xl = sample_shard(&x, (lat0, lat0 + la), (ch0, ch0 + lc));
                let yl = sample_shard(&y, (lat0, lat0 + la), (ch0, ch0 + lc));
                let dp_group = mesh.dp_group(dp, r);
                let mut ctx = Ctx::new(mesh, r, &mut mp_comm, &backend);
                let mut s =
                    GradReduceScheduler::new(&mut dp_comm, &dp_group, 1024);
                let (_, mut grads) = model
                    .loss_and_grad_with(&mut ctx, &xl, &yl, 1, &mut s)
                    .unwrap();
                s.finish(&mut grads);
                grads
            }));
        }
    }
    let engine: Vec<PStore> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (a, b) in oracle.iter().zip(&engine) {
        for (name, ma) in &a.mats {
            let mb = &b.mats[name];
            for (key, ta) in &ma.blocks {
                let d = ta.max_abs_diff(&mb.blocks[key]);
                assert!(d < 1e-4, "mat {name} block {key:?} diff {d}");
            }
        }
        for (name, va) in &a.vecs {
            let d = va.local.max_abs_diff(&b.vecs[name].local);
            assert!(d < 1e-4, "vec {name} diff {d}");
        }
    }
}
