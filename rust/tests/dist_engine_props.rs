//! System-level invariants of the jigsaw engine: communication volumes,
//! zero-redundancy memory, and domain-parallel I/O ratios — the paper's
//! Section 4 claims, checked on the real engine rather than the analytic
//! perf model.

mod common;

use std::sync::Arc;

use jigsaw::comm::Network;
use jigsaw::config::ModelConfig;
use jigsaw::jigsaw::{Ctx, Mesh};
use jigsaw::model::dist::DistModel;
use jigsaw::model::init_global_params;
use jigsaw::model::params::shard_params;
use jigsaw::runtime::native::NativeBackend;
use jigsaw::runtime::Backend;
use jigsaw::tensor::Tensor;
use jigsaw::trainer::oracle::sample_shard;
use jigsaw::util::prop::check;
use jigsaw::util::rng::Rng;

fn mk_sample(cfg: &ModelConfig, seed: u64) -> Tensor {
    let mut rng = Rng::seed_from(seed);
    let mut d = vec![0.0; cfg.lat * cfg.lon * cfg.channels_padded];
    rng.fill_normal(&mut d, 1.0);
    Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d)
}

/// Run one mesh-parallel loss_and_grad over a fresh fabric; return total
/// fabric bytes.
fn fabric_bytes(cfg: &ModelConfig, mesh: Mesh, seed: u64) -> u64 {
    let net = Network::new(mesh.n());
    let global = init_global_params(cfg, seed);
    let x = mk_sample(cfg, seed + 1);
    let y = mk_sample(cfg, seed + 2);
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
    let mut handles = Vec::new();
    for r in 0..mesh.n() {
        let cfg = cfg.clone();
        let mut comm = net.endpoint(r);
        let backend = backend.clone();
        let global = global.clone();
        let (x, y) = (x.clone(), y.clone());
        handles.push(std::thread::spawn(move || {
            let store = shard_params(&cfg, &mesh, r, &global).unwrap();
            let model = DistModel::new(cfg, &mesh, r, store);
            let (la, _, lc) = model.local_dims();
            let (lat0, ch0) = (model.lat_offset(), model.ch_offset());
            let xl = sample_shard(&x, (lat0, lat0 + la), (ch0, ch0 + lc));
            let yl = sample_shard(&y, (lat0, lat0 + la), (ch0, ch0 + lc));
            let mut ctx = Ctx::new(mesh, r, &mut comm, backend.as_ref());
            model.loss_and_grad(&mut ctx, &xl, &yl, 1).unwrap();
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    net.total_bytes()
}

#[test]
fn one_way_has_zero_comm() {
    let cfg = common::config("tiny");
    assert_eq!(
        fabric_bytes(&cfg, Mesh::unit(), 3),
        0,
        "1-way must not communicate"
    );
}

#[test]
fn comm_grows_with_way_but_stays_bounded() {
    let cfg = common::config("tiny");
    let b2 = fabric_bytes(&cfg, Mesh::from_degree(2).unwrap(), 5);
    let b4 = fabric_bytes(&cfg, Mesh::from_degree(4).unwrap(), 5);
    assert!(b2 > 0 && b4 > b2, "b2={b2} b4={b4}");
    // communication must stay far below an allgather-everything scheme:
    // <= ~3 shard-sized messages per linear layer per pass
    let act_bytes = (cfg.tokens * cfg.d_emb.max(cfg.patch_dim) * 4) as u64;
    let n_linear = (4 * cfg.blocks + 2) as u64;
    let bound = 3 * n_linear * 3 * act_bytes + (1 << 16);
    assert!(b4 < bound, "4-way comm {b4} exceeds jigsaw bound {bound}");
}

#[test]
fn zero_memory_redundancy_across_ways() {
    // paper Section 4: each rank holds exactly 1/n of every weight matrix
    let cfg = common::config("small");
    let global = init_global_params(&cfg, 1);
    let total_mat: usize = global
        .iter()
        .filter(|(_, t)| t.rank() == 2)
        .map(|(_, t)| t.numel())
        .sum();
    for way in [2usize, 4, 8] {
        let w = Mesh::from_degree(way).unwrap();
        if w.validate_config(&cfg).is_err() {
            continue;
        }
        for r in 0..way {
            let store = shard_params(&cfg, &w, r, &global).unwrap();
            let local_mat: usize = store
                .mats
                .values()
                .flat_map(|m| m.blocks.values().map(|b| b.numel()))
                .sum();
            assert_eq!(
                local_mat,
                total_mat / way,
                "rank {r} of {way}-way holds wrong weight fraction"
            );
        }
    }
}

#[test]
fn property_loss_invariant_to_way() {
    // the group-reduced loss must be identical (to fp tolerance) across
    // 2- and 4-way for arbitrary random parameters and samples
    let cfg = common::config("tiny");
    check("loss invariant to way", 5, |g| {
        let seed = g.rng.next_u64() % 1000;
        let global = init_global_params(&cfg, seed);
        let x = mk_sample(&cfg, seed + 10);
        let y = mk_sample(&cfg, seed + 20);
        let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
        let run = |mesh: Mesh| -> f32 {
            jigsaw::trainer::oracle::run_dist_loss_and_grad(
                &cfg, &mesh, &global, &x, &y, backend.clone(), 1,
            )
            .unwrap()
            .0
        };
        let (l2, l4) = (
            run(Mesh::from_degree(2).unwrap()),
            run(Mesh::from_degree(4).unwrap()),
        );
        if (l2 - l4).abs() < 1e-4 * l2.abs().max(1.0) {
            Ok(())
        } else {
            Err(format!("l2={l2} l4={l4}"))
        }
    });
}

#[test]
fn domain_parallel_read_volume_partition() {
    // the paper's I/O claim on the real loader: the 4 ranks together read
    // (about) one sample's physical bytes — not 4 copies
    let cfg = common::config("tiny");
    let mut l1 =
        jigsaw::data::ShardedLoader::new(&cfg, &Mesh::unit(), 0, 8, 1, 3, 8).unwrap();
    let full: u64 = l1.next_item().bytes_read;
    let mesh4 = Mesh::from_degree(4).unwrap();
    let mut total4 = 0u64;
    for r in 0..4 {
        let mut l =
            jigsaw::data::ShardedLoader::new(&cfg, &mesh4, r, 8, 1, 3, 8).unwrap();
        total4 += l.next_item().bytes_read;
    }
    assert!(
        total4 <= full,
        "4-way ranks together read {total4} > 1-way {full}"
    );
    assert!(total4 * 2 > full, "shards should cover the physical sample");
}
