//! The rust per-shard Adam + clip pipeline must reproduce the fused AOT
//! `train_step` program (loss + grads + global-norm clip + Adam) for the
//! 1-way model, and n-way training must stay consistent with 1-way at the
//! parameter level after an update.

mod common;

use std::sync::Arc;

use jigsaw::comm::Network;
use jigsaw::jigsaw::{Ctx, Mesh};
use jigsaw::model::dist::DistModel;
use jigsaw::model::params::{assemble_params, shard_params};
use jigsaw::model::{init_global_params, param_order};
use jigsaw::optim::Adam;
use jigsaw::runtime::engine::PjrtBackend;
use jigsaw::runtime::Backend;
use jigsaw::tensor::Tensor;
use jigsaw::trainer::oracle::sample_shard;
use jigsaw::util::rng::Rng;

fn mk_sample(cfg: &jigsaw::config::ModelConfig, seed: u64) -> Tensor {
    let mut rng = Rng::seed_from(seed);
    let mut d = vec![0.0; cfg.lat * cfg.lon * cfg.channels_padded];
    rng.fill_normal(&mut d, 1.0);
    Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d)
}

#[test]
fn rust_adam_step_matches_aot_train_step() {
    if !common::can_run_programs() {
        eprintln!("skipping train_step oracle: HLO programs need the pjrt feature");
        return;
    }
    let cfg = common::config("tiny");
    let engine = common::engine("tiny");
    let params = init_global_params(&cfg, 3);
    let x = mk_sample(&cfg, 10);
    let y = mk_sample(&cfg, 11);
    let lr = 1e-3f32;

    // -- oracle: the fused jax program (step=1, zero moments) ------------
    let mut inputs: Vec<Tensor> = params.iter().map(|(_, t)| t.clone()).collect();
    let zeros: Vec<Tensor> = params
        .iter()
        .map(|(_, t)| Tensor::zeros(&t.shape))
        .collect();
    inputs.extend(zeros.clone()); // m
    inputs.extend(zeros); // v
    inputs.push(Tensor::scalar(1.0)); // step (1-based)
    inputs.push(Tensor::scalar(lr));
    inputs.push(x.clone());
    inputs.push(y.clone());
    let outs = engine.run_program("train_step", inputs).unwrap();
    let n = param_order(&cfg).len();
    assert_eq!(outs.len(), 1 + 3 * n);
    let loss_oracle = outs[0].data[0];
    let new_params_oracle = &outs[1..1 + n];

    // -- rust: dist loss_and_grad + clip + Adam on 1 rank ------------------
    let backend: Arc<dyn Backend> = Arc::new(PjrtBackend { engine: engine.clone() });
    let net = Network::new(1);
    let mut comm = net.endpoint(0);
    let store = shard_params(&cfg, &Mesh::unit(), 0, &params).unwrap();
    let mut model = DistModel::new(cfg.clone(), &Mesh::unit(), 0, store);
    let mut ctx = Ctx::new(Mesh::unit(), 0, &mut comm, backend.as_ref());
    let (loss, grads) = model.loss_and_grad(&mut ctx, &x, &y, 1).unwrap();
    assert!((loss - loss_oracle).abs() < 1e-5, "{loss} vs {loss_oracle}");
    let clip = Adam::clip_scale(&grads, &mut comm, &[0]);
    let mut adam = Adam::new(&model.params, lr);
    adam.update(&mut model.params, &grads, clip);

    let got = assemble_params(&cfg, &[&model.params]);
    for (i, name) in param_order(&cfg).iter().enumerate() {
        let err = got[i].1.max_abs_diff(&new_params_oracle[i]);
        assert!(err < 1e-5, "param '{name}' post-step err {err}");
    }
}

#[test]
fn n_way_update_consistent_with_1_way() {
    // One full update step in 2-way must land on (numerically) the same
    // parameters as 1-way when LN grouping matches — validated through
    // the shared loss value and a small post-step parameter distance.
    let cfg = common::config("tiny");
    let engine = common::engine("tiny");
    let backend: Arc<dyn Backend> = Arc::new(PjrtBackend { engine });
    let global = init_global_params(&cfg, 8);
    let x = mk_sample(&cfg, 20);
    let y = mk_sample(&cfg, 21);
    let lr = 1e-3f32;

    let run = |way: usize| -> Vec<(String, Tensor)> {
        let w = Mesh::from_degree(way).unwrap();
        let net = Network::new(way);
        let mut handles = Vec::new();
        for r in 0..way {
            let cfg = cfg.clone();
            let mut comm = net.endpoint(r);
            let backend = backend.clone();
            let global = global.clone();
            let (x, y) = (x.clone(), y.clone());
            handles.push(std::thread::spawn(move || {
                let store = shard_params(&cfg, &w, r, &global).unwrap();
                let mut model = DistModel::new(cfg, &w, r, store);
                let (la, _, lc) = model.local_dims();
                let lat0 = model.lat_offset();
                let ch0 = model.ch_offset();
                let xl = sample_shard(&x, (lat0, lat0 + la), (ch0, ch0 + lc));
                let yl = sample_shard(&y, (lat0, lat0 + la), (ch0, ch0 + lc));
                let mut ctx = Ctx::new(w, r, &mut comm, backend.as_ref());
                let (_, grads) = model.loss_and_grad(&mut ctx, &xl, &yl, 1).unwrap();
                let clip = Adam::clip_scale(&grads, &mut comm, &(0..way).collect::<Vec<_>>());
                let mut adam = Adam::new(&model.params, lr);
                adam.update(&mut model.params, &grads, clip);
                model.params
            }));
        }
        let stores: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let refs: Vec<&_> = stores.iter().collect();
        assemble_params(&cfg, &refs)
    };

    let p2 = run(2);
    let p4 = run(4);
    // 2-way and 4-way share LN statistics (channel halves) -> identical
    for ((n, a), (_, b)) in p2.iter().zip(&p4) {
        let err = a.max_abs_diff(b);
        assert!(err < 1e-5, "2-way vs 4-way param '{n}' err {err}");
    }
}
