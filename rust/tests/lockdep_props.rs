//! Runtime lock-order witness (lockdep) properties:
//!
//!   * a forced two-lock inversion panics on the *first* ordering
//!     cycle, naming both lock classes and both acquisition chains —
//!     before the schedule that would actually deadlock;
//!   * a full 2x2 mesh training step under `JIGSAW_LOCKDEP`-style
//!     enablement is finding-free and bit-identical to the
//!     witness-off run (the witness only observes), and the witness
//!     provably watched it (the `comm.queues -> comm.waiters` edge is
//!     in the held-before graph afterwards);
//!   * the serving stack's worker threads ([`RolloutEngine`] rank
//!     threads under a [`ServeEngine`]) answer a seeded query stream
//!     clean under the witness, bit-identical to the witness-off run.
//!
//! The lockdep default is process-wide, so every test here serializes
//! on one gate and resets the default via RAII — a failing assert must
//! not leak a pinned default into its siblings.

use std::sync::{Arc, Mutex};

use jigsaw::benchkit::{synth_config, TrafficGen};
use jigsaw::comm::{set_deadlock_detect_default, FabricSpec};
use jigsaw::jigsaw::Mesh;
use jigsaw::model::init_global_params;
use jigsaw::runtime::native::NativeBackend;
use jigsaw::runtime::Backend;
use jigsaw::serve::{RegionQuery, RolloutEngine, ServeEngine};
use jigsaw::tensor::{Precision, Tensor};
use jigsaw::trainer::oracle::run_dist_loss_and_grad;
use jigsaw::util::rng::Rng;
use jigsaw::util::{lockdep, plock, plock_named};

/// Serializes the tests in this binary: each pins the process-wide
/// lockdep default, and cargo runs tests on parallel threads.
static GATE: Mutex<()> = Mutex::new(());

/// RAII reset so a failing assert can't leak a pinned lockdep (or
/// deadlock-detector) default into other tests in this binary.
struct DefaultReset;
impl Drop for DefaultReset {
    fn drop(&mut self) {
        lockdep::set_lockdep_default(None);
        set_deadlock_detect_default(None);
    }
}

fn panic_text(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else {
        String::new()
    }
}

#[test]
fn forced_inversion_panics_naming_both_classes_and_chains() {
    let _g = plock(&GATE);
    let _reset = DefaultReset;
    lockdep::set_lockdep_default(Some(true));

    let ma = Mutex::new(0u32);
    let mb = Mutex::new(0u32);
    {
        // teach the graph alpha -> beta
        let a = plock_named(&ma, "lockdep-props.alpha");
        let _b = plock_named(&mb, "lockdep-props.beta");
        drop(a);
    }
    // now invert: beta held, alpha requested — must panic on the
    // acquisition, before ever blocking on the mutex
    let b = plock_named(&mb, "lockdep-props.beta");
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let _a = plock_named(&ma, "lockdep-props.alpha");
    }))
    .expect_err("inverted acquisition order must panic");
    drop(b);

    let msg = panic_text(&*err);
    assert!(msg.contains("lockdep"), "not a lockdep panic: {msg}");
    assert!(msg.contains("lockdep-props.alpha"), "missing class alpha: {msg}");
    assert!(msg.contains("lockdep-props.beta"), "missing class beta: {msg}");
    assert!(msg.contains("while holding"), "missing current chain: {msg}");
    assert!(msg.contains("first seen"), "missing recorded chain: {msg}");
}

#[test]
fn mesh_training_under_lockdep_is_finding_free_and_bit_identical() {
    let _g = plock(&GATE);
    let _reset = DefaultReset;
    // the deadlock detector stays on for BOTH runs so the only variable
    // is the witness — and so the waiter registry (the queues->waiters
    // nesting) is actually exercised
    set_deadlock_detect_default(Some(true));

    let cfg = jigsaw::config::ModelConfig {
        name: "lockdep-props".into(),
        lat: 8,
        lon: 16,
        channels: 6,
        channels_padded: 8,
        patch: 2,
        d_emb: 32,
        d_tok: 48,
        d_ch: 32,
        blocks: 2,
        tokens: 32,
        patch_dim: 32,
        param_count: 12904,
        flops_forward: 0,
        channel_weights: vec![1.0; 6],
    };
    let global = init_global_params(&cfg, 21);
    let mk = |seed: u64| {
        let mut rng = Rng::seed_from(seed);
        let mut d = vec![0.0; cfg.lat * cfg.lon * cfg.channels_padded];
        rng.fill_normal(&mut d, 1.0);
        Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d)
    };
    let (x, y) = (mk(31), mk(32));
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
    let mesh = Mesh::new(2, 2).unwrap();

    let mut runs = Vec::new();
    for on in [false, true] {
        lockdep::set_lockdep_default(Some(on));
        // finding-free: any ordering cycle would panic a rank thread
        // and surface here as an Err / propagated panic
        let (loss, grads) =
            run_dist_loss_and_grad(&cfg, &mesh, &global, &x, &y, backend.clone(), 1).unwrap();
        runs.push((loss, grads));
    }

    // the witness provably watched the run: registering a waiter nests
    // the waiters lock under the queues lock
    let edges = lockdep::observed_edges();
    assert!(
        edges.contains(&("comm.queues".to_string(), "comm.waiters".to_string())),
        "witness never saw the queues->waiters nesting: {edges:?}"
    );

    let (loss_off, grads_off) = &runs[0];
    let (loss_on, grads_on) = &runs[1];
    assert_eq!(loss_off.to_bits(), loss_on.to_bits(), "loss differs with lockdep on");
    assert_eq!(grads_off.len(), grads_on.len());
    for ((n, a), (_, b)) in grads_off.iter().zip(grads_on.iter()) {
        assert_eq!(a.shape, b.shape, "grad '{n}' shape");
        for (va, vb) in a.data.iter().zip(b.data.iter()) {
            assert_eq!(va.to_bits(), vb.to_bits(), "grad '{n}' bits differ with lockdep on");
        }
    }
}

/// One pass of the seeded query stream through a fresh serving stack;
/// returns every answered window flattened to bit patterns.
fn serve_pass(seed: u64, n_queries: usize) -> Vec<u32> {
    let cfg = synth_config("lockdep-serve", 64, 48, 2);
    let mesh = Mesh::new(1, 2).unwrap();
    let global = init_global_params(&cfg, seed);
    let engine = RolloutEngine::new(
        &cfg,
        &mesh,
        &global,
        Arc::new(NativeBackend),
        Precision::F32,
        1,
    )
    .expect("rollout engine");
    engine.set_fabric(FabricSpec::from_us(100, 25, 1.0), seed);
    let mut srv = ServeEngine::new(engine, 8, 4, false);

    let mut rng = Rng::seed_from(seed ^ 0x5EED_1D);
    for id in 0..2u64 {
        let mut d = vec![0.0f32; cfg.lat * cfg.lon * cfg.channels_padded];
        rng.fill_normal(&mut d, 1.0);
        srv.add_init(id, Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d))
            .expect("add init");
    }

    let mut gen = TrafficGen::new(seed, 2, 4, cfg.lat, cfg.lon);
    let mut bits = Vec::new();
    for _ in 0..n_queries {
        let q: RegionQuery = gen.next_query();
        let ans = srv.answer(q).expect("serve worker answered clean");
        let v = ans.view();
        for i in 0..v.nrows() {
            for j in 0..v.ncols() {
                bits.push(v.at(i, j).to_bits());
            }
        }
    }
    bits
}

#[test]
fn serve_workers_run_clean_under_lockdep() {
    let _g = plock(&GATE);
    let _reset = DefaultReset;

    lockdep::set_lockdep_default(Some(false));
    let off = serve_pass(0xCAFE, 12);
    lockdep::set_lockdep_default(Some(true));
    let on = serve_pass(0xCAFE, 12);

    assert!(!off.is_empty());
    assert_eq!(off, on, "served bits differ with lockdep on");
}
