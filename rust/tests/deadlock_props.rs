//! Wait-graph deadlock detector properties:
//!
//!   * a forced two-rank recv/recv cycle on mismatched tags panics
//!     *immediately* with a typed [`CommError::Deadlock`] naming both
//!     ranks and both tags — instead of hanging until a CI timeout;
//!   * a legitimate blocking wait under `FabricSpec` delivery delay
//!     does NOT trip the detector (an in-flight message counts as
//!     progress even before its simulated delivery time);
//!   * the detector-disabled path is bit-identical to detector-enabled
//!     on an existing `mesh_props`-style distributed case (the checker
//!     only reads state — it must never perturb results).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use jigsaw::comm::{set_deadlock_detect_default, CommError, FabricSpec, Network};
use jigsaw::jigsaw::Mesh;
use jigsaw::model::init_global_params;
use jigsaw::runtime::native::NativeBackend;
use jigsaw::runtime::Backend;
use jigsaw::tensor::Tensor;
use jigsaw::trainer::oracle::run_dist_loss_and_grad;
use jigsaw::util::rng::Rng;

/// Abort the fabric if the test has not finished within `secs` — the
/// hang-breaker that turns a detector regression into a clean failure
/// (peers unwind with `Aborted`, which the asserts below reject)
/// instead of a wedged test binary.
fn watchdog(net: &Network, done: &Arc<AtomicBool>, secs: u64) -> thread::JoinHandle<()> {
    let net = net.clone();
    let done = done.clone();
    thread::spawn(move || {
        for _ in 0..secs * 20 {
            if done.load(Ordering::SeqCst) {
                return;
            }
            thread::sleep(Duration::from_millis(50));
        }
        net.abort();
    })
}

#[test]
fn forced_two_rank_cycle_panics_naming_both_ranks_and_tags() {
    let net = Network::new(2);
    net.set_deadlock_detect(true);
    let done = Arc::new(AtomicBool::new(false));
    let _dog = watchdog(&net, &done, 30);

    // rank 0 waits on (src 1, tag 0xb); rank 1 waits on (src 0, tag
    // 0x16); nobody ever sends — a textbook recv/recv tag mismatch
    let mut handles = Vec::new();
    for (rank, src, tag) in [(0usize, 1usize, 0xbu64), (1, 0, 0x16)] {
        let ep = net.endpoint(rank);
        handles.push(thread::spawn(move || {
            let _ = ep.recv(src, tag);
        }));
    }
    let payloads: Vec<CommError> = handles
        .into_iter()
        .map(|h| {
            let p = h.join().expect_err("rank must panic, not return");
            CommError::from_panic(&*p).expect("typed CommError payload")
        })
        .collect();
    done.store(true, Ordering::SeqCst);

    for (i, ce) in payloads.iter().enumerate() {
        match ce {
            CommError::Deadlock { desc } => {
                // the knot names every member and its waited keys
                assert!(desc.contains("rank 0"), "rank {i}: missing rank 0 in {desc:?}");
                assert!(desc.contains("rank 1"), "rank {i}: missing rank 1 in {desc:?}");
                assert!(desc.contains("src 1 tag 0xb"), "rank {i}: missing r0's key in {desc:?}");
                assert!(desc.contains("src 0 tag 0x16"), "rank {i}: missing r1's key in {desc:?}");
            }
            other => panic!("rank {i}: expected Deadlock, got {other:?} (watchdog fired?)"),
        }
    }
    // the fabric records the knot for post-mortems
    let info = net.deadlock_info().expect("deadlock recorded on the network");
    assert!(info.contains("rank 0") && info.contains("rank 1"));
    // and Display carries the diagnosis end to end
    let shown = payloads[0].to_string();
    assert!(shown.contains("deadlock") && shown.contains("rank 1"), "{shown}");
}

#[test]
fn in_flight_delayed_message_does_not_trip_detector() {
    let net = Network::new(2);
    net.set_deadlock_detect(true);
    net.set_fabric(
        FabricSpec {
            latency: Duration::from_millis(50),
            jitter: Duration::ZERO,
            bytes_per_sec: 1e12,
        },
        0xD1CE,
    );
    let done = Arc::new(AtomicBool::new(false));
    let _dog = watchdog(&net, &done, 30);

    // receiver parks first (registers with an empty queue), then the
    // send lands in-flight: for ~50ms the queue is non-empty but not
    // deliverable, and the detector must treat that as progress
    let ep1 = net.endpoint(1);
    let recv = thread::spawn(move || ep1.recv(0, 7));
    thread::sleep(Duration::from_millis(10));
    let ep0 = net.endpoint(0);
    ep0.send(1, 7, Tensor::new(vec![2], vec![3.0, 4.0]));
    let got = recv.join().expect("delayed delivery must complete, not panic");
    done.store(true, Ordering::SeqCst);
    assert_eq!(got.data, vec![3.0, 4.0]);
    assert!(net.deadlock_info().is_none(), "detector tripped on live traffic");
}

/// RAII reset so a failing assert can't leak a pinned process-wide
/// detector default into other tests in this binary.
struct DefaultReset;
impl Drop for DefaultReset {
    fn drop(&mut self) {
        set_deadlock_detect_default(None);
    }
}

#[test]
fn detector_disabled_path_is_bit_identical_on_mesh_case() {
    let _reset = DefaultReset;
    let cfg = jigsaw::config::ModelConfig {
        name: "deadlock-props".into(),
        lat: 8,
        lon: 16,
        channels: 6,
        channels_padded: 8,
        patch: 2,
        d_emb: 32,
        d_tok: 48,
        d_ch: 32,
        blocks: 2,
        tokens: 32,
        patch_dim: 32,
        param_count: 12904,
        flops_forward: 0,
        channel_weights: vec![1.0; 6],
    };
    let global = init_global_params(&cfg, 21);
    let mk = |seed: u64| {
        let mut rng = Rng::seed_from(seed);
        let mut d = vec![0.0; cfg.lat * cfg.lon * cfg.channels_padded];
        rng.fill_normal(&mut d, 1.0);
        Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d)
    };
    let (x, y) = (mk(31), mk(32));
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
    let mesh = Mesh::new(2, 2).unwrap();

    let mut runs = Vec::new();
    for on in [false, true] {
        set_deadlock_detect_default(Some(on));
        let (loss, grads) =
            run_dist_loss_and_grad(&cfg, &mesh, &global, &x, &y, backend.clone(), 1).unwrap();
        runs.push((loss, grads));
    }
    set_deadlock_detect_default(None);

    let (loss_off, grads_off) = &runs[0];
    let (loss_on, grads_on) = &runs[1];
    assert_eq!(loss_off.to_bits(), loss_on.to_bits(), "loss differs with detector on");
    assert_eq!(grads_off.len(), grads_on.len());
    for ((n, a), (_, b)) in grads_off.iter().zip(grads_on.iter()) {
        assert_eq!(a.shape, b.shape, "grad '{n}' shape");
        for (va, vb) in a.data.iter().zip(b.data.iter()) {
            assert_eq!(va.to_bits(), vb.to_bits(), "grad '{n}' bits differ with detector on");
        }
    }
}
