//! Checkpoint + elastic-reshard oracles (artifact-free, native backend):
//!
//!   * **checkpoint fidelity** — the assembled parameters of a saved
//!     checkpoint are bit-identical to the saving run's in-memory
//!     final parameters;
//!   * **reshard identity** — sharding the assembled state onto any
//!     viable target mesh and reassembling reproduces the globals bit
//!     for bit (the restore planner is a pure owner-map remapping);
//!   * **same-mesh resume** — save at step k, resume to k+m: losses and
//!     final weights bit-identical to an uninterrupted k+m run on the
//!     same mesh (f32 and bf16 — determinism holds at both precisions);
//!   * **cross-mesh resume** — save on mesh A, resume on mesh B: a
//!     doubly-interrupted resume on B is bit-identical to a singly
//!     interrupted one, i.e. once resharded onto B, the trajectory is
//!     exactly B's (cross-mesh *trajectories* differ in fp rounding —
//!     mesh_props pins that tolerance — so the oracle compares runs
//!     that share the resharded starting point);
//!   * **crash safety** — a torn shard write or missing manifest never
//!     yields a corrupt "latest": `latest()` falls back to the newest
//!     checkpoint whose digests verify;
//!   * **pruning** — keep-last-N retains exactly N step directories.

use std::path::PathBuf;
use std::sync::Arc;

use jigsaw::checkpoint::{self, CheckpointSpec};
use jigsaw::config::ModelConfig;
use jigsaw::jigsaw::Mesh;
use jigsaw::model::params::{assemble_params, shard_params};
use jigsaw::runtime::native::NativeBackend;
use jigsaw::tensor::{Precision, Tensor};
use jigsaw::trainer::{train, TrainSpec};

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "ckpt-test".into(),
        lat: 8,
        lon: 16,
        channels: 6,
        channels_padded: 8,
        patch: 2,
        d_emb: 32,
        d_tok: 48,
        d_ch: 32,
        blocks: 2,
        tokens: 32,
        patch_dim: 32,
        param_count: 12904,
        flops_forward: 0,
        channel_weights: vec![1.0; 6],
    }
}

fn tmp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("jigsaw-ckpt-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn spec(mesh: Mesh, dp: usize, steps: usize, prec: Precision) -> TrainSpec {
    let mut s = TrainSpec::with_mesh(mesh, dp, steps);
    s.seed = 7;
    s.precision = prec;
    s
}

fn assert_params_bitwise(
    a: &[(String, Tensor)],
    b: &[(String, Tensor)],
    what: &str,
) {
    assert_eq!(a.len(), b.len(), "{what}: param list length");
    for ((na, ta), (nb, tb)) in a.iter().zip(b) {
        assert_eq!(na, nb, "{what}: param order");
        assert_eq!(ta.shape, tb.shape, "{what}: {na} shape");
        for (i, (va, vb)) in ta.data.iter().zip(&tb.data).enumerate() {
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "{what}: {na}[{i}] {va} != {vb}"
            );
        }
    }
}

/// Train `k` steps with a checkpoint at step `k` in `dir`, on `mesh_a`.
fn save_run(
    c: &ModelConfig,
    mesh_a: Mesh,
    dp: usize,
    k: usize,
    prec: Precision,
    dir: &PathBuf,
) -> jigsaw::trainer::TrainReport {
    let mut s = spec(mesh_a, dp, k, prec);
    s.checkpoint = Some(CheckpointSpec { dir: dir.clone(), every: k, keep_last: 3 });
    train(c, &s, Arc::new(NativeBackend)).unwrap()
}

#[test]
fn checkpoint_params_match_in_memory_finals_bitwise() {
    let c = cfg();
    for (mesh, dp) in [
        (Mesh::unit(), 1usize),
        (Mesh::new(1, 2).unwrap(), 2),
        (Mesh::new(2, 2).unwrap(), 1),
    ] {
        let dir = tmp_dir(&format!("fidelity-{mesh}-dp{dp}"));
        let report = save_run(&c, mesh, dp, 3, Precision::F32, &dir);
        let meta = checkpoint::latest(&dir).unwrap().expect("checkpoint written");
        assert_eq!(meta.step, 3);
        assert_eq!(meta.dp, dp);
        let st = checkpoint::load_state(&c, &meta).unwrap();
        assert_params_bitwise(&st.params, &report.final_params, &format!("{mesh} dp{dp}"));
        assert_eq!(st.loaders.len(), dp);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn reshard_roundtrip_is_identity_across_meshes() {
    // layout-level oracle: assemble(shard(globals, mesh_b)) == globals,
    // for globals that came out of a real mesh_a checkpoint
    let c = cfg();
    let dir = tmp_dir("reshard-id");
    save_run(&c, Mesh::new(2, 2).unwrap(), 1, 2, Precision::F32, &dir);
    let meta = checkpoint::latest(&dir).unwrap().unwrap();
    let st = checkpoint::load_state(&c, &meta).unwrap();
    for mesh_b in [
        Mesh::unit(),
        Mesh::new(1, 2).unwrap(),
        Mesh::new(2, 2).unwrap(),
        Mesh::new(2, 4).unwrap(),
    ] {
        let stores: Vec<_> = (0..mesh_b.n())
            .map(|r| shard_params(&c, &mesh_b, r, &st.params).unwrap())
            .collect();
        let back = assemble_params(&c, &stores.iter().collect::<Vec<_>>());
        assert_params_bitwise(&back, &st.params, &format!("reshard via {mesh_b}"));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn same_mesh_resume_is_bit_identical_to_uninterrupted() {
    let c = cfg();
    let cases = [
        (Mesh::unit(), 1usize, Precision::F32),
        (Mesh::new(1, 2).unwrap(), 1, Precision::F32),
        (Mesh::new(2, 2).unwrap(), 2, Precision::F32),
        (Mesh::new(1, 2).unwrap(), 1, Precision::Bf16),
    ];
    for (mesh, dp, prec) in cases {
        let (k, m) = (3usize, 3usize);
        let dir = tmp_dir(&format!("resume-{mesh}-dp{dp}-{prec}"));
        // interrupted: k steps + checkpoint, then resume to k+m
        save_run(&c, mesh, dp, k, prec, &dir);
        let mut s2 = spec(mesh, dp, k + m, prec);
        s2.checkpoint = Some(CheckpointSpec { dir: dir.clone(), every: k, keep_last: 3 });
        s2.resume = true;
        let resumed = train(&c, &s2, Arc::new(NativeBackend)).unwrap();
        assert_eq!(resumed.resumed_from, Some(k));
        // uninterrupted reference (checkpointing on, so the collective
        // schedule matches; it only adds barriers, never arithmetic)
        let dir_u = tmp_dir(&format!("resume-u-{mesh}-dp{dp}-{prec}"));
        let mut su = spec(mesh, dp, k + m, prec);
        su.checkpoint =
            Some(CheckpointSpec { dir: dir_u.clone(), every: k, keep_last: 3 });
        let full = train(&c, &su, Arc::new(NativeBackend)).unwrap();

        assert_params_bitwise(
            &resumed.final_params,
            &full.final_params,
            &format!("{mesh} dp{dp} {prec}"),
        );
        // the resumed run's step records are the tail of the full run's
        assert_eq!(resumed.steps.len(), m);
        for (sr, sf) in resumed.steps.iter().zip(full.steps.iter().skip(k)) {
            assert_eq!(sr.step, sf.step);
            assert_eq!(
                sr.loss.to_bits(),
                sf.loss.to_bits(),
                "{mesh} dp{dp} {prec} step {} loss {} vs {}",
                sr.step,
                sr.loss,
                sf.loss
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&dir_u);
    }
}

#[test]
fn cross_mesh_resume_matches_target_mesh_trajectory() {
    // save on mesh A; resume on mesh B. Oracle: a second interruption on
    // B (resume, checkpoint again, resume again) lands bit-identically
    // on the single-resume run — i.e. after the reshard the trajectory
    // is purely B's own.
    let c = cfg();
    let cases = [
        // (mesh_a, dp_a, mesh_b, dp_b, precision)
        (Mesh::new(2, 2).unwrap(), 1usize, Mesh::unit(), 1usize, Precision::F32),
        (Mesh::new(2, 2).unwrap(), 1, Mesh::new(1, 2).unwrap(), 1, Precision::F32),
        (Mesh::unit(), 1, Mesh::new(2, 4).unwrap(), 1, Precision::F32),
        (Mesh::new(1, 2).unwrap(), 2, Mesh::new(2, 2).unwrap(), 1, Precision::F32),
        (Mesh::new(2, 2).unwrap(), 1, Mesh::new(1, 2).unwrap(), 1, Precision::Bf16),
    ];
    for (mesh_a, dp_a, mesh_b, dp_b, prec) in cases {
        let (k, j, m) = (2usize, 2usize, 2usize);
        let label = format!("{mesh_a}dp{dp_a} -> {mesh_b}dp{dp_b} {prec}");
        let dir1 = tmp_dir(&format!("xmesh1-{label}"));
        save_run(&c, mesh_a, dp_a, k, prec, &dir1);

        // single interruption: resume on B straight to k+j+m
        let dir_single = tmp_dir(&format!("xmesh-single-{label}"));
        copy_tree(&dir1, &dir_single);
        let mut s_single = spec(mesh_b, dp_b, k + j + m, prec);
        s_single.checkpoint =
            Some(CheckpointSpec { dir: dir_single.clone(), every: k + j, keep_last: 3 });
        s_single.resume = true;
        let single = train(&c, &s_single, Arc::new(NativeBackend)).unwrap();
        assert_eq!(single.resumed_from, Some(k), "{label}");

        // double interruption: resume on B to k+j (checkpointing at
        // k+j), then resume again on B to k+j+m
        let dir_double = tmp_dir(&format!("xmesh-double-{label}"));
        copy_tree(&dir1, &dir_double);
        let mut s_d1 = spec(mesh_b, dp_b, k + j, prec);
        s_d1.checkpoint =
            Some(CheckpointSpec { dir: dir_double.clone(), every: k + j, keep_last: 3 });
        s_d1.resume = true;
        let d1 = train(&c, &s_d1, Arc::new(NativeBackend)).unwrap();
        assert_eq!(d1.resumed_from, Some(k), "{label}");
        let mut s_d2 = spec(mesh_b, dp_b, k + j + m, prec);
        s_d2.checkpoint =
            Some(CheckpointSpec { dir: dir_double.clone(), every: k + j, keep_last: 3 });
        s_d2.resume = true;
        let d2 = train(&c, &s_d2, Arc::new(NativeBackend)).unwrap();
        assert_eq!(d2.resumed_from, Some(k + j), "{label}");

        assert_params_bitwise(&d2.final_params, &single.final_params, &label);
        // the double run's final leg matches the single run's tail
        for (sr, sf) in d2.steps.iter().zip(single.steps.iter().skip(j)) {
            assert_eq!(sr.step, sf.step, "{label}");
            assert_eq!(sr.loss.to_bits(), sf.loss.to_bits(), "{label} step {}", sr.step);
        }
        for d in [&dir1, &dir_single, &dir_double] {
            let _ = std::fs::remove_dir_all(d);
        }
    }
}

/// Recursive copy (std-only; test fixture helper).
fn copy_tree(src: &PathBuf, dst: &PathBuf) {
    std::fs::create_dir_all(dst).unwrap();
    for e in std::fs::read_dir(src).unwrap() {
        let e = e.unwrap();
        let to = dst.join(e.file_name());
        if e.file_type().unwrap().is_dir() {
            copy_tree(&e.path(), &to);
        } else {
            std::fs::copy(e.path(), &to).unwrap();
        }
    }
}

#[test]
fn corrupt_or_torn_checkpoints_fall_back_to_older_valid_ones() {
    let c = cfg();
    let dir = tmp_dir("fallback");
    // checkpoints at steps 2 and 4
    let mut s = spec(Mesh::new(1, 2).unwrap(), 1, 4, Precision::F32);
    s.checkpoint = Some(CheckpointSpec { dir: dir.clone(), every: 2, keep_last: 3 });
    train(&c, &s, Arc::new(NativeBackend)).unwrap();
    assert_eq!(checkpoint::latest(&dir).unwrap().unwrap().step, 4);

    // flip one byte inside step-4's shard: digest mismatch -> fall back
    let shard4 = dir.join("step-00000004").join("shard-mp0.bin");
    let mut bytes = std::fs::read(&shard4).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&shard4, &bytes).unwrap();
    let meta = checkpoint::latest(&dir).unwrap().expect("older checkpoint survives");
    assert_eq!(meta.step, 2, "torn step-4 shard must not be 'latest'");

    // a manifest-less directory (the kill-mid-write shape: files
    // present, rename never happened) is skipped entirely
    let half = dir.join("step-00000006");
    std::fs::create_dir_all(&half).unwrap();
    std::fs::write(half.join("shard-mp0.bin"), b"partial garbage").unwrap();
    std::fs::write(half.join("manifest.json.tmp"), b"{\"step\":6").unwrap();
    let meta = checkpoint::latest(&dir).unwrap().unwrap();
    assert_eq!(meta.step, 2);

    // the fallback checkpoint actually loads and resumes
    let mut s2 = spec(Mesh::new(1, 2).unwrap(), 1, 5, Precision::F32);
    s2.checkpoint = Some(CheckpointSpec { dir: dir.clone(), every: 10, keep_last: 3 });
    s2.resume = true;
    let r = train(&c, &s2, Arc::new(NativeBackend)).unwrap();
    assert_eq!(r.resumed_from, Some(2));
    assert_eq!(r.steps.first().unwrap().step, 2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn keep_last_prunes_old_step_directories() {
    let c = cfg();
    let dir = tmp_dir("prune");
    let mut s = spec(Mesh::unit(), 1, 5, Precision::F32);
    s.checkpoint = Some(CheckpointSpec { dir: dir.clone(), every: 1, keep_last: 2 });
    train(&c, &s, Arc::new(NativeBackend)).unwrap();
    let mut kept: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.starts_with("step-"))
        .collect();
    kept.sort();
    assert_eq!(kept, vec!["step-00000004", "step-00000005"], "{kept:?}");
    assert_eq!(checkpoint::latest(&dir).unwrap().unwrap().step, 5);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mismatched_config_or_precision_is_refused() {
    let c = cfg();
    let dir = tmp_dir("refuse");
    save_run(&c, Mesh::unit(), 1, 2, Precision::F32, &dir);
    let meta = checkpoint::latest(&dir).unwrap().unwrap();

    // different architecture -> load_state refuses
    let mut other = cfg();
    other.d_emb = 64;
    other.d_ch = 64;
    let err = checkpoint::load_state(&other, &meta).unwrap_err();
    assert!(err.to_string().contains("refusing"), "{err}");

    // different precision -> train refuses the resume
    let mut s = spec(Mesh::unit(), 1, 4, Precision::Bf16);
    s.checkpoint = Some(CheckpointSpec { dir: dir.clone(), every: 10, keep_last: 3 });
    s.resume = true;
    let err = train(&c, &s, Arc::new(NativeBackend)).unwrap_err();
    assert!(err.to_string().contains("precision"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}
