//! Shared helpers for the integration tests: one PJRT engine per preset
//! per test binary (the CPU client is heavyweight; tests share it).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, OnceLock};

use jigsaw::config::{Manifest, ModelConfig};
use jigsaw::runtime::engine::Engine;

pub fn artifacts() -> PathBuf {
    // integration tests run from the workspace root
    let p = PathBuf::from("artifacts");
    assert!(
        p.join("tiny").join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    p
}

static ENGINES: OnceLock<Mutex<HashMap<String, Arc<Engine>>>> = OnceLock::new();

pub fn engine(preset: &str) -> Arc<Engine> {
    let map = ENGINES.get_or_init(|| Mutex::new(HashMap::new()));
    let mut m = jigsaw::util::plock(map);
    m.entry(preset.to_string())
        .or_insert_with(|| {
            let manifest = Manifest::load(&artifacts(), preset).expect("manifest");
            Engine::start(manifest).expect("engine start")
        })
        .clone()
}

pub fn config(preset: &str) -> ModelConfig {
    ModelConfig::load(&artifacts(), preset).expect("config")
}

/// Monolithic HLO programs only execute with the `pjrt` feature (and real
/// bindings patched over the stub). The featureless engine still serves
/// every matmul natively against the checked-in manifest, so tests that
/// need `run_program` skip rather than fail in the default build.
pub fn can_run_programs() -> bool {
    cfg!(feature = "pjrt")
}
