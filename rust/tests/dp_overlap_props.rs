//! Property tests for the grad-ready (backward-overlapped) DP gradient
//! reduction: the `GradReduceScheduler` driven through
//! `DistModel::loss_and_grad_with` must produce gradients bit-identical
//! to the post-hoc `dp_allreduce_grads_bucketed` oracle — across mesh
//! shapes, DP degrees, bucket sizes, rollout lengths, and (crucially)
//! arbitrary fabric delivery delays. Determinism across repeated runs
//! with different delay seeds is what makes the overlapped path safe to
//! enable by default.

use std::time::Duration;

use jigsaw::benchkit::synth_config;
use jigsaw::comm::{FabricSpec, Network};
use jigsaw::config::ModelConfig;
use jigsaw::jigsaw::{Ctx, Mesh};
use jigsaw::model::dist::DistModel;
use jigsaw::model::init_global_params;
use jigsaw::model::params::{shard_params, PStore};
use jigsaw::runtime::native::NativeBackend;
use jigsaw::tensor::Tensor;
use jigsaw::trainer::oracle::sample_shard;
use jigsaw::trainer::{dp_allreduce_grads_bucketed, GradReduceScheduler};
use jigsaw::util::rng::Rng;

/// Which reduction path a world runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Sched {
    /// post-hoc `dp_allreduce_grads_bucketed` — the oracle
    PostHoc,
    /// grad-ready scheduler, emission-point polling only (PR-4 baseline)
    Emission,
    /// grad-ready scheduler with the progress-engine hook installed
    Engine,
}

/// One full loss_and_grad + DP reduce on a `mesh x dp` world; returns
/// every rank's reduced gradient store, in world-rank order.
fn run_world(
    cfg: &ModelConfig,
    mesh: Mesh,
    dp: usize,
    rollout: usize,
    bucket_elems: usize,
    fabric: Option<(FabricSpec, u64)>,
    sched: Sched,
) -> Vec<PStore> {
    let mp = mesh.n();
    let mp_nets: Vec<Network> = (0..dp).map(|_| Network::new(mp)).collect();
    let dp_net = Network::new(mp * dp);
    if let Some((spec, seed)) = fabric {
        dp_net.set_fabric(spec, seed);
    }
    let global = init_global_params(cfg, 7);
    let mut handles = Vec::new();
    for g in 0..dp {
        for r in 0..mp {
            let cfg = cfg.clone();
            let params = shard_params(&cfg, &mesh, r, &global).unwrap();
            let mut mp_comm = mp_nets[g].endpoint(r);
            let mut dp_comm = dp_net.endpoint(g * mp + r);
            handles.push(std::thread::spawn(move || {
                let backend = NativeBackend;
                let model = DistModel::new(cfg.clone(), &mesh, r, params);
                // per-DP-group sample, identical across both paths
                let mut rng = Rng::seed_from(0xD00D ^ g as u64);
                let mut d = vec![0.0; cfg.lat * cfg.lon * cfg.channels_padded];
                rng.fill_normal(&mut d, 1.0);
                let x =
                    Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d.clone());
                rng.fill_normal(&mut d, 1.0);
                let y = Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d);
                let (la, _, lc) = model.local_dims();
                let (lat0, ch0) = (model.lat_offset(), model.ch_offset());
                let xl = sample_shard(&x, (lat0, lat0 + la), (ch0, ch0 + lc));
                let yl = sample_shard(&y, (lat0, lat0 + la), (ch0, ch0 + lc));
                let dp_group = mesh.dp_group(dp, r);
                let mut ctx = Ctx::new(mesh, r, &mut mp_comm, &backend);
                match sched {
                    Sched::PostHoc => {
                        let (_, mut grads) =
                            model.loss_and_grad(&mut ctx, &xl, &yl, rollout).unwrap();
                        dp_allreduce_grads_bucketed(
                            &mut grads,
                            &mut dp_comm,
                            &dp_group,
                            bucket_elems,
                        );
                        grads
                    }
                    Sched::Emission | Sched::Engine => {
                        let mut s = if sched == Sched::Engine {
                            GradReduceScheduler::new(
                                &mut dp_comm,
                                &dp_group,
                                bucket_elems,
                            )
                        } else {
                            GradReduceScheduler::new_emission_only(
                                &mut dp_comm,
                                &dp_group,
                                bucket_elems,
                            )
                        };
                        let (_, mut grads) = model
                            .loss_and_grad_with(&mut ctx, &xl, &yl, rollout, &mut s)
                            .unwrap();
                        s.finish(&mut grads);
                        grads
                    }
                }
            }));
        }
    }
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

fn assert_stores_bit_equal(a: &PStore, b: &PStore, ctx: &str) {
    assert_eq!(a.mats.len(), b.mats.len(), "{ctx}: mat count");
    for (name, ma) in &a.mats {
        let mb = &b.mats[name];
        for (key, ta) in &ma.blocks {
            let tb = &mb.blocks[key];
            for (i, (va, vb)) in ta.data.iter().zip(&tb.data).enumerate() {
                assert_eq!(
                    va.to_bits(),
                    vb.to_bits(),
                    "{ctx}: mat {name} block {key:?} elem {i}: {va} vs {vb}"
                );
            }
        }
    }
    for (name, va) in &a.vecs {
        let vb = &b.vecs[name];
        for (i, (x, y)) in va.local.data.iter().zip(&vb.local.data).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{ctx}: vec {name} elem {i}: {x} vs {y}"
            );
        }
    }
}

#[test]
fn overlapped_reduce_bit_identical_across_meshes_and_dp() {
    let cfg = synth_config("dp-props", 32, 48, 2);
    // through 16-way (4x4): every mesh shape the planner trains must
    // reduce identically on all three paths — including the
    // progress-engine hook path, which covers the kernel-driver and
    // dist_matmul dry-wait polling at every shape
    let meshes = [
        Mesh::new(1, 1).unwrap(),
        Mesh::new(1, 2).unwrap(),
        Mesh::new(2, 2).unwrap(),
        Mesh::new(2, 4).unwrap(),
        Mesh::new(4, 4).unwrap(),
    ];
    for mesh in meshes {
        for dp in [2usize, 4] {
            // a tiny bucket forces many collectives (and the gather
            // dispatch for small vector-only buckets); the big one packs
            // nearly everything into a single ring
            for bucket_elems in [1usize, 4096] {
                let ctx = format!("mesh {mesh} dp {dp} bucket {bucket_elems}");
                let oracle =
                    run_world(&cfg, mesh, dp, 1, bucket_elems, None, Sched::PostHoc);
                for sched in [Sched::Emission, Sched::Engine] {
                    let overlapped =
                        run_world(&cfg, mesh, dp, 1, bucket_elems, None, sched);
                    for (a, b) in oracle.iter().zip(&overlapped) {
                        assert_stores_bit_equal(a, b, &format!("{ctx} {sched:?}"));
                    }
                }
            }
        }
    }
}

#[test]
fn overlapped_reduce_bit_identical_with_rollout() {
    // rollout > 1: weight grads accumulate across iterations and must
    // only be emitted on the final backward pass
    let cfg = synth_config("dp-props-roll", 32, 48, 2);
    let mesh = Mesh::new(1, 2).unwrap();
    let oracle = run_world(&cfg, mesh, 2, 3, 512, None, Sched::PostHoc);
    for sched in [Sched::Emission, Sched::Engine] {
        let overlapped = run_world(&cfg, mesh, 2, 3, 512, None, sched);
        for (a, b) in oracle.iter().zip(&overlapped) {
            assert_stores_bit_equal(a, b, &format!("rollout 3 {sched:?}"));
        }
    }
}

#[test]
fn overlapped_reduce_bit_identical_under_fabric_delays() {
    // the oracle runs on an instantaneous fabric; the overlapped path
    // under injected latency + jitter (scrambled delivery timing) must
    // still match bit for bit — the reduction order is fixed by the
    // schedule, not by arrival order (nor by when the engine hook
    // happens to poll)
    let cfg = synth_config("dp-props-fab", 32, 48, 2);
    let spec = FabricSpec {
        latency: Duration::from_micros(150),
        jitter: Duration::from_micros(400),
        bytes_per_sec: 5e8,
    };
    for mesh in [Mesh::new(1, 2).unwrap(), Mesh::new(2, 2).unwrap()] {
        let oracle = run_world(&cfg, mesh, 2, 1, 512, None, Sched::PostHoc);
        for seed in [1u64, 99] {
            for sched in [Sched::Emission, Sched::Engine] {
                let overlapped =
                    run_world(&cfg, mesh, 2, 1, 512, Some((spec, seed)), sched);
                for (a, b) in oracle.iter().zip(&overlapped) {
                    assert_stores_bit_equal(
                        a,
                        b,
                        &format!("mesh {mesh} seed {seed} {sched:?}"),
                    );
                }
            }
        }
    }
}

#[test]
fn overlapped_scheduling_deterministic_across_runs() {
    // repeated runs — including runs whose fabric jitter draws differ —
    // must produce identical gradients: scheduling is deterministic
    let cfg = synth_config("dp-props-det", 32, 48, 2);
    let mesh = Mesh::new(2, 2).unwrap();
    let spec = FabricSpec {
        latency: Duration::from_micros(100),
        jitter: Duration::from_micros(300),
        bytes_per_sec: 1e9,
    };
    let base = run_world(&cfg, mesh, 2, 1, 2048, Some((spec, 5)), Sched::Engine);
    for seed in [5u64, 6, 1234] {
        let again =
            run_world(&cfg, mesh, 2, 1, 2048, Some((spec, seed)), Sched::Engine);
        for (a, b) in base.iter().zip(&again) {
            assert_stores_bit_equal(a, b, &format!("repeat seed {seed}"));
        }
    }
}
