//! Property tests for the blocked/parallel kernel layer: the optimized
//! `_into` kernels and the zero-copy view slicing must match the retained
//! naive oracle (`tensor::ref_kernels`) to 1e-5 across random shapes,
//! strides (views carved out of larger parents), accumulate modes, and
//! thread counts.

use jigsaw::tensor::{ops, ref_kernels, Tensor};
use jigsaw::util::prop::{check, Gen};

fn rand_t(g: &mut Gen, r: usize, c: usize) -> Tensor {
    Tensor::new(vec![r, c], g.f32s(r * c))
}

/// Max elementwise error, relative to the oracle's scale.
fn rel_err(got: &Tensor, want: &Tensor) -> f32 {
    assert_eq!(got.shape, want.shape);
    let scale = 1.0
        + want
            .data
            .iter()
            .map(|v| v.abs())
            .fold(0.0f32, f32::max);
    got.max_abs_diff(want) / scale
}

/// Embed `inner` in a larger random parent and return (parent, row0, col0)
/// so `parent.view2().slice_rows(..).slice_cols(..)` is a strided view of
/// `inner`'s values.
fn embed(g: &mut Gen, inner: &Tensor) -> (Tensor, usize, usize) {
    let (r, c) = inner.dims2();
    let (pr, pc) = (g.int(0, 3), g.int(0, 3));
    let (r0, c0) = (g.int(0, pr), g.int(0, pc));
    let mut parent = rand_t(g, r + pr, c + pc);
    for i in 0..r {
        for j in 0..c {
            parent.data[(i + r0) * (c + pc) + (j + c0)] = inner.at2(i, j);
        }
    }
    (parent, r0, c0)
}

#[test]
fn blocked_kernels_match_reference_oracle() {
    check("blocked matmul == ref_kernels over shapes/strides/threads", 80, |g| {
        let m = g.int(1, 24);
        let k = g.int(1, 24);
        let n = g.int(1, 24);
        let threads = g.int(1, 4);
        let acc = g.bool();
        let which = g.int(0, 2); // 0 = nt, 1 = nn, 2 = tn

        let (x, w, want_product) = match which {
            0 => {
                let x = rand_t(g, m, k);
                let w = rand_t(g, n, k);
                let p = ref_kernels::matmul_nt(&x, &w);
                (x, w, p)
            }
            1 => {
                let x = rand_t(g, m, k);
                let w = rand_t(g, k, n);
                let p = ref_kernels::matmul_nn(&x, &w);
                (x, w, p)
            }
            _ => {
                let x = rand_t(g, k, m);
                let w = rand_t(g, k, n);
                let p = ref_kernels::matmul_tn(&x, &w);
                (x, w, p)
            }
        };

        // operands and output live as strided views inside larger parents
        let (xp, xr0, xc0) = embed(g, &x);
        let (wp, wr0, wc0) = embed(g, &w);
        let out0 = rand_t(g, m, n);
        let (mut op_parent, or0, oc0) = embed(g, &out0);
        let before = op_parent.clone();

        {
            let xv = xp
                .view2()
                .slice_rows(xr0, xr0 + x.shape[0])
                .slice_cols(xc0, xc0 + x.shape[1]);
            let wv = wp
                .view2()
                .slice_rows(wr0, wr0 + w.shape[0])
                .slice_cols(wc0, wc0 + w.shape[1]);
            let ov = op_parent
                .view2_mut()
                .into_rows(or0, or0 + m)
                .into_cols(oc0, oc0 + n);
            match which {
                0 => ops::matmul_nt_into_with(ov, xv, wv, acc, threads),
                1 => ops::matmul_nn_into_with(ov, xv, wv, acc, threads),
                _ => ops::matmul_tn_into_with(ov, xv, wv, acc, threads),
            }
        }

        let want = if acc { ops::add(&out0, &want_product) } else { want_product };
        let got = op_parent
            .view2()
            .slice_rows(or0, or0 + m)
            .slice_cols(oc0, oc0 + n)
            .to_tensor();
        let err = rel_err(&got, &want);
        if err >= 1e-5 {
            return Err(format!(
                "op {which} m={m} k={k} n={n} threads={threads} acc={acc}: err {err}"
            ));
        }

        // everything outside the output window is untouched
        let (prow, pcol) = op_parent.dims2();
        for i in 0..prow {
            for j in 0..pcol {
                let inside =
                    (or0..or0 + m).contains(&i) && (oc0..oc0 + n).contains(&j);
                if !inside && op_parent.at2(i, j) != before.at2(i, j) {
                    return Err(format!("kernel wrote outside its window at ({i},{j})"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn view_slicing_matches_materialized_slicing() {
    check("view slicing == copying slicing", 60, |g: &mut Gen| {
        let r = g.int(1, 12);
        let c = g.int(1, 12);
        let t = rand_t(g, r, c);
        let rl = g.int(0, r - 1);
        let rh = g.int(rl, r);
        let cl = g.int(0, c - 1);
        let ch = g.int(cl, c);
        let via_view = t.view2().slice_rows(rl, rh).slice_cols(cl, ch).to_tensor();
        let mut manual = Vec::new();
        for i in rl..rh {
            for j in cl..ch {
                manual.push(t.at2(i, j));
            }
        }
        if via_view.data == manual && via_view.shape == vec![rh - rl, ch - cl] {
            Ok(())
        } else {
            Err(format!("mismatch r{rl}..{rh} c{cl}..{ch}"))
        }
    });
}

#[test]
fn view_block_roundtrip_random_grids() {
    check("view block extraction == Tensor::block", 40, |g: &mut Gen| {
        let rb = g.int(1, 4);
        let cb = g.int(1, 4);
        let (br, bc) = (g.int(1, 5), g.int(1, 5));
        let t = rand_t(g, rb * br, cb * bc);
        for bi in 0..rb {
            for bj in 0..cb {
                let a = t.view2().block(bi, bj, rb, cb).to_tensor();
                let b = t.block(bi, bj, rb, cb);
                if a != b {
                    return Err(format!("block ({bi},{bj}) of {rb}x{cb} differs"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn threaded_bands_match_serial_above_flop_threshold() {
    // The random property cases above stay below the kernel's FLOP
    // threshold, so their threads dimension exercises only the serial
    // path; this test is the one that actually spawns bands, for all
    // three ops (tn is the tricky case: bands split x by *columns*).
    let mut g = Gen::new(7);
    let (m, k, n) = (150, 140, 90);
    let x = rand_t(&mut g, m, k);
    let w = rand_t(&mut g, n, k);
    let want = ref_kernels::matmul_nt(&x, &w);
    for threads in [1usize, 2, 3, 5, 8] {
        let mut out = Tensor::zeros(&[m, n]);
        ops::matmul_nt_into_with(out.view2_mut(), x.view2(), w.view2(), false, threads);
        let err = rel_err(&out, &want);
        assert!(err < 1e-5, "threads={threads} err={err}");
    }
    let wn = rand_t(&mut g, k, n);
    let want = ref_kernels::matmul_nn(&x, &wn);
    for threads in [1usize, 3, 8] {
        let mut out = Tensor::zeros(&[m, n]);
        ops::matmul_nn_into_with(out.view2_mut(), x.view2(), wn.view2(), false, threads);
        let err = rel_err(&out, &want);
        assert!(err < 1e-5, "nn threads={threads} err={err}");
    }
    let xt = rand_t(&mut g, k, m);
    let want = ref_kernels::matmul_tn(&xt, &wn);
    for threads in [1usize, 2, 4, 7] {
        let mut out = Tensor::zeros(&[m, n]);
        ops::matmul_tn_into_with(out.view2_mut(), xt.view2(), wn.view2(), false, threads);
        let err = rel_err(&out, &want);
        assert!(err < 1e-5, "tn threads={threads} err={err}");
    }
    // accumulate mode through the banded path
    let base = rand_t(&mut g, m, n);
    let mut out = base.clone();
    ops::matmul_nt_into_with(out.view2_mut(), x.view2(), w.view2(), true, 4);
    let want = ops::add(&base, &ref_kernels::matmul_nt(&x, &w));
    assert!(rel_err(&out, &want) < 1e-5, "banded accumulate");
}

#[test]
fn allocating_wrappers_match_oracle() {
    check("ops::matmul_* == ref_kernels::matmul_*", 40, |g: &mut Gen| {
        let m = g.int(1, 16);
        let k = g.int(1, 16);
        let n = g.int(1, 16);
        let x = rand_t(g, m, k);
        let wt = rand_t(g, n, k);
        let wn = rand_t(g, k, n);
        let xt = rand_t(g, k, m);
        let cases = [
            (ops::matmul_nt(&x, &wt), ref_kernels::matmul_nt(&x, &wt), "nt"),
            (ops::matmul_nn(&x, &wn), ref_kernels::matmul_nn(&x, &wn), "nn"),
            (ops::matmul_tn(&xt, &wn), ref_kernels::matmul_tn(&xt, &wn), "tn"),
        ];
        for (got, want, tag) in &cases {
            let err = rel_err(got, want);
            if err >= 1e-5 {
                return Err(format!("{tag} {m}x{k}x{n} err {err}"));
            }
        }
        Ok(())
    });
}

/// The SIMD register tile keeps the scalar tile's per-element operation
/// order (separate multiply then add, same kk sequence), so a `simd`
/// build must be *bit-identical* to the scalar path — the scalar tile is
/// the oracle, not a tolerance reference. Exercised across the same
/// shape/stride/thread/accumulate grid as the blocked-kernel property
/// test, flipping [`ops::set_force_scalar_tile`] between runs.
#[cfg(feature = "simd")]
#[test]
fn simd_tile_is_bit_identical_to_scalar() {
    check("simd tile == scalar tile (to_bits)", 80, |g: &mut Gen| {
        let m = g.int(1, 40);
        let k = g.int(1, 40);
        let n = g.int(1, 40);
        let threads = g.int(1, 4);
        let acc = g.bool();
        let which = g.int(0, 2);
        let (x, w) = match which {
            0 => (rand_t(g, m, k), rand_t(g, n, k)),
            1 => (rand_t(g, m, k), rand_t(g, k, n)),
            _ => (rand_t(g, k, m), rand_t(g, k, n)),
        };
        let base = rand_t(g, m, n);

        let mut run = |force_scalar: bool| -> Tensor {
            let prev = ops::set_force_scalar_tile(force_scalar);
            let mut out = base.clone();
            match which {
                0 => ops::matmul_nt_into_with(
                    out.view2_mut(), x.view2(), w.view2(), acc, threads,
                ),
                1 => ops::matmul_nn_into_with(
                    out.view2_mut(), x.view2(), w.view2(), acc, threads,
                ),
                _ => ops::matmul_tn_into_with(
                    out.view2_mut(), x.view2(), w.view2(), acc, threads,
                ),
            }
            ops::set_force_scalar_tile(prev);
            out
        };

        let scalar = run(true);
        let simd = run(false);
        for (i, (a, b)) in scalar.data.iter().zip(&simd.data).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!(
                    "op {which} m={m} k={k} n={n} threads={threads} acc={acc}: \
                     bit mismatch at {i}: {a:?} vs {b:?}"
                ));
            }
        }
        Ok(())
    });
}
