//! Forecast serving: autoregressive rollouts over sharded weights, a
//! trajectory cache, and a regional query layer on top.
//!
//! Training and serving share exactly one forward implementation
//! (`DistModel::forward_core`); this module owns everything *around* it
//! that an inference deployment needs and a trainer does not:
//!
//! * [`RolloutEngine`] — one persistent worker thread per mesh rank,
//!   each holding an [`InferModel`] (weights only: no Adam moments, no
//!   scaler, sync-group-free vec shards) and a fabric endpoint. A step
//!   scatters the global [lat, lon, C] state into rank shards, runs the
//!   forward-only pass on every rank, and reassembles the predicted
//!   next state. `begin_step`/`finish_step` split the dispatch from the
//!   collect so a step can overlap with query answering.
//! * [`TrajectoryCache`] — assembled global states keyed
//!   `(init_id, lead_step)` with LRU eviction and hit/miss/eviction
//!   counters in [`metrics::ServeCounters`].
//! * [`ServeEngine`] — the request layer: answers
//!   [`RegionQuery`]s (a lat/lon window at an arbitrary lead time) as
//!   O(1) [`TensorView`] windows into cached states, rolling forward
//!   from the nearest cached ancestor on a miss and prefetching the
//!   next lead step while queries drain.
//!
//! Serving issues no gradient collectives — the comm capacity the
//! training loop spends on `ProgressEngine` idle polls is what funds
//! the prefetch here: worker threads advance `(init, lead+1)` through
//! the fabric while the serving thread answers cached queries.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, ensure, Result};

use crate::comm::{FabricSpec, Network, FABRIC_ABORTED};
use crate::config::ModelConfig;
use crate::jigsaw::{Ctx, Mesh};
use crate::metrics::{ServeCounters, ServeStats};
use crate::model::InferModel;
use crate::runtime::Backend;
use crate::tensor::{Precision, Tensor, TensorView};
use crate::trainer::oracle::sample_shard;

/// One rank's shard extent within the global [lat, lon, C] state.
#[derive(Clone, Copy, Debug)]
struct ShardSpec {
    lat0: usize,
    lat_l: usize,
    ch0: usize,
    ch_l: usize,
}

enum RankCmd {
    /// Run one forward-only step on this rank's local shard.
    Step(Tensor),
    Stop,
}

struct Worker {
    cmds: mpsc::Sender<RankCmd>,
    handle: Option<JoinHandle<()>>,
}

/// Mesh-parallel autoregressive rollout engine: sharded forward-only
/// steps with global scatter/gather at the state boundary.
pub struct RolloutEngine {
    cfg: ModelConfig,
    mesh: Mesh,
    net: Network,
    workers: Vec<Worker>,
    results: mpsc::Receiver<(usize, Result<Tensor, String>)>,
    shards: Vec<ShardSpec>,
    rollout: usize,
    in_flight: bool,
}

impl RolloutEngine {
    /// Shard `global` weights across `mesh` and spawn one worker thread
    /// per rank. `rollout` is the processor repeat count baked into the
    /// model's forward (a training hyperparameter, not the lead time).
    pub fn new(
        cfg: &ModelConfig,
        mesh: &Mesh,
        global: &[(String, Tensor)],
        backend: Arc<dyn Backend>,
        precision: Precision,
        rollout: usize,
    ) -> Result<Self> {
        let mesh = *mesh;
        let net = Network::new(mesh.n());
        let (tx, results) = mpsc::channel();
        let mut workers = Vec::with_capacity(mesh.n());
        let mut shards = Vec::with_capacity(mesh.n());
        for r in 0..mesh.n() {
            let model = InferModel::new(cfg.clone(), &mesh, r, global)
                .map_err(|e| anyhow!("serve: rank {r}: {e}"))?;
            let (lat_l, _lon, ch_l) = model.local_dims();
            shards.push(ShardSpec {
                lat0: model.lat_offset(),
                lat_l,
                ch0: model.ch_offset(),
                ch_l,
            });
            let (cmd_tx, cmd_rx) = mpsc::channel::<RankCmd>();
            let mut comm = net.endpoint(r);
            let abort_net = net.clone();
            let backend = backend.clone();
            let tx = tx.clone();
            let handle = std::thread::spawn(move || {
                while let Ok(cmd) = cmd_rx.recv() {
                    let xl = match cmd {
                        RankCmd::Step(xl) => xl,
                        RankCmd::Stop => break,
                    };
                    let run = catch_unwind(AssertUnwindSafe(|| {
                        let mut ctx = Ctx::infer(
                            mesh,
                            r,
                            &mut comm,
                            backend.as_ref(),
                            precision,
                        );
                        model.predict(&mut ctx, &xl, rollout)
                    }));
                    let out = match run {
                        Ok(Ok(pred)) => Ok(pred),
                        Ok(Err(e)) => {
                            abort_net.abort_from(r);
                            Err(format!("rank {r}: {e}"))
                        }
                        Err(p) => {
                            let msg = p
                                .downcast_ref::<&str>()
                                .map(|s| s.to_string())
                                .or_else(|| p.downcast_ref::<String>().cloned())
                                .unwrap_or_else(|| "panic".into());
                            if !msg.contains(FABRIC_ABORTED) {
                                abort_net.abort_from(r);
                            }
                            Err(format!("rank {r}: {msg}"))
                        }
                    };
                    if tx.send((r, out)).is_err() {
                        break;
                    }
                }
            });
            workers.push(Worker { cmds: cmd_tx, handle: Some(handle) });
        }
        Ok(RolloutEngine {
            cfg: cfg.clone(),
            mesh,
            net,
            workers,
            results,
            shards,
            rollout,
            in_flight: false,
        })
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn mesh(&self) -> Mesh {
        self.mesh
    }

    pub fn rollout(&self) -> usize {
        self.rollout
    }

    /// Inject simulated fabric timing into the engine's network (seeded,
    /// so delivery reorderings reproduce across runs).
    pub fn set_fabric(&self, spec: FabricSpec, seed: u64) {
        self.net.set_fabric(spec, seed);
    }

    /// Total bytes the rollout fabric has carried so far.
    pub fn total_bytes(&self) -> u64 {
        self.net.total_bytes()
    }

    /// Dispatch one step: scatter `state` ([lat, lon, C] global) into
    /// rank shards and hand every worker its piece. Returns immediately;
    /// the forward passes run on the worker threads until
    /// [`finish_step`](Self::finish_step) collects them.
    pub fn begin_step(&mut self, state: &Tensor) -> Result<()> {
        assert!(!self.in_flight, "serve: begin_step while a step is in flight");
        ensure!(
            state.shape
                == vec![self.cfg.lat, self.cfg.lon, self.cfg.channels_padded],
            "serve: state shape {:?}, expected [{}, {}, {}]",
            state.shape,
            self.cfg.lat,
            self.cfg.lon,
            self.cfg.channels_padded,
        );
        for (r, s) in self.shards.iter().enumerate() {
            let xl = sample_shard(
                state,
                (s.lat0, s.lat0 + s.lat_l),
                (s.ch0, s.ch0 + s.ch_l),
            );
            self.workers[r]
                .cmds
                .send(RankCmd::Step(xl))
                .map_err(|_| anyhow!("serve: rank {r} worker is gone"))?;
        }
        self.in_flight = true;
        Ok(())
    }

    /// Collect the in-flight step and reassemble the global next state.
    pub fn finish_step(&mut self) -> Result<Tensor> {
        assert!(self.in_flight, "serve: finish_step without begin_step");
        self.in_flight = false;
        let mut locals: Vec<Option<Tensor>> = (0..self.mesh.n()).map(|_| None).collect();
        let mut errs: Vec<String> = Vec::new();
        for _ in 0..self.mesh.n() {
            let (r, out) = self
                .results
                .recv()
                .map_err(|_| anyhow!("serve: all workers are gone"))?;
            match out {
                Ok(t) => locals[r] = Some(t),
                Err(e) => errs.push(e),
            }
        }
        if !errs.is_empty() {
            // a failing rank aborts the fabric and every peer's blocking
            // receive panics with FABRIC_ABORTED — report the root cause,
            // not the cascade
            let root = errs
                .iter()
                .find(|e| !e.contains(FABRIC_ABORTED))
                .unwrap_or(&errs[0])
                .clone();
            bail!("serve: step failed: {root}");
        }
        let mut next = Tensor::zeros(&[
            self.cfg.lat,
            self.cfg.lon,
            self.cfg.channels_padded,
        ]);
        for (r, s) in self.shards.iter().enumerate() {
            let local = locals[r].take().expect("every rank reported");
            scatter_shard(&mut next, &local, s);
        }
        Ok(next)
    }

    /// One full rollout step: dispatch, wait, reassemble.
    pub fn step(&mut self, state: &Tensor) -> Result<Tensor> {
        self.begin_step(state)?;
        self.finish_step()
    }
}

impl Drop for RolloutEngine {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.cmds.send(RankCmd::Stop);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

/// Inverse of `sample_shard`: write a rank's [lat_l, lon, ch_l] local
/// prediction into its window of the global [lat, lon, C] state.
fn scatter_shard(global: &mut Tensor, local: &Tensor, s: &ShardSpec) {
    let (lon, c) = (global.shape[1], global.shape[2]);
    assert_eq!(local.shape, vec![s.lat_l, lon, s.ch_l]);
    for li in 0..s.lat_l {
        for lj in 0..lon {
            for ci in 0..s.ch_l {
                global.data[((s.lat0 + li) * lon + lj) * c + s.ch0 + ci] =
                    local.data[(li * lon + lj) * s.ch_l + ci];
            }
        }
    }
}

struct CacheEntry {
    state: Arc<Tensor>,
    last_used: u64,
}

/// LRU cache of assembled global forecast states keyed
/// `(init_id, lead_step)`. Lookups and evictions bump the shared
/// [`ServeCounters`]; recency ticks are a monotonic counter, so
/// eviction order is deterministic (ticks never tie).
pub struct TrajectoryCache {
    cap: usize,
    tick: u64,
    map: HashMap<(u64, usize), CacheEntry>,
    counters: Arc<ServeCounters>,
}

impl TrajectoryCache {
    pub fn new(cap: usize, counters: Arc<ServeCounters>) -> Self {
        assert!(cap >= 1, "trajectory cache needs capacity >= 1");
        TrajectoryCache { cap, tick: 0, map: HashMap::new(), counters }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Counting lookup: a user-facing query probing for this state.
    pub fn get(&mut self, key: &(u64, usize)) -> Option<Arc<Tensor>> {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(key) {
            Some(e) => {
                e.last_used = tick;
                self.counters.hit();
                Some(e.state.clone())
            }
            None => {
                self.counters.miss();
                None
            }
        }
    }

    /// Non-counting recency bump: internal reuse of a cached ancestor
    /// while rebuilding a missed lead step. Keeps the ancestor warm
    /// without polluting the hit/miss statistics.
    pub fn touch(&mut self, key: &(u64, usize)) -> Option<Arc<Tensor>> {
        self.tick += 1;
        let tick = self.tick;
        let e = self.map.get_mut(key)?;
        e.last_used = tick;
        Some(e.state.clone())
    }

    /// Non-counting, non-bumping probe.
    pub fn contains(&self, key: &(u64, usize)) -> bool {
        self.map.contains_key(key)
    }

    /// Insert (or refresh) a state, evicting the least-recently-used
    /// entry when at capacity.
    pub fn insert(&mut self, key: (u64, usize), state: Arc<Tensor>) {
        self.tick += 1;
        let tick = self.tick;
        if let Some(e) = self.map.get_mut(&key) {
            e.state = state;
            e.last_used = tick;
            return;
        }
        if self.map.len() >= self.cap {
            let victim = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
                .expect("cache at capacity is non-empty");
            self.map.remove(&victim);
            self.counters.eviction();
        }
        self.map.insert(key, CacheEntry { state, last_used: tick });
    }
}

/// A regional forecast request: the `[lat.0, lat.1) x [lon.0, lon.1)`
/// window of initial condition `init_id` at lead step `lead` (all
/// channels).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RegionQuery {
    pub init_id: u64,
    pub lead: usize,
    pub lat: (usize, usize),
    pub lon: (usize, usize),
}

/// A served regional forecast: a shared handle on the cached global
/// state plus the window coordinates. [`view`](Self::view) is the O(1)
/// answer — a strided window into the state, no copy.
pub struct RegionAnswer {
    state: Arc<Tensor>,
    lat: (usize, usize),
    lon: (usize, usize),
    lon_full: usize,
    channels: usize,
}

impl RegionAnswer {
    /// The regional window as a strided 2-D view: `lat_span` rows of
    /// `lon_span * C` contiguous floats each, row stride `lon * C`.
    pub fn view(&self) -> TensorView<'_> {
        let c = self.channels;
        let off = (self.lat.0 * self.lon_full + self.lon.0) * c;
        TensorView::new(
            &self.state.data[off..],
            self.lat.1 - self.lat.0,
            (self.lon.1 - self.lon.0) * c,
            self.lon_full * c,
        )
    }

    /// The full global state this answer windows into.
    pub fn state(&self) -> &Arc<Tensor> {
        &self.state
    }
}

/// The request layer: initial conditions, the trajectory cache, and the
/// rollout engine behind it, with next-step prefetch overlap.
pub struct ServeEngine {
    engine: RolloutEngine,
    cache: TrajectoryCache,
    inits: HashMap<u64, Arc<Tensor>>,
    counters: Arc<ServeCounters>,
    max_lead: usize,
    prefetch: bool,
    /// a rollout step currently running on the workers for this key
    pending: Option<(u64, usize)>,
}

impl ServeEngine {
    pub fn new(
        engine: RolloutEngine,
        cache_states: usize,
        max_lead: usize,
        prefetch: bool,
    ) -> Self {
        let counters = Arc::new(ServeCounters::default());
        let cache = TrajectoryCache::new(cache_states, counters.clone());
        ServeEngine {
            engine,
            cache,
            inits: HashMap::new(),
            counters,
            max_lead,
            prefetch,
            pending: None,
        }
    }

    /// Register an initial condition (lead 0). Inits live outside the
    /// LRU cache — they are the roots every rebuild walks back to.
    pub fn add_init(&mut self, id: u64, state: Tensor) -> Result<()> {
        let want =
            vec![self.engine.cfg.lat, self.engine.cfg.lon, self.engine.cfg.channels_padded];
        ensure!(
            state.shape == want,
            "serve: init {id} shape {:?}, expected {want:?}",
            state.shape,
        );
        self.inits.insert(id, Arc::new(state));
        Ok(())
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.engine.cfg
    }

    pub fn counters(&self) -> Arc<ServeCounters> {
        self.counters.clone()
    }

    pub fn stats(&self) -> ServeStats {
        self.counters.snapshot()
    }

    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// The full global state of `init` at `lead`, from cache when
    /// possible, else rolled forward from the nearest cached ancestor
    /// (caching every intermediate step on the way).
    pub fn state(&mut self, init: u64, lead: usize) -> Result<Arc<Tensor>> {
        ensure!(
            lead <= self.max_lead,
            "serve: lead {lead} beyond max lead {}",
            self.max_lead
        );
        let init_state = self
            .inits
            .get(&init)
            .cloned()
            .ok_or_else(|| anyhow!("serve: unknown init {init}"))?;
        if lead == 0 {
            self.maybe_prefetch(init, 0, &init_state)?;
            return Ok(init_state);
        }
        // land any in-flight prefetch first so this lookup can see it
        self.drain_pending()?;
        if let Some(s) = self.cache.get(&(init, lead)) {
            self.maybe_prefetch(init, lead, &s)?;
            return Ok(s);
        }
        // miss: find the deepest cached ancestor and roll forward
        let mut base_lead = 0;
        let mut base = init_state;
        for l in (1..lead).rev() {
            if let Some(s) = self.cache.touch(&(init, l)) {
                base_lead = l;
                base = s;
                break;
            }
        }
        for l in base_lead + 1..=lead {
            let next = Arc::new(self.engine.step(&base)?);
            self.cache.insert((init, l), next.clone());
            base = next;
        }
        self.maybe_prefetch(init, lead, &base)?;
        Ok(base)
    }

    /// Answer one regional query as an O(1) window of the cached state.
    pub fn answer(&mut self, q: RegionQuery) -> Result<RegionAnswer> {
        let (glat, glon, gch) = (
            self.engine.cfg.lat,
            self.engine.cfg.lon,
            self.engine.cfg.channels_padded,
        );
        ensure!(
            q.lat.0 < q.lat.1 && q.lat.1 <= glat,
            "serve: latitude window {:?} out of [0, {glat}]",
            q.lat,
        );
        ensure!(
            q.lon.0 < q.lon.1 && q.lon.1 <= glon,
            "serve: longitude window {:?} out of [0, {glon}]",
            q.lon,
        );
        let state = self.state(q.init_id, q.lead)?;
        Ok(RegionAnswer {
            state,
            lat: q.lat,
            lon: q.lon,
            lon_full: glon,
            channels: gch,
        })
    }

    /// Answer a batch of queries. Within the batch, queries execute
    /// grouped by initial condition and ascending lead so rollout work
    /// builds forward monotonically instead of thrashing the cache;
    /// answers come back in request order.
    pub fn answer_batch(&mut self, queries: &[RegionQuery]) -> Result<Vec<RegionAnswer>> {
        let mut order: Vec<usize> = (0..queries.len()).collect();
        order.sort_by_key(|&i| (queries[i].init_id, queries[i].lead, i));
        let mut out: Vec<Option<RegionAnswer>> =
            (0..queries.len()).map(|_| None).collect();
        for i in order {
            out[i] = Some(self.answer(queries[i])?);
        }
        Ok(out.into_iter().map(|a| a.expect("every query answered")).collect())
    }

    /// Collect an in-flight prefetch step into the cache.
    fn drain_pending(&mut self) -> Result<()> {
        if let Some((i, l)) = self.pending.take() {
            let state = Arc::new(self.engine.finish_step()?);
            self.cache.insert((i, l), state);
        }
        Ok(())
    }

    /// Start computing `(init, lead + 1)` on the worker threads while
    /// the serving thread goes back to draining queries — the serving
    /// analogue of the training fabric's idle-poll overlap.
    fn maybe_prefetch(
        &mut self,
        init: u64,
        lead: usize,
        served: &Arc<Tensor>,
    ) -> Result<()> {
        if !self.prefetch || self.pending.is_some() {
            return Ok(());
        }
        let next = lead + 1;
        if next > self.max_lead || self.cache.contains(&(init, next)) {
            return Ok(());
        }
        self.engine.begin_step(served)?;
        self.pending = Some((init, next));
        self.counters.prefetch();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(v: f32) -> Arc<Tensor> {
        Arc::new(Tensor::new(vec![1], vec![v]))
    }

    #[test]
    fn cache_hits_misses_and_counters() {
        let counters = Arc::new(ServeCounters::default());
        let mut c = TrajectoryCache::new(2, counters.clone());
        assert!(c.get(&(1, 1)).is_none());
        c.insert((1, 1), state(1.0));
        assert_eq!(c.get(&(1, 1)).unwrap().data[0], 1.0);
        let s = counters.snapshot();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 1, 0));
    }

    #[test]
    fn cache_evicts_least_recently_used() {
        let counters = Arc::new(ServeCounters::default());
        let mut c = TrajectoryCache::new(2, counters.clone());
        c.insert((0, 1), state(1.0));
        c.insert((0, 2), state(2.0));
        // touch (0,1) so (0,2) becomes the LRU victim
        assert!(c.get(&(0, 1)).is_some());
        c.insert((0, 3), state(3.0));
        assert!(c.contains(&(0, 1)));
        assert!(!c.contains(&(0, 2)));
        assert!(c.contains(&(0, 3)));
        assert_eq!(counters.snapshot().evictions, 1);
    }

    #[test]
    fn cache_reinsert_refreshes_without_evicting() {
        let counters = Arc::new(ServeCounters::default());
        let mut c = TrajectoryCache::new(2, counters.clone());
        c.insert((0, 1), state(1.0));
        c.insert((0, 2), state(2.0));
        c.insert((0, 1), state(9.0));
        assert_eq!(c.len(), 2);
        assert_eq!(counters.snapshot().evictions, 0);
        assert_eq!(c.touch(&(0, 1)).unwrap().data[0], 9.0);
        // (0,2) is now LRU
        c.insert((0, 3), state(3.0));
        assert!(!c.contains(&(0, 2)));
    }

    #[test]
    fn touch_and_contains_do_not_count() {
        let counters = Arc::new(ServeCounters::default());
        let mut c = TrajectoryCache::new(2, counters.clone());
        c.insert((0, 1), state(1.0));
        assert!(c.contains(&(0, 1)));
        assert!(c.touch(&(0, 1)).is_some());
        assert!(c.touch(&(0, 9)).is_none());
        let s = counters.snapshot();
        assert_eq!((s.hits, s.misses), (0, 0));
    }

    #[test]
    fn region_answer_view_windows_the_state() {
        // 2x4 grid, 3 channels, value = 100*lat + 10*lon + ch
        let (lat, lon, c) = (2usize, 4usize, 3usize);
        let mut data = vec![0.0f32; lat * lon * c];
        for i in 0..lat {
            for j in 0..lon {
                for k in 0..c {
                    data[(i * lon + j) * c + k] =
                        (100 * i + 10 * j + k) as f32;
                }
            }
        }
        let ans = RegionAnswer {
            state: Arc::new(Tensor::new(vec![lat, lon, c], data)),
            lat: (1, 2),
            lon: (2, 4),
            lon_full: lon,
            channels: c,
        };
        let v = ans.view();
        assert_eq!(v.dims(), (1, 2 * c));
        assert_eq!(v.at(0, 0), 120.0); // lat 1, lon 2, ch 0
        assert_eq!(v.at(0, 3), 130.0); // lat 1, lon 3, ch 0
        assert_eq!(v.at(0, 5), 132.0); // lat 1, lon 3, ch 2
    }
}
