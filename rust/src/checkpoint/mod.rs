//! Sharded checkpointing + elastic mesh resharding (ROADMAP item;
//! motivated by paper Section 7's multi-day runs — at multi-hundred-GPU
//! scale rank failure is a when, not an if).
//!
//! Layout on disk, one directory per checkpointed step:
//!
//! ```text
//! <dir>/step-00000040/
//!   shard-mp0.bin      one per model-parallel rank (codec format);
//!   shard-mp1.bin      written by the dp-group-0 replica only, since
//!   ...                DP replicas are bit-identical after grad reduce
//!   loader-dp0.json    one per data-parallel group (sample cursor +
//!   ...                shuffle-RNG state), written by its mp-rank 0
//!   manifest.json      written LAST by global rank 0, via tmp file +
//!                      atomic rename, after a world barrier
//! ```
//!
//! The ordering is the crash-safety argument: shard and loader files
//! are fully written and fsync-visible before any rank passes the
//! barrier, and the manifest only appears (atomically, via `rename`)
//! after the barrier. A kill at *any* point therefore leaves either a
//! complete checkpoint or a manifest-less directory that
//! [`latest`] skips — never a corrupt "latest". Manifests also record
//! an FNV-64 digest per file, so torn writes from crashed *earlier*
//! attempts are detected and that checkpoint is skipped in favor of an
//! older valid one.
//!
//! Restore is mesh-agnostic: shard files are self-describing (they
//! embed the saving mesh's block-owner tables), so [`load_state`]
//! assembles the global tensors and the trainer reshards them onto
//! whatever mesh the resumed run uses — train on 2x2, resume on 4x4 or
//! 1x2. The reshard oracle (tests/checkpoint_props.rs) pins that a
//! resharded resume is bit-identical to an uninterrupted run on the
//! target mesh.

pub mod codec;

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::comm::Comm;
use crate::config::ModelConfig;
use crate::data::LoaderState;
use crate::jigsaw::Mesh;
use crate::model::params::{assemble_params, PStore};
use crate::tensor::Precision;
use crate::util::json::Json;

/// Where and how often to checkpoint. Carried on `TrainSpec`.
#[derive(Clone, Debug)]
pub struct CheckpointSpec {
    pub dir: PathBuf,
    /// save every N steps (a save fires when `(step+1) % every == 0`)
    pub every: usize,
    /// retain at most this many step directories (min 1)
    pub keep_last: usize,
}

impl CheckpointSpec {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        CheckpointSpec { dir: dir.into(), every: 25, keep_last: 3 }
    }
}

/// Parsed, checksum-verified manifest of one checkpoint directory.
#[derive(Clone, Debug)]
pub struct CheckpointMeta {
    /// the `step-XXXXXXXX` directory this manifest describes
    pub dir: PathBuf,
    pub step: usize,
    pub adam_step: u64,
    pub mesh: Mesh,
    pub dp: usize,
    pub precision: Precision,
    pub config_name: String,
    pub config_hash: u64,
    pub lr: f32,
    pub encdec_lr_factor: f32,
    pub scaler_scale: f32,
    pub scaler_good_steps: usize,
    /// (file name, fnv64) per model-parallel shard
    pub shards: Vec<(String, u64)>,
    /// (file name, fnv64) per data-parallel loader state
    pub loaders: Vec<(String, u64)>,
}

/// Everything one rank contributes to a checkpoint. All ranks call
/// [`save_rank`] (it contains a world barrier); which files a rank
/// actually writes depends on its coordinates.
pub struct RankSave<'a> {
    pub mesh: &'a Mesh,
    pub dp: usize,
    pub dp_idx: usize,
    pub mp_rank: usize,
    pub precision: Precision,
    /// steps completed — the resumed run starts at this step
    pub step: usize,
    pub adam_step: u64,
    pub lr: f32,
    pub encdec_lr_factor: f32,
    pub scaler: (f32, usize),
    pub config_name: &'a str,
    pub config_hash: u64,
    pub params: &'a PStore,
    pub m: &'a PStore,
    pub v: &'a PStore,
    pub loader: LoaderState,
}

/// Global (assembled, mesh-free) training state reloaded from a
/// checkpoint — ready to be resharded onto any viable mesh.
pub struct GlobalState {
    pub meta: CheckpointMeta,
    pub params: Vec<(String, crate::tensor::Tensor)>,
    pub m: Vec<(String, crate::tensor::Tensor)>,
    pub v: Vec<(String, crate::tensor::Tensor)>,
    /// loader state per saved data-parallel group (index = dp_idx)
    pub loaders: Vec<LoaderState>,
}

fn step_dir_name(step: usize) -> String {
    format!("step-{step:08}")
}

fn parse_step_dir(name: &str) -> Option<usize> {
    let digits = name.strip_prefix("step-")?;
    if digits.len() != 8 || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    fs::write(&tmp, bytes).with_context(|| format!("write {}", tmp.display()))?;
    fs::rename(&tmp, path).with_context(|| format!("rename into {}", path.display()))?;
    Ok(())
}

fn hex64(v: u64) -> String {
    format!("0x{v:016x}")
}

fn parse_hex64(s: &str) -> Result<u64> {
    let digits = s.strip_prefix("0x").unwrap_or(s);
    u64::from_str_radix(digits, 16).map_err(|e| anyhow!("bad hex u64 {s:?}: {e}"))
}

fn loader_to_json(s: &LoaderState) -> Json {
    let mut o = BTreeMap::new();
    o.insert(
        "order".to_string(),
        Json::Arr(s.order.iter().map(|&i| Json::Num(i as f64)).collect()),
    );
    o.insert("cursor".to_string(), Json::Num(s.cursor as f64));
    // rng words are full-width u64 — they don't fit f64, so hex strings
    o.insert(
        "rng".to_string(),
        Json::Arr(s.rng.iter().map(|&w| Json::Str(hex64(w))).collect()),
    );
    Json::Obj(o)
}

fn loader_from_json(j: &Json) -> Result<LoaderState> {
    let order = j
        .get("order")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("loader state: missing order"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("loader state: bad order entry")))
        .collect::<Result<Vec<_>>>()?;
    let cursor = j
        .get("cursor")
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow!("loader state: missing cursor"))?;
    let rng_arr = j
        .get("rng")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("loader state: missing rng"))?;
    if rng_arr.len() != 4 {
        bail!("loader state: rng has {} words, want 4", rng_arr.len());
    }
    let mut rng = [0u64; 4];
    for (i, w) in rng_arr.iter().enumerate() {
        rng[i] = parse_hex64(w.as_str().ok_or_else(|| anyhow!("loader state: rng word not a string"))?)?;
    }
    Ok(LoaderState { order, cursor, rng })
}

/// Write this rank's contribution to the checkpoint at `s.step`, then
/// barrier on the world group; global rank 0 finishes by checksumming
/// all files, atomically publishing `manifest.json`, and pruning old
/// step directories. Must be called by every rank at the same step (the
/// barrier deadlocks otherwise — same contract as any collective).
pub fn save_rank(
    ck: &CheckpointSpec,
    s: &RankSave,
    comm: &mut Comm,
    world: &[usize],
) -> Result<()> {
    let dir = ck.dir.join(step_dir_name(s.step));
    fs::create_dir_all(&dir).with_context(|| format!("create {}", dir.display()))?;

    if s.dp_idx == 0 {
        let bytes = codec::encode_shard(s.params, s.m, s.v);
        write_atomic(&dir.join(format!("shard-mp{}.bin", s.mp_rank)), &bytes)?;
    }
    if s.mp_rank == 0 {
        let j = loader_to_json(&s.loader).to_string();
        write_atomic(&dir.join(format!("loader-dp{}.json", s.dp_idx)), j.as_bytes())?;
    }

    // Every rank's files are complete before anyone proceeds; only then
    // may rank 0 publish the manifest that makes this checkpoint "real".
    comm.allreduce_scalar(world, 0.0);

    if s.dp_idx == 0 && s.mp_rank == 0 {
        let mut shards = Vec::new();
        for r in 0..s.mesh.n() {
            let f = format!("shard-mp{r}.bin");
            let bytes = fs::read(dir.join(&f)).with_context(|| format!("read back {f}"))?;
            shards.push((f, codec::fnv64(&bytes)));
        }
        let mut loaders = Vec::new();
        for g in 0..s.dp {
            let f = format!("loader-dp{g}.json");
            let bytes = fs::read(dir.join(&f)).with_context(|| format!("read back {f}"))?;
            loaders.push((f, codec::fnv64(&bytes)));
        }

        let mut o = BTreeMap::new();
        o.insert("version".into(), Json::Num(1.0));
        o.insert("config".into(), Json::Str(s.config_name.to_string()));
        o.insert("config_hash".into(), Json::Str(hex64(s.config_hash)));
        o.insert("mesh".into(), Json::Str(s.mesh.to_string()));
        o.insert("dp".into(), Json::Num(s.dp as f64));
        o.insert("precision".into(), Json::Str(s.precision.to_string()));
        o.insert("step".into(), Json::Num(s.step as f64));
        o.insert("adam_step".into(), Json::Num(s.adam_step as f64));
        o.insert("lr".into(), Json::Num(s.lr as f64));
        o.insert("encdec_lr_factor".into(), Json::Num(s.encdec_lr_factor as f64));
        let mut sc = BTreeMap::new();
        sc.insert("scale".into(), Json::Num(s.scaler.0 as f64));
        sc.insert("good_steps".into(), Json::Num(s.scaler.1 as f64));
        o.insert("scaler".into(), Json::Obj(sc));
        let file_list = |v: &[(String, u64)]| {
            Json::Arr(
                v.iter()
                    .map(|(f, h)| {
                        let mut e = BTreeMap::new();
                        e.insert("file".into(), Json::Str(f.clone()));
                        e.insert("fnv".into(), Json::Str(hex64(*h)));
                        Json::Obj(e)
                    })
                    .collect(),
            )
        };
        o.insert("shards".into(), file_list(&shards));
        o.insert("loaders".into(), file_list(&loaders));

        write_atomic(&dir.join("manifest.json"), Json::Obj(o).to_string().as_bytes())?;
        prune(ck, s.step)?;
    }
    Ok(())
}

/// Delete step directories beyond `keep_last`, never touching the one
/// just written. Best-effort: a failed delete is not a training error.
fn prune(ck: &CheckpointSpec, just_wrote: usize) -> Result<()> {
    let keep = ck.keep_last.max(1);
    let mut steps: Vec<usize> = match fs::read_dir(&ck.dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .filter_map(|e| parse_step_dir(&e.file_name().to_string_lossy()))
            .collect(),
        Err(_) => return Ok(()),
    };
    steps.sort_unstable_by(|a, b| b.cmp(a));
    for &st in steps.iter().skip(keep) {
        if st != just_wrote {
            let _ = fs::remove_dir_all(ck.dir.join(step_dir_name(st)));
        }
    }
    Ok(())
}

fn read_meta(dir: &Path) -> Result<CheckpointMeta> {
    let raw = fs::read_to_string(dir.join("manifest.json"))
        .with_context(|| format!("read {}/manifest.json", dir.display()))?;
    let j = Json::parse(&raw).map_err(|e| anyhow!("manifest parse: {e}"))?;
    let get_str = |k: &str| -> Result<&str> {
        j.get(k).and_then(|v| v.as_str()).ok_or_else(|| anyhow!("manifest: missing {k}"))
    };
    let get_num = |k: &str| -> Result<f64> {
        j.get(k).and_then(|v| v.as_f64()).ok_or_else(|| anyhow!("manifest: missing {k}"))
    };
    let version = get_num("version")? as u32;
    if version != 1 {
        bail!("manifest: unsupported version {version}");
    }
    let mesh = Mesh::parse(get_str("mesh")?).map_err(|e| anyhow!("manifest mesh: {e}"))?;
    let precision: Precision = get_str("precision")?
        .parse()
        .map_err(|e| anyhow!("manifest precision: {e}"))?;
    let scaler = j.get("scaler").ok_or_else(|| anyhow!("manifest: missing scaler"))?;
    let file_list = |k: &str| -> Result<Vec<(String, u64)>> {
        j.get(k)
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest: missing {k}"))?
            .iter()
            .map(|e| {
                let f = e
                    .get("file")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("manifest {k}: missing file"))?;
                let h = parse_hex64(
                    e.get("fnv")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow!("manifest {k}: missing fnv"))?,
                )?;
                Ok((f.to_string(), h))
            })
            .collect()
    };
    let meta = CheckpointMeta {
        dir: dir.to_path_buf(),
        step: get_num("step")? as usize,
        adam_step: get_num("adam_step")? as u64,
        dp: get_num("dp")? as usize,
        mesh,
        precision,
        config_name: get_str("config")?.to_string(),
        config_hash: parse_hex64(get_str("config_hash")?)?,
        lr: get_num("lr")? as f32,
        encdec_lr_factor: get_num("encdec_lr_factor")? as f32,
        scaler_scale: scaler
            .get("scale")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("manifest: missing scaler.scale"))? as f32,
        scaler_good_steps: scaler
            .get("good_steps")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("manifest: missing scaler.good_steps"))?,
        shards: file_list("shards")?,
        loaders: file_list("loaders")?,
    };
    if meta.shards.len() != meta.mesh.n() {
        bail!("manifest: {} shards for a {} mesh", meta.shards.len(), meta.mesh);
    }
    if meta.loaders.len() != meta.dp {
        bail!("manifest: {} loader states for dp {}", meta.loaders.len(), meta.dp);
    }
    // verify every listed file's digest — a torn write from a crashed
    // attempt fails here and latest() falls back to an older step
    for (f, want) in meta.shards.iter().chain(meta.loaders.iter()) {
        let bytes = fs::read(dir.join(f)).with_context(|| format!("checkpoint file {f}"))?;
        let got = codec::fnv64(&bytes);
        if got != *want {
            bail!("checkpoint file {f}: digest {} != manifest {}", hex64(got), hex64(*want));
        }
    }
    Ok(meta)
}

/// Newest valid checkpoint under `dir`, or `None`. "Valid" means the
/// manifest parses and every listed file passes its digest; invalid or
/// manifest-less step directories are skipped in favor of older ones.
pub fn latest(dir: &Path) -> Result<Option<CheckpointMeta>> {
    let rd = match fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(_) => return Ok(None),
    };
    let mut steps: Vec<usize> = rd
        .filter_map(|e| e.ok())
        .filter_map(|e| parse_step_dir(&e.file_name().to_string_lossy()))
        .collect();
    steps.sort_unstable_by(|a, b| b.cmp(a));
    for st in steps {
        if let Ok(meta) = read_meta(&dir.join(step_dir_name(st))) {
            if meta.step == st {
                return Ok(Some(meta));
            }
        }
    }
    Ok(None)
}

/// Load and assemble the full global state of a verified checkpoint.
/// `cfg` must hash-match the saving run; the result is mesh-free and is
/// resharded by the trainer onto the resumed run's mesh.
pub fn load_state(cfg: &ModelConfig, meta: &CheckpointMeta) -> Result<GlobalState> {
    if meta.config_hash != cfg.content_hash() {
        bail!(
            "checkpoint was saved for config {:?} (hash {}), refusing to resume config {:?} (hash {})",
            meta.config_name,
            hex64(meta.config_hash),
            cfg.name,
            hex64(cfg.content_hash()),
        );
    }
    let mut pstores = Vec::new();
    let mut mstores = Vec::new();
    let mut vstores = Vec::new();
    for (f, _) in &meta.shards {
        let bytes = fs::read(meta.dir.join(f)).with_context(|| format!("shard {f}"))?;
        let (p, m, v) = codec::decode_shard(&bytes).with_context(|| format!("shard {f}"))?;
        pstores.push(p);
        mstores.push(m);
        vstores.push(v);
    }
    let params = assemble_params(cfg, &pstores.iter().collect::<Vec<_>>());
    let m = assemble_params(cfg, &mstores.iter().collect::<Vec<_>>());
    let v = assemble_params(cfg, &vstores.iter().collect::<Vec<_>>());
    let mut loaders = Vec::new();
    for (f, _) in &meta.loaders {
        let raw = fs::read_to_string(meta.dir.join(f)).with_context(|| format!("loader {f}"))?;
        let j = Json::parse(&raw).map_err(|e| anyhow!("loader {f}: {e}"))?;
        loaders.push(loader_from_json(&j).with_context(|| format!("loader {f}"))?);
    }
    Ok(GlobalState { meta: meta.clone(), params, m, v, loaders })
}

/// Load and assemble *weights only* from a verified checkpoint — the
/// serving path. Adam moments, the loss-scaler state, and loader cursors
/// are decoded shard-by-shard but never assembled or returned: an
/// inference deployment holds exactly one copy of the parameters and no
/// optimizer state. Same config-hash gate as [`load_state`].
pub fn load_params(
    cfg: &ModelConfig,
    meta: &CheckpointMeta,
) -> Result<Vec<(String, Tensor)>> {
    if meta.config_hash != cfg.content_hash() {
        bail!(
            "checkpoint was saved for config {:?} (hash {}), refusing to serve config {:?} (hash {})",
            meta.config_name,
            hex64(meta.config_hash),
            cfg.name,
            hex64(cfg.content_hash()),
        );
    }
    let mut pstores = Vec::new();
    for (f, _) in &meta.shards {
        let bytes = fs::read(meta.dir.join(f)).with_context(|| format!("shard {f}"))?;
        let (p, _m, _v) = codec::decode_shard(&bytes).with_context(|| format!("shard {f}"))?;
        pstores.push(p);
    }
    Ok(assemble_params(cfg, &pstores.iter().collect::<Vec<_>>()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loader_state_json_roundtrip() {
        let s = LoaderState {
            order: vec![3, 0, 2, 1],
            cursor: 2,
            rng: [u64::MAX, 0, 0xDEADBEEFCAFEBABE, 1],
        };
        let j = loader_to_json(&s).to_string();
        let back = loader_from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn step_dir_names_parse_and_sort() {
        assert_eq!(parse_step_dir("step-00000040"), Some(40));
        assert_eq!(parse_step_dir("step-0040"), None);
        assert_eq!(parse_step_dir("manifest.json"), None);
        assert_eq!(parse_step_dir("step-abcdefgh"), None);
        assert_eq!(step_dir_name(40), "step-00000040");
    }

    #[test]
    fn hex64_roundtrip() {
        for v in [0u64, 1, u64::MAX, 0xcbf29ce484222325] {
            assert_eq!(parse_hex64(&hex64(v)).unwrap(), v);
        }
    }
}
