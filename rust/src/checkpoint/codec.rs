//! Binary shard codec for checkpoints.
//!
//! One shard file holds three [`PStore`] sections — parameters, Adam
//! first moments, Adam second moments — for a single model-parallel
//! rank. The format is self-describing: every matrix carries its global
//! dims and the full `BlockGrid` owner table of the mesh it was saved
//! on, so restore can reassemble the global tensors without knowing the
//! saving mesh's `Planner` (this is what makes resharding onto a
//! different mesh a pure assemble-then-reshard pass).
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic      b"JGSWCKP1"
//! section x3 (params, m, v):
//!   u32 n_mats
//!   per mat:  str name | u64 rows | u64 cols | u32 rb | u32 cb
//!             u32 owner[rb*cb] (row-major) | u32 n_local_blocks
//!             per block: u32 bi | u32 bj | f32 data[rows/rb * cols/cb]
//!   u32 n_vecs
//!   per vec:  str name | u64 full_len | u64 lo | u64 hi | f32 data[hi-lo]
//! str = u32 byte-len | utf8 bytes
//! ```
//!
//! Integrity is enforced one level up: the manifest records an
//! [`fnv64`] digest of each shard file's bytes, and `latest()` refuses
//! any checkpoint whose digests don't verify.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::jigsaw::{BlockGrid, DistMat};
use crate::model::params::{PStore, VecShard};
use crate::tensor::Tensor;

pub const MAGIC: &[u8; 8] = b"JGSWCKP1";

/// FNV-1a over raw bytes — the manifest checksum primitive.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn encode_store(out: &mut Vec<u8>, store: &PStore) {
    put_u32(out, store.mats.len() as u32);
    for (name, m) in &store.mats {
        put_str(out, name);
        put_u64(out, m.rows as u64);
        put_u64(out, m.cols as u64);
        put_u32(out, m.grid.rb as u32);
        put_u32(out, m.grid.cb as u32);
        for row in &m.grid.owner {
            for &r in row {
                put_u32(out, r as u32);
            }
        }
        put_u32(out, m.blocks.len() as u32);
        for ((bi, bj), t) in &m.blocks {
            put_u32(out, *bi as u32);
            put_u32(out, *bj as u32);
            put_f32s(out, &t.data);
        }
    }
    put_u32(out, store.vecs.len() as u32);
    for (name, v) in &store.vecs {
        put_str(out, name);
        put_u64(out, v.full_len as u64);
        put_u64(out, v.lo as u64);
        put_u64(out, v.hi as u64);
        put_f32s(out, &v.local.data);
    }
}

/// Serialize one rank's parameter + Adam-moment shards.
pub fn encode_shard(params: &PStore, m: &PStore, v: &PStore) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + params.local_count() * 12);
    out.extend_from_slice(MAGIC);
    encode_store(&mut out, params);
    encode_store(&mut out, m);
    encode_store(&mut out, v);
    out
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("checkpoint shard truncated at byte {} (wanted {n} more)", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    /// `take(N)` as a fixed-size array (the length check already
    /// happened in `take`, so this conversion is infallible by
    /// construction — spelled without `unwrap` so a future length bug
    /// surfaces as a typed error, not a rank panic).
    fn arr<const N: usize>(&mut self) -> Result<[u8; N]> {
        let mut a = [0u8; N];
        a.copy_from_slice(self.take(N)?);
        Ok(a)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.arr::<4>()?))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.arr::<8>()?))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(std::str::from_utf8(self.take(n)?)
            .context("checkpoint shard: non-utf8 name")?
            .to_string())
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

fn decode_store(r: &mut Reader) -> Result<PStore> {
    let n_mats = r.u32()? as usize;
    let mut mats = BTreeMap::new();
    for _ in 0..n_mats {
        let name = r.str()?;
        let rows = r.u64()? as usize;
        let cols = r.u64()? as usize;
        let rb = r.u32()? as usize;
        let cb = r.u32()? as usize;
        if rb == 0 || cb == 0 || rows % rb != 0 || cols % cb != 0 {
            bail!("checkpoint shard: mat {name} has bad grid {rb}x{cb} for {rows}x{cols}");
        }
        let mut owner = vec![vec![0usize; cb]; rb];
        for row in owner.iter_mut() {
            for o in row.iter_mut() {
                *o = r.u32()? as usize;
            }
        }
        let (br, bc) = (rows / rb, cols / cb);
        let n_blocks = r.u32()? as usize;
        let mut blocks = BTreeMap::new();
        for _ in 0..n_blocks {
            let bi = r.u32()? as usize;
            let bj = r.u32()? as usize;
            if bi >= rb || bj >= cb {
                bail!("checkpoint shard: mat {name} block ({bi},{bj}) outside {rb}x{cb} grid");
            }
            let data = r.f32s(br * bc)?;
            blocks.insert((bi, bj), Tensor::new(vec![br, bc], data));
        }
        mats.insert(
            name,
            DistMat { grid: BlockGrid::new(owner), rows, cols, blocks, cache: None },
        );
    }
    let n_vecs = r.u32()? as usize;
    let mut vecs = BTreeMap::new();
    for _ in 0..n_vecs {
        let name = r.str()?;
        let full_len = r.u64()? as usize;
        let lo = r.u64()? as usize;
        let hi = r.u64()? as usize;
        if lo > hi || hi > full_len {
            bail!("checkpoint shard: vec {name} slice {lo}..{hi} outside 0..{full_len}");
        }
        let data = r.f32s(hi - lo)?;
        // sync_group is a property of the *target* mesh, not the saved
        // shard; restore reshards via shard_params which rebuilds it.
        vecs.insert(
            name,
            VecShard { full_len, lo, hi, local: Tensor::new(vec![hi - lo], data), sync_group: Vec::new() },
        );
    }
    Ok(PStore { mats, vecs })
}

/// Decode one shard file back into (params, m, v) stores. The stores
/// describe the *saving* mesh's layout; callers assemble and reshard.
pub fn decode_shard(bytes: &[u8]) -> Result<(PStore, PStore, PStore)> {
    let mut r = Reader { b: bytes, i: 0 };
    let magic = r.take(8)?;
    if magic != MAGIC {
        bail!("checkpoint shard: bad magic {magic:02x?} (want {MAGIC:02x?})");
    }
    let params = decode_store(&mut r)?;
    let m = decode_store(&mut r)?;
    let v = decode_store(&mut r)?;
    if r.i != r.b.len() {
        bail!("checkpoint shard: {} trailing bytes", r.b.len() - r.i);
    }
    Ok((params, m, v))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::jigsaw::Mesh;
    use crate::model::init_global_params;
    use crate::model::params::shard_params;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            lat: 8,
            lon: 16,
            channels: 6,
            channels_padded: 8,
            patch: 2,
            d_emb: 32,
            d_tok: 48,
            d_ch: 32,
            blocks: 1,
            tokens: 32,
            patch_dim: 32,
            param_count: 0,
            flops_forward: 0,
            channel_weights: vec![1.0; 6],
        }
    }

    #[test]
    fn shard_roundtrips_bit_exactly() {
        let cfg = tiny_cfg();
        let global = init_global_params(&cfg, 3);
        let mesh = Mesh::new(2, 2).unwrap();
        for rank in 0..mesh.n() {
            let p = shard_params(&cfg, &mesh, rank, &global).unwrap();
            let m = p.zeros_like();
            let v = p.zeros_like();
            let bytes = encode_shard(&p, &m, &v);
            let (p2, m2, v2) = decode_shard(&bytes).unwrap();
            assert_eq!(p.mats.len(), p2.mats.len());
            assert_eq!(p.vecs.len(), p2.vecs.len());
            for (name, dm) in &p.mats {
                let dm2 = &p2.mats[name];
                assert_eq!(dm.grid.owner, dm2.grid.owner, "{name} owner table");
                assert_eq!((dm.rows, dm.cols), (dm2.rows, dm2.cols));
                for (key, t) in &dm.blocks {
                    assert_eq!(t.data, dm2.blocks[key].data, "{name} block {key:?}");
                }
                assert!(dm2.cache.is_none(), "decoded mats carry no cache identity");
            }
            for (name, vs) in &p.vecs {
                let vs2 = &p2.vecs[name];
                assert_eq!((vs.full_len, vs.lo, vs.hi), (vs2.full_len, vs2.lo, vs2.hi));
                assert_eq!(vs.local.data, vs2.local.data, "{name} slice");
            }
            assert_eq!(m.mats.len(), m2.mats.len());
            assert_eq!(v.vecs.len(), v2.vecs.len());
        }
    }

    #[test]
    fn corruption_is_rejected() {
        let cfg = tiny_cfg();
        let global = init_global_params(&cfg, 3);
        let p = shard_params(&cfg, &Mesh::unit(), 0, &global).unwrap();
        let m = p.zeros_like();
        let v = p.zeros_like();
        let bytes = encode_shard(&p, &m, &v);
        // truncation
        assert!(decode_shard(&bytes[..bytes.len() - 5]).is_err());
        // bad magic
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(decode_shard(&bad).is_err());
        // trailing garbage
        let mut long = bytes.clone();
        long.push(0);
        assert!(decode_shard(&long).is_err());
        // checksum catches interior bit-flips even when the structure
        // still parses
        let mut flip = bytes.clone();
        let mid = flip.len() / 2;
        flip[mid] ^= 0x01;
        assert_ne!(fnv64(&flip), fnv64(&bytes));
    }
}
