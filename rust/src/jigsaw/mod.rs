//! Jigsaw parallelism: the paper's core contribution as a general
//! block-distributed matmul engine.
//!
//! Paper Section 4 derives 2-way (Eq. 1-2) and 4-way (Eq. 3-4) schemes and
//! notes that "the model parallelism can be extended to arbitrary n-way
//! parallelism by further splitting up the final dimensions into blockwise
//! subdivisions". This module implements exactly that generalisation:
//!
//!   * a matrix is block-partitioned over a rank grid (`BlockGrid`);
//!   * `dist_matmul` executes Y = X op W over the blocks, computing each
//!     term at a stationary operand's owner (weights never move — the
//!     zero-memory-redundancy property), shipping the mobile operand's
//!     blocks point-to-point, and reducing partial sums at the output
//!     owners;
//!   * communication overlaps computation through a *ready-queue*
//!     schedule over the non-blocking fabric, mirroring the paper's
//!     Section 4.1/5 isend/irecv pipelining: outgoing blocks are posted
//!     (isend) up front; local-input terms compute while the fabric is
//!     polled (`try_recv`); each remote term runs the moment its mobile
//!     block lands (`recv_any` = waitany once local work runs dry); and
//!     every partial sum is posted the moment its accumulator completes,
//!     not after the whole term loop. Output owners receive incoming
//!     partials in arrival order and reduce them in a fixed order. The
//!     pre-ready-queue fixed-order pipeline survives as
//!     `dist_matmul_blocking` — the overlap benches' baseline and a
//!     second oracle for the scheduler. When a `comm::ProgressEngine` is
//!     installed on the rank (the trainer's grad-ready DP scheduler does
//!     this for the whole backward pass), the schedule's dry-waits —
//!     `recv_any` with no computable term, and the phase-4 partial-sum
//!     collection — double as poll points: in-flight DP bucket rings on
//!     the *other* fabric advance while this rank waits for jigsaw
//!     traffic, instead of stalling until the next gradient emission.
//!
//! For the paper's layouts this reproduces the published schedules term
//! for term: in 2-way each rank computes X_r W_{r,j}^T locally and
//! exchanges one partial sum per linear layer; in 4-way ranks exchange
//! data blocks along column pairs (0<->2, 1<->3) and partial sums along
//! row pairs, and e.g. rank 1 sends X_1 W_1^T to rank 0 while rank 0
//! computes X_0 W_0^T — the exact example in Section 4.2.
//!
//! *Which* blocks live where is no longer hand-enumerated per parallel
//! degree: the [`mesh`] module holds the first-class parallelism API — a
//! [`Mesh`] describing the device grid with named `tok x ch` axes, a
//! [`ShardSpec`] per logical tensor, and a [`Planner`] deriving the
//! `BlockGrid`s/owner maps this engine consumes. The paper's 1/2/4-way
//! schemes are the `1x1`, `1x2`, and `2x2` meshes; `2x4` and `4x4` give
//! 8- and 16-way jigsaw with the same schedule machinery.

pub mod mesh;

pub use mesh::{block_cache_key, LAxis, Mesh, MeshError, Planner, ShardSpec};

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::Arc;

use anyhow::Result;

use crate::comm::{Comm, Payload};
use crate::runtime::{Backend, MatmulOp};
use crate::tensor::{ops, Bf16Tensor, Precision, Tensor};

/// Block partition of a [rows, cols] matrix over ranks: `owner[bi][bj]`
/// names the rank holding block (bi, bj). Several blocks may share an
/// owner; every block has exactly one owner (zero redundancy).
#[derive(Clone, Debug, PartialEq)]
pub struct BlockGrid {
    pub rb: usize,
    pub cb: usize,
    pub owner: Vec<Vec<usize>>,
}

impl BlockGrid {
    pub fn new(owner: Vec<Vec<usize>>) -> Self {
        let rb = owner.len();
        let cb = owner[0].len();
        for row in &owner {
            assert_eq!(row.len(), cb, "ragged owner grid");
        }
        BlockGrid { rb, cb, owner }
    }

    /// Single block owned by rank 0 (the 1-way layout).
    pub fn single() -> Self {
        BlockGrid::new(vec![vec![0]])
    }

    pub fn owner_of(&self, bi: usize, bj: usize) -> usize {
        self.owner[bi][bj]
    }

    /// All (bi, bj) owned by `rank`.
    pub fn blocks_of(&self, rank: usize) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for bi in 0..self.rb {
            for bj in 0..self.cb {
                if self.owner[bi][bj] == rank {
                    out.push((bi, bj));
                }
            }
        }
        out
    }
}

/// One rank's shard of a block-distributed matrix.
#[derive(Clone, Debug)]
pub struct DistMat {
    pub grid: BlockGrid,
    /// global dims
    pub rows: usize,
    pub cols: usize,
    /// blocks this rank owns
    pub blocks: BTreeMap<(usize, usize), Tensor>,
    /// device-buffer cache identity (id base, version) — set for parameter
    /// matrices so the runtime keeps their blocks resident (§Perf);
    /// None for activations/gradients.
    pub cache: Option<crate::runtime::CacheKey>,
}

impl DistMat {
    pub fn block_dims(&self) -> (usize, usize) {
        assert!(
            self.rows % self.grid.rb == 0 && self.cols % self.grid.cb == 0,
            "{}x{} not divisible by {}x{} grid",
            self.rows,
            self.cols,
            self.grid.rb,
            self.grid.cb
        );
        (self.rows / self.grid.rb, self.cols / self.grid.cb)
    }

    /// Shard a global tensor: keep only the blocks `rank` owns.
    pub fn from_global(global: &Tensor, grid: BlockGrid, rank: usize) -> Self {
        let (r, c) = global.dims2();
        let mut m = DistMat { grid, rows: r, cols: c, blocks: BTreeMap::new(), cache: None };
        let _ = m.block_dims(); // divisibility check
        for (bi, bj) in m.grid.blocks_of(rank) {
            m.blocks
                .insert((bi, bj), global.block(bi, bj, m.grid.rb, m.grid.cb));
        }
        m
    }

    /// Empty (no local blocks yet) with a given layout.
    pub fn empty(rows: usize, cols: usize, grid: BlockGrid) -> Self {
        DistMat { grid, rows, cols, blocks: BTreeMap::new(), cache: None }
    }

    /// Zero-filled local blocks for `rank`.
    pub fn zeros(rows: usize, cols: usize, grid: BlockGrid, rank: usize) -> Self {
        let mut m = DistMat::empty(rows, cols, grid);
        let (br, bc) = m.block_dims();
        for key in m.grid.blocks_of(rank) {
            m.blocks.insert(key, Tensor::zeros(&[br, bc]));
        }
        m
    }

    /// Reassemble the global matrix from per-rank shards (test/checkpoint
    /// helper; `parts` are the same DistMat from every rank). Each block
    /// is copied exactly once, straight into its strided slot of the
    /// output (no intermediate block grid).
    pub fn assemble(parts: &[&DistMat]) -> Tensor {
        let grid = &parts[0].grid;
        let (rows, cols) = (parts[0].rows, parts[0].cols);
        let (br, bc) = parts[0].block_dims();
        let mut out = Tensor::zeros(&[rows, cols]);
        for bi in 0..grid.rb {
            for bj in 0..grid.cb {
                let blk = parts
                    .iter()
                    .find_map(|p| p.blocks.get(&(bi, bj)))
                    .unwrap_or_else(|| panic!("no rank holds block ({bi},{bj})"));
                assert_eq!(blk.dims2(), (br, bc), "ragged blocks");
                out.view2_mut()
                    .into_block(bi, bj, grid.rb, grid.cb)
                    .copy_from(blk.view2());
            }
        }
        out
    }

    /// Apply f to every local block.
    pub fn map(&self, f: impl Fn(&Tensor) -> Tensor) -> DistMat {
        DistMat {
            grid: self.grid.clone(),
            rows: self.rows,
            cols: self.cols,
            blocks: self
                .blocks
                .iter()
                .map(|(k, v)| (*k, f(v)))
                .collect(),
            cache: None,
        }
    }

    /// Mutate every local block in place (no per-block reallocation).
    pub fn map_assign(&mut self, f: impl Fn(&mut Tensor)) {
        for b in self.blocks.values_mut() {
            f(b);
        }
        self.cache = None;
    }

    /// Elementwise combine with another DistMat of identical layout.
    pub fn zip(&self, other: &DistMat, f: impl Fn(&Tensor, &Tensor) -> Tensor) -> DistMat {
        assert_eq!(self.grid, other.grid, "layout mismatch in zip");
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        DistMat {
            grid: self.grid.clone(),
            rows: self.rows,
            cols: self.cols,
            blocks: self
                .blocks
                .iter()
                .map(|(k, v)| (*k, f(v, &other.blocks[k])))
                .collect(),
            cache: None,
        }
    }

    /// Elementwise combine in place: f(&mut self_block, &other_block) per
    /// block. The buffer-reuse twin of `zip` for residual adds and
    /// gradient accumulation on the forward/backward hot path.
    pub fn zip_assign(&mut self, other: &DistMat, f: impl Fn(&mut Tensor, &Tensor)) {
        assert_eq!(self.grid, other.grid, "layout mismatch in zip_assign");
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (k, b) in self.blocks.iter_mut() {
            f(b, &other.blocks[k]);
        }
        self.cache = None;
    }
}

/// Which operand stays put (its owner computes the term). Weights are
/// stationary — `XIsWeights` for the transposed-MLP layers where the
/// weight matrix is the left operand.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// terms run at the x-operand owner; w blocks are shipped
    XOwner,
    /// terms run at the w-operand owner; x blocks are shipped
    WOwner,
}

/// Execution context of one rank inside one jigsaw group: the group's
/// device mesh, this rank's flattened coordinate on it, and the fabric +
/// compute handles.
pub struct Ctx<'a> {
    pub mesh: Mesh,
    pub rank: usize,
    pub comm: &'a mut Comm,
    pub backend: &'a dyn Backend,
    /// per-group call sequence number (identical across ranks by SPMD
    /// construction); namespaces message tags per dist_matmul call.
    pub seq: u64,
    /// Fabric precision for shipped mobile blocks and partial sums:
    /// `F32` moves tensors verbatim; `Bf16` quantizes (round to nearest
    /// even) at the send side and widens back into pooled f32 buffers on
    /// arrival, halving jigsaw traffic. Accumulation is f32 either way.
    pub precision: Precision,
}

impl<'a> Ctx<'a> {
    pub fn new(
        mesh: Mesh,
        rank: usize,
        comm: &'a mut Comm,
        backend: &'a dyn Backend,
    ) -> Self {
        Ctx { mesh, rank, comm, backend, seq: 0, precision: Precision::F32 }
    }

    /// Forward-only execution context (the serving path). Identical to
    /// [`Ctx::new`] plus an explicit fabric/storage precision; named
    /// separately because an infer ctx is paired with a sync-group-free
    /// parameter store (`model::params::shard_params_infer`) — no
    /// gradient collectives can ever be issued through it, so the only
    /// traffic is `dist_matmul`'s block exchange.
    pub fn infer(
        mesh: Mesh,
        rank: usize,
        comm: &'a mut Comm,
        backend: &'a dyn Backend,
        precision: Precision,
    ) -> Self {
        Ctx { mesh, rank, comm, backend, seq: 0, precision }
    }
}

/// A term of the block matmul: Y[yi,yj] += x_block op w_block.
#[derive(Clone, Copy, Debug)]
struct Term {
    x: (usize, usize),
    w: (usize, usize),
    y: (usize, usize),
}

/// Enumerate the block terms of Y = X op W and check grid conformance.
fn terms(op: MatmulOp, x: &DistMat, w: &DistMat, y_grid: &BlockGrid) -> Vec<Term> {
    let (xg, wg) = (&x.grid, &w.grid);
    let mut out = Vec::new();
    match op {
        // Y[i,j] = sum_k X[i,k] W[j,k]^T
        MatmulOp::NT => {
            assert_eq!(xg.cb, wg.cb, "nt contraction grids");
            assert_eq!((y_grid.rb, y_grid.cb), (xg.rb, wg.rb), "nt output grid");
            for i in 0..xg.rb {
                for j in 0..wg.rb {
                    for k in 0..xg.cb {
                        out.push(Term { x: (i, k), w: (j, k), y: (i, j) });
                    }
                }
            }
        }
        // Y[i,j] = sum_k X[i,k] W[k,j]
        MatmulOp::NN => {
            assert_eq!(xg.cb, wg.rb, "nn contraction grids");
            assert_eq!((y_grid.rb, y_grid.cb), (xg.rb, wg.cb), "nn output grid");
            for i in 0..xg.rb {
                for j in 0..wg.cb {
                    for k in 0..xg.cb {
                        out.push(Term { x: (i, k), w: (k, j), y: (i, j) });
                    }
                }
            }
        }
        // Y[i,j] = sum_k X[k,i]^T W[k,j]
        MatmulOp::TN => {
            assert_eq!(xg.rb, wg.rb, "tn contraction grids");
            assert_eq!((y_grid.rb, y_grid.cb), (xg.cb, wg.cb), "tn output grid");
            for i in 0..xg.cb {
                for j in 0..wg.cb {
                    for k in 0..xg.rb {
                        out.push(Term { x: (k, i), w: (k, j), y: (i, j) });
                    }
                }
            }
        }
    }
    out
}

/// Tag layout for dist_matmul messages:
/// [63]=0  [62:56]=kind  [55:40]=seq  [39:20]=block id  [19:0]=aux
fn tag_ship(seq: u64, bi: usize, bj: usize) -> u64 {
    (1u64 << 56) | ((seq & 0xFFFF) << 40) | ((bi as u64) << 30) | ((bj as u64) << 20)
}

fn tag_partial(seq: u64, yi: usize, yj: usize, site: usize) -> u64 {
    (2u64 << 56)
        | ((seq & 0xFFFF) << 40)
        | ((yi as u64) << 30)
        | ((yj as u64) << 20)
        | site as u64
}

/// The rank a term computes at.
fn term_site(site: Site, x: &DistMat, w: &DistMat, t: &Term) -> usize {
    match site {
        Site::XOwner => x.grid.owner_of(t.x.0, t.x.1),
        Site::WOwner => w.grid.owner_of(t.w.0, t.w.1),
    }
}

/// The rank that owns (and may have to ship) a term's mobile operand.
fn term_mobile_owner(site: Site, x: &DistMat, w: &DistMat, t: &Term) -> usize {
    match site {
        Site::XOwner => w.grid.owner_of(t.w.0, t.w.1),
        Site::WOwner => x.grid.owner_of(t.x.0, t.x.1),
    }
}

/// Block key of a term's mobile operand.
fn term_mobile_key(site: Site, t: &Term) -> (usize, usize) {
    match site {
        Site::XOwner => t.w,
        Site::WOwner => t.x,
    }
}

/// Phase 1 of both schedules: post every mobile-operand block this rank
/// must ship (isend). One payload per block — fanning a block out to
/// several sites enqueues reference clones, never data copies — and one
/// quantization per block in bf16 mode, shared by every destination.
fn ship_mobile_blocks(
    comm: &Comm,
    me: usize,
    seq: u64,
    site: Site,
    x: &DistMat,
    w: &DistMat,
    all_terms: &[Term],
    prec: Precision,
) {
    let mut shipped: BTreeSet<((usize, usize), usize)> = Default::default();
    let mut outbox: BTreeMap<(usize, usize), Payload> = BTreeMap::new();
    for t in all_terms {
        let s = term_site(site, x, w, t);
        let mo = term_mobile_owner(site, x, w, t);
        let key = term_mobile_key(site, t);
        if mo == me && s != me && shipped.insert((key, s)) {
            let p = outbox
                .entry(key)
                .or_insert_with(|| {
                    let blk = match site {
                        Site::XOwner => &w.blocks[&key],
                        Site::WOwner => &x.blocks[&key],
                    };
                    match prec {
                        Precision::F32 => Payload::F32(Arc::new(blk.clone())),
                        Precision::Bf16 => {
                            Payload::Bf16(Arc::new(Bf16Tensor::from_tensor(blk)))
                        }
                    }
                })
                .clone();
            comm.send_payload(s, tag_ship(seq, key.0, key.1), p);
        }
    }
}

/// Post a completed partial sum at the fabric precision: f32 moves the
/// accumulator itself into the fabric (zero copies); bf16 ships a
/// quantized copy and returns the f32 accumulator to the pool.
fn send_partial(comm: &Comm, dst: usize, tag: u64, p: Tensor, prec: Precision) {
    match prec {
        Precision::F32 => comm.send(dst, tag, p),
        Precision::Bf16 => {
            comm.send_bf16(dst, tag, Bf16Tensor::from_tensor(&p));
            p.recycle();
        }
    }
}

/// Resolve a term's operands (local blocks carry their device-buffer
/// cache key; shipped blocks are activations and never cached) and reduce
/// it straight into the partial-sum accumulator: the native backend
/// computes in place (zero intermediate tensors), device backends combine
/// host-side and recycle the transient.
#[allow(clippy::too_many_arguments)]
fn compute_term(
    backend: &dyn Backend,
    op: MatmulOp,
    site: Site,
    me: usize,
    x: &DistMat,
    w: &DistMat,
    received: &BTreeMap<(usize, usize), Arc<Tensor>>,
    partials: &mut BTreeMap<(usize, usize), Tensor>,
    use_into: bool,
    t: &Term,
) -> Result<()> {
    let (xb, xkey, wb, wkey): (&Tensor, _, &Tensor, _) = match site {
        Site::XOwner => {
            let xb = &x.blocks[&t.x];
            let xkey = x.cache.map(|c| block_cache_key(c, t.x));
            let (wb, wkey) = if w.grid.owner_of(t.w.0, t.w.1) == me {
                (&w.blocks[&t.w], w.cache.map(|c| block_cache_key(c, t.w)))
            } else {
                (&*received[&t.w], None)
            };
            (xb, xkey, wb, wkey)
        }
        Site::WOwner => {
            let wb = &w.blocks[&t.w];
            let wkey = w.cache.map(|c| block_cache_key(c, t.w));
            let (xb, xkey) = if x.grid.owner_of(t.x.0, t.x.1) == me {
                (&x.blocks[&t.x], x.cache.map(|c| block_cache_key(c, t.x)))
            } else {
                (&*received[&t.x], None)
            };
            (xb, xkey, wb, wkey)
        }
    };
    match partials.entry(t.y) {
        std::collections::btree_map::Entry::Vacant(e) => {
            if use_into {
                let (m, n) = op.out_dims(xb, wb);
                let mut acc = Tensor::pooled_zeros(&[m, n]);
                backend.matmul_into(op, xb, xkey, wb, wkey, &mut acc, false)?;
                e.insert(acc);
            } else {
                e.insert(backend.matmul_cached(op, xb, xkey, wb, wkey)?);
            }
        }
        std::collections::btree_map::Entry::Occupied(mut e) => {
            backend.matmul_into(op, xb, xkey, wb, wkey, e.get_mut(), true)?;
        }
    }
    Ok(())
}

/// Global output dims of Y = X op W.
fn out_global_dims(op: MatmulOp, x: &DistMat, w: &DistMat) -> (usize, usize) {
    match op {
        MatmulOp::NT => (x.rows, w.rows),
        MatmulOp::NN => (x.rows, w.cols),
        MatmulOp::TN => (x.cols, w.cols),
    }
}

/// Distributed block matmul. Every rank of the group calls this with the
/// same arguments structurally (SPMD); returns this rank's shard of Y.
///
/// Ready-queue schedule per rank:
///   1. post all mobile-operand blocks this rank must ship (isend);
///   2. compute terms off a ready queue: local-input terms fill the
///      pipeline while the fabric is polled (`try_recv`); each remote
///      term runs the moment its mobile block lands, and once local work
///      runs dry the rank blocks on *whichever* in-flight block arrives
///      first (`recv_any`) — no fixed receive order;
///   3. each partial sum is posted the moment its accumulator is
///      complete (not after the whole term loop), so downstream owners
///      start receiving while this rank still computes;
///   4. receive partial sums for output blocks owned here in arrival
///      order, then apply the adds in fixed (block, sender) order so the
///      final reduction is deterministic.
///
/// Note on determinism: like NCCL/MPI overlap schedules, the order in
/// which a site *accumulates its own terms* follows operand arrival, so
/// results can wobble within fp tolerance run to run when a rank computes
/// several remote terms; the partial-sum reduction itself is
/// order-fixed. `dist_matmul_blocking` remains fully deterministic.
pub fn dist_matmul(
    ctx: &mut Ctx,
    op: MatmulOp,
    x: &DistMat,
    w: &DistMat,
    y_grid: &BlockGrid,
    site: Site,
) -> Result<DistMat> {
    let me = ctx.rank;
    let seq = ctx.seq;
    ctx.seq += 1;
    let backend = ctx.backend;
    let prec = ctx.precision;
    let use_into = backend.supports_into();
    let comm = &mut *ctx.comm;
    let all_terms = terms(op, x, w, y_grid);

    // -- phase 1: ship mobile blocks I own to sites that need them --------
    ship_mobile_blocks(comm, me, seq, site, x, w, &all_terms, prec);

    // -- phases 2+3: ready-queue term loop --------------------------------
    let my_terms: Vec<&Term> = all_terms
        .iter()
        .filter(|t| term_site(site, x, w, t) == me)
        .collect();
    // terms outstanding per output block, for eager partial posting
    let mut remaining: BTreeMap<(usize, usize), usize> = BTreeMap::new();
    for t in &my_terms {
        *remaining.entry(t.y).or_insert(0) += 1;
    }
    let mut local_terms: Vec<&Term> = Vec::new();
    // mobile blocks still in flight: block key -> (src, dependent terms)
    let mut waiting: BTreeMap<(usize, usize), (usize, Vec<&Term>)> = BTreeMap::new();
    for &t in &my_terms {
        let mo = term_mobile_owner(site, x, w, t);
        if mo == me {
            local_terms.push(t);
        } else {
            waiting
                .entry(term_mobile_key(site, t))
                .or_insert_with(|| (mo, Vec::new()))
                .1
                .push(t);
        }
    }

    let mut received: BTreeMap<(usize, usize), Arc<Tensor>> = BTreeMap::new();
    let mut partials: BTreeMap<(usize, usize), Tensor> = BTreeMap::new();
    let mut mine: BTreeMap<(usize, usize), Tensor> = BTreeMap::new();
    let mut ready: VecDeque<&Term> = VecDeque::new();
    let mut next_local = 0usize;
    let mut done = 0usize;
    let total = my_terms.len();
    while done < total {
        // poll the fabric: take (at most) one mobile block that has
        // landed since the last term — a single lock acquisition
        if !waiting.is_empty() && ready.is_empty() {
            let polled: Vec<(usize, usize)> = waiting.keys().copied().collect();
            let keys: Vec<(usize, u64)> = polled
                .iter()
                .map(|k| (waiting[k].0, tag_ship(seq, k.0, k.1)))
                .collect();
            if let Some((idx, blk)) = comm.try_recv_any_payload(&keys) {
                let mkey = polled[idx];
                received.insert(mkey, blk.widen());
                let (_, ts) = waiting.remove(&mkey).unwrap();
                ready.extend(ts);
            }
        }
        let t: &Term = if let Some(t) = ready.pop_front() {
            t
        } else if next_local < local_terms.len() {
            // no remote operand has landed: overlap the wait with a
            // local-input term
            next_local += 1;
            local_terms[next_local - 1]
        } else {
            // local work exhausted: block on whichever in-flight mobile
            // block arrives first. This dry-wait is hook-aware — with a
            // progress engine installed, registered DP collectives keep
            // advancing while this rank waits for jigsaw traffic.
            let polled: Vec<(usize, usize)> = waiting.keys().copied().collect();
            let keys: Vec<(usize, u64)> = polled
                .iter()
                .map(|k| (waiting[k].0, tag_ship(seq, k.0, k.1)))
                .collect();
            let (idx, blk) = comm.recv_any_payload(&keys);
            let mkey = polled[idx];
            received.insert(mkey, blk.widen());
            let (_, ts) = waiting.remove(&mkey).unwrap();
            ready.extend(ts);
            ready.pop_front().unwrap()
        };
        compute_term(
            backend, op, site, me, x, w, &received, &mut partials, use_into, t,
        )?;
        done += 1;
        // eager partial posting: the accumulator may now be complete
        let r = remaining.get_mut(&t.y).unwrap();
        *r -= 1;
        if *r == 0 {
            let p = partials.remove(&t.y).unwrap();
            let owner = y_grid.owner_of(t.y.0, t.y.1);
            if owner == me {
                mine.insert(t.y, p);
            } else {
                send_partial(comm, owner, tag_partial(seq, t.y.0, t.y.1, me), p, prec);
            }
        }
    }
    // shipped activation blocks are dead after the compute phase; return
    // uniquely-owned buffers to the pool
    for (_, blk) in received {
        if let Ok(t) = Arc::try_unwrap(blk) {
            t.recycle();
        }
    }

    // -- phase 4: collect partials for my output blocks ------------------
    let mut y = DistMat::empty(0, 0, y_grid.clone());
    let (yr, yc) = out_global_dims(op, x, w);
    y.rows = yr;
    y.cols = yc;
    let (ybr, ybc) = y.block_dims();
    let mut pending: Vec<((usize, usize), usize)> = Vec::new();
    for yk in y_grid.blocks_of(me) {
        // which sites produce partials for this block?
        let mut senders: Vec<usize> = all_terms
            .iter()
            .filter(|t| t.y == yk)
            .map(|t| term_site(site, x, w, t))
            .collect();
        senders.sort_unstable();
        senders.dedup();
        let acc = mine
            .remove(&yk)
            .unwrap_or_else(|| Tensor::pooled_zeros(&[ybr, ybc]));
        y.blocks.insert(yk, acc);
        pending.extend(senders.into_iter().filter(|&s| s != me).map(|s| (yk, s)));
    }
    // receive in arrival order (overlapping senders' tails), but apply
    // the adds in (block, sender) order so the reduction itself stays
    // deterministic run to run — the adds are noise next to the matmuls.
    // (These recv_any waits are hook-aware too: the tail of a backward
    // matmul chain keeps driving in-flight DP rings.)
    let mut arrived: BTreeMap<((usize, usize), usize), Payload> = BTreeMap::new();
    while arrived.len() < pending.len() {
        let outstanding: Vec<((usize, usize), usize)> = pending
            .iter()
            .filter(|k| !arrived.contains_key(k))
            .copied()
            .collect();
        let keys: Vec<(usize, u64)> = outstanding
            .iter()
            .map(|&(yk, s)| (s, tag_partial(seq, yk.0, yk.1, s)))
            .collect();
        let (idx, p) = comm.recv_any_payload(&keys);
        arrived.insert(outstanding[idx], p);
    }
    for ((yk, _s), p) in arrived {
        // partial sums were moved into the fabric, so the buffer is
        // uniquely owned; the drained copy goes back to the pool.
        // accumulation is f32 at either fabric precision.
        crate::comm::payload_add_into(&mut y.blocks.get_mut(&yk).unwrap().data, p);
    }
    Ok(y)
}

/// Reference fixed-order schedule (the pre-ready-queue pipeline): local
/// terms first, then each shipped operand awaited in term order
/// (`recv_shared`), every partial sum posted only after the whole term
/// loop, and incoming partials reduced in sender order. Numerically a
/// second oracle for `dist_matmul`; wall-clock the overlap benches'
/// baseline.
pub fn dist_matmul_blocking(
    ctx: &mut Ctx,
    op: MatmulOp,
    x: &DistMat,
    w: &DistMat,
    y_grid: &BlockGrid,
    site: Site,
) -> Result<DistMat> {
    let me = ctx.rank;
    let seq = ctx.seq;
    ctx.seq += 1;
    let backend = ctx.backend;
    let prec = ctx.precision;
    let use_into = backend.supports_into();
    let comm = &mut *ctx.comm;
    let all_terms = terms(op, x, w, y_grid);

    ship_mobile_blocks(comm, me, seq, site, x, w, &all_terms, prec);

    let my_terms: Vec<&Term> = all_terms
        .iter()
        .filter(|t| term_site(site, x, w, t) == me)
        .collect();
    let mut received: BTreeMap<(usize, usize), Arc<Tensor>> = BTreeMap::new();
    let mut partials: BTreeMap<(usize, usize), Tensor> = BTreeMap::new();
    let mut ordered: Vec<&Term> = my_terms
        .iter()
        .filter(|t| term_mobile_owner(site, x, w, t) == me)
        .copied()
        .collect();
    ordered.extend(
        my_terms
            .iter()
            .filter(|t| term_mobile_owner(site, x, w, t) != me)
            .copied(),
    );
    for t in ordered {
        let mkey = term_mobile_key(site, t);
        if term_mobile_owner(site, x, w, t) != me && !received.contains_key(&mkey) {
            let src = term_mobile_owner(site, x, w, t);
            let (_, blk) =
                comm.recv_any_payload(&[(src, tag_ship(seq, mkey.0, mkey.1))]);
            received.insert(mkey, blk.widen());
        }
        compute_term(
            backend, op, site, me, x, w, &received, &mut partials, use_into, t,
        )?;
    }
    for (_, blk) in received {
        if let Ok(t) = Arc::try_unwrap(blk) {
            t.recycle();
        }
    }

    // post partial sums owned elsewhere, all at once
    let mut mine: BTreeMap<(usize, usize), Tensor> = BTreeMap::new();
    for (yk, p) in partials {
        let owner = y_grid.owner_of(yk.0, yk.1);
        if owner == me {
            mine.insert(yk, p);
        } else {
            send_partial(comm, owner, tag_partial(seq, yk.0, yk.1, me), p, prec);
        }
    }

    // reduce partials for my output blocks in fixed sender order
    let mut y = DistMat::empty(0, 0, y_grid.clone());
    let (yr, yc) = out_global_dims(op, x, w);
    y.rows = yr;
    y.cols = yc;
    let (ybr, ybc) = y.block_dims();
    for yk in y_grid.blocks_of(me) {
        let mut senders: Vec<usize> = all_terms
            .iter()
            .filter(|t| t.y == yk)
            .map(|t| term_site(site, x, w, t))
            .collect();
        senders.sort_unstable();
        senders.dedup();
        let mut acc = mine
            .remove(&yk)
            .unwrap_or_else(|| Tensor::pooled_zeros(&[ybr, ybc]));
        for s in senders.into_iter().filter(|&s| s != me) {
            let (_, p) = ctx
                .comm
                .recv_any_payload(&[(s, tag_partial(seq, yk.0, yk.1, s))]);
            crate::comm::payload_add_into(&mut acc.data, p);
        }
        y.blocks.insert(yk, acc);
    }
    Ok(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{FabricSpec, Network};
    use crate::runtime::native::NativeBackend;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::Rng;
    use std::thread;
    use std::time::Duration;

    fn rand_t(rng: &mut Rng, r: usize, c: usize) -> Tensor {
        let mut d = vec![0.0; r * c];
        rng.fill_normal(&mut d, 1.0);
        Tensor::new(vec![r, c], d)
    }

    /// Run a dist matmul schedule across `n` rank threads on `net` and
    /// reassemble the output.
    #[allow(clippy::too_many_arguments)]
    fn run_dist_on(
        net: &Network,
        n: usize,
        op: MatmulOp,
        xg: BlockGrid,
        wg: BlockGrid,
        yg: BlockGrid,
        x: &Tensor,
        w: &Tensor,
        site: Site,
        blocking: bool,
    ) -> Tensor {
        let mesh = Mesh::flat(n).unwrap();
        let mut handles = Vec::new();
        for r in 0..n {
            let mut comm = net.endpoint(r);
            let (xg, wg, yg) = (xg.clone(), wg.clone(), yg.clone());
            let (x, w) = (x.clone(), w.clone());
            handles.push(thread::spawn(move || {
                let backend = NativeBackend;
                let mut ctx = Ctx::new(mesh, r, &mut comm, &backend);
                let xd = DistMat::from_global(&x, xg, r);
                let wd = DistMat::from_global(&w, wg, r);
                if blocking {
                    dist_matmul_blocking(&mut ctx, op, &xd, &wd, &yg, site).unwrap()
                } else {
                    dist_matmul(&mut ctx, op, &xd, &wd, &yg, site).unwrap()
                }
            }));
        }
        let parts: Vec<DistMat> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let refs: Vec<&DistMat> = parts.iter().collect();
        DistMat::assemble(&refs)
    }

    #[allow(clippy::too_many_arguments)]
    fn run_dist(
        n: usize,
        op: MatmulOp,
        xg: BlockGrid,
        wg: BlockGrid,
        yg: BlockGrid,
        x: &Tensor,
        w: &Tensor,
        site: Site,
    ) -> Tensor {
        let net = Network::new(n);
        run_dist_on(&net, n, op, xg, wg, yg, x, w, site, false)
    }

    #[test]
    fn two_way_nt_matches_serial() {
        // the paper's Eq (1)-(2): channel-sharded activations, weight
        // in-feature shards, partial-sum exchange.
        let mut rng = Rng::seed_from(1);
        let x = rand_t(&mut rng, 6, 8);
        let w = rand_t(&mut rng, 10, 8);
        let xg = BlockGrid::new(vec![vec![0, 1]]);
        let wg = BlockGrid::new(vec![vec![0, 1], vec![0, 1]]);
        let yg = BlockGrid::new(vec![vec![0, 1]]);
        let got = run_dist(2, MatmulOp::NT, xg, wg, yg, &x, &w, Site::WOwner);
        let want = ops::matmul_nt(&x, &w);
        assert!(got.max_abs_diff(&want) < 1e-4, "err {}", got.max_abs_diff(&want));
    }

    #[test]
    fn four_way_nt_matches_serial() {
        // the paper's Eq (3)-(4): 2x2 data & weight grids.
        let mut rng = Rng::seed_from(2);
        let x = rand_t(&mut rng, 8, 12);
        let w = rand_t(&mut rng, 6, 12);
        let xg = BlockGrid::new(vec![vec![0, 1], vec![2, 3]]);
        let wg = BlockGrid::new(vec![vec![0, 1], vec![2, 3]]);
        let yg = BlockGrid::new(vec![vec![0, 1], vec![2, 3]]);
        let got = run_dist(4, MatmulOp::NT, xg, wg, yg, &x, &w, Site::WOwner);
        let want = ops::matmul_nt(&x, &w);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn nn_with_stationary_left_operand() {
        // transposed-MLP form: weights are the left operand (token mixing)
        let mut rng = Rng::seed_from(3);
        let w1 = rand_t(&mut rng, 6, 4); // [d_tok, T]
        let u = rand_t(&mut rng, 4, 10); // [T, d]
        let xg = BlockGrid::new(vec![vec![0], vec![1]]); // d_tok row shards
        let wg = BlockGrid::new(vec![vec![0, 1]]); // d col shards
        let yg = BlockGrid::new(vec![vec![0, 0], vec![1, 1]]); // rank i holds row i
        let got = run_dist(2, MatmulOp::NN, xg, wg, yg, &w1, &u, Site::XOwner);
        assert!(got.max_abs_diff(&ops::matmul_nn(&w1, &u)) < 1e-4);
    }

    #[test]
    fn comm_volume_two_way_is_one_partial_per_output_block() {
        // Eq (2): the only traffic is the bold partial sums.
        let net = Network::new(2);
        let x = Tensor::zeros(&[4, 8]);
        let w = Tensor::zeros(&[6, 8]);
        let xg = BlockGrid::new(vec![vec![0, 1]]);
        let wg = BlockGrid::new(vec![vec![0, 1], vec![0, 1]]);
        let yg = BlockGrid::new(vec![vec![0, 1]]);
        let mut handles = Vec::new();
        for r in 0..2 {
            let mut comm = net.endpoint(r);
            let (xg, wg, yg) = (xg.clone(), wg.clone(), yg.clone());
            let (x, w) = (x.clone(), w.clone());
            handles.push(thread::spawn(move || {
                let backend = NativeBackend;
                let mut ctx = Ctx::new(Mesh::flat(2).unwrap(), r, &mut comm, &backend);
                let xd = DistMat::from_global(&x, xg, r);
                let wd = DistMat::from_global(&w, wg, r);
                dist_matmul(&mut ctx, MatmulOp::NT, &xd, &wd, &yg, Site::WOwner).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // each rank ships exactly one [4, 3] f32 partial = 48 bytes
        assert_eq!(net.link_bytes(0, 1), 48);
        assert_eq!(net.link_bytes(1, 0), 48);
    }

    #[test]
    fn property_random_grids_match_serial() {
        check("dist_matmul == serial for random grids", 40, |g: &mut Gen| {
            let rb = g.int(1, 3);
            let cb = g.int(1, 3);
            let kb = g.int(1, 3);
            let n = g.int(1, 4);
            let (br, bc, bk) = (g.int(1, 4), g.int(1, 4), g.int(1, 4));
            let (m, nn, kk) = (rb * br, cb * bc, kb * bk);
            let mut mk_grid = |r: usize, c: usize| -> BlockGrid {
                BlockGrid::new(
                    (0..r)
                        .map(|_| (0..c).map(|_| g.int(0, n - 1)).collect())
                        .collect(),
                )
            };
            let xg = mk_grid(rb, kb);
            let wg = mk_grid(cb, kb);
            let yg = mk_grid(rb, cb);
            let xd = g.f32s(m * kk);
            let wd = g.f32s(nn * kk);
            let x = Tensor::new(vec![m, kk], xd);
            let w = Tensor::new(vec![nn, kk], wd);
            let site = if g.bool() { Site::XOwner } else { Site::WOwner };
            let got = run_dist(n, MatmulOp::NT, xg, wg, yg, &x, &w, site);
            let want = ops::matmul_nt(&x, &w);
            let err = got.max_abs_diff(&want);
            if err < 1e-3 {
                Ok(())
            } else {
                Err(format!("err {err}"))
            }
        });
    }

    #[test]
    fn property_ready_queue_matches_serial_under_delivery_delay() {
        // the satellite fault injector: seeded per-message delays scramble
        // arrival order; the ready-queue schedule (and the blocking
        // reference) must still reproduce the serial product.
        check("ready-queue == serial under delay", 12, |g: &mut Gen| {
            let rb = g.int(1, 2);
            let cb = g.int(1, 2);
            let kb = g.int(1, 3);
            let n = g.int(2, 4);
            let (br, bc, bk) = (g.int(1, 4), g.int(1, 4), g.int(1, 4));
            let (m, nn, kk) = (rb * br, cb * bc, kb * bk);
            let mut mk_grid = |r: usize, c: usize| -> BlockGrid {
                BlockGrid::new(
                    (0..r)
                        .map(|_| (0..c).map(|_| g.int(0, n - 1)).collect())
                        .collect(),
                )
            };
            let xg = mk_grid(rb, kb);
            let wg = mk_grid(cb, kb);
            let yg = mk_grid(rb, cb);
            let x = Tensor::new(vec![m, kk], g.f32s(m * kk));
            let w = Tensor::new(vec![nn, kk], g.f32s(nn * kk));
            let site = if g.bool() { Site::XOwner } else { Site::WOwner };
            let net = Network::new(n);
            net.set_fabric(
                FabricSpec {
                    latency: Duration::from_micros(30),
                    jitter: Duration::from_micros(400),
                    bytes_per_sec: 1e9,
                },
                g.seed,
            );
            let got = run_dist_on(
                &net,
                n,
                MatmulOp::NT,
                xg.clone(),
                wg.clone(),
                yg.clone(),
                &x,
                &w,
                site,
                false,
            );
            let want = ops::matmul_nt(&x, &w);
            let err = got.max_abs_diff(&want);
            if err >= 1e-3 {
                return Err(format!("ready-queue err {err}"));
            }
            let got_blocking =
                run_dist_on(&net, n, MatmulOp::NT, xg, wg, yg, &x, &w, site, true);
            let err = got_blocking.max_abs_diff(&want);
            if err >= 1e-3 {
                return Err(format!("blocking err {err}"));
            }
            Ok(())
        });
    }

    #[test]
    fn property_nn_tn_random_grids() {
        check("nn/tn dist == serial", 30, |g: &mut Gen| {
            let rb = g.int(1, 2);
            let cb = g.int(1, 2);
            let kb = g.int(1, 2);
            let n = g.int(1, 4);
            let (br, bc, bk) = (g.int(1, 4), g.int(1, 4), g.int(1, 4));
            let (m, nn, kk) = (rb * br, cb * bc, kb * bk);
            let op = *g.pick(&[MatmulOp::NN, MatmulOp::TN]);
            let mut mk_grid = |g: &mut Gen, r: usize, c: usize| -> BlockGrid {
                BlockGrid::new(
                    (0..r)
                        .map(|_| (0..c).map(|_| g.int(0, n - 1)).collect())
                        .collect(),
                )
            };
            let (xg, wg, yg, x, w) = match op {
                MatmulOp::NN => {
                    let xg = mk_grid(g, rb, kb);
                    let wg = mk_grid(g, kb, cb);
                    let yg = mk_grid(g, rb, cb);
                    let x = Tensor::new(vec![m, kk], g.f32s(m * kk));
                    let w = Tensor::new(vec![kk, nn], g.f32s(kk * nn));
                    (xg, wg, yg, x, w)
                }
                _ => {
                    let xg = mk_grid(g, kb, rb);
                    let wg = mk_grid(g, kb, cb);
                    let yg = mk_grid(g, rb, cb);
                    let x = Tensor::new(vec![kk, m], g.f32s(kk * m));
                    let w = Tensor::new(vec![kk, nn], g.f32s(kk * nn));
                    (xg, wg, yg, x, w)
                }
            };
            let site = if g.bool() { Site::XOwner } else { Site::WOwner };
            let got = run_dist(n, op, xg, wg, yg, &x, &w, site);
            let want = match op {
                MatmulOp::NN => ops::matmul_nn(&x, &w),
                _ => ops::matmul_tn(&x, &w),
            };
            let err = got.max_abs_diff(&want);
            if err < 1e-3 {
                Ok(())
            } else {
                Err(format!("op {op:?} err {err}"))
            }
        });
    }

    #[test]
    fn blocking_schedule_matches_ready_queue() {
        check("blocking == ready-queue", 20, |g: &mut Gen| {
            let rb = g.int(1, 3);
            let cb = g.int(1, 3);
            let kb = g.int(1, 3);
            let n = g.int(1, 4);
            let (br, bc, bk) = (g.int(1, 3), g.int(1, 3), g.int(1, 3));
            let (m, nn, kk) = (rb * br, cb * bc, kb * bk);
            let mut mk_grid = |r: usize, c: usize| -> BlockGrid {
                BlockGrid::new(
                    (0..r)
                        .map(|_| (0..c).map(|_| g.int(0, n - 1)).collect())
                        .collect(),
                )
            };
            let xg = mk_grid(rb, kb);
            let wg = mk_grid(cb, kb);
            let yg = mk_grid(rb, cb);
            let x = Tensor::new(vec![m, kk], g.f32s(m * kk));
            let w = Tensor::new(vec![nn, kk], g.f32s(nn * kk));
            let site = if g.bool() { Site::XOwner } else { Site::WOwner };
            let net = Network::new(n);
            let a = run_dist_on(
                &net,
                n,
                MatmulOp::NT,
                xg.clone(),
                wg.clone(),
                yg.clone(),
                &x,
                &w,
                site,
                false,
            );
            let b = run_dist_on(&net, n, MatmulOp::NT, xg, wg, yg, &x, &w, site, true);
            let err = a.max_abs_diff(&b);
            if err < 1e-4 {
                Ok(())
            } else {
                Err(format!("schedules diverge: {err}"))
            }
        });
    }

    #[test]
    fn from_global_assemble_roundtrip() {
        check("shard/assemble roundtrip", 30, |g: &mut Gen| {
            let rb = g.int(1, 4);
            let cb = g.int(1, 4);
            let n = g.int(1, 4);
            let (br, bc) = (g.int(1, 5), g.int(1, 5));
            let t = Tensor::new(vec![rb * br, cb * bc], g.f32s(rb * br * cb * bc));
            let grid = BlockGrid::new(
                (0..rb)
                    .map(|_| (0..cb).map(|_| g.int(0, n - 1)).collect())
                    .collect(),
            );
            let parts: Vec<DistMat> = (0..n)
                .map(|r| DistMat::from_global(&t, grid.clone(), r))
                .collect();
            let refs: Vec<&DistMat> = parts.iter().collect();
            if DistMat::assemble(&refs) == t {
                Ok(())
            } else {
                Err("roundtrip mismatch".into())
            }
        });
    }
}
