//! The WeatherMixer sharding plan: which rank grid each activation and
//! weight matrix lives on, for 1-, 2-, and 4-way jigsaw.
//!
//! Paper Section 4:
//!   * 2-way  — data & parameters split along the final (channel-like)
//!     dimension; weights additionally split along the second-to-last dim
//!     so the output keeps the input's partitioning (Eq. 1).
//!   * 4-way  — data split along the last two dims (spatial x channel);
//!     weights in a 2x2 grid (Eq. 3). Rank = 2*spatial_half + channel_half.
//!
//! Domain note: the paper splits the spatial dim along longitude; our
//! patchify orders tokens latitude-major, so the contiguous token split is
//! along *latitude*. The scheme is symmetric in which spatial axis is
//! halved; DESIGN.md §Hardware-Adaptation records the swap.

use super::BlockGrid;

/// A jigsaw group's parallel degree.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Way {
    One,
    Two,
    Four,
}

impl Way {
    pub fn n(&self) -> usize {
        match self {
            Way::One => 1,
            Way::Two => 2,
            Way::Four => 4,
        }
    }

    pub fn from_n(n: usize) -> Way {
        match n {
            1 => Way::One,
            2 => Way::Two,
            4 => Way::Four,
            _ => panic!("jigsaw supports 1/2/4-way (paper); got {n}"),
        }
    }

    /// How many shards the channel-like dims split into.
    pub fn ch_split(&self) -> usize {
        match self {
            Way::One => 1,
            Way::Two | Way::Four => 2,
        }
    }

    /// How many shards the token (spatial) dim splits into.
    pub fn tok_split(&self) -> usize {
        match self {
            Way::One | Way::Two => 1,
            Way::Four => 2,
        }
    }
}

/// Layout set for one jigsaw way. All grids name global ranks 0..way-1.
pub struct Layouts {
    pub way: Way,
}

impl Layouts {
    pub fn new(way: Way) -> Self {
        Layouts { way }
    }

    /// Activations [T, d]-shaped (z, u, v, mlp hidden h_ch, patches, y):
    /// token rows split tok_split-ways, channel cols ch_split-ways;
    /// owner(i, j) = tok_split_index * ch_split + channel_index.
    pub fn act(&self) -> BlockGrid {
        let (ts, cs) = (self.way.tok_split(), self.way.ch_split());
        BlockGrid::new(
            (0..ts)
                .map(|i| (0..cs).map(|j| i * cs + j).collect())
                .collect(),
        )
    }

    /// NT-form weights W[N, K] (encoder, channel MLPs, decoder): out-block
    /// rows j, in-block cols k; owner = j * ch_split_k... For 2-way the
    /// paper puts W[:, k] on rank k (all out-blocks); for 4-way W is the
    /// same 2x2 grid as the data (Eq. 3).
    pub fn weight_nt(&self) -> BlockGrid {
        match self.way {
            Way::One => BlockGrid::single(),
            // owner[j][k] = k : rank k holds W[:, in-block k]
            Way::Two => BlockGrid::new(vec![vec![0, 1], vec![0, 1]]),
            // owner[j][k] = 2j + k (paper's W grid)
            Way::Four => BlockGrid::new(vec![vec![0, 1], vec![2, 3]]),
        }
    }

    /// Token-mix W1 [d_tok, T]: out-block rows i (d_tok), in-block cols k
    /// (tokens). 2-way: rank i holds row-block i (tokens unsplit). 4-way:
    /// owner[i][k] = 2i + k.
    pub fn weight_tok1(&self) -> BlockGrid {
        match self.way {
            Way::One => BlockGrid::single(),
            Way::Two => BlockGrid::new(vec![vec![0], vec![1]]),
            Way::Four => BlockGrid::new(vec![vec![0, 1], vec![2, 3]]),
        }
    }

    /// Token-mix hidden h [d_tok, d]: d_tok rows split 2-ways from W1,
    /// channel cols follow the activation channel split. 2-way: rank i
    /// owns row-block i entirely (both channel blocks). 4-way: owner
    /// (i, j) = 2i + j.
    pub fn tok_hidden(&self) -> BlockGrid {
        match self.way {
            Way::One => BlockGrid::single(),
            Way::Two => BlockGrid::new(vec![vec![0, 0], vec![1, 1]]),
            Way::Four => BlockGrid::new(vec![vec![0, 1], vec![2, 3]]),
        }
    }

    /// Token-mix W2 [T, d_tok]: token rows i, d_tok cols k. 2-way: rank k
    /// holds col-block k. 4-way: owner[i][k] = 2i + k.
    pub fn weight_tok2(&self) -> BlockGrid {
        match self.way {
            Way::One => BlockGrid::single(),
            Way::Two => BlockGrid::new(vec![vec![0, 1]]),
            Way::Four => BlockGrid::new(vec![vec![0, 1], vec![2, 3]]),
        }
    }

    /// Grad sync groups for a parameter vector sharded along the
    /// activation *channel* axis (LN affine, channel-MLP biases, blend):
    /// in 4-way, ranks j and 2+j hold the same channel shard and must
    /// pairwise-reduce its gradient (paper Section 5, layer norms).
    /// Returns, per owning rank, the group it reduces with.
    pub fn ch_vec_sync_group(&self, rank: usize) -> Vec<usize> {
        match self.way {
            Way::One | Way::Two => vec![rank],
            Way::Four => {
                let j = rank % 2;
                vec![j, 2 + j]
            }
        }
    }

    /// Sync groups for a vector sharded along the token-mix hidden axis
    /// (tok_b1, [d_tok]) or the token axis (tok_b2, [T]): owners of row
    /// block i are ranks {2i, 2i+1} in 4-way.
    pub fn tok_vec_sync_group(&self, rank: usize) -> Vec<usize> {
        match self.way {
            Way::One => vec![rank],
            // tok_b1 is sharded per rank in 2-way (no sync); tok_b2 [T] is
            // replicated across both ranks (tokens unsplit) -> group {0,1}
            Way::Two => vec![rank],
            Way::Four => {
                let i = rank / 2;
                vec![2 * i, 2 * i + 1]
            }
        }
    }

    /// tok_b2 [T] in 2-way is replicated on both ranks (token dim is not
    /// split), so its grads always reduce over the whole group.
    pub fn tok_b2_sync_group(&self, rank: usize) -> Vec<usize> {
        match self.way {
            Way::One => vec![rank],
            Way::Two => vec![0, 1],
            Way::Four => {
                let i = rank / 2;
                vec![2 * i, 2 * i + 1]
            }
        }
    }

    /// Which channel-column block this rank owns (for slicing per-channel
    /// vectors like LN affine / biases / channel weights).
    pub fn ch_block_of(&self, rank: usize) -> usize {
        match self.way {
            Way::One => 0,
            Way::Two => rank,
            Way::Four => rank % 2,
        }
    }

    /// Which token-row block this rank owns.
    pub fn tok_block_of(&self, rank: usize) -> usize {
        match self.way {
            Way::One | Way::Two => 0,
            Way::Four => rank / 2,
        }
    }

    /// Which d_tok row block this rank owns (token-mix hidden axis).
    pub fn dtok_block_of(&self, rank: usize) -> usize {
        match self.way {
            Way::One => 0,
            Way::Two => rank,
            Way::Four => rank / 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn way_splits() {
        assert_eq!(Way::One.ch_split(), 1);
        assert_eq!(Way::Two.ch_split(), 2);
        assert_eq!(Way::Four.ch_split(), 2);
        assert_eq!(Way::Four.tok_split(), 2);
        assert_eq!(Way::from_n(4), Way::Four);
    }

    #[test]
    #[should_panic(expected = "jigsaw supports")]
    fn way_rejects_3() {
        Way::from_n(3);
    }

    #[test]
    fn act_grid_owners() {
        let l2 = Layouts::new(Way::Two);
        assert_eq!(l2.act().owner, vec![vec![0, 1]]);
        let l4 = Layouts::new(Way::Four);
        assert_eq!(l4.act().owner, vec![vec![0, 1], vec![2, 3]]);
    }

    #[test]
    fn weight_nt_two_way_is_column_sharded() {
        // paper Eq (1): rank k holds W[:, in-block k], both out blocks
        let g = Layouts::new(Way::Two).weight_nt();
        assert_eq!(g.blocks_of(0), vec![(0, 0), (1, 0)]);
        assert_eq!(g.blocks_of(1), vec![(0, 1), (1, 1)]);
    }

    #[test]
    fn four_way_ln_sync_pairs() {
        // paper Section 5: ranks 0 & 2 (and 1 & 3) share LN parameters
        let l = Layouts::new(Way::Four);
        assert_eq!(l.ch_vec_sync_group(0), vec![0, 2]);
        assert_eq!(l.ch_vec_sync_group(2), vec![0, 2]);
        assert_eq!(l.ch_vec_sync_group(1), vec![1, 3]);
        assert_eq!(l.ch_vec_sync_group(3), vec![1, 3]);
    }

    #[test]
    fn every_rank_owns_one_block_of_each_weight() {
        for way in [Way::Two, Way::Four] {
            let l = Layouts::new(way);
            let n = way.n();
            for g in [l.weight_nt(), l.weight_tok1(), l.weight_tok2(), l.act()] {
                let total: usize = (0..n).map(|r| g.blocks_of(r).len()).sum();
                assert_eq!(total, g.rb * g.cb, "all blocks owned");
                for r in 0..n {
                    assert!(
                        !g.blocks_of(r).is_empty() || g.rb * g.cb < n,
                        "rank {r} owns nothing in {way:?}"
                    );
                }
            }
        }
    }
}
