//! First-class parallelism API: the device **Mesh**, per-tensor
//! **ShardSpec**s, and the **Planner** that derives block-ownership grids
//! from them.
//!
//! The paper derives 2-way (Eq. 1-2) and 4-way (Eq. 3-4) jigsaw schemes by
//! hand and notes the construction extends to arbitrary degrees. This
//! module is that extension as an API: a mesh names two axes,
//!
//!   * `tok` — the token (spatial) axis; activations split their row
//!     (token) dimension across it, the data loader splits latitude;
//!   * `ch`  — the channel axis; activations and channel-like parameter
//!     dimensions split across it (the paper's 2-way axis).
//!
//! Rank layout is row-major: `rank = tok_coord * ch + ch_coord`, which
//! reproduces the paper's "rank = 2*spatial_half + channel_half" for the
//! 2x2 mesh. Legacy degrees map to meshes `1x1`, `1x2`, `2x2`; the same
//! planner formulas generalize to `2x4` (8-way), `4x4` (16-way) and any
//! `tok <= ch` grid — the planner-derived grids are bit-identical to the
//! seed's hand-enumerated `Layouts` tables for the paper's degrees (see
//! the golden tests below).
//!
//! A [`ShardSpec`] states which logical axis shards each matrix dimension
//! ([`LAxis`]); [`Planner::grid`] turns a spec into a [`BlockGrid`]
//! (block counts + owner map). Invalid shapes (a `4x2` mesh, an axis that
//! does not divide a model dimension) surface as typed [`MeshError`]s
//! instead of panics, so the CLI and the examples can report them
//! cleanly.

use std::fmt;

use super::BlockGrid;
use crate::config::ModelConfig;

/// Typed mesh/config validation error (replaces the seed's
/// `Way::from_n` panic and the scattered shape `assert!`s).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MeshError {
    /// an axis length of zero
    EmptyAxis,
    /// tok > ch: NT weights form a `ch x ch` block grid, so a mesh with
    /// more token shards than channel shards cannot keep zero weight
    /// redundancy (more ranks than weight blocks)
    TokExceedsCh { tok: usize, ch: usize },
    /// a parallel degree with no valid mesh factorization (n = 0)
    Degree(usize),
    /// a mesh axis does not divide a model dimension
    Indivisible { what: &'static str, dim: usize, split: usize },
    /// unparsable mesh spec string
    Parse(String),
    /// a ShardSpec axis combination with no planner rule
    UnsupportedSpec(String),
}

impl fmt::Display for MeshError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MeshError::EmptyAxis => write!(f, "mesh axes must be >= 1"),
            MeshError::TokExceedsCh { tok, ch } => write!(
                f,
                "mesh {tok}x{ch} invalid: tok ({tok}) must not exceed ch ({ch}) — \
                 NT weight grids are ch x ch, so tok > ch leaves ranks without blocks"
            ),
            MeshError::Degree(n) => write!(f, "no mesh factorization for degree {n}"),
            MeshError::Indivisible { what, dim, split } => write!(
                f,
                "mesh does not fit the model: {what} ({dim}) is not divisible by {split}"
            ),
            MeshError::Parse(s) => {
                write!(f, "cannot parse mesh '{s}' (want TOKxCH, e.g. 2x4)")
            }
            MeshError::UnsupportedSpec(s) => {
                write!(f, "no planner rule for shard spec {s}")
            }
        }
    }
}

impl std::error::Error for MeshError {}

/// The device grid of one jigsaw group: `tok * ch` ranks with named axes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Mesh {
    tok: usize,
    ch: usize,
}

impl Mesh {
    /// A validated `tok x ch` mesh.
    pub fn new(tok: usize, ch: usize) -> Result<Mesh, MeshError> {
        if tok == 0 || ch == 0 {
            return Err(MeshError::EmptyAxis);
        }
        if tok > ch {
            return Err(MeshError::TokExceedsCh { tok, ch });
        }
        Ok(Mesh { tok, ch })
    }

    /// The single-rank mesh (the 1-way layout).
    pub fn unit() -> Mesh {
        Mesh { tok: 1, ch: 1 }
    }

    /// A `1 x n` mesh: every rank on the channel axis. Always valid —
    /// the SPMD shape raw `dist_matmul` callers want for ad-hoc groups.
    pub fn flat(n: usize) -> Result<Mesh, MeshError> {
        Mesh::new(1, n)
    }

    /// Most-balanced mesh for a total degree: the largest `tok` with
    /// `tok * ch == n` and `tok <= ch`. Reproduces the paper's layouts
    /// for the published degrees (1 -> 1x1, 2 -> 1x2, 4 -> 2x2) and
    /// extends them (8 -> 2x4, 16 -> 4x4). Primes fall back to `1 x n`.
    pub fn from_degree(n: usize) -> Result<Mesh, MeshError> {
        let mut best = None;
        let mut t = 1;
        while t * t <= n {
            if n % t == 0 {
                best = Some(Mesh { tok: t, ch: n / t });
            }
            t += 1;
        }
        best.ok_or(MeshError::Degree(n))
    }

    /// Largest viable mesh strictly smaller than `below` ranks that both
    /// factors ([`from_degree`](Mesh::from_degree)) and divides `cfg`'s
    /// dimensions. This is the elastic-recovery shrink policy: after a
    /// rank dies on an `n`-rank mesh, training resumes on
    /// `shrink_for(cfg, n)`. `Err(Degree(0))` means no smaller mesh fits
    /// the model (already at 1x1).
    pub fn shrink_for(cfg: &ModelConfig, below: usize) -> Result<Mesh, MeshError> {
        for d in (1..below).rev() {
            if let Ok(m) = Mesh::from_degree(d) {
                if m.validate_config(cfg).is_ok() {
                    return Ok(m);
                }
            }
        }
        Err(MeshError::Degree(0))
    }

    /// Parse a `TOKxCH` spec like `2x4` (also accepts a bare degree).
    pub fn parse(s: &str) -> Result<Mesh, MeshError> {
        let err = || MeshError::Parse(s.to_string());
        if let Some((a, b)) = s.split_once(['x', 'X']) {
            let tok: usize = a.trim().parse().map_err(|_| err())?;
            let ch: usize = b.trim().parse().map_err(|_| err())?;
            Mesh::new(tok, ch)
        } else {
            let n: usize = s.trim().parse().map_err(|_| err())?;
            Mesh::from_degree(n)
        }
    }

    /// Token-axis length.
    pub fn tok(&self) -> usize {
        self.tok
    }

    /// Channel-axis length.
    pub fn ch(&self) -> usize {
        self.ch
    }

    /// Total ranks in the mesh.
    pub fn n(&self) -> usize {
        self.tok * self.ch
    }

    /// Flattened rank of a (tok, ch) coordinate (row-major).
    pub fn rank_of(&self, tok: usize, ch: usize) -> usize {
        debug_assert!(tok < self.tok && ch < self.ch);
        tok * self.ch + ch
    }

    /// (tok, ch) coordinate of a rank.
    pub fn coord_of(&self, rank: usize) -> (usize, usize) {
        debug_assert!(rank < self.n());
        (rank / self.ch, rank % self.ch)
    }

    /// All ranks of the mesh, in rank order — the model-parallel
    /// communication group.
    pub fn ranks(&self) -> Vec<usize> {
        (0..self.n()).collect()
    }

    /// The data-parallel peer group of `mp_rank`: with `dp` replicas of
    /// this mesh packed world-rank = dp_idx * n + mp_rank, the ranks
    /// holding the same parameter shard (the paper's `r % way` rule).
    pub fn dp_group(&self, dp: usize, mp_rank: usize) -> Vec<usize> {
        (0..dp).map(|g| g * self.n() + mp_rank).collect()
    }

    /// Check the mesh against a model architecture: every sharded
    /// dimension must divide evenly. Returns the first violation.
    pub fn validate_config(&self, cfg: &ModelConfig) -> Result<(), MeshError> {
        let (t, c) = (self.tok, self.ch);
        let div = |what: &'static str, dim: usize, split: usize| {
            if split > 1 && dim % split != 0 {
                Err(MeshError::Indivisible { what, dim, split })
            } else {
                Ok(())
            }
        };
        div("channels_padded", cfg.channels_padded, c)?;
        div("d_emb", cfg.d_emb, c)?;
        div("d_ch", cfg.d_ch, c)?;
        div("d_tok", cfg.d_tok, c)?;
        div("patch_dim", cfg.patch_dim, c)?;
        div("lat", cfg.lat, t)?;
        // token rows are latitude-major patches: the latitude band of a
        // token shard must hold whole patch rows
        div("lat patch-rows (lat/patch)", cfg.lat / cfg.patch.max(1), t)?;
        div("tokens", cfg.tokens, t)?;
        Ok(())
    }
}

impl fmt::Display for Mesh {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}", self.tok, self.ch)
    }
}

impl std::str::FromStr for Mesh {
    type Err = MeshError;

    fn from_str(s: &str) -> Result<Mesh, MeshError> {
        Mesh::parse(s)
    }
}

/// Logical sharding axis of one matrix dimension.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LAxis {
    /// unsharded
    Full,
    /// the spatial token dimension — splits `mesh.tok` ways
    Token,
    /// a channel-like dimension (d_emb, d_ch, patch_dim, out-features) —
    /// splits `mesh.ch` ways
    Channel,
    /// the token-mix hidden dimension — splits `mesh.ch` ways, assigned
    /// row-cyclically over the tok axis (the paper's 2-way/4-way W1 rule)
    DTok,
}

/// Which logical axes shard a matrix's rows and columns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    pub rows: LAxis,
    pub cols: LAxis,
}

impl ShardSpec {
    pub const fn new(rows: LAxis, cols: LAxis) -> ShardSpec {
        ShardSpec { rows, cols }
    }

    /// Activations [T, d] and everything act-shaped (z, u, v, h_ch,
    /// patches, y): token rows x channel cols.
    pub const ACT: ShardSpec = ShardSpec::new(LAxis::Token, LAxis::Channel);
    /// NT-form weights W[N, K] (encoder, channel MLPs, decoder):
    /// out-features x in-features, both channel-like.
    pub const WEIGHT_NT: ShardSpec = ShardSpec::new(LAxis::Channel, LAxis::Channel);
    /// Token-mix W1 [d_tok, T].
    pub const WEIGHT_TOK1: ShardSpec = ShardSpec::new(LAxis::DTok, LAxis::Token);
    /// Token-mix hidden h [d_tok, d].
    pub const TOK_HIDDEN: ShardSpec = ShardSpec::new(LAxis::DTok, LAxis::Channel);
    /// Token-mix W2 [T, d_tok].
    pub const WEIGHT_TOK2: ShardSpec = ShardSpec::new(LAxis::Token, LAxis::DTok);
}

/// Per-block cache key derived from a matrix-level base key (device
/// buffer identity for resident parameter blocks). Lives with the
/// planner because it is part of the block-ownership contract.
pub fn block_cache_key(
    base: crate::runtime::CacheKey,
    blk: (usize, usize),
) -> crate::runtime::CacheKey {
    let (id, version) = base;
    (
        id ^ (blk.0 as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (blk.1 as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F)
            ^ 1,
        version,
    )
}

/// Derives block grids, owner maps, vector slicing, and gradient
/// sync groups from (mesh, spec) pairs — the single source of the
/// sharding truth that `layouts.rs` used to hand-enumerate per way.
#[derive(Clone, Copy, Debug)]
pub struct Planner {
    mesh: Mesh,
}

impl Planner {
    pub fn new(mesh: Mesh) -> Planner {
        Planner { mesh }
    }

    pub fn mesh(&self) -> Mesh {
        self.mesh
    }

    /// Split count of a logical axis on this mesh.
    pub fn splits(&self, axis: LAxis) -> usize {
        match axis {
            LAxis::Full => 1,
            LAxis::Token => self.mesh.tok,
            LAxis::Channel | LAxis::DTok => self.mesh.ch,
        }
    }

    /// Block grid for a shard spec. The owner formulas reproduce the
    /// paper's hand-derived 2-way/4-way assignments and generalize:
    ///
    ///   * act (Token x Channel): owner(i, j) = rank(i, j)
    ///   * W_nt (Channel x Channel): owner(j, k) = rank(j mod tok, k) —
    ///     out-feature blocks cycle over the tok axis so every rank holds
    ///     some block (zero redundancy); exactly ch/tok each when tok
    ///     divides ch, otherwise within one block of even
    ///   * W1 (DTok x Token): owner(i, k) = i*tok + k — a bijection onto
    ///     the flattened mesh (each rank owns exactly one block)
    ///   * h (DTok x Channel): owner(i, j) = i*tok + (j mod tok) — rank r
    ///     owns d_tok row block r/tok, matching its W1 rows
    ///   * W2 (Token x DTok): owner(i, k) = rank(i, k)
    pub fn grid(&self, spec: ShardSpec) -> Result<BlockGrid, MeshError> {
        let (t, c) = (self.mesh.tok, self.mesh.ch);
        let owner: Vec<Vec<usize>> = match (spec.rows, spec.cols) {
            (LAxis::Full, LAxis::Full) => vec![vec![0]],
            (LAxis::Token, LAxis::Channel) => (0..t)
                .map(|i| (0..c).map(|j| self.mesh.rank_of(i, j)).collect())
                .collect(),
            (LAxis::Channel, LAxis::Channel) => (0..c)
                .map(|j| (0..c).map(|k| self.mesh.rank_of(j % t, k)).collect())
                .collect(),
            (LAxis::DTok, LAxis::Token) => {
                (0..c).map(|i| (0..t).map(|k| i * t + k).collect()).collect()
            }
            (LAxis::DTok, LAxis::Channel) => {
                (0..c).map(|i| (0..c).map(|j| i * t + (j % t)).collect()).collect()
            }
            (LAxis::Token, LAxis::DTok) => (0..t)
                .map(|i| (0..c).map(|k| self.mesh.rank_of(i, k)).collect())
                .collect(),
            _ => return Err(MeshError::UnsupportedSpec(format!("{spec:?}"))),
        };
        Ok(BlockGrid::new(owner))
    }

    // -- the model's tensor-class grids (specs are always supported) ------

    pub fn act(&self) -> BlockGrid {
        self.grid(ShardSpec::ACT).expect("act spec")
    }

    pub fn weight_nt(&self) -> BlockGrid {
        self.grid(ShardSpec::WEIGHT_NT).expect("weight_nt spec")
    }

    pub fn weight_tok1(&self) -> BlockGrid {
        self.grid(ShardSpec::WEIGHT_TOK1).expect("weight_tok1 spec")
    }

    pub fn tok_hidden(&self) -> BlockGrid {
        self.grid(ShardSpec::TOK_HIDDEN).expect("tok_hidden spec")
    }

    pub fn weight_tok2(&self) -> BlockGrid {
        self.grid(ShardSpec::WEIGHT_TOK2).expect("weight_tok2 spec")
    }

    /// Grid for a named weight matrix (the parameter-ABI mapping the
    /// sharder uses; previously inlined in `shard_params`).
    pub fn param_grid(&self, name: &str) -> BlockGrid {
        if name.ends_with("tok_w1") {
            self.weight_tok1()
        } else if name.ends_with("tok_w2") {
            self.weight_tok2()
        } else {
            self.weight_nt()
        }
    }

    // -- per-rank block coordinates ---------------------------------------

    /// Which channel-column block this rank owns (slicing per-channel
    /// vectors: LN affine, channel biases, blend gate).
    pub fn ch_block_of(&self, rank: usize) -> usize {
        rank % self.mesh.ch
    }

    /// Which token-row block this rank owns.
    pub fn tok_block_of(&self, rank: usize) -> usize {
        rank / self.mesh.ch
    }

    /// Which d_tok row block this rank owns (token-mix hidden axis).
    pub fn dtok_block_of(&self, rank: usize) -> usize {
        rank / self.mesh.tok
    }

    // -- gradient sync groups ---------------------------------------------

    /// Ranks holding this rank's channel-axis vector shard (LN affine,
    /// channel biases, blend): the tok-axis fiber through the mesh —
    /// the paper's Section-5 pairwise layer-norm reduce at 2x2.
    pub fn ch_vec_sync_group(&self, rank: usize) -> Vec<usize> {
        let j = self.ch_block_of(rank);
        (0..self.mesh.tok).map(|i| self.mesh.rank_of(i, j)).collect()
    }

    /// Ranks holding this rank's d_tok-axis vector shard (tok_b1):
    /// the `tok` consecutive ranks sharing d_tok block rank/tok.
    pub fn tok_vec_sync_group(&self, rank: usize) -> Vec<usize> {
        let i = self.dtok_block_of(rank);
        (0..self.mesh.tok).map(|k| i * self.mesh.tok + k).collect()
    }

    /// Ranks holding this rank's token-axis vector shard (tok_b2 [T]):
    /// the ch-axis fiber (token rows are replicated across channels).
    pub fn tok_b2_sync_group(&self, rank: usize) -> Vec<usize> {
        let i = self.tok_block_of(rank);
        (0..self.mesh.ch).map(|j| self.mesh.rank_of(i, j)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degree_factorizations_match_paper() {
        assert_eq!(Mesh::from_degree(1).unwrap(), Mesh::unit());
        assert_eq!(Mesh::from_degree(2).unwrap(), Mesh::new(1, 2).unwrap());
        assert_eq!(Mesh::from_degree(4).unwrap(), Mesh::new(2, 2).unwrap());
        assert_eq!(Mesh::from_degree(8).unwrap(), Mesh::new(2, 4).unwrap());
        assert_eq!(Mesh::from_degree(16).unwrap(), Mesh::new(4, 4).unwrap());
        assert_eq!(Mesh::from_degree(3).unwrap(), Mesh::new(1, 3).unwrap());
        assert_eq!(Mesh::from_degree(0), Err(MeshError::Degree(0)));
    }

    #[test]
    fn invalid_shapes_are_typed_errors() {
        assert_eq!(
            Mesh::new(4, 2),
            Err(MeshError::TokExceedsCh { tok: 4, ch: 2 })
        );
        assert_eq!(Mesh::new(0, 2), Err(MeshError::EmptyAxis));
        assert!(matches!(Mesh::parse("wat"), Err(MeshError::Parse(_))));
        assert_eq!(Mesh::parse("2x4").unwrap(), Mesh::new(2, 4).unwrap());
        assert_eq!(Mesh::parse("8").unwrap(), Mesh::new(2, 4).unwrap());
        assert_eq!(Mesh::parse("2X4").unwrap(), Mesh::new(2, 4).unwrap());
    }

    #[test]
    fn rank_coord_roundtrip() {
        let m = Mesh::new(2, 4).unwrap();
        assert_eq!(m.n(), 8);
        for r in 0..m.n() {
            let (i, j) = m.coord_of(r);
            assert_eq!(m.rank_of(i, j), r);
        }
        // the paper's 2x2 rule: rank = 2*spatial_half + channel_half
        let m4 = Mesh::from_degree(4).unwrap();
        assert_eq!(m4.rank_of(1, 0), 2);
        assert_eq!(m4.rank_of(1, 1), 3);
    }

    /// The seed's hand-written `Layouts` tables, verbatim — the golden
    /// reference the planner must reproduce bit-identically.
    fn legacy_tables(way: usize) -> [(&'static str, Vec<Vec<usize>>); 5] {
        match way {
            1 => [
                ("act", vec![vec![0]]),
                ("weight_nt", vec![vec![0]]),
                ("weight_tok1", vec![vec![0]]),
                ("tok_hidden", vec![vec![0]]),
                ("weight_tok2", vec![vec![0]]),
            ],
            2 => [
                ("act", vec![vec![0, 1]]),
                ("weight_nt", vec![vec![0, 1], vec![0, 1]]),
                ("weight_tok1", vec![vec![0], vec![1]]),
                ("tok_hidden", vec![vec![0, 0], vec![1, 1]]),
                ("weight_tok2", vec![vec![0, 1]]),
            ],
            4 => [
                ("act", vec![vec![0, 1], vec![2, 3]]),
                ("weight_nt", vec![vec![0, 1], vec![2, 3]]),
                ("weight_tok1", vec![vec![0, 1], vec![2, 3]]),
                ("tok_hidden", vec![vec![0, 1], vec![2, 3]]),
                ("weight_tok2", vec![vec![0, 1], vec![2, 3]]),
            ],
            _ => unreachable!(),
        }
    }

    #[test]
    fn golden_planner_grids_match_seed_layouts() {
        for way in [1usize, 2, 4] {
            let p = Planner::new(Mesh::from_degree(way).unwrap());
            for (name, want) in legacy_tables(way) {
                let got = match name {
                    "act" => p.act(),
                    "weight_nt" => p.weight_nt(),
                    "weight_tok1" => p.weight_tok1(),
                    "tok_hidden" => p.tok_hidden(),
                    _ => p.weight_tok2(),
                };
                assert_eq!(got.owner, want, "{way}-way {name} drifted from the seed");
            }
        }
    }

    #[test]
    fn golden_sync_groups_match_seed_layouts() {
        // 2-way (seed `Layouts`): ch vectors private, tok_b2 replicated
        let p2 = Planner::new(Mesh::from_degree(2).unwrap());
        for r in 0..2 {
            assert_eq!(p2.ch_vec_sync_group(r), vec![r]);
            assert_eq!(p2.tok_vec_sync_group(r), vec![r]);
            assert_eq!(p2.tok_b2_sync_group(r), vec![0, 1]);
            assert_eq!(p2.ch_block_of(r), r);
            assert_eq!(p2.tok_block_of(r), 0);
            assert_eq!(p2.dtok_block_of(r), r);
        }
        // 4-way: the paper's Section-5 pairings
        let p4 = Planner::new(Mesh::from_degree(4).unwrap());
        for r in 0..4 {
            assert_eq!(p4.ch_vec_sync_group(r), vec![r % 2, 2 + r % 2]);
            let i = r / 2;
            assert_eq!(p4.tok_vec_sync_group(r), vec![2 * i, 2 * i + 1]);
            assert_eq!(p4.tok_b2_sync_group(r), vec![2 * i, 2 * i + 1]);
            assert_eq!(p4.ch_block_of(r), r % 2);
            assert_eq!(p4.tok_block_of(r), r / 2);
            assert_eq!(p4.dtok_block_of(r), r / 2);
        }
    }

    #[test]
    fn general_mesh_grids_cover_every_rank() {
        for (t, c) in [(1usize, 1usize), (1, 4), (2, 4), (4, 4), (2, 8)] {
            let m = Mesh::new(t, c).unwrap();
            let p = Planner::new(m);
            for (name, g) in [
                ("weight_nt", p.weight_nt()),
                ("weight_tok1", p.weight_tok1()),
                ("tok_hidden", p.tok_hidden()),
                ("weight_tok2", p.weight_tok2()),
                ("act", p.act()),
            ] {
                let mut counts = vec![0usize; m.n()];
                for row in &g.owner {
                    for &o in row {
                        assert!(o < m.n(), "{t}x{c} {name} owner {o} out of range");
                        counts[o] += 1;
                    }
                }
                assert!(
                    counts.iter().all(|&k| k > 0),
                    "{t}x{c} {name} leaves ranks idle: {counts:?}"
                );
                // perfect balance whenever tok divides ch
                if c % t == 0 {
                    assert_eq!(
                        counts.iter().max(),
                        counts.iter().min(),
                        "{t}x{c} {name} unbalanced"
                    );
                }
            }
            // W1 is a bijection: exactly one block per rank
            let w1 = p.weight_tok1();
            let mut owners: Vec<usize> =
                w1.owner.iter().flatten().copied().collect();
            owners.sort_unstable();
            assert_eq!(owners, m.ranks(), "{t}x{c} weight_tok1 not bijective");
        }
    }

    #[test]
    fn sync_groups_partition_and_agree() {
        for (t, c) in [(2usize, 4usize), (4, 4), (2, 6)] {
            let m = Mesh::new(t, c).unwrap();
            let p = Planner::new(m);
            type GroupFn = fn(&Planner, usize) -> Vec<usize>;
            let fns: [(&str, GroupFn); 3] = [
                ("ch_vec", Planner::ch_vec_sync_group),
                ("tok_vec", Planner::tok_vec_sync_group),
                ("tok_b2", Planner::tok_b2_sync_group),
            ];
            for (name, f) in fns {
                for r in 0..m.n() {
                    let g = f(&p, r);
                    assert!(g.contains(&r), "{t}x{c} {name}: {r} not in own group");
                    for &s in &g {
                        assert_eq!(f(&p, s), g, "{t}x{c} {name}: group of {s} != {r}");
                    }
                }
            }
            // members of a sync group hold the same vector block
            for r in 0..m.n() {
                for &s in &p.ch_vec_sync_group(r) {
                    assert_eq!(p.ch_block_of(s), p.ch_block_of(r));
                }
                for &s in &p.tok_vec_sync_group(r) {
                    assert_eq!(p.dtok_block_of(s), p.dtok_block_of(r));
                }
                for &s in &p.tok_b2_sync_group(r) {
                    assert_eq!(p.tok_block_of(s), p.tok_block_of(r));
                }
            }
        }
    }

    #[test]
    fn tok_hidden_rows_match_w1_ownership() {
        // rank r's tok_hidden row block must equal its W1 row block
        // (dtok_block_of), or its row-bias adds would misalign
        for (t, c) in [(1usize, 2usize), (2, 2), (2, 4), (4, 4)] {
            let p = Planner::new(Mesh::new(t, c).unwrap());
            let th = p.tok_hidden();
            for r in 0..t * c {
                for (i, row) in th.owner.iter().enumerate() {
                    if row.contains(&r) {
                        assert_eq!(i, p.dtok_block_of(r), "{t}x{c} rank {r}");
                    }
                }
            }
        }
    }

    #[test]
    fn validate_config_reports_indivisible_dims() {
        let cfg = ModelConfig {
            name: "t".into(),
            lat: 8,
            lon: 16,
            channels: 6,
            channels_padded: 8,
            patch: 2,
            d_emb: 32,
            d_tok: 48,
            d_ch: 32,
            blocks: 2,
            tokens: 32,
            patch_dim: 32,
            param_count: 0,
            flops_forward: 0,
            channel_weights: vec![1.0; 6],
        };
        for (t, c) in [(1usize, 1usize), (1, 2), (2, 2), (2, 4), (4, 4)] {
            Mesh::new(t, c).unwrap().validate_config(&cfg).unwrap();
        }
        // ch = 3 does not divide channels_padded = 8
        let e = Mesh::new(1, 3).unwrap().validate_config(&cfg).unwrap_err();
        assert!(matches!(e, MeshError::Indivisible { split: 3, .. }), "{e}");
        // tok = 4 works on lat 8 / patch 2 (4 patch rows)...
        Mesh::new(4, 4).unwrap().validate_config(&cfg).unwrap();
        // ...but a lat-16/patch-4 grid only has 4 patch rows: tok 8 fails
        let mut big = cfg.clone();
        big.lat = 16;
        big.patch = 4;
        big.channels_padded = 16;
        big.d_emb = 64;
        big.d_tok = 64;
        big.d_ch = 64;
        big.patch_dim = 256;
        assert!(Mesh::new(8, 8).unwrap().validate_config(&big).is_err());
    }

    #[test]
    fn shrink_for_picks_largest_smaller_viable_mesh() {
        let cfg = ModelConfig {
            name: "t".into(),
            lat: 8,
            lon: 16,
            channels: 6,
            channels_padded: 8,
            patch: 2,
            d_emb: 32,
            d_tok: 48,
            d_ch: 32,
            blocks: 2,
            tokens: 32,
            patch_dim: 32,
            param_count: 0,
            flops_forward: 0,
            channel_weights: vec![1.0; 6],
        };
        // losing a rank from 2x2 lands on 1x3? no — 3 doesn't divide
        // channels_padded 8 — so the next viable degree is 2 -> 1x2.
        let m = Mesh::shrink_for(&cfg, 4).unwrap();
        assert_eq!((m.tok(), m.ch()), (1, 2));
        let m = Mesh::shrink_for(&cfg, 8).unwrap();
        assert_eq!((m.tok(), m.ch()), (2, 2), "degree 7,6,5 don't fit; 4 does");
        // already at a single rank: nothing smaller exists
        assert!(Mesh::shrink_for(&cfg, 1).is_err());
    }
}
