//! `jigsaw` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   train          run distributed training on the synthetic atmosphere
//!   validate       check jigsaw n-way numerics against the AOT oracle
//!   simulate       drive the cluster performance model from a spec
//!   roofline       print the Fig-7 roofline series
//!   energy-report  print the Table-3 energy/CO2e accounting

use anyhow::Result;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    jigsaw::cli_main(&args)
}
