//! Cluster performance model: the HoreKa-testbed substitute.
//!
//! The paper's scaling evaluation (Figs 7-10, Table 3) ran on 256 NVIDIA
//! A100-40GB GPUs (4/node, NVLink intra-node, InfiniBand 4X HDR inter-
//! node). That hardware is simulated here by an analytic timing model with
//! the published peaks/bandwidths; the *comm volumes* mirror the real
//! jigsaw engine's schedule (and are cross-checked against the engine's
//! byte counters in rust/tests/).
//!
//! Step time decomposes into a prefetch-pipelined I/O stage and a compute
//! + communication stage (paper Section 6.3: epochs overlap CPU prefetch
//! with GPU work):
//!
//!     t_step = max(t_io, t_compute_path)
//!     t_compute_path = t_compute + (1 - alpha) * t_mp_comm + t_dp_exposed
//!
//! Domain parallelism divides t_io by the jigsaw way (each rank reads only
//! its partition) — the mechanism behind the paper's superscalar weak
//! scaling in I/O-bound regimes.

use crate::config::zoo::{ZooModel, PAPER_SAMPLE_BYTES};
use crate::jigsaw::Mesh;

/// Numeric precision regimes: the paper's two measured columns plus the
/// engine's bf16 storage-and-fabric mode (`--precision bf16`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// uniform single precision: 19.5 TFLOP/s peak on A100
    Fp32,
    /// TensorFloat-32 mixed precision: 156 TFLOP/s peak
    Tf32,
    /// bfloat16 tensor cores: 312 TFLOP/s peak on A100; unlike TF32 the
    /// *storage and fabric* are 16-bit too, so every shipped byte halves
    Bf16,
}

impl Precision {
    pub fn peak_flops(&self) -> f64 {
        match self {
            Precision::Fp32 => 19.5e12,
            Precision::Tf32 => 156e12,
            Precision::Bf16 => 312e12,
        }
    }

    /// Achievable GEMM fraction of peak. Together with the fixed per-step
    /// overhead this calibrates to the paper's measured non-MP baselines
    /// (Section 6.3.1: 81% fp32, 43% TF32 of peak at the 16-TFLOP model).
    /// bf16 sits near TF32's fraction: double the peak, the same
    /// memory-system limits on these layer shapes.
    pub fn gemm_efficiency(&self) -> f64 {
        match self {
            Precision::Fp32 => 0.83,
            Precision::Tf32 => 0.46,
            Precision::Bf16 => 0.42,
        }
    }

    /// Bytes per element the engine actually ships (activations, partial
    /// sums, gradient ring chunks) under this regime. TF32 is a compute
    /// format — its fabric traffic stays f32 — while bf16 stores and
    /// ships in 16 bits, which is exactly what the real engine's
    /// per-link byte counters report under `--precision bf16`.
    pub fn wire_bytes(&self) -> f64 {
        match self {
            Precision::Fp32 | Precision::Tf32 => 4.0,
            Precision::Bf16 => 2.0,
        }
    }
}

/// The HoreKa-like cluster description.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub gpus_per_node: usize,
    /// effective NVLink bandwidth for the 2-way pairwise exchange (bytes/s)
    pub mp_bw_2way: f64,
    /// effective NVLink bandwidth for the 4-way pattern — lower: two-hop
    /// data+partial routing, all-pairs contention, smaller messages
    pub mp_bw_4way: f64,
    /// InfiniBand effective per-node bandwidth (bytes/s; HoreKa nodes have
    /// two HDR adapters), shared by the node's GPUs during the DP allreduce
    pub ib_bw: f64,
    /// fabric contention growth per doubling of the node count (ring
    /// allreduces across more switches expose more synchronization)
    pub ib_contention_per_doubling: f64,
    /// storage read bandwidth per node; nodes run fully occupied, so each
    /// rank gets a 1/gpus_per_node share (domain parallelism divides the
    /// *bytes*, which is how jigsaw wins the I/O-bound regime)
    pub storage_bw_node: f64,
    /// fraction of MP communication hidden under compute: `overlap_2way`
    /// for channel-only meshes (tok = 1), `overlap_4way` once the token
    /// axis joins (two-hop data + partial routing)
    pub overlap_2way: f64,
    pub overlap_4way: f64,
    /// MP bandwidth degradation per doubling of the mesh beyond its
    /// calibrated anchor (2 ranks for channel-only, 4 for token x channel):
    /// larger meshes contend for the same NVLink fabric
    pub mp_contention_per_doubling: f64,
    /// fraction of the DP allreduce hidden under the backward pass
    pub dp_overlap: f64,
    /// fixed per-step overhead (launch, optimizer, host logic), seconds
    pub step_overhead: f64,
}

impl ClusterSpec {
    /// HoreKa per the paper's Section 6.1. Effective bandwidths and the
    /// step overhead are calibrated against the paper's measured anchors:
    /// 81%/43% non-MP peak fractions, the ~1 TFLOP fp32 roofline
    /// crossover, and the 1.9x/2.7x fp32 strong-scaling speedups
    /// (EXPERIMENTS.md §Calibration).
    pub fn horeka() -> Self {
        ClusterSpec {
            gpus_per_node: 4,
            mp_bw_2way: 60e9,
            mp_bw_4way: 8e9,
            ib_bw: 50e9,
            ib_contention_per_doubling: 1.5,
            storage_bw_node: 12e9,
            overlap_2way: 0.92,
            overlap_4way: 0.10,
            mp_contention_per_doubling: 0.6,
            dp_overlap: 0.9,
            step_overhead: 0.05,
        }
    }
}

/// One simulated workload: a Table-1 model trained at a given parallelism.
#[derive(Clone, Debug)]
pub struct Workload {
    pub model: ZooModel,
    /// jigsaw mesh of each model instance (legacy "way" = `mesh.n()`)
    pub mesh: Mesh,
    pub dp: usize,
    pub precision: Precision,
    /// include the storage->CPU->GPU data path (paper's "full training
    /// loop" vs "no data loading" modes)
    pub dataload: bool,
}

impl Workload {
    /// Model-parallel degree of the mesh.
    pub fn way(&self) -> usize {
        self.mesh.n()
    }
}

/// Paper-scale token count (0.25 deg grid, patch 12) used for activation
/// sizing in the comm model.
pub const PAPER_TOKENS: f64 = 7200.0;

/// Per-step timing breakdown (seconds).
#[derive(Clone, Debug, Default)]
pub struct StepTime {
    pub io: f64,
    pub compute: f64,
    pub mp_comm: f64,
    pub mp_comm_exposed: f64,
    pub dp_comm: f64,
    pub dp_comm_exposed: f64,
    pub total: f64,
}

/// Number of jigsaw-distributed linear layers in a WeatherMixer step
/// (paper architecture: 3 blocks x 4 MLP matmuls + encoder + decoder).
pub const N_LINEAR: f64 = 3.0 * 4.0 + 2.0;

pub fn simulate_step(cluster: &ClusterSpec, w: &Workload) -> StepTime {
    let way = w.way() as f64;
    let mut t = StepTime::default();

    // -- I/O: each jigsaw rank reads sample/way (x and y). Nodes run
    //    fully occupied, so a rank's storage share is bw/gpus_per_node;
    //    domain parallelism divides the byte volume by `way`. ------------
    if w.dataload {
        let bytes_per_rank = 2.0 * PAPER_SAMPLE_BYTES / way;
        let node_bw_per_rank =
            cluster.storage_bw_node / cluster.gpus_per_node as f64;
        t.io = bytes_per_rank / node_bw_per_rank;
    }

    // -- compute: fwd + 2x bwd FLOPs, 1/way per rank ----------------------
    let eff_peak = w.precision.peak_flops() * w.precision.gemm_efficiency();
    t.compute = w.model.flops_step() / way / eff_peak;

    // -- MP communication: per linear layer and pass, each rank ships
    //    activation-shard-sized messages over NVLink. The count follows
    //    the planner's schedule: a rank exchanges partial sums across the
    //    channel axis (ch - 1 shard messages; Eq. 2's single exchange at
    //    ch = 2) and, once the token axis joins, data blocks across the
    //    token axis as well (tok - 1 more; Eq. 4's data + partial at
    //    2x2). Token-axis meshes ride the lower-effective-bandwidth
    //    4-way path (two-hop routing + all-pairs contention), and meshes
    //    beyond the calibrated 2-/4-rank anchors pay a per-doubling
    //    fabric-contention premium on top. -------------------------------
    if w.way() > 1 {
        let prec_bytes = w.precision.wire_bytes(); // f32/TF32 ship 4B, bf16 ships 2B
        let act_bytes = PAPER_TOKENS * w.model.d_emb as f64 * prec_bytes;
        let channel_only = w.mesh.tok() == 1;
        let msgs_per_linear = ((w.mesh.tok() - 1) + (w.mesh.ch() - 1)) as f64;
        // forward + backward (dX and dW reuse one exchange each)
        let passes = 3.0;
        let bytes = passes * N_LINEAR * msgs_per_linear * act_bytes / way;
        let (bw, alpha, anchor) = if channel_only {
            (cluster.mp_bw_2way, cluster.overlap_2way, 2.0)
        } else {
            (cluster.mp_bw_4way, cluster.overlap_4way, 4.0)
        };
        let contention =
            1.0 + cluster.mp_contention_per_doubling * (way / anchor).max(1.0).log2();
        t.mp_comm = bytes * contention / bw;
        t.mp_comm_exposed = (1.0 - alpha) * t.mp_comm;
    }

    // -- DP allreduce: ring over IB between same-shard ranks; gradient
    //    volume is the *shard* size (the paper's Fig-10 insight: MP
    //    shrinks DP traffic by 1/way). The node's IB port is shared. ----
    if w.dp > 1 {
        let grad_bytes =
            w.model.param_bytes() / way * (w.precision.wire_bytes() / 4.0);
        let n = w.dp as f64;
        let ring = 2.0 * (n - 1.0) / n * grad_bytes;
        let ib_share = cluster.ib_bw / cluster.gpus_per_node as f64;
        t.dp_comm = ring / ib_share;
        // larger rings span more switches: exposure grows with node count
        let nodes = ((w.way() * w.dp) as f64 / cluster.gpus_per_node as f64).max(1.0);
        let contention = 1.0 + cluster.ib_contention_per_doubling * nodes.log2();
        t.dp_comm_exposed =
            t.dp_comm * (((1.0 - cluster.dp_overlap) * contention).min(1.2));
    }

    let compute_path =
        t.compute + t.mp_comm_exposed + t.dp_comm_exposed + cluster.step_overhead;
    t.total = t.io.max(compute_path);
    t
}

/// Schedule-level overlap accounting: what the ready-queue/bucketed
/// schedules buy over a fully blocking one.
///
/// The blocking baseline (fixed-order receives, partials posted after the
/// term loop, per-parameter DP collectives) exposes *every* comm second
/// on the critical path; the overlapped schedule exposes only the
/// residual fractions `simulate_step` models. The delta is what the
/// `hotpath_micro` overlap bench measures on the thread fabric.
#[derive(Clone, Debug)]
pub struct OverlapReport {
    /// MP comm seconds hidden under compute by the ready-queue schedule
    pub mp_hidden: f64,
    /// DP comm seconds hidden under the backward pass by the grad-ready
    /// bucket scheduler — bounded by `dp_backward_window`
    pub dp_hidden: f64,
    /// backward-pass compute seconds available to hide DP rings under:
    /// grad-ready scheduling can only launch a bucket's ring while later
    /// (earlier-layer) gradients are still differentiating, so the
    /// backward share of the step's FLOPs (2 of the fwd+2x-bwd 3) is the
    /// ceiling on hideable DP seconds
    pub dp_backward_window: f64,
    /// seconds of the DP reduce left on the critical path after the
    /// backward pass retires — the drain tail
    /// `trainer::GradReduceScheduler::finish` pays before Adam: the
    /// calibrated exposed fraction plus any nominally-hidden seconds the
    /// backward window could not actually cover. The progress engine
    /// exists to keep this term at its model floor (rings advance
    /// throughout backward); emission-point-only polling inflates it,
    /// which is what `hotpath_micro` §Progress measures on the thread
    /// fabric (BENCH_progress.json)
    pub dp_drain_tail: f64,
    /// step time if no comm overlapped compute
    pub blocking_total: f64,
    /// step time with the modeled overlap: `simulate_step`'s total plus
    /// any DP hiding the backward window cannot actually cover (equal to
    /// it whenever the window does not bind)
    pub overlapped_total: f64,
    pub predicted_speedup: f64,
}

/// Overlap-aware time accounting for one workload.
pub fn overlap_report(cluster: &ClusterSpec, w: &Workload) -> OverlapReport {
    let t = simulate_step(cluster, w);
    let mp_hidden = (t.mp_comm - t.mp_comm_exposed).max(0.0);
    // the DP rings ride under the backward pass only (2/3 of a
    // fwd + 2x-bwd step): hidden seconds beyond that window would claim
    // overlap with compute that has already retired
    let dp_backward_window = 2.0 / 3.0 * t.compute;
    // exposed DP time can exceed the raw transfer under contention; only
    // genuinely hidden seconds count
    let raw_hidden = (t.dp_comm - t.dp_comm_exposed).max(0.0);
    let dp_hidden = raw_hidden.min(dp_backward_window);
    // seconds the calibrated exposure model hides but the grad-ready
    // scheduler's backward window cannot cover: they surface back on
    // the overlapped critical path, so the report stays consistent
    // (blocking - overlapped <= mp_hidden + dp_hidden) even when the
    // window binds
    let window_excess = raw_hidden - dp_hidden;
    // everything of the DP reduce that surfaces after backward retires:
    // the calibrated exposure plus the window excess. Algebraically
    // max(dp_comm, dp_comm_exposed) - dp_hidden — the identity the
    // consistency test pins.
    let dp_drain_tail = t.dp_comm_exposed + window_excess;
    let blocking_path = t.compute
        + t.mp_comm
        + t.dp_comm.max(t.dp_comm_exposed)
        + cluster.step_overhead;
    let blocking_total = t.io.max(blocking_path);
    let overlapped_path =
        t.compute + t.mp_comm_exposed + dp_drain_tail + cluster.step_overhead;
    let overlapped_total = t.io.max(overlapped_path);
    OverlapReport {
        mp_hidden,
        dp_hidden,
        dp_backward_window,
        dp_drain_tail,
        blocking_total,
        overlapped_total,
        predicted_speedup: blocking_total / overlapped_total,
    }
}

/// Achieved FLOP/s per GPU for a workload.
pub fn flops_per_gpu(cluster: &ClusterSpec, w: &Workload) -> f64 {
    let t = simulate_step(cluster, w);
    w.model.flops_step() / w.way() as f64 / t.total
}

/// Fraction of theoretical peak.
pub fn peak_fraction(cluster: &ClusterSpec, w: &Workload) -> f64 {
    flops_per_gpu(cluster, w) / w.precision.peak_flops()
}

/// Strong-scaling speedup of a mesh vs the single rank for a fixed model.
pub fn strong_speedup(
    cluster: &ClusterSpec,
    model: ZooModel,
    mesh: &Mesh,
    precision: Precision,
    dataload: bool,
) -> f64 {
    let base = simulate_step(
        cluster,
        &Workload { model, mesh: Mesh::unit(), dp: 1, precision, dataload },
    );
    let par = simulate_step(
        cluster,
        &Workload { model, mesh: *mesh, dp: 1, precision, dataload },
    );
    base.total / par.total
}

/// Step-time sweep over a set of mesh shapes for one model — the
/// planning view behind the mesh benches (`BENCH_mesh.json`).
pub fn mesh_sweep(
    cluster: &ClusterSpec,
    model: ZooModel,
    precision: Precision,
    dataload: bool,
    meshes: &[Mesh],
) -> Vec<(Mesh, StepTime)> {
    meshes
        .iter()
        .map(|m| {
            let w = Workload { model, mesh: *m, dp: 1, precision, dataload };
            (*m, simulate_step(cluster, &w))
        })
        .collect()
}

/// Weak-scaling efficiency: per-GPU workload kept constant, model grown
/// `way`-fold (paper Section 6.3.3). `base` is the 1-way model;
/// `scaled` the model with way-times the FLOPs.
pub fn weak_efficiency(
    cluster: &ClusterSpec,
    base: ZooModel,
    scaled: ZooModel,
    mesh: &Mesh,
    precision: Precision,
    dataload: bool,
) -> f64 {
    let t1 = simulate_step(
        cluster,
        &Workload { model: base, mesh: Mesh::unit(), dp: 1, precision, dataload },
    );
    let tn = simulate_step(
        cluster,
        &Workload { model: scaled, mesh: *mesh, dp: 1, precision, dataload },
    );
    // efficiency = (useful work rate scaled) / (mesh.n() * base rate)
    (scaled.flops_step() / tn.total) / (mesh.n() as f64 * base.flops_step() / t1.total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo::TABLE1;

    fn horeka() -> ClusterSpec {
        ClusterSpec::horeka()
    }

    fn mesh(way: usize) -> Mesh {
        Mesh::from_degree(way).unwrap()
    }

    #[test]
    fn fp32_roofline_crossover_near_1_tflop() {
        // paper Fig 7 left: compute-bound regime starts ~1 TFLOP/fwd
        let c = horeka();
        let small = Workload {
            model: TABLE1[0], // 0.25 TFLOPs
            mesh: mesh(1),
            dp: 1,
            precision: Precision::Fp32,
            dataload: true,
        };
        let t_small = simulate_step(&c, &small);
        assert!(t_small.io > t_small.compute, "0.25TF model should be I/O-bound");
        let big = Workload { model: TABLE1[4], ..small.clone() }; // 4 TFLOPs
        let t_big = simulate_step(&c, &big);
        assert!(t_big.compute > t_big.io, "4TF model should be compute-bound");
    }

    #[test]
    fn one_way_baselines_match_paper() {
        // 81% fp32 and 43% tf32 of peak for large compute-bound models
        let c = horeka();
        let m = TABLE1[6]; // 16 TFLOPs
        let f32frac = peak_fraction(
            &c,
            &Workload { model: m, mesh: mesh(1), dp: 1, precision: Precision::Fp32, dataload: false },
        );
        assert!((f32frac - 0.81).abs() < 0.02, "fp32 frac {f32frac}");
    }

    #[test]
    fn strong_scaling_fp32_beats_megatron() {
        // paper 6.3.2: 1.4B model, no-dataload fp32: 1.9x / 2.7x
        let c = horeka();
        let m = TABLE1[6];
        let s2 = strong_speedup(&c, m, &mesh(2), Precision::Fp32, false);
        let s4 = strong_speedup(&c, m, &mesh(4), Precision::Fp32, false);
        assert!(s2 > 1.7 && s2 <= 2.0, "2-way speedup {s2}");
        assert!(s4 > 2.3 && s4 <= 4.0, "4-way speedup {s4}");
        assert!(s2 > 1.6 && s4 > 2.3, "must beat Megatron-LM (1.6 / 2.3)");
    }

    #[test]
    fn io_bound_regime_benefits_from_domain_parallelism() {
        // small model, full loop: jigsaw divides the I/O volume
        let c = horeka();
        let m = TABLE1[0];
        let t1 = simulate_step(
            &c,
            &Workload { model: m, mesh: mesh(1), dp: 1, precision: Precision::Tf32, dataload: true },
        );
        let t4 = simulate_step(
            &c,
            &Workload { model: m, mesh: mesh(4), dp: 1, precision: Precision::Tf32, dataload: true },
        );
        assert!(t4.total < t1.total / 2.0, "superscalar I/O win: {t1:?} {t4:?}");
    }

    #[test]
    fn overlap_report_is_consistent() {
        let c = horeka();
        for (way, dp) in [(1usize, 1usize), (2, 8), (4, 16)] {
            let w = Workload {
                model: TABLE1[6],
                mesh: mesh(way),
                dp,
                precision: Precision::Tf32,
                dataload: false,
            };
            let r = overlap_report(&c, &w);
            assert!(r.mp_hidden >= 0.0 && r.dp_hidden >= 0.0);
            assert!(
                r.predicted_speedup >= 1.0 - 1e-12,
                "overlap can only help: {r:?}"
            );
            // drain-tail identity: what surfaces after backward is the
            // full DP cost minus what the backward window truly hid
            let t = simulate_step(&c, &w);
            assert!(r.dp_drain_tail >= -1e-12, "negative drain tail: {r:?}");
            assert!(
                (r.dp_drain_tail
                    - (t.dp_comm.max(t.dp_comm_exposed) - r.dp_hidden))
                    .abs()
                    < 1e-9,
                "drain tail must account for every unhidden DP second: {r:?}"
            );
            // accounting identity: the overlapped step can only be
            // faster than blocking by the seconds actually hidden —
            // including when the backward window clamps DP hiding
            assert!(
                r.blocking_total - r.overlapped_total
                    <= r.mp_hidden + r.dp_hidden + 1e-9,
                "speedup must be covered by hidden seconds: {r:?}"
            );
            // the window excess only ever adds exposure on top of the
            // calibrated simulate_step total
            assert!(
                r.overlapped_total >= simulate_step(&c, &w).total - 1e-12,
                "window clamp cannot make the step faster: {r:?}"
            );
        }
        // at 2-way the model hides 92% of MP comm: the blocking schedule
        // must be measurably slower
        let w = Workload {
            model: TABLE1[6],
            mesh: mesh(2),
            dp: 1,
            precision: Precision::Tf32,
            dataload: false,
        };
        let r = overlap_report(&c, &w);
        assert!(r.predicted_speedup > 1.0, "2-way should hide MP comm: {r:?}");
        assert!(r.mp_hidden > 0.0);
    }

    #[test]
    fn mesh_sweep_prices_eight_and_sixteen_way() {
        // the regimes the hand-written layouts could never reach: the
        // model must price 2x4 and 4x4 meshes distinctly — compute keeps
        // shrinking with the degree while per-rank MP comm pays the
        // fabric-contention premium
        let c = horeka();
        let meshes: Vec<Mesh> = [1usize, 2, 4, 8, 16]
            .iter()
            .map(|&n| Mesh::from_degree(n).unwrap())
            .collect();
        let sweep = mesh_sweep(&c, TABLE1[8], Precision::Tf32, false, &meshes);
        assert_eq!(sweep.len(), 5);
        for w in sweep.windows(2) {
            assert!(
                w[1].1.compute < w[0].1.compute,
                "compute must shrink with the mesh: {:?}",
                (w[0].0, w[1].0)
            );
        }
        let t8 = &sweep[3].1;
        let t16 = &sweep[4].1;
        assert!(t8.mp_comm > 0.0 && t16.mp_comm > 0.0);
        // contention premium: 16-way per-rank comm is NOT half of 8-way
        assert!(t16.mp_comm > t8.mp_comm / 2.0, "{t8:?} vs {t16:?}");
        // ...and a 1x4 mesh prices differently from the 2x2 mesh of the
        // same degree: it ships MORE messages per linear (3 partial
        // shards vs data+partial = 2) but rides the fast pairwise
        // channel-exchange links (mp_bw_2way vs mp_bw_4way), which wins
        // while that bandwidth gap exceeds the 3:2 volume ratio
        let flat4 = Workload {
            model: TABLE1[8],
            mesh: Mesh::flat(4).unwrap(),
            dp: 1,
            precision: Precision::Tf32,
            dataload: false,
        };
        let square4 = Workload { mesh: mesh(4), ..flat4.clone() };
        let tf = simulate_step(&c, &flat4);
        let ts = simulate_step(&c, &square4);
        assert!(tf.mp_comm < ts.mp_comm, "channel-only mesh ships less: {tf:?} {ts:?}");
    }

    #[test]
    fn dp_traffic_shrinks_with_way() {
        let c = horeka();
        let m = TABLE1[6];
        let w1 = Workload { model: m, mesh: mesh(1), dp: 64, precision: Precision::Tf32, dataload: true };
        let w4 = Workload { model: m, mesh: mesh(4), dp: 16, precision: Precision::Tf32, dataload: true };
        let t1 = simulate_step(&c, &w1);
        let t4 = simulate_step(&c, &w4);
        assert!(t4.dp_comm < t1.dp_comm, "MP shards the gradient volume");
    }

    #[test]
    fn bf16_halves_fabric_bytes_and_prices_faster_steps() {
        // the --precision bf16 storage-and-fabric path: same schedule,
        // half the shipped bytes on both the NVLink MP exchanges and the
        // IB DP rings, and a higher effective GEMM roofline.
        let c = horeka();
        let m = TABLE1[6];
        let tf32 = Workload {
            model: m,
            mesh: mesh(4),
            dp: 16,
            precision: Precision::Tf32,
            dataload: false,
        };
        let bf16 = Workload { precision: Precision::Bf16, ..tf32.clone() };
        let t_tf = simulate_step(&c, &tf32);
        let t_bf = simulate_step(&c, &bf16);
        let mp_ratio = t_bf.mp_comm / t_tf.mp_comm;
        assert!((mp_ratio - 0.5).abs() < 1e-9, "MP bytes must halve: {mp_ratio}");
        let dp_ratio = t_bf.dp_comm / t_tf.dp_comm;
        assert!((dp_ratio - 0.5).abs() < 1e-9, "DP ring bytes must halve: {dp_ratio}");
        assert!(t_bf.compute < t_tf.compute, "bf16 roofline beats TF32");
        assert!(t_bf.total < t_tf.total, "bf16 step must price faster");
        // wire-bytes contract the engine's byte counters rely on
        assert_eq!(Precision::Fp32.wire_bytes(), 4.0);
        assert_eq!(Precision::Tf32.wire_bytes(), 4.0);
        assert_eq!(Precision::Bf16.wire_bytes(), 2.0);
    }

    #[test]
    fn weak_scaling_superscalar_when_io_bound() {
        // paper Fig 9 bottom right: the smallest (purely I/O-limited)
        // series is superscalar; in larger models 4-way computational /
        // communication costs start to dominate.
        let c = horeka();
        let eff_small =
            weak_efficiency(&c, TABLE1[0], TABLE1[2], &mesh(4), Precision::Tf32, true);
        assert!(eff_small > 1.0, "superscalar expected, got {eff_small}");
        let eff_2way =
            weak_efficiency(&c, TABLE1[2], TABLE1[3], &mesh(2), Precision::Tf32, true);
        assert!(eff_2way > 1.0, "2-way superscalar expected, got {eff_2way}");
        // the largest series is no longer superscalar (Fig 9: "in the
        // largest model communication overhead dominates")
        let eff_big =
            weak_efficiency(&c, TABLE1[6], TABLE1[8], &mesh(4), Precision::Tf32, true);
        assert!(eff_big < 1.0, "largest series must not superscale: {eff_big}");
    }
}
