//! Bench harness support: wall-clock timing, result persistence, and the
//! shared synthetic-training runs used by the paper-figure benches
//! (criterion is unavailable offline; benches are `harness = false`
//! binaries built on this module).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use crate::config::ModelConfig;
use crate::runtime::native::NativeBackend;
use crate::runtime::{Backend, MatmulOp};
use crate::tensor::Tensor;

/// Where bench CSVs land.
pub const RESULTS_DIR: &str = "bench_results";

pub fn csv_path(name: &str) -> String {
    format!("{RESULTS_DIR}/{name}.csv")
}

/// Print a bench banner.
pub fn banner(id: &str, what: &str) {
    println!("\n=== {id}: {what} ===");
}

/// Time a closure (seconds), best of `reps`.
pub fn time_best<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

/// Synthetic ModelConfig used by benches that don't need artifacts (the
/// native-backend training benches: Figs 3-6 analogues).
pub fn synth_config(name: &str, d_emb: usize, d_tok: usize, blocks: usize) -> ModelConfig {
    let (lat, lon, channels, patch) = (16usize, 32usize, 20usize, 4usize);
    let channels_padded = channels + (channels.wrapping_neg() & 3);
    let tokens = (lat / patch) * (lon / patch);
    let patch_dim = channels_padded * patch * patch;
    let weights = crate::config::zoo_channel_weights(channels);
    let mut cfg = ModelConfig {
        name: name.to_string(),
        lat,
        lon,
        channels,
        channels_padded,
        patch,
        d_emb,
        d_tok,
        d_ch: d_emb,
        blocks,
        tokens,
        patch_dim,
        param_count: 0,
        flops_forward: 0,
        channel_weights: weights,
    };
    cfg.param_count = cfg.derived_param_count();
    cfg
}

/// Fault-injection backend for the elastic-recovery tests and bench:
/// delegates to [`NativeBackend`] but fails exactly one matmul — the
/// `fail_at`-th call across all rank threads. Because the call counter
/// keeps monotonically increasing, retried runs against the *same*
/// instance sail past the trigger and complete, which is precisely the
/// "node died once, fleet recovered" shape `train_elastic` handles.
pub struct FlakyBackend {
    inner: NativeBackend,
    calls: AtomicUsize,
    fail_at: usize,
}

impl FlakyBackend {
    pub fn new(fail_at: usize) -> Self {
        FlakyBackend { inner: NativeBackend, calls: AtomicUsize::new(0), fail_at }
    }

    /// Total matmul calls observed so far (fired or not).
    pub fn calls(&self) -> usize {
        self.calls.load(Ordering::SeqCst)
    }
}

impl Backend for FlakyBackend {
    fn matmul(&self, op: MatmulOp, x: &Tensor, w: &Tensor) -> anyhow::Result<Tensor> {
        if self.calls.fetch_add(1, Ordering::SeqCst) == self.fail_at {
            anyhow::bail!("injected rank fault (flaky backend, call {})", self.fail_at);
        }
        self.inner.matmul(op, x, w)
    }

    fn name(&self) -> &'static str {
        "flaky"
    }
}

/// Seeded synthetic forecast-query traffic for `jigsaw serve` and the
/// serving bench: regional windows of random initial conditions at
/// skewed lead times (short leads dominate — users mostly ask about the
/// near future — which is what makes a small trajectory cache earn its
/// keep).
pub struct TrafficGen {
    rng: crate::util::rng::Rng,
    n_inits: u64,
    max_lead: usize,
    lat: usize,
    lon: usize,
}

impl TrafficGen {
    pub fn new(seed: u64, n_inits: u64, max_lead: usize, lat: usize, lon: usize) -> Self {
        assert!(n_inits >= 1, "traffic needs at least one init");
        assert!(lat >= 1 && lon >= 1, "traffic needs a non-empty grid");
        TrafficGen {
            rng: crate::util::rng::Rng::seed_from(seed ^ 0x7AFF_1C00),
            n_inits,
            max_lead,
            lat,
            lon,
        }
    }

    /// Next query. Lead is the min of two uniform draws over
    /// `[0, max_lead]` (triangular, short-skewed); the window is an
    /// arbitrary non-empty `[lat0, lat1) x [lon0, lon1)` box.
    pub fn next_query(&mut self) -> crate::serve::RegionQuery {
        let a = self.rng.below(self.max_lead + 1);
        let b = self.rng.below(self.max_lead + 1);
        let init_id = self.rng.below(self.n_inits as usize) as u64;
        let lat0 = self.rng.below(self.lat);
        let lat1 = lat0 + 1 + self.rng.below(self.lat - lat0);
        let lon0 = self.rng.below(self.lon);
        let lon1 = lon0 + 1 + self.rng.below(self.lon - lon0);
        crate::serve::RegionQuery {
            init_id,
            lead: a.min(b),
            lat: (lat0, lat1),
            lon: (lon0, lon1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traffic_gen_is_seeded_and_in_bounds() {
        let mut g = TrafficGen::new(7, 3, 8, 16, 32);
        let mut h = TrafficGen::new(7, 3, 8, 16, 32);
        let mut leads = [0usize; 9];
        for _ in 0..500 {
            let q = g.next_query();
            assert_eq!(q, h.next_query(), "same seed, same stream");
            assert!(q.init_id < 3);
            assert!(q.lead <= 8);
            assert!(q.lat.0 < q.lat.1 && q.lat.1 <= 16);
            assert!(q.lon.0 < q.lon.1 && q.lon.1 <= 32);
            leads[q.lead] += 1;
        }
        // min-of-two-uniforms skews short
        assert!(leads[0] > leads[8], "short leads must dominate: {leads:?}");
    }

    #[test]
    fn synth_config_consistent() {
        let c = synth_config("x", 64, 48, 2);
        assert_eq!(c.channels_padded % 4, 0);
        assert!(c.param_count > 0);
        assert_eq!(c.tokens, 32);
    }

    #[test]
    fn time_best_positive() {
        let t = time_best(2, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t >= 0.0);
    }
}
