//! Energy & carbon accounting (paper Section 6.3.5, Table 3).
//!
//! HoreKa's XClarity whole-node power sensors are replaced by a node power
//! model integrated over simulated runtime: the *methodology* (report kWh,
//! derive CO2e with PUE and grid carbon intensity) is the reproduced
//! artifact; absolute joules depend on the hardware substitute.

use crate::perfmodel::{simulate_step, ClusterSpec, Workload};

/// A100 SXM board power and the host share of a HoreKa node.
#[derive(Clone, Debug)]
pub struct PowerModel {
    pub gpu_max_w: f64,
    pub gpu_idle_w: f64,
    /// CPUs + RAM + NICs per node
    pub host_w: f64,
    /// power usage effectiveness of the data centre (paper: 1.05)
    pub pue: f64,
    /// grid carbon intensity, g CO2e per kWh (paper: 381, German mix)
    pub carbon_g_per_kwh: f64,
}

impl PowerModel {
    pub fn horeka() -> Self {
        PowerModel {
            gpu_max_w: 400.0,
            gpu_idle_w: 55.0,
            host_w: 550.0,
            pue: 1.05,
            carbon_g_per_kwh: 381.0,
        }
    }

    /// Node power draw at a given per-GPU utilization in [0, 1].
    pub fn node_power_w(&self, gpus: usize, util: f64) -> f64 {
        self.host_w
            + gpus as f64 * (self.gpu_idle_w + util * (self.gpu_max_w - self.gpu_idle_w))
    }
}

/// Energy report for one training experiment.
#[derive(Clone, Debug)]
pub struct EnergyReport {
    pub kwh: f64,
    pub co2e_kg: f64,
    pub gpu_hours: f64,
    pub wall_hours: f64,
}

/// Integrate the power model over a simulated training run.
///
/// `steps` optimizer steps at the workload's simulated step time; GPU
/// utilization is the compute fraction of the step (I/O-bound phases burn
/// idle-ish power — the effect behind Table 3's 4-way premium).
pub fn training_energy(
    cluster: &ClusterSpec,
    power: &PowerModel,
    w: &Workload,
    steps: usize,
) -> EnergyReport {
    let t = simulate_step(cluster, w);
    let gpus = w.way() * w.dp;
    let nodes = (gpus as f64 / cluster.gpus_per_node as f64).ceil();
    let gpus_per_node = (gpus as f64 / nodes).min(cluster.gpus_per_node as f64);
    let util = (t.compute / t.total).clamp(0.05, 1.0);
    let node_w = power.node_power_w(gpus_per_node.round() as usize, util);
    let wall_s = t.total * steps as f64;
    let joules = node_w * nodes * wall_s;
    let kwh = joules / 3.6e6;
    EnergyReport {
        kwh,
        co2e_kg: kwh * power.pue * power.carbon_g_per_kwh / 1000.0,
        gpu_hours: gpus as f64 * wall_s / 3600.0,
        wall_hours: wall_s / 3600.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo::TABLE1;
    use crate::perfmodel::Precision;

    #[test]
    fn node_power_ranges() {
        let p = PowerModel::horeka();
        let idle = p.node_power_w(4, 0.0);
        let full = p.node_power_w(4, 1.0);
        assert!((idle - (550.0 + 4.0 * 55.0)).abs() < 1e-9);
        assert!((full - (550.0 + 4.0 * 400.0)).abs() < 1e-9);
    }

    #[test]
    fn co2_follows_paper_formula() {
        // CO2e = E * PUE * e_C
        let c = ClusterSpec::horeka();
        let p = PowerModel::horeka();
        let w = Workload {
            model: TABLE1[6],
            mesh: crate::jigsaw::Mesh::from_degree(2).unwrap(),
            dp: 4,
            precision: Precision::Tf32,
            dataload: true,
        };
        let r = training_energy(&c, &p, &w, 1000);
        assert!((r.co2e_kg - r.kwh * 1.05 * 0.381).abs() < 1e-9);
        assert!(r.kwh > 0.0 && r.gpu_hours > 0.0);
    }

    #[test]
    fn four_way_burns_more_energy_under_equivalent_usage() {
        // paper Table 3 / Section 6.2.1: on a fixed 8-GPU budget and a
        // fixed dataset, the 4-way run (dp=2 -> 4x the optimizer steps
        // per epoch) takes the longest wall time and the most energy
        // (155 vs 104 min/epoch).
        let c = ClusterSpec::horeka();
        let p = PowerModel::horeka();
        let dataset = 8000usize;
        let mk = |way: usize, dp: usize| {
            training_energy(
                &c,
                &p,
                &Workload {
                    model: TABLE1[5], // ~1B params
                    mesh: crate::jigsaw::Mesh::from_degree(way).unwrap(),
                    dp,
                    precision: Precision::Tf32,
                    dataload: true,
                },
                dataset / dp,
            )
        };
        let e1 = mk(1, 8);
        let e4 = mk(4, 2);
        assert!(
            e4.wall_hours > e1.wall_hours,
            "4-way {} !> 1-way {}",
            e4.wall_hours,
            e1.wall_hours
        );
        assert!(e4.kwh > e1.kwh);
    }
}
