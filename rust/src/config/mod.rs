//! Configuration: the model config contract with the python compile path
//! (`artifacts/<preset>/config.json`), the Table-1 scaling-model zoo, and
//! the Table-2 parallel plan.

pub mod zoo;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// WeatherMixer architecture config — mirror of python configs.ModelConfig.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub lat: usize,
    pub lon: usize,
    pub channels: usize,
    pub channels_padded: usize,
    pub patch: usize,
    pub d_emb: usize,
    pub d_tok: usize,
    pub d_ch: usize,
    pub blocks: usize,
    pub tokens: usize,
    pub patch_dim: usize,
    pub param_count: usize,
    pub flops_forward: u64,
    pub channel_weights: Vec<f32>,
}

impl ModelConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let get = |k: &str| -> Result<&Json> {
            j.get(k).ok_or_else(|| anyhow!("config.json missing key '{k}'"))
        };
        let us = |k: &str| -> Result<usize> {
            get(k)?.as_usize().ok_or_else(|| anyhow!("'{k}' not a number"))
        };
        let weights = get("channel_weights")?
            .as_arr()
            .ok_or_else(|| anyhow!("channel_weights not an array"))?
            .iter()
            .map(|v| v.as_f64().unwrap_or(0.0) as f32)
            .collect();
        Ok(ModelConfig {
            name: get("name")?.as_str().unwrap_or("?").to_string(),
            lat: us("lat")?,
            lon: us("lon")?,
            channels: us("channels")?,
            channels_padded: us("channels_padded")?,
            patch: us("patch")?,
            d_emb: us("d_emb")?,
            d_tok: us("d_tok")?,
            d_ch: us("d_ch")?,
            blocks: us("blocks")?,
            tokens: us("tokens")?,
            patch_dim: us("patch_dim")?,
            param_count: us("param_count")?,
            flops_forward: us("flops_forward")? as u64,
            channel_weights: weights,
        })
    }

    pub fn load(artifacts: &Path, preset: &str) -> Result<Self> {
        let path = artifacts.join(preset).join("config.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        Self::from_json(&j)
    }

    /// Per-channel loss weights padded with zeros to channels_padded.
    pub fn padded_channel_weights(&self) -> Vec<f32> {
        let mut w = self.channel_weights.clone();
        w.truncate(self.channels);
        w.resize(self.channels_padded, 0.0);
        w
    }

    /// FNV-1a digest over every architecture field (including the
    /// channel-weight bits). Checkpoint manifests record it so a resume
    /// against a different model configuration is rejected up front
    /// instead of mis-assembling shards.
    pub fn content_hash(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        eat(self.name.as_bytes());
        for d in [
            self.lat,
            self.lon,
            self.channels,
            self.channels_padded,
            self.patch,
            self.d_emb,
            self.d_tok,
            self.d_ch,
            self.blocks,
            self.tokens,
            self.patch_dim,
        ] {
            eat(&(d as u64).to_le_bytes());
        }
        for &w in &self.channel_weights {
            eat(&w.to_bits().to_le_bytes());
        }
        h
    }

    /// sample size in bytes (f32) — the domain-parallel I/O unit.
    pub fn sample_bytes(&self) -> u64 {
        (self.lat * self.lon * self.channels_padded * 4) as u64
    }

    /// Parameter count implied by the architecture fields (mirrors
    /// python configs.ModelConfig.param_count): encoder + per-block
    /// LN/token/channel MLPs + decoder + blend gate. Synthetic configs
    /// (benchkit, zoo) derive `param_count` from this.
    pub fn derived_param_count(&self) -> usize {
        let (t, d) = (self.tokens, self.d_emb);
        let mut n = self.patch_dim * d + d;
        for _ in 0..self.blocks {
            n += 2 * d;
            n += t * self.d_tok + self.d_tok;
            n += self.d_tok * t + t;
            n += 2 * d;
            n += d * self.d_ch + self.d_ch;
            n += self.d_ch * d + d;
        }
        n += d * self.patch_dim + self.patch_dim;
        n += self.channels_padded;
        n
    }
}

/// Artifact manifest (program + primitive index, parameter ABI).
#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub dir: PathBuf,
    pub param_order: Vec<String>,
    pub param_shapes: Vec<(String, Vec<usize>)>,
    pub programs: Vec<(String, String)>,
    pub primitives: Vec<(String, String)>,
    pub adam_b1: f32,
    pub adam_b2: f32,
    pub adam_eps: f32,
    pub grad_clip: f32,
}

impl Manifest {
    pub fn load(artifacts: &Path, preset: &str) -> Result<Self> {
        let dir = artifacts.join(preset);
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parse manifest: {e}"))?;
        let order: Vec<String> = j
            .get("param_order")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest missing param_order"))?
            .iter()
            .filter_map(|v| v.as_str().map(|s| s.to_string()))
            .collect();
        let shapes_obj = j
            .get("param_shapes")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| anyhow!("manifest missing param_shapes"))?;
        let mut param_shapes = Vec::new();
        for name in &order {
            let shp = shapes_obj
                .get(name)
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("missing shape for {name}"))?
                .iter()
                .filter_map(|v| v.as_usize())
                .collect();
            param_shapes.push((name.clone(), shp));
        }
        let to_pairs = |key: &str| -> Vec<(String, String)> {
            j.get(key)
                .and_then(|v| v.as_obj())
                .map(|m| {
                    m.iter()
                        .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                        .collect()
                })
                .unwrap_or_default()
        };
        let adam = j.get("adam");
        let af = |k: &str, dflt: f32| -> f32 {
            adam.and_then(|a| a.get(k))
                .and_then(|v| v.as_f64())
                .map(|v| v as f32)
                .unwrap_or(dflt)
        };
        Ok(Manifest {
            preset: preset.to_string(),
            dir,
            param_order: order,
            param_shapes,
            programs: to_pairs("programs"),
            primitives: to_pairs("primitives"),
            adam_b1: af("b1", 0.9),
            adam_b2: af("b2", 0.999),
            adam_eps: af("eps", 1e-8),
            grad_clip: af("grad_clip", 1.0),
        })
    }

    pub fn program_path(&self, tag: &str) -> Option<PathBuf> {
        self.programs
            .iter()
            .find(|(k, _)| k == tag)
            .map(|(_, rel)| self.dir.join(rel))
    }

    pub fn primitive_path(&self, key: &str) -> Option<PathBuf> {
        self.primitives
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, rel)| self.dir.join(rel))
    }

    pub fn shape_of(&self, name: &str) -> Option<&[usize]> {
        self.param_shapes
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, s)| s.as_slice())
    }
}

/// First `n` entries of the paper's channel-weight table (Pangu surface/
/// pressure-level weights x the paper's level weighting) — the rust twin
/// of python `configs.channel_weights()` for artifact-free configs.
pub fn zoo_channel_weights(n: usize) -> Vec<f32> {
    let surface = [0.77f32, 0.66, 3.0, 1.5];
    let plev = [("z", 3.0f32), ("q", 0.6), ("t", 1.7), ("u", 0.87), ("v", 0.6)];
    let level_w = [1.0f32, 1.0, 1.0, 1.0, 1.0, 1.0, 0.9, 0.8, 0.7, 0.6, 0.5, 0.4, 0.3];
    let mut ws: Vec<f32> = surface.to_vec();
    for (_, w) in plev {
        for lw in level_w {
            ws.push(w * lw);
        }
    }
    ws.truncate(n.min(ws.len()));
    while ws.len() < n {
        ws.push(1.0);
    }
    ws
}

/// Locate the artifacts directory: $JIGSAW_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("JIGSAW_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_config_json() -> Json {
        Json::parse(
            r#"{
              "name": "t", "lat": 8, "lon": 16, "channels": 6,
              "channels_padded": 8, "patch": 2, "d_emb": 32, "d_tok": 48,
              "d_ch": 32, "blocks": 2, "tokens": 32, "patch_dim": 32,
              "param_count": 12904, "flops_forward": 1000000,
              "channel_weights": [1.0, 2.0]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_config() {
        let c = ModelConfig::from_json(&sample_config_json()).unwrap();
        assert_eq!(c.d_emb, 32);
        assert_eq!(c.tokens, 32);
        assert_eq!(c.sample_bytes(), 8 * 16 * 8 * 4);
    }

    #[test]
    fn padded_weights_zero_fill() {
        let mut c = ModelConfig::from_json(&sample_config_json()).unwrap();
        c.channels = 2;
        c.channels_padded = 4;
        assert_eq!(c.padded_channel_weights(), vec![1.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn missing_key_is_error() {
        let j = Json::parse(r#"{"name": "x"}"#).unwrap();
        assert!(ModelConfig::from_json(&j).is_err());
    }
}
