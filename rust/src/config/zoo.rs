//! The paper's Table-1 model zoo and Table-2 parallel plan.
//!
//! These drive the performance-model benches (Figs 7-10) at the paper's
//! scale — the architectures are the *paper's* (0.25-degree ERA5 grid,
//! d_emb up to 10 352), evaluated analytically; the runnable presets in
//! `artifacts/` are their scaled-down counterparts.

/// One row of paper Table 1.
#[derive(Clone, Copy, Debug)]
pub struct ZooModel {
    pub id: usize,
    /// TFLOPs per forward pass (the paper's workload unit).
    pub tflops_fwd: f64,
    /// Total parameters, millions (paper's reported column).
    pub params_mil: f64,
    pub d_emb: usize,
    pub d_tok: usize,
    pub d_ch: usize,
}

/// Paper Table 1, verbatim.
pub const TABLE1: [ZooModel; 9] = [
    ZooModel { id: 1, tflops_fwd: 0.25, params_mil: 60.0, d_emb: 240, d_tok: 540, d_ch: 240 },
    ZooModel { id: 2, tflops_fwd: 0.5, params_mil: 230.0, d_emb: 512, d_tok: 2160, d_ch: 512 },
    ZooModel { id: 3, tflops_fwd: 1.0, params_mil: 240.0, d_emb: 896, d_tok: 2160, d_ch: 896 },
    ZooModel { id: 4, tflops_fwd: 2.0, params_mil: 260.0, d_emb: 1600, d_tok: 2160, d_ch: 1600 },
    ZooModel { id: 5, tflops_fwd: 4.0, params_mil: 500.0, d_emb: 2192, d_tok: 4320, d_ch: 2192 },
    ZooModel { id: 6, tflops_fwd: 8.0, params_mil: 980.0, d_emb: 2832, d_tok: 8640, d_ch: 2832 },
    ZooModel { id: 7, tflops_fwd: 16.0, params_mil: 1400.0, d_emb: 4896, d_tok: 8640, d_ch: 4896 },
    ZooModel { id: 8, tflops_fwd: 32.0, params_mil: 2000.0, d_emb: 6064, d_tok: 17280, d_ch: 6064 },
    ZooModel { id: 9, tflops_fwd: 64.0, params_mil: 2600.0, d_emb: 10352, d_tok: 17280, d_ch: 10352 },
];

impl ZooModel {
    pub fn by_id(id: usize) -> ZooModel {
        TABLE1[id - 1]
    }

    /// FLOPs for one forward pass (absolute).
    pub fn flops_fwd(&self) -> f64 {
        self.tflops_fwd * 1e12
    }

    /// Paper Section 6.3: "the backward pass was considered to have two
    /// times the number of FLOPs as the forward pass".
    pub fn flops_step(&self) -> f64 {
        3.0 * self.flops_fwd()
    }

    pub fn param_bytes(&self) -> f64 {
        self.params_mil * 1e6 * 4.0
    }

    /// Natively-runnable counterpart of this Table-1 row: the synthetic
    /// 16x32 grid of the bench presets with this row's hidden dims
    /// divided by `scale` (rounded up to a multiple of 16, so every
    /// 2-/4-way sharding divides evenly). `scale=1` keeps the paper's
    /// dims; the e2e driver defaults to 8, which puts the mid-size rows
    /// within thread-fabric reach.
    pub fn native_config(&self, scale: usize) -> crate::config::ModelConfig {
        let scale = scale.max(1);
        let dim = |v: usize| v.div_ceil(scale).max(16).div_ceil(16) * 16;
        let (lat, lon, channels, patch) = (16usize, 32usize, 20usize, 4usize);
        let channels_padded = channels + (channels.wrapping_neg() & 3);
        let tokens = (lat / patch) * (lon / patch);
        let patch_dim = channels_padded * patch * patch;
        let mut cfg = crate::config::ModelConfig {
            name: format!("zoo{}-s{}", self.id, scale),
            lat,
            lon,
            channels,
            channels_padded,
            patch,
            d_emb: dim(self.d_emb),
            d_tok: dim(self.d_tok),
            d_ch: dim(self.d_ch),
            blocks: 3,
            tokens,
            patch_dim,
            param_count: 0,
            flops_forward: 0,
            channel_weights: crate::config::zoo_channel_weights(channels),
        };
        cfg.param_count = cfg.derived_param_count();
        cfg
    }
}

/// Paper Section 6: ERA5 0.25-degree sample = 721 x 1440 x 69 channels f32.
pub const PAPER_SAMPLE_BYTES: f64 = 721.0 * 1440.0 * 69.0 * 4.0;

/// Table 2: the DP-instance layout for the system-scale weak scaling runs.
#[derive(Clone, Copy, Debug)]
pub struct ParallelPlan {
    pub way: usize,
    pub tflops_fwd: f64,
    pub params_mil: f64,
}

pub const TABLE2: [ParallelPlan; 3] = [
    ParallelPlan { way: 1, tflops_fwd: 16.0, params_mil: 1000.0 },
    ParallelPlan { way: 2, tflops_fwd: 32.0, params_mil: 1400.0 },
    ParallelPlan { way: 4, tflops_fwd: 64.0, params_mil: 2400.0 },
];

impl ParallelPlan {
    /// Number of data-parallel model instances on `gpus` GPUs (Table 2).
    /// None when the model does not fit (fewer GPUs than the MP way).
    pub fn dp_instances(&self, gpus: usize) -> Option<usize> {
        if gpus < self.way {
            None
        } else {
            Some(gpus / self.way)
        }
    }

    /// The plan's jigsaw mesh (Table 2 uses the balanced factorization
    /// of its degree: 1 -> 1x1, 2 -> 1x2, 4 -> 2x2).
    pub fn mesh(&self) -> Result<crate::jigsaw::Mesh, crate::jigsaw::MeshError> {
        crate::jigsaw::Mesh::from_degree(self.way)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_workload_doubles() {
        for w in TABLE1.windows(2) {
            assert!((w[1].tflops_fwd / w[0].tflops_fwd - 2.0).abs() < 1e-9);
        }
    }

    #[test]
    fn table1_largest_single_gpu_model() {
        // paper: ~1.4B params is the largest fitting a 40 GB A100
        assert!((ZooModel::by_id(7).params_mil - 1400.0).abs() < 1.0);
    }

    #[test]
    fn table2_matches_paper() {
        // paper Table 2 at 256 GPUs: 256 / 128 / 64 instances
        assert_eq!(TABLE2[0].dp_instances(256), Some(256));
        assert_eq!(TABLE2[1].dp_instances(256), Some(128));
        assert_eq!(TABLE2[2].dp_instances(256), Some(64));
        // 4-way does not fit on fewer than 4 GPUs
        assert_eq!(TABLE2[2].dp_instances(2), None);
    }

    #[test]
    fn native_configs_are_runnable_shapes() {
        for row in TABLE1.iter() {
            let cfg = row.native_config(8);
            assert_eq!(cfg.d_emb % 16, 0);
            assert_eq!(cfg.d_tok % 16, 0);
            assert_eq!(cfg.d_ch % 16, 0);
            assert_eq!(cfg.channels_padded % 4, 0);
            assert_eq!(cfg.tokens, 32);
            assert!(cfg.param_count > 0);
        }
        // scaling down preserves the zoo's workload ordering
        let a = ZooModel::by_id(4).native_config(8);
        let b = ZooModel::by_id(6).native_config(8);
        assert!(b.param_count > a.param_count);
    }

    #[test]
    fn backward_is_twice_forward() {
        let m = ZooModel::by_id(3);
        assert!((m.flops_step() / m.flops_fwd() - 3.0).abs() < 1e-12);
    }
}
