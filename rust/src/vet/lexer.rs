//! Token-level Rust lexer for the `vet` rule engine.
//!
//! The rules this repo enforces (see [`super::rules`]) are all visible at
//! the token level — a `.lock().unwrap()` chain, a shift by the tag
//! field's bit offset, a `Condvar::wait` outside a loop — so `vet` does
//! not need (and, per the no-new-dependencies policy, cannot vendor) a
//! full parser like `syn`. This lexer produces the three token classes
//! the rules consume (identifiers, numeric literals, single-char
//! punctuation), drops comments / strings / char literals / lifetimes so
//! rule text inside a doc comment or a diagnostic string can never
//! trigger a finding, and collects `// vet: allow(<rule>, ...)`
//! suppression pragmas by line.
//!
//! On top of the token stream, [`analyze_scopes`] runs a single
//! brace-matching pass that labels every token with its enclosing
//! function (name + return-type tokens), whether it sits inside a
//! `loop`/`while`/`for` body, and whether it is test code (`#[test]`
//! functions and `#[cfg(test)]` modules) — the only structure the rules
//! need.

use std::collections::HashMap;

/// Token classes the rules care about. Everything else (comments,
/// string/char literals, lifetimes) is dropped during lexing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Num,
    Punct,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    pub fn is(&self, text: &str) -> bool {
        self.text == text
    }

    pub fn is_ident(&self, text: &str) -> bool {
        self.kind == TokKind::Ident && self.text == text
    }
}

/// Lex result: tokens plus the suppression pragmas found in comments,
/// keyed by the line the pragma comment sits on.
pub struct Lexed {
    pub toks: Vec<Tok>,
    /// line -> rule names listed in a `// vet: allow(...)` pragma
    pub allows: HashMap<u32, Vec<String>>,
}

/// Lex `src` into rule-relevant tokens. Never fails: unterminated
/// constructs simply run to end of input (vet lints source that `rustc`
/// already accepts, so error recovery is not a goal).
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut toks = Vec::new();
    let mut allows: HashMap<u32, Vec<String>> = HashMap::new();
    let mut i = 0usize;
    let mut line: u32 = 1;
    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_ascii_whitespace() => i += 1,
            b'/' if b.get(i + 1) == Some(&b'/') => {
                let start = i;
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                scan_pragma(&src[start..i], line, &mut allows);
            }
            b'/' if b.get(i + 1) == Some(&b'*') => {
                // block comment, nesting supported
                let mut depth = 1usize;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            b'"' => i = skip_string(b, i, &mut line),
            b'r' | b'b'
                if is_raw_string_start(b, i) =>
            {
                i = skip_raw_string(b, i, &mut line)
            }
            b'\'' => {
                // lifetime ('a) vs char literal ('x', '\n', '\u{..}')
                if is_lifetime(b, i) {
                    i += 1;
                    while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                        i += 1;
                    }
                } else {
                    i += 1;
                    while i < b.len() && b[i] != b'\'' {
                        if b[i] == b'\\' {
                            i += 1;
                        }
                        if i < b.len() {
                            if b[i] == b'\n' {
                                line += 1;
                            }
                            i += 1;
                        }
                    }
                    i += 1; // closing quote
                }
            }
            c if c.is_ascii_digit() => {
                let start = i;
                // numeric literal: digits, hex/bin/oct prefixes, `_`,
                // type suffixes, float dots handled as separate puncts
                i += 1;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                toks.push(Tok { kind: TokKind::Num, text: src[start..i].to_string(), line });
            }
            c if c.is_ascii_alphabetic() || c == b'_' => {
                let start = i;
                i += 1;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                toks.push(Tok { kind: TokKind::Ident, text: src[start..i].to_string(), line });
            }
            _ => {
                toks.push(Tok { kind: TokKind::Punct, text: (c as char).to_string(), line });
                i += 1;
            }
        }
    }
    Lexed { toks, allows }
}

/// `r"..."`, `r#"..."#`, `br"..."` — raw (byte) string openers. Plain
/// `b"..."` byte strings are handled by the `"` arm after the `b` lexes
/// as part of an identifier only when not followed by a quote, so catch
/// them here too.
fn is_raw_string_start(b: &[u8], i: usize) -> bool {
    let rest = &b[i..];
    rest.starts_with(b"r\"")
        || rest.starts_with(b"r#")
        || rest.starts_with(b"br\"")
        || rest.starts_with(b"br#")
        || rest.starts_with(b"b\"")
}

fn skip_string(b: &[u8], mut i: usize, line: &mut u32) -> usize {
    i += 1; // opening quote
    while i < b.len() && b[i] != b'"' {
        if b[i] == b'\\' {
            i += 1;
        }
        if i < b.len() {
            if b[i] == b'\n' {
                *line += 1;
            }
            i += 1;
        }
    }
    i + 1
}

fn skip_raw_string(b: &[u8], mut i: usize, line: &mut u32) -> usize {
    if b[i] == b'b' {
        i += 1;
    }
    if b[i] == b'"' {
        // plain byte string: escape-aware
        return skip_string(b, i, line);
    }
    i += 1; // the `r`
    let mut hashes = 0usize;
    while i < b.len() && b[i] == b'#' {
        hashes += 1;
        i += 1;
    }
    i += 1; // opening quote
    loop {
        if i >= b.len() {
            return i;
        }
        if b[i] == b'\n' {
            *line += 1;
        }
        if b[i] == b'"' {
            let mut ok = true;
            for k in 0..hashes {
                if b.get(i + 1 + k) != Some(&b'#') {
                    ok = false;
                    break;
                }
            }
            if ok {
                return i + 1 + hashes;
            }
        }
        i += 1;
    }
}

/// `'a` is a lifetime (quote + ident not closed by another quote),
/// `'a'` is a char literal.
fn is_lifetime(b: &[u8], i: usize) -> bool {
    match b.get(i + 1) {
        Some(c) if c.is_ascii_alphabetic() || *c == b'_' => b.get(i + 2) != Some(&b'\''),
        _ => false,
    }
}

/// Recognize `// vet: allow(rule-a, rule-b)` in a line comment.
fn scan_pragma(comment: &str, line: u32, allows: &mut HashMap<u32, Vec<String>>) {
    let Some(at) = comment.find("vet:") else { return };
    let rest = comment[at + 4..].trim_start();
    let Some(rest) = rest.strip_prefix("allow(") else { return };
    let Some(close) = rest.find(')') else { return };
    let rules: Vec<String> = rest[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    if !rules.is_empty() {
        allows.entry(line).or_default().extend(rules);
    }
}

// ---------------------------------------------------------------------------
// Scope analysis
// ---------------------------------------------------------------------------

/// One function item found during scope analysis.
#[derive(Clone, Debug)]
pub struct FnInfo {
    pub name: String,
    /// tokens of the return type (`-> Vec<f32>` records `Vec`, `<`,
    /// `f32`, `>`), empty for `()` returns
    pub ret: Vec<String>,
    /// token index of the body's `{`
    pub body_start: usize,
    /// token index of the body's matching `}` (= toks.len() when
    /// unterminated)
    pub body_end: usize,
}

/// Per-token context the rules consume.
#[derive(Clone, Copy, Debug, Default)]
pub struct Ctx {
    /// innermost enclosing fn (index into `Scopes::fns`)
    pub fn_id: Option<usize>,
    /// inside a `loop` / `while` / `for` body within the enclosing fn
    pub in_loop: bool,
    /// inside a `#[test]` fn or `#[cfg(test)]` module
    pub in_test: bool,
}

pub struct Scopes {
    pub fns: Vec<FnInfo>,
    /// parallel to the token stream
    pub ctx: Vec<Ctx>,
}

enum ScopeKind {
    Fn(usize),
    Loop,
    TestMod,
    Other,
}

/// Label every token with its enclosing fn / loop / test context via one
/// brace-matching pass. Heuristic by design: expression blocks and
/// struct literals land in `Other` scopes, which is exactly as much
/// structure as the rules need.
pub fn analyze_scopes(toks: &[Tok]) -> Scopes {
    let mut fns: Vec<FnInfo> = Vec::new();
    let mut ctx = vec![Ctx::default(); toks.len()];
    let mut stack: Vec<ScopeKind> = Vec::new();
    // set when an attribute containing `test` was seen and no item
    // consumed it yet
    let mut attr_test = false;
    // pending item headers: set at the keyword, consumed at its `{`
    let mut pending: Option<ScopeKind> = None;
    // while a fn header is pending: its index, and whether we are past
    // the `->` (collecting return-type tokens)
    let mut pending_fn: Option<(usize, bool)> = None;
    let mut i = 0usize;
    while i < toks.len() {
        let t = &toks[i];
        // current context for this token
        let mut c = Ctx::default();
        for s in stack.iter().rev() {
            match s {
                ScopeKind::Fn(id) => {
                    if c.fn_id.is_none() {
                        c.fn_id = Some(*id);
                        if fns[*id].name.starts_with("__test__") {
                            c.in_test = true;
                        }
                    }
                }
                ScopeKind::Loop => {
                    if c.fn_id.is_none() {
                        c.in_loop = true;
                    }
                }
                ScopeKind::TestMod => c.in_test = true,
                ScopeKind::Other => {}
            }
        }
        ctx[i] = c;

        match (t.kind, t.text.as_str()) {
            (TokKind::Punct, "#") => {
                // attribute: scan to the matching `]`, look for `test`
                let mut j = i + 1;
                if toks.get(j).map_or(false, |t| t.is("!")) {
                    j += 1; // inner attribute `#![...]`
                }
                if toks.get(j).map_or(false, |t| t.is("[")) {
                    let mut depth = 0usize;
                    let mut has_test = false;
                    while j < toks.len() {
                        if toks[j].is("[") {
                            depth += 1;
                        } else if toks[j].is("]") {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        } else if toks[j].is_ident("test") {
                            has_test = true;
                        }
                        j += 1;
                    }
                    if has_test {
                        attr_test = true;
                    }
                    i = j + 1;
                    continue;
                }
            }
            (TokKind::Ident, "fn") => {
                let name = toks
                    .get(i + 1)
                    .filter(|t| t.kind == TokKind::Ident)
                    .map(|t| t.text.clone())
                    .unwrap_or_default();
                // test fns are tracked through a name prefix so the
                // context pass above needs no second lookup table
                let stored = if attr_test { format!("__test__{name}") } else { name };
                attr_test = false;
                fns.push(FnInfo {
                    name: stored,
                    ret: Vec::new(),
                    body_start: toks.len(),
                    body_end: toks.len(),
                });
                pending_fn = Some((fns.len() - 1, false));
                pending = Some(ScopeKind::Fn(fns.len() - 1));
            }
            (TokKind::Ident, "mod") => {
                pending = Some(if attr_test { ScopeKind::TestMod } else { ScopeKind::Other });
                attr_test = false;
            }
            (TokKind::Ident, "loop") | (TokKind::Ident, "while") | (TokKind::Ident, "for")
                if pending.is_none() =>
            {
                pending = Some(ScopeKind::Loop);
            }
            (TokKind::Ident, "impl") | (TokKind::Ident, "trait") if pending.is_none() => {
                // `impl Trait for Type` — keep the `for` from opening a
                // phantom loop scope
                pending = Some(ScopeKind::Other);
            }
            (TokKind::Punct, "-") => {
                if let Some((id, _)) = pending_fn {
                    if toks.get(i + 1).map_or(false, |t| t.is(">")) {
                        pending_fn = Some((id, true));
                        i += 2;
                        continue;
                    }
                }
            }
            (TokKind::Punct, ";") => {
                // `fn name(...);` — trait method declaration, no body
                if pending_fn.is_some() {
                    pending_fn = None;
                    pending = None;
                }
            }
            (TokKind::Punct, "{") => {
                if let Some((id, _)) = pending_fn.take() {
                    fns[id].body_start = i;
                }
                stack.push(pending.take().unwrap_or(ScopeKind::Other));
            }
            (TokKind::Punct, "}") => {
                if let Some(kind) = stack.pop() {
                    if let ScopeKind::Fn(id) = kind {
                        fns[id].body_end = i;
                    }
                }
            }
            _ => {
                if let Some((id, in_ret)) = pending_fn {
                    if in_ret {
                        fns[id].ret.push(t.text.clone());
                    }
                }
            }
        }
        i += 1;
    }
    // strip the test marker back off the stored names
    for f in fns.iter_mut() {
        if let Some(stripped) = f.name.strip_prefix("__test__") {
            f.name = stripped.to_string();
        }
    }
    Scopes { fns, ctx }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_comments_and_lifetimes_are_dropped() {
        let lx = lex(r##"
            // comment with .lock().unwrap() text
            /* block /* nested */ .unwrap() */
            fn f<'a>(s: &'a str) -> u32 {
                let _c = 'x';
                let _s = "quoted .unwrap()";
                let _r = r#"raw .lock()"#;
                42
            }
        "##);
        assert!(!lx.toks.iter().any(|t| t.is_ident("unwrap")));
        assert!(!lx.toks.iter().any(|t| t.is_ident("lock")));
        assert!(lx.toks.iter().any(|t| t.is_ident("quoted")) == false);
        assert!(lx.toks.iter().any(|t| t.kind == TokKind::Num && t.is("42")));
    }

    #[test]
    fn pragmas_collect_by_line() {
        let lx = lex("let a = 1;\n// vet: allow(raw-lock, lib-unwrap)\nlet b = 2;\n");
        assert_eq!(
            lx.allows.get(&2),
            Some(&vec!["raw-lock".to_string(), "lib-unwrap".to_string()])
        );
    }

    #[test]
    fn scopes_track_fn_loop_and_test() {
        let lx = lex(
            "fn outer() -> Vec<f32> { for i in 0..3 { mark1(); } mark2() }\n\
             #[cfg(test)] mod t { fn inner() { mark3(); } }",
        );
        let sc = analyze_scopes(&lx.toks);
        assert_eq!(sc.fns.len(), 2);
        assert_eq!(sc.fns[0].name, "outer");
        assert_eq!(sc.fns[0].ret, vec!["Vec", "<", "f32", ">"]);
        let at = |name: &str| {
            lx.toks.iter().position(|t| t.is_ident(name)).unwrap()
        };
        assert!(sc.ctx[at("mark1")].in_loop);
        assert!(!sc.ctx[at("mark1")].in_test);
        assert!(!sc.ctx[at("mark2")].in_loop);
        assert!(sc.ctx[at("mark3")].in_test);
        assert_eq!(sc.fns[1].name, "inner");
    }

    #[test]
    fn impl_for_is_not_a_loop() {
        let lx = lex("impl Trait for Thing { fn m(&self) { mark(); } }");
        let sc = analyze_scopes(&lx.toks);
        let at = lx.toks.iter().position(|t| t.is_ident("mark")).unwrap();
        assert!(!sc.ctx[at].in_loop);
        assert_eq!(sc.fns[0].name, "m");
    }
}
