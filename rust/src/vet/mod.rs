//! # `vet` — the repo-specific static lint pass
//!
//! Every rule encodes an invariant this codebase has actually broken
//! (missed condvar wakeups, tag wraparound, pool leaks on abort,
//! poisoned-lock panics — see `docs/static-analysis.md` for the full
//! catalogue and the historical bug behind each rule). The binary
//! (`cargo run --bin vet`) walks `rust/src`, runs the registry over
//! every `.rs` file, and exits nonzero on any finding; CI runs it on
//! every push plus a fixtures self-test that proves each rule still
//! fires on a seeded-bad file.
//!
//! The analysis is a hand-rolled token/scope pass ([`lexer`]), not a
//! `syn` AST walk: the container policy forbids new dependencies, and
//! every invariant here is token-visible. The trade-off is documented
//! per rule — heuristics are tuned to the idioms this repo uses, and
//! `// vet: allow(<rule>)` pragmas exist for the escape hatch.

pub mod lexer;
pub mod rules;

pub use rules::{analyze_source, Finding, RuleInfo, RULES};

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Recursively collect `.rs` files under each path (files pass
/// through), sorted for deterministic reports.
pub fn collect_rs_files(paths: &[PathBuf]) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    for p in paths {
        walk(p, &mut out)?;
    }
    out.sort();
    out.dedup();
    Ok(out)
}

fn walk(p: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let meta = fs::metadata(p)?;
    if meta.is_file() {
        if p.extension().map_or(false, |e| e == "rs") {
            out.push(p.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = fs::read_dir(p)?
        .collect::<io::Result<Vec<_>>>()?
        .into_iter()
        .map(|e| e.path())
        .collect();
    entries.sort();
    for e in entries {
        walk(&e, out)?;
    }
    Ok(())
}

/// Run the registry over every `.rs` file under `paths`. Returns
/// `(files_scanned, findings)`.
pub fn analyze_paths(paths: &[PathBuf]) -> io::Result<(usize, Vec<Finding>)> {
    let files = collect_rs_files(paths)?;
    let mut findings = Vec::new();
    for f in &files {
        let src = fs::read_to_string(f)?;
        let name = f.to_string_lossy().replace('\\', "/");
        findings.extend(analyze_source(&name, &src));
    }
    Ok((files.len(), findings))
}

/// Machine-readable report (schema `version` guards CI consumers
/// against silent drift).
pub fn report_json(files: usize, findings: &[Finding]) -> String {
    let mut s = String::new();
    s.push_str(&format!("{{\"version\":1,\"files\":{files},\"findings\":["));
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"file\":{},\"line\":{},\"rule\":{},\"message\":{}}}",
            json_str(&f.file),
            f.line,
            json_str(f.rule),
            json_str(&f.message)
        ));
    }
    s.push_str("]}");
    s
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Human diagnostics, one line per finding.
pub fn report_human(files: usize, findings: &[Finding]) -> String {
    let mut s = String::new();
    for f in findings {
        s.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
    }
    if findings.is_empty() {
        s.push_str(&format!("vet: {files} files clean\n"));
    } else {
        s.push_str(&format!("vet: {} finding(s) in {files} files\n", findings.len()));
    }
    s
}

/// Outcome of checking one fixture file.
pub struct FixtureResult {
    pub file: String,
    pub expected_rule: String,
    pub ok: bool,
    pub detail: String,
}

/// Self-test over the seeded-bad fixture corpus: each
/// `<rule_name_with_underscores>.rs` must produce at least one finding
/// and *only* findings of its rule; `allow_pragmas.rs` must produce
/// zero findings (it is full of violations, each suppressed). This is
/// what keeps the rules from silently rotting into no-ops.
pub fn self_test(dir: &Path) -> io::Result<Vec<FixtureResult>> {
    let files = collect_rs_files(&[dir.to_path_buf()])?;
    let mut out = Vec::new();
    for f in &files {
        let stem = f.file_stem().map(|s| s.to_string_lossy().to_string()).unwrap_or_default();
        let expected = stem.replace('_', "-");
        let src = fs::read_to_string(f)?;
        let findings = analyze_source(&f.to_string_lossy(), &src);
        let (ok, detail) = if expected == "allow-pragmas" {
            if findings.is_empty() {
                (true, "all violations suppressed by pragmas".to_string())
            } else {
                (false, format!("expected 0 findings, got {:?}", rule_names(&findings)))
            }
        } else if findings.is_empty() {
            (false, format!("expected >=1 `{expected}` finding, got none"))
        } else if findings.iter().all(|x| x.rule == expected) {
            (true, format!("{} `{expected}` finding(s)", findings.len()))
        } else {
            (false, format!("expected only `{expected}`, got {:?}", rule_names(&findings)))
        };
        out.push(FixtureResult { file: f.to_string_lossy().to_string(), expected_rule: expected, ok, detail });
    }
    Ok(out)
}

fn rule_names(f: &[Finding]) -> Vec<&'static str> {
    f.iter().map(|x| x.rule).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_report_escapes_and_structures() {
        let f = vec![Finding {
            file: "a\"b.rs".into(),
            line: 7,
            rule: "raw-lock",
            message: "x\ny".into(),
        }];
        let j = report_json(3, &f);
        assert_eq!(
            j,
            "{\"version\":1,\"files\":3,\"findings\":[{\"file\":\"a\\\"b.rs\",\"line\":7,\"rule\":\"raw-lock\",\"message\":\"x\\ny\"}]}"
        );
    }

    #[test]
    fn registry_names_are_kebab_and_unique() {
        let mut names: Vec<&str> = RULES.iter().map(|r| r.name).collect();
        assert!(names.iter().all(|n| n.chars().all(|c| c.is_ascii_lowercase() || c == '-')));
        names.sort();
        names.dedup();
        assert_eq!(names.len(), RULES.len());
    }

    /// The in-repo fixture corpus must pass the self-test — the same
    /// invariant CI enforces, kept runnable offline.
    #[test]
    fn fixtures_self_test_passes() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/xtask/fixtures");
        let results = self_test(&dir).expect("fixtures dir readable");
        assert!(!results.is_empty(), "no fixtures found at {}", dir.display());
        let expected: Vec<String> = {
            let mut v: Vec<String> = RULES.iter().map(|r| r.name.to_string()).collect();
            v.push("allow-pragmas".to_string());
            v.sort();
            v
        };
        let mut got: Vec<String> = results.iter().map(|r| r.expected_rule.clone()).collect();
        got.sort();
        assert_eq!(got, expected, "one fixture per rule plus allow_pragmas");
        for r in &results {
            assert!(r.ok, "{}: {}", r.file, r.detail);
        }
    }

    /// vet must be clean on its own source tree — zero findings, zero
    /// suppressions outside fixtures (mirrors the CI gate).
    #[test]
    fn own_tree_is_clean() {
        let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
        let (files, findings) = analyze_paths(&[src]).expect("rust/src readable");
        assert!(files > 10, "suspiciously few files scanned: {files}");
        assert!(
            findings.is_empty(),
            "vet findings in tree:\n{}",
            report_human(files, &findings)
        );
    }
}
