//! # `vet` — the repo-specific static lint pass
//!
//! Every rule encodes an invariant this codebase has actually broken
//! (missed condvar wakeups, tag wraparound, pool leaks on abort,
//! poisoned-lock panics — see `docs/static-analysis.md` for the full
//! catalogue and the historical bug behind each rule). The binary
//! (`cargo run --bin vet`) walks `rust/src`, runs the registry over
//! every `.rs` file, and exits nonzero on any finding; CI runs it on
//! every push plus a fixtures self-test that proves each rule still
//! fires on a seeded-bad file.
//!
//! Two analysis tiers: the per-file token/scope rules in [`rules`], and
//! the cross-file `lock-order` pass in [`callgraph`], which builds a
//! call graph over the whole file set and checks every "class B
//! acquired while class A held" edge — direct or through any call chain
//! — against the hierarchy declared in `rust/src/vet/lock_order.toml`.
//!
//! The analysis is a hand-rolled token/scope pass ([`lexer`]), not a
//! `syn` AST walk: the container policy forbids new dependencies, and
//! every invariant here is token-visible. The trade-off is documented
//! per rule — heuristics are tuned to the idioms this repo uses, and
//! `// vet: allow(<rule>)` pragmas exist for the escape hatch.

pub mod callgraph;
pub mod lexer;
pub mod rules;

pub use callgraph::{analyze_lock_order, Hierarchy, DEFAULT_HIERARCHY};
pub use rules::{analyze_source, Finding, RuleInfo, RULES};

use std::collections::BTreeMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Recursively collect `.rs` files under each path (files pass
/// through), sorted for deterministic reports.
pub fn collect_rs_files(paths: &[PathBuf]) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    for p in paths {
        walk(p, &mut out)?;
    }
    out.sort();
    out.dedup();
    Ok(out)
}

fn walk(p: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let meta = fs::metadata(p)?;
    if meta.is_file() {
        if p.extension().map_or(false, |e| e == "rs") {
            out.push(p.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = fs::read_dir(p)?
        .collect::<io::Result<Vec<_>>>()?
        .into_iter()
        .map(|e| e.path())
        .collect();
    entries.sort();
    for e in entries {
        walk(&e, out)?;
    }
    Ok(())
}

/// Result of one lint run. Per-file read failures (missing file,
/// non-UTF-8 bytes) land in `errors` instead of aborting the walk: the
/// remaining files still get linted and the binary fails at the end.
pub struct ScanResult {
    /// files the run attempted to lint (readable or not)
    pub files: usize,
    pub findings: Vec<Finding>,
    pub errors: Vec<(PathBuf, String)>,
}

/// Run the registry plus the cross-file lock-order pass over every
/// `.rs` file under `paths`.
pub fn analyze_paths(paths: &[PathBuf]) -> io::Result<ScanResult> {
    let files = collect_rs_files(paths)?;
    analyze_file_set(&files, &files)
}

/// Lint `lint_files` with the per-file rules and build the lock-order
/// call graph over `graph_files`. The graph set is kept separate so
/// `--changed` can lint only the changed files while still resolving
/// call chains whose other half lives in an unchanged file.
pub fn analyze_file_set(
    lint_files: &[PathBuf],
    graph_files: &[PathBuf],
) -> io::Result<ScanResult> {
    let hier = Hierarchy::parse(DEFAULT_HIERARCHY)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
    let mut union: Vec<PathBuf> =
        lint_files.iter().chain(graph_files.iter()).cloned().collect();
    union.sort();
    union.dedup();
    let mut errors: Vec<(PathBuf, String)> = Vec::new();
    let mut read: BTreeMap<PathBuf, (String, String)> = BTreeMap::new();
    for f in &union {
        match fs::read_to_string(f) {
            Ok(src) => {
                let name = f.to_string_lossy().replace('\\', "/");
                read.insert(f.clone(), (name, src));
            }
            Err(e) => errors.push((f.clone(), e.to_string())),
        }
    }
    let mut findings = Vec::new();
    for f in lint_files {
        if let Some((name, src)) = read.get(f) {
            findings.extend(analyze_source(name, src));
        }
    }
    let graph_set: Vec<(String, String)> =
        graph_files.iter().filter_map(|f| read.get(f).cloned()).collect();
    findings.extend(analyze_lock_order(&graph_set, &hier));
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(ScanResult { files: lint_files.len(), findings, errors })
}

/// Machine-readable report (schema `version` guards CI consumers
/// against silent drift). `errors` lists files the run could not read.
pub fn report_json(res: &ScanResult) -> String {
    let mut s = String::new();
    s.push_str(&format!("{{\"version\":1,\"files\":{},\"findings\":[", res.files));
    for (i, f) in res.findings.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"file\":{},\"line\":{},\"rule\":{},\"message\":{}}}",
            json_str(&f.file),
            f.line,
            json_str(f.rule),
            json_str(&f.message)
        ));
    }
    s.push_str("],\"errors\":[");
    for (i, (path, err)) in res.errors.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"file\":{},\"error\":{}}}",
            json_str(&path.to_string_lossy().replace('\\', "/")),
            json_str(err)
        ));
    }
    s.push_str("]}");
    s
}

/// SARIF 2.1.0 report for GitHub code-scanning upload: tool driver with
/// the rule registry as metadata, one `result` per finding anchored at
/// its file + line. Minimal by design, but schema-valid — the CI `vet`
/// job uploads this so findings render as inline annotations.
pub fn report_sarif(findings: &[Finding]) -> String {
    let mut s = String::new();
    s.push_str(concat!(
        "{\"$schema\":\"https://raw.githubusercontent.com/oasis-tcs/",
        "sarif-spec/master/Schemata/sarif-schema-2.1.0.json\",",
        "\"version\":\"2.1.0\",\"runs\":[{\"tool\":{\"driver\":",
        "{\"name\":\"jigsaw-vet\",\"rules\":["
    ));
    for (i, r) in RULES.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"id\":{},\"shortDescription\":{{\"text\":{}}}}}",
            json_str(r.name),
            json_str(r.summary)
        ));
    }
    s.push_str("]}},\"results\":[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let rule_index = RULES
            .iter()
            .position(|r| r.name == f.rule)
            .map_or(String::new(), |p| format!("\"ruleIndex\":{p},"));
        s.push_str(&format!(
            "{{\"ruleId\":{},{rule_index}\"level\":\"error\",\"message\":{{\"text\":{}}},\
             \"locations\":[{{\"physicalLocation\":{{\"artifactLocation\":{{\"uri\":{}}},\
             \"region\":{{\"startLine\":{}}}}}}}]}}",
            json_str(f.rule),
            json_str(&f.message),
            json_str(&f.file),
            f.line.max(1)
        ));
    }
    s.push_str("]}]}");
    s
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Human diagnostics, one line per finding, then one per read error.
pub fn report_human(res: &ScanResult) -> String {
    let mut s = String::new();
    for f in &res.findings {
        s.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
    }
    for (path, err) in &res.errors {
        s.push_str(&format!("vet: cannot read {}: {err}\n", path.display()));
    }
    if res.findings.is_empty() && res.errors.is_empty() {
        s.push_str(&format!("vet: {} files clean\n", res.files));
    } else {
        s.push_str(&format!(
            "vet: {} finding(s), {} unreadable file(s) in {} files\n",
            res.findings.len(),
            res.errors.len(),
            res.files
        ));
    }
    s
}

/// Outcome of checking one fixture unit (a file, or a directory of
/// files exercising the cross-file lock-order pass).
pub struct FixtureResult {
    pub file: String,
    pub expected_rule: String,
    pub ok: bool,
    pub detail: String,
}

/// Self-test over the seeded-bad fixture corpus. Each
/// `<rule_name_with_underscores>.rs` must produce at least one finding
/// and *only* findings of its rule; a fixture *directory* is analyzed
/// as one cross-file unit (this is how `lock_order/` seeds an inversion
/// split across two functions in two files). Units named
/// `allow_pragmas` or ending in `_ok` must produce zero findings. This
/// is what keeps the rules from silently rotting into no-ops.
pub fn self_test(dir: &Path) -> io::Result<Vec<FixtureResult>> {
    let hier = Hierarchy::parse(DEFAULT_HIERARCHY)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)?
        .collect::<io::Result<Vec<_>>>()?
        .into_iter()
        .map(|e| e.path())
        .collect();
    entries.sort();
    let mut out = Vec::new();
    for e in entries {
        let stem =
            e.file_stem().map(|s| s.to_string_lossy().to_string()).unwrap_or_default();
        let expected = stem.replace('_', "-");
        let meta = fs::metadata(&e)?;
        let findings = if meta.is_dir() {
            let files = collect_rs_files(&[e.clone()])?;
            let mut set: Vec<(String, String)> = Vec::new();
            let mut acc = Vec::new();
            for f in &files {
                let src = fs::read_to_string(f)?;
                let name = f.to_string_lossy().replace('\\', "/");
                acc.extend(analyze_source(&name, &src));
                set.push((name, src));
            }
            acc.extend(analyze_lock_order(&set, &hier));
            acc
        } else if e.extension().map_or(false, |x| x == "rs") {
            let src = fs::read_to_string(&e)?;
            let name = e.to_string_lossy().replace('\\', "/");
            let mut acc = analyze_source(&name, &src);
            acc.extend(analyze_lock_order(&[(name, src)], &hier));
            acc
        } else {
            continue;
        };
        let expects_zero = expected == "allow-pragmas" || expected.ends_with("-ok");
        let (ok, detail) = if expects_zero {
            if findings.is_empty() {
                (true, "clean, as the fixture requires".to_string())
            } else {
                (false, format!("expected 0 findings, got {:?}", rule_names(&findings)))
            }
        } else if findings.is_empty() {
            (false, format!("expected >=1 `{expected}` finding, got none"))
        } else if findings.iter().all(|x| x.rule == expected) {
            (true, format!("{} `{expected}` finding(s)", findings.len()))
        } else {
            (false, format!("expected only `{expected}`, got {:?}", rule_names(&findings)))
        };
        out.push(FixtureResult {
            file: e.to_string_lossy().to_string(),
            expected_rule: expected,
            ok,
            detail,
        });
    }
    Ok(out)
}

fn rule_names(f: &[Finding]) -> Vec<&'static str> {
    f.iter().map(|x| x.rule).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_report_escapes_and_structures() {
        let res = ScanResult {
            files: 3,
            findings: vec![Finding {
                file: "a\"b.rs".into(),
                line: 7,
                rule: "raw-lock",
                message: "x\ny".into(),
            }],
            errors: vec![(PathBuf::from("bad.rs"), "boom".into())],
        };
        let j = report_json(&res);
        assert_eq!(
            j,
            "{\"version\":1,\"files\":3,\"findings\":[{\"file\":\"a\\\"b.rs\",\"line\":7,\"rule\":\"raw-lock\",\"message\":\"x\\ny\"}],\"errors\":[{\"file\":\"bad.rs\",\"error\":\"boom\"}]}"
        );
    }

    #[test]
    fn sarif_report_names_tool_rules_and_locations() {
        let f = vec![Finding {
            file: "rust/src/x.rs".into(),
            line: 3,
            rule: "lock-order",
            message: "inverted".into(),
        }];
        let s = report_sarif(&f);
        assert!(s.contains("\"version\":\"2.1.0\""), "{s}");
        assert!(s.contains("\"name\":\"jigsaw-vet\""), "{s}");
        assert!(s.contains("\"ruleId\":\"lock-order\""), "{s}");
        assert!(s.contains("\"uri\":\"rust/src/x.rs\""), "{s}");
        assert!(s.contains("\"startLine\":3"), "{s}");
        // every registry rule ships as driver metadata
        for r in RULES {
            assert!(s.contains(&format!("\"id\":\"{}\"", r.name)), "missing {}", r.name);
        }
    }

    #[test]
    fn registry_names_are_kebab_and_unique() {
        let mut names: Vec<&str> = RULES.iter().map(|r| r.name).collect();
        assert!(names.iter().all(|n| n.chars().all(|c| c.is_ascii_lowercase() || c == '-')));
        names.sort();
        names.dedup();
        assert_eq!(names.len(), RULES.len());
    }

    /// The in-repo fixture corpus must pass the self-test — the same
    /// invariant CI enforces, kept runnable offline.
    #[test]
    fn fixtures_self_test_passes() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/xtask/fixtures");
        let results = self_test(&dir).expect("fixtures dir readable");
        assert!(!results.is_empty(), "no fixtures found at {}", dir.display());
        let expected: Vec<String> = {
            let mut v: Vec<String> = RULES.iter().map(|r| r.name.to_string()).collect();
            v.push("allow-pragmas".to_string());
            v.push("lock-order-ok".to_string());
            v.sort();
            v
        };
        let mut got: Vec<String> = results.iter().map(|r| r.expected_rule.clone()).collect();
        got.sort();
        assert_eq!(got, expected, "one fixture unit per rule plus the clean corpora");
        for r in &results {
            assert!(r.ok, "{}: {}", r.file, r.detail);
        }
    }

    /// vet must be clean on its own source tree — zero findings, zero
    /// suppressions outside fixtures (mirrors the CI gate). This gates
    /// the cross-file `lock-order` pass too.
    #[test]
    fn own_tree_is_clean() {
        let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
        let res = analyze_paths(&[src]).expect("rust/src readable");
        assert!(res.files > 10, "suspiciously few files scanned: {}", res.files);
        assert!(res.errors.is_empty(), "unreadable files under rust/src: {:?}", res.errors);
        assert!(res.findings.is_empty(), "vet findings in tree:\n{}", report_human(&res));
    }

    /// The small fix this PR ships: an unreadable (here: non-UTF-8) file
    /// is reported by path and the remaining files still get linted,
    /// instead of the whole run aborting with a bare I/O error.
    #[test]
    fn unreadable_file_is_reported_and_linting_continues() {
        let dir = std::env::temp_dir()
            .join(format!("vet-badutf8-{}", std::process::id()));
        fs::create_dir_all(&dir).expect("temp fixture dir");
        fs::write(dir.join("bad.rs"), [0xFFu8, 0xFE, b'f', b'n']).expect("write bad");
        fs::write(dir.join("ok.rs"), "fn f(m: &M) -> u32 { m.lock().unwrap(); 1 }\n")
            .expect("write ok");
        let res = analyze_paths(&[dir.clone()]).expect("walk succeeds");
        fs::remove_dir_all(&dir).ok();
        assert_eq!(res.files, 2, "both files attempted");
        assert_eq!(res.errors.len(), 1, "{:?}", res.errors);
        assert!(res.errors[0].0.ends_with("bad.rs"), "{:?}", res.errors);
        assert_eq!(res.findings.len(), 1, "{:?}", res.findings);
        assert_eq!(res.findings[0].rule, "raw-lock");
        let human = report_human(&res);
        assert!(human.contains("cannot read"), "{human}");
        assert!(human.contains("bad.rs"), "{human}");
    }
}
