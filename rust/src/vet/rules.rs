//! The `vet` rule registry.
//!
//! Every rule here encodes an invariant this repo broke once and then
//! fixed (see `docs/static-analysis.md` for the bug behind each one).
//! Rules operate on the token stream + scope labels from
//! [`super::lexer`]; all are per-file.

use super::lexer::{analyze_scopes, lex, Lexed, Scopes, Tok, TokKind};

/// One diagnostic produced by a rule.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
}

/// Registry entry: rule name + one-line description (drives `--list`
/// and keeps docs honest).
pub struct RuleInfo {
    pub name: &'static str,
    pub summary: &'static str,
}

pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        name: "raw-lock",
        summary: "`.lock().unwrap()/.expect()` outside `plock` — poisoned-lock panic on abort paths",
    },
    RuleInfo {
        name: "condvar-no-repredicate",
        summary: "Condvar wait not re-checked in a loop (or a tail-position wrapper) — missed-wakeup class",
    },
    RuleInfo {
        name: "raw-tag-literal",
        summary: "collective tag bit-twiddling outside `next_coll_tag` — tag-wraparound class",
    },
    RuleInfo {
        name: "hot-loop-clock",
        summary: "`Instant::now` inside kernel/band-driver loops — clock syscalls on the compute hot path",
    },
    RuleInfo {
        name: "pool-unpaired",
        summary: "`pool::take*` with no `put*`/ownership escape in the same fn — abort-path buffer leak",
    },
    RuleInfo {
        name: "lib-unwrap",
        summary: "`.unwrap()/.expect()` on fallible std calls in library code — should be typed errors",
    },
    RuleInfo {
        name: "wire-bytes-drift",
        summary: "elem-width byte math on `numel()` / shadow `Payload` outside comm — fabric-accounting drift",
    },
    // Cross-file: not run by `analyze_source` — the callgraph pass in
    // `vet::callgraph` needs the whole file set, so `analyze_paths`
    // wires it in. Registered here so `--list`, pragma suppression, and
    // SARIF rule metadata all see it.
    RuleInfo {
        name: "lock-order",
        summary: "lock acquired against the declared hierarchy, directly or via a call chain — deadlock-by-inversion class",
    },
];

/// Shift amounts / masks that define the collective tag layout
/// (`[63]=COLLECTIVE_BIT [62]=REPLY_BIT [61:44]=group hash [43:0]=seq`).
/// Only `next_coll_tag` and top-level consts may spell these out.
const TAG_SHIFTS: &[u64] = &[44, 62, 63];
const TAG_MASKS: &[&str] = &["3ffff", "fffffffffff"];

/// Fallible-by-contract std calls whose `Err` must become a typed error
/// in library code. Lock and condvar families are deliberately absent
/// (owned by `raw-lock` / `condvar-no-repredicate`), as is
/// `JoinHandle::join` (its `Err` is a propagated panic; re-raising is
/// the contract).
const RESULT_SET: &[&str] = &[
    "parse", "try_into", "try_from", "from_utf8", "from_str", "read_to_string", "write_all",
    "read_exact", "flush", "sync_all", "set_len", "seek", "create_dir_all", "remove_file",
    "remove_dir_all", "rename", "read_dir", "metadata", "canonicalize", "open", "create", "var",
    "try_borrow", "try_borrow_mut", "recv_timeout",
];

/// Identifiers whose lowercase form marks a condvar-ish receiver.
fn condvar_receiver(name: &str) -> bool {
    let l = name.to_ascii_lowercase();
    matches!(l.as_str(), "cv" | "cvar" | "cond") || l.contains("condvar")
}

/// Run every rule over one file's source. `file` is used verbatim in
/// findings and for the `hot-loop-clock` path scope.
pub fn analyze_source(file: &str, src: &str) -> Vec<Finding> {
    let lexed = lex(src);
    let scopes = analyze_scopes(&lexed.toks);
    let mut out = Vec::new();
    rule_raw_lock(file, &lexed, &scopes, &mut out);
    rule_condvar(file, &lexed, &scopes, &mut out);
    rule_raw_tag(file, &lexed, &scopes, &mut out);
    rule_hot_loop_clock(file, &lexed, &scopes, &mut out);
    rule_pool_unpaired(file, &lexed, &scopes, &mut out);
    rule_lib_unwrap(file, &lexed, &scopes, &mut out);
    rule_wire_bytes_drift(file, &lexed, &scopes, &mut out);
    // suppression pragmas: a finding at line L is suppressed by a
    // pragma on L (trailing) or L-1 (preceding line)
    out.retain(|f| {
        for l in [f.line, f.line.saturating_sub(1)] {
            if let Some(rules) = lexed.allows.get(&l) {
                if rules.iter().any(|r| r == f.rule || r == "all") {
                    return false;
                }
            }
        }
        true
    });
    out.sort_by_key(|f| (f.line, f.rule));
    out
}

fn push(out: &mut Vec<Finding>, file: &str, line: u32, rule: &'static str, message: String) {
    out.push(Finding { file: file.to_string(), line, rule, message });
}

// ---------------------------------------------------------------------------
// raw-lock
// ---------------------------------------------------------------------------

/// `.lock().unwrap()` / `.try_lock().unwrap()` / `.expect(..)` anywhere
/// (tests included — a poisoned lock in a test harness hides the real
/// panic too). The only sanctioned spelling lives inside `plock`.
fn rule_raw_lock(file: &str, lx: &Lexed, sc: &Scopes, out: &mut Vec<Finding>) {
    let t = &lx.toks;
    for i in 0..t.len() {
        if t[i].is(".")
            && t.get(i + 1).map_or(false, |x| x.is_ident("lock") || x.is_ident("try_lock"))
            && t.get(i + 2).map_or(false, |x| x.is("("))
            && t.get(i + 3).map_or(false, |x| x.is(")"))
            && t.get(i + 4).map_or(false, |x| x.is("."))
            && t.get(i + 5).map_or(false, |x| x.is_ident("unwrap") || x.is_ident("expect"))
            && t.get(i + 6).map_or(false, |x| x.is("("))
        {
            let in_plock = sc.ctx[i].fn_id.map_or(false, |f| sc.fns[f].name == "plock");
            if !in_plock {
                push(
                    out,
                    file,
                    t[i + 1].line,
                    "raw-lock",
                    format!(
                        "`.{}().{}(..)` — use `crate::util::plock` (poison-tolerant) instead",
                        t[i + 1].text,
                        t[i + 5].text
                    ),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// condvar-no-repredicate
// ---------------------------------------------------------------------------

/// A `Condvar::wait`/`wait_timeout` must be re-checked under the lock:
/// either the call sits lexically inside a loop, or it is the tail
/// expression of a small wrapper fn — in which case every *call* to
/// that wrapper must itself sit in a loop (or be a further tail
/// wrapper). `wait_while` re-checks by construction and is exempt.
fn rule_condvar(file: &str, lx: &Lexed, sc: &Scopes, out: &mut Vec<Finding>) {
    let t = &lx.toks;
    // pass A: direct waits — classify as in-loop (ok), tail-of-fn
    // (records a wrapper), or violation
    let mut wrappers: Vec<String> = Vec::new();
    for i in 0..t.len() {
        if !(t[i].is(".")
            && t.get(i + 1).map_or(false, |x| x.is_ident("wait") || x.is_ident("wait_timeout"))
            && t.get(i + 2).map_or(false, |x| x.is("(")))
        {
            continue;
        }
        // receiver: nearest ident before the `.` chain start
        let Some(recv) = receiver_ident(t, i) else { continue };
        if !condvar_receiver(&recv) {
            continue;
        }
        let ctx = sc.ctx[i];
        if ctx.in_loop {
            continue;
        }
        if let Some(fid) = ctx.fn_id {
            if is_tail_of_fn(t, i, sc.fns[fid].body_end) {
                wrappers.push(sc.fns[fid].name.clone());
                continue;
            }
        }
        push(
            out,
            file,
            t[i + 1].line,
            "condvar-no-repredicate",
            format!(
                "condvar `.{}(..)` outside a re-check loop — spurious/missed wakeups lose the predicate",
                t[i + 1].text
            ),
        );
    }
    // pass B: calls to tail wrappers must themselves be looped or tail
    for i in 0..t.len() {
        if t[i].kind != TokKind::Ident || !wrappers.iter().any(|w| t[i].is(&w[..])) {
            continue;
        }
        if !t.get(i + 1).map_or(false, |x| x.is("(")) {
            continue;
        }
        // skip the wrapper's own definition (`fn cv_wait(...)`)
        if i > 0 && t[i - 1].is_ident("fn") {
            continue;
        }
        let ctx = sc.ctx[i];
        if ctx.in_loop {
            continue;
        }
        if let Some(fid) = ctx.fn_id {
            if is_tail_of_fn(t, i, sc.fns[fid].body_end) {
                continue; // wrapper-of-wrapper: its callers get checked too
            }
        }
        push(
            out,
            file,
            t[i].line,
            "condvar-no-repredicate",
            format!("call to condvar-wait wrapper `{}` outside a re-check loop", t[i].text),
        );
    }
}

/// Tail position: no `;` between the call and the enclosing fn's
/// closing brace — i.e. the wait's value is the fn's return value and
/// the caller owns the re-check.
fn is_tail_of_fn(t: &[Tok], i: usize, body_end: usize) -> bool {
    let end = body_end.min(t.len());
    !t[i..end].iter().any(|x| x.is(";"))
}

/// Nearest identifier before the `.` at index `dot` — the receiver of
/// a short method chain (`self.net.cv.wait(..)` resolves to `cv`).
fn receiver_ident(t: &[Tok], dot: usize) -> Option<String> {
    let prev = t.get(dot.checked_sub(1)?)?;
    if prev.kind == TokKind::Ident {
        return Some(prev.text.clone());
    }
    // `cv).wait(..)` / `cv()).wait(..)`: scan back over one balanced
    // paren group then take the ident
    if prev.is(")") {
        let open = match_back(t, dot - 1, "(", ")")?;
        let before = t.get(open.checked_sub(1)?)?;
        if before.kind == TokKind::Ident {
            return Some(before.text.clone());
        }
    }
    None
}

/// Index of the opener matching the closer at `close`, scanning back.
fn match_back(t: &[Tok], close: usize, open_s: &str, close_s: &str) -> Option<usize> {
    let mut depth = 0i64;
    let mut j = close;
    loop {
        if t[j].is(close_s) {
            depth += 1;
        } else if t[j].is(open_s) {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
        j = j.checked_sub(1)?;
    }
}

// ---------------------------------------------------------------------------
// raw-tag-literal
// ---------------------------------------------------------------------------

/// The 44/62/63-bit shifts and the group-hash / sequence masks that
/// define the collective tag word may only be written inside
/// `next_coll_tag` or in top-level const items. Anywhere else is tag
/// bit-twiddling waiting to drift from the layout (the PR-5 32-bit
/// wraparound started exactly this way). Test code is exempt (tests
/// craft raw tags on purpose).
fn rule_raw_tag(file: &str, lx: &Lexed, sc: &Scopes, out: &mut Vec<Finding>) {
    let t = &lx.toks;
    for i in 0..t.len() {
        let ctx = sc.ctx[i];
        if ctx.in_test {
            continue;
        }
        let allowed =
            ctx.fn_id.map_or(true, |f| sc.fns[f].name == "next_coll_tag");
        if allowed {
            continue;
        }
        // `<< 44|62|63`
        if t[i].is("<")
            && t.get(i + 1).map_or(false, |x| x.is("<"))
            && t.get(i + 2).map_or(false, |x| x.kind == TokKind::Num)
        {
            if let Some(v) = num_value(&t[i + 2].text) {
                if TAG_SHIFTS.contains(&v) {
                    push(
                        out,
                        file,
                        t[i + 2].line,
                        "raw-tag-literal",
                        format!(
                            "shift by tag-layout offset {v} outside `next_coll_tag` — use the tag helpers/consts"
                        ),
                    );
                }
            }
        }
        // group-hash / sequence mask literals
        if t[i].kind == TokKind::Num {
            if let Some(hex) = hex_norm(&t[i].text) {
                if TAG_MASKS.contains(&hex.as_str()) {
                    push(
                        out,
                        file,
                        t[i].line,
                        "raw-tag-literal",
                        format!(
                            "tag-layout mask `{}` outside `next_coll_tag` — use the tag helpers/consts",
                            t[i].text
                        ),
                    );
                }
            }
        }
    }
}

/// Parse a numeric literal to a value (decimal or 0x/0b/0o), ignoring
/// `_` separators and type suffixes.
fn num_value(text: &str) -> Option<u64> {
    let s: String = text.chars().filter(|c| *c != '_').collect::<String>().to_ascii_lowercase();
    let (digits, radix) = if let Some(h) = s.strip_prefix("0x") {
        (h, 16)
    } else if let Some(b) = s.strip_prefix("0b") {
        (b, 2)
    } else if let Some(o) = s.strip_prefix("0o") {
        (o, 8)
    } else {
        (s.as_str(), 10)
    };
    let digits = digits.trim_end_matches(|c: char| c.is_ascii_alphabetic() && !(radix == 16 && c.is_ascii_hexdigit()));
    // strip usize/u64-style suffixes that survive the trim (e.g. "3u64"
    // trims to "3"; hex "ffu8" needs the explicit split below)
    let digits = split_suffix(digits, radix);
    u64::from_str_radix(digits, radix).ok()
}

/// Normalized hex form of a literal if it is hex (`0xFFF_FFFF_FFFF` ->
/// `"fffffffffff"`).
fn hex_norm(text: &str) -> Option<String> {
    let s: String = text.chars().filter(|c| *c != '_').collect::<String>().to_ascii_lowercase();
    let h = s.strip_prefix("0x")?;
    let h = split_suffix(h, 16);
    if h.is_empty() {
        return None;
    }
    Some(h.to_string())
}

/// Strip a trailing integer type suffix (`u8|u16|u32|u64|usize|i..`).
/// For hex this has to be explicit because `f`/`e` etc. are digits.
fn split_suffix(digits: &str, radix: u32) -> &str {
    for suf in ["u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize"] {
        if let Some(d) = digits.strip_suffix(suf) {
            // only treat as suffix when something is left and, for
            // non-hex, the remainder is all digits
            if !d.is_empty() && (radix == 16 || d.chars().all(|c| c.is_ascii_digit())) {
                return d;
            }
        }
    }
    digits
}

// ---------------------------------------------------------------------------
// hot-loop-clock
// ---------------------------------------------------------------------------

/// `Instant::now()` inside a loop in kernel/band-driver code: a clock
/// syscall per register tile or row band serializes the compute hot
/// path. Scope: files under `tensor/`, or fns whose name says they are
/// kernel/band/tile/matmul drivers. Timing at loop *boundaries* is
/// fine and common.
fn rule_hot_loop_clock(file: &str, lx: &Lexed, sc: &Scopes, out: &mut Vec<Finding>) {
    let t = &lx.toks;
    let hot_file = file.replace('\\', "/").contains("/tensor/");
    for i in 0..t.len() {
        if !(t[i].is_ident("Instant")
            && t.get(i + 1).map_or(false, |x| x.is(":"))
            && t.get(i + 2).map_or(false, |x| x.is(":"))
            && t.get(i + 3).map_or(false, |x| x.is_ident("now")))
        {
            continue;
        }
        let ctx = sc.ctx[i];
        if ctx.in_test || !ctx.in_loop {
            continue;
        }
        let hot_fn = ctx.fn_id.map_or(false, |f| {
            let n = sc.fns[f].name.to_ascii_lowercase();
            ["kernel", "band", "tile", "matmul"].iter().any(|k| n.contains(k))
        });
        if hot_file || hot_fn {
            push(
                out,
                file,
                t[i].line,
                "hot-loop-clock",
                "`Instant::now()` inside a kernel/band loop — hoist timing out of the tile loop"
                    .to_string(),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// pool-unpaired
// ---------------------------------------------------------------------------

/// Identifiers that return a taken pool buffer to circulation: the pool
/// itself (`put*`, `recycle`) or the fabric (a `send*` transfers
/// ownership to the receiver, which recycles on its own unwind path).
const POOL_RETURN: &[&str] = &["put", "put_u16", "recycle", "send", "send_bf16", "send_payload"];

/// Return types that mean the taken buffer (or a wrapper owning it)
/// escapes to the caller, which then owns the pairing obligation.
const POOL_ESCAPE_RET: &[&str] = &["Vec", "Tensor", "Bf16Tensor", "Self"];

/// A fn that calls `pool::take`/`take_u16` must either return the
/// buffer to circulation in the same fn (put/recycle/send) or hand
/// ownership out through its return type. Anything else leaks the
/// buffer on every early return and unwind (the PR-5 abort-leak class).
fn rule_pool_unpaired(file: &str, lx: &Lexed, sc: &Scopes, out: &mut Vec<Finding>) {
    let t = &lx.toks;
    for i in 0..t.len() {
        if !(t[i].is_ident("pool")
            && t.get(i + 1).map_or(false, |x| x.is(":"))
            && t.get(i + 2).map_or(false, |x| x.is(":"))
            && t.get(i + 3).map_or(false, |x| x.is_ident("take") || x.is_ident("take_u16"))
            && t.get(i + 4).map_or(false, |x| x.is("(")))
        {
            continue;
        }
        let ctx = sc.ctx[i];
        if ctx.in_test {
            continue;
        }
        let Some(fid) = ctx.fn_id else { continue };
        let f = &sc.fns[fid];
        let escapes = f.ret.iter().any(|r| POOL_ESCAPE_RET.contains(&r.as_str()));
        if escapes {
            continue;
        }
        let body = &t[f.body_start.min(t.len())..f.body_end.min(t.len())];
        let paired = body
            .iter()
            .any(|x| x.kind == TokKind::Ident && POOL_RETURN.contains(&x.text.as_str()));
        if !paired {
            push(
                out,
                file,
                t[i + 3].line,
                "pool-unpaired",
                format!(
                    "`pool::{}` in `{}` with no put/recycle/send and no ownership-escaping return — leaks on unwind",
                    t[i + 3].text, f.name
                ),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// lib-unwrap
// ---------------------------------------------------------------------------

/// `.unwrap()`/`.expect(..)` directly on a call to a known-fallible std
/// API, in non-test code. These must surface as typed errors — a panic
/// here tears down a rank and reads as a training bug instead of an
/// I/O/parse condition.
fn rule_lib_unwrap(file: &str, lx: &Lexed, sc: &Scopes, out: &mut Vec<Finding>) {
    let t = &lx.toks;
    for i in 0..t.len() {
        if !(t[i].is(".")
            && t.get(i + 1).map_or(false, |x| x.is_ident("unwrap") || x.is_ident("expect"))
            && t.get(i + 2).map_or(false, |x| x.is("(")))
        {
            continue;
        }
        if sc.ctx[i].in_test {
            continue;
        }
        // receiver must be `ident(...)` — find the call's `(` and the
        // name before it (skipping a turbofish)
        let Some(close) = i.checked_sub(1) else { continue };
        if !t[close].is(")") {
            continue;
        }
        let Some(open) = match_back(t, close, "(", ")") else { continue };
        let Some(mut j) = open.checked_sub(1) else { continue };
        if t[j].is(">") {
            // turbofish `parse::<u64>()` — skip back over `< .. >`
            let Some(lt) = match_back(t, j, "<", ">") else { continue };
            // expect `::` before the `<`
            if lt < 2 || !t[lt - 1].is(":") || !t[lt - 2].is(":") {
                continue;
            }
            let Some(k) = (lt - 2).checked_sub(1) else { continue };
            j = k;
        }
        if t[j].kind != TokKind::Ident || !RESULT_SET.contains(&t[j].text.as_str()) {
            continue;
        }
        push(
            out,
            file,
            t[i + 1].line,
            "lib-unwrap",
            format!(
                "`{}(..).{}(..)` in library code — propagate a typed error instead",
                t[j].text,
                t[i + 1].text
            ),
        );
    }
}

// ---------------------------------------------------------------------------
// wire-bytes-drift
// ---------------------------------------------------------------------------

/// Element byte widths whose product with `numel()` reads as a wire /
/// storage size derivation (f32=4, bf16=2, f64/u64=8, u8=1).
const ELEM_WIDTHS: &[u64] = &[1, 2, 4, 8];

/// The fabric charges every link through `Payload::wire_bytes`, and the
/// perfmodel prices the same traffic via the precision's
/// wire-bytes-per-elem. Two spellings let those accountings drift: a
/// hand-rolled `numel() * <elem width>` (either operand order) outside
/// the sanctioned helpers, and a shadow `enum Payload` outside `comm`
/// whose variants the byte helpers never learn about. Test code is
/// exempt — tests size buffers by hand on purpose.
fn rule_wire_bytes_drift(file: &str, lx: &Lexed, sc: &Scopes, out: &mut Vec<Finding>) {
    let t = &lx.toks;
    let in_comm = file.replace('\\', "/").contains("/comm/");
    let width = |text: &str| num_value(text).map_or(false, |v| ELEM_WIDTHS.contains(&v));
    for i in 0..t.len() {
        if sc.ctx[i].in_test {
            continue;
        }
        if t[i].is_ident("enum")
            && t.get(i + 1).map_or(false, |x| x.is_ident("Payload"))
            && !in_comm
        {
            push(
                out,
                file,
                t[i + 1].line,
                "wire-bytes-drift",
                "shadow `enum Payload` outside `comm` — its variants escape the wire-byte accounting"
                    .to_string(),
            );
        }
        if !(t[i].is_ident("numel")
            && t.get(i + 1).map_or(false, |x| x.is("("))
            && t.get(i + 2).map_or(false, |x| x.is(")")))
        {
            continue;
        }
        let sanctioned = sc.ctx[i].fn_id.map_or(false, |f| {
            matches!(sc.fns[f].name.as_str(), "wire_bytes" | "wire_bytes_per_elem")
        });
        if sanctioned {
            continue;
        }
        // forward form: `numel() * <width>`
        let fwd = t.get(i + 3).map_or(false, |x| x.is("*"))
            && t.get(i + 4).map_or(false, |x| x.kind == TokKind::Num && width(&x.text));
        // reverse form: `<width> * recv.chain.numel()` — walk back over
        // the `.`-separated receiver chain to the token before it
        let rev = {
            let mut j = i;
            while j >= 2 && t[j - 1].is(".") && t[j - 2].kind == TokKind::Ident {
                j -= 2;
            }
            j >= 2
                && t[j - 1].is("*")
                && t[j - 2].kind == TokKind::Num
                && width(&t[j - 2].text)
        };
        if fwd || rev {
            push(
                out,
                file,
                t[i].line,
                "wire-bytes-drift",
                "elem-width byte math on `numel()` outside `wire_bytes` — route sizing through the wire-byte helpers".to_string(),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(src: &str) -> Vec<Finding> {
        analyze_source("rust/src/some/mod.rs", src)
    }

    fn rules_of(f: &[Finding]) -> Vec<&'static str> {
        f.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn raw_lock_flags_outside_plock_only() {
        let f = run("fn plock(m: &M) -> G { m.lock().unwrap_or_else(PoisonError::into_inner) }\n\
                     fn good(m: &M) { let _g = plock(m); }\n\
                     fn bad(m: &M) { let _g = m.lock().unwrap(); }\n\
                     fn bad2(m: &M) { let _g = m.try_lock().expect(\"x\"); }");
        assert_eq!(rules_of(&f), vec!["raw-lock", "raw-lock"]);
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn raw_lock_applies_in_tests_too() {
        let f = run("#[cfg(test)] mod t { fn h(m: &M) { m.lock().unwrap(); } }");
        assert_eq!(rules_of(&f), vec!["raw-lock"]);
    }

    #[test]
    fn condvar_in_loop_ok_tail_wrapper_ok_bare_flagged() {
        let f = run(
            "fn looped(cv: &C, mut g: G) { while !*g { g = cv.wait(g).unwrap_or_else(e); } }\n\
             fn cv_wait(cv: &C, g: G) -> G { cv.wait(g).unwrap_or_else(e) }\n\
             fn caller(cv: &C, mut g: G) { loop { g = cv_wait(cv, g); } }\n\
             fn bare(cv: &C, g: G) { let _g = cv.wait(g).unwrap_or_else(e); let _x = 1; }",
        );
        assert_eq!(rules_of(&f), vec!["condvar-no-repredicate"]);
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn condvar_wrapper_called_outside_loop_flagged() {
        let f = run(
            "fn cv_wait(cv: &C, g: G) -> G { cv.wait(g).unwrap_or_else(e) }\n\
             fn caller(cv: &C, g: G) { let _g = cv_wait(cv, g); done(); }",
        );
        assert_eq!(rules_of(&f), vec!["condvar-no-repredicate"]);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn wait_while_and_non_condvar_receivers_exempt() {
        let f = run(
            "fn a(cv: &C, g: G) { let _g = cv.wait_while(g, |s| !*s); done(); }\n\
             fn b(rx: &R) { let _v = handle.wait(); done(); }",
        );
        assert!(f.is_empty());
    }

    #[test]
    fn raw_tag_shift_and_mask_flagged_outside_helper() {
        let f = run(
            "fn elsewhere(gh: u64, seq: u64) -> u64 { (1u64 << 63) | ((gh & 0x3_FFFF) << 44) | (seq & 0xFFF_FFFF_FFFF) }",
        );
        assert_eq!(rules_of(&f), vec!["raw-tag-literal"; 4]);
    }

    #[test]
    fn raw_tag_allowed_in_helper_consts_and_tests() {
        let f = run(
            "const COLLECTIVE_BIT: u64 = 1 << 63;\n\
             fn next_coll_tag(gh: u64, s: u64) -> u64 { ((gh & 0x3_FFFF) << 44) | s }\n\
             #[cfg(test)] mod t { fn mk() -> u64 { 1u64 << 62 } }",
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn hot_loop_clock_scoped_to_kernel_fns_and_tensor_files() {
        let f = run(
            "fn kernel_band(n: usize) { for _ in 0..n { let t = Instant::now(); work(t); } }\n\
             fn orchestrate(n: usize) { for _ in 0..n { let t = Instant::now(); work(t); } }\n\
             fn kernel_edge() { let t0 = Instant::now(); for _ in 0..9 { work(); } }",
        );
        assert_eq!(rules_of(&f), vec!["hot-loop-clock"]);
        assert_eq!(f[0].line, 1);
        let tensor = analyze_source(
            "rust/src/tensor/ops.rs",
            "fn anything(n: usize) { while n > 0 { let _ = Instant::now(); } }",
        );
        assert_eq!(rules_of(&tensor), vec!["hot-loop-clock"]);
    }

    #[test]
    fn pool_pairing_and_escapes() {
        let f = run(
            "fn leak(n: usize) { let b = pool::take(n); fill(&b); }\n\
             fn paired(n: usize) { let b = pool::take(n); pool::put(b); }\n\
             fn shipped(n: usize) { let b = pool::take(n); ep.send(1, tag, b); }\n\
             fn escapes(n: usize) -> Vec<f32> { pool::take(n) }",
        );
        assert_eq!(rules_of(&f), vec!["pool-unpaired"]);
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn lib_unwrap_on_result_set_only() {
        let f = run(
            "fn a(s: &str) -> u32 { s.parse().unwrap() }\n\
             fn b(s: &str) -> u32 { s.parse::<u32>().expect(\"num\") }\n\
             fn c(v: Vec<u8>) -> [u8; 4] { v.try_into().unwrap() }\n\
             fn d(h: std::thread::JoinHandle<()>) { h.join().unwrap(); }\n\
             fn e(o: Option<u32>) -> u32 { o.unwrap() }",
        );
        assert_eq!(rules_of(&f), vec!["lib-unwrap"; 3]);
        assert_eq!(f.iter().map(|x| x.line).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn wire_bytes_drift_flags_raw_byte_math_both_orders() {
        let f = run(
            "fn wire_bytes(t: &T) -> u64 { (t.numel() * 4) as u64 }\n\
             fn charge(t: &T) -> u64 { (t.numel() * 2) as u64 }\n\
             fn budget(p: &P) -> u64 { (4 * p.inner.numel()) as u64 }\n\
             fn fine(t: &T) -> usize { t.numel() * stride }\n\
             fn fine2(t: &T) -> usize { t.numel() * 3 }",
        );
        assert_eq!(rules_of(&f), vec!["wire-bytes-drift"; 2]);
        assert_eq!(f.iter().map(|x| x.line).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn wire_bytes_drift_flags_shadow_payload_enum_outside_comm() {
        let f = run("enum Payload { F32(A), Bf16(B) }");
        assert_eq!(rules_of(&f), vec!["wire-bytes-drift"]);
        let comm = analyze_source(
            "rust/src/comm/mod.rs",
            "enum Payload { F32(A), Bf16(B) }\n\
             impl Payload { fn wire_bytes(&self) -> u64 { (self.numel() * 4) as u64 } }",
        );
        assert!(comm.is_empty(), "{comm:?}");
    }

    #[test]
    fn wire_bytes_drift_exempts_tests_and_comparisons() {
        let f = run(
            "#[cfg(test)] mod t { fn sz(t: &T) -> u64 { (t.numel() * 4) as u64 } }\n\
             fn guard(t: &T, n: usize) -> bool { t.numel() < n * 4 }",
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn pragma_suppresses_same_line_and_next_line() {
        let f = run(
            "// vet: allow(lib-unwrap)\n\
             fn a(s: &str) -> u32 { s.parse().unwrap() }\n\
             fn b(m: &M) { m.lock().unwrap(); } // vet: allow(raw-lock)\n\
             fn c(s: &str) -> u32 { s.parse().unwrap() }",
        );
        assert_eq!(rules_of(&f), vec!["lib-unwrap"]);
        assert_eq!(f[0].line, 4);
    }
}
