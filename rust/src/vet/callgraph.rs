//! Cross-file lock-order analysis: the `lock-order` rule.
//!
//! The per-file rules in [`super::rules`] cannot see a two-lock
//! inversion split across two functions — `f` takes `waiters` and calls
//! `g`, `g` (another file) takes `queues`. This pass can: it builds a
//! per-function summary over the whole file set of (a) which named lock
//! classes the function acquires through `plock`/`plock_named` and (b)
//! which crate-local functions it calls, recording the guard classes
//! plausibly live at each site (a guard counts as live once bound with
//! `let` and until it is `drop`ped, its scope closes, or — for an
//! unbound temporary — its statement ends). A fixpoint propagates
//! "may-acquire" sets through the call graph, and every `(held,
//! acquired)` edge is checked against the declared hierarchy in
//! `rust/src/vet/lock_order.toml`; a back-edge is reported with the full
//! provenance chain that produces it.
//!
//! Honest limits, in the same spirit as the rest of `vet`: the walk is
//! linear, not path-sensitive — a conditional `drop(q)` kills the guard
//! for the remainder of the function (an under-approximation: it can
//! miss an order, never invent one), and callees are resolved by bare
//! name across the crate, with ubiquitous std/trait method names
//! excluded so `.clone()`/`.next()` chains don't smear summaries
//! together. The runtime lockdep witness (`util::lockdep`) covers the
//! orders this pass conservatively misses.

use std::collections::{BTreeMap, HashMap, HashSet};

use super::lexer::{analyze_scopes, lex, Tok, TokKind};
use super::rules::Finding;

/// The declared hierarchy shipped with the crate.
pub const DEFAULT_HIERARCHY: &str = include_str!("lock_order.toml");

/// A parsed lock hierarchy: per-domain ordered class lists.
#[derive(Debug)]
pub struct Hierarchy {
    /// class -> (domain index, rank within the domain)
    rank: HashMap<String, (usize, usize)>,
    /// domain name + its ordered classes, for diagnostics
    domains: Vec<(String, Vec<String>)>,
}

impl Hierarchy {
    /// Parse the `domain = "a < b < c"` format of `lock_order.toml`.
    /// Hand-rolled on purpose: the no-new-dependencies policy rules out
    /// a TOML crate, and the format needs exactly one line shape.
    pub fn parse(src: &str) -> Result<Hierarchy, String> {
        let mut rank = HashMap::new();
        let mut domains: Vec<(String, Vec<String>)> = Vec::new();
        for (idx, raw) in src.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((key, val)) = line.split_once('=') else {
                return Err(format!(
                    "lock hierarchy line {}: expected `domain = \"a < b\"`, got `{line}`",
                    idx + 1
                ));
            };
            let domain = key.trim().to_string();
            let classes: Vec<String> = val
                .trim()
                .trim_matches('"')
                .split('<')
                .map(|c| c.trim().to_string())
                .filter(|c| !c.is_empty())
                .collect();
            if domain.is_empty() || classes.is_empty() {
                return Err(format!("lock hierarchy line {}: empty domain or class list", idx + 1));
            }
            for (i, c) in classes.iter().enumerate() {
                if rank.insert(c.clone(), (domains.len(), i)).is_some() {
                    return Err(format!(
                        "lock hierarchy line {}: class `{c}` declared in two domains",
                        idx + 1
                    ));
                }
            }
            domains.push((domain, classes));
        }
        Ok(Hierarchy { rank, domains })
    }

    fn order_of(&self, class: &str) -> Option<(usize, usize)> {
        self.rank.get(class).copied()
    }

    fn domain_decl(&self, dom: usize) -> String {
        let (name, classes) = &self.domains[dom];
        format!("{name}: {}", classes.join(" < "))
    }
}

/// Callee names never resolved to crate functions: ubiquitous std/trait
/// method names that would smear unrelated summaries together (every
/// `.clone()` under a guard would otherwise merge with any crate fn
/// named `clone`), plus the atomics' `load`/`store`, which comm calls
/// under its guards and which collide with `config::load`.
const CALLEE_DENYLIST: &[&str] = &[
    "drop", "clone", "fmt", "eq", "ne", "cmp", "partial_cmp", "hash", "default", "next", "from",
    "into", "try_from", "try_into", "deref", "deref_mut", "index", "index_mut", "new", "as_ref",
    "as_mut", "to_string", "to_owned", "borrow", "borrow_mut", "load", "store",
];

/// Keywords that can precede a `(` without being a call.
const KEYWORDS: &[&str] = &[
    "if", "else", "while", "for", "loop", "match", "return", "break", "continue", "in", "as",
    "let", "fn", "impl", "struct", "enum", "mod", "use", "pub", "const", "static", "move", "ref",
    "mut", "where", "unsafe", "dyn", "self", "Self", "super", "crate", "true", "false", "type",
    "trait", "await", "async",
];

/// One lock acquisition observed in a function body.
struct Acq {
    class: String,
    file: String,
    line: u32,
    /// guard classes live at this point (deduped, acquisition order)
    held: Vec<String>,
}

/// One call to a (possibly) crate-local function.
struct Call {
    callee: String,
    file: String,
    line: u32,
    held: Vec<String>,
}

#[derive(Default)]
struct FnSummary {
    acqs: Vec<Acq>,
    calls: Vec<Call>,
}

/// A let-bound or temporary guard being tracked through the walk.
struct LiveGuard {
    /// `None` for an unbound temporary (dies at its statement's `;`)
    name: Option<String>,
    class: String,
    depth: i32,
}

fn held_classes(guards: &[LiveGuard]) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for g in guards {
        if !out.contains(&g.class) {
            out.push(g.class.clone());
        }
    }
    out
}

/// Extract the lock class from a `plock(...)`/`plock_named(...)` arg
/// list starting at the `(` at `open`: the last identifier of the first
/// argument's field path (`&self.inner.queues` -> `queues`). Returns the
/// class and the token index just past the closing `)`.
fn parse_plock_class(t: &[Tok], open: usize) -> (Option<String>, usize) {
    let mut depth = 0i32;
    let mut class: Option<String> = None;
    let mut i = open;
    while i < t.len() {
        let tok = &t[i];
        match tok.text.as_str() {
            "(" | "[" => depth += 1,
            ")" | "]" => {
                depth -= 1;
                if depth == 0 {
                    return (class.map(|c| c.to_ascii_lowercase()), i + 1);
                }
            }
            "," if depth == 1 => {
                // only the first argument names the mutex
                while i < t.len() {
                    match t[i].text.as_str() {
                        "(" | "[" => depth += 1,
                        ")" | "]" => {
                            depth -= 1;
                            if depth == 0 {
                                return (class.map(|c| c.to_ascii_lowercase()), i + 1);
                            }
                        }
                        _ => {}
                    }
                    i += 1;
                }
            }
            _ => {
                if tok.kind == TokKind::Ident && depth == 1 {
                    class = Some(tok.text.clone());
                }
            }
        }
        i += 1;
    }
    (class.map(|c| c.to_ascii_lowercase()), i)
}

/// Is the `plock` at token `i` bound to a name (`let q = plock(...)` /
/// `q = plock(...)`)? Skips a `crate::util::` path prefix first.
fn binding_name(t: &[Tok], i: usize) -> Option<String> {
    let mut j = i;
    // step back over `ident ::`* path segments
    while j >= 2 && t[j - 1].is(":") && t[j - 2].is(":") {
        j -= 2;
        if j >= 1 && t[j - 1].kind == TokKind::Ident {
            j -= 1;
        }
    }
    if j < 2 || !t[j - 1].is("=") {
        return None;
    }
    // `==`, `=>`, `+=` etc. are not plain assignment
    if t[j - 2].is("=") || t[j - 2].is("<") || t[j - 2].is(">") || t[j - 2].is("+")
        || t[j - 2].is("-") || t[j - 2].is("*") || t[j - 2].is("/") || t[j - 2].is("!")
    {
        return None;
    }
    let name = &t[j - 2];
    if name.kind == TokKind::Ident && !name.is("_") && !KEYWORDS.contains(&name.text.as_str()) {
        Some(name.text.clone())
    } else {
        None
    }
}

/// Walk one function body, collecting acquisitions and calls with the
/// guard set live at each site.
fn scan_fn(
    t: &[Tok],
    in_test: &[bool],
    fn_name: &str,
    body_start: usize,
    body_end: usize,
    file: &str,
    sum: &mut FnSummary,
) {
    let mut guards: Vec<LiveGuard> = Vec::new();
    let mut depth = 0i32;
    let mut i = body_start + 1;
    while i < body_end.min(t.len()) {
        let tok = &t[i];
        match tok.text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                guards.retain(|g| g.depth <= depth);
            }
            ";" => {
                let d = depth;
                guards.retain(|g| !(g.name.is_none() && g.depth == d));
            }
            _ => {}
        }
        // `drop(name)` kills the named guard for the rest of the walk
        // (linear, not path-sensitive: a conditional drop over-kills,
        // which can only hide an order, never invent one)
        if tok.is_ident("drop")
            && t.get(i + 1).map_or(false, |x| x.is("("))
            && t.get(i + 2).map_or(false, |x| x.kind == TokKind::Ident)
            && t.get(i + 3).map_or(false, |x| x.is(")"))
        {
            let victim = t[i + 2].text.clone();
            guards.retain(|g| g.name.as_deref() != Some(victim.as_str()));
            i += 4;
            continue;
        }
        let is_plock = tok.is_ident("plock") || tok.is_ident("plock_named");
        if tok.kind == TokKind::Ident
            && t.get(i + 1).map_or(false, |x| x.is("("))
            && !(i > 0 && t[i - 1].is_ident("fn"))
            && !in_test.get(i).copied().unwrap_or(false)
        {
            if is_plock {
                let (class, after) = parse_plock_class(t, i + 1);
                if let Some(class) = class {
                    sum.acqs.push(Acq {
                        class: class.clone(),
                        file: file.to_string(),
                        line: tok.line,
                        held: held_classes(&guards),
                    });
                    guards.push(LiveGuard {
                        name: binding_name(t, i),
                        class,
                        depth,
                    });
                }
                i = after;
                continue;
            }
            let name = tok.text.as_str();
            let starts_lower = name
                .chars()
                .next()
                .map_or(false, |c| c.is_ascii_lowercase() || c == '_');
            if starts_lower
                && !KEYWORDS.contains(&name)
                && !CALLEE_DENYLIST.contains(&name)
                // skip self-recursion: `Engine::send` calling `.send()`
                // on its channel would otherwise read as itself
                && name != fn_name
            {
                sum.calls.push(Call {
                    callee: name.to_string(),
                    file: file.to_string(),
                    line: tok.line,
                    held: held_classes(&guards),
                });
            }
        }
        i += 1;
    }
}

/// A lock-order edge: `to` may be acquired while `from` is held.
struct Edge {
    from: String,
    to: String,
    file: String,
    line: u32,
    why: String,
}

/// Run the cross-file lock-order analysis over `(file, source)` pairs
/// against `hier`. Findings are anchored at the edge's acquisition or
/// call site and honor the usual `// vet: allow(lock-order)` pragmas.
pub fn analyze_lock_order(files: &[(String, String)], hier: &Hierarchy) -> Vec<Finding> {
    // --- per-function summaries, merged by bare name across files ---
    let mut sums: BTreeMap<String, FnSummary> = BTreeMap::new();
    let mut allows: HashMap<String, HashMap<u32, Vec<String>>> = HashMap::new();
    for (file, src) in files {
        let lexed = lex(src);
        let scopes = analyze_scopes(&lexed.toks);
        let in_test: Vec<bool> = scopes.ctx.iter().map(|c| c.in_test).collect();
        for f in &scopes.fns {
            if f.body_start >= lexed.toks.len() {
                continue; // bodyless trait declaration
            }
            let sum = sums.entry(f.name.clone()).or_default();
            scan_fn(&lexed.toks, &in_test, &f.name, f.body_start, f.body_end, file, sum);
        }
        allows.insert(file.clone(), lexed.allows);
    }

    // --- fixpoint: may-acquire sets, with first-seen provenance ---
    // fn -> class -> how it gets there (chain text)
    let mut may: BTreeMap<String, BTreeMap<String, String>> = BTreeMap::new();
    for (name, sum) in &sums {
        let entry = may.entry(name.clone()).or_default();
        for a in &sum.acqs {
            entry.entry(a.class.clone()).or_insert_with(|| {
                format!("`{name}` acquires `{}` ({}:{})", a.class, a.file, a.line)
            });
        }
    }
    loop {
        let mut changed = false;
        for (name, sum) in &sums {
            for c in &sum.calls {
                let Some(callee_may) = may.get(&c.callee) else { continue };
                let additions: Vec<(String, String)> = callee_may
                    .iter()
                    .filter(|(class, _)| {
                        !may.get(name).map_or(false, |m| m.contains_key(*class))
                    })
                    .map(|(class, chain)| {
                        (
                            class.clone(),
                            format!("`{name}` calls `{}` ({}:{}) -> {chain}", c.callee, c.file, c.line),
                        )
                    })
                    .collect();
                if !additions.is_empty() {
                    let entry = may.entry(name.clone()).or_default();
                    for (class, chain) in additions {
                        entry.entry(class).or_insert(chain);
                        changed = true;
                    }
                }
            }
        }
        if !changed {
            break;
        }
    }

    // --- held-before edges: direct + through calls ---
    let mut edges: Vec<Edge> = Vec::new();
    for (name, sum) in &sums {
        for a in &sum.acqs {
            for h in &a.held {
                edges.push(Edge {
                    from: h.clone(),
                    to: a.class.clone(),
                    file: a.file.clone(),
                    line: a.line,
                    why: format!(
                        "`{name}` acquires `{}` while holding `{h}` ({}:{})",
                        a.class, a.file, a.line
                    ),
                });
            }
        }
        for c in &sum.calls {
            if c.held.is_empty() {
                continue;
            }
            let Some(callee_may) = may.get(&c.callee) else { continue };
            for (class, chain) in callee_may {
                for h in &c.held {
                    edges.push(Edge {
                        from: h.clone(),
                        to: class.clone(),
                        file: c.file.clone(),
                        line: c.line,
                        why: format!(
                            "`{name}` calls `{}` while holding `{h}` ({}:{}) -> {chain}",
                            c.callee, c.file, c.line
                        ),
                    });
                }
            }
        }
    }

    // --- check edges against the hierarchy ---
    let mut seen: HashSet<(String, String, String, u32)> = HashSet::new();
    let mut out: Vec<Finding> = Vec::new();
    for e in edges {
        let (Some((dom_f, rank_f)), Some((dom_t, rank_t))) =
            (hier.order_of(&e.from), hier.order_of(&e.to))
        else {
            continue; // classes outside the hierarchy are unconstrained
        };
        if dom_f != dom_t || rank_t > rank_f {
            continue; // cross-domain or forward edge: fine
        }
        if !seen.insert((e.from.clone(), e.to.clone(), e.file.clone(), e.line)) {
            continue;
        }
        let shape = if rank_t == rank_f { "re-acquires" } else { "inverts" };
        out.push(Finding {
            file: e.file,
            line: e.line,
            rule: "lock-order",
            message: format!(
                "acquiring `{}` while `{}` may be held {shape} the declared hierarchy ({}); {}",
                e.to,
                e.from,
                hier.domain_decl(dom_f),
                e.why
            ),
        });
    }

    // --- pragma suppression, per anchoring file ---
    out.retain(|f| {
        let Some(file_allows) = allows.get(&f.file) else { return true };
        for l in [f.line, f.line.saturating_sub(1)] {
            if let Some(rules) = file_allows.get(&l) {
                if rules.iter().any(|r| r == f.rule || r == "all") {
                    return false;
                }
            }
        }
        true
    });
    out.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hier() -> Hierarchy {
        Hierarchy::parse(DEFAULT_HIERARCHY).expect("shipped hierarchy parses")
    }

    fn run(files: &[(&str, &str)]) -> Vec<Finding> {
        let owned: Vec<(String, String)> =
            files.iter().map(|(f, s)| (f.to_string(), s.to_string())).collect();
        analyze_lock_order(&owned, &hier())
    }

    #[test]
    fn shipped_hierarchy_parses_and_orders_comm() {
        let h = hier();
        let q = h.order_of("queues").expect("queues declared");
        let w = h.order_of("waiters").expect("waiters declared");
        assert_eq!(q.0, w.0, "same domain");
        assert!(q.1 < w.1, "queues before waiters");
        assert!(h.order_of("nonexistent").is_none());
    }

    #[test]
    fn duplicate_class_across_domains_is_rejected() {
        let err = Hierarchy::parse("a = \"x < y\"\nb = \"y < z\"\n")
            .expect_err("duplicate class must be rejected");
        assert!(err.contains("`y`"), "{err}");
    }

    #[test]
    fn malformed_hierarchy_lines_are_rejected() {
        assert!(Hierarchy::parse("comm queues waiters\n").is_err());
        assert!(Hierarchy::parse("comm = \"\"\n").is_err());
    }

    #[test]
    fn direct_inversion_in_one_fn_fires() {
        let f = run(&[(
            "a.rs",
            "fn f(net: &Net) { let w = plock(&net.waiters); let _q = plock(&net.queues); drop(w); }",
        )]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "lock-order");
        assert!(f[0].message.contains("`queues`"), "{}", f[0].message);
        assert!(f[0].message.contains("`waiters`"), "{}", f[0].message);
    }

    #[test]
    fn cross_file_inversion_fires_with_chain() {
        let f = run(&[
            ("a.rs", "fn outer(net: &Net) { let w = plock(&net.waiters); refill(net); drop(w); }"),
            ("b.rs", "fn refill(net: &Net) { let _q = plock(&net.queues); }"),
        ]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].file, "a.rs");
        assert!(f[0].message.contains("`outer` calls `refill`"), "{}", f[0].message);
        assert!(f[0].message.contains("`refill` acquires `queues`"), "{}", f[0].message);
    }

    #[test]
    fn conforming_order_is_clean() {
        let f = run(&[
            ("a.rs", "fn outer(net: &Net) { let q = plock(&net.queues); register(net); drop(q); }"),
            ("b.rs", "fn register(net: &Net) { plock(&net.waiters).insert(1); }"),
        ]);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn dropped_guard_no_longer_holds() {
        let f = run(&[(
            "a.rs",
            "fn f(net: &Net) { let w = plock(&net.waiters); drop(w); let _q = plock(&net.queues); }",
        )]);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn scope_close_releases_guard() {
        let f = run(&[(
            "a.rs",
            "fn f(net: &Net) { { let _w = plock(&net.waiters); } let _q = plock(&net.queues); }",
        )]);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn temporary_guard_dies_at_statement_end() {
        let f = run(&[(
            "a.rs",
            "fn f(net: &Net) { plock(&net.waiters).remove(1); let _q = plock(&net.queues); }",
        )]);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn underscore_binding_is_a_temporary() {
        // `let _ = plock(..)` drops the guard immediately — Rust `_`
        // semantics — so nothing nests under it
        let f = run(&[(
            "a.rs",
            "fn f(net: &Net) { let _ = plock(&net.waiters); let _q = plock(&net.queues); }",
        )]);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn plock_named_classes_come_from_the_mutex_path() {
        let f = run(&[(
            "a.rs",
            "fn f(net: &Net) { let w = plock_named(&net.waiters, \"comm.waiters\"); \
             let _q = plock_named(&net.queues, \"comm.queues\"); drop(w); }",
        )]);
        assert_eq!(f.len(), 1, "{f:?}");
    }

    #[test]
    fn same_class_reacquire_is_reported() {
        let f = run(&[(
            "a.rs",
            "fn f(net: &Net) { let q = plock(&net.queues); let _q2 = plock(&net.queues); drop(q); }",
        )]);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("re-acquires"), "{}", f[0].message);
    }

    #[test]
    fn unknown_classes_are_unconstrained() {
        let f = run(&[(
            "a.rs",
            "fn f(s: &S) { let a = plock(&s.mystery); let _b = plock(&s.other); drop(a); }",
        )]);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn pragma_suppresses_lock_order() {
        let f = run(&[(
            "a.rs",
            "fn f(net: &Net) { let w = plock(&net.waiters);\n// vet: allow(lock-order)\nlet _q = plock(&net.queues); drop(w); }",
        )]);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn test_code_is_exempt() {
        let f = run(&[(
            "a.rs",
            "#[test] fn forced(net: &Net) { let w = plock(&net.waiters); let _q = plock(&net.queues); drop(w); }",
        )]);
        assert!(f.is_empty(), "{f:?}");
    }
}
