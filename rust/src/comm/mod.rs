//! Simulated NCCL/MPI: tagged point-to-point message passing between rank
//! threads plus the collectives jigsaw needs (allreduce, pairwise grad
//! reduce, barrier), with per-link byte accounting.
//!
//! The paper implements communication with MPI non-blocking point-to-point
//! operations (Section 5); here `send` is non-blocking (enqueue) and
//! `recv` blocks, which preserves the overlap structure: a rank posts its
//! outgoing partial sums, computes its local terms, then blocks on the
//! partner's message — the same isend/compute/wait pattern.
//!
//! Byte counters feed the perf model validation and the comm-volume
//! benches; timing at paper scale comes from `perfmodel`, not wallclock.
//!
//! Messages travel as `Arc<Tensor>`: a block fanned out to several
//! destinations is materialized once and reference-shared (the jigsaw
//! exchange path ships borrowed blocks without per-destination clones),
//! and a uniquely-owned message is recovered by the receiver without a
//! copy (`Arc::try_unwrap`).

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

use crate::tensor::Tensor;

type Key = (usize, usize, u64); // (src, dst, tag)

struct Shared {
    queues: Mutex<HashMap<Key, Vec<Arc<Tensor>>>>,
    cv: Condvar,
    /// bytes sent per (src, dst) link
    bytes: Mutex<Vec<u64>>,
    n: usize,
}

/// The in-process "fabric" connecting `n` ranks.
#[derive(Clone)]
pub struct Network {
    inner: Arc<Shared>,
}

impl Network {
    pub fn new(n: usize) -> Self {
        Network {
            inner: Arc::new(Shared {
                queues: Mutex::new(HashMap::new()),
                cv: Condvar::new(),
                bytes: Mutex::new(vec![0; n * n]),
                n,
            }),
        }
    }

    pub fn n_ranks(&self) -> usize {
        self.inner.n
    }

    /// Endpoint for one rank (hand one to each rank thread).
    pub fn endpoint(&self, rank: usize) -> Comm {
        assert!(rank < self.inner.n);
        Comm { rank, net: self.inner.clone(), coll_seq: 0 }
    }

    /// Total bytes sent over every link.
    pub fn total_bytes(&self) -> u64 {
        self.inner.bytes.lock().unwrap().iter().sum()
    }

    /// Bytes sent src -> dst.
    pub fn link_bytes(&self, src: usize, dst: usize) -> u64 {
        self.inner.bytes.lock().unwrap()[src * self.inner.n + dst]
    }

    pub fn reset_bytes(&self) {
        for b in self.inner.bytes.lock().unwrap().iter_mut() {
            *b = 0;
        }
    }
}

/// Per-rank communicator.
pub struct Comm {
    pub rank: usize,
    net: Arc<Shared>,
    /// local collective sequence number; all ranks must issue collectives
    /// in the same order (MPI semantics).
    coll_seq: u64,
}

/// Tag namespaces so user tags, collectives, and engine-internal messages
/// never collide.
const COLLECTIVE_BIT: u64 = 1 << 63;

impl Comm {
    pub fn n_ranks(&self) -> usize {
        self.net.n
    }

    /// Non-blocking send (isend): enqueues and returns.
    pub fn send(&self, dst: usize, tag: u64, t: Tensor) {
        self.send_shared(dst, tag, Arc::new(t));
    }

    /// Non-blocking send of a reference-shared tensor: fanning one block
    /// out to several destinations enqueues Arc clones, not data copies.
    pub fn send_shared(&self, dst: usize, tag: u64, t: Arc<Tensor>) {
        assert!(dst < self.net.n, "bad dst {dst}");
        assert!(dst != self.rank, "self-send rank {dst}");
        {
            let mut bytes = self.net.bytes.lock().unwrap();
            bytes[self.rank * self.net.n + dst] += (t.numel() * 4) as u64;
        }
        let mut q = self.net.queues.lock().unwrap();
        q.entry((self.rank, dst, tag)).or_default().push(t);
        self.net.cv.notify_all();
    }

    /// Blocking receive of a specific (src, tag) message. Zero-copy when
    /// the sender moved a uniquely-owned tensor in.
    pub fn recv(&self, src: usize, tag: u64) -> Tensor {
        match Arc::try_unwrap(self.recv_shared(src, tag)) {
            Ok(t) => t,
            Err(shared) => (*shared).clone(),
        }
    }

    /// Blocking receive returning the shared handle (read-only use, e.g.
    /// shipped stationary-operand blocks).
    pub fn recv_shared(&self, src: usize, tag: u64) -> Arc<Tensor> {
        let key = (src, self.rank, tag);
        let mut q = self.net.queues.lock().unwrap();
        loop {
            if let Some(list) = q.get_mut(&key) {
                if !list.is_empty() {
                    let t = list.remove(0);
                    if list.is_empty() {
                        q.remove(&key);
                    }
                    return t;
                }
            }
            q = self.net.cv.wait(q).unwrap();
        }
    }

    fn next_coll_tag(&mut self, group: &[usize]) -> u64 {
        // group identity folded into the tag so disjoint groups (e.g. the
        // paper's r%n DP groups) never cross-talk.
        let mut gh: u64 = 0xcbf29ce484222325;
        for &r in group {
            gh = (gh ^ r as u64).wrapping_mul(0x100000001b3);
        }
        // layout: [63]=collective  [62]=reply  [61:32]=group hash  [31:0]=seq
        let tag = COLLECTIVE_BIT
            | ((gh & 0x3FFF_FFFF) << 32)
            | (self.coll_seq & 0xFFFF_FFFF);
        self.coll_seq += 1;
        tag
    }

    /// Sum-allreduce across `group` (must contain self; all members call).
    ///
    /// Gather-to-root + broadcast: root = lowest rank in the group. The
    /// simulated fabric has no topology, so ring vs tree only matters to
    /// the perf model (which models a ring, Section `perfmodel`).
    pub fn allreduce_sum(&mut self, group: &[usize], t: &Tensor) -> Tensor {
        assert!(group.contains(&self.rank));
        if group.len() == 1 {
            return t.clone();
        }
        let tag = self.next_coll_tag(group);
        let root = *group.iter().min().unwrap();
        if self.rank == root {
            let mut acc = t.clone();
            for &r in group.iter().filter(|&&r| r != root) {
                let part = self.recv_shared(r, tag);
                crate::tensor::ops::add_assign(&mut acc, &part);
            }
            // broadcast one shared copy instead of cloning per peer
            let acc = Arc::new(acc);
            for &r in group.iter().filter(|&&r| r != root) {
                self.send_shared(r, tag | 1 << 62, acc.clone());
            }
            match Arc::try_unwrap(acc) {
                Ok(t) => t,
                Err(shared) => (*shared).clone(),
            }
        } else {
            self.send(root, tag, t.clone());
            self.recv(root, tag | 1 << 62)
        }
    }

    /// Scalar allreduce convenience (loss, grad-norm).
    pub fn allreduce_scalar(&mut self, group: &[usize], v: f32) -> f32 {
        self.allreduce_sum(group, &Tensor::scalar(v)).data[0]
    }

    /// Barrier across a group.
    pub fn barrier(&mut self, group: &[usize]) {
        let _ = self.allreduce_scalar(group, 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn point_to_point_delivers_in_order() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let h = thread::spawn(move || {
            a.send(1, 7, Tensor::scalar(1.0));
            a.send(1, 7, Tensor::scalar(2.0));
        });
        assert_eq!(b.recv(0, 7).data, vec![1.0]);
        assert_eq!(b.recv(0, 7).data, vec![2.0]);
        h.join().unwrap();
    }

    #[test]
    fn tags_do_not_cross() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        a.send(1, 1, Tensor::scalar(10.0));
        a.send(1, 2, Tensor::scalar(20.0));
        assert_eq!(b.recv(0, 2).data, vec![20.0]);
        assert_eq!(b.recv(0, 1).data, vec![10.0]);
    }

    #[test]
    fn allreduce_sums_over_group() {
        let net = Network::new(4);
        let group = vec![0, 1, 2, 3];
        let mut handles = Vec::new();
        for r in 0..4 {
            let mut c = net.endpoint(r);
            let g = group.clone();
            handles.push(thread::spawn(move || {
                let t = Tensor::new(vec![2], vec![r as f32, 1.0]);
                c.allreduce_sum(&g, &t).data
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![6.0, 4.0]);
        }
    }

    #[test]
    fn disjoint_groups_do_not_interfere() {
        // the paper's DP groups: ranks with equal r % n share parameters
        let net = Network::new(4);
        let mut handles = Vec::new();
        for r in 0..4 {
            let mut c = net.endpoint(r);
            let g = if r % 2 == 0 { vec![0, 2] } else { vec![1, 3] };
            handles.push(thread::spawn(move || {
                c.allreduce_scalar(&g, (r + 1) as f32)
            }));
        }
        let sums: Vec<f32> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(sums, vec![4.0, 6.0, 4.0, 6.0]); // {1+3}, {2+4}
    }

    #[test]
    fn byte_accounting() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        a.send(1, 0, Tensor::zeros(&[10, 10]));
        assert_eq!(net.link_bytes(0, 1), 400);
        assert_eq!(net.link_bytes(1, 0), 0);
        assert_eq!(net.total_bytes(), 400);
        net.reset_bytes();
        assert_eq!(net.total_bytes(), 0);
    }
}
