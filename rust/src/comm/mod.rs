//! Simulated NCCL/MPI: tagged point-to-point message passing between rank
//! threads plus the collectives jigsaw needs (ring allreduce, pairwise
//! grad reduce, barrier), with per-link byte accounting and an optional
//! fabric model that injects per-message latency/bandwidth delays.
//!
//! The paper implements communication with MPI non-blocking point-to-point
//! operations (Section 5). Here `send` is non-blocking (enqueue) and the
//! receive side offers the full non-blocking surface the ready-queue
//! schedules need:
//!
//!   * `recv`/`recv_shared` — blocking receive of a specific (src, tag);
//!   * `try_recv`/`try_recv_shared` — non-blocking poll (MPI `irecv` +
//!     `test`): returns `None` until the message has arrived;
//!   * `recv_any` — blocking poll over a *set* of (src, tag) keys (MPI
//!     `waitany`): returns whichever message lands first, which is what
//!     lets `dist_matmul` compute terms in arrival order instead of a
//!     fixed order.
//!
//! Collectives: `allreduce_sum` runs a ring reduce-scatter + allgather
//! (bandwidth-optimal, 2(n-1)/n of the payload per link — the schedule
//! `perfmodel` prices) for payloads worth chunking, and falls back to
//! gather-to-root for latency-bound scalars. Both variants are public so
//! benches and tests can compare them. `allreduce_start` is the
//! *in-flight* form: it returns a [`PackedAllreduce`] state machine
//! (same dispatch, same tags, same addition order — bit-identical
//! results) that callers `poll` between slabs of compute, so several
//! collectives can be outstanding at once; `wait_any_ready` parks a
//! thread until any of their next messages lands without consuming it.
//! This is the multi-bucket bookkeeping under the trainer's grad-ready
//! DP reduce.
//!
//! The [`ProgressEngine`] closes the gap between those poll points: it
//! is a per-rank registry of in-flight `PackedAllreduce` machines that
//! *any* code running on the owning rank thread can drive forward.
//! Installing an engine ([`ProgressEngine::install`]) points the kernel
//! driver's callback (`tensor::ops::set_driver_hook`) at it, after which
//! registered collectives advance while the rank waits at a blocked-
//! kernel row-band barrier, between register-tile row groups of the
//! serial kernels, and inside every blocking fabric wait (`recv`,
//! `recv_any`, `wait_any_ready`) — including the `dist_matmul`
//! ready-queue's dry-wait on a *different* fabric. Rings posted early in
//! the backward pass therefore make progress during every subsequent
//! matmul instead of only at the next gradient emission, and the
//! trainer's drain becomes a short tail. Hook-mode waits never park
//! unbounded: after running the hook (with the net lock released) they
//! re-probe under the lock before sleeping, and sleep at most one
//! `PROGRESS_TICK` — the hook's collectives may ride fabrics whose
//! deliveries do not signal this fabric's condvar, and a message that
//! lands while the hook runs has already spent its `notify_all`.
//!
//! Failure containment: `Network::abort` (or `abort_from`, which also
//! records the originating rank) flips the fabric into an aborted state
//! in which every blocking receive panics with a typed
//! [`CommError::Aborted`] payload instead of waiting forever — the
//! trainer downcasts that payload to tell peer-death casualties apart
//! from genuine bugs, and all comm locks are poison-tolerant so the
//! original failure stays readable.
//!
//! Byte counters feed the perf model validation and the comm-volume
//! benches. Wall-clock timing at paper scale comes from `perfmodel`; the
//! in-process fabric is instantaneous unless a `FabricSpec` is installed
//! (`Network::set_fabric`), which delays each message by latency + jitter
//! + bytes/bandwidth with per-endpoint link serialization — the
//! fault/latency injector behind the overlap benches and the
//! delivery-delay property tests.
//!
//! Messages travel as `Arc<Tensor>`: a block fanned out to several
//! destinations is materialized once and reference-shared (the jigsaw
//! exchange path ships borrowed blocks without per-destination clones),
//! and a uniquely-owned message is recovered by the receiver without a
//! copy (`Arc::try_unwrap`).
//!
//! The fabric is **precision-aware**: a message is a [`Payload`] — an
//! f32 tensor or a bf16 tensor ([`crate::tensor::Bf16Tensor`], u16
//! storage) — and the per-link byte counters charge the payload's
//! *actual* element size (4 or 2 bytes/elem), so a bf16 run's halved
//! fabric volume shows up in every byte stat without special-casing.
//! The collectives take a [`Precision`] policy (`allreduce_sum_prec`,
//! `allreduce_packed_prec`, `allreduce_start_prec`; the plain names
//! delegate with `F32` and stay bit-identical to the pre-precision
//! engine): under `Bf16` the ring's chunks are quantized
//! (round-to-nearest-even) onto the wire and accumulated in f32 on
//! arrival — and when a rank feeds its fully-reduced chunk into the
//! allgather it quantizes its *local* copy too, so every rank finishes
//! with bit-identical values (DP replicas must not drift). The
//! gather-to-root path stays f32: it only carries latency-bound scalar
//! payloads where halving bytes buys nothing.

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

use crate::tensor::{Bf16Tensor, Precision, Tensor};

type Key = (usize, usize, u64); // (src, dst, tag)

/// Upper bound on a blocking wait's sleep while a kernel-driver hook is
/// installed: the hook's collectives may ride other fabrics whose
/// deliveries do not signal this fabric's condvar, so hook-mode waits
/// wake on their own cadence to keep polling.
const PROGRESS_TICK: Duration = Duration::from_micros(100);

/// Display text of [`CommError::Aborted`] (kept as a constant so log
/// scrapers and older tests keep matching). Classification no longer
/// goes through this string: blocking receives raise a typed
/// [`CommError`] panic payload, and the trainer downcasts it.
pub const FABRIC_ABORTED: &str = "comm: fabric aborted (a peer rank failed)";

/// Typed failure raised by fabric operations. Blocking receives unwound
/// by [`Network::abort`] carry this as their panic payload
/// (`panic_any`), so the recovery loop can tell a peer-death casualty
/// apart from a genuine bug by downcast instead of panic-string
/// matching.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CommError {
    /// The fabric was aborted because a peer rank died. `rank` names the
    /// rank that originated the abort when the aborter recorded it via
    /// [`Network::abort_from`]; `None` for an anonymous abort.
    Aborted { rank: Option<usize> },
    /// The wait-graph deadlock detector proved that a set of blocked
    /// waits can never be satisfied (every member is parked on an empty
    /// queue whose source is itself a member). `desc` names the full
    /// knot — each rank and the (src, tag) keys it is waiting on — so a
    /// would-be CI timeout reads as a diagnosis instead.
    Deadlock { desc: String },
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Aborted { rank: Some(r) } => {
                write!(f, "{FABRIC_ABORTED} (origin rank {r})")
            }
            CommError::Aborted { rank: None } => write!(f, "{FABRIC_ABORTED}"),
            CommError::Deadlock { desc } => write!(f, "comm: deadlock detected — {desc}"),
        }
    }
}

impl std::error::Error for CommError {}

impl CommError {
    /// Recover the typed error from a caught panic payload (the shape
    /// `catch_unwind` hands back). `None` for any other panic.
    pub fn from_panic(p: &(dyn std::any::Any + Send)) -> Option<CommError> {
        p.downcast_ref::<CommError>().cloned()
    }
}

use crate::util::{plock, plock_named};

/// Queues-lock guard type: every acquisition of `Shared::queues` goes
/// through [`plock_named`] so the runtime lock-order witness
/// ([`crate::util::lockdep`]) sees it, and the condvar re-acquisition
/// helpers thread the same guard type through
/// [`PlockGuard::map`](crate::util::PlockGuard::map) — the lock class
/// stays held across a wait, which is what the thread observably does.
type QueueGuard<'a> = crate::util::PlockGuard<'a, HashMap<Key, VecDeque<Msg>>>;

/// What a fabric message carries: an f32 tensor or a bf16 tensor. The
/// payload's element kind decides the wire bytes charged to the link —
/// f32 messages cost 4 bytes/elem, bf16 messages 2.
#[derive(Clone)]
pub enum Payload {
    F32(Arc<Tensor>),
    Bf16(Arc<Bf16Tensor>),
}

impl Payload {
    pub fn numel(&self) -> usize {
        match self {
            Payload::F32(t) => t.numel(),
            Payload::Bf16(t) => t.numel(),
        }
    }

    /// Bytes this payload occupies on the wire.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Payload::F32(t) => (t.numel() * 4) as u64,
            Payload::Bf16(t) => (t.numel() * 2) as u64,
        }
    }

    /// Widen to a shared f32 tensor: f32 payloads pass through untouched,
    /// bf16 payloads expand into a pooled f32 buffer (the receive-side
    /// unpack of the mixed-precision fabric), returning the u16 buffer to
    /// the pool when this was the last reference.
    pub fn widen(self) -> Arc<Tensor> {
        match self {
            Payload::F32(t) => t,
            Payload::Bf16(b) => {
                let t = b.to_tensor();
                if let Ok(bt) = Arc::try_unwrap(b) {
                    bt.recycle();
                }
                Arc::new(t)
            }
        }
    }

    fn expect_f32(self) -> Arc<Tensor> {
        match self {
            Payload::F32(t) => t,
            Payload::Bf16(_) => panic!("comm: bf16 payload on an f32 receive"),
        }
    }

    fn expect_bf16(self) -> Arc<Bf16Tensor> {
        match self {
            Payload::Bf16(t) => t,
            Payload::F32(_) => panic!("comm: f32 payload on a bf16 receive"),
        }
    }
}

/// One in-flight message. `ready_at` is `None` on the instantaneous
/// fabric; under a `FabricSpec` it is the simulated delivery time and the
/// receive side withholds the message until then.
struct Msg {
    p: Payload,
    ready_at: Option<Instant>,
}

impl Msg {
    fn deliverable(&self, now: Instant) -> bool {
        self.ready_at.map_or(true, |r| r <= now)
    }
}

/// Injected fabric timing: every message is delayed by
/// `latency + U[0, jitter) + bytes / bytes_per_sec`, and transfers
/// serialize on the sender's egress and the receiver's ingress link
/// (latency pipelines; occupancy does not) — enough structure to make
/// gather-to-root pay its root bottleneck and a fixed-order receive pay
/// for out-of-order arrivals.
#[derive(Clone, Copy, Debug)]
pub struct FabricSpec {
    pub latency: Duration,
    /// per-message uniform jitter added to `latency` (seeded, so delivery
    /// reorderings reproduce)
    pub jitter: Duration,
    pub bytes_per_sec: f64,
}

impl FabricSpec {
    /// Convenience constructor in the units benchmarks and the CLI use:
    /// microseconds of base latency and jitter, gigabytes per second of
    /// link bandwidth.
    pub fn from_us(latency_us: u64, jitter_us: u64, gbps: f64) -> Self {
        FabricSpec {
            latency: Duration::from_micros(latency_us),
            jitter: Duration::from_micros(jitter_us),
            bytes_per_sec: gbps * 1e9,
        }
    }
}

struct FabricState {
    spec: FabricSpec,
    /// when each rank's egress link frees up
    egress_free: Vec<Instant>,
    /// when each rank's ingress link frees up
    ingress_free: Vec<Instant>,
    /// xorshift state for the jitter draw
    rng: u64,
}

struct Shared {
    queues: Mutex<HashMap<Key, VecDeque<Msg>>>,
    cv: Condvar,
    /// bytes sent per (src, dst) link
    bytes: Mutex<Vec<u64>>,
    /// deepest any per-key queue has grown (receive-side backlog stat)
    max_depth: AtomicU64,
    fabric: Mutex<Option<FabricState>>,
    /// set by [`Network::abort`]: blocking receives panic instead of
    /// waiting forever for a peer that died
    aborted: AtomicBool,
    /// rank that originated the abort (`usize::MAX` = none recorded);
    /// first writer wins, so casualties that re-abort after unwinding
    /// never overwrite the true failer
    abort_rank: AtomicUsize,
    /// wait-graph deadlock detector enabled? One relaxed load per
    /// blocking wait when off (see [`Network::set_deadlock_detect`]).
    detect: AtomicBool,
    /// rank -> the keys its blocking wait is currently parked on. Every
    /// access happens while `queues` is held (lock order: queues, then
    /// waiters), so a checker can never observe "message consumed but
    /// waiter still registered" or vice versa.
    waiters: Mutex<HashMap<usize, Waiting>>,
    /// knot description recorded by the first detector trip; every
    /// sleeper woken by its `notify_all` re-raises it
    deadlock: Mutex<Option<String>>,
    deadlocked: AtomicBool,
    n: usize,
}

/// One registered blocking wait (see `Shared::waiters`).
struct Waiting {
    keys: Vec<(usize, u64)>,
    /// waits that run a kernel-driver hook can consume and send traffic
    /// while "blocked", so the knot check must treat them as able to
    /// make progress on their own (conservative: a knot hiding behind a
    /// hooked waiter goes undetected rather than ever false-firing)
    hooked: bool,
}

/// Removes the rank's `waiters` entry on every exit from a blocking
/// wait — normal returns (while the queues lock is still held, keeping
/// the registry coherent with message consumption) and unwinds alike.
struct WaiterGuard<'a> {
    net: Option<&'a Shared>,
    rank: usize,
}

impl Drop for WaiterGuard<'_> {
    fn drop(&mut self) {
        if let Some(net) = self.net {
            plock_named(&net.waiters, "comm.waiters").remove(&self.rank);
        }
    }
}

/// Process-wide override for the deadlock detector's default state:
/// 0 = none (env / build profile decides), 1 = force off, 2 = force on.
/// Tests use [`set_deadlock_detect_default`] to pin either way
/// regardless of profile.
static DETECT_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Pin (or release, with `None`) the default detector state for
/// networks created after this call. Per-network
/// [`Network::set_deadlock_detect`] still wins on individual fabrics.
pub fn set_deadlock_detect_default(v: Option<bool>) {
    DETECT_OVERRIDE.store(match v { None => 0, Some(false) => 1, Some(true) => 2 }, Ordering::SeqCst);
}

/// Default detector state for a fresh [`Network`]: process override,
/// else `JIGSAW_DEADLOCK_DETECT` (`0`/`off`/`false` disable, anything
/// else enables), else on in debug builds (= `cargo test`) and off in
/// release.
fn deadlock_detect_default() -> bool {
    match DETECT_OVERRIDE.load(Ordering::SeqCst) {
        1 => false,
        2 => true,
        _ => match std::env::var("JIGSAW_DEADLOCK_DETECT") {
            Ok(v) => !matches!(v.as_str(), "0" | "off" | "false" | ""),
            Err(_) => cfg!(debug_assertions),
        },
    }
}

/// The in-process "fabric" connecting `n` ranks.
#[derive(Clone)]
pub struct Network {
    inner: Arc<Shared>,
}

impl Network {
    pub fn new(n: usize) -> Self {
        Network {
            inner: Arc::new(Shared {
                queues: Mutex::new(HashMap::new()),
                cv: Condvar::new(),
                bytes: Mutex::new(vec![0; n * n]),
                max_depth: AtomicU64::new(0),
                fabric: Mutex::new(None),
                aborted: AtomicBool::new(false),
                abort_rank: AtomicUsize::new(usize::MAX),
                detect: AtomicBool::new(deadlock_detect_default()),
                waiters: Mutex::new(HashMap::new()),
                deadlock: Mutex::new(None),
                deadlocked: AtomicBool::new(false),
                n,
            }),
        }
    }

    pub fn n_ranks(&self) -> usize {
        self.inner.n
    }

    /// Endpoint for one rank (hand one to each rank thread).
    pub fn endpoint(&self, rank: usize) -> Comm {
        assert!(rank < self.inner.n);
        Comm { rank, net: self.inner.clone(), coll_seq: HashMap::new() }
    }

    /// Install the delay injector: subsequent sends acquire simulated
    /// delivery times. `seed` drives the per-message jitter draw.
    pub fn set_fabric(&self, spec: FabricSpec, seed: u64) {
        let now = Instant::now();
        *plock_named(&self.inner.fabric, "comm.fabric") = Some(FabricState {
            spec,
            egress_free: vec![now; self.inner.n],
            ingress_free: vec![now; self.inner.n],
            rng: seed | 1,
        });
    }

    /// Remove the delay injector (messages deliver instantly again).
    pub fn clear_fabric(&self) {
        *plock_named(&self.inner.fabric, "comm.fabric") = None;
    }

    /// Abort the fabric: every rank currently (or subsequently) blocked
    /// in a receive panics with a [`CommError::Aborted`] payload instead
    /// of waiting forever for a peer that died. Called by the trainer
    /// when a rank thread fails, so the surviving ranks unwind and
    /// `train()` can report *which* rank failed rather than deadlocking
    /// in its join loop.
    pub fn abort(&self) {
        self.abort_impl(None);
    }

    /// Like [`abort`](Network::abort), but records `rank` as the origin
    /// of the failure. The first recorded origin sticks (casualties that
    /// re-abort while unwinding don't overwrite the true failer), and
    /// subsequent aborted receives carry it in their
    /// [`CommError::Aborted`] payload.
    pub fn abort_from(&self, rank: usize) {
        self.abort_impl(Some(rank));
    }

    fn abort_impl(&self, rank: Option<usize>) {
        if let Some(r) = rank {
            let _ = self.inner.abort_rank.compare_exchange(
                usize::MAX,
                r,
                Ordering::SeqCst,
                Ordering::SeqCst,
            );
        }
        // take the queue lock so the flag flip and the wake-up are
        // ordered against sleeping receivers
        let _q = plock_named(&self.inner.queues, "comm.queues");
        self.inner.aborted.store(true, Ordering::SeqCst);
        self.inner.cv.notify_all();
    }

    /// Whether [`abort`](Network::abort) has been called.
    pub fn is_aborted(&self) -> bool {
        self.inner.aborted.load(Ordering::SeqCst)
    }

    /// Enable/disable the wait-graph deadlock detector on this fabric.
    /// When on, every blocking wait registers the (src, tag) keys it
    /// parks on; before sleeping, the waiter runs a greatest-fixpoint
    /// "knot" check over the who-waits-on-whom graph and a provable
    /// cycle panics immediately with [`CommError::Deadlock`] naming
    /// every member — instead of hanging the run until a CI timeout.
    /// When off, the cost is one relaxed atomic load per blocking wait.
    ///
    /// Soundness rests on the SPMD usage this crate holds everywhere: a
    /// rank's traffic originates from its own (single) thread, so a
    /// registered waiter with no queued message on any key, all of
    /// whose sources are themselves knot members, can never be woken.
    /// Hook-running waits (an installed [`ProgressEngine`] can consume
    /// and send while "blocked") are conservatively treated as live.
    pub fn set_deadlock_detect(&self, on: bool) {
        self.inner.detect.store(on, Ordering::Relaxed);
    }

    /// Whether the wait-graph deadlock detector is currently on.
    pub fn deadlock_detect_enabled(&self) -> bool {
        self.inner.detect.load(Ordering::Relaxed)
    }

    /// The knot description recorded by a detector trip, if one fired
    /// on this fabric.
    pub fn deadlock_info(&self) -> Option<String> {
        plock_named(&self.inner.deadlock, "comm.deadlock").clone()
    }

    /// The rank recorded as the abort's origin, if any.
    pub fn abort_origin(&self) -> Option<usize> {
        let r = self.inner.abort_rank.load(Ordering::SeqCst);
        if r == usize::MAX { None } else { Some(r) }
    }

    /// Total bytes sent over every link.
    pub fn total_bytes(&self) -> u64 {
        plock_named(&self.inner.bytes, "comm.bytes").iter().sum()
    }

    /// Bytes sent src -> dst.
    pub fn link_bytes(&self, src: usize, dst: usize) -> u64 {
        plock_named(&self.inner.bytes, "comm.bytes")[src * self.inner.n + dst]
    }

    /// Deepest backlog any (src, dst, tag) queue reached — how far sends
    /// ran ahead of receives (benches record this alongside timings).
    pub fn max_queue_depth(&self) -> u64 {
        self.inner.max_depth.load(Ordering::Relaxed)
    }

    pub fn reset_bytes(&self) {
        for b in plock_named(&self.inner.bytes, "comm.bytes").iter_mut() {
            *b = 0;
        }
        self.inner.max_depth.store(0, Ordering::Relaxed);
    }
}

/// Per-rank communicator.
pub struct Comm {
    pub rank: usize,
    net: Arc<Shared>,
    /// per-group collective sequence numbers (keyed by group hash): the
    /// members of a group must issue its collectives in the same order,
    /// but collectives on *different* groups may interleave freely —
    /// which lets e.g. the bucketed replicated-grad sync visit each
    /// rank's own sync groups without global coordination.
    coll_seq: HashMap<u64, u64>,
}

/// Tag namespaces so user tags, collectives, and engine-internal messages
/// never collide.
const COLLECTIVE_BIT: u64 = 1 << 63;
/// Reply / second-phase leg of a collective (root broadcast, ring
/// allgather): keeps both directions of one collective on distinct keys.
const REPLY_BIT: u64 = 1 << 62;

impl Comm {
    pub fn n_ranks(&self) -> usize {
        self.net.n
    }

    /// Non-blocking send (isend): enqueues and returns.
    pub fn send(&self, dst: usize, tag: u64, t: Tensor) {
        self.send_shared(dst, tag, Arc::new(t));
    }

    /// Non-blocking send of a reference-shared tensor: fanning one block
    /// out to several destinations enqueues Arc clones, not data copies.
    pub fn send_shared(&self, dst: usize, tag: u64, t: Arc<Tensor>) {
        self.send_payload(dst, tag, Payload::F32(t));
    }

    /// Non-blocking send of an owned bf16 tensor (2 bytes/elem on the
    /// wire and in the link byte stats).
    pub fn send_bf16(&self, dst: usize, tag: u64, t: Bf16Tensor) {
        self.send_payload(dst, tag, Payload::Bf16(Arc::new(t)));
    }

    /// Non-blocking send of a reference-shared bf16 tensor.
    pub fn send_bf16_shared(&self, dst: usize, tag: u64, t: Arc<Bf16Tensor>) {
        self.send_payload(dst, tag, Payload::Bf16(t));
    }

    /// Payload-generic send core: link byte accounting (at the payload's
    /// actual element size), fabric delivery-time modelling, enqueue.
    pub fn send_payload(&self, dst: usize, tag: u64, p: Payload) {
        assert!(dst < self.net.n, "bad dst {dst}");
        assert!(dst != self.rank, "self-send rank {dst}");
        let bytes = p.wire_bytes();
        {
            let mut b = plock_named(&self.net.bytes, "comm.bytes");
            b[self.rank * self.net.n + dst] += bytes;
        }
        // simulated delivery time, when the injector is installed
        let ready_at = {
            let mut fab = plock_named(&self.net.fabric, "comm.fabric");
            fab.as_mut().map(|f| {
                let now = Instant::now();
                let start = now.max(f.egress_free[self.rank]).max(f.ingress_free[dst]);
                let xfer = Duration::from_secs_f64(bytes as f64 / f.spec.bytes_per_sec);
                let busy = start + xfer;
                f.egress_free[self.rank] = busy;
                f.ingress_free[dst] = busy;
                // xorshift64 jitter draw
                f.rng ^= f.rng << 13;
                f.rng ^= f.rng >> 7;
                f.rng ^= f.rng << 17;
                let frac = (f.rng >> 11) as f64 / (1u64 << 53) as f64;
                busy + f.spec.latency + f.spec.jitter.mul_f64(frac)
            })
        };
        let mut q = plock_named(&self.net.queues, "comm.queues");
        let list = q.entry((self.rank, dst, tag)).or_default();
        list.push_back(Msg { p, ready_at });
        self.net
            .max_depth
            .fetch_max(list.len() as u64, Ordering::Relaxed);
        self.net.cv.notify_all();
    }

    /// Blocking receive of a specific (src, tag) message. Zero-copy when
    /// the sender moved a uniquely-owned tensor in.
    pub fn recv(&self, src: usize, tag: u64) -> Tensor {
        match Arc::try_unwrap(self.recv_shared(src, tag)) {
            Ok(t) => t,
            Err(shared) => (*shared).clone(),
        }
    }

    /// Blocking receive returning the shared handle (read-only use, e.g.
    /// shipped stationary-operand blocks).
    pub fn recv_shared(&self, src: usize, tag: u64) -> Arc<Tensor> {
        self.await_any(&[(src, tag)], true).unwrap().1.expect_f32()
    }

    /// Blocking receive of a bf16 message from (src, tag).
    pub fn recv_bf16(&self, src: usize, tag: u64) -> Bf16Tensor {
        let shared = self.await_any(&[(src, tag)], true).unwrap().1.expect_bf16();
        match Arc::try_unwrap(shared) {
            Ok(t) => t,
            Err(shared) => (*shared).clone(),
        }
    }

    /// The shared blocking-wait core behind [`recv`](Comm::recv),
    /// [`recv_any`](Comm::recv_any), and
    /// [`wait_any_ready`](Comm::wait_any_ready): park until one of
    /// `keys` = [(src, tag), ..] has a deliverable message. With `take`
    /// the winning message is consumed and returned; without, it stays
    /// queued (MPI `Probe`) and the return is `None`.
    ///
    /// When a kernel-driver hook is installed
    /// (`tensor::ops::set_driver_hook` — the [`ProgressEngine`]'s poll
    /// path), the wait drives it instead of parking cold: probe, run the
    /// hook with the net lock *released* (its collectives may ride this
    /// very fabric), then **re-probe under the lock before any sleep**.
    /// The re-probe is load-bearing: a message delivered while the hook
    /// ran has already fired its `notify_all` at a moment nobody was on
    /// the condvar, so parking without re-probing would strand this
    /// thread until an unrelated notification — the missed-wakeup window
    /// `wait_does_not_strand_when_delivery_lands_during_hook` pins.
    /// Hook-mode sleeps are additionally bounded by [`PROGRESS_TICK`].
    fn await_any(&self, keys: &[(usize, u64)], take: bool) -> Option<(usize, Payload)> {
        assert!(!keys.is_empty(), "blocking wait over an empty key set");
        // set when the hook already ran since the last probe: the next
        // pass may sleep instead of ticking again
        let mut just_ticked = false;
        let detect = self.net.detect.load(Ordering::Relaxed);
        let mut q = plock_named(&self.net.queues, "comm.queues");
        if detect {
            // register under the queues lock so the registry is always
            // coherent with the queue contents a checker snapshots
            plock_named(&self.net.waiters, "comm.waiters").insert(
                self.rank,
                Waiting {
                    keys: keys.to_vec(),
                    hooked: crate::tensor::ops::driver_hook_installed(),
                },
            );
        }
        // declared after `q`, so on normal returns it drops first —
        // i.e. while the queues lock is still held
        let _unreg = WaiterGuard { net: detect.then_some(&*self.net), rank: self.rank };
        loop {
            if self.net.aborted.load(Ordering::SeqCst) {
                let origin = {
                    let r = self.net.abort_rank.load(Ordering::SeqCst);
                    if r == usize::MAX { None } else { Some(r) }
                };
                drop(q);
                std::panic::panic_any(CommError::Aborted { rank: origin });
            }
            if detect && self.net.deadlocked.load(Ordering::SeqCst) {
                // another waiter proved the knot; re-raise it here so
                // every member unwinds instead of sleeping forever
                let desc = plock_named(&self.net.deadlock, "comm.deadlock")
                    .clone()
                    .unwrap_or_else(|| "wait-graph knot".to_string());
                drop(q);
                std::panic::panic_any(CommError::Deadlock { desc });
            }
            let now = Instant::now();
            let mut next_ready: Option<Duration> = None;
            for (i, &(src, tag)) in keys.iter().enumerate() {
                let key = (src, self.rank, tag);
                if let Some(list) = q.get_mut(&key) {
                    if let Some(head) = list.front() {
                        if head.deliverable(now) {
                            if !take {
                                return None;
                            }
                            let msg = list.pop_front().unwrap();
                            if list.is_empty() {
                                q.remove(&key);
                            }
                            return Some((i, msg.p));
                        }
                        let d = head.ready_at.unwrap().saturating_duration_since(now);
                        next_ready = Some(next_ready.map_or(d, |c| c.min(d)));
                    }
                }
            }
            if crate::tensor::ops::driver_hook_installed() {
                if !just_ticked {
                    drop(q);
                    let progressed = crate::tensor::ops::driver_tick();
                    q = plock_named(&self.net.queues, "comm.queues");
                    if progressed && !take {
                        // the hook may have CONSUMED a message for one of
                        // `keys` (a drain waits on exactly the keys the
                        // installed engine polls, on this very fabric) and
                        // advanced or completed that machine — the
                        // caller's key snapshot is stale, and parking on
                        // it would hang forever once no more traffic
                        // targets those keys. A probe-style wait treats
                        // hook progress as a wake: return so the caller
                        // re-derives its key set.
                        return None;
                    }
                    // while the hook advances its collectives, stay hot
                    // (probe -> tick -> probe); once it runs dry, the
                    // next pass probes and then sleeps one tick
                    just_ticked = !progressed;
                    continue;
                }
                if detect {
                    q = self.check_deadlock(q);
                }
                let d = next_ready.map_or(PROGRESS_TICK, |d| d.min(PROGRESS_TICK));
                q = self.cv_wait_timeout(q, d);
                just_ticked = false;
            } else {
                if detect {
                    q = self.check_deadlock(q);
                }
                q = match next_ready {
                    Some(d) => self.cv_wait_timeout(q, d),
                    None => self.cv_wait(q),
                };
            }
        }
    }

    /// The wait-graph knot check, run before a registered waiter
    /// sleeps. Over the snapshot the held queues lock pins, compute the
    /// greatest fixpoint of "cannot possibly be woken": start from
    /// every registered non-hooked waiter and repeatedly remove any
    /// rank that has a queued message on one of its keys (deliverable
    /// or merely delayed — a `FabricSpec` send enqueues immediately, so
    /// in-flight traffic counts as progress) or a key whose source is
    /// not itself stuck. A nonempty fixpoint is a true deadlock: every
    /// member waits only on empty queues fed exclusively by other
    /// members, and (per the SPMD single-thread-per-rank contract) no
    /// one else can ever fill them. Panics with
    /// [`CommError::Deadlock`] naming the whole knot after waking every
    /// peer; returns the guard unchanged otherwise.
    fn check_deadlock<'a>(&self, q: QueueGuard<'a>) -> QueueGuard<'a> {
        let desc = {
            let waiters = plock_named(&self.net.waiters, "comm.waiters");
            let mut stuck: Vec<usize> = waiters
                .iter()
                .filter(|(_, w)| !w.hooked)
                .map(|(&r, _)| r)
                .collect();
            loop {
                let before = stuck.len();
                let cur: std::collections::HashSet<usize> = stuck.iter().copied().collect();
                stuck.retain(|&r| {
                    waiters[&r].keys.iter().all(|&(src, tag)| {
                        cur.contains(&src) && q.get(&(src, r, tag)).map_or(true, |l| l.is_empty())
                    })
                });
                if stuck.len() == before {
                    break;
                }
            }
            if stuck.is_empty() {
                return q;
            }
            stuck.sort_unstable();
            let parts: Vec<String> = stuck
                .iter()
                .map(|&r| {
                    let keys: Vec<String> = waiters[&r]
                        .keys
                        .iter()
                        .map(|&(s, t)| format!("src {s} tag {t:#x}"))
                        .collect();
                    format!("rank {r} waiting on [{}]", keys.join(", "))
                })
                .collect();
            format!("wait-graph knot: {}", parts.join("; "))
        };
        *plock_named(&self.net.deadlock, "comm.deadlock") = Some(desc.clone());
        self.net.deadlocked.store(true, Ordering::SeqCst);
        self.net.cv.notify_all();
        drop(q);
        std::panic::panic_any(CommError::Deadlock { desc });
    }

    /// Poison-tolerant condvar wait (see [`plock`]). The lockdep class
    /// rides through `PlockGuard::map` — a condvar wait re-acquires
    /// before returning, so the class genuinely stays held.
    fn cv_wait<'a>(&self, q: QueueGuard<'a>) -> QueueGuard<'a> {
        q.map(|g| self.net.cv.wait(g).unwrap_or_else(PoisonError::into_inner))
    }

    /// Poison-tolerant condvar timed wait (see [`plock`]).
    fn cv_wait_timeout<'a>(&self, q: QueueGuard<'a>, d: Duration) -> QueueGuard<'a> {
        q.map(|g| self.net.cv.wait_timeout(g, d).unwrap_or_else(PoisonError::into_inner).0)
    }

    /// Non-blocking payload receive (irecv + test): `None` until the
    /// message from (src, tag) has arrived. Delivery stays in send order
    /// per key.
    pub fn try_recv_payload(&self, src: usize, tag: u64) -> Option<Payload> {
        let key = (src, self.rank, tag);
        let mut q = plock_named(&self.net.queues, "comm.queues");
        let now = Instant::now();
        if let Some(list) = q.get_mut(&key) {
            if list.front().map_or(false, |m| m.deliverable(now)) {
                let msg = list.pop_front().unwrap();
                if list.is_empty() {
                    q.remove(&key);
                }
                return Some(msg.p);
            }
        }
        None
    }

    /// Non-blocking f32 receive returning the shared handle.
    pub fn try_recv_shared(&self, src: usize, tag: u64) -> Option<Arc<Tensor>> {
        self.try_recv_payload(src, tag).map(Payload::expect_f32)
    }

    /// Non-blocking owned bf16 receive.
    pub fn try_recv_bf16(&self, src: usize, tag: u64) -> Option<Bf16Tensor> {
        self.try_recv_payload(src, tag).map(|p| {
            match Arc::try_unwrap(p.expect_bf16()) {
                Ok(t) => t,
                Err(shared) => (*shared).clone(),
            }
        })
    }

    /// Non-blocking owned receive.
    pub fn try_recv(&self, src: usize, tag: u64) -> Option<Tensor> {
        self.try_recv_shared(src, tag).map(|a| match Arc::try_unwrap(a) {
            Ok(t) => t,
            Err(shared) => (*shared).clone(),
        })
    }

    /// Non-blocking poll over a key set (testany): the first key with a
    /// deliverable message wins. One lock acquisition for the whole set —
    /// the ready-queue scheduler's per-term probe.
    pub fn try_recv_any_payload(&self, keys: &[(usize, u64)]) -> Option<(usize, Payload)> {
        let mut q = plock_named(&self.net.queues, "comm.queues");
        let now = Instant::now();
        for (i, &(src, tag)) in keys.iter().enumerate() {
            let key = (src, self.rank, tag);
            if let Some(list) = q.get_mut(&key) {
                if list.front().map_or(false, |m| m.deliverable(now)) {
                    let msg = list.pop_front().unwrap();
                    if list.is_empty() {
                        q.remove(&key);
                    }
                    return Some((i, msg.p));
                }
            }
        }
        None
    }

    /// [`try_recv_any_payload`](Comm::try_recv_any_payload) for f32-only
    /// protocols.
    pub fn try_recv_any(&self, keys: &[(usize, u64)]) -> Option<(usize, Arc<Tensor>)> {
        self.try_recv_any_payload(keys).map(|(i, p)| (i, p.expect_f32()))
    }

    /// Blocking receive of *whichever* of `keys` = [(src, tag), ..]
    /// arrives first (MPI waitany). Returns the index into `keys` and the
    /// message. Ready-queue schedules use this to take work in arrival
    /// order once local compute runs dry — and, with a [`ProgressEngine`]
    /// installed, the wait doubles as a poll point for in-flight
    /// collectives on other fabrics (the `dist_matmul` dry-wait hook).
    pub fn recv_any_payload(&self, keys: &[(usize, u64)]) -> (usize, Payload) {
        self.await_any(keys, true).unwrap()
    }

    /// [`recv_any_payload`](Comm::recv_any_payload) for f32-only
    /// protocols.
    pub fn recv_any(&self, keys: &[(usize, u64)]) -> (usize, Arc<Tensor>) {
        let (i, p) = self.await_any(keys, true).unwrap();
        (i, p.expect_f32())
    }

    /// Block until one of `keys` = [(src, tag), ..] has a deliverable
    /// message, *without* consuming it (MPI `Probe` over a key set).
    /// The in-flight collective drain loops use this to sleep
    /// efficiently between polls: the message stays queued so the
    /// owning state machine's next `poll` pops it itself.
    ///
    /// With a driver hook installed this may also return because the
    /// hook made progress (it can consume the awaited messages itself —
    /// the drain's keys are exactly what the installed engine polls), so
    /// callers must re-derive their key set and re-poll after every
    /// return rather than assume a `keys` message is queued.
    pub fn wait_any_ready(&self, keys: &[(usize, u64)]) {
        let _ = self.await_any(keys, false);
    }

    fn next_coll_tag(&mut self, group: &[usize]) -> u64 {
        // group identity folded into the tag so disjoint groups (e.g. the
        // paper's r%n DP groups) never cross-talk.
        let gh = group_hash(group);
        // layout: [63]=collective  [62]=reply  [61:44]=18-bit group hash
        // [43:0]=seq XOR the hash's high bits. The counter is u64 and the
        // tag keeps 44 bits of it: the old 32-bit field silently collided
        // with a still-in-flight tag after ~4.3e9 collectives per group
        // (hours on a long run); 2^44 is centuries at the same rate. The
        // XOR keeps per-group tags unique (bijective in seq) while giving
        // colliding 18-bit hashes extra discrimination.
        let seq = self.coll_seq.entry(gh).or_insert(0);
        let tag = COLLECTIVE_BIT
            | ((gh & 0x3_FFFF) << 44)
            | ((*seq ^ (gh >> 18)) & 0xFFF_FFFF_FFFF);
        *seq = seq.wrapping_add(1);
        tag
    }

    /// Sum-allreduce across `group` (must contain self; all members call
    /// with the same group in the same order).
    ///
    /// Dispatch: payloads worth chunking run the bandwidth-optimal ring
    /// (`allreduce_sum_ring`); scalars and other latency-bound messages
    /// take the two-hop gather-to-root path (`allreduce_sum_gather`) —
    /// the same small-message switch real collective libraries make.
    pub fn allreduce_sum(&mut self, group: &[usize], t: &Tensor) -> Tensor {
        self.allreduce_sum_prec(group, t, Precision::F32)
    }

    /// [`allreduce_sum`](Comm::allreduce_sum) under a wire-precision
    /// policy. `Bf16` applies to the ring path only (chunks quantized on
    /// the wire, f32 accumulation on arrival); the gather path carries
    /// latency-bound scalars where halving bytes buys nothing, so it
    /// stays f32 under either policy.
    pub fn allreduce_sum_prec(
        &mut self,
        group: &[usize],
        t: &Tensor,
        prec: Precision,
    ) -> Tensor {
        assert!(group.contains(&self.rank), "allreduce group excludes self");
        if group.len() == 1 {
            return t.clone();
        }
        if t.numel() < group.len() * 4 {
            self.allreduce_sum_gather(group, t)
        } else {
            self.allreduce_sum_ring_prec(group, t, prec)
        }
    }

    /// Gather-to-root + broadcast allreduce: root = lowest rank in the
    /// group. Two message hops total — best for tiny payloads, but the
    /// root's links serialize O(n) full-size transfers.
    pub fn allreduce_sum_gather(&mut self, group: &[usize], t: &Tensor) -> Tensor {
        assert!(group.contains(&self.rank));
        if group.len() == 1 {
            return t.clone();
        }
        let tag = self.next_coll_tag(group);
        let root = *group.iter().min().unwrap();
        if self.rank == root {
            let mut acc = t.clone();
            for &r in group.iter().filter(|&&r| r != root) {
                let part = self.recv_shared(r, tag);
                crate::tensor::ops::add_assign(&mut acc, &part);
            }
            // broadcast one shared copy instead of cloning per peer
            let acc = Arc::new(acc);
            for &r in group.iter().filter(|&&r| r != root) {
                self.send_shared(r, tag | REPLY_BIT, acc.clone());
            }
            match Arc::try_unwrap(acc) {
                Ok(t) => t,
                Err(shared) => (*shared).clone(),
            }
        } else {
            self.send(root, tag, t.clone());
            self.recv(root, tag | REPLY_BIT)
        }
    }

    /// Ring allreduce: reduce-scatter then allgather, 2(n-1) steps of
    /// payload/n each, so every link carries 2(n-1)/n of the payload —
    /// the collective `perfmodel` prices for the DP gradient reduction.
    /// Chunk messages ride pooled buffers; the reduction is in place over
    /// slices of one working copy.
    pub fn allreduce_sum_ring(&mut self, group: &[usize], t: &Tensor) -> Tensor {
        self.allreduce_sum_ring_prec(group, t, Precision::F32)
    }

    /// Ring allreduce under a wire-precision policy. Under `Bf16` every
    /// chunk crosses the fabric as u16 (half the bytes), arrivals
    /// accumulate in f32, and a rank entering the allgather quantizes its
    /// own fully-reduced chunk *in place* before shipping it — a peer
    /// installs the quantized values, so without the local quantize the
    /// owner would end the collective holding different bits than
    /// everyone else (fatal for DP replicas that must stay in lockstep).
    pub fn allreduce_sum_ring_prec(
        &mut self,
        group: &[usize],
        t: &Tensor,
        prec: Precision,
    ) -> Tensor {
        assert!(group.contains(&self.rank));
        let n = group.len();
        if n == 1 {
            return t.clone();
        }
        let tag = self.next_coll_tag(group);
        let p = group.iter().position(|&r| r == self.rank).unwrap();
        let right = group[(p + 1) % n];
        let left = group[(p + n - 1) % n];
        let bounds = ring_bounds(t.numel(), n);
        let send_chunk = |me: &Comm, idx: usize, data: &[f32], tag: u64| {
            ring_send_chunk_prec(me, right, &bounds, idx, data, tag, prec);
        };
        let mut out = t.clone();
        // reduce-scatter: after n-1 steps this rank holds the fully
        // reduced chunk (p+1) % n
        for step in 0..n - 1 {
            let sc = (p + n - step) % n;
            let rc = (p + n - step - 1) % n;
            send_chunk(self, sc, &out.data, tag);
            let (lo, hi) = bounds[rc];
            match prec {
                Precision::F32 => {
                    let got = self.recv(left, tag);
                    debug_assert_eq!(got.numel(), hi - lo);
                    for (o, g) in out.data[lo..hi].iter_mut().zip(got.data.iter()) {
                        *o += *g;
                    }
                    got.recycle();
                }
                Precision::Bf16 => {
                    let got = self.recv_bf16(left, tag);
                    debug_assert_eq!(got.numel(), hi - lo);
                    got.add_into(&mut out.data[lo..hi]);
                    got.recycle();
                }
            }
        }
        // the owner's reduced chunk enters the allgather exactly as the
        // peers will see it (see the doc comment)
        if prec == Precision::Bf16 {
            let (lo, hi) = bounds[(p + 1) % n];
            crate::tensor::bf16::quantize_slice(&mut out.data[lo..hi]);
        }
        // allgather: cascade the reduced chunks around the ring
        for step in 0..n - 1 {
            let sc = (p + 1 + n - step) % n;
            let rc = (p + n - step) % n;
            send_chunk(self, sc, &out.data, tag | REPLY_BIT);
            let (lo, hi) = bounds[rc];
            match prec {
                Precision::F32 => {
                    let got = self.recv(left, tag | REPLY_BIT);
                    debug_assert_eq!(got.numel(), hi - lo);
                    out.data[lo..hi].copy_from_slice(&got.data);
                    got.recycle();
                }
                Precision::Bf16 => {
                    let got = self.recv_bf16(left, tag | REPLY_BIT);
                    debug_assert_eq!(got.numel(), hi - lo);
                    got.copy_into(&mut out.data[lo..hi]);
                    got.recycle();
                }
            }
        }
        out
    }

    /// Allreduce a set of tensors as one packed payload: pack flat (via a
    /// pooled buffer) -> a single collective -> unpack in place. The
    /// bucketing primitive behind the DP gradient reduction and the
    /// replicated-vector grad sync; all group members must pass tensors
    /// of identical shapes in identical order.
    pub fn allreduce_packed(&mut self, group: &[usize], tensors: &mut [&mut Tensor]) {
        self.allreduce_packed_prec(group, tensors, Precision::F32);
    }

    /// [`allreduce_packed`](Comm::allreduce_packed) under a
    /// wire-precision policy (the pack buffer stays f32; quantization
    /// happens at ring-chunk granularity inside the collective).
    pub fn allreduce_packed_prec(
        &mut self,
        group: &[usize],
        tensors: &mut [&mut Tensor],
        prec: Precision,
    ) {
        if group.len() <= 1 || tensors.is_empty() {
            return;
        }
        let total: usize = tensors.iter().map(|t| t.numel()).sum();
        let mut flat = crate::tensor::pool::take(total);
        let mut off = 0usize;
        for t in tensors.iter() {
            flat[off..off + t.numel()].copy_from_slice(&t.data);
            off += t.numel();
        }
        let packed = Tensor::new(vec![total], flat);
        let reduced = self.allreduce_sum_prec(group, &packed, prec);
        packed.recycle();
        let mut off = 0usize;
        for t in tensors.iter_mut() {
            let n = t.numel();
            t.data.copy_from_slice(&reduced.data[off..off + n]);
            off += n;
        }
        reduced.recycle();
    }

    /// Scalar allreduce convenience (loss, grad-norm).
    pub fn allreduce_scalar(&mut self, group: &[usize], v: f32) -> f32 {
        self.allreduce_sum(group, &Tensor::scalar(v)).data[0]
    }

    /// Barrier across a group.
    pub fn barrier(&mut self, group: &[usize]) {
        let _ = self.allreduce_scalar(group, 0.0);
    }

    /// Begin a non-blocking allreduce of an owned payload over `group`:
    /// the in-flight form of [`allreduce_packed`], returned as a
    /// [`PackedAllreduce`] state machine that is driven forward by
    /// `poll` and finished by `wait`/`take`.
    ///
    /// Dispatch (ring vs gather-to-root), tag sequencing, chunk bounds,
    /// and — crucially — the order of floating-point additions are
    /// *identical* to the blocking [`allreduce_sum`], so a payload
    /// reduced through a handle is bit-for-bit what the blocking
    /// collective would produce regardless of delivery timing. That is
    /// the property the grad-ready DP reduce's oracle tests pin.
    ///
    /// Several handles may be in flight at once (multi-bucket
    /// bookkeeping rides the per-group tag/seq machinery); all group
    /// members must start them in the same order.
    pub fn allreduce_start(&mut self, group: &[usize], t: Tensor) -> PackedAllreduce {
        self.allreduce_start_prec(group, t, Precision::F32)
    }

    /// [`allreduce_start`](Comm::allreduce_start) under a wire-precision
    /// policy: the in-flight ring ships and receives chunks at `prec`
    /// with exactly the quantization points of
    /// [`allreduce_sum_ring_prec`](Comm::allreduce_sum_ring_prec), so
    /// the two stay bit-identical at either precision.
    pub fn allreduce_start_prec(
        &mut self,
        group: &[usize],
        t: Tensor,
        prec: Precision,
    ) -> PackedAllreduce {
        assert!(group.contains(&self.rank), "allreduce group excludes self");
        if group.len() <= 1 {
            return PackedAllreduce { state: CollState::Done(t) };
        }
        let tag = self.next_coll_tag(group);
        let n = group.len();
        if t.numel() < n * 4 {
            // latency-bound payloads: two-hop gather-to-root
            let root = *group.iter().min().unwrap();
            if self.rank == root {
                let peers: Vec<usize> =
                    group.iter().copied().filter(|&r| r != root).collect();
                PackedAllreduce {
                    state: CollState::GatherRoot { out: t, peers, idx: 0, tag },
                }
            } else {
                self.send(root, tag, t);
                PackedAllreduce { state: CollState::GatherLeaf { root, tag } }
            }
        } else {
            let p = group.iter().position(|&r| r == self.rank).unwrap();
            let right = group[(p + 1) % n];
            let left = group[(p + n - 1) % n];
            let bounds = ring_bounds(t.numel(), n);
            // reduce-scatter step 0 ships this rank's own chunk
            ring_send_chunk_prec(self, right, &bounds, p, &t.data, tag, prec);
            PackedAllreduce {
                state: CollState::Ring {
                    out: t,
                    bounds,
                    left,
                    right,
                    p,
                    n,
                    tag,
                    prec,
                    allgather: false,
                    step: 0,
                },
            }
        }
    }
}

/// FNV-1a fold of a collective group's membership: the per-group key of
/// the tag-sequence counters (full 64 bits) and the tag's group field
/// (truncated). Identical on every member because groups are passed in
/// identical order.
fn group_hash(group: &[usize]) -> u64 {
    let mut gh: u64 = 0xcbf29ce484222325;
    for &r in group {
        gh = (gh ^ r as u64).wrapping_mul(0x100000001b3);
    }
    gh
}

/// Balanced ring chunk bounds, identical on every rank (shared by the
/// blocking ring and the in-flight state machine so the two can never
/// disagree on the schedule).
fn ring_bounds(numel: usize, n: usize) -> Vec<(usize, usize)> {
    (0..n)
        .map(|i| {
            let (q, r) = (numel / n, numel % n);
            let lo = i * q + i.min(r);
            (lo, lo + q + usize::from(i < r))
        })
        .collect()
}

/// Ship ring chunk `idx` of `data` to `dst` on a pooled buffer, packed
/// at the wire precision (f32 copy, or bf16 quantize into a u16 buffer).
fn ring_send_chunk_prec(
    comm: &Comm,
    dst: usize,
    bounds: &[(usize, usize)],
    idx: usize,
    data: &[f32],
    tag: u64,
    prec: Precision,
) {
    let (lo, hi) = bounds[idx];
    match prec {
        Precision::F32 => {
            let mut buf = crate::tensor::pool::take(hi - lo);
            buf.copy_from_slice(&data[lo..hi]);
            comm.send(dst, tag, Tensor::new(vec![hi - lo], buf));
        }
        Precision::Bf16 => {
            comm.send_bf16(dst, tag, Bf16Tensor::from_f32(&[hi - lo], &data[lo..hi]));
        }
    }
}

/// Accumulate a payload into `dst` in f32 — the shared reduce-scatter
/// arrival step of the blocking and in-flight rings, and the partial-sum
/// reduction step of the jigsaw schedules — recycling the source buffer.
pub fn payload_add_into(dst: &mut [f32], p: Payload) {
    match p {
        Payload::F32(g) => {
            debug_assert_eq!(g.numel(), dst.len());
            for (o, v) in dst.iter_mut().zip(g.data.iter()) {
                *o += *v;
            }
            if let Ok(t) = Arc::try_unwrap(g) {
                t.recycle();
            }
        }
        Payload::Bf16(g) => {
            debug_assert_eq!(g.numel(), dst.len());
            g.add_into(dst);
            if let Ok(t) = Arc::try_unwrap(g) {
                t.recycle();
            }
        }
    }
}

/// Install a ring-chunk payload into `dst` (the allgather arrival step),
/// recycling the chunk's buffer.
fn payload_copy_into(dst: &mut [f32], p: Payload) {
    match p {
        Payload::F32(g) => {
            debug_assert_eq!(g.numel(), dst.len());
            dst.copy_from_slice(&g.data);
            if let Ok(t) = Arc::try_unwrap(g) {
                t.recycle();
            }
        }
        Payload::Bf16(g) => {
            debug_assert_eq!(g.numel(), dst.len());
            g.copy_into(dst);
            if let Ok(t) = Arc::try_unwrap(g) {
                t.recycle();
            }
        }
    }
}

/// One in-flight packed allreduce (see [`Comm::allreduce_start`]).
/// `poll` consumes whatever messages have arrived and immediately posts
/// the sends they unlock; it never blocks, so a caller can keep many
/// collectives in flight and make progress on each between slabs of
/// compute — the shape the grad-ready DP gradient scheduler needs.
pub struct PackedAllreduce {
    state: CollState,
}

enum CollState {
    /// ring reduce-scatter (+ allgather once `allgather` flips)
    Ring {
        out: Tensor,
        bounds: Vec<(usize, usize)>,
        left: usize,
        right: usize,
        p: usize,
        n: usize,
        tag: u64,
        prec: Precision,
        allgather: bool,
        step: usize,
    },
    /// gather root: receive peers *in group order* (the blocking
    /// collective's addition order), then broadcast
    GatherRoot { out: Tensor, peers: Vec<usize>, idx: usize, tag: u64 },
    /// gather leaf: payload sent at start, waiting for the root's reply
    GatherLeaf { root: usize, tag: u64 },
    Done(Tensor),
    /// payload moved out by `take` — also what `Drop` leaves behind
    /// after recycling whatever the machine still held
    Taken,
}

impl PackedAllreduce {
    /// Whether the reduced payload is ready to `take`.
    pub fn is_done(&self) -> bool {
        matches!(self.state, CollState::Done(_))
    }

    /// The (src, tag) key this machine is currently waiting on (`None`
    /// once done) — feed the keys of all in-flight collectives to
    /// [`Comm::wait_any_ready`] to sleep between polls.
    pub fn awaited(&self) -> Option<(usize, u64)> {
        match &self.state {
            CollState::Ring { left, tag, allgather, .. } => {
                Some((*left, if *allgather { *tag | REPLY_BIT } else { *tag }))
            }
            CollState::GatherRoot { peers, idx, tag, .. } => {
                peers.get(*idx).map(|&r| (r, *tag))
            }
            CollState::GatherLeaf { root, tag } => Some((*root, *tag | REPLY_BIT)),
            CollState::Done(_) | CollState::Taken => None,
        }
    }

    /// Drive the machine as far as already-arrived messages allow.
    /// Returns `true` if any message was consumed. Never blocks.
    pub fn poll(&mut self, comm: &Comm) -> bool {
        let mut progress = false;
        let mut finished: Option<Tensor> = None;
        match &mut self.state {
            CollState::Done(_) | CollState::Taken => {}
            CollState::Ring {
                out, bounds, left, right, p, n, tag, prec, allgather, step,
            } => {
                loop {
                    let rtag = if *allgather { *tag | REPLY_BIT } else { *tag };
                    let Some(got) = comm.try_recv_payload(*left, rtag) else { break };
                    progress = true;
                    if !*allgather {
                        // reduce-scatter: add the arriving chunk, then
                        // forward the freshly reduced one
                        let rc = (*p + *n - *step - 1) % *n;
                        let (lo, hi) = bounds[rc];
                        payload_add_into(&mut out.data[lo..hi], got);
                        *step += 1;
                        if *step < *n - 1 {
                            let sc = (*p + *n - *step) % *n;
                            ring_send_chunk_prec(
                                comm, *right, bounds, sc, &out.data, *tag, *prec,
                            );
                        } else {
                            *allgather = true;
                            *step = 0;
                            let sc = (*p + 1) % *n;
                            // same local quantize as the blocking ring:
                            // the owner must hold its reduced chunk
                            // exactly as the peers will install it
                            if *prec == Precision::Bf16 {
                                let (lo, hi) = bounds[sc];
                                crate::tensor::bf16::quantize_slice(
                                    &mut out.data[lo..hi],
                                );
                            }
                            ring_send_chunk_prec(
                                comm,
                                *right,
                                bounds,
                                sc,
                                &out.data,
                                *tag | REPLY_BIT,
                                *prec,
                            );
                        }
                    } else {
                        // allgather: install the cascaded chunk, forward it
                        let rc = (*p + *n - *step) % *n;
                        let (lo, hi) = bounds[rc];
                        payload_copy_into(&mut out.data[lo..hi], got);
                        *step += 1;
                        if *step < *n - 1 {
                            let sc = (*p + 1 + *n - *step) % *n;
                            ring_send_chunk_prec(
                                comm,
                                *right,
                                bounds,
                                sc,
                                &out.data,
                                *tag | REPLY_BIT,
                                *prec,
                            );
                        } else {
                            finished =
                                Some(std::mem::replace(out, Tensor::scalar(0.0)));
                            break;
                        }
                    }
                }
            }
            CollState::GatherRoot { out, peers, idx, tag } => {
                // strictly in-order receives preserve the blocking
                // collective's addition order (bit-identity)
                while *idx < peers.len() {
                    let Some(part) = comm.try_recv_shared(peers[*idx], *tag) else {
                        break;
                    };
                    crate::tensor::ops::add_assign(out, &part);
                    *idx += 1;
                    progress = true;
                }
                if *idx == peers.len() {
                    let acc =
                        Arc::new(std::mem::replace(out, Tensor::scalar(0.0)));
                    for &r in peers.iter() {
                        comm.send_shared(r, *tag | REPLY_BIT, acc.clone());
                    }
                    finished = Some(match Arc::try_unwrap(acc) {
                        Ok(t) => t,
                        Err(shared) => (*shared).clone(),
                    });
                }
            }
            CollState::GatherLeaf { root, tag } => {
                if let Some(t) = comm.try_recv(*root, *tag | REPLY_BIT) {
                    progress = true;
                    finished = Some(t);
                }
            }
        }
        if let Some(t) = finished {
            self.state = CollState::Done(t);
        }
        progress
    }

    /// Block until the collective completes and return the reduced
    /// payload. (Per-handle convenience; multi-bucket callers poll and
    /// sleep on `wait_any_ready` across all handles instead.)
    pub fn wait(mut self, comm: &Comm) -> Tensor {
        loop {
            self.poll(comm);
            if self.is_done() {
                return self.take();
            }
            if let Some(key) = self.awaited() {
                comm.wait_any_ready(&[key]);
            }
        }
    }

    /// Take the reduced payload out of a completed collective.
    pub fn take(mut self) -> Tensor {
        match std::mem::replace(&mut self.state, CollState::Taken) {
            CollState::Done(t) => t,
            _ => panic!("PackedAllreduce::take before completion"),
        }
    }
}

impl Drop for PackedAllreduce {
    /// A machine dropped mid-flight (a rank aborting on
    /// [`CommError::Aborted`] unwinds its scheduler with buckets still
    /// ringing) returns its
    /// working payload to the tensor pool instead of freeing it, so an
    /// injected rank failure does not degrade the survivor's (or a
    /// restarted step's) steady-state pool behaviour.
    fn drop(&mut self) {
        match std::mem::replace(&mut self.state, CollState::Taken) {
            CollState::Ring { out, .. } => out.recycle(),
            CollState::GatherRoot { out, .. } => out.recycle(),
            CollState::Done(t) => t.recycle(),
            CollState::GatherLeaf { .. } | CollState::Taken => {}
        }
    }
}

// ---------------------------------------------------------------------------
// Progress engine: drive in-flight collectives from anywhere on the rank
// ---------------------------------------------------------------------------

thread_local! {
    /// The engine (if any) installed on this thread — what the kernel
    /// driver's callback polls. Band worker threads never inherit it, so
    /// only the rank thread that installed the engine drives it.
    static CURRENT_ENGINE: RefCell<Option<Rc<RefCell<EngineInner>>>> =
        const { RefCell::new(None) };
}

/// Per-rank registry of in-flight [`PackedAllreduce`] state machines that
/// any code running on the owning rank thread can drive forward.
///
/// The grad-ready DP scheduler `register`s each bucket's collective the
/// moment it is posted and `try_take`s the reduced payload when it needs
/// it back; in between, *whoever is burning the rank's wall-clock* makes
/// the rings progress: [`install`](ProgressEngine::install) points the
/// kernel driver's callback at this engine, so polls fire at the blocked-
/// kernel row-band barrier, between register-tile row groups of the
/// serial kernels, and inside every blocking fabric wait (the
/// `dist_matmul` ready-queue's dry-wait included). `Rc`-internal by
/// design — an engine lives and is driven on exactly one rank thread.
pub struct ProgressEngine {
    inner: Rc<RefCell<EngineInner>>,
}

struct EngineInner {
    /// poll-only endpoint on the collectives' fabric: consumes arrivals
    /// and forwards ring chunks, but never issues a collective itself,
    /// so the registering endpoint's tag sequencing stays untouched
    poll_comm: Comm,
    /// registered machines, indexed by ticket; `None` once taken
    slots: Vec<Option<PackedAllreduce>>,
    /// machines not yet done — lets the hot poll path bail in O(1) when
    /// nothing is in flight (every kernel row-group ticks through here)
    live: usize,
}

/// Handle to one registered collective (index into the engine's slots).
#[derive(Clone, Copy, Debug)]
pub struct ProgressTicket(usize);

/// Restores the previously installed engine/driver hook on drop, so a
/// scheduler unwinding on a rank failure cannot leave a dangling hook
/// pointing at a dead engine.
pub struct ProgressGuard {
    prev_engine: Option<Rc<RefCell<EngineInner>>>,
    prev_hook: Option<fn() -> bool>,
}

/// Poll the engine behind `inner` once: drive every in-flight machine as
/// far as arrived messages allow. Returns whether anything progressed.
/// `try_borrow_mut` guards re-entrancy (a hook firing inside an engine
/// poll is a no-op rather than a RefCell panic).
///
/// Cost note: each in-flight machine's `poll` takes the fabric's queue
/// lock for its own `try_recv`, so one tick costs `live` short lock
/// round-trips (~25ns uncontended each). At the kernel's ~tens-of-
/// microseconds tick cadence and single-digit bucket counts that is
/// well under 1% of a rank's time; if bucket counts grow an order of
/// magnitude, batch the probes under one lock (a `poll_locked` variant)
/// before reaching for a coarser tick.
fn poll_engine_inner(inner: &RefCell<EngineInner>) -> bool {
    let Ok(mut guard) = inner.try_borrow_mut() else {
        return false;
    };
    let inner = &mut *guard;
    if inner.live == 0 {
        return false;
    }
    let mut progress = false;
    let mut live = 0usize;
    let comm = &inner.poll_comm;
    for slot in inner.slots.iter_mut() {
        if let Some(coll) = slot {
            if !coll.is_done() {
                progress |= coll.poll(comm);
                if !coll.is_done() {
                    live += 1;
                }
            }
        }
    }
    inner.live = live;
    progress
}

/// The kernel driver's callback body: poll whatever engine is installed
/// on the current thread. No-op (`false`) when none is.
fn poll_current_engine() -> bool {
    let engine = CURRENT_ENGINE.with(|cur| match cur.try_borrow() {
        Ok(b) => b.clone(),
        Err(_) => None,
    });
    match engine {
        Some(inner) => poll_engine_inner(&inner),
        None => false,
    }
}

impl ProgressEngine {
    /// New engine polling the same fabric endpoint as `comm`.
    pub fn new(comm: &Comm) -> Self {
        ProgressEngine {
            inner: Rc::new(RefCell::new(EngineInner {
                poll_comm: Comm {
                    rank: comm.rank,
                    net: comm.net.clone(),
                    coll_seq: HashMap::new(),
                },
                slots: Vec::new(),
                live: 0,
            })),
        }
    }

    /// Register an in-flight collective; the engine owns it until
    /// [`try_take`](ProgressEngine::try_take).
    pub fn register(&self, coll: PackedAllreduce) -> ProgressTicket {
        let mut inner = self.inner.borrow_mut();
        if !coll.is_done() {
            inner.live += 1;
        }
        inner.slots.push(Some(coll));
        ProgressTicket(inner.slots.len() - 1)
    }

    /// Drive every registered machine as far as already-arrived messages
    /// allow. Never blocks; returns whether anything progressed.
    pub fn poll(&self) -> bool {
        poll_engine_inner(&self.inner)
    }

    /// Whether the ticket's collective has completed (or been taken).
    pub fn is_done(&self, t: &ProgressTicket) -> bool {
        self.inner.borrow().slots[t.0]
            .as_ref()
            .map_or(true, |c| c.is_done())
    }

    /// Take the reduced payload of a completed collective; `None` while
    /// it is still in flight (or if already taken).
    pub fn try_take(&self, t: &ProgressTicket) -> Option<Tensor> {
        let mut inner = self.inner.borrow_mut();
        if inner.slots[t.0].as_ref().map_or(false, |c| c.is_done()) {
            inner.slots[t.0].take().map(PackedAllreduce::take)
        } else {
            None
        }
    }

    /// Number of registered collectives still in flight.
    pub fn in_flight(&self) -> usize {
        self.inner.borrow().live
    }

    /// The (src, tag) keys the in-flight machines are waiting on — feed
    /// to [`Comm::wait_any_ready`] to park until any can advance.
    pub fn awaited(&self) -> Vec<(usize, u64)> {
        self.inner
            .borrow()
            .slots
            .iter()
            .flatten()
            .filter_map(PackedAllreduce::awaited)
            .collect()
    }

    /// Install this engine as the current thread's driven registry and
    /// point the kernel driver's callback at it. The returned guard
    /// restores the previous hook (drop it when the collectives' owner —
    /// the grad scheduler — is done).
    pub fn install(&self) -> ProgressGuard {
        let prev_engine = CURRENT_ENGINE.with(|c| c.replace(Some(self.inner.clone())));
        let prev_hook = crate::tensor::ops::set_driver_hook(Some(poll_current_engine));
        ProgressGuard { prev_engine, prev_hook }
    }
}

impl Drop for ProgressGuard {
    fn drop(&mut self) {
        crate::tensor::ops::set_driver_hook(self.prev_hook.take());
        CURRENT_ENGINE.with(|c| {
            *c.borrow_mut() = self.prev_engine.take();
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};
    use std::thread;

    #[test]
    fn point_to_point_delivers_in_order() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let h = thread::spawn(move || {
            a.send(1, 7, Tensor::scalar(1.0));
            a.send(1, 7, Tensor::scalar(2.0));
        });
        assert_eq!(b.recv(0, 7).data, vec![1.0]);
        assert_eq!(b.recv(0, 7).data, vec![2.0]);
        h.join().unwrap();
    }

    #[test]
    fn tags_do_not_cross() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        a.send(1, 1, Tensor::scalar(10.0));
        a.send(1, 2, Tensor::scalar(20.0));
        assert_eq!(b.recv(0, 2).data, vec![20.0]);
        assert_eq!(b.recv(0, 1).data, vec![10.0]);
    }

    #[test]
    fn try_recv_none_before_arrival_in_order_after() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        assert!(b.try_recv(0, 9).is_none(), "nothing sent yet");
        a.send(1, 9, Tensor::scalar(1.0));
        a.send(1, 9, Tensor::scalar(2.0));
        assert_eq!(b.try_recv(0, 9).unwrap().data, vec![1.0]);
        assert_eq!(b.try_recv(0, 9).unwrap().data, vec![2.0]);
        assert!(b.try_recv(0, 9).is_none(), "queue drained");
    }

    #[test]
    fn recv_any_returns_whichever_arrived() {
        let net = Network::new(3);
        let b = net.endpoint(1);
        let c = net.endpoint(2);
        let r = net.endpoint(0);
        c.send(0, 5, Tensor::scalar(30.0));
        let keys = [(1usize, 5u64), (2usize, 5u64)];
        let (idx, got) = r.recv_any(&keys);
        assert_eq!(idx, 1, "only rank 2's message is in flight");
        assert_eq!(got.data, vec![30.0]);
        b.send(0, 5, Tensor::scalar(20.0));
        let (idx, got) = r.recv_any(&keys);
        assert_eq!(idx, 0);
        assert_eq!(got.data, vec![20.0]);
    }

    #[test]
    fn fabric_latency_withholds_then_delivers() {
        let net = Network::new(2);
        net.set_fabric(
            FabricSpec {
                latency: Duration::from_millis(30),
                jitter: Duration::ZERO,
                bytes_per_sec: 1e12,
            },
            7,
        );
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let t0 = Instant::now();
        a.send(1, 3, Tensor::scalar(5.0));
        assert!(b.try_recv(0, 3).is_none(), "message still in flight");
        let got = b.recv(0, 3);
        assert_eq!(got.data, vec![5.0]);
        assert!(
            t0.elapsed() >= Duration::from_millis(25),
            "delivered before the injected latency"
        );
    }

    #[test]
    fn allreduce_sums_over_group() {
        let net = Network::new(4);
        let group = vec![0, 1, 2, 3];
        let mut handles = Vec::new();
        for r in 0..4 {
            let mut c = net.endpoint(r);
            let g = group.clone();
            handles.push(thread::spawn(move || {
                let t = Tensor::new(vec![2], vec![r as f32, 1.0]);
                c.allreduce_sum(&g, &t).data
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![6.0, 4.0]);
        }
    }

    #[test]
    fn ring_matches_gather_exactly() {
        // integer-valued payloads add exactly in any order, so the ring
        // must reproduce gather-to-root bit for bit
        check("ring == gather allreduce", 25, |g: &mut Gen| {
            let n = g.int(2, 6);
            let numel = g.int(1, 97);
            let net = Network::new(n);
            let group: Vec<usize> = (0..n).collect();
            let mut handles = Vec::new();
            for r in 0..n {
                let mut c = net.endpoint(r);
                let grp = group.clone();
                let data: Vec<f32> =
                    (0..numel).map(|i| ((i * 7 + r * 13) % 32) as f32).collect();
                handles.push(thread::spawn(move || {
                    let t = Tensor::new(vec![numel], data);
                    let ring = c.allreduce_sum_ring(&grp, &t);
                    let gather = c.allreduce_sum_gather(&grp, &t);
                    (ring.data, gather.data)
                }));
            }
            for h in handles {
                let (ring, gather) = h.join().unwrap();
                if ring != gather {
                    return Err(format!("n={n} numel={numel}: ring != gather"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ring_on_disjoint_dp_groups() {
        // the paper's DP groups: ranks with equal r % way share params.
        // Both groups ring concurrently without cross-talk.
        let net = Network::new(4);
        let mut handles = Vec::new();
        for r in 0..4 {
            let mut c = net.endpoint(r);
            let g = if r % 2 == 0 { vec![0, 2] } else { vec![1, 3] };
            handles.push(thread::spawn(move || {
                let t = Tensor::new(vec![16], vec![(r + 1) as f32; 16]);
                c.allreduce_sum_ring(&g, &t).data
            }));
        }
        let sums: Vec<Vec<f32>> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(sums[0], vec![4.0; 16]); // 1 + 3
        assert_eq!(sums[1], vec![6.0; 16]); // 2 + 4
        assert_eq!(sums[2], vec![4.0; 16]);
        assert_eq!(sums[3], vec![6.0; 16]);
    }

    #[test]
    fn disjoint_groups_do_not_interfere() {
        // scalar path (gather dispatch) on the r%n DP groups
        let net = Network::new(4);
        let mut handles = Vec::new();
        for r in 0..4 {
            let mut c = net.endpoint(r);
            let g = if r % 2 == 0 { vec![0, 2] } else { vec![1, 3] };
            handles.push(thread::spawn(move || {
                c.allreduce_scalar(&g, (r + 1) as f32)
            }));
        }
        let sums: Vec<f32> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(sums, vec![4.0, 6.0, 4.0, 6.0]); // {1+3}, {2+4}
    }

    #[test]
    fn packed_allreduce_matches_blocking_bit_for_bit() {
        // the in-flight state machine must reproduce the blocking
        // collective exactly — both dispatch branches (tiny payloads
        // gather, larger ones ring). Fractional values make any change
        // in addition order visible in the bits.
        check("allreduce_start == allreduce_sum", 25, |g: &mut Gen| {
            let n = g.int(2, 6);
            let numel = g.int(1, 120); // < 4n exercises the gather branch
            let net = Network::new(n);
            let group: Vec<usize> = (0..n).collect();
            let mut handles = Vec::new();
            for r in 0..n {
                let mut c = net.endpoint(r);
                let grp = group.clone();
                let data: Vec<f32> = (0..numel)
                    .map(|i| 0.1 + ((i * 31 + r * 17) % 97) as f32 / 7.0)
                    .collect();
                handles.push(thread::spawn(move || {
                    let t = Tensor::new(vec![numel], data);
                    let blocking = c.allreduce_sum(&grp, &t);
                    let machine = c.allreduce_start(&grp, t).wait(&c);
                    (blocking.data, machine.data)
                }));
            }
            for h in handles {
                let (blocking, machine) = h.join().unwrap();
                let same = blocking
                    .iter()
                    .zip(&machine)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                if !same {
                    return Err(format!("n={n} numel={numel}: bits diverge"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn multiple_packed_allreduces_in_flight() {
        // three collectives started back to back per rank, then polled to
        // completion in whatever order messages land — the multi-bucket
        // bookkeeping the grad-ready DP scheduler relies on
        let n = 4usize;
        let net = Network::new(n);
        let group: Vec<usize> = (0..n).collect();
        let mut handles = Vec::new();
        for r in 0..n {
            let mut c = net.endpoint(r);
            let grp = group.clone();
            handles.push(thread::spawn(move || {
                let mut colls: Vec<PackedAllreduce> = (0..3)
                    .map(|b| {
                        let t = Tensor::new(vec![32], vec![(r + b) as f32; 32]);
                        c.allreduce_start(&grp, t)
                    })
                    .collect();
                loop {
                    let mut waiting = Vec::new();
                    for coll in colls.iter_mut() {
                        if !coll.is_done() {
                            coll.poll(&c);
                        }
                        if let Some(k) = coll.awaited() {
                            waiting.push(k);
                        }
                    }
                    if waiting.is_empty() {
                        break;
                    }
                    c.wait_any_ready(&waiting);
                }
                colls.into_iter().map(|pa| pa.take().data).collect::<Vec<_>>()
            }));
        }
        for h in handles {
            let outs = h.join().unwrap();
            for (b, data) in outs.iter().enumerate() {
                // sum over r of (r + b) = 6 + 4b
                assert_eq!(data, &vec![(6 + 4 * b) as f32; 32], "bucket {b}");
            }
        }
    }

    #[test]
    fn abort_unblocks_a_blocked_receiver() {
        let net = Network::new(2);
        let b = net.endpoint(1);
        let h = thread::spawn(move || {
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                b.recv(0, 1) // never sent
            }))
        });
        std::thread::sleep(Duration::from_millis(20));
        net.abort();
        let err = h.join().unwrap().unwrap_err();
        let ce = CommError::from_panic(&*err).expect("typed CommError payload");
        assert_eq!(ce, CommError::Aborted { rank: None });
        // display keeps the legacy sentinel for log scrapers
        assert!(ce.to_string().contains(FABRIC_ABORTED), "{ce}");
        assert!(net.is_aborted());
        assert_eq!(net.abort_origin(), None);
    }

    #[test]
    fn abort_from_records_first_origin_and_payload_carries_it() {
        let net = Network::new(4);
        let b = net.endpoint(1);
        let h = thread::spawn(move || {
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                b.recv(0, 1) // never sent
            }))
        });
        std::thread::sleep(Duration::from_millis(20));
        net.abort_from(3);
        // a casualty re-aborting must not overwrite the true failer
        net.abort_from(1);
        let err = h.join().unwrap().unwrap_err();
        let ce = CommError::from_panic(&*err).expect("typed CommError payload");
        assert_eq!(ce, CommError::Aborted { rank: Some(3) });
        assert!(ce.to_string().contains("origin rank 3"), "{ce}");
        assert_eq!(net.abort_origin(), Some(3));
    }

    #[test]
    fn deadlock_detector_breaks_three_rank_cycle() {
        // 0 waits on 1, 1 waits on 2, 2 waits on 0 — every member must
        // unwind with the same knot description instead of sleeping
        let net = Network::new(3);
        net.set_deadlock_detect(true);
        assert!(net.deadlock_detect_enabled());
        let handles: Vec<_> = (0..3)
            .map(|r| {
                let ep = net.endpoint(r);
                thread::spawn(move || {
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        ep.recv((r + 1) % 3, 40 + r as u64)
                    }))
                })
            })
            .collect();
        for h in handles {
            let err = h.join().unwrap().unwrap_err();
            match CommError::from_panic(&*err).expect("typed CommError payload") {
                CommError::Deadlock { desc } => {
                    for r in 0..3 {
                        assert!(desc.contains(&format!("rank {r}")), "{desc}");
                    }
                }
                other => panic!("expected Deadlock, got {other:?}"),
            }
        }
        assert!(net.deadlock_info().is_some());
    }

    #[test]
    fn deadlock_detector_spares_waiter_on_running_rank() {
        // rank 1 blocks on a key whose source is alive outside the
        // registry — the knot check must see the chain anchored on a
        // runnable rank and never trip
        let net = Network::new(2);
        net.set_deadlock_detect(true);
        let b = net.endpoint(1);
        let h = thread::spawn(move || b.recv(0, 6));
        thread::sleep(Duration::from_millis(20));
        net.endpoint(0).send(1, 6, Tensor::scalar(4.0));
        assert_eq!(h.join().expect("no detector trip").data, vec![4.0]);
        assert!(net.deadlock_info().is_none());
    }

    #[test]
    fn deadlock_detect_default_override_and_per_net_setter() {
        set_deadlock_detect_default(Some(false));
        let off = Network::new(2);
        assert!(!off.deadlock_detect_enabled());
        set_deadlock_detect_default(Some(true));
        let on = Network::new(2);
        assert!(on.deadlock_detect_enabled());
        set_deadlock_detect_default(None);
        // the per-network setter wins over whatever the default said
        on.set_deadlock_detect(false);
        assert!(!on.deadlock_detect_enabled());
        off.set_deadlock_detect(true);
        assert!(off.deadlock_detect_enabled());
    }

    #[test]
    fn wait_any_ready_does_not_consume() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        a.send(1, 3, Tensor::scalar(9.0));
        b.wait_any_ready(&[(0, 2), (0, 3)]);
        // the message is still there for the real receive
        assert_eq!(b.try_recv(0, 3).unwrap().data, vec![9.0]);
    }

    #[test]
    fn byte_accounting() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        a.send(1, 0, Tensor::zeros(&[10, 10]));
        assert_eq!(net.link_bytes(0, 1), 400);
        assert_eq!(net.link_bytes(1, 0), 0);
        assert_eq!(net.total_bytes(), 400);
        net.reset_bytes();
        assert_eq!(net.total_bytes(), 0);
    }

    #[test]
    fn max_queue_depth_tracks_backlog() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        assert_eq!(net.max_queue_depth(), 0);
        for i in 0..3 {
            a.send(1, 4, Tensor::scalar(i as f32));
        }
        assert_eq!(net.max_queue_depth(), 3);
        for _ in 0..3 {
            let _ = b.recv(0, 4);
        }
        // draining does not lower the high-water mark
        assert_eq!(net.max_queue_depth(), 3);
        net.reset_bytes();
        assert_eq!(net.max_queue_depth(), 0);
    }

    #[test]
    fn collective_tag_seq_survives_32bit_wrap() {
        // the old layout masked the per-group sequence to 32 tag bits, so
        // tags at seq k and k + 2^32 collided bit for bit on long runs;
        // the widened 44-bit field must keep them distinct
        let net = Network::new(2);
        let mut c = net.endpoint(0);
        let group = vec![0usize, 1];
        let gh = group_hash(&group);
        // near-wrap start value: straddle the old field's boundary
        c.coll_seq.insert(gh, (1u64 << 32) - 2);
        let mut tags = std::collections::BTreeSet::new();
        for _ in 0..4 {
            assert!(
                tags.insert(c.next_coll_tag(&group)),
                "tag collided crossing the 32-bit seq boundary"
            );
        }
        // the direct collision of the old layout
        c.coll_seq.insert(gh, 5);
        let a = c.next_coll_tag(&group);
        c.coll_seq.insert(gh, 5 + (1u64 << 32));
        let b = c.next_coll_tag(&group);
        assert_ne!(a, b, "seq tag field must be wider than 32 bits");
        // and the tags still live in the collective namespace
        assert!(a & COLLECTIVE_BIT != 0 && a & REPLY_BIT == 0);
    }

    #[test]
    fn dropped_inflight_collective_recycles_its_buffers() {
        // rank 0 posts a ring and dies before the peer answers (the
        // FABRIC_ABORTED unwind shape): dropping the machine must hand
        // its working payload back to this thread's pool
        let net = Network::new(2);
        let mut c = net.endpoint(0);
        // distinctive capacity marks the payload buffer, so finding it in
        // this thread's (otherwise untouched) pool is unambiguous — no
        // reliance on the process-global hit/miss counters other test
        // threads also bump
        let numel = 4099usize;
        let mut data = Vec::with_capacity(5000);
        data.resize(numel, 1.0);
        let payload = Tensor::new(vec![numel], data);
        let coll = c.allreduce_start(&[0, 1], payload);
        assert!(!coll.is_done(), "peerless ring must still be in flight");
        drop(coll);
        let got = crate::tensor::pool::take(100);
        assert_eq!(
            got.capacity(),
            5000,
            "dropped machine's working payload was freed, not pooled"
        );
        crate::tensor::pool::put(got);
    }

    #[test]
    fn dropped_inflight_bf16_collective_recycles_its_buffers() {
        // same unwind shape as above, but with a bf16 ring in flight —
        // the bf16 path wires extra quantize buffers through the machine
        // and the abort-recovery loop re-enters bf16 training on the same
        // thread pool, so pool recycling must hold for this precision too
        let net = Network::new(2);
        let mut c = net.endpoint(0);
        let numel = 4099usize;
        let mut data = Vec::with_capacity(6000);
        data.resize(numel, 1.0);
        let payload = Tensor::new(vec![numel], data);
        let coll = c.allreduce_start_prec(&[0, 1], payload, Precision::Bf16);
        assert!(!coll.is_done(), "peerless bf16 ring must still be in flight");
        drop(coll);
        let got = crate::tensor::pool::take(100);
        assert_eq!(
            got.capacity(),
            6000,
            "dropped bf16 machine's working payload was freed, not pooled"
        );
        crate::tensor::pool::put(got);
    }

    /// Dawdling driver hook for the missed-wakeup regression: long enough
    /// that a fabric-delayed message becomes deliverable while it runs.
    fn slow_hook() -> bool {
        std::thread::sleep(Duration::from_millis(12));
        false
    }

    #[test]
    fn wait_does_not_strand_when_delivery_lands_during_hook() {
        // the missed-wakeup window: wait_any_ready probes (nothing), runs
        // the driver hook with the lock released, and while the hook runs
        // the seeded-delay fabric delivers the message — its notify_all
        // fires with nobody on the condvar. Parking without re-probing
        // under the lock would strand this thread forever (no further
        // sends). The fixed wait re-probes and returns promptly.
        let net = Network::new(2);
        net.set_fabric(
            FabricSpec {
                latency: Duration::from_millis(3),
                jitter: Duration::ZERO,
                bytes_per_sec: 1e12,
            },
            9,
        );
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let sender = thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(4));
            a.send(1, 5, Tensor::scalar(1.0));
        });
        let prev = crate::tensor::ops::set_driver_hook(Some(slow_hook));
        let t0 = Instant::now();
        b.wait_any_ready(&[(0, 5)]);
        let waited = t0.elapsed();
        crate::tensor::ops::set_driver_hook(prev);
        sender.join().unwrap();
        assert_eq!(b.try_recv(0, 5).unwrap().data, vec![1.0]);
        assert!(
            waited < Duration::from_millis(500),
            "wait stranded past the hook window: {waited:?}"
        );
    }

    /// Endpoint the consuming hook drains through (same rank as the
    /// waiter, second endpoint on the same fabric — the shape of a
    /// progress engine's poll_comm).
    static HOOK_COMM: Mutex<Option<Comm>> = Mutex::new(None);

    fn consuming_hook() -> bool {
        // dawdle past the fabric delay so the message becomes deliverable
        // mid-hook, then consume it — what an installed engine does to a
        // drain's awaited ring hop
        std::thread::sleep(Duration::from_millis(60));
        plock(&HOOK_COMM)
            .as_ref()
            .map_or(false, |c| c.try_recv(0, 9).is_some())
    }

    #[test]
    fn hooked_probe_wait_returns_when_hook_consumes_the_awaited_key() {
        // the stale-snapshot hang: wait_any_ready parks on key (0, 9);
        // the driver hook itself consumes that message (an engine polls
        // exactly the keys the drain waits on, on this very fabric), so
        // no future traffic ever targets the key. The wait must treat
        // hook progress as a wake and return — the old structure spun on
        // its tick forever.
        let net = Network::new(2);
        // 50ms latency: generous margin for the waiter to be parked
        // before the message becomes deliverable (the hook's 60ms nap
        // then strictly covers the delivery instant)
        net.set_fabric(
            FabricSpec {
                latency: Duration::from_millis(50),
                jitter: Duration::ZERO,
                bytes_per_sec: 1e12,
            },
            5,
        );
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        *plock(&HOOK_COMM) = Some(net.endpoint(1));
        a.send(1, 9, Tensor::scalar(4.0));
        let prev = crate::tensor::ops::set_driver_hook(Some(consuming_hook));
        let t0 = Instant::now();
        b.wait_any_ready(&[(0, 9)]);
        let waited = t0.elapsed();
        crate::tensor::ops::set_driver_hook(prev);
        *plock(&HOOK_COMM) = None;
        assert!(
            b.try_recv(0, 9).is_none(),
            "the hook should have consumed the awaited message"
        );
        assert!(
            waited < Duration::from_secs(2),
            "stranded on a stale key snapshot: {waited:?}"
        );
    }

    #[test]
    fn progress_engine_drives_registered_collectives() {
        // three collectives per rank, driven only through engine polls
        // (never the per-handle wait): the registry must complete them
        // all and hand back the same sums the blocking path produces
        let n = 4usize;
        let net = Network::new(n);
        let group: Vec<usize> = (0..n).collect();
        let mut handles = Vec::new();
        for r in 0..n {
            let mut c = net.endpoint(r);
            let grp = group.clone();
            handles.push(thread::spawn(move || {
                let engine = ProgressEngine::new(&c);
                let tickets: Vec<ProgressTicket> = (0..3)
                    .map(|b| {
                        let t = Tensor::new(vec![32], vec![(r + b) as f32; 32]);
                        engine.register(c.allreduce_start(&grp, t))
                    })
                    .collect();
                while engine.in_flight() > 0 {
                    engine.poll();
                    let waiting = engine.awaited();
                    if !waiting.is_empty() {
                        c.wait_any_ready(&waiting);
                    }
                }
                tickets
                    .iter()
                    .map(|t| engine.try_take(t).unwrap().data)
                    .collect::<Vec<_>>()
            }));
        }
        for h in handles {
            let outs = h.join().unwrap();
            for (b, data) in outs.iter().enumerate() {
                assert_eq!(data, &vec![(6 + 4 * b) as f32; 32], "bucket {b}");
            }
        }
    }

    #[test]
    fn ring_bytes_are_2_nm1_over_n() {
        // 4 ranks, 16 floats: each rank sends 2*(n-1) chunks of numel/n
        // = 6 * 4 floats = 96 bytes
        let net = Network::new(4);
        let group = vec![0, 1, 2, 3];
        let mut handles = Vec::new();
        for r in 0..4 {
            let mut c = net.endpoint(r);
            let g = group.clone();
            handles.push(thread::spawn(move || {
                let t = Tensor::new(vec![16], vec![r as f32; 16]);
                c.allreduce_sum_ring(&g, &t)
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // every rank ships only to its right neighbour
        assert_eq!(net.link_bytes(0, 1), 96);
        assert_eq!(net.link_bytes(1, 2), 96);
        assert_eq!(net.link_bytes(2, 3), 96);
        assert_eq!(net.link_bytes(3, 0), 96);
        assert_eq!(net.link_bytes(0, 2), 0);
    }

    #[test]
    fn bf16_point_to_point_roundtrip_and_bytes() {
        let net = Network::new(2);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let t = Tensor::new(vec![10, 10], (0..100).map(|i| i as f32 / 3.0).collect());
        a.send_bf16(1, 4, Bf16Tensor::from_tensor(&t));
        // 2 bytes/elem on the link stats, not 4
        assert_eq!(net.link_bytes(0, 1), 200);
        assert!(b.try_recv_bf16(0, 5).is_none());
        let got = b.recv_bf16(0, 4);
        assert_eq!(got.shape, vec![10, 10]);
        let wide = got.to_tensor();
        for (w, v) in wide.data.iter().zip(t.data.iter()) {
            assert_eq!(*w, crate::tensor::bf16::quantize(*v));
        }
        got.recycle();
        wide.recycle();
    }

    #[test]
    fn bf16_ring_bytes_are_half_of_f32() {
        // same collective as ring_bytes_are_2_nm1_over_n, bf16 wire:
        // 6 chunks * 4 elems * 2 bytes = 48 per right-neighbour link
        let net = Network::new(4);
        let group = vec![0, 1, 2, 3];
        let mut handles = Vec::new();
        for r in 0..4 {
            let mut c = net.endpoint(r);
            let g = group.clone();
            handles.push(thread::spawn(move || {
                let t = Tensor::new(vec![16], vec![r as f32; 16]);
                c.allreduce_sum_ring_prec(&g, &t, Precision::Bf16)
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(net.link_bytes(0, 1), 48);
        assert_eq!(net.link_bytes(1, 2), 48);
        assert_eq!(net.link_bytes(2, 3), 48);
        assert_eq!(net.link_bytes(3, 0), 48);
    }

    #[test]
    fn bf16_ring_blocking_matches_inflight_and_replicas_agree() {
        // two properties at once: (a) the in-flight bf16 ring reproduces
        // the blocking bf16 ring bit for bit (same quantization points,
        // same addition order), and (b) after the collective *every rank
        // holds identical bits* — the owner-quantize at the allgather
        // handoff is what makes DP replicas stay in lockstep, and this
        // is the test that fails without it. Fractional values make any
        // rounding divergence visible.
        check("bf16 ring: blocking == in-flight, ranks agree", 20, |g: &mut Gen| {
            let n = g.int(2, 6);
            let numel = g.int(4 * n, 150); // always the ring branch
            let net = Network::new(n);
            let group: Vec<usize> = (0..n).collect();
            let mut handles = Vec::new();
            for r in 0..n {
                let mut c = net.endpoint(r);
                let grp = group.clone();
                let data: Vec<f32> = (0..numel)
                    .map(|i| 0.1 + ((i * 31 + r * 17) % 97) as f32 / 7.0)
                    .collect();
                handles.push(thread::spawn(move || {
                    let t = Tensor::new(vec![numel], data);
                    let blocking = c.allreduce_sum_prec(&grp, &t, Precision::Bf16);
                    let machine =
                        c.allreduce_start_prec(&grp, t, Precision::Bf16).wait(&c);
                    (blocking.data, machine.data)
                }));
            }
            let per_rank: Vec<(Vec<f32>, Vec<f32>)> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            for (r, (blocking, machine)) in per_rank.iter().enumerate() {
                let same = blocking
                    .iter()
                    .zip(machine)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                if !same {
                    return Err(format!(
                        "n={n} numel={numel} rank {r}: blocking != in-flight"
                    ));
                }
                let agree = blocking
                    .iter()
                    .zip(&per_rank[0].0)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                if !agree {
                    return Err(format!(
                        "n={n} numel={numel}: rank {r} bits differ from rank 0"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bf16_ring_is_close_to_f32_ring() {
        // the quantized collective is a tolerance oracle, not a bit
        // oracle: against the f32 ring the error is bounded by bf16's
        // half-ulp (2^-8 relative) per hop, n hops
        let n = 4usize;
        let numel = 64usize;
        let net = Network::new(n);
        let group: Vec<usize> = (0..n).collect();
        let mut handles = Vec::new();
        for r in 0..n {
            let mut c = net.endpoint(r);
            let grp = group.clone();
            let data: Vec<f32> =
                (0..numel).map(|i| ((i * 13 + r * 7) % 23) as f32 / 11.0).collect();
            handles.push(thread::spawn(move || {
                let t = Tensor::new(vec![numel], data);
                let f32_out = c.allreduce_sum_ring(&grp, &t);
                let bf16_out = c.allreduce_sum_ring_prec(&grp, &t, Precision::Bf16);
                (f32_out, bf16_out)
            }));
        }
        for h in handles {
            let (want, got) = h.join().unwrap();
            let scale = 1.0 + want.data.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
            let err = got.max_abs_diff(&want) / scale;
            assert!(err <= (n as f32) / 256.0, "bf16 ring err {err}");
        }
    }
}
