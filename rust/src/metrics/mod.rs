//! Evaluation metrics: latitude-weighted RMSE (WeatherBench2 convention,
//! paper Section 6) and training-curve logging.

use std::fs;
use std::io::Write;
use std::path::Path;

use crate::model::latitude_weights;
use crate::tensor::Tensor;

/// Latitude-weighted RMSE of one channel: pred/target are [lat, lon]
/// fields. `lat0` is the global latitude offset of row 0 (for shard
/// evaluation).
pub fn lat_weighted_rmse_field(
    pred: &Tensor,
    target: &Tensor,
    global_lat: usize,
    lat0: usize,
) -> f32 {
    let (lat, lon) = pred.dims2();
    assert_eq!(pred.shape, target.shape);
    let w = latitude_weights(global_lat);
    let mut s = 0.0f32;
    for i in 0..lat {
        for j in 0..lon {
            let e = pred.at2(i, j) - target.at2(i, j);
            s += w[lat0 + i] * e * e;
        }
    }
    (s / (lat * lon) as f32).sqrt()
}

/// Per-channel latitude-weighted RMSE over a [lat, lon, C] sample.
pub fn lat_weighted_rmse(pred: &Tensor, target: &Tensor, global_lat: usize, lat0: usize) -> Vec<f32> {
    assert_eq!(pred.shape, target.shape);
    let (lat, lon, c) = (pred.shape[0], pred.shape[1], pred.shape[2]);
    let w = latitude_weights(global_lat);
    let mut acc = vec![0.0f32; c];
    for i in 0..lat {
        for j in 0..lon {
            for ci in 0..c {
                let idx = (i * lon + j) * c + ci;
                let e = pred.data[idx] - target.data[idx];
                acc[ci] += w[lat0 + i] * e * e;
            }
        }
    }
    acc.iter()
        .map(|s| (s / (lat * lon) as f32).sqrt())
        .collect()
}

/// Append-only JSONL training log (loss curves, RMSE series).
pub struct RunLog {
    path: String,
}

impl RunLog {
    pub fn create(path: &str) -> std::io::Result<Self> {
        if let Some(dir) = Path::new(path).parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(path, "")?;
        Ok(RunLog { path: path.to_string() })
    }

    pub fn record(&self, fields: &[(&str, f64)]) -> std::io::Result<()> {
        let mut f = fs::OpenOptions::new().append(true).open(&self.path)?;
        let body: Vec<String> = fields
            .iter()
            .map(|(k, v)| format!("\"{k}\":{v}"))
            .collect();
        writeln!(f, "{{{}}}", body.join(","))
    }
}

/// Live counters for the forecast serving engine: trajectory-cache hits
/// and misses, LRU evictions, and prefetched rollout steps. All atomic —
/// the serving thread and the bench harness read them concurrently with
/// the engine bumping them. Relaxed ordering: these are monotonically
/// increasing statistics, never synchronization.
#[derive(Debug, Default)]
pub struct ServeCounters {
    hits: std::sync::atomic::AtomicU64,
    misses: std::sync::atomic::AtomicU64,
    evictions: std::sync::atomic::AtomicU64,
    prefetches: std::sync::atomic::AtomicU64,
}

/// Point-in-time copy of [`ServeCounters`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub prefetches: u64,
}

impl ServeStats {
    /// Fraction of cache lookups answered without a rollout step.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }
}

impl ServeCounters {
    const ORD: std::sync::atomic::Ordering = std::sync::atomic::Ordering::Relaxed;

    pub fn hit(&self) {
        self.hits.fetch_add(1, Self::ORD);
    }

    pub fn miss(&self) {
        self.misses.fetch_add(1, Self::ORD);
    }

    pub fn eviction(&self) {
        self.evictions.fetch_add(1, Self::ORD);
    }

    pub fn prefetch(&self) {
        self.prefetches.fetch_add(1, Self::ORD);
    }

    pub fn snapshot(&self) -> ServeStats {
        ServeStats {
            hits: self.hits.load(Self::ORD),
            misses: self.misses.load(Self::ORD),
            evictions: self.evictions.load(Self::ORD),
            prefetches: self.prefetches.load(Self::ORD),
        }
    }

    pub fn reset(&self) {
        self.hits.store(0, Self::ORD);
        self.misses.store(0, Self::ORD);
        self.evictions.store(0, Self::ORD);
        self.prefetches.store(0, Self::ORD);
    }
}

/// Simple persistence baseline: forecast = current state (the standard
/// weather-model sanity baseline for Fig-5-style comparisons).
pub fn persistence_forecast(x: &Tensor) -> Tensor {
    x.clone()
}

/// Climatology baseline: forecast = per-channel mean field.
pub fn climatology_forecast(samples: &[Tensor]) -> Tensor {
    assert!(!samples.is_empty());
    let mut acc = Tensor::zeros(&samples[0].shape.clone());
    for s in samples {
        crate::tensor::ops::add_assign(&mut acc, s);
    }
    crate::tensor::ops::scale(&acc, 1.0 / samples.len() as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmse_zero_for_perfect_forecast() {
        let t = Tensor::new(vec![4, 4], (0..16).map(|v| v as f32).collect());
        assert_eq!(lat_weighted_rmse_field(&t, &t, 4, 0), 0.0);
    }

    #[test]
    fn rmse_matches_hand_computation_uniform() {
        // constant error of 2.0 everywhere -> rmse == 2 (weights mean 1)
        let a = Tensor::zeros(&[4, 8]);
        let b = Tensor::new(vec![4, 8], vec![2.0; 32]);
        let r = lat_weighted_rmse_field(&a, &b, 4, 0);
        assert!((r - 2.0).abs() < 1e-5);
    }

    #[test]
    fn per_channel_rmse_shapes() {
        let a = Tensor::zeros(&[4, 4, 3]);
        let mut b = Tensor::zeros(&[4, 4, 3]);
        for i in 0..16 {
            b.data[i * 3 + 1] = 1.0;
        }
        let r = lat_weighted_rmse(&a, &b, 4, 0);
        assert_eq!(r.len(), 3);
        assert!(r[0] < 1e-6 && (r[1] - 1.0).abs() < 1e-5 && r[2] < 1e-6);
    }

    #[test]
    fn climatology_is_mean() {
        let a = Tensor::new(vec![2], vec![0.0, 2.0]);
        let b = Tensor::new(vec![2], vec![4.0, 2.0]);
        let c = climatology_forecast(&[a, b]);
        assert_eq!(c.data, vec![2.0, 2.0]);
    }

    #[test]
    fn serve_counters_snapshot_and_hit_rate() {
        let c = ServeCounters::default();
        assert_eq!(c.snapshot().hit_rate(), 0.0);
        c.hit();
        c.hit();
        c.hit();
        c.miss();
        c.eviction();
        c.prefetch();
        let s = c.snapshot();
        assert_eq!((s.hits, s.misses, s.evictions, s.prefetches), (3, 1, 1, 1));
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        c.reset();
        assert_eq!(c.snapshot().hits, 0);
    }

    #[test]
    fn runlog_appends_jsonl() {
        let path = std::env::temp_dir().join("jigsaw_runlog_test.jsonl");
        let log = RunLog::create(path.to_str().unwrap()).unwrap();
        log.record(&[("step", 1.0), ("loss", 0.5)]).unwrap();
        log.record(&[("step", 2.0), ("loss", 0.4)]).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert_eq!(body.lines().count(), 2);
        assert!(body.contains("\"loss\":0.5"));
        let _ = std::fs::remove_file(path);
    }
}
