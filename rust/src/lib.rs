//! # Jigsaw — training multi-billion-parameter AI weather models with
//! optimized model parallelism
//!
//! A Rust + JAX + Pallas reproduction of *Kieckhefen et al., 2025*:
//! the **WeatherMixer** MLP-Mixer atmospheric model and **Jigsaw**
//! parallelism (combined tensor + domain parallelism with zero memory
//! redundancy).
//!
//! Three layers:
//! * **L1** (`python/compile/kernels/`) — Pallas kernels for the matmul
//!   hot-spots, AOT-lowered to HLO text;
//! * **L2** (`python/compile/model.py`) — the WeatherMixer forward /
//!   backward in JAX, exported once at build time;
//! * **L3** (this crate) — the distributed-training coordinator: the
//!   jigsaw block-matmul engine, simulated NCCL fabric, sharded data
//!   loading, optimizer, trainer, and the cluster performance model that
//!   regenerates the paper's evaluation at 256-GPU scale.
//!
//! Python never runs on the training path: the rust binary loads
//! `artifacts/**/*.hlo.txt` through the PJRT C API (`xla` crate) and is
//! self-contained afterwards.

pub mod baselines;
pub mod benchkit;
pub mod cli;
pub mod comm;
pub mod config;
pub mod data;
pub mod energy;
pub mod jigsaw;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod perfmodel;
pub mod runtime;
pub mod tensor;
pub mod trainer;
pub mod util;

pub use cli::cli_main;
