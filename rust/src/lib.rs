#![cfg_attr(feature = "simd", feature(portable_simd))]

//! # Jigsaw — training multi-billion-parameter AI weather models with
//! optimized model parallelism
//!
//! A Rust + JAX + Pallas reproduction of *Kieckhefen et al., 2025*:
//! the **WeatherMixer** MLP-Mixer atmospheric model and **Jigsaw**
//! parallelism (combined tensor + domain parallelism with zero memory
//! redundancy).
//!
//! Three layers:
//! * **L1** (`python/compile/kernels/`) — Pallas kernels for the matmul
//!   hot-spots, AOT-lowered to HLO text;
//! * **L2** (`python/compile/model.py`) — the WeatherMixer forward /
//!   backward in JAX, exported once at build time;
//! * **L3** (this crate) — the distributed-training coordinator: the
//!   jigsaw block-matmul engine, simulated NCCL fabric, sharded data
//!   loading, optimizer, trainer, and the cluster performance model that
//!   regenerates the paper's evaluation at 256-GPU scale.
//!
//! Parallelism is a first-class API ([`jigsaw::mesh`]): a
//! [`jigsaw::Mesh`] names the device grid's `tok x ch` axes, a
//! [`jigsaw::ShardSpec`] states which axis shards each tensor dimension,
//! and the [`jigsaw::Planner`] derives every block grid, owner map,
//! vector slice, and gradient sync group from them. The paper's 1-, 2-,
//! and 4-way schemes are the `1x1`, `1x2`, and `2x2` meshes (the planner
//! reproduces the hand-derived layouts bit-identically — golden-tested);
//! `2x4` and `4x4` extend the same machinery to 8- and 16-way jigsaw.
//! Everything downstream is mesh-keyed: `DistModel::new(cfg, &mesh, rank,
//! params)`, `Ctx` carries the mesh handle, `TrainSpec`/the CLI take a
//! mesh shape (`--mesh 2x4`), the sharded loader splits latitude and
//! channels along the mesh axes, and `perfmodel` prices arbitrary mesh
//! shapes (`BENCH_mesh.json` sweeps them on the real engine). Invalid
//! shapes surface as typed [`jigsaw::MeshError`]s, not panics.
//!
//! L3's compute substrate is the **view/kernel architecture** in
//! [`tensor`]: zero-copy strided views (`TensorView`/`TensorViewMut`)
//! carry block slices without allocation; cache-blocked, register-tiled
//! `_into` kernels (`tensor::ops`) write or accumulate into caller-owned
//! buffers, optionally across row-band threads
//! (`JIGSAW_KERNEL_THREADS`); a per-thread buffer pool (`tensor::pool`)
//! recycles matmul-sized temporaries so a steady-state train step
//! allocates nothing on the matmul path; and the seed's naive kernels
//! survive in `tensor::ref_kernels` as the differential-testing oracle.
//! The jigsaw engine ships blocks over the fabric as `Arc`-shared
//! messages (one materialization per block regardless of fan-out) and
//! reduces partial sums in place through `Backend::matmul_into`. The
//! fabric itself is non-blocking end to end ([`comm`]): `dist_matmul`
//! runs a ready-queue schedule (poll `try_recv`, compute whichever
//! term's operands arrived, post each partial sum as its accumulator
//! completes), collectives ride a ring reduce-scatter + allgather, and
//! the DP gradient reduction runs *under* the backward pass: a
//! grad-ready hook through `DistModel::loss_and_grad_with` streams each
//! finished gradient (reverse-layer order) into the trainer's
//! `GradReduceScheduler`, which packs flat buckets and posts each
//! bucket's in-flight ring (`comm::PackedAllreduce`) while earlier
//! layers still differentiate. Posted rings are registered with a
//! `comm::ProgressEngine` — a per-rank registry the kernel driver's
//! callback polls between register-tile row groups, at row-band
//! barriers, and inside every blocking fabric wait (the `dist_matmul`
//! dry-wait included) — so collectives advance during every matmul
//! between emissions and the pre-Adam drain is a short tail
//! (`BENCH_progress.json` pins it against emission-only polling). The
//! paper's isend/irecv overlap is measurable under the fabric's
//! injected-delay model (`BENCH_overlap.json`, `BENCH_dp_overlap.json`)
//! and bit-identical to the retained post-hoc `dp_allreduce_grads`
//! oracle. A failing rank aborts the fabric so peers unwind instead of
//! deadlocking (in-flight collective buffers recycle on the unwind);
//! the abort travels as a typed [`comm::CommError::Aborted`] panic
//! payload carrying the origin rank, so the trainer can tell peer-death
//! apart from genuine bugs and `train` names the rank that actually
//! failed.
//!
//! Failure is survivable, not just contained ([`checkpoint`]): every
//! `--checkpoint-every` steps each rank writes its parameter + Adam
//! shards (self-describing block-owner tables), each DP group persists
//! its loader cursor/RNG, and — only after a world barrier — rank 0
//! atomically publishes a checksummed manifest, so a kill at any
//! instant leaves a valid "latest". Restore assembles the saved shards
//! mesh-free and reshards them onto *any* viable mesh (train on 2x2,
//! resume on 1x2 or 4x4); `tests/checkpoint_props.rs` pins the oracle
//! that a resharded resume is bit-identical to an uninterrupted run on
//! the target mesh. `trainer::train_elastic` closes the loop: on a
//! typed rank failure it tears down both fabrics, shrinks the mesh
//! (drop a DP replica first, else `Mesh::shrink_for`), reloads the last
//! checkpoint, and keeps training — `BENCH_elastic.json` prices the
//! save/restore/reshard path.
//!
//! Compute density and fabric volume have first-class knobs. The `simd`
//! cargo feature (nightly) rewrites the kernels' 4x8 register tile on
//! explicit `std::simd` f32x8 lanes — bit-identical to the scalar tile
//! (separate multiply and add in the same element order), which stays
//! the stable-toolchain default and the oracle. A [`tensor::Precision`]
//! policy (`--precision bf16`) switches storage and fabric to software
//! bfloat16: activations quantize at layer boundaries, shipped jigsaw
//! blocks, partial sums, and DP ring chunks travel as u16 payloads
//! (half the bytes, counted exactly by the fabric's per-link stats and
//! priced by `perfmodel`'s bf16 column), while master weights, kernel
//! accumulation, and every reduction stay f32. A `trainer::GradScaler`
//! (dynamic loss scaling, power-of-two scales, overflow backoff) keeps
//! bf16 gradients finite; `BENCH_precision.json` pins the byte halving
//! and the bf16-vs-f32 loss tolerance the way `mesh_props` pins 1e-4.
//!
//! Correctness of the concurrency substrate is enforced by tooling,
//! not convention ([`vet`] + `docs/static-analysis.md`): the `vet`
//! binary lints every file under `rust/src` against a registry of
//! rules distilled from this repo's own shipped-and-fixed bugs
//! (poisoned-lock unwraps, condvar waits without a re-check loop, tag
//! bit-twiddling outside `next_coll_tag`, clock reads in kernel loops,
//! unpaired `pool::take`s, bare unwraps on fallible std calls), with
//! `// vet: allow(<rule>)` pragmas as the audited escape hatch and a
//! seeded-bad fixture corpus (`rust/xtask/fixtures/`) proving in CI
//! that every rule still fires. At runtime, [`comm`] carries a
//! wait-graph deadlock detector: every blocking fabric wait registers
//! the (rank, keys) it parks on, and before any waiter sleeps it runs
//! a greatest-fixpoint "knot" check over the who-waits-on-whom graph —
//! a true cycle (every member waiting on a queue-empty key from
//! another member) panics *immediately* with the full cycle named
//! (ranks + tags) as a typed [`comm::CommError::Deadlock`], instead of
//! hanging a CI job until timeout. It is on by default in debug/test
//! builds (`JIGSAW_DEADLOCK_DETECT` overrides either way) and a single
//! relaxed atomic load when off.
//!
//! Training is no longer the only consumer of the forward graph.
//! [`model::dist`] factors the WeatherMixer forward into a single
//! shared core with a `Retention` policy: the training path retains
//! the `FwdCache` for backward, while [`model::InferModel`] runs the
//! same core forward-only — no cache, no gradient registry, sync-
//! group-free parameter shards, every per-layer activation recycled
//! into the buffer pool as the next layer consumes it. The two paths
//! are pinned bit-identical (`tests/infer_props.rs`), so a served
//! forecast is byte-for-byte the forecast the trainer would score.
//! On top sits the serving engine ([`serve`]): per-rank worker
//! threads roll sharded-weight autoregressive forecasts (weights come
//! from checkpoint shards via `checkpoint::load_params` — never Adam
//! state), assembled global states land in a `(init_id, lead_step)`-
//! keyed LRU [`serve::TrajectoryCache`] with hit/miss/eviction
//! counters in [`metrics::ServeCounters`], and regional queries at
//! arbitrary lead times are answered as O(1) strided `TensorView`
//! windows of cached states. Serving issues no gradient collectives,
//! so the fabric capacity the trainer spends on `ProgressEngine` idle
//! polls funds next-step prefetch instead: the workers advance
//! `(init, lead+1)` while the serving thread drains queries
//! (`jigsaw serve`, `BENCH_serving.json`, `docs/serving.md`).
//!
//! Python never runs on the training path: the rust binary loads
//! `artifacts/**/*.hlo.txt` through the PJRT C API (`xla` crate, behind
//! the `pjrt` cargo feature; without it an API-identical engine serves
//! every matmul from the blocked native kernels) and is self-contained
//! afterwards.

pub mod baselines;
pub mod benchkit;
pub mod checkpoint;
pub mod cli;
pub mod comm;
pub mod config;
pub mod data;
pub mod energy;
pub mod jigsaw;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod perfmodel;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod trainer;
pub mod util;
pub mod vet;

pub use cli::cli_main;
