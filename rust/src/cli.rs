//! Command-line interface (hand-rolled: the offline registry has no clap).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::config::zoo::{ParallelPlan, ZooModel, TABLE1, TABLE2};
use crate::config::{artifacts_dir, Manifest, ModelConfig};
use crate::energy::{training_energy, PowerModel};
use crate::jigsaw::Mesh;
use crate::perfmodel::{
    peak_fraction, simulate_step, ClusterSpec, Precision, Workload,
};
use crate::runtime::engine::{Engine, PjrtBackend};
use crate::runtime::native::NativeBackend;
use crate::runtime::Backend;
use crate::checkpoint::CheckpointSpec;
use crate::trainer::{train, train_elastic, TrainSpec};
use crate::util::table::{fmt, Table};

/// Split an argv tail into positionals and `--key value` / `--key=value`
/// flags; a `--flag` followed by another flag (or nothing) parses as the
/// bare boolean `"true"`. Public so the examples share one grammar with
/// the binary instead of re-implementing a subset (the old train_e2e
/// copy lacked `=` and bare-flag forms and drifted).
pub fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                flags.insert(name.to_string(), it.next().unwrap().clone());
            } else {
                flags.insert(name.to_string(), "true".into());
            }
        } else {
            pos.push(a.clone());
        }
    }
    (pos, flags)
}

fn flag<T: std::str::FromStr>(
    flags: &HashMap<String, String>,
    key: &str,
    default: T,
) -> T {
    flags
        .get(key)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Resolve the jigsaw mesh from `--mesh TOKxCH` (preferred) or the
/// legacy `--way N` degree. Invalid shapes surface as typed MeshErrors.
/// Shared with the examples (train_e2e) so flag precedence never forks.
pub fn mesh_flag(flags: &HashMap<String, String>, default_way: usize) -> Result<Mesh> {
    let mesh = match flags.get("mesh") {
        Some(s) => Mesh::parse(s)?,
        None => Mesh::from_degree(flag(flags, "way", default_way))?,
    };
    Ok(mesh)
}

/// `--precision f32|bf16` for the engine commands (train, serve).
/// Junk values are a typed error — train's old `flag(..)` form silently
/// fell back to f32, which is exactly the kind of per-command drift
/// these shared helpers exist to kill.
pub fn precision_flag(
    flags: &HashMap<String, String>,
) -> Result<crate::tensor::Precision> {
    match flags.get("precision") {
        None => Ok(crate::tensor::Precision::F32),
        Some(s) => s.parse().map_err(|e: String| anyhow!("--precision: {e}")),
    }
}

/// `--precision fp32|tf32|bf16` for the perfmodel commands (simulate,
/// roofline), defaulting to tf32 (the paper's cluster math mode). Junk
/// values error instead of silently simulating tf32.
pub fn sim_precision_flag(flags: &HashMap<String, String>) -> Result<Precision> {
    match flags.get("precision").map(|s| s.as_str()) {
        None => Ok(Precision::Tf32),
        Some("fp32") => Ok(Precision::Fp32),
        Some("tf32") => Ok(Precision::Tf32),
        Some("bf16") => Ok(Precision::Bf16),
        Some(other) => bail!("--precision: unknown precision '{other}' (fp32|tf32|bf16)"),
    }
}

/// Build the compute backend: PJRT when artifacts exist, native otherwise
/// (or on `--backend native`).
pub fn make_backend(preset: &str, kind: &str) -> Result<Arc<dyn Backend>> {
    match kind {
        "native" => Ok(Arc::new(NativeBackend)),
        "pjrt" | "auto" => {
            match Manifest::load(&artifacts_dir(), preset) {
                Ok(m) => {
                    let engine = Engine::start(m)?;
                    Ok(Arc::new(PjrtBackend { engine }))
                }
                Err(e) if kind == "auto" => {
                    eprintln!(
                        "warning: artifacts for '{preset}' unavailable ({e}); using native backend"
                    );
                    Ok(Arc::new(NativeBackend))
                }
                Err(e) => Err(e),
            }
        }
        other => bail!("unknown backend '{other}' (native|pjrt|auto)"),
    }
}

pub fn cli_main(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let (pos, flags) = parse_flags(&args[1..]);
    match cmd.as_str() {
        "train" => cmd_train(&pos, &flags),
        "serve" => cmd_serve(&flags),
        "validate" => cmd_validate(&pos, &flags),
        "simulate" => cmd_simulate(&flags),
        "roofline" => cmd_roofline(&flags),
        "energy-report" => cmd_energy(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' — try `jigsaw help`"),
    }
}

fn print_usage() {
    println!(
        "jigsaw — WeatherMixer training with jigsaw model parallelism\n\
         \n\
         USAGE: jigsaw <command> [--flags]\n\
         \n\
         COMMANDS\n\
           train     --preset tiny --mesh 2x4 --dp 2 --steps 50 --lr 1e-3\n\
                     [--way N: legacy degree, N -> balanced mesh]\n\
                     [--precision f32|bf16: bf16 stores/ships 16-bit,\n\
                      f32 master weights + dynamic loss scaling]\n\
                     [--backend auto|pjrt|native] [--rollout 1] [--log path]\n\
                     [--checkpoint-dir d --checkpoint-every 25 --keep-last 3:\n\
                      sharded checkpoints + elastic recovery (shrink the\n\
                      mesh on rank failure, --max-recoveries 3)]\n\
                     [--resume: continue from the newest valid checkpoint,\n\
                      resharding onto the current mesh if it differs]\n\
           serve     --preset tiny --mesh 1x2 --precision f32|bf16\n\
                     [--checkpoint-dir d: weights from the newest valid\n\
                      checkpoint (params only; Adam state never loads)]\n\
                     [--cache-states 8: trajectory-cache LRU capacity]\n\
                     [--qps 0: paced query arrival, 0 = open loop]\n\
                     [--queries 64 --inits 2 --max-lead 8 --seed 0]\n\
                     [--fabric-latency-us N: inject simulated link delay]\n\
                     [--no-prefetch: disable next-step rollout overlap]\n\
           validate  --preset tiny --mesh 1x2  check mesh numerics vs the AOT oracle\n\
           simulate  --model 7 --mesh 2x2 --dp 8 --precision tf32|bf16 [--no-dataload]\n\
           roofline  [--precision fp32]      print the Fig-7 series\n\
           energy-report                     print the Table-3 accounting\n\
         \n\
         MESHES: TOKxCH device grids (1x2 = paper 2-way, 2x2 = 4-way,\n\
         2x4 = 8-way, 4x4 = 16-way); tok must not exceed ch.\n"
    );
}

fn cmd_train(_pos: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let preset: String = flag(flags, "preset", "tiny".to_string());
    let cfg = ModelConfig::load(&artifacts_dir(), &preset)?;
    let backend = make_backend(&preset, &flag(flags, "backend", "auto".to_string()))?;
    let mesh = mesh_flag(flags, 1)?;
    let mut spec = TrainSpec::with_mesh(
        mesh,
        flag(flags, "dp", 1usize),
        flag(flags, "steps", 50usize),
    );
    spec.lr = flag(flags, "lr", 1e-3f32);
    spec.max_rollout = flag(flags, "rollout", 1usize);
    spec.n_times = flag(flags, "ntimes", 32usize);
    spec.val_every = flag(flags, "val-every", 0usize);
    spec.seed = flag(flags, "seed", 0u64);
    spec.precision = precision_flag(flags)?;
    if let Some(dir) = flags.get("checkpoint-dir") {
        let mut ck = CheckpointSpec::new(dir);
        ck.every = flag(flags, "checkpoint-every", ck.every);
        ck.keep_last = flag(flags, "keep-last", ck.keep_last);
        spec.checkpoint = Some(ck);
    }
    spec.resume = flag(flags, "resume", false);
    println!(
        "training {} ({} params) mesh={} ({}-way) dp={} steps={} precision={} backend={}",
        cfg.name, cfg.param_count, spec.mesh, spec.way(), spec.dp, spec.steps,
        spec.precision, backend.name()
    );
    let report = if spec.checkpoint.is_some() {
        let out = train_elastic(
            &cfg,
            &spec,
            backend,
            flag(flags, "max-recoveries", 3usize),
        )?;
        for ev in &out.recoveries {
            println!(
                "recovered: mesh {} dp {} -> mesh {} dp {} (resume step {:?}) after: {}",
                ev.from_mesh, ev.from_dp, ev.to_mesh, ev.to_dp, ev.resumed_step,
                ev.failure
            );
        }
        out.report
    } else {
        train(&cfg, &spec, backend)?
    };
    if let Some(from) = report.resumed_from {
        println!("resumed from step {from}");
    }
    for s in report.steps.iter().step_by((spec.steps / 10).max(1)) {
        println!(
            "  step {:>4}  loss {:.5}  lr {:.2e}  rollout {}  read {} KiB",
            s.step, s.loss, s.lr, s.rollout, s.bytes_read / 1024
        );
    }
    if let Some(last) = report.steps.last() {
        println!("final loss {:.5}", last.loss);
    }
    println!("fabric bytes: {} KiB", report.comm_bytes / 1024);
    if let Some(path) = flags.get("log") {
        let log = crate::metrics::RunLog::create(path)?;
        for s in &report.steps {
            log.record(&[("step", s.step as f64), ("loss", s.loss as f64)])?;
        }
        println!("log written to {path}");
    }
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let preset: String = flag(flags, "preset", "tiny".to_string());
    let cfg = ModelConfig::load(&artifacts_dir(), &preset)?;
    let backend = make_backend(&preset, &flag(flags, "backend", "auto".to_string()))?;
    let mesh = mesh_flag(flags, 1)?;
    let precision = precision_flag(flags)?;
    let cache_states = flag(flags, "cache-states", 8usize);
    let qps = flag(flags, "qps", 0.0f64);
    let n_queries = flag(flags, "queries", 64usize);
    let max_lead = flag(flags, "max-lead", 8usize);
    let n_inits = flag(flags, "inits", 2usize);
    let seed = flag(flags, "seed", 0u64);
    let rollout = flag(flags, "rollout", 1usize);

    let global = match flags.get("checkpoint-dir") {
        Some(dir) => {
            let meta = crate::checkpoint::latest(std::path::Path::new(dir))?
                .ok_or_else(|| anyhow!("no valid checkpoint under {dir}"))?;
            println!("weights: checkpoint step {} under {dir}", meta.step);
            crate::checkpoint::load_params(&cfg, &meta)?
        }
        None => {
            println!("weights: fresh init (no --checkpoint-dir)");
            crate::model::init_global_params(&cfg, seed)
        }
    };

    let engine = crate::serve::RolloutEngine::new(
        &cfg, &mesh, &global, backend, precision, rollout,
    )?;
    if flags.contains_key("fabric-latency-us") {
        let us = flag(flags, "fabric-latency-us", 50u64);
        engine.set_fabric(crate::comm::FabricSpec::from_us(us, us / 4, 10.0), seed);
    }
    let prefetch = !flags.contains_key("no-prefetch");
    let mut srv =
        crate::serve::ServeEngine::new(engine, cache_states, max_lead, prefetch);

    let mut rng = crate::util::rng::Rng::seed_from(seed ^ 0x5EED_1D);
    for id in 0..n_inits as u64 {
        let mut d = vec![0.0f32; cfg.lat * cfg.lon * cfg.channels_padded];
        rng.fill_normal(&mut d, 1.0);
        srv.add_init(
            id,
            crate::tensor::Tensor::new(
                vec![cfg.lat, cfg.lon, cfg.channels_padded],
                d,
            ),
        )?;
    }

    println!(
        "serving {} mesh={} precision={} cache={} max_lead={} prefetch={} queries={}",
        cfg.name, mesh, precision, cache_states, max_lead, prefetch, n_queries
    );
    let mut traffic = crate::benchkit::TrafficGen::new(
        seed,
        n_inits as u64,
        max_lead,
        cfg.lat,
        cfg.lon,
    );
    let t0 = std::time::Instant::now();
    let mut lat_us: Vec<f64> = Vec::with_capacity(n_queries);
    let mut checksum = 0.0f64;
    for i in 0..n_queries {
        if qps > 0.0 {
            let due = t0 + std::time::Duration::from_secs_f64(i as f64 / qps);
            let now = std::time::Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
        }
        let q = traffic.next_query();
        let qt = std::time::Instant::now();
        let ans = srv.answer(q)?;
        checksum += ans.view().at(0, 0) as f64;
        lat_us.push(qt.elapsed().as_secs_f64() * 1e6);
    }
    let wall = t0.elapsed().as_secs_f64();
    lat_us.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    let pct = |p: usize| lat_us[(lat_us.len() * p / 100).min(lat_us.len() - 1)];
    let s = srv.stats();
    println!(
        "  {:.1} queries/s  p50 {:.0} us  p99 {:.0} us  (checksum {checksum:.3})",
        n_queries as f64 / wall,
        pct(50),
        pct(99),
    );
    println!(
        "  cache: {} hits  {} misses  {} evictions  {} prefetches  hit rate {:.0}%",
        s.hits,
        s.misses,
        s.evictions,
        s.prefetches,
        100.0 * s.hit_rate()
    );
    Ok(())
}

fn cmd_validate(_pos: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let preset: String = flag(flags, "preset", "tiny".to_string());
    let mesh = mesh_flag(flags, 2)?;
    let report = crate::trainer::oracle::validate_against_oracle(&preset, &mesh)?;
    println!("{report}");
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> Result<()> {
    let cluster = ClusterSpec::horeka();
    let id: usize = flag(flags, "model", 7usize);
    if !(1..=9).contains(&id) {
        return Err(anyhow!("--model must be 1..9 (Table 1)"));
    }
    let w = Workload {
        model: ZooModel::by_id(id),
        mesh: mesh_flag(flags, 1)?,
        dp: flag(flags, "dp", 1usize),
        precision: sim_precision_flag(flags)?,
        dataload: !flags.contains_key("no-dataload"),
    };
    let t = simulate_step(&cluster, &w);
    println!(
        "model {} ({} TFLOPs/fwd, {} M params) mesh={} ({}-way) dp={} {:?}",
        id, w.model.tflops_fwd, w.model.params_mil, w.mesh, w.way(), w.dp, w.precision
    );
    println!("  io        {:>9.4} s", t.io);
    println!("  compute   {:>9.4} s", t.compute);
    println!("  mp comm   {:>9.4} s (exposed {:.4})", t.mp_comm, t.mp_comm_exposed);
    println!("  dp comm   {:>9.4} s (exposed {:.4})", t.dp_comm, t.dp_comm_exposed);
    println!("  step      {:>9.4} s", t.total);
    println!(
        "  perf      {:>9.2} TFLOP/s/GPU ({:.0}% of peak)",
        crate::perfmodel::flops_per_gpu(&cluster, &w) / 1e12,
        100.0 * peak_fraction(&cluster, &w)
    );
    Ok(())
}

fn cmd_roofline(flags: &HashMap<String, String>) -> Result<()> {
    let cluster = ClusterSpec::horeka();
    let precision = sim_precision_flag(flags)?;
    let mut t = Table::new(&[
        "TFLOPs/fwd", "1x1", "1x2", "2x2", "2x4", "4x4", "unit",
    ]);
    for m in TABLE1 {
        let frac = |mesh: Mesh| -> String {
            if mesh.n() == 2 && m.params_mil > 2000.0 {
                return "-".into();
            }
            let w = Workload { model: m, mesh, dp: 1, precision, dataload: true };
            fmt(crate::perfmodel::flops_per_gpu(&cluster, &w) / 1e12)
        };
        t.row(&[
            fmt(m.tflops_fwd),
            frac(Mesh::unit()),
            frac(Mesh::from_degree(2).unwrap()),
            frac(Mesh::from_degree(4).unwrap()),
            frac(Mesh::from_degree(8).unwrap()),
            frac(Mesh::from_degree(16).unwrap()),
            "TFLOP/s/GPU".into(),
        ]);
    }
    println!("Roofline ({precision:?}), full training loop:\n{}", t.render());
    Ok(())
}

fn cmd_energy(_flags: &HashMap<String, String>) -> Result<()> {
    let cluster = ClusterSpec::horeka();
    let power = PowerModel::horeka();
    let mut t = Table::new(&["Experiment", "kWh", "CO2e kg", "GPUh"]);
    for plan in TABLE2 {
        let w = Workload {
            model: nearest_model(plan),
            mesh: plan.mesh()?,
            dp: 8 / plan.way,
            precision: Precision::Tf32,
            dataload: true,
        };
        // paper: 100 epochs x ~2338 optimizer steps (6h-subsampled ERA5)
        let r = training_energy(&cluster, &power, &w, 100 * 2338);
        t.row(&[
            format!("{}-way", plan.way),
            fmt(r.kwh),
            fmt(r.co2e_kg),
            fmt(r.gpu_hours),
        ]);
    }
    println!("Energy accounting (simulated HoreKa):\n{}", t.render());
    Ok(())
}

/// Build the ZooModel for a Table-2 plan: the plan's exact FLOPs/params
/// (which sit between Table-1 rows) with the nearest row's dims.
pub fn nearest_model(plan: ParallelPlan) -> ZooModel {
    let row = *TABLE1
        .iter()
        .min_by(|a, b| {
            let da = (a.params_mil - plan.params_mil).abs();
            let db = (b.params_mil - plan.params_mil).abs();
            da.partial_cmp(&db).unwrap()
        })
        .unwrap();
    ZooModel {
        tflops_fwd: plan.tflops_fwd,
        params_mil: plan.params_mil,
        ..row
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_flags_forms() {
        let args: Vec<String> = ["--a=1", "--b", "2", "--c", "pos"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let (pos, flags) = parse_flags(&args);
        assert_eq!(flags["a"], "1");
        assert_eq!(flags["b"], "2");
        assert_eq!(flags["c"], "pos"); // greedy value
        assert!(pos.is_empty());
    }

    #[test]
    fn flag_parses_with_default() {
        let mut flags = HashMap::new();
        flags.insert("x".to_string(), "7".to_string());
        assert_eq!(flag(&flags, "x", 0usize), 7);
        assert_eq!(flag(&flags, "missing", 3usize), 3);
    }

    #[test]
    fn unknown_command_errors() {
        let args = vec!["wat".to_string()];
        assert!(cli_main(&args).is_err());
    }

    #[test]
    fn roofline_and_simulate_run() {
        cli_main(&["roofline".to_string()]).unwrap();
        cli_main(&["simulate".to_string(), "--model".into(), "3".into()]).unwrap();
        cli_main(&[
            "simulate".to_string(),
            "--model".into(),
            "3".into(),
            "--mesh".into(),
            "2x4".into(),
        ])
        .unwrap();
        cli_main(&[
            "simulate".to_string(),
            "--model".into(),
            "3".into(),
            "--precision".into(),
            "bf16".into(),
        ])
        .unwrap();
        cli_main(&["energy-report".to_string()]).unwrap();
    }

    #[test]
    fn junk_precision_is_a_clean_cli_error() {
        // simulate used to silently fall back to tf32 on junk; now both
        // precision grammars reject it through the shared helpers
        let err = cli_main(&[
            "simulate".to_string(),
            "--precision".into(),
            "f64".into(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("f64"), "{err}");
        let mut flags = HashMap::new();
        flags.insert("precision".to_string(), "wat".to_string());
        assert!(precision_flag(&flags).unwrap_err().to_string().contains("wat"));
        assert!(sim_precision_flag(&flags)
            .unwrap_err()
            .to_string()
            .contains("wat"));
        // tf32 is now an accepted spelling of the simulate default
        flags.insert("precision".to_string(), "tf32".to_string());
        assert_eq!(sim_precision_flag(&flags).unwrap(), Precision::Tf32);
        // bare `--precision` (no value) parses as "true" -> clean error,
        // the bare-flag form train gained in the checkpoint PR
        flags.insert("precision".to_string(), "true".to_string());
        assert!(precision_flag(&flags).is_err());
    }

    #[test]
    fn zero_way_is_a_clean_cli_error() {
        // `--way 0` used to reach TrainSpec::quick's `expect("nonzero
        // way")` panic path; it must surface as a typed degree error
        let err = cli_main(&[
            "simulate".to_string(),
            "--way".into(),
            "0".into(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("degree 0"), "{err}");
    }

    #[test]
    fn invalid_mesh_is_a_clean_cli_error() {
        // a 4x2 mesh cannot keep zero weight redundancy: typed MeshError,
        // surfaced through the CLI instead of a panic
        let err = cli_main(&[
            "simulate".to_string(),
            "--mesh".into(),
            "4x2".into(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("tok"), "{err}");
        let err = cli_main(&[
            "simulate".to_string(),
            "--mesh".into(),
            "wat".into(),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("parse"), "{err}");
    }
}
