//! Host tensor: row-major f32 buffers with the block/partition algebra the
//! jigsaw engine is built on.
//!
//! This is deliberately minimal — device compute happens in the PJRT
//! runtime (or the native fallback backend); the tensor type exists to
//! carry shards between ranks, slice/assemble jigsaw blocks, and implement
//! the cheap pointwise stages of the model natively.
//!
//! Sub-modules:
//! * [`view`] — zero-copy strided views (`TensorView`/`TensorViewMut`);
//!   row/column/block slicing without allocation, the substrate of the
//!   blocked kernels;
//! * [`ops`] — the optimized kernel layer (blocked `_into` matmuls with an
//!   optional `std::simd` inner tile behind the `simd` feature, pointwise
//!   stages);
//! * [`ref_kernels`] — the retained naive matmuls, the property-test
//!   oracle for `ops`;
//! * [`pool`] — per-thread buffer recycling, keyed by (capacity, elem
//!   kind), so steady-state training does no matmul-sized heap
//!   allocations in either f32 or bf16 mode;
//! * [`bf16`] — software bfloat16 (round-to-nearest-even u16 storage),
//!   [`bf16::Bf16Tensor`] fabric payloads, and the [`bf16::Precision`]
//!   policy the mixed-precision path threads through the engine.

pub mod bf16;
pub mod ops;
pub mod pool;
pub mod ref_kernels;
pub mod view;

pub use bf16::{Bf16Tensor, Precision};
pub use view::{TensorView, TensorViewMut};

/// Row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} vs data len {}",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Rows/cols of a 2-D tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.rank(), 2, "expected 2-D, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {:?}",
            self.shape,
            shape
        );
        self.shape = shape.to_vec();
        self
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    /// Zero-copy view of a 2-D tensor.
    pub fn view2(&self) -> TensorView<'_> {
        let (r, c) = self.dims2();
        TensorView::new(&self.data, r, c, c)
    }

    /// Zero-copy mutable view of a 2-D tensor.
    pub fn view2_mut(&mut self) -> TensorViewMut<'_> {
        let (r, c) = self.dims2();
        TensorViewMut::new(&mut self.data, r, c, c)
    }

    /// Column-range slice of a 2-D tensor (materialized; use
    /// `view2().slice_cols(..)` for the O(1) borrow).
    pub fn slice_cols(&self, lo: usize, hi: usize) -> Tensor {
        self.view2().slice_cols(lo, hi).to_tensor()
    }

    /// Row-range slice of a 2-D tensor (materialized; use
    /// `view2().slice_rows(..)` for the O(1) borrow).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Tensor {
        self.view2().slice_rows(lo, hi).to_tensor()
    }

    /// Block (bi, bj) of a 2-D tensor split into rb x cb equal blocks
    /// (materialized; use `view2().block(..)` for the O(1) borrow).
    pub fn block(&self, bi: usize, bj: usize, rb: usize, cb: usize) -> Tensor {
        self.view2().block(bi, bj, rb, cb).to_tensor()
    }

    /// Inverse of `block`: assemble an rb x cb grid of equal blocks.
    pub fn from_blocks(blocks: &[Vec<Tensor>]) -> Tensor {
        let rb = blocks.len();
        let cb = blocks[0].len();
        let (br, bc) = blocks[0][0].dims2();
        for row in blocks {
            assert_eq!(row.len(), cb);
            for b in row {
                assert_eq!(b.dims2(), (br, bc), "ragged blocks");
            }
        }
        let mut out = Tensor::zeros(&[rb * br, cb * bc]);
        for (bi, row) in blocks.iter().enumerate() {
            for (bj, b) in row.iter().enumerate() {
                out.view2_mut()
                    .into_block(bi, bj, rb, cb)
                    .copy_from(b.view2());
            }
        }
        out
    }

    /// Transpose a 2-D tensor (materialized; used off the hot path only —
    /// the jigsaw matmuls use nt/nn/tn primitives instead).
    pub fn transposed(&self) -> Tensor {
        let (r, c) = self.dims2();
        let mut data = vec![0.0; r * c];
        for i in 0..r {
            for j in 0..c {
                data[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::new(vec![c, r], data)
    }

    /// Zero-pad a 2-D tensor to [rows, cols].
    pub fn pad_to(&self, rows: usize, cols: usize) -> Tensor {
        let (r, c) = self.dims2();
        assert!(rows >= r && cols >= c);
        if rows == r && cols == c {
            return self.clone();
        }
        let mut data = vec![0.0; rows * cols];
        for i in 0..r {
            data[i * cols..i * cols + c]
                .copy_from_slice(&self.data[i * c..(i + 1) * c]);
        }
        Tensor::new(vec![rows, cols], data)
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn frobenius_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(r: usize, c: usize) -> Tensor {
        Tensor::new(vec![r, c], (0..r * c).map(|v| v as f32).collect())
    }

    #[test]
    fn block_roundtrip() {
        let t = t2(6, 8);
        let blocks: Vec<Vec<Tensor>> = (0..2)
            .map(|i| (0..4).map(|j| t.block(i, j, 2, 4)).collect())
            .collect();
        assert_eq!(Tensor::from_blocks(&blocks), t);
    }

    #[test]
    fn slice_cols_values() {
        let t = t2(2, 4);
        let s = t.slice_cols(1, 3);
        assert_eq!(s.data, vec![1.0, 2.0, 5.0, 6.0]);
    }

    #[test]
    fn slice_rows_values() {
        let t = t2(3, 2);
        let s = t.slice_rows(1, 2);
        assert_eq!(s.data, vec![2.0, 3.0]);
    }

    #[test]
    fn transpose_involution() {
        let t = t2(3, 5);
        assert_eq!(t.transposed().transposed(), t);
    }

    #[test]
    fn pad_to_extends_with_zeros() {
        let t = t2(2, 2);
        let p = t.pad_to(3, 4);
        assert_eq!(p.shape, vec![3, 4]);
        assert_eq!(p.at2(0, 0), 0.0);
        assert_eq!(p.at2(1, 1), 3.0);
        assert_eq!(p.at2(2, 3), 0.0);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn new_checks_len() {
        Tensor::new(vec![2, 2], vec![0.0; 3]);
    }
}
