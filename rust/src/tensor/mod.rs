//! Host tensor: row-major f32 buffers with the block/partition algebra the
//! jigsaw engine is built on.
//!
//! This is deliberately minimal — device compute happens in the PJRT
//! runtime (or the native fallback backend); the tensor type exists to
//! carry shards between ranks, slice/assemble jigsaw blocks, and implement
//! the cheap pointwise stages of the model natively.

pub mod ops;

/// Row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} vs data len {}",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Rows/cols of a 2-D tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.rank(), 2, "expected 2-D, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {:?}",
            self.shape,
            shape
        );
        self.shape = shape.to_vec();
        self
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    /// Contiguous column-range slice of a 2-D tensor.
    pub fn slice_cols(&self, lo: usize, hi: usize) -> Tensor {
        let (r, c) = self.dims2();
        assert!(lo <= hi && hi <= c);
        let w = hi - lo;
        let mut data = Vec::with_capacity(r * w);
        for i in 0..r {
            data.extend_from_slice(&self.data[i * c + lo..i * c + hi]);
        }
        Tensor::new(vec![r, w], data)
    }

    /// Contiguous row-range slice of a 2-D tensor.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Tensor {
        let (r, c) = self.dims2();
        assert!(lo <= hi && hi <= r);
        Tensor::new(vec![hi - lo, c], self.data[lo * c..hi * c].to_vec())
    }

    /// Block (bi, bj) of a 2-D tensor split into rb x cb equal blocks.
    pub fn block(&self, bi: usize, bj: usize, rb: usize, cb: usize) -> Tensor {
        let (r, c) = self.dims2();
        assert!(r % rb == 0 && c % cb == 0, "{}x{} into {}x{} blocks", r, c, rb, cb);
        let (br, bc) = (r / rb, c / cb);
        let mut data = Vec::with_capacity(br * bc);
        for i in 0..br {
            let row = (bi * br + i) * c + bj * bc;
            data.extend_from_slice(&self.data[row..row + bc]);
        }
        Tensor::new(vec![br, bc], data)
    }

    /// Inverse of `block`: assemble an rb x cb grid of equal blocks.
    pub fn from_blocks(blocks: &[Vec<Tensor>]) -> Tensor {
        let rb = blocks.len();
        let cb = blocks[0].len();
        let (br, bc) = blocks[0][0].dims2();
        for row in blocks {
            assert_eq!(row.len(), cb);
            for b in row {
                assert_eq!(b.dims2(), (br, bc), "ragged blocks");
            }
        }
        let (r, c) = (rb * br, cb * bc);
        let mut data = vec![0.0; r * c];
        for (bi, row) in blocks.iter().enumerate() {
            for (bj, b) in row.iter().enumerate() {
                for i in 0..br {
                    let src = &b.data[i * bc..(i + 1) * bc];
                    let dst = (bi * br + i) * c + bj * bc;
                    data[dst..dst + bc].copy_from_slice(src);
                }
            }
        }
        Tensor::new(vec![r, c], data)
    }

    /// Transpose a 2-D tensor (materialized; used off the hot path only —
    /// the jigsaw matmuls use nt/nn/tn primitives instead).
    pub fn transposed(&self) -> Tensor {
        let (r, c) = self.dims2();
        let mut data = vec![0.0; r * c];
        for i in 0..r {
            for j in 0..c {
                data[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::new(vec![c, r], data)
    }

    /// Zero-pad a 2-D tensor to [rows, cols].
    pub fn pad_to(&self, rows: usize, cols: usize) -> Tensor {
        let (r, c) = self.dims2();
        assert!(rows >= r && cols >= c);
        if rows == r && cols == c {
            return self.clone();
        }
        let mut data = vec![0.0; rows * cols];
        for i in 0..r {
            data[i * cols..i * cols + c]
                .copy_from_slice(&self.data[i * c..(i + 1) * c]);
        }
        Tensor::new(vec![rows, cols], data)
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn frobenius_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(r: usize, c: usize) -> Tensor {
        Tensor::new(vec![r, c], (0..r * c).map(|v| v as f32).collect())
    }

    #[test]
    fn block_roundtrip() {
        let t = t2(6, 8);
        let blocks: Vec<Vec<Tensor>> = (0..2)
            .map(|i| (0..4).map(|j| t.block(i, j, 2, 4)).collect())
            .collect();
        assert_eq!(Tensor::from_blocks(&blocks), t);
    }

    #[test]
    fn slice_cols_values() {
        let t = t2(2, 4);
        let s = t.slice_cols(1, 3);
        assert_eq!(s.data, vec![1.0, 2.0, 5.0, 6.0]);
    }

    #[test]
    fn slice_rows_values() {
        let t = t2(3, 2);
        let s = t.slice_rows(1, 2);
        assert_eq!(s.data, vec![2.0, 3.0]);
    }

    #[test]
    fn transpose_involution() {
        let t = t2(3, 5);
        assert_eq!(t.transposed().transposed(), t);
    }

    #[test]
    fn pad_to_extends_with_zeros() {
        let t = t2(2, 2);
        let p = t.pad_to(3, 4);
        assert_eq!(p.shape, vec![3, 4]);
        assert_eq!(p.at2(0, 0), 0.0);
        assert_eq!(p.at2(1, 1), 3.0);
        assert_eq!(p.at2(2, 3), 0.0);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn new_checks_len() {
        Tensor::new(vec![2, 2], vec![0.0; 3]);
    }
}
