//! Zero-copy strided 2-D views over `Tensor` storage.
//!
//! A view is (base slice, rows, cols, row stride): `slice_rows`,
//! `slice_cols`, and jigsaw block extraction become O(1) borrows instead
//! of per-call allocations, and the blocked kernels in `ops` read/write
//! through views so one packed output buffer can back many logical
//! sub-matrices.
//!
//! Safety model: everything here is safe Rust. Mutable views hand out
//! disjoint row bands via `split_at_rows` (built on `split_at_mut`), which
//! is what the thread-parallel kernel driver uses to farm out bands
//! without copies or locks. The invariant `stride >= cols` guarantees the
//! rows of a view never overlap.

use super::Tensor;

/// Immutable strided view of a 2-D matrix.
#[derive(Clone, Copy, Debug)]
pub struct TensorView<'a> {
    pub(crate) data: &'a [f32],
    pub(crate) rows: usize,
    pub(crate) cols: usize,
    pub(crate) stride: usize,
}

fn check_extent(len: usize, rows: usize, cols: usize, stride: usize) {
    assert!(stride >= cols, "stride {stride} < cols {cols}");
    if rows > 0 && cols > 0 {
        let need = (rows - 1) * stride + cols;
        assert!(len >= need, "view needs {need} elems, slice has {len}");
    }
}

impl<'a> TensorView<'a> {
    pub fn new(data: &'a [f32], rows: usize, cols: usize, stride: usize) -> Self {
        check_extent(data.len(), rows, cols, stride);
        TensorView { data, rows, cols, stride }
    }

    pub fn nrows(&self) -> usize {
        self.rows
    }

    pub fn ncols(&self) -> usize {
        self.cols
    }

    pub fn row_stride(&self) -> usize {
        self.stride
    }

    pub fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// One row as a contiguous slice.
    #[inline]
    pub fn row(&self, i: usize) -> &'a [f32] {
        debug_assert!(i < self.rows);
        if self.cols == 0 {
            return &[];
        }
        &self.data[i * self.stride..i * self.stride + self.cols]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.stride + j]
    }

    /// Row-range sub-view (O(1), no copy).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> TensorView<'a> {
        assert!(lo <= hi && hi <= self.rows, "rows {lo}..{hi} of {}", self.rows);
        let data = if hi > lo { &self.data[lo * self.stride..] } else { &self.data[..0] };
        TensorView { data, rows: hi - lo, cols: self.cols, stride: self.stride }
    }

    /// Column-range sub-view (O(1), no copy).
    pub fn slice_cols(&self, lo: usize, hi: usize) -> TensorView<'a> {
        assert!(lo <= hi && hi <= self.cols, "cols {lo}..{hi} of {}", self.cols);
        let data = if hi > lo && self.rows > 0 { &self.data[lo..] } else { &self.data[..0] };
        TensorView { data, rows: self.rows, cols: hi - lo, stride: self.stride }
    }

    /// Block (bi, bj) of this matrix split into an rb x cb grid (O(1)).
    pub fn block(&self, bi: usize, bj: usize, rb: usize, cb: usize) -> TensorView<'a> {
        assert!(
            self.rows % rb == 0 && self.cols % cb == 0,
            "{}x{} into {}x{} blocks",
            self.rows,
            self.cols,
            rb,
            cb
        );
        let (br, bc) = (self.rows / rb, self.cols / cb);
        self.slice_rows(bi * br, (bi + 1) * br)
            .slice_cols(bj * bc, (bj + 1) * bc)
    }

    /// True when the rows are adjacent in memory (single memcpy suffices).
    pub fn is_contiguous(&self) -> bool {
        self.stride == self.cols || self.rows <= 1
    }

    /// Materialize into an owned tensor (the only copying operation here).
    pub fn to_tensor(&self) -> Tensor {
        let mut data = Vec::with_capacity(self.rows * self.cols);
        if self.is_contiguous() && self.rows > 0 && self.cols > 0 {
            data.extend_from_slice(&self.data[..self.rows * self.cols]);
        } else {
            for i in 0..self.rows {
                data.extend_from_slice(self.row(i));
            }
        }
        Tensor { shape: vec![self.rows, self.cols], data }
    }

    pub fn max_abs_diff(&self, other: &TensorView<'_>) -> f32 {
        assert_eq!(self.dims(), other.dims());
        let mut m = 0.0f32;
        for i in 0..self.rows {
            for (a, b) in self.row(i).iter().zip(other.row(i)) {
                m = m.max((a - b).abs());
            }
        }
        m
    }
}

/// Mutable strided view of a 2-D matrix.
#[derive(Debug)]
pub struct TensorViewMut<'a> {
    pub(crate) data: &'a mut [f32],
    pub(crate) rows: usize,
    pub(crate) cols: usize,
    pub(crate) stride: usize,
}

impl<'a> TensorViewMut<'a> {
    pub fn new(data: &'a mut [f32], rows: usize, cols: usize, stride: usize) -> Self {
        check_extent(data.len(), rows, cols, stride);
        TensorViewMut { data, rows, cols, stride }
    }

    pub fn nrows(&self) -> usize {
        self.rows
    }

    pub fn ncols(&self) -> usize {
        self.cols
    }

    pub fn row_stride(&self) -> usize {
        self.stride
    }

    pub fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        if self.cols == 0 {
            return &mut [];
        }
        &mut self.data[i * self.stride..i * self.stride + self.cols]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.stride + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.stride + j] = v;
    }

    /// Reborrow as an immutable view.
    pub fn as_view(&self) -> TensorView<'_> {
        TensorView { data: self.data, rows: self.rows, cols: self.cols, stride: self.stride }
    }

    /// Split into two disjoint row bands at row `r` (consumes the view —
    /// the parallel kernel driver hands each band to its own thread).
    pub fn split_at_rows(self, r: usize) -> (TensorViewMut<'a>, TensorViewMut<'a>) {
        assert!(r <= self.rows, "split at {r} of {} rows", self.rows);
        let off = (r * self.stride).min(self.data.len());
        let (top, bot) = self.data.split_at_mut(off);
        (
            TensorViewMut { data: top, rows: r, cols: self.cols, stride: self.stride },
            TensorViewMut {
                data: bot,
                rows: self.rows - r,
                cols: self.cols,
                stride: self.stride,
            },
        )
    }

    /// Row-range sub-view (consuming; O(1)).
    pub fn into_rows(self, lo: usize, hi: usize) -> TensorViewMut<'a> {
        assert!(lo <= hi && hi <= self.rows);
        let data = if hi > lo {
            &mut self.data[lo * self.stride..]
        } else {
            &mut self.data[..0]
        };
        TensorViewMut { data, rows: hi - lo, cols: self.cols, stride: self.stride }
    }

    /// Column-range sub-view (consuming; O(1)).
    pub fn into_cols(self, lo: usize, hi: usize) -> TensorViewMut<'a> {
        assert!(lo <= hi && hi <= self.cols);
        let data = if hi > lo && self.rows > 0 {
            &mut self.data[lo..]
        } else {
            &mut self.data[..0]
        };
        TensorViewMut { data, rows: self.rows, cols: hi - lo, stride: self.stride }
    }

    /// Block (bi, bj) of an rb x cb grid (consuming; O(1)).
    pub fn into_block(self, bi: usize, bj: usize, rb: usize, cb: usize) -> TensorViewMut<'a> {
        assert!(self.rows % rb == 0 && self.cols % cb == 0);
        let (br, bc) = (self.rows / rb, self.cols / cb);
        self.into_rows(bi * br, (bi + 1) * br)
            .into_cols(bj * bc, (bj + 1) * bc)
    }

    pub fn fill(&mut self, v: f32) {
        for i in 0..self.rows {
            self.row_mut(i).fill(v);
        }
    }

    /// Copy `src` into this view row by row.
    pub fn copy_from(&mut self, src: TensorView<'_>) {
        assert_eq!(self.dims(), src.dims(), "copy_from shape mismatch");
        for i in 0..self.rows {
            self.row_mut(i).copy_from_slice(src.row(i));
        }
    }

    /// Elementwise add `src` into this view.
    pub fn add_from(&mut self, src: TensorView<'_>) {
        assert_eq!(self.dims(), src.dims(), "add_from shape mismatch");
        for i in 0..self.rows {
            for (d, s) in self.row_mut(i).iter_mut().zip(src.row(i)) {
                *d += s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(r: usize, c: usize) -> Tensor {
        Tensor::new(vec![r, c], (0..r * c).map(|v| v as f32).collect())
    }

    #[test]
    fn view_row_col_slicing_matches_copying() {
        let t = t2(6, 8);
        let v = t.view2();
        assert_eq!(v.slice_rows(1, 4).to_tensor(), t.slice_rows(1, 4));
        assert_eq!(v.slice_cols(2, 7).to_tensor(), t.slice_cols(2, 7));
        assert_eq!(
            v.slice_rows(2, 6).slice_cols(1, 5).to_tensor(),
            t.slice_rows(2, 6).slice_cols(1, 5)
        );
    }

    #[test]
    fn view_block_matches_tensor_block() {
        let t = t2(6, 8);
        for bi in 0..2 {
            for bj in 0..4 {
                assert_eq!(t.view2().block(bi, bj, 2, 4).to_tensor(), t.block(bi, bj, 2, 4));
            }
        }
    }

    #[test]
    fn split_at_rows_is_disjoint_and_complete() {
        let mut t = t2(5, 3);
        let v = t.view2_mut();
        let (mut top, mut bot) = v.split_at_rows(2);
        top.fill(1.0);
        bot.fill(2.0);
        assert_eq!(t.data[..6], vec![1.0; 6][..]);
        assert_eq!(t.data[6..], vec![2.0; 9][..]);
    }

    #[test]
    fn split_at_rows_edges() {
        let mut t = t2(3, 4);
        let (top, bot) = t.view2_mut().split_at_rows(0);
        assert_eq!((top.nrows(), bot.nrows()), (0, 3));
        let (top, bot) = t.view2_mut().split_at_rows(3);
        assert_eq!((top.nrows(), bot.nrows()), (3, 0));
    }

    #[test]
    fn copy_and_add_between_strided_views() {
        let src = t2(4, 6);
        let mut dst = Tensor::zeros(&[4, 6]);
        {
            let sv = src.view2().slice_cols(1, 4);
            let mut dv = dst.view2_mut().into_cols(1, 4);
            dv.copy_from(sv);
            dv.add_from(sv);
        }
        assert_eq!(dst.at2(0, 1), 2.0 * src.at2(0, 1));
        assert_eq!(dst.at2(3, 3), 2.0 * src.at2(3, 3));
        assert_eq!(dst.at2(0, 0), 0.0);
        assert_eq!(dst.at2(0, 5), 0.0);
    }

    #[test]
    fn contiguity() {
        let t = t2(4, 4);
        assert!(t.view2().is_contiguous());
        assert!(!t.view2().slice_cols(0, 2).is_contiguous());
        assert!(t.view2().slice_rows(1, 2).slice_cols(0, 2).is_contiguous());
    }
}
