//! Software bfloat16: u16 storage with round-to-nearest-even conversion,
//! and the crate-wide [`Precision`] policy.
//!
//! bf16 keeps f32's 8-bit exponent and truncates the mantissa to 7 bits,
//! so conversion is a pure bit operation on the high half of the f32
//! word — no libm, no lookup tables. Everything numeric stays f32: the
//! mixed-precision recipe here is *storage and fabric* in bf16 (shipped
//! activation blocks, partial sums, DP gradient ring chunks, cached
//! activations) with f32 master weights and f32 accumulation everywhere
//! values are combined. [`Bf16Tensor`] is the carrier: a shaped `Vec<u16>`
//! backed by the (elem-kind-keyed) thread-local buffer pool, shippable
//! through `comm` as a first-class payload so per-link byte accounting
//! sees the real 2-bytes-per-element wire size.
//!
//! Rounding is round-to-nearest-even (the IEEE default, and what every
//! hardware bf16 cast implements): add `0x7fff + lsb` to the f32 bits and
//! truncate. NaNs are quieted with their sign preserved instead of being
//! rounded (rounding a NaN's mantissa can carry into the exponent and
//! produce infinity); infinities and subnormals fall out of the bit
//! arithmetic correctly.

use std::str::FromStr;

use super::{pool, Tensor};

/// Numeric storage/fabric policy, threaded through `Ctx`/`DistModel`/
/// `Comm`/`TrainSpec`. `F32` is the default and keeps every code path
/// bit-identical to the pre-precision engine; `Bf16` stores activations
/// and ships every fabric payload in 16 bits (f32 master weights, f32
/// accumulation, loss scaling in the trainer).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    #[default]
    F32,
    Bf16,
}

impl Precision {
    /// Bytes per element actually moved on the wire for payloads under
    /// this policy (collective chunks, shipped blocks, partial sums).
    pub fn wire_bytes_per_elem(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::Bf16 => 2,
        }
    }
}

impl FromStr for Precision {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "f32" | "fp32" => Ok(Precision::F32),
            "bf16" => Ok(Precision::Bf16),
            other => Err(format!("unknown precision '{other}' (f32|bf16)")),
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
        })
    }
}

/// f32 -> bf16 with round-to-nearest-even. NaN payloads are quieted (top
/// mantissa bit forced) rather than rounded.
#[inline(always)]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = 0x7fff + ((bits >> 16) & 1);
    ((bits + round) >> 16) as u16
}

/// bf16 -> f32 (exact: bf16 values are a subset of f32).
#[inline(always)]
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Round an f32 to the nearest representable bf16 value (RNE), staying
/// in f32. The activation-storage primitive: a value stored in bf16 and
/// read back is exactly `quantize` of the original.
#[inline(always)]
pub fn quantize(x: f32) -> f32 {
    bf16_to_f32(f32_to_bf16(x))
}

/// Quantize a buffer in place (activation blocks at layer boundaries).
pub fn quantize_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = quantize(*x);
    }
}

/// Shaped bf16 tensor: the 16-bit twin of [`Tensor`], used for fabric
/// payloads and cached activations. Buffers come from the u16 side of
/// the thread-local pool (`pool::take_u16`), so steady-state bf16
/// training recycles them exactly like the f32 hot-path buffers — and
/// never contends with the f32 free list (the pool keys by elem kind).
#[derive(Clone, Debug, PartialEq)]
pub struct Bf16Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<u16>,
}

impl Bf16Tensor {
    /// Quantize an f32 slice into a pooled bf16 buffer.
    pub fn from_f32(shape: &[usize], src: &[f32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), src.len());
        let mut data = pool::take_u16(src.len());
        for (d, &s) in data.iter_mut().zip(src.iter()) {
            *d = f32_to_bf16(s);
        }
        Bf16Tensor { shape: shape.to_vec(), data }
    }

    pub fn from_tensor(t: &Tensor) -> Self {
        Self::from_f32(&t.shape, &t.data)
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Widen into a pooled f32 tensor.
    pub fn to_tensor(&self) -> Tensor {
        let mut t = Tensor::pooled_zeros(&self.shape);
        self.copy_into(&mut t.data);
        t
    }

    /// dst[i] = f32(self[i]) — the allgather install step.
    pub fn copy_into(&self, dst: &mut [f32]) {
        assert_eq!(dst.len(), self.data.len());
        for (d, &h) in dst.iter_mut().zip(self.data.iter()) {
            *d = bf16_to_f32(h);
        }
    }

    /// dst[i] += f32(self[i]) — f32 accumulation of a bf16 payload
    /// (reduce-scatter hop, partial-sum reduction) with no temporary.
    pub fn add_into(&self, dst: &mut [f32]) {
        assert_eq!(dst.len(), self.data.len());
        for (d, &h) in dst.iter_mut().zip(self.data.iter()) {
            *d += bf16_to_f32(h);
        }
    }

    /// Return the u16 buffer to this thread's pool.
    pub fn recycle(self) {
        pool::put_u16(self.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_golden_vectors() {
        // (input bits, expected bf16) — RNE including ties, subnormals,
        // overflow-to-inf, infinities, and signed zero. Cross-checked
        // against an independent arbitrary-precision model of RNE.
        let cases: [(u32, u16); 14] = [
            (0x0000_0000, 0x0000), // +0
            (0x8000_0000, 0x8000), // -0
            (0x3f80_0000, 0x3f80), // 1.0
            (0x3f80_8000, 0x3f80), // 1.0 + half-ulp tie -> even (down)
            (0x3f81_8000, 0x3f82), // 1.0 + 3*half-ulp tie -> even (up)
            (0x3f80_8001, 0x3f81), // just above the tie -> up
            (0x3f80_7fff, 0x3f80), // just below the tie -> down
            (0x4049_0fdb, 0x4049), // pi rounds down
            (0x7f7f_ffff, 0x7f80), // max finite f32 -> +inf in bf16
            (0x7f80_0000, 0x7f80), // +inf stays inf
            (0xff80_0000, 0xff80), // -inf stays inf
            (0x0000_0001, 0x0000), // smallest subnormal underflows to +0
            (0x0001_8000, 0x0002), // subnormal tie -> even (up)
            (0x3380_0000, 0x3380), // 2^-24 is exactly representable
        ];
        for (bits, want) in cases {
            let got = f32_to_bf16(f32::from_bits(bits));
            assert_eq!(got, want, "bits {bits:#010x}: got {got:#06x} want {want:#06x}");
        }
    }

    #[test]
    fn nan_is_quieted_not_rounded() {
        for bits in [0x7fc0_0000u32, 0x7f80_0001, 0xffc0_1234, 0x7fbf_ffff] {
            let h = f32_to_bf16(f32::from_bits(bits));
            let back = bf16_to_f32(h);
            assert!(back.is_nan(), "bits {bits:#010x} -> {h:#06x} not NaN");
            assert_eq!(
                (h >> 15) as u32,
                bits >> 31,
                "NaN sign not preserved for {bits:#010x}"
            );
        }
    }

    #[test]
    fn quantize_is_idempotent() {
        let mut x = -1.0f32;
        while x < 1.0 {
            let q = quantize(x);
            assert_eq!(quantize(q).to_bits(), q.to_bits(), "x={x}");
            // error bounded by half an ulp: 2^-8 relative for normals
            if x != 0.0 {
                assert!((q - x).abs() / x.abs() <= 1.0 / 256.0, "x={x} q={q}");
            }
            x += 0.001;
        }
    }

    #[test]
    fn tensor_round_trip_and_accumulate() {
        let t = Tensor::new(vec![2, 3], vec![1.5, -2.25, 0.1, 1e30, -1e-30, 0.0]);
        let b = Bf16Tensor::from_tensor(&t);
        assert_eq!(b.numel(), 6);
        let back = b.to_tensor();
        assert_eq!(back.shape, t.shape);
        for (a, w) in back.data.iter().zip(t.data.iter()) {
            assert_eq!(*a, quantize(*w));
        }
        // exactly-representable values survive and accumulate in f32
        let mut acc = vec![1.0f32; 6];
        b.add_into(&mut acc);
        assert_eq!(acc[0], 2.5);
        back.recycle();
        b.recycle();
    }

    #[test]
    fn precision_parses_and_prices() {
        assert_eq!("bf16".parse::<Precision>().unwrap(), Precision::Bf16);
        assert_eq!("f32".parse::<Precision>().unwrap(), Precision::F32);
        assert!("fp8".parse::<Precision>().is_err());
        assert_eq!(Precision::F32.wire_bytes_per_elem(), 4);
        assert_eq!(Precision::Bf16.wire_bytes_per_elem(), 2);
        assert_eq!(Precision::default(), Precision::F32);
    }
}
