//! Reference (naive) matmul kernels — the seed implementations, kept
//! verbatim as the differential-testing oracle for the blocked/parallel
//! kernels in `ops`. Never used on a hot path; property tests assert the
//! optimized kernels match these to tight tolerance across random shapes,
//! strides, and thread counts.

use super::Tensor;

/// y = x @ w.T   x:[M,K], w:[N,K] -> [M,N]
pub fn matmul_nt(x: &Tensor, w: &Tensor) -> Tensor {
    let (m, k) = x.dims2();
    let (n, k2) = w.dims2();
    assert_eq!(k, k2, "nt contraction mismatch {:?} {:?}", x.shape, w.shape);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let xi = &x.data[i * k..(i + 1) * k];
        for j in 0..n {
            let wj = &w.data[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += xi[kk] * wj[kk];
            }
            out[i * n + j] = acc;
        }
    }
    Tensor::new(vec![m, n], out)
}

/// y = x @ w     x:[M,K], w:[K,N] -> [M,N]
pub fn matmul_nn(x: &Tensor, w: &Tensor) -> Tensor {
    let (m, k) = x.dims2();
    let (k2, n) = w.dims2();
    assert_eq!(k, k2, "nn contraction mismatch {:?} {:?}", x.shape, w.shape);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let xi = &x.data[i * k..(i + 1) * k];
        let oi = &mut out[i * n..(i + 1) * n];
        for (kk, &xv) in xi.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wr = &w.data[kk * n..(kk + 1) * n];
            for j in 0..n {
                oi[j] += xv * wr[j];
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

/// y = x.T @ w   x:[K,M], w:[K,N] -> [M,N]
pub fn matmul_tn(x: &Tensor, w: &Tensor) -> Tensor {
    let (k, m) = x.dims2();
    let (k2, n) = w.dims2();
    assert_eq!(k, k2, "tn contraction mismatch {:?} {:?}", x.shape, w.shape);
    let mut out = vec![0.0f32; m * n];
    for kk in 0..k {
        let xr = &x.data[kk * m..(kk + 1) * m];
        let wr = &w.data[kk * n..(kk + 1) * n];
        for i in 0..m {
            let xv = xr[i];
            if xv == 0.0 {
                continue;
            }
            let oi = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                oi[j] += xv * wr[j];
            }
        }
    }
    Tensor::new(vec![m, n], out)
}
