//! Native f32 compute: blocked matmul kernels (fallback backend / tests)
//! and the pointwise stages the coordinator runs outside PJRT (GELU, layer
//! norm, bias/residual adds, blend). Pointwise formulas mirror
//! python/compile/kernels/ref.py bit-for-bit in structure.
//!
//! # Kernel layer
//!
//! The matmuls are out-parameter kernels over [`TensorView`]s:
//! `matmul_{nt,nn,tn}_into(out, x, w, accumulate)` write (or accumulate
//! into) a caller-owned buffer, so the jigsaw engine's partial-sum
//! reductions and the runtime's fallback path run without intermediate
//! allocations. The schedule is the classic cache-blocked AXPY form:
//!
//! * output columns blocked by `NC`, contraction blocked by `KC`;
//! * a 4x8 register micro-tile (`MR` x `NR`) with the contraction
//!   innermost, so each loaded operand row feeds 32 FLOPs;
//! * for the `nt` form the weight block is packed into a K-major panel
//!   once per (j, k) block (K-panel packing), turning the strided
//!   dot-product traversal into contiguous SIMD-friendly rows;
//! * an optional row-band parallel driver (`std::thread::scope`) gated by
//!   the `JIGSAW_KERNEL_THREADS` env knob (default 1: the trainer already
//!   runs one thread per rank). Bands split the *output*, so no reduction
//!   or synchronization is needed.
//!
//! With the `simd` feature (nightly, `std::simd`), the register tile's
//! contraction loop runs on explicit `f32x8` lanes instead of relying on
//! autovectorization. The SIMD tile uses separate multiply and add (no
//! `mul_add`) in the same per-element order as the scalar loop, so the
//! two paths are **bit-identical** — the scalar tile remains both the
//! stable-toolchain default and the oracle the SIMD build is tested
//! against ([`set_force_scalar_tile`] routes a `simd` binary through the
//! scalar tile so benches can measure the speedup in-process; it is a
//! process-global switch because band worker threads must see it too).
//!
//! The driver also hosts the crate's **progress callback**
//! ([`set_driver_hook`]): a thread-local hook the kernels tick between
//! register-tile row groups and while the calling thread waits at the
//! row-band barrier. `comm::ProgressEngine` installs itself here so
//! in-flight collectives (the trainer's DP bucket rings) advance during
//! long matmuls instead of only at gradient-emission points — the hook
//! is a bare `fn` pointer read from a `Cell`, one predictable branch per
//! ~hundred-KFLOP row group when disengaged, and band worker threads
//! never inherit it.
//!
//! The seed's naive triple loops live on in [`super::ref_kernels`] as the
//! property-test oracle (`rust/tests/kernel_props.rs`).

use std::cell::Cell;
use std::sync::OnceLock;

use super::pool;
use super::view::{TensorView, TensorViewMut};
use super::Tensor;

pub const SQRT_2_OVER_PI: f32 = 0.797_884_56;
pub const GELU_C: f32 = 0.044_715;
pub const LN_EPS: f32 = 1e-5;

// ---------------------------------------------------------------------------
// Blocked matmul kernels
// ---------------------------------------------------------------------------

/// Register micro-tile rows.
const MR: usize = 4;
/// Register micro-tile cols (one/two SIMD vectors).
const NR: usize = 8;
/// Output-column block (fits the micro-panel in L1).
const NC: usize = 128;
/// Contraction block (keeps the packed panel L2-resident).
const KC: usize = 256;
/// Below this many FLOPs the thread-spawn overhead dominates.
const PAR_MIN_FLOPS: usize = 1 << 21;

static THREADS: OnceLock<usize> = OnceLock::new();

/// With the `simd` feature, routes the register tile through the scalar
/// path when set. Process-global (not thread-local): the banded driver's
/// scoped worker threads never inherit thread-locals, and the whole
/// point of the switch is that one flip covers every band.
#[cfg(feature = "simd")]
static FORCE_SCALAR_TILE: std::sync::atomic::AtomicBool =
    std::sync::atomic::AtomicBool::new(false);

/// Force (or release, with `false`) the scalar register tile in a
/// `simd`-featured binary, returning the previous setting. Benches use
/// this to measure the SIMD microkernel against the scalar blocked
/// kernel inside one process; tests use it to check bit-identity. No-op
/// (returns `false`) without the feature.
pub fn set_force_scalar_tile(force: bool) -> bool {
    #[cfg(feature = "simd")]
    {
        FORCE_SCALAR_TILE.swap(force, std::sync::atomic::Ordering::Relaxed)
    }
    #[cfg(not(feature = "simd"))]
    {
        let _ = force;
        false
    }
}

/// Whether the explicit-SIMD register tile is compiled in and currently
/// active (i.e. not forced scalar).
pub fn simd_tile_active() -> bool {
    #[cfg(feature = "simd")]
    {
        !FORCE_SCALAR_TILE.load(std::sync::atomic::Ordering::Relaxed)
    }
    #[cfg(not(feature = "simd"))]
    {
        false
    }
}

/// Kernel thread count: `JIGSAW_KERNEL_THREADS` (>= 1), default 1. Read
/// once; tests that need specific counts use the `*_into_with` entry
/// points instead of the env.
pub fn kernel_threads() -> usize {
    *THREADS.get_or_init(|| {
        std::env::var("JIGSAW_KERNEL_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&t| t >= 1)
            .unwrap_or(1)
    })
}

thread_local! {
    /// Kernel-driver progress callback (see the module docs). `None` on
    /// every thread until an installer (`comm::ProgressEngine::install`)
    /// sets it; the hook returns whether it made progress.
    static DRIVER_HOOK: Cell<Option<fn() -> bool>> = const { Cell::new(None) };
}

/// Install (or clear, with `None`) this thread's kernel-driver progress
/// hook, returning the previous one so scoped installers can restore it.
pub fn set_driver_hook(hook: Option<fn() -> bool>) -> Option<fn() -> bool> {
    DRIVER_HOOK.with(|h| h.replace(hook))
}

/// Whether a driver hook is installed on the current thread. Blocking
/// fabric waits use this to pick the hook-driven (bounded-sleep) path.
pub fn driver_hook_installed() -> bool {
    DRIVER_HOOK.with(|h| h.get().is_some())
}

/// Run the installed hook once (no-op without one); returns whether the
/// hook reported progress. Called by the kernels between row groups, by
/// the band-barrier wait loop, and by hook-aware comm waits.
pub fn driver_tick() -> bool {
    match DRIVER_HOOK.with(|h| h.get()) {
        Some(hook) => hook(),
        None => false,
    }
}

/// Drive the band barrier: while any band thread is still computing,
/// keep ticking the installed hook instead of parking in `join`. Without
/// a hook this is skipped entirely and `join` blocks as before. When the
/// hook reports no progress (e.g. nothing is in flight), the caller naps
/// briefly rather than spinning — a hot `yield_now` loop would
/// oversubscribe the cores the band workers need.
fn drive_band_barrier<T>(handles: &[std::thread::ScopedJoinHandle<'_, T>]) {
    while driver_hook_installed() && handles.iter().any(|h| !h.is_finished()) {
        if !driver_tick() {
            std::thread::sleep(std::time::Duration::from_micros(20));
        }
    }
}

fn effective_threads(requested: usize, rows: usize, flops: usize) -> usize {
    if requested <= 1 || rows < 2 || flops < PAR_MIN_FLOPS {
        1
    } else {
        requested.min(rows)
    }
}

/// Split `rows` into `bands` near-equal contiguous ranges.
fn band_ranges(rows: usize, bands: usize) -> Vec<(usize, usize)> {
    let base = rows / bands;
    let extra = rows % bands;
    let mut out = Vec::with_capacity(bands);
    let mut lo = 0;
    for b in 0..bands {
        let take = base + usize::from(b < extra);
        out.push((lo, lo + take));
        lo += take;
    }
    out
}

/// Four disjoint mutable row slices (cols j0..j1) of a strided buffer.
#[inline(always)]
fn quad_rows<'o>(
    out: &'o mut [f32],
    os: usize,
    i0: usize,
    j0: usize,
    j1: usize,
) -> [&'o mut [f32]; 4] {
    let base = &mut out[i0 * os..];
    let (a, rest) = base.split_at_mut(os);
    let (b, rest) = rest.split_at_mut(os);
    let (c, rest) = rest.split_at_mut(os);
    let dlen = rest.len().min(os);
    let d = &mut rest[..dlen];
    [&mut a[j0..j1], &mut b[j0..j1], &mut c[j0..j1], &mut d[j0..j1]]
}

#[inline(always)]
fn row_slice<'o>(out: &'o mut [f32], os: usize, i: usize, j0: usize, j1: usize) -> &'o mut [f32] {
    let start = i * os;
    &mut out[start + j0..start + j1]
}

/// Contraction loop of one MR x NR register tile, scalar form: the
/// bit-exact reference schedule. Each accumulator element sees, in kk
/// order, one multiply then one add (no fused op) — the SIMD tile below
/// replays exactly this sequence per lane.
#[inline(always)]
fn tile_kloop_scalar<'b, FA, FB>(
    acc: &mut [[f32; NR]; MR],
    i0: usize,
    jj: usize,
    k0: usize,
    k1: usize,
    a: &FA,
    brow: &FB,
) where
    FA: Fn(usize, usize) -> f32,
    FB: Fn(usize) -> &'b [f32],
{
    for kk in k0..k1 {
        let b = &brow(kk)[jj..jj + NR];
        let av = [a(i0, kk), a(i0 + 1, kk), a(i0 + 2, kk), a(i0 + 3, kk)];
        for (accr, &ar) in acc.iter_mut().zip(av.iter()) {
            for t in 0..NR {
                accr[t] += ar * b[t];
            }
        }
    }
}

/// Contraction loop of one MR x NR register tile on `f32x8` lanes. Uses
/// separate `*` and `+=` (NOT `mul_add`): per output element this is the
/// same multiply-round-add-round sequence in the same kk order as
/// [`tile_kloop_scalar`], so the two are bit-identical and the property
/// suite can compare them with `to_bits`.
#[cfg(feature = "simd")]
#[inline(always)]
fn tile_kloop_simd<'b, FA, FB>(
    acc: &mut [[f32; NR]; MR],
    i0: usize,
    jj: usize,
    k0: usize,
    k1: usize,
    a: &FA,
    brow: &FB,
) where
    FA: Fn(usize, usize) -> f32,
    FB: Fn(usize) -> &'b [f32],
{
    use std::simd::f32x8;
    let mut v0 = f32x8::from_array(acc[0]);
    let mut v1 = f32x8::from_array(acc[1]);
    let mut v2 = f32x8::from_array(acc[2]);
    let mut v3 = f32x8::from_array(acc[3]);
    for kk in k0..k1 {
        let b = f32x8::from_slice(&brow(kk)[jj..jj + NR]);
        v0 += f32x8::splat(a(i0, kk)) * b;
        v1 += f32x8::splat(a(i0 + 1, kk)) * b;
        v2 += f32x8::splat(a(i0 + 2, kk)) * b;
        v3 += f32x8::splat(a(i0 + 3, kk)) * b;
    }
    acc[0] = v0.to_array();
    acc[1] = v1.to_array();
    acc[2] = v2.to_array();
    acc[3] = v3.to_array();
}

/// Contraction loop of a single-row NR tile (tail rows), scalar form.
#[inline(always)]
fn row_kloop_scalar<'b, FA, FB>(
    acc: &mut [f32; NR],
    i0: usize,
    jj: usize,
    k0: usize,
    k1: usize,
    a: &FA,
    brow: &FB,
) where
    FA: Fn(usize, usize) -> f32,
    FB: Fn(usize) -> &'b [f32],
{
    for kk in k0..k1 {
        let b = &brow(kk)[jj..jj + NR];
        let av = a(i0, kk);
        for t in 0..NR {
            acc[t] += av * b[t];
        }
    }
}

/// Single-row NR tile on `f32x8` lanes; bit-identical to
/// [`row_kloop_scalar`] by the same separate-mul-add argument.
#[cfg(feature = "simd")]
#[inline(always)]
fn row_kloop_simd<'b, FA, FB>(
    acc: &mut [f32; NR],
    i0: usize,
    jj: usize,
    k0: usize,
    k1: usize,
    a: &FA,
    brow: &FB,
) where
    FA: Fn(usize, usize) -> f32,
    FB: Fn(usize) -> &'b [f32],
{
    use std::simd::f32x8;
    let mut v = f32x8::from_array(*acc);
    for kk in k0..k1 {
        let b = f32x8::from_slice(&brow(kk)[jj..jj + NR]);
        v += f32x8::splat(a(i0, kk)) * b;
    }
    *acc = v.to_array();
}

/// Core blocked GEMM block: out[0..m, j0..j1] (+)= sum_{k0..k1} a(i,k)*b(k,j).
///
/// `a(i, k)` loads the left operand; `brow(k)` yields the right operand's
/// row k restricted to columns j0..j1 (a packed panel row for `nt`). When
/// `init` is set the tile is overwritten instead of accumulated into.
#[inline(always)]
fn kernel_block<'b, FA, FB>(
    out: &mut [f32],
    os: usize,
    m: usize,
    j0: usize,
    j1: usize,
    k0: usize,
    k1: usize,
    init: bool,
    a: FA,
    brow: FB,
) where
    FA: Fn(usize, usize) -> f32,
    FB: Fn(usize) -> &'b [f32],
{
    let width = j1 - j0;
    if width == 0 || m == 0 {
        return;
    }
    let mut i0 = 0;
    while i0 + MR <= m {
        // progress tick between register-tile row groups: with an engine
        // installed, in-flight collectives advance mid-matmul (~tens of
        // microseconds of FLOPs per group at training shapes); a bare
        // thread-local read otherwise
        driver_tick();
        let [r0, r1, r2, r3] = quad_rows(out, os, i0, j0, j1);
        let mut jj = 0;
        while jj + NR <= width {
            let mut acc = [[0.0f32; NR]; MR];
            if !init {
                for t in 0..NR {
                    acc[0][t] = r0[jj + t];
                    acc[1][t] = r1[jj + t];
                    acc[2][t] = r2[jj + t];
                    acc[3][t] = r3[jj + t];
                }
            }
            #[cfg(feature = "simd")]
            if simd_tile_active() {
                tile_kloop_simd(&mut acc, i0, jj, k0, k1, &a, &brow);
            } else {
                tile_kloop_scalar(&mut acc, i0, jj, k0, k1, &a, &brow);
            }
            #[cfg(not(feature = "simd"))]
            tile_kloop_scalar(&mut acc, i0, jj, k0, k1, &a, &brow);
            for t in 0..NR {
                r0[jj + t] = acc[0][t];
                r1[jj + t] = acc[1][t];
                r2[jj + t] = acc[2][t];
                r3[jj + t] = acc[3][t];
            }
            jj += NR;
        }
        while jj < width {
            let mut s = if init {
                [0.0f32; MR]
            } else {
                [r0[jj], r1[jj], r2[jj], r3[jj]]
            };
            for kk in k0..k1 {
                let b = brow(kk)[jj];
                s[0] += a(i0, kk) * b;
                s[1] += a(i0 + 1, kk) * b;
                s[2] += a(i0 + 2, kk) * b;
                s[3] += a(i0 + 3, kk) * b;
            }
            r0[jj] = s[0];
            r1[jj] = s[1];
            r2[jj] = s[2];
            r3[jj] = s[3];
            jj += 1;
        }
        i0 += MR;
    }
    while i0 < m {
        let row = row_slice(out, os, i0, j0, j1);
        let mut jj = 0;
        while jj + NR <= width {
            let mut acc = [0.0f32; NR];
            if !init {
                acc.copy_from_slice(&row[jj..jj + NR]);
            }
            #[cfg(feature = "simd")]
            if simd_tile_active() {
                row_kloop_simd(&mut acc, i0, jj, k0, k1, &a, &brow);
            } else {
                row_kloop_scalar(&mut acc, i0, jj, k0, k1, &a, &brow);
            }
            #[cfg(not(feature = "simd"))]
            row_kloop_scalar(&mut acc, i0, jj, k0, k1, &a, &brow);
            row[jj..jj + NR].copy_from_slice(&acc);
            jj += NR;
        }
        while jj < width {
            let mut s = if init { 0.0 } else { row[jj] };
            for kk in k0..k1 {
                s += a(i0, kk) * brow(kk)[jj];
            }
            row[jj] = s;
            jj += 1;
        }
        i0 += 1;
    }
}

/// Panel workspace length for an nt matmul with the given (n, k).
fn nt_panel_len(n: usize, k: usize) -> usize {
    n.min(NC) * k.min(KC)
}

/// Serial blocked y (+)= x @ w.T with K-panel packing of w. Takes the
/// pack workspace from this thread's pool and returns it afterwards.
fn nt_serial(out: TensorViewMut<'_>, x: TensorView<'_>, w: TensorView<'_>, acc: bool) {
    let mut panel = pool::take(nt_panel_len(w.nrows(), w.ncols()));
    nt_serial_panel(out, x, w, acc, &mut panel);
    pool::put(panel);
}

/// `nt_serial` with a caller-provided pack panel (the banded driver packs
/// into panels owned by the calling thread's pool, so scoped band threads
/// don't heap-allocate).
fn nt_serial_panel(
    mut out: TensorViewMut<'_>,
    x: TensorView<'_>,
    w: TensorView<'_>,
    acc: bool,
    panel: &mut [f32],
) {
    let (m, k) = x.dims();
    let (n, k2) = w.dims();
    assert_eq!(k, k2, "nt contraction mismatch {:?} {:?}", x.dims(), w.dims());
    assert_eq!(out.dims(), (m, n), "nt out shape");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        if !acc {
            out.fill(0.0);
        }
        return;
    }
    assert!(panel.len() >= nt_panel_len(n, k), "nt pack panel too small");
    let os = out.stride;
    let od: &mut [f32] = out.data;
    let (xd, xs) = (x.data, x.stride);
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + NC).min(n);
        let width = j1 - j0;
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + KC).min(k);
            // pack panel[kk - k0][j - j0] = w[j, kk]: contiguous reads of
            // w's rows, K-major writes so the kernel streams panel rows.
            for j in j0..j1 {
                let wr = &w.row(j)[k0..k1];
                for (kk, &v) in wr.iter().enumerate() {
                    panel[kk * width + (j - j0)] = v;
                }
            }
            let init = k0 == 0 && !acc;
            kernel_block(
                od,
                os,
                m,
                j0,
                j1,
                k0,
                k1,
                init,
                |i, kk| xd[i * xs + kk],
                |kk| &panel[(kk - k0) * width..(kk - k0) * width + width],
            );
            k0 = k1;
        }
        j0 = j1;
    }
}

/// Serial blocked y (+)= x @ w (w rows are already contraction-major).
fn nn_serial(mut out: TensorViewMut<'_>, x: TensorView<'_>, w: TensorView<'_>, acc: bool) {
    let (m, k) = x.dims();
    let (k2, n) = w.dims();
    assert_eq!(k, k2, "nn contraction mismatch {:?} {:?}", x.dims(), w.dims());
    assert_eq!(out.dims(), (m, n), "nn out shape");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        if !acc {
            out.fill(0.0);
        }
        return;
    }
    let os = out.stride;
    let od: &mut [f32] = out.data;
    let (xd, xs) = (x.data, x.stride);
    let (wd, ws) = (w.data, w.stride);
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + NC).min(n);
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + KC).min(k);
            let init = k0 == 0 && !acc;
            kernel_block(
                od,
                os,
                m,
                j0,
                j1,
                k0,
                k1,
                init,
                |i, kk| xd[i * xs + kk],
                |kk| &wd[kk * ws + j0..kk * ws + j1],
            );
            k0 = k1;
        }
        j0 = j1;
    }
}

/// Serial blocked y (+)= x.T @ w (x is [K, M]; columns of x drive rows of y).
fn tn_serial(mut out: TensorViewMut<'_>, x: TensorView<'_>, w: TensorView<'_>, acc: bool) {
    let (k, m) = x.dims();
    let (k2, n) = w.dims();
    assert_eq!(k, k2, "tn contraction mismatch {:?} {:?}", x.dims(), w.dims());
    assert_eq!(out.dims(), (m, n), "tn out shape");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        if !acc {
            out.fill(0.0);
        }
        return;
    }
    let os = out.stride;
    let od: &mut [f32] = out.data;
    let (xd, xs) = (x.data, x.stride);
    let (wd, ws) = (w.data, w.stride);
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + NC).min(n);
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + KC).min(k);
            let init = k0 == 0 && !acc;
            kernel_block(
                od,
                os,
                m,
                j0,
                j1,
                k0,
                k1,
                init,
                |i, kk| xd[kk * xs + i],
                |kk| &wd[kk * ws + j0..kk * ws + j1],
            );
            k0 = k1;
        }
        j0 = j1;
    }
}

/// y (+)= x @ w.T with an explicit thread count (row-band parallel).
pub fn matmul_nt_into_with(
    out: TensorViewMut<'_>,
    x: TensorView<'_>,
    w: TensorView<'_>,
    acc: bool,
    threads: usize,
) {
    let (m, k) = x.dims();
    let n = w.nrows();
    assert_eq!(out.dims(), (m, n), "nt out shape");
    let t = effective_threads(threads, m, 2 * m * n * k);
    if t <= 1 {
        return nt_serial(out, x, w, acc);
    }
    // pack panels are taken from (and returned to) the calling thread's
    // pool: the short-lived band threads would otherwise heap-allocate
    // one panel per call and leak it into their dying thread-locals.
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(t);
        let mut rest = out;
        for (lo, hi) in band_ranges(m, t) {
            let (band, r) = rest.split_at_rows(hi - lo);
            rest = r;
            let xb = x.slice_rows(lo, hi);
            let mut panel = pool::take(nt_panel_len(n, k));
            handles.push(s.spawn(move || {
                nt_serial_panel(band, xb, w, acc, &mut panel);
                panel
            }));
        }
        // band barrier: the calling thread drives the progress hook (if
        // installed) while the bands finish, instead of parking in join
        drive_band_barrier(&handles);
        for h in handles {
            pool::put(h.join().expect("nt kernel band thread panicked"));
        }
    });
}

/// y (+)= x @ w with an explicit thread count.
pub fn matmul_nn_into_with(
    out: TensorViewMut<'_>,
    x: TensorView<'_>,
    w: TensorView<'_>,
    acc: bool,
    threads: usize,
) {
    let (m, k) = x.dims();
    let n = w.ncols();
    assert_eq!(out.dims(), (m, n), "nn out shape");
    let t = effective_threads(threads, m, 2 * m * n * k);
    if t <= 1 {
        return nn_serial(out, x, w, acc);
    }
    std::thread::scope(|s| {
        let mut rest = out;
        let mut handles = Vec::with_capacity(t);
        for (lo, hi) in band_ranges(m, t) {
            let (band, r) = rest.split_at_rows(hi - lo);
            rest = r;
            let xb = x.slice_rows(lo, hi);
            handles.push(s.spawn(move || nn_serial(band, xb, w, acc)));
        }
        drive_band_barrier(&handles);
        for h in handles {
            h.join().expect("nn kernel band thread panicked");
        }
    });
}

/// y (+)= x.T @ w with an explicit thread count (bands over x's columns).
pub fn matmul_tn_into_with(
    out: TensorViewMut<'_>,
    x: TensorView<'_>,
    w: TensorView<'_>,
    acc: bool,
    threads: usize,
) {
    let (k, m) = x.dims();
    let n = w.ncols();
    assert_eq!(out.dims(), (m, n), "tn out shape");
    let t = effective_threads(threads, m, 2 * m * n * k);
    if t <= 1 {
        return tn_serial(out, x, w, acc);
    }
    std::thread::scope(|s| {
        let mut rest = out;
        let mut handles = Vec::with_capacity(t);
        for (lo, hi) in band_ranges(m, t) {
            let (band, r) = rest.split_at_rows(hi - lo);
            rest = r;
            let xb = x.slice_cols(lo, hi);
            handles.push(s.spawn(move || tn_serial(band, xb, w, acc)));
        }
        drive_band_barrier(&handles);
        for h in handles {
            h.join().expect("tn kernel band thread panicked");
        }
    });
}

/// y (+)= x @ w.T   x:[M,K], w:[N,K] -> [M,N]
pub fn matmul_nt_into(out: TensorViewMut<'_>, x: TensorView<'_>, w: TensorView<'_>, acc: bool) {
    matmul_nt_into_with(out, x, w, acc, kernel_threads());
}

/// y (+)= x @ w     x:[M,K], w:[K,N] -> [M,N]
pub fn matmul_nn_into(out: TensorViewMut<'_>, x: TensorView<'_>, w: TensorView<'_>, acc: bool) {
    matmul_nn_into_with(out, x, w, acc, kernel_threads());
}

/// y (+)= x.T @ w   x:[K,M], w:[K,N] -> [M,N]
pub fn matmul_tn_into(out: TensorViewMut<'_>, x: TensorView<'_>, w: TensorView<'_>, acc: bool) {
    matmul_tn_into_with(out, x, w, acc, kernel_threads());
}

/// y = x @ w.T (allocating wrapper; output buffer comes from the pool).
pub fn matmul_nt(x: &Tensor, w: &Tensor) -> Tensor {
    let (m, _) = x.dims2();
    let (n, _) = w.dims2();
    let mut out = Tensor::pooled_zeros(&[m, n]);
    matmul_nt_into(out.view2_mut(), x.view2(), w.view2(), false);
    out
}

/// y = x @ w (allocating wrapper).
pub fn matmul_nn(x: &Tensor, w: &Tensor) -> Tensor {
    let (m, _) = x.dims2();
    let (_, n) = w.dims2();
    let mut out = Tensor::pooled_zeros(&[m, n]);
    matmul_nn_into(out.view2_mut(), x.view2(), w.view2(), false);
    out
}

/// y = x.T @ w (allocating wrapper).
pub fn matmul_tn(x: &Tensor, w: &Tensor) -> Tensor {
    let (_, m) = x.dims2();
    let (_, n) = w.dims2();
    let mut out = Tensor::pooled_zeros(&[m, n]);
    matmul_tn_into(out.view2_mut(), x.view2(), w.view2(), false);
    out
}

// ---------------------------------------------------------------------------
// Pointwise / reductions (native on the coordinator)
// ---------------------------------------------------------------------------

pub fn gelu_scalar(x: f32) -> f32 {
    let x3 = x * x * x;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + GELU_C * x3)).tanh())
}

pub fn gelu_grad_scalar(x: f32) -> f32 {
    let x2 = x * x;
    let inner = SQRT_2_OVER_PI * (x + GELU_C * x * x2);
    let t = inner.tanh();
    let sech2 = 1.0 - t * t;
    let dinner = SQRT_2_OVER_PI * (1.0 + 3.0 * GELU_C * x2);
    0.5 * (1.0 + t) + 0.5 * x * sech2 * dinner
}

pub fn gelu(x: &Tensor) -> Tensor {
    Tensor::new(x.shape.clone(), x.data.iter().map(|&v| gelu_scalar(v)).collect())
}

pub fn gelu_bwd(x: &Tensor, dy: &Tensor) -> Tensor {
    assert_eq!(x.shape, dy.shape);
    Tensor::new(
        x.shape.clone(),
        x.data
            .iter()
            .zip(&dy.data)
            .map(|(&v, &d)| d * gelu_grad_scalar(v))
            .collect(),
    )
}

/// In-place gelu backward: dy <- dy * gelu'(x).
pub fn gelu_bwd_assign(x: &Tensor, dy: &mut Tensor) {
    assert_eq!(x.shape, dy.shape);
    for (d, &v) in dy.data.iter_mut().zip(&x.data) {
        *d *= gelu_grad_scalar(v);
    }
}

/// y = x + b broadcast over rows (b per column).
pub fn add_bias_cols(x: &Tensor, b: &Tensor) -> Tensor {
    let mut out = x.clone();
    add_bias_cols_assign(&mut out, b);
    out
}

/// In-place x += b broadcast over rows (b per column).
pub fn add_bias_cols_assign(x: &mut Tensor, b: &Tensor) {
    let (r, c) = x.dims2();
    assert_eq!(b.numel(), c);
    for i in 0..r {
        for (v, bv) in x.data[i * c..(i + 1) * c].iter_mut().zip(&b.data) {
            *v += bv;
        }
    }
}

/// y = x + b broadcast over columns (b per row).
pub fn add_bias_rows(x: &Tensor, b: &Tensor) -> Tensor {
    let mut out = x.clone();
    add_bias_rows_assign(&mut out, b);
    out
}

/// In-place x += b broadcast over columns (b per row).
pub fn add_bias_rows_assign(x: &mut Tensor, b: &Tensor) {
    let (r, c) = x.dims2();
    assert_eq!(b.numel(), r);
    for i in 0..r {
        let bv = b.data[i];
        for v in x.data[i * c..(i + 1) * c].iter_mut() {
            *v += bv;
        }
    }
}

pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape, b.shape);
    Tensor::new(
        a.shape.clone(),
        a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect(),
    )
}

pub fn add_assign(a: &mut Tensor, b: &Tensor) {
    assert_eq!(a.shape, b.shape, "add_assign shape mismatch");
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += y;
    }
}

pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape, b.shape);
    Tensor::new(
        a.shape.clone(),
        a.data.iter().zip(&b.data).map(|(x, y)| x - y).collect(),
    )
}

pub fn scale(a: &Tensor, s: f32) -> Tensor {
    Tensor::new(a.shape.clone(), a.data.iter().map(|x| x * s).collect())
}

/// Column sums (grad of a per-column bias): [R, C] -> [C].
pub fn sum_rows(x: &Tensor) -> Tensor {
    let (_, c) = x.dims2();
    let mut out = Tensor::zeros(&[c]);
    sum_rows_acc(x, &mut out);
    out
}

/// Accumulating column sums: acc[C] += per-column sums of x[R, C].
pub fn sum_rows_acc(x: &Tensor, acc: &mut Tensor) {
    let (r, c) = x.dims2();
    assert_eq!(acc.numel(), c, "sum_rows_acc shape");
    for i in 0..r {
        for (a, v) in acc.data.iter_mut().zip(&x.data[i * c..(i + 1) * c]) {
            *a += v;
        }
    }
}

/// Row sums (grad of a per-row bias): [R, C] -> [R].
pub fn sum_cols(x: &Tensor) -> Tensor {
    let (r, _) = x.dims2();
    let mut out = Tensor::zeros(&[r]);
    sum_cols_acc(x, &mut out);
    out
}

/// Accumulating row sums: acc[R] += per-row sums of x[R, C].
pub fn sum_cols_acc(x: &Tensor, acc: &mut Tensor) {
    let (r, c) = x.dims2();
    assert_eq!(acc.numel(), r, "sum_cols_acc shape");
    for i in 0..r {
        acc.data[i] += x.data[i * c..(i + 1) * c].iter().sum::<f32>();
    }
}

// ---------------------------------------------------------------------------
// Layer norm (last axis of [R, C], per-column affine) — mirrors ref.py
// ---------------------------------------------------------------------------

pub struct LnSaved {
    pub mean: Vec<f32>,
    pub rstd: Vec<f32>,
}

pub fn layernorm(x: &Tensor, gamma: &Tensor, beta: &Tensor) -> (Tensor, LnSaved) {
    let (r, c) = x.dims2();
    assert_eq!(gamma.numel(), c);
    assert_eq!(beta.numel(), c);
    let mut out = vec![0.0; r * c];
    let mut mean = vec![0.0; r];
    let mut rstd = vec![0.0; r];
    for i in 0..r {
        let row = &x.data[i * c..(i + 1) * c];
        let mu = row.iter().sum::<f32>() / c as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / c as f32;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        mean[i] = mu;
        rstd[i] = rs;
        for j in 0..c {
            out[i * c + j] = (row[j] - mu) * rs * gamma.data[j] + beta.data[j];
        }
    }
    (Tensor::new(vec![r, c], out), LnSaved { mean, rstd })
}

/// Returns (dx, dgamma, dbeta).
pub fn layernorm_bwd(
    x: &Tensor,
    gamma: &Tensor,
    saved: &LnSaved,
    dy: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let (r, c) = x.dims2();
    let mut dx = vec![0.0; r * c];
    let mut dg = vec![0.0; c];
    let mut db = vec![0.0; c];
    for i in 0..r {
        let row = &x.data[i * c..(i + 1) * c];
        let dyr = &dy.data[i * c..(i + 1) * c];
        let (mu, rs) = (saved.mean[i], saved.rstd[i]);
        let mut mean_dxhat = 0.0f32;
        let mut mean_dxhat_xhat = 0.0f32;
        for j in 0..c {
            let xhat = (row[j] - mu) * rs;
            let dxhat = dyr[j] * gamma.data[j];
            dg[j] += dyr[j] * xhat;
            db[j] += dyr[j];
            mean_dxhat += dxhat;
            mean_dxhat_xhat += dxhat * xhat;
        }
        mean_dxhat /= c as f32;
        mean_dxhat_xhat /= c as f32;
        for j in 0..c {
            let xhat = (row[j] - mu) * rs;
            let dxhat = dyr[j] * gamma.data[j];
            dx[i * c + j] = rs * (dxhat - mean_dxhat - xhat * mean_dxhat_xhat);
        }
    }
    (
        Tensor::new(vec![r, c], dx),
        Tensor::new(vec![c], dg),
        Tensor::new(vec![c], db),
    )
}

pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ref_kernels;
    use crate::util::rng::Rng;

    fn rand_t(rng: &mut Rng, r: usize, c: usize) -> Tensor {
        let mut d = vec![0.0; r * c];
        rng.fill_normal(&mut d, 1.0);
        Tensor::new(vec![r, c], d)
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Rng::seed_from(3);
        let x = rand_t(&mut rng, 5, 7);
        let w = rand_t(&mut rng, 4, 7); // for nt
        let a = matmul_nt(&x, &w);
        let b = matmul_nn(&x, &w.transposed());
        let c = matmul_tn(&x.transposed(), &w.transposed());
        assert!(a.max_abs_diff(&b) < 1e-5);
        assert!(a.max_abs_diff(&c) < 1e-5);
    }

    #[test]
    fn matmul_identity() {
        let n = 4;
        let mut eye = Tensor::zeros(&[n, n]);
        for i in 0..n {
            eye.data[i * n + i] = 1.0;
        }
        let mut rng = Rng::seed_from(4);
        let x = rand_t(&mut rng, 3, n);
        assert!(matmul_nn(&x, &eye).max_abs_diff(&x) < 1e-7);
    }

    #[test]
    fn blocked_matches_reference_on_awkward_shapes() {
        // shapes chosen to hit every remainder path of the 4x8 micro-tile
        let mut rng = Rng::seed_from(9);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (4, 8, 8), (5, 9, 17), (13, 33, 29)] {
            let x = rand_t(&mut rng, m, k);
            let w = rand_t(&mut rng, n, k);
            let got = matmul_nt(&x, &w);
            let want = ref_kernels::matmul_nt(&x, &w);
            assert!(got.max_abs_diff(&want) < 1e-5, "nt {m}x{k}x{n}");

            let wn = rand_t(&mut rng, k, n);
            let got = matmul_nn(&x, &wn);
            let want = ref_kernels::matmul_nn(&x, &wn);
            assert!(got.max_abs_diff(&want) < 1e-5, "nn {m}x{k}x{n}");

            let xt = rand_t(&mut rng, k, m);
            let got = matmul_tn(&xt, &wn);
            let want = ref_kernels::matmul_tn(&xt, &wn);
            assert!(got.max_abs_diff(&want) < 1e-5, "tn {m}x{k}x{n}");
        }
    }

    #[test]
    fn into_kernel_accumulates() {
        let mut rng = Rng::seed_from(10);
        let x = rand_t(&mut rng, 6, 11);
        let w = rand_t(&mut rng, 9, 11);
        let mut out = rand_t(&mut rng, 6, 9);
        let before = out.clone();
        matmul_nt_into(out.view2_mut(), x.view2(), w.view2(), true);
        let want = add(&before, &ref_kernels::matmul_nt(&x, &w));
        assert!(out.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn into_kernel_writes_through_strided_views() {
        // compute a matmul directly into the (1,1) block of a 2x2 output
        let mut rng = Rng::seed_from(11);
        let x = rand_t(&mut rng, 4, 6);
        let w = rand_t(&mut rng, 5, 6);
        let mut big = Tensor::zeros(&[8, 10]);
        matmul_nt_into(
            big.view2_mut().into_rows(4, 8).into_cols(5, 10),
            x.view2(),
            w.view2(),
            false,
        );
        let want = ref_kernels::matmul_nt(&x, &w);
        let got = big.view2().slice_rows(4, 8).slice_cols(5, 10).to_tensor();
        assert!(got.max_abs_diff(&want) < 1e-5);
        // untouched quadrant stays zero
        assert_eq!(big.at2(0, 0), 0.0);
        assert_eq!(big.at2(3, 9), 0.0);
    }

    #[test]
    fn threaded_kernel_matches_serial() {
        // large enough to clear PAR_MIN_FLOPS so bands really spawn
        let (m, k, n) = (131usize, 120usize, 97usize);
        assert!(2 * m * k * n >= PAR_MIN_FLOPS);
        let mut rng = Rng::seed_from(12);
        let x = rand_t(&mut rng, m, k);
        let w = rand_t(&mut rng, n, k);
        let mut serial = Tensor::zeros(&[m, n]);
        matmul_nt_into_with(serial.view2_mut(), x.view2(), w.view2(), false, 1);
        for threads in [2, 3, 8] {
            let mut par = Tensor::zeros(&[m, n]);
            matmul_nt_into_with(par.view2_mut(), x.view2(), w.view2(), false, threads);
            assert!(par.max_abs_diff(&serial) < 1e-6, "threads={threads}");
        }
    }

    static TICKS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

    fn counting_hook() -> bool {
        TICKS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        false
    }

    #[test]
    fn driver_hook_ticks_during_kernels_and_results_are_unchanged() {
        let (m, k, n) = (131usize, 120usize, 97usize);
        assert!(2 * m * k * n >= PAR_MIN_FLOPS, "must clear the band gate");
        let mut rng = Rng::seed_from(21);
        let x = rand_t(&mut rng, m, k);
        let w = rand_t(&mut rng, n, k);
        let mut base = Tensor::zeros(&[m, n]);
        matmul_nt_into_with(base.view2_mut(), x.view2(), w.view2(), false, 1);

        assert!(!driver_hook_installed());
        let prev = set_driver_hook(Some(counting_hook));
        assert!(driver_hook_installed());
        let before = TICKS.load(std::sync::atomic::Ordering::Relaxed);
        // serial driver: ticks fire between register-tile row groups
        let mut serial = Tensor::zeros(&[m, n]);
        matmul_nt_into_with(serial.view2_mut(), x.view2(), w.view2(), false, 1);
        // banded driver: the caller ticks at the band barrier; the band
        // threads themselves never inherit the hook
        let mut banded = Tensor::zeros(&[m, n]);
        matmul_nt_into_with(banded.view2_mut(), x.view2(), w.view2(), false, 3);
        let after = TICKS.load(std::sync::atomic::Ordering::Relaxed);
        set_driver_hook(prev);
        assert!(!driver_hook_installed());

        assert!(after > before, "hook never ticked during the kernels");
        assert!(serial.max_abs_diff(&base) == 0.0, "hook changed serial result");
        assert!(banded.max_abs_diff(&base) < 1e-6, "hook changed banded result");
    }

    #[test]
    fn band_ranges_cover_exactly() {
        for rows in [1usize, 2, 7, 16, 33] {
            for bands in [1usize, 2, 3, 8] {
                let bands = bands.min(rows);
                let r = band_ranges(rows, bands);
                assert_eq!(r.len(), bands);
                assert_eq!(r[0].0, 0);
                assert_eq!(r.last().unwrap().1, rows);
                for w in r.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                }
            }
        }
    }

    #[test]
    fn gelu_known_values() {
        assert!((gelu_scalar(0.0)).abs() < 1e-7);
        assert!((gelu_scalar(30.0) - 30.0).abs() < 1e-4);
        assert!(gelu_scalar(-30.0).abs() < 1e-6);
        // gelu(1) ~ 0.8412 for the tanh approximation
        assert!((gelu_scalar(1.0) - 0.8412).abs() < 1e-3);
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let eps = 1e-3;
            let fd = (gelu_scalar(x + eps) - gelu_scalar(x - eps)) / (2.0 * eps);
            assert!(
                (fd - gelu_grad_scalar(x)).abs() < 1e-3,
                "x={x} fd={fd} got={}",
                gelu_grad_scalar(x)
            );
        }
    }

    #[test]
    fn gelu_bwd_assign_matches_alloc_version() {
        let mut rng = Rng::seed_from(13);
        let x = rand_t(&mut rng, 4, 9);
        let dy = rand_t(&mut rng, 4, 9);
        let want = gelu_bwd(&x, &dy);
        let mut got = dy.clone();
        gelu_bwd_assign(&x, &mut got);
        assert!(got.max_abs_diff(&want) == 0.0);
    }

    #[test]
    fn layernorm_normalizes() {
        let mut rng = Rng::seed_from(5);
        let x = rand_t(&mut rng, 6, 32);
        let g = Tensor::new(vec![32], vec![1.0; 32]);
        let b = Tensor::zeros(&[32]);
        let (y, _) = layernorm(&x, &g, &b);
        for i in 0..6 {
            let row = &y.data[i * 32..(i + 1) * 32];
            let mu = row.iter().sum::<f32>() / 32.0;
            let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / 32.0;
            assert!(mu.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn layernorm_bwd_finite_difference() {
        let mut rng = Rng::seed_from(6);
        let x = rand_t(&mut rng, 3, 8);
        let g = rand_t(&mut rng, 1, 8).reshape(&[8]);
        let b = rand_t(&mut rng, 1, 8).reshape(&[8]);
        let dy = rand_t(&mut rng, 3, 8);
        let (_, saved) = layernorm(&x, &g, &b);
        let (dx, dg, db) = layernorm_bwd(&x, &g, &saved, &dy);
        let loss = |x: &Tensor, g: &Tensor, b: &Tensor| -> f32 {
            let (y, _) = layernorm(x, g, b);
            y.data.iter().zip(&dy.data).map(|(a, d)| a * d).sum()
        };
        let eps = 1e-2;
        // probe a few coordinates of each grad
        for idx in [0usize, 5, 17] {
            let mut xp = x.clone();
            xp.data[idx] += eps;
            let mut xm = x.clone();
            xm.data[idx] -= eps;
            let fd = (loss(&xp, &g, &b) - loss(&xm, &g, &b)) / (2.0 * eps);
            assert!((fd - dx.data[idx]).abs() < 2e-2, "dx[{idx}] fd={fd} got={}", dx.data[idx]);
        }
        for idx in [0usize, 3] {
            let mut gp = g.clone();
            gp.data[idx] += eps;
            let mut gm = g.clone();
            gm.data[idx] -= eps;
            let fd = (loss(&x, &gp, &b) - loss(&x, &gm, &b)) / (2.0 * eps);
            assert!((fd - dg.data[idx]).abs() < 2e-2);
            let mut bp = b.clone();
            bp.data[idx] += eps;
            let mut bm = b.clone();
            bm.data[idx] -= eps;
            let fd = (loss(&x, &g, &bp) - loss(&x, &g, &bm)) / (2.0 * eps);
            assert!((fd - db.data[idx]).abs() < 2e-2);
        }
    }

    #[test]
    fn bias_adds() {
        let x = Tensor::new(vec![2, 3], vec![0.0; 6]);
        let bc = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let br = Tensor::new(vec![2], vec![10.0, 20.0]);
        assert_eq!(add_bias_cols(&x, &bc).data, vec![1., 2., 3., 1., 2., 3.]);
        assert_eq!(add_bias_rows(&x, &br).data, vec![10., 10., 10., 20., 20., 20.]);
    }

    #[test]
    fn reductions() {
        let x = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(sum_rows(&x).data, vec![4.0, 6.0]);
        assert_eq!(sum_cols(&x).data, vec![3.0, 7.0]);
        let mut acc = Tensor::new(vec![2], vec![1.0, 1.0]);
        sum_rows_acc(&x, &mut acc);
        assert_eq!(acc.data, vec![5.0, 7.0]);
    }
}
