//! Native f32 compute: matmuls (fallback backend / tests) and the
//! pointwise stages the coordinator runs outside PJRT (GELU, layer norm,
//! bias/residual adds, blend). All formulas mirror
//! python/compile/kernels/ref.py bit-for-bit in structure.

use super::Tensor;

pub const SQRT_2_OVER_PI: f32 = 0.797_884_56;
pub const GELU_C: f32 = 0.044_715;
pub const LN_EPS: f32 = 1e-5;

// ---------------------------------------------------------------------------
// Matmuls (native fallback; the hot path uses the PJRT primitives)
// ---------------------------------------------------------------------------

/// y = x @ w.T   x:[M,K], w:[N,K] -> [M,N]
pub fn matmul_nt(x: &Tensor, w: &Tensor) -> Tensor {
    let (m, k) = x.dims2();
    let (n, k2) = w.dims2();
    assert_eq!(k, k2, "nt contraction mismatch {:?} {:?}", x.shape, w.shape);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let xi = &x.data[i * k..(i + 1) * k];
        for j in 0..n {
            let wj = &w.data[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += xi[kk] * wj[kk];
            }
            out[i * n + j] = acc;
        }
    }
    Tensor::new(vec![m, n], out)
}

/// y = x @ w     x:[M,K], w:[K,N] -> [M,N]
pub fn matmul_nn(x: &Tensor, w: &Tensor) -> Tensor {
    let (m, k) = x.dims2();
    let (k2, n) = w.dims2();
    assert_eq!(k, k2, "nn contraction mismatch {:?} {:?}", x.shape, w.shape);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let xi = &x.data[i * k..(i + 1) * k];
        let oi = &mut out[i * n..(i + 1) * n];
        for (kk, &xv) in xi.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wr = &w.data[kk * n..(kk + 1) * n];
            for j in 0..n {
                oi[j] += xv * wr[j];
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

/// y = x.T @ w   x:[K,M], w:[K,N] -> [M,N]
pub fn matmul_tn(x: &Tensor, w: &Tensor) -> Tensor {
    let (k, m) = x.dims2();
    let (k2, n) = w.dims2();
    assert_eq!(k, k2, "tn contraction mismatch {:?} {:?}", x.shape, w.shape);
    let mut out = vec![0.0f32; m * n];
    for kk in 0..k {
        let xr = &x.data[kk * m..(kk + 1) * m];
        let wr = &w.data[kk * n..(kk + 1) * n];
        for i in 0..m {
            let xv = xr[i];
            if xv == 0.0 {
                continue;
            }
            let oi = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                oi[j] += xv * wr[j];
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

// ---------------------------------------------------------------------------
// Pointwise / reductions (native on the coordinator)
// ---------------------------------------------------------------------------

pub fn gelu_scalar(x: f32) -> f32 {
    let x3 = x * x * x;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + GELU_C * x3)).tanh())
}

pub fn gelu_grad_scalar(x: f32) -> f32 {
    let x2 = x * x;
    let inner = SQRT_2_OVER_PI * (x + GELU_C * x * x2);
    let t = inner.tanh();
    let sech2 = 1.0 - t * t;
    let dinner = SQRT_2_OVER_PI * (1.0 + 3.0 * GELU_C * x2);
    0.5 * (1.0 + t) + 0.5 * x * sech2 * dinner
}

pub fn gelu(x: &Tensor) -> Tensor {
    Tensor::new(x.shape.clone(), x.data.iter().map(|&v| gelu_scalar(v)).collect())
}

pub fn gelu_bwd(x: &Tensor, dy: &Tensor) -> Tensor {
    assert_eq!(x.shape, dy.shape);
    Tensor::new(
        x.shape.clone(),
        x.data
            .iter()
            .zip(&dy.data)
            .map(|(&v, &d)| d * gelu_grad_scalar(v))
            .collect(),
    )
}

/// y = x + b broadcast over rows (b per column).
pub fn add_bias_cols(x: &Tensor, b: &Tensor) -> Tensor {
    let (r, c) = x.dims2();
    assert_eq!(b.numel(), c);
    let mut out = x.data.clone();
    for i in 0..r {
        for j in 0..c {
            out[i * c + j] += b.data[j];
        }
    }
    Tensor::new(x.shape.clone(), out)
}

/// y = x + b broadcast over columns (b per row).
pub fn add_bias_rows(x: &Tensor, b: &Tensor) -> Tensor {
    let (r, c) = x.dims2();
    assert_eq!(b.numel(), r);
    let mut out = x.data.clone();
    for i in 0..r {
        for j in 0..c {
            out[i * c + j] += b.data[i];
        }
    }
    Tensor::new(x.shape.clone(), out)
}

pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape, b.shape);
    Tensor::new(
        a.shape.clone(),
        a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect(),
    )
}

pub fn add_assign(a: &mut Tensor, b: &Tensor) {
    assert_eq!(a.shape, b.shape, "add_assign shape mismatch");
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += y;
    }
}

pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape, b.shape);
    Tensor::new(
        a.shape.clone(),
        a.data.iter().zip(&b.data).map(|(x, y)| x - y).collect(),
    )
}

pub fn scale(a: &Tensor, s: f32) -> Tensor {
    Tensor::new(a.shape.clone(), a.data.iter().map(|x| x * s).collect())
}

/// Column sums (grad of a per-column bias): [R, C] -> [C].
pub fn sum_rows(x: &Tensor) -> Tensor {
    let (r, c) = x.dims2();
    let mut out = vec![0.0; c];
    for i in 0..r {
        for j in 0..c {
            out[j] += x.data[i * c + j];
        }
    }
    Tensor::new(vec![c], out)
}

/// Row sums (grad of a per-row bias): [R, C] -> [R].
pub fn sum_cols(x: &Tensor) -> Tensor {
    let (r, c) = x.dims2();
    let mut out = vec![0.0; r];
    for i in 0..r {
        for j in 0..c {
            out[i] += x.data[i * c + j];
        }
    }
    Tensor::new(vec![r], out)
}

// ---------------------------------------------------------------------------
// Layer norm (last axis of [R, C], per-column affine) — mirrors ref.py
// ---------------------------------------------------------------------------

pub struct LnSaved {
    pub mean: Vec<f32>,
    pub rstd: Vec<f32>,
}

pub fn layernorm(x: &Tensor, gamma: &Tensor, beta: &Tensor) -> (Tensor, LnSaved) {
    let (r, c) = x.dims2();
    assert_eq!(gamma.numel(), c);
    assert_eq!(beta.numel(), c);
    let mut out = vec![0.0; r * c];
    let mut mean = vec![0.0; r];
    let mut rstd = vec![0.0; r];
    for i in 0..r {
        let row = &x.data[i * c..(i + 1) * c];
        let mu = row.iter().sum::<f32>() / c as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / c as f32;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        mean[i] = mu;
        rstd[i] = rs;
        for j in 0..c {
            out[i * c + j] = (row[j] - mu) * rs * gamma.data[j] + beta.data[j];
        }
    }
    (Tensor::new(vec![r, c], out), LnSaved { mean, rstd })
}

/// Returns (dx, dgamma, dbeta).
pub fn layernorm_bwd(
    x: &Tensor,
    gamma: &Tensor,
    saved: &LnSaved,
    dy: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let (r, c) = x.dims2();
    let mut dx = vec![0.0; r * c];
    let mut dg = vec![0.0; c];
    let mut db = vec![0.0; c];
    for i in 0..r {
        let row = &x.data[i * c..(i + 1) * c];
        let dyr = &dy.data[i * c..(i + 1) * c];
        let (mu, rs) = (saved.mean[i], saved.rstd[i]);
        let mut mean_dxhat = 0.0f32;
        let mut mean_dxhat_xhat = 0.0f32;
        for j in 0..c {
            let xhat = (row[j] - mu) * rs;
            let dxhat = dyr[j] * gamma.data[j];
            dg[j] += dyr[j] * xhat;
            db[j] += dyr[j];
            mean_dxhat += dxhat;
            mean_dxhat_xhat += dxhat * xhat;
        }
        mean_dxhat /= c as f32;
        mean_dxhat_xhat /= c as f32;
        for j in 0..c {
            let xhat = (row[j] - mu) * rs;
            let dxhat = dyr[j] * gamma.data[j];
            dx[i * c + j] = rs * (dxhat - mean_dxhat - xhat * mean_dxhat_xhat);
        }
    }
    (
        Tensor::new(vec![r, c], dx),
        Tensor::new(vec![c], dg),
        Tensor::new(vec![c], db),
    )
}

pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_t(rng: &mut Rng, r: usize, c: usize) -> Tensor {
        let mut d = vec![0.0; r * c];
        rng.fill_normal(&mut d, 1.0);
        Tensor::new(vec![r, c], d)
    }

    #[test]
    fn matmul_variants_agree() {
        let mut rng = Rng::seed_from(3);
        let x = rand_t(&mut rng, 5, 7);
        let w = rand_t(&mut rng, 4, 7); // for nt
        let a = matmul_nt(&x, &w);
        let b = matmul_nn(&x, &w.transposed());
        let c = matmul_tn(&x.transposed(), &w.transposed());
        assert!(a.max_abs_diff(&b) < 1e-5);
        assert!(a.max_abs_diff(&c) < 1e-5);
    }

    #[test]
    fn matmul_identity() {
        let n = 4;
        let mut eye = Tensor::zeros(&[n, n]);
        for i in 0..n {
            eye.data[i * n + i] = 1.0;
        }
        let mut rng = Rng::seed_from(4);
        let x = rand_t(&mut rng, 3, n);
        assert!(matmul_nn(&x, &eye).max_abs_diff(&x) < 1e-7);
    }

    #[test]
    fn gelu_known_values() {
        assert!((gelu_scalar(0.0)).abs() < 1e-7);
        assert!((gelu_scalar(30.0) - 30.0).abs() < 1e-4);
        assert!(gelu_scalar(-30.0).abs() < 1e-6);
        // gelu(1) ~ 0.8412 for the tanh approximation
        assert!((gelu_scalar(1.0) - 0.8412).abs() < 1e-3);
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let eps = 1e-3;
            let fd = (gelu_scalar(x + eps) - gelu_scalar(x - eps)) / (2.0 * eps);
            assert!(
                (fd - gelu_grad_scalar(x)).abs() < 1e-3,
                "x={x} fd={fd} got={}",
                gelu_grad_scalar(x)
            );
        }
    }

    #[test]
    fn layernorm_normalizes() {
        let mut rng = Rng::seed_from(5);
        let x = rand_t(&mut rng, 6, 32);
        let g = Tensor::new(vec![32], vec![1.0; 32]);
        let b = Tensor::zeros(&[32]);
        let (y, _) = layernorm(&x, &g, &b);
        for i in 0..6 {
            let row = &y.data[i * 32..(i + 1) * 32];
            let mu = row.iter().sum::<f32>() / 32.0;
            let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / 32.0;
            assert!(mu.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn layernorm_bwd_finite_difference() {
        let mut rng = Rng::seed_from(6);
        let x = rand_t(&mut rng, 3, 8);
        let g = rand_t(&mut rng, 1, 8).reshape(&[8]);
        let b = rand_t(&mut rng, 1, 8).reshape(&[8]);
        let dy = rand_t(&mut rng, 3, 8);
        let (_, saved) = layernorm(&x, &g, &b);
        let (dx, dg, db) = layernorm_bwd(&x, &g, &saved, &dy);
        let loss = |x: &Tensor, g: &Tensor, b: &Tensor| -> f32 {
            let (y, _) = layernorm(x, g, b);
            y.data.iter().zip(&dy.data).map(|(a, d)| a * d).sum()
        };
        let eps = 1e-2;
        // probe a few coordinates of each grad
        for idx in [0usize, 5, 17] {
            let mut xp = x.clone();
            xp.data[idx] += eps;
            let mut xm = x.clone();
            xm.data[idx] -= eps;
            let fd = (loss(&xp, &g, &b) - loss(&xm, &g, &b)) / (2.0 * eps);
            assert!((fd - dx.data[idx]).abs() < 2e-2, "dx[{idx}] fd={fd} got={}", dx.data[idx]);
        }
        for idx in [0usize, 3] {
            let mut gp = g.clone();
            gp.data[idx] += eps;
            let mut gm = g.clone();
            gm.data[idx] -= eps;
            let fd = (loss(&x, &gp, &b) - loss(&x, &gm, &b)) / (2.0 * eps);
            assert!((fd - dg.data[idx]).abs() < 2e-2);
            let mut bp = b.clone();
            bp.data[idx] += eps;
            let mut bm = b.clone();
            bm.data[idx] -= eps;
            let fd = (loss(&x, &g, &bp) - loss(&x, &g, &bm)) / (2.0 * eps);
            assert!((fd - db.data[idx]).abs() < 2e-2);
        }
    }

    #[test]
    fn bias_adds() {
        let x = Tensor::new(vec![2, 3], vec![0.0; 6]);
        let bc = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        let br = Tensor::new(vec![2], vec![10.0, 20.0]);
        assert_eq!(add_bias_cols(&x, &bc).data, vec![1., 2., 3., 1., 2., 3.]);
        assert_eq!(add_bias_rows(&x, &br).data, vec![10., 10., 10., 20., 20., 20.]);
    }

    #[test]
    fn reductions() {
        let x = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(sum_rows(&x).data, vec![4.0, 6.0]);
        assert_eq!(sum_cols(&x).data, vec![3.0, 7.0]);
    }
}
