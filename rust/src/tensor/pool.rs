//! Reusable buffer pool for matmul-sized temporaries, keyed by element
//! kind.
//!
//! The jigsaw hot path allocates the same handful of buffer shapes every
//! step (matmul outputs, partial-sum accumulators, packed kernel panels,
//! shipped activation blocks). This pool recycles them per thread so
//! steady-state training performs no matmul-sized heap allocations: each
//! rank thread's free list converges after the first step and every
//! subsequent `take` is a hit.
//!
//! Free lists are segregated by element kind — f32 work buffers and u16
//! bf16 pack buffers live on separate lists (`take`/`put` vs
//! `take_u16`/`put_u16`), so a bf16 training run's half-size wire
//! buffers never poison the f32 list's best-fit search or evict the
//! expensive f32 panels under the MAX_FREE bound. Effectively the pool
//! key is (capacity, elem kind).
//!
//! Buffers are zero-filled on `take` (a memset is noise next to the
//! matmul that follows, and it keeps callers honest). Hit/miss counters
//! are process-global atomics shared by both kinds so benches can report
//! allocation behaviour across rank threads (`hotpath_micro` records
//! them in BENCH_kernels.json).

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

use super::Tensor;

static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);

/// Per-thread free list; bounded so a burst of odd shapes cannot pin
/// unbounded memory.
const MAX_FREE: usize = 32;

thread_local! {
    static FREE: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
    static FREE_U16: RefCell<Vec<Vec<u16>>> = const { RefCell::new(Vec::new()) };
}

/// Best fit: the smallest free buffer that holds `len`, so small requests
/// don't steal the large panels/accumulators and force them to
/// reallocate. Zero-fills on both hit and miss.
fn take_from<T: Copy + Default>(free: &RefCell<Vec<Vec<T>>>, len: usize) -> Vec<T> {
    let reused = {
        let mut f = free.borrow_mut();
        f.iter()
            .enumerate()
            .filter(|(_, v)| v.capacity() >= len)
            .min_by_key(|(_, v)| v.capacity())
            .map(|(pos, _)| pos)
            .map(|pos| f.swap_remove(pos))
    };
    match reused {
        Some(mut v) => {
            HITS.fetch_add(1, Ordering::Relaxed);
            v.clear();
            v.resize(len, T::default());
            v
        }
        None => {
            MISSES.fetch_add(1, Ordering::Relaxed);
            vec![T::default(); len]
        }
    }
}

fn put_into<T>(free: &RefCell<Vec<Vec<T>>>, v: Vec<T>) {
    let mut f = free.borrow_mut();
    if f.len() < MAX_FREE {
        f.push(v);
    } else if let Some(smallest) = f
        .iter()
        .enumerate()
        .min_by_key(|(_, b)| b.capacity())
        .map(|(i, _)| i)
    {
        // keep the largest buffers: they are the expensive ones
        if f[smallest].capacity() < v.capacity() {
            f[smallest] = v;
        }
    }
}

/// Take a zero-filled f32 buffer of exactly `len` elements.
pub fn take(len: usize) -> Vec<f32> {
    FREE.with(|f| take_from(f, len))
}

/// Return an f32 buffer to this thread's free list.
pub fn put(v: Vec<f32>) {
    if v.capacity() == 0 {
        return;
    }
    // try_with: a buffer surfacing during thread teardown (e.g. an
    // in-flight collective dropped out of a thread-local registry) is
    // simply freed instead of aborting on the destroyed pool
    let _ = FREE.try_with(|f| put_into(f, v));
}

/// Take a zero-filled u16 buffer (bf16 wire/pack payloads) of exactly
/// `len` elements, from the u16 free list.
pub fn take_u16(len: usize) -> Vec<u16> {
    FREE_U16.with(|f| take_from(f, len))
}

/// Return a u16 buffer to this thread's u16 free list.
pub fn put_u16(v: Vec<u16>) {
    if v.capacity() == 0 {
        return;
    }
    let _ = FREE_U16.try_with(|f| put_into(f, v));
}

/// (hits, misses) since process start or the last `reset_stats`.
pub fn stats() -> (u64, u64) {
    (HITS.load(Ordering::Relaxed), MISSES.load(Ordering::Relaxed))
}

pub fn reset_stats() {
    HITS.store(0, Ordering::Relaxed);
    MISSES.store(0, Ordering::Relaxed);
}

impl Tensor {
    /// Zero tensor backed by a pooled buffer.
    pub fn pooled_zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: take(n) }
    }

    /// Return this tensor's buffer to the thread-local pool.
    pub fn recycle(self) {
        put(self.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_after_reuse() {
        let mut v = take(16);
        v.iter_mut().for_each(|x| *x = 7.0);
        put(v);
        let v2 = take(8);
        assert!(v2.iter().all(|&x| x == 0.0));
        assert_eq!(v2.len(), 8);
    }

    #[test]
    fn pooled_tensor_roundtrip() {
        let t = Tensor::pooled_zeros(&[4, 4]);
        assert_eq!(t.shape, vec![4, 4]);
        assert_eq!(t.data, vec![0.0; 16]);
        t.recycle();
        let t2 = Tensor::pooled_zeros(&[2, 2]);
        assert_eq!(t2.numel(), 4);
    }

    #[test]
    fn u16_list_is_separate_from_f32() {
        // a u16 put must not satisfy (or evict) f32 takes, and vice versa
        let mut h = take_u16(64);
        h.iter_mut().for_each(|x| *x = 0x3f80);
        put_u16(h);
        let h2 = take_u16(32);
        assert!(h2.iter().all(|&x| x == 0));
        assert_eq!(h2.len(), 32);
        put_u16(h2);
        // an f32 take of the same footprint cannot be a reuse of the u16
        // buffer — if the lists were shared this would type-confuse
        let v = take(64);
        assert_eq!(v.len(), 64);
        put(v);
    }

    #[test]
    fn stats_count_hits_and_misses() {
        // other tests run concurrently; only check monotonicity
        let (h0, m0) = stats();
        let v = take(1024 * 9);
        put(v);
        let _v2 = take(1024 * 9);
        let (h1, m1) = stats();
        assert!(h1 + m1 > h0 + m0);
    }
}
