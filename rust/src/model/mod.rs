//! WeatherMixer in rust: parameter specification, patchify/unpatchify,
//! and the jigsaw-distributed forward/backward (`dist`).
//!
//! The layer graph mirrors python/compile/model.py exactly — same
//! parameter names, same (c, pi, pj) patch-feature ordering, same
//! latitude/variable-weighted loss — so the AOT-exported monolithic
//! programs are bit-comparable oracles for the distributed engine.

pub mod dist;
pub mod infer;
pub mod params;

pub use infer::InferModel;

use crate::config::ModelConfig;
use crate::tensor::Tensor;

/// Canonical parameter order — the ABI shared with the python exporter
/// (manifest `param_order`).
pub fn param_order(cfg: &ModelConfig) -> Vec<String> {
    let mut names = vec!["enc_w".to_string(), "enc_b".to_string()];
    for i in 0..cfg.blocks {
        for suffix in [
            "ln1_g", "ln1_b", "tok_w1", "tok_b1", "tok_w2", "tok_b2",
            "ln2_g", "ln2_b", "ch_w1", "ch_b1", "ch_w2", "ch_b2",
        ] {
            names.push(format!("blk{i}_{suffix}"));
        }
    }
    names.push("dec_w".into());
    names.push("dec_b".into());
    names.push("blend_g".into());
    names
}

/// Shape of a named parameter.
pub fn param_shape(cfg: &ModelConfig, name: &str) -> Vec<usize> {
    let (t, d, pd) = (cfg.tokens, cfg.d_emb, cfg.patch_dim);
    let suffix = name.split('_').skip(1).collect::<Vec<_>>().join("_");
    match name {
        "enc_w" => vec![d, pd],
        "enc_b" => vec![d],
        "dec_w" => vec![pd, d],
        "dec_b" => vec![pd],
        "blend_g" => vec![cfg.channels_padded],
        _ => match suffix.as_str() {
            "ln1_g" | "ln1_b" | "ln2_g" | "ln2_b" | "ch_b2" => vec![d],
            "tok_w1" => vec![cfg.d_tok, t],
            "tok_b1" => vec![cfg.d_tok],
            "tok_w2" => vec![t, cfg.d_tok],
            "tok_b2" => vec![t],
            "ch_w1" => vec![cfg.d_ch, d],
            "ch_b1" => vec![cfg.d_ch],
            "ch_w2" => vec![d, cfg.d_ch],
            _ => panic!("unknown param {name}"),
        },
    }
}

/// Deterministic global parameter init (LeCun-style scale, zero biases,
/// unit LN gains, zero blend gate — matches the python init *scheme*;
/// actual values come from the rust RNG since jax.random is not
/// reproducible here. Oracle tests feed identical params to both sides.)
pub fn init_global_params(
    cfg: &ModelConfig,
    seed: u64,
) -> Vec<(String, Tensor)> {
    let mut rng = crate::util::rng::Rng::seed_from(seed);
    param_order(cfg)
        .into_iter()
        .map(|name| {
            let shape = param_shape(cfg, name.as_str());
            let t = if name.ends_with("ln1_g")
                || name.ends_with("ln2_g")
            {
                Tensor::new(shape.clone(), vec![1.0; shape.iter().product()])
            } else if shape.len() == 1 {
                Tensor::zeros(&shape)
            } else {
                let fan_in = *shape.last().unwrap() as f32;
                let mut data = vec![0.0; shape.iter().product()];
                rng.fill_normal(&mut data, 1.0 / fan_in.sqrt());
                Tensor::new(shape.clone(), data)
            };
            (name, t)
        })
        .collect()
}

/// [lat, lon, C] -> [T, patch_dim], feature index = c*p*p + pi*p + pj,
/// token index latitude-major. Must mirror python `patchify` exactly.
pub fn patchify(
    x: &Tensor,
    lat: usize,
    lon: usize,
    c: usize,
    p: usize,
) -> Tensor {
    assert_eq!(x.shape, vec![lat, lon, c]);
    let (lp, lo) = (lat / p, lon / p);
    let pd = c * p * p;
    let mut out = vec![0.0f32; lp * lo * pd];
    for ti in 0..lp {
        for tj in 0..lo {
            let tok = ti * lo + tj;
            for ch in 0..c {
                for pi in 0..p {
                    for pj in 0..p {
                        let src = ((ti * p + pi) * lon + (tj * p + pj)) * c + ch;
                        let dst = tok * pd + ch * p * p + pi * p + pj;
                        out[dst] = x.data[src];
                    }
                }
            }
        }
    }
    Tensor::new(vec![lp * lo, pd], out)
}

/// Inverse of `patchify`.
pub fn unpatchify(
    y: &Tensor,
    lat: usize,
    lon: usize,
    c: usize,
    p: usize,
) -> Tensor {
    let (lp, lo) = (lat / p, lon / p);
    let pd = c * p * p;
    assert_eq!(y.shape, vec![lp * lo, pd]);
    let mut out = vec![0.0f32; lat * lon * c];
    for ti in 0..lp {
        for tj in 0..lo {
            let tok = ti * lo + tj;
            for ch in 0..c {
                for pi in 0..p {
                    for pj in 0..p {
                        let dst = ((ti * p + pi) * lon + (tj * p + pj)) * c + ch;
                        let src = tok * pd + ch * p * p + pi * p + pj;
                        out[dst] = y.data[src];
                    }
                }
            }
        }
    }
    Tensor::new(vec![lat, lon, c], out)
}

/// cos-latitude cell-center weights normalized to mean 1 (WeatherBench2).
pub fn latitude_weights(lat: usize) -> Vec<f32> {
    let mut w: Vec<f32> = (0..lat)
        .map(|i| {
            let phi = (-90.0 + (i as f32 + 0.5) * 180.0 / lat as f32)
                .to_radians();
            phi.cos()
        })
        .collect();
    let mean = w.iter().sum::<f32>() / lat as f32;
    for v in w.iter_mut() {
        *v /= mean;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            lat: 8,
            lon: 16,
            channels: 6,
            channels_padded: 8,
            patch: 2,
            d_emb: 32,
            d_tok: 48,
            d_ch: 32,
            blocks: 2,
            tokens: 32,
            patch_dim: 32,
            param_count: 12904,
            flops_forward: 0,
            channel_weights: vec![1.0; 6],
        }
    }

    #[test]
    fn param_order_matches_python_count() {
        let cfg = tiny_cfg();
        let order = param_order(&cfg);
        assert_eq!(order.len(), 2 + 12 * cfg.blocks + 3);
        assert_eq!(order[0], "enc_w");
        assert_eq!(order.last().unwrap(), "blend_g");
    }

    #[test]
    fn param_count_matches_config() {
        let cfg = tiny_cfg();
        let total: usize = param_order(&cfg)
            .iter()
            .map(|n| param_shape(&cfg, n).iter().product::<usize>())
            .sum();
        assert_eq!(total, cfg.param_count);
    }

    #[test]
    fn patchify_roundtrip() {
        let mut rng = Rng::seed_from(0);
        let mut data = vec![0.0; 8 * 16 * 8];
        rng.fill_normal(&mut data, 1.0);
        let x = Tensor::new(vec![8, 16, 8], data);
        let p = patchify(&x, 8, 16, 8, 2);
        assert_eq!(p.shape, vec![32, 32]);
        assert_eq!(unpatchify(&p, 8, 16, 8, 2), x);
    }

    #[test]
    fn patchify_channel_major_feature_order() {
        // channel 3 at (0,0) lands at feature index 3*p*p
        let mut x = Tensor::zeros(&[8, 16, 8]);
        x.data[3] = 1.0;
        let p = patchify(&x, 8, 16, 8, 2);
        assert_eq!(p.at2(0, 3 * 4), 1.0);
    }

    #[test]
    fn latitude_weights_mean_one() {
        let w = latitude_weights(16);
        let mean: f32 = w.iter().sum::<f32>() / 16.0;
        assert!((mean - 1.0).abs() < 1e-5);
        assert!(w[0] < w[8]);
    }

    #[test]
    fn init_is_deterministic() {
        let cfg = tiny_cfg();
        let a = init_global_params(&cfg, 7);
        let b = init_global_params(&cfg, 7);
        assert_eq!(a, b);
        let c = init_global_params(&cfg, 8);
        assert_ne!(a, c);
    }
}
