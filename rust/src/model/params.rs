//! Per-rank parameter storage: jigsaw-sharded matrices and sliced vectors
//! with gradient-sync groups.
//!
//! Zero memory redundancy (paper Section 4): every weight matrix block has
//! exactly one owner. The only replicated parameters are small vectors
//! whose axis is not sharded on this rank's grid (e.g. the token-mix
//! output bias on a `1x2` mesh, LN affine fibers on meshes with `tok > 1`);
//! their gradients are reconciled by the sync-group reduce the paper
//! describes for layer norms. All grids and sync groups come from the
//! mesh [`Planner`] — `shard_params` is mesh-keyed.

use std::collections::BTreeMap;

use crate::comm::Comm;
use crate::config::ModelConfig;
use crate::jigsaw::{BlockGrid, DistMat, Mesh, MeshError, Planner};
use crate::tensor::{ops, Tensor};

/// A rank's slice of a 1-D parameter plus its gradient sync group.
#[derive(Clone, Debug)]
pub struct VecShard {
    pub full_len: usize,
    pub lo: usize,
    pub hi: usize,
    pub local: Tensor,
    /// ranks holding an identical copy (incl. self); grads allreduce here.
    pub sync_group: Vec<usize>,
}

impl VecShard {
    pub fn from_global(
        global: &Tensor,
        n_blocks: usize,
        block: usize,
        sync_group: Vec<usize>,
    ) -> Self {
        let full_len = global.numel();
        assert_eq!(full_len % n_blocks, 0, "vector not divisible");
        let bl = full_len / n_blocks;
        let (lo, hi) = (block * bl, (block + 1) * bl);
        VecShard {
            full_len,
            lo,
            hi,
            local: Tensor::new(vec![hi - lo], global.data[lo..hi].to_vec()),
            sync_group,
        }
    }

    pub fn zeros_like(&self) -> VecShard {
        VecShard {
            full_len: self.full_len,
            lo: self.lo,
            hi: self.hi,
            local: Tensor::zeros(&[self.hi - self.lo]),
            sync_group: self.sync_group.clone(),
        }
    }
}

/// One rank's full parameter (or gradient / optimizer-moment) store.
#[derive(Clone, Debug, Default)]
pub struct PStore {
    pub mats: BTreeMap<String, DistMat>,
    pub vecs: BTreeMap<String, VecShard>,
}

impl PStore {
    pub fn zeros_like(&self) -> PStore {
        PStore {
            mats: self
                .mats
                .iter()
                .map(|(k, m)| (k.clone(), m.map(|b| Tensor::zeros(&b.shape))))
                .collect(),
            vecs: self
                .vecs
                .iter()
                .map(|(k, v)| (k.clone(), v.zeros_like()))
                .collect(),
        }
    }

    /// Total local parameter count on this rank (the zero-redundancy
    /// memory footprint check: sums to global count + replicated vectors).
    pub fn local_count(&self) -> usize {
        let m: usize = self
            .mats
            .values()
            .flat_map(|d| d.blocks.values().map(|b| b.numel()))
            .sum();
        let v: usize = self.vecs.values().map(|v| v.local.numel()).sum();
        m + v
    }

    /// Squared L2 norm of the local store, counting synced (replicated)
    /// vectors at 1/|group| weight so a cross-rank sum gives the true
    /// global norm.
    pub fn global_norm_sq_contrib(&self) -> f32 {
        let mut s = 0.0f32;
        for m in self.mats.values() {
            for b in m.blocks.values() {
                s += b.data.iter().map(|v| v * v).sum::<f32>();
            }
        }
        for v in self.vecs.values() {
            let w = 1.0 / v.sync_group.len() as f32;
            s += w * v.local.data.iter().map(|x| x * x).sum::<f32>();
        }
        s
    }

    /// Allreduce grads of replicated vectors within their sync groups
    /// (the paper's pairwise layer-norm gradient reduce, Section 5).
    ///
    /// Vectors sharing a sync group are packed into one flat payload and
    /// reduced with a single collective per group instead of one per
    /// vector — the same bucketing the DP gradient reduction uses.
    /// Groups are visited in a globally sorted order, so overlapping
    /// groups on different ranks can never issue collectives in
    /// conflicting orders.
    pub fn sync_replicated_grads(&mut self, comm: &mut Comm) {
        let mut by_group: BTreeMap<Vec<usize>, Vec<&mut Tensor>> = BTreeMap::new();
        for v in self.vecs.values_mut() {
            if v.sync_group.len() > 1 {
                by_group
                    .entry(v.sync_group.clone())
                    .or_default()
                    .push(&mut v.local);
            }
        }
        for (group, mut tensors) in by_group {
            comm.allreduce_packed(&group, &mut tensors);
        }
    }

    /// Every local gradient tensor, in key (alphabetical) order — a
    /// convenience view for tests and benches that just need to visit
    /// each tensor once. The DP gradient reduction does NOT use this
    /// order; it packs in the stable backward-emission order of
    /// [`grad_tensors_reduce_order_mut`](PStore::grad_tensors_reduce_order_mut).
    pub fn grad_tensors_mut(&mut self) -> Vec<&mut Tensor> {
        let mut out: Vec<&mut Tensor> = Vec::new();
        for m in self.mats.values_mut() {
            for b in m.blocks.values_mut() {
                out.push(b);
            }
        }
        for v in self.vecs.values_mut() {
            out.push(&mut v.local);
        }
        out
    }

    /// The stable DP-reduce registry: every local gradient tensor's id
    /// in the order the backward pass finishes them (matrices in
    /// reverse-layer emission order — decoder, then blocks from last to
    /// first, each `ch_w2, ch_w1, tok_w2, tok_w1`, then the encoder —
    /// followed by all vectors, which only finish after the replicated
    /// sync, in key order). All ranks of a DP group hold identically
    /// shaped shards, so this order makes every rank cut bucket
    /// boundaries at the same elements — the invariant both the
    /// grad-ready scheduler and the post-hoc oracle bucketing rely on.
    pub fn grad_reduce_order(&self) -> Vec<GradId> {
        // element: ((bwd key, name, block), id) — sorted by the first
        let mut mats: Vec<_> = self
            .mats
            .iter()
            .flat_map(|(name, m)| {
                let key = bwd_mat_key(name);
                m.blocks.keys().map(move |&bk| {
                    ((key, name.clone(), bk), GradId::Mat(name.clone(), bk))
                })
            })
            .collect();
        mats.sort_by(|a, b| a.0.cmp(&b.0));
        mats.into_iter()
            .map(|(_, id)| id)
            .chain(self.vecs.keys().map(|n| GradId::Vec(n.clone())))
            .collect()
    }

    /// Mutable gradient tensors in [`grad_reduce_order`](PStore::grad_reduce_order):
    /// the flat view the bucketed DP reduction packs from.
    pub fn grad_tensors_reduce_order_mut(&mut self) -> Vec<&mut Tensor> {
        // element: ((bwd key, name, block), tensor) — sorted by the first
        let mut mats: Vec<_> = self
            .mats
            .iter_mut()
            .flat_map(|(name, m)| {
                let key = bwd_mat_key(name);
                m.blocks
                    .iter_mut()
                    .map(move |(&bk, t)| ((key, name.as_str(), bk), t))
            })
            .collect();
        mats.sort_by(|a, b| a.0.cmp(&b.0));
        mats.into_iter()
            .map(|(_, t)| t)
            .chain(self.vecs.values_mut().map(|v| &mut v.local))
            .collect()
    }

    /// True if any local tensor holds a non-finite value (inf/NaN). The
    /// overflow probe of the trainer's dynamic loss scaler: each rank
    /// checks its shard, then the group agrees via a scalar allreduce so
    /// every replica skips (or takes) the step together.
    pub fn has_non_finite(&self) -> bool {
        self.mats
            .values()
            .flat_map(|m| m.blocks.values())
            .any(|b| b.data.iter().any(|x| !x.is_finite()))
            || self
                .vecs
                .values()
                .any(|v| v.local.data.iter().any(|x| !x.is_finite()))
    }

    pub fn scale_all(&mut self, s: f32) {
        for m in self.mats.values_mut() {
            for b in m.blocks.values_mut() {
                for x in b.data.iter_mut() {
                    *x *= s;
                }
            }
        }
        for v in self.vecs.values_mut() {
            for x in v.local.data.iter_mut() {
                *x *= s;
            }
        }
    }

    pub fn add_assign(&mut self, other: &PStore) {
        for (k, m) in self.mats.iter_mut() {
            let o = &other.mats[k];
            for (bk, b) in m.blocks.iter_mut() {
                ops::add_assign(b, &o.blocks[bk]);
            }
        }
        for (k, v) in self.vecs.iter_mut() {
            ops::add_assign(&mut v.local, &other.vecs[k].local);
        }
    }
}

/// Identity of one local gradient tensor inside a [`PStore`]: either a
/// block of a sharded matrix or a (possibly replicated) vector slice.
/// The unit of the DP-reduce registry and of bucket unpacking.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum GradId {
    /// block `(bi, bj)` of matrix gradient `name`
    Mat(String, (usize, usize)),
    Vec(String),
}

/// Backward-emission sort key of a matrix gradient: (class, reversed
/// block index, intra-block position). Mirrors the order
/// `DistModel::loss_and_grad` finishes matrix gradients — decoder
/// first, mixer blocks from last to first (within a block: `ch_w2,
/// ch_w1, tok_w2, tok_w1`, the channel-then-token backward), encoder
/// last. Unknown names sort after everything (alphabetically, via the
/// caller's secondary key).
type BwdKey = (u8, u32, u8);

fn bwd_mat_key(name: &str) -> BwdKey {
    if name == "dec_w" {
        return (0, 0, 0);
    }
    if let Some(rest) = name.strip_prefix("blk") {
        if let Some((i, suffix)) = rest.split_once('_') {
            if let Ok(i) = i.parse::<u32>() {
                let s = match suffix {
                    "ch_w2" => 0,
                    "ch_w1" => 1,
                    "tok_w2" => 2,
                    "tok_w1" => 3,
                    _ => 4,
                };
                return (1, u32::MAX - i, s);
            }
        }
    }
    if name == "enc_w" {
        (2, 0, 0)
    } else {
        (3, 0, 0)
    }
}

/// Receiver of grad-ready events from the backward pass: each call
/// means the named gradient is *fully accumulated* (all rollout
/// iterations folded in; vectors additionally synced across their
/// replication group) and will not change again this step. The
/// trainer's `GradReduceScheduler` implements this to start DP bucket
/// rings while later (earlier-layer) gradients are still being
/// differentiated.
pub trait GradSink {
    /// All local blocks of matrix gradient `name` are final.
    fn mat_ready(&mut self, name: &str, mat: &DistMat);
    /// Vector gradient `name` is final (post replicated-group sync).
    fn vec_ready(&mut self, name: &str, v: &Tensor);
}

/// No-op sink: the plain (post-hoc reduce) training path.
pub struct NullSink;

impl GradSink for NullSink {
    fn mat_ready(&mut self, _name: &str, _mat: &DistMat) {}
    fn vec_ready(&mut self, _name: &str, _v: &Tensor) {}
}

/// Vector-parameter axis kinds (decides slicing + sync groups).
#[derive(Clone, Copy, Debug)]
enum VecKind {
    /// sharded along a channel-like axis (enc_b, LN affine, ch biases,
    /// dec_b, blend_g)
    Channel,
    /// sharded along the token-mix hidden axis (tok_b1)
    TokHidden,
    /// sharded along the token axis (tok_b2)
    Token,
}

/// Shard a full set of global parameters for `rank` on `mesh`. The mesh
/// is validated against the architecture first, so an incompatible shape
/// surfaces as a typed [`MeshError`] rather than a slicing panic deep in
/// a rank thread.
pub fn shard_params(
    cfg: &ModelConfig,
    mesh: &Mesh,
    rank: usize,
    global: &[(String, Tensor)],
) -> Result<PStore, MeshError> {
    mesh.validate_config(cfg)?;
    let l = Planner::new(*mesh);
    let mut store = PStore::default();
    let vec_of = |name: &str| -> VecKind {
        if name.ends_with("tok_b1") {
            VecKind::TokHidden
        } else if name.ends_with("tok_b2") {
            VecKind::Token
        } else {
            VecKind::Channel
        }
    };
    // unique cache namespace per shard_params call: two models of the
    // same preset (tests, DP replicas) must never share device buffers.
    use std::sync::atomic::{AtomicU64, Ordering};
    static INSTANCE: AtomicU64 = AtomicU64::new(1);
    let nonce = INSTANCE.fetch_add(1, Ordering::Relaxed);

    for (name, t) in global {
        if t.rank() == 2 {
            let grid: BlockGrid = l.param_grid(name);
            let mut dm = DistMat::from_global(t, grid, rank);
            dm.cache = Some((fnv1a(name) ^ nonce.rotate_left(32) ^ rank as u64, 0));
            store.mats.insert(name.clone(), dm);
        } else {
            let (n_blocks, block, sync) = match vec_of(name) {
                VecKind::Channel => (
                    mesh.ch(),
                    l.ch_block_of(rank),
                    l.ch_vec_sync_group(rank),
                ),
                VecKind::TokHidden => (
                    mesh.ch(),
                    l.dtok_block_of(rank),
                    l.tok_vec_sync_group(rank),
                ),
                VecKind::Token => (
                    mesh.tok(),
                    l.tok_block_of(rank),
                    l.tok_b2_sync_group(rank),
                ),
            };
            store.vecs.insert(
                name.clone(),
                VecShard::from_global(t, n_blocks, block, sync),
            );
        }
    }
    Ok(store)
}

/// [`shard_params`] for the forward-only path: identical weight slicing,
/// but every vector's gradient sync group collapses to `{rank}` — the
/// inference store carries no grad registry, so no replicated-gradient
/// collective can ever be issued from it (and
/// [`PStore::sync_replicated_grads`] is a guaranteed no-op). Forward
/// math never reads sync groups, so predictions are unaffected.
pub fn shard_params_infer(
    cfg: &ModelConfig,
    mesh: &Mesh,
    rank: usize,
    global: &[(String, Tensor)],
) -> Result<PStore, MeshError> {
    let mut store = shard_params(cfg, mesh, rank, global)?;
    for v in store.vecs.values_mut() {
        v.sync_group = vec![rank];
    }
    Ok(store)
}

fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h = (h ^ *b as u64).wrapping_mul(0x100000001b3);
    }
    h
}

/// Reassemble global parameters from all ranks' stores (tests/checkpoints).
pub fn assemble_params(
    cfg: &ModelConfig,
    stores: &[&PStore],
) -> Vec<(String, Tensor)> {
    let order = super::param_order(cfg);
    order
        .into_iter()
        .map(|name| {
            if stores[0].mats.contains_key(&name) {
                let parts: Vec<&DistMat> =
                    stores.iter().map(|s| &s.mats[&name]).collect();
                (name, DistMat::assemble(&parts))
            } else {
                let full_len = stores[0].vecs[&name].full_len;
                let mut out = vec![0.0f32; full_len];
                let mut filled = vec![false; full_len];
                for s in stores {
                    let v = &s.vecs[&name];
                    for (i, &x) in v.local.data.iter().enumerate() {
                        if !filled[v.lo + i] {
                            out[v.lo + i] = x;
                            filled[v.lo + i] = true;
                        }
                    }
                }
                assert!(filled.iter().all(|&f| f), "vector {name} has holes");
                (name, Tensor::new(vec![full_len], out))
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init_global_params;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            lat: 8,
            lon: 16,
            channels: 6,
            channels_padded: 8,
            patch: 2,
            d_emb: 32,
            d_tok: 48,
            d_ch: 32,
            blocks: 2,
            tokens: 32,
            patch_dim: 32,
            param_count: 12904,
            flops_forward: 0,
            channel_weights: vec![1.0; 6],
        }
    }

    fn meshes() -> Vec<Mesh> {
        [(1, 1), (1, 2), (2, 2), (2, 4), (4, 4)]
            .iter()
            .map(|&(t, c)| Mesh::new(t, c).unwrap())
            .collect()
    }

    #[test]
    fn shard_assemble_roundtrip_all_meshes() {
        let cfg = tiny_cfg();
        let global = init_global_params(&cfg, 3);
        for mesh in meshes() {
            let stores: Vec<PStore> = (0..mesh.n())
                .map(|r| shard_params(&cfg, &mesh, r, &global).unwrap())
                .collect();
            let refs: Vec<&PStore> = stores.iter().collect();
            let back = assemble_params(&cfg, &refs);
            assert_eq!(back.len(), global.len());
            for ((n1, t1), (n2, t2)) in global.iter().zip(&back) {
                assert_eq!(n1, n2);
                assert!(t1.max_abs_diff(t2) == 0.0, "param {n1} mismatch on {mesh}");
            }
        }
    }

    #[test]
    fn zero_memory_redundancy_for_matrices() {
        // sum of local matrix elements across ranks == global element count
        let cfg = tiny_cfg();
        let global = init_global_params(&cfg, 1);
        let global_mat_count: usize = global
            .iter()
            .filter(|(_, t)| t.rank() == 2)
            .map(|(_, t)| t.numel())
            .sum();
        for mesh in meshes() {
            if mesh.n() == 1 {
                continue;
            }
            let total: usize = (0..mesh.n())
                .map(|r| {
                    shard_params(&cfg, &mesh, r, &global)
                        .unwrap()
                        .mats
                        .values()
                        .flat_map(|m| m.blocks.values().map(|b| b.numel()))
                        .sum::<usize>()
                })
                .sum();
            assert_eq!(total, global_mat_count, "{mesh} duplicates weights");
        }
    }

    #[test]
    fn four_way_ln_sync_is_the_paper_pairing() {
        let cfg = tiny_cfg();
        let global = init_global_params(&cfg, 1);
        let mesh = Mesh::from_degree(4).unwrap();
        let s0 = shard_params(&cfg, &mesh, 0, &global).unwrap();
        let s2 = shard_params(&cfg, &mesh, 2, &global).unwrap();
        let v0 = &s0.vecs["blk0_ln1_g"];
        let v2 = &s2.vecs["blk0_ln1_g"];
        assert_eq!(v0.sync_group, vec![0, 2]);
        assert_eq!((v0.lo, v0.hi), (v2.lo, v2.hi));
        assert_eq!(v0.local, v2.local);
    }

    #[test]
    fn two_way_tok_b2_is_replicated() {
        let cfg = tiny_cfg();
        let global = init_global_params(&cfg, 1);
        let mesh = Mesh::from_degree(2).unwrap();
        let s0 = shard_params(&cfg, &mesh, 0, &global).unwrap();
        let s1 = shard_params(&cfg, &mesh, 1, &global).unwrap();
        let a = &s0.vecs["blk0_tok_b2"];
        let b = &s1.vecs["blk0_tok_b2"];
        assert_eq!(a.sync_group, vec![0, 1]);
        assert_eq!(a.local.numel(), cfg.tokens);
        assert_eq!(a.local, b.local);
    }

    #[test]
    fn norm_contrib_counts_replicas_once() {
        let cfg = tiny_cfg();
        let global = init_global_params(&cfg, 5);
        let global_sq: f32 = global
            .iter()
            .flat_map(|(_, t)| t.data.iter().map(|v| v * v))
            .sum();
        for mesh in meshes() {
            let total: f32 = (0..mesh.n())
                .map(|r| {
                    shard_params(&cfg, &mesh, r, &global)
                        .unwrap()
                        .global_norm_sq_contrib()
                })
                .sum();
            assert!(
                (total - global_sq).abs() / global_sq < 1e-5,
                "{mesh}: {total} vs {global_sq}"
            );
        }
    }

    #[test]
    fn grad_reduce_order_is_backward_emission_order() {
        let cfg = tiny_cfg(); // blocks = 2
        let global = init_global_params(&cfg, 0);
        let mut s = shard_params(&cfg, &Mesh::unit(), 0, &global).unwrap();
        let order = s.grad_reduce_order();
        let mat_names: Vec<&str> = order
            .iter()
            .filter_map(|id| match id {
                GradId::Mat(n, _) => Some(n.as_str()),
                GradId::Vec(_) => None,
            })
            .collect();
        assert_eq!(
            mat_names,
            vec![
                "dec_w", "blk1_ch_w2", "blk1_ch_w1", "blk1_tok_w2", "blk1_tok_w1",
                "blk0_ch_w2", "blk0_ch_w1", "blk0_tok_w2", "blk0_tok_w1", "enc_w",
            ],
            "matrix grads must follow the reverse-layer emission order"
        );
        // every matrix id precedes every vector id, vectors in key order
        let first_vec = order
            .iter()
            .position(|id| matches!(id, GradId::Vec(_)))
            .unwrap();
        assert!(order[first_vec..]
            .iter()
            .all(|id| matches!(id, GradId::Vec(_))));
        let vec_names: Vec<&str> = order[first_vec..]
            .iter()
            .map(|id| match id {
                GradId::Vec(n) => n.as_str(),
                GradId::Mat(..) => unreachable!(),
            })
            .collect();
        let mut sorted = vec_names.clone();
        sorted.sort();
        assert_eq!(vec_names, sorted, "vectors flush in key order");
        // the mutable view walks the same tensors in the same order
        let numels: Vec<usize> = order
            .iter()
            .map(|id| match id {
                GradId::Mat(n, k) => s.mats[n].blocks[k].numel(),
                GradId::Vec(n) => s.vecs[n].local.numel(),
            })
            .collect();
        let view_numels: Vec<usize> = s
            .grad_tensors_reduce_order_mut()
            .iter()
            .map(|t| t.numel())
            .collect();
        assert_eq!(numels, view_numels);
        assert_eq!(order.len(), s.grad_tensors_mut().len());
    }

    #[test]
    fn grad_reduce_order_identical_shapes_across_dp_peers() {
        // DP peers share an mp_rank, hence identical shard structure: the
        // registry (and so every bucket boundary) must agree entry for
        // entry. Sharded meshes exercise the multi-block mats.
        let cfg = tiny_cfg();
        for mesh in meshes() {
            for r in 0..mesh.n() {
                let a = shard_params(&cfg, &mesh, r, &init_global_params(&cfg, 1))
                    .unwrap();
                let b = shard_params(&cfg, &mesh, r, &init_global_params(&cfg, 2))
                    .unwrap();
                assert_eq!(
                    a.grad_reduce_order(),
                    b.grad_reduce_order(),
                    "{mesh} rank {r}"
                );
            }
        }
    }

    #[test]
    fn incompatible_mesh_is_a_typed_error() {
        let cfg = tiny_cfg();
        let global = init_global_params(&cfg, 1);
        // ch = 3 does not divide channels_padded = 8
        let err = shard_params(&cfg, &Mesh::new(1, 3).unwrap(), 0, &global).unwrap_err();
        assert!(matches!(err, MeshError::Indivisible { .. }), "{err}");
    }
}
