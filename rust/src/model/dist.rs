//! Jigsaw-distributed WeatherMixer: forward, loss, and hand-derived
//! backward composed from `dist_matmul` calls and rank-local pointwise
//! stages.
//!
//! Every heavy matmul goes through the runtime backend (PJRT primitives);
//! communication points sit *between* backend executions, exactly where
//! the paper's MPI isend/irecv sit between cuBLAS calls — and each
//! `dist_matmul` below runs the ready-queue overlap schedule internally,
//! so a layer's exchanges hide under its own block compute. Layer norms
//! use local channel-shard statistics (paper Section 5), which the AOT
//! oracle reproduces with `ln_groups = 2`; their replicated affine grads
//! are reconciled by the bucketed per-sync-group reduce in
//! `PStore::sync_replicated_grads`.
//!
//! The backward pass is *grad-ready instrumented*: `loss_and_grad_with`
//! hands each finished gradient tensor to a [`GradSink`] while earlier
//! layers are still differentiating (matrices in reverse-layer order,
//! vectors after the replicated sync — the sequence pinned by
//! `PStore::grad_reduce_order`). The trainer's `GradReduceScheduler`
//! rides this hook to launch DP bucket ring-allreduces under backward
//! compute, the overlap the paper's Section 6.3.4 scaling relies on.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use super::params::{GradSink, NullSink, PStore};
use super::{latitude_weights, patchify, unpatchify};
use crate::config::ModelConfig;
use crate::jigsaw::{dist_matmul, BlockGrid, Ctx, DistMat, Mesh, Planner, Site};
use crate::runtime::MatmulOp;
use crate::tensor::{ops, Precision, Tensor};

/// Saved layer-norm statistics per local block.
type LnSavedMap = BTreeMap<(usize, usize), ops::LnSaved>;

/// What the shared forward core does with per-layer intermediates.
///
/// `Train` keeps every activation in a [`FwdCache`] for the backward
/// pass; `Infer` recycles each layer's tensors into the thread-local
/// buffer pool the moment the next layer no longer needs them, so a
/// steady-state forward-only step allocates nothing matmul-sized. The
/// *arithmetic* is identical either way — `infer_props` pins the two
/// modes bit-identical — retention is the only difference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Retention {
    Train,
    Infer,
}

/// Forward cache of one mixer block.
pub struct MixCache {
    z_in: DistMat,
    u: DistMat,
    ln1: LnSavedMap,
    h1_pre: DistMat,
    h1: DistMat,
    z2: DistMat,
    v: DistMat,
    ln2: LnSavedMap,
    h2_pre: DistMat,
    h2: DistMat,
}

/// Forward cache of a full pass (supports rollout > 1: one entry of
/// `iters` per processor application — the paper's randomized-rollout
/// fine-tuning repeats only the processor, Section 6).
pub struct FwdCache {
    pub patches: DistMat,
    pub z0: DistMat,
    pub iters: Vec<Vec<MixCache>>,
    pub z_final: DistMat,
    pub y_patches: DistMat,
    pub delta_local: Tensor,
    pub x_local: Tensor,
}

/// One rank's WeatherMixer instance on a device mesh.
pub struct DistModel {
    pub cfg: ModelConfig,
    pub mesh: Mesh,
    pub rank: usize,
    pub params: PStore,
}

impl DistModel {
    pub fn new(cfg: ModelConfig, mesh: &Mesh, rank: usize, params: PStore) -> Self {
        DistModel { cfg, mesh: *mesh, rank, params }
    }

    fn planner(&self) -> Planner {
        Planner::new(self.mesh)
    }

    /// This rank's (tok, ch) coordinate on the mesh.
    pub fn coord(&self) -> (usize, usize) {
        self.mesh.coord_of(self.rank)
    }

    /// local spatial/channel extents
    pub fn local_dims(&self) -> (usize, usize, usize) {
        (
            self.cfg.lat / self.mesh.tok(),
            self.cfg.lon,
            self.cfg.channels_padded / self.mesh.ch(),
        )
    }

    /// global row offset of this rank's latitude slice
    pub fn lat_offset(&self) -> usize {
        self.planner().tok_block_of(self.rank) * (self.cfg.lat / self.mesh.tok())
    }

    /// global channel offset of this rank's channel slice
    pub fn ch_offset(&self) -> usize {
        self.planner().ch_block_of(self.rank)
            * (self.cfg.channels_padded / self.mesh.ch())
    }

    // -- local pointwise helpers -----------------------------------------

    /// column-bias add, in place on every local block (vec sliced to the
    /// block's global column range).
    fn add_vec_cols_assign(&self, m: &mut DistMat, v: &super::params::VecShard) {
        let (_, bc) = m.block_dims();
        for (&(_, bj), t) in m.blocks.iter_mut() {
            debug_assert_eq!(bj * bc, v.lo, "col-bias slice misaligned");
            ops::add_bias_cols_assign(t, &v.local);
        }
        m.cache = None;
    }

    /// row-bias add, in place on every local block.
    fn add_vec_rows_assign(&self, m: &mut DistMat, v: &super::params::VecShard) {
        let (br, _) = m.block_dims();
        for (&(bi, _), t) in m.blocks.iter_mut() {
            debug_assert_eq!(bi * br, v.lo, "row-bias slice misaligned");
            ops::add_bias_rows_assign(t, &v.local);
        }
        m.cache = None;
    }

    /// layer norm over the local channel shard of every block.
    fn ln_fwd(
        &self,
        m: &DistMat,
        g: &super::params::VecShard,
        b: &super::params::VecShard,
    ) -> (DistMat, LnSavedMap) {
        let mut saved = LnSavedMap::new();
        let out = m_map_keyed(m, |key, t| {
            let (y, s) = ops::layernorm(t, &g.local, &b.local);
            saved.insert(key, s);
            y
        });
        (out, saved)
    }

    fn ln_bwd(
        &self,
        x: &DistMat,
        g: &super::params::VecShard,
        saved: &LnSavedMap,
        dy: &DistMat,
    ) -> (DistMat, Tensor, Tensor) {
        let mut dg_acc: Option<Tensor> = None;
        let mut db_acc: Option<Tensor> = None;
        let mut blocks = BTreeMap::new();
        for (key, xb) in &x.blocks {
            let (dxb, dgb, dbb) =
                ops::layernorm_bwd(xb, &g.local, &saved[key], &dy.blocks[key]);
            blocks.insert(*key, dxb);
            match &mut dg_acc {
                None => {
                    dg_acc = Some(dgb);
                    db_acc = Some(dbb);
                }
                Some(a) => {
                    ops::add_assign(a, &dgb);
                    ops::add_assign(db_acc.as_mut().unwrap(), &dbb);
                }
            }
        }
        let dx = DistMat {
            grid: x.grid.clone(),
            rows: x.rows,
            cols: x.cols,
            blocks,
            cache: None,
        };
        (dx, dg_acc.unwrap(), db_acc.unwrap())
    }

    /// grad of a column bias: sum over rows of every local block,
    /// accumulated in place (no per-block temporaries).
    fn bias_cols_grad(&self, dy: &DistMat) -> Tensor {
        let (_, bc) = dy.block_dims();
        assert!(!dy.blocks.is_empty(), "rank owns no blocks");
        let mut acc = Tensor::zeros(&[bc]);
        for b in dy.blocks.values() {
            ops::sum_rows_acc(b, &mut acc);
        }
        acc
    }

    /// grad of a row bias: sum over cols of every local block.
    fn bias_rows_grad(&self, dy: &DistMat) -> Tensor {
        let (br, _) = dy.block_dims();
        assert!(!dy.blocks.is_empty(), "rank owns no blocks");
        let mut acc = Tensor::zeros(&[br]);
        for b in dy.blocks.values() {
            ops::sum_cols_acc(b, &mut acc);
        }
        acc
    }

    // -- grids -------------------------------------------------------------

    fn act_grid(&self) -> BlockGrid {
        self.planner().act()
    }

    /// bf16 activation storage: round the residual stream to bf16
    /// (round to nearest even) at layer boundaries — a no-op in f32
    /// mode. Master weights and every accumulation stay f32; this
    /// models the memory half of the mixed-precision policy the way
    /// the fabric payloads model the communication half.
    fn store_act(&self, ctx: &Ctx, m: &mut DistMat) {
        if ctx.precision == Precision::Bf16 {
            m.map_assign(|t| crate::tensor::bf16::quantize_slice(&mut t.data));
        }
    }

    // -- forward ------------------------------------------------------------

    fn mixer_block_fwd(
        &self,
        ctx: &mut Ctx,
        i: usize,
        z: DistMat,
    ) -> Result<(DistMat, MixCache)> {
        let p = &self.params;
        let l = self.planner();
        let name = |s: &str| format!("blk{i}_{s}");

        // token mixing (transposed-MLP form). Linear outputs are consumed
        // in place: bias adds and the residual land in the dist_matmul
        // result's buffers, so no activation-sized temporaries are left
        // behind (the residual input z survives in the cache).
        let (u, ln1) = self.ln_fwd(&z, &p.vecs[&name("ln1_g")], &p.vecs[&name("ln1_b")]);
        let mut h1_pre = dist_matmul(
            ctx,
            MatmulOp::NN,
            &p.mats[&name("tok_w1")],
            &u,
            &l.tok_hidden(),
            Site::XOwner,
        )?;
        self.add_vec_rows_assign(&mut h1_pre, &p.vecs[&name("tok_b1")]);
        let h1 = h1_pre.map(ops::gelu);
        let mut tokout = dist_matmul(
            ctx,
            MatmulOp::NN,
            &p.mats[&name("tok_w2")],
            &h1,
            &self.act_grid(),
            Site::XOwner,
        )?;
        self.add_vec_rows_assign(&mut tokout, &p.vecs[&name("tok_b2")]);
        let mut z2 = tokout;
        z2.zip_assign(&z, |a, b| ops::add_assign(a, b));

        // channel mixing
        let (v, ln2) = self.ln_fwd(&z2, &p.vecs[&name("ln2_g")], &p.vecs[&name("ln2_b")]);
        let mut h2_pre = dist_matmul(
            ctx,
            MatmulOp::NT,
            &v,
            &p.mats[&name("ch_w1")],
            &self.act_grid(),
            Site::WOwner,
        )?;
        self.add_vec_cols_assign(&mut h2_pre, &p.vecs[&name("ch_b1")]);
        let h2 = h2_pre.map(ops::gelu);
        let mut z3 = dist_matmul(
            ctx,
            MatmulOp::NT,
            &h2,
            &p.mats[&name("ch_w2")],
            &self.act_grid(),
            Site::WOwner,
        )?;
        self.add_vec_cols_assign(&mut z3, &p.vecs[&name("ch_b2")]);
        z3.zip_assign(&z2, |a, b| ops::add_assign(a, b));
        self.store_act(ctx, &mut z3);

        let cache = MixCache {
            z_in: z,
            u,
            ln1,
            h1_pre,
            h1,
            z2,
            v,
            ln2,
            h2_pre,
            h2,
        };
        Ok((z3, cache))
    }

    /// Gated blend, in place: `out = g*x + (1-g)*out` per channel, where
    /// `out` arrives holding the decoded delta. The single blend
    /// implementation both forward modes share.
    fn blend_pred_assign(&self, pred: &mut Tensor, x_local: &Tensor) {
        let (lat_l, lon_l, c_l) = self.local_dims();
        let gate = &self.params.vecs["blend_g"];
        for li in 0..lat_l {
            for lj in 0..lon_l {
                for c in 0..c_l {
                    let idx = (li * lon_l + lj) * c_l + c;
                    let g = ops::sigmoid(gate.local.data[c]);
                    pred.data[idx] =
                        g * x_local.data[idx] + (1.0 - g) * pred.data[idx];
                }
            }
        }
    }

    /// The one forward implementation. Both consumers go through here:
    /// the training path ([`forward`](DistModel::forward), and through
    /// it `loss_and_grad`) with [`Retention::Train`], and the
    /// forward-only inference path ([`forward_infer`](DistModel::forward_infer),
    /// wrapped by `model::InferModel`) with [`Retention::Infer`]. The
    /// arithmetic — and therefore the prediction bits — does not depend
    /// on `retain`; only what happens to intermediates does.
    fn forward_core(
        &self,
        ctx: &mut Ctx,
        x_local: &Tensor,
        rollout: usize,
        retain: Retention,
    ) -> Result<(Tensor, Option<FwdCache>)> {
        let cfg = &self.cfg;
        ensure!(
            ctx.mesh == self.mesh,
            "ctx mesh {} != model mesh {}",
            ctx.mesh,
            self.mesh
        );
        let (lat_l, lon_l, c_l) = self.local_dims();
        ensure!(
            x_local.shape == vec![lat_l, lon_l, c_l],
            "sample shard shape {:?}, want [{lat_l},{lon_l},{c_l}]",
            x_local.shape
        );
        let keep = retain == Retention::Train;
        let p = &self.params;
        let l = self.planner();

        // encoder: local patchify -> this rank's block of the patch matrix
        let patches_local = patchify(x_local, lat_l, lon_l, c_l, cfg.patch);
        let mut patches = DistMat::empty(cfg.tokens, cfg.patch_dim, self.act_grid());
        patches.blocks.insert(
            (l.tok_block_of(self.rank), l.ch_block_of(self.rank)),
            patches_local,
        );
        let mut z0 = dist_matmul(
            ctx,
            MatmulOp::NT,
            &patches,
            &p.mats["enc_w"],
            &self.act_grid(),
            Site::WOwner,
        )?;
        self.add_vec_cols_assign(&mut z0, &p.vecs["enc_b"]);
        self.store_act(ctx, &mut z0);

        // processor (rollout repeats). Training clones z0 (the backward
        // needs it); inference moves it — the first mixer block's cache
        // recycles it.
        let (mut z, z0) = if keep {
            (z0.clone(), Some(z0))
        } else {
            recycle_dist(std::mem::replace(
                &mut patches,
                DistMat::empty(0, 0, self.act_grid()),
            ));
            (z0, None)
        };
        let mut iters = Vec::with_capacity(if keep { rollout } else { 0 });
        for _ in 0..rollout {
            let mut caches = Vec::with_capacity(if keep { cfg.blocks } else { 0 });
            for i in 0..cfg.blocks {
                let (znext, c) = self.mixer_block_fwd(ctx, i, z)?;
                z = znext;
                if keep {
                    caches.push(c);
                } else {
                    recycle_mix(c);
                }
            }
            if keep {
                iters.push(caches);
            }
        }
        let z_final = z;

        // decoder
        let mut y_patches = dist_matmul(
            ctx,
            MatmulOp::NT,
            &z_final,
            &p.mats["dec_w"],
            &self.act_grid(),
            Site::WOwner,
        )?;
        self.add_vec_cols_assign(&mut y_patches, &p.vecs["dec_b"]);
        let y_local = y_patches
            .blocks
            .values()
            .next()
            .expect("rank owns an output block");
        let delta_local = unpatchify(y_local, lat_l, lon_l, c_l, cfg.patch);

        // blend: out = g*x + (1-g)*delta, per channel
        let mut pred = delta_local.clone();
        self.blend_pred_assign(&mut pred, x_local);

        if !keep {
            recycle_dist(z_final);
            recycle_dist(y_patches);
            delta_local.recycle();
            return Ok((pred, None));
        }
        Ok((
            pred,
            Some(FwdCache {
                patches,
                z0: z0.expect("train retention keeps z0"),
                iters,
                z_final,
                y_patches,
                delta_local,
                x_local: x_local.clone(),
            }),
        ))
    }

    /// Full forward from this rank's sample shard, retaining the
    /// activation cache for backward. `rollout` repeats the processor
    /// with a single encode/decode.
    pub fn forward(
        &self,
        ctx: &mut Ctx,
        x_local: &Tensor,
        rollout: usize,
    ) -> Result<(Tensor, FwdCache)> {
        let (pred, cache) = self.forward_core(ctx, x_local, rollout, Retention::Train)?;
        Ok((pred, cache.expect("train retention returns a cache")))
    }

    /// Forward-only pass: same core, no cache, per-layer activations
    /// recycled into the buffer pool. The serving path.
    pub fn forward_infer(
        &self,
        ctx: &mut Ctx,
        x_local: &Tensor,
        rollout: usize,
    ) -> Result<Tensor> {
        let (pred, _) = self.forward_core(ctx, x_local, rollout, Retention::Infer)?;
        Ok(pred)
    }

    /// Latitude/variable-weighted MSE over the local shard (not yet
    /// reduced across the group).
    pub fn local_loss(&self, pred: &Tensor, target: &Tensor) -> f32 {
        let (lat_l, lon_l, c_l) = self.local_dims();
        let wlat = latitude_weights(self.cfg.lat);
        let wch = self.cfg.padded_channel_weights();
        let (lat0, ch0) = (self.lat_offset(), self.ch_offset());
        let norm = (self.cfg.lat * self.cfg.lon * self.cfg.channels_padded) as f32;
        let mut s = 0.0f32;
        for li in 0..lat_l {
            for lj in 0..lon_l {
                for c in 0..c_l {
                    let idx = (li * lon_l + lj) * c_l + c;
                    let e = pred.data[idx] - target.data[idx];
                    s += wlat[lat0 + li] * wch[ch0 + c] * e * e;
                }
            }
        }
        s / norm
    }

    /// d(loss)/d(pred) over the local shard.
    fn loss_grad(&self, pred: &Tensor, target: &Tensor) -> Tensor {
        let (lat_l, lon_l, c_l) = self.local_dims();
        let wlat = latitude_weights(self.cfg.lat);
        let wch = self.cfg.padded_channel_weights();
        let (lat0, ch0) = (self.lat_offset(), self.ch_offset());
        let norm = (self.cfg.lat * self.cfg.lon * self.cfg.channels_padded) as f32;
        let mut out = Tensor::zeros(&[lat_l, lon_l, c_l]);
        for li in 0..lat_l {
            for lj in 0..lon_l {
                for c in 0..c_l {
                    let idx = (li * lon_l + lj) * c_l + c;
                    out.data[idx] = 2.0
                        * wlat[lat0 + li]
                        * wch[ch0 + c]
                        * (pred.data[idx] - target.data[idx])
                        / norm;
                }
            }
        }
        out
    }

    /// Backward of one mixer block. When `emit` is set (the final
    /// rollout iteration — the last pass that touches these weights),
    /// each weight gradient is handed to `sink` the moment its
    /// accumulation completes, in the order the math finishes them:
    /// `ch_w2, ch_w1, tok_w2, tok_w1` — the per-block slice of
    /// `PStore::grad_reduce_order`.
    #[allow(clippy::too_many_arguments)]
    fn mixer_block_bwd(
        &self,
        ctx: &mut Ctx,
        i: usize,
        cache: &MixCache,
        dz3: &DistMat,
        grads: &mut PStore,
        sink: &mut dyn GradSink,
        emit: bool,
    ) -> Result<DistMat> {
        let p = &self.params;
        let l = self.planner();
        let name = |s: &str| format!("blk{i}_{s}");
        let ready = |grads: &PStore, sink: &mut dyn GradSink, n: &str| {
            if emit {
                sink.mat_ready(n, &grads.mats[n]);
            }
        };

        // -- channel mixing backward --
        let dchout = dz3;
        add_vec_grad(grads, &name("ch_b2"), &self.bias_cols_grad(dchout));
        let dh2 = dist_matmul(
            ctx,
            MatmulOp::NN,
            dchout,
            &p.mats[&name("ch_w2")],
            &cache.h2.grid,
            Site::WOwner,
        )?;
        let d_ch_w2 = dist_matmul(
            ctx,
            MatmulOp::TN,
            dchout,
            &cache.h2,
            &p.mats[&name("ch_w2")].grid,
            Site::WOwner,
        )?;
        add_mat_grad(grads, &name("ch_w2"), d_ch_w2);
        ready(grads, sink, &name("ch_w2"));
        let mut dh2_pre = dh2;
        dh2_pre.zip_assign(&cache.h2_pre, |d, x| ops::gelu_bwd_assign(x, d));
        add_vec_grad(grads, &name("ch_b1"), &self.bias_cols_grad(&dh2_pre));
        let dv = dist_matmul(
            ctx,
            MatmulOp::NN,
            &dh2_pre,
            &p.mats[&name("ch_w1")],
            &self.act_grid(),
            Site::WOwner,
        )?;
        let d_ch_w1 = dist_matmul(
            ctx,
            MatmulOp::TN,
            &dh2_pre,
            &cache.v,
            &p.mats[&name("ch_w1")].grid,
            Site::WOwner,
        )?;
        add_mat_grad(grads, &name("ch_w1"), d_ch_w1);
        ready(grads, sink, &name("ch_w1"));
        let (mut dz2, dg2, db2) =
            self.ln_bwd(&cache.z2, &p.vecs[&name("ln2_g")], &cache.ln2, &dv);
        add_vec_grad(grads, &name("ln2_g"), &dg2);
        add_vec_grad(grads, &name("ln2_b"), &db2);
        dz2.zip_assign(dz3, |a, b| ops::add_assign(a, b));

        // -- token mixing backward --
        let dtokout = &dz2;
        add_vec_grad(grads, &name("tok_b2"), &self.bias_rows_grad(dtokout));
        let dh1 = dist_matmul(
            ctx,
            MatmulOp::TN,
            &p.mats[&name("tok_w2")],
            dtokout,
            &l.tok_hidden(),
            Site::XOwner,
        )?;
        let d_tok_w2 = dist_matmul(
            ctx,
            MatmulOp::NT,
            dtokout,
            &cache.h1,
            &p.mats[&name("tok_w2")].grid,
            Site::WOwner,
        )?;
        add_mat_grad(grads, &name("tok_w2"), d_tok_w2);
        ready(grads, sink, &name("tok_w2"));
        let mut dh1_pre = dh1;
        dh1_pre.zip_assign(&cache.h1_pre, |d, x| ops::gelu_bwd_assign(x, d));
        add_vec_grad(grads, &name("tok_b1"), &self.bias_rows_grad(&dh1_pre));
        let du = dist_matmul(
            ctx,
            MatmulOp::TN,
            &p.mats[&name("tok_w1")],
            &dh1_pre,
            &self.act_grid(),
            Site::XOwner,
        )?;
        let d_tok_w1 = dist_matmul(
            ctx,
            MatmulOp::NT,
            &dh1_pre,
            &cache.u,
            &p.mats[&name("tok_w1")].grid,
            Site::XOwner,
        )?;
        add_mat_grad(grads, &name("tok_w1"), d_tok_w1);
        ready(grads, sink, &name("tok_w1"));
        let (mut dz, dg1, db1) =
            self.ln_bwd(&cache.z_in, &p.vecs[&name("ln1_g")], &cache.ln1, &du);
        add_vec_grad(grads, &name("ln1_g"), &dg1);
        add_vec_grad(grads, &name("ln1_b"), &db1);
        dz.zip_assign(&dz2, |a, b| ops::add_assign(a, b));
        Ok(dz)
    }

    /// Loss + parameter gradients for one (x, y) sample shard. The loss is
    /// group-reduced; replicated-vector grads are group-synced (the
    /// paper's pairwise reduce). `rollout` as in `forward`.
    pub fn loss_and_grad(
        &self,
        ctx: &mut Ctx,
        x_local: &Tensor,
        y_local: &Tensor,
        rollout: usize,
    ) -> Result<(f32, PStore)> {
        self.loss_and_grad_with(ctx, x_local, y_local, rollout, &mut NullSink)
    }

    /// [`loss_and_grad`](DistModel::loss_and_grad) with a grad-ready
    /// hook: `sink` is notified the moment each gradient tensor is
    /// final, while earlier layers are still differentiating — matrix
    /// grads stream out in reverse-layer order (decoder, blocks from
    /// last to first, encoder); vector grads flush after the replicated
    /// sync, in key order. The emission sequence is exactly
    /// `PStore::grad_reduce_order`, which is what lets the trainer's DP
    /// scheduler start bucket ring-allreduces *under* the backward pass
    /// (paper Section 6.3.4) and still reduce bit-identically to the
    /// post-hoc oracle.
    pub fn loss_and_grad_with(
        &self,
        ctx: &mut Ctx,
        x_local: &Tensor,
        y_local: &Tensor,
        rollout: usize,
        sink: &mut dyn GradSink,
    ) -> Result<(f32, PStore)> {
        let cfg = &self.cfg;
        let (pred, cache) = self.forward(ctx, x_local, rollout)?;
        let local_loss = self.local_loss(&pred, y_local);
        let group = self.mesh.ranks();
        let loss = ctx.comm.allreduce_scalar(&group, local_loss);

        let mut grads = self.params.zeros_like();
        let p = &self.params;
        let (lat_l, lon_l, c_l) = self.local_dims();

        // blend backward
        let dpred = self.loss_grad(&pred, y_local);
        let gate = &p.vecs["blend_g"];
        let mut ddelta = Tensor::zeros(&[lat_l, lon_l, c_l]);
        let mut dgate = Tensor::zeros(&[c_l]);
        for li in 0..lat_l {
            for lj in 0..lon_l {
                for c in 0..c_l {
                    let idx = (li * lon_l + lj) * c_l + c;
                    let g = ops::sigmoid(gate.local.data[c]);
                    ddelta.data[idx] = dpred.data[idx] * (1.0 - g);
                    dgate.data[c] += dpred.data[idx]
                        * (cache.x_local.data[idx] - cache.delta_local.data[idx])
                        * g
                        * (1.0 - g);
                }
            }
        }
        add_vec_grad(&mut grads, "blend_g", &dgate);

        // decoder backward
        let dy_local = patchify(&ddelta, lat_l, lon_l, c_l, cfg.patch);
        let mut dy = DistMat::empty(cfg.tokens, cfg.patch_dim, self.act_grid());
        let l = self.planner();
        dy.blocks.insert(
            (l.tok_block_of(self.rank), l.ch_block_of(self.rank)),
            dy_local,
        );
        add_vec_grad(&mut grads, "dec_b", &self.bias_cols_grad(&dy));
        let mut dz = dist_matmul(
            ctx,
            MatmulOp::NN,
            &dy,
            &p.mats["dec_w"],
            &self.act_grid(),
            Site::WOwner,
        )?;
        let d_dec_w = dist_matmul(
            ctx,
            MatmulOp::TN,
            &dy,
            &cache.z_final,
            &p.mats["dec_w"].grid,
            Site::WOwner,
        )?;
        add_mat_grad(&mut grads, "dec_w", d_dec_w);
        sink.mat_ready("dec_w", &grads.mats["dec_w"]);

        // processor backward (reverse rollout, reverse blocks). Weight
        // grads accumulate across every rollout iteration, so they are
        // only emitted on the final (first-rollout) pass.
        let iters = cache.iters.len();
        for (rev, iter_cache) in cache.iters.iter().rev().enumerate() {
            let emit = rev + 1 == iters;
            for (i, c) in iter_cache.iter().enumerate().rev() {
                dz = self.mixer_block_bwd(ctx, i, c, &dz, &mut grads, sink, emit)?;
            }
        }

        // encoder backward
        add_vec_grad(&mut grads, "enc_b", &self.bias_cols_grad(&dz));
        let d_enc_w = dist_matmul(
            ctx,
            MatmulOp::TN,
            &dz,
            &cache.patches,
            &p.mats["enc_w"].grid,
            Site::WOwner,
        )?;
        add_mat_grad(&mut grads, "enc_w", d_enc_w);
        sink.mat_ready("enc_w", &grads.mats["enc_w"]);

        // the paper's pairwise reduce for replicated parameters; only
        // now are the (replicated) vector grads final
        grads.sync_replicated_grads(ctx.comm);
        for (name, v) in &grads.vecs {
            sink.vec_ready(name, &v.local);
        }

        Ok((loss, grads))
    }
}

/// Return every local block buffer of a consumed [`DistMat`] to the
/// thread-local pool (inference retention).
fn recycle_dist(m: DistMat) {
    for (_, b) in m.blocks {
        b.recycle();
    }
}

/// Recycle a whole mixer-block cache: every activation `DistMat`. The
/// `LnSaved` statistics are plain vectors and simply drop.
fn recycle_mix(c: MixCache) {
    let MixCache { z_in, u, ln1: _, h1_pre, h1, z2, v, ln2: _, h2_pre, h2 } = c;
    for m in [z_in, u, h1_pre, h1, z2, v, h2_pre, h2] {
        recycle_dist(m);
    }
}

fn m_map_keyed(
    m: &DistMat,
    mut f: impl FnMut((usize, usize), &Tensor) -> Tensor,
) -> DistMat {
    DistMat {
        grid: m.grid.clone(),
        rows: m.rows,
        cols: m.cols,
        blocks: m.blocks.iter().map(|(k, v)| (*k, f(*k, v))).collect(),
        cache: None,
    }
}

fn add_mat_grad(grads: &mut PStore, name: &str, d: DistMat) {
    let g = grads.mats.get_mut(name).expect("unknown mat grad");
    for (k, b) in d.blocks {
        match g.blocks.get_mut(&k) {
            Some(acc) => ops::add_assign(acc, &b),
            None => {
                g.blocks.insert(k, b);
            }
        }
    }
}

fn add_vec_grad(grads: &mut PStore, name: &str, d: &Tensor) {
    let g = grads.vecs.get_mut(name).expect("unknown vec grad");
    ops::add_assign(&mut g.local, d);
}
