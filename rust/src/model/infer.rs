//! Forward-only model wrapper: the inference consumer of the shared
//! forward core.
//!
//! [`InferModel`] is what the serving engine instantiates per mesh rank.
//! It is a [`DistModel`] built on a sync-group-free parameter store
//! ([`shard_params_infer`]) and restricted to the
//! [`Retention::Infer`](crate::model::dist::Retention) forward path:
//! no `FwdCache` is ever materialized, no gradient registry exists, and
//! every per-layer activation is recycled into the thread-local buffer
//! pool as soon as the next layer has consumed it — a steady-state
//! rollout step performs no matmul-sized allocations. Predictions are
//! pinned bit-identical to the training path's forward
//! (`tests/infer_props.rs`): there is exactly one forward
//! implementation, `DistModel::forward_core`, and this type merely
//! selects its retention policy.

use anyhow::Result;

use super::dist::DistModel;
use super::params::shard_params_infer;
use crate::config::ModelConfig;
use crate::jigsaw::{Ctx, Mesh, MeshError};
use crate::tensor::Tensor;

/// One rank's forward-only WeatherMixer instance.
pub struct InferModel {
    model: DistModel,
}

impl InferModel {
    /// Shard `global` weights for `rank` on `mesh` (sync-group-free) and
    /// wrap them. Weights typically come from a checkpoint via
    /// `checkpoint::load_params` — never Adam or scaler state.
    pub fn new(
        cfg: ModelConfig,
        mesh: &Mesh,
        rank: usize,
        global: &[(String, Tensor)],
    ) -> Result<Self, MeshError> {
        let params = shard_params_infer(&cfg, mesh, rank, global)?;
        Ok(InferModel { model: DistModel::new(cfg, mesh, rank, params) })
    }

    /// One forward-only step from this rank's sample shard. `rollout`
    /// repeats the processor exactly as the training forward does.
    pub fn predict(
        &self,
        ctx: &mut Ctx,
        x_local: &Tensor,
        rollout: usize,
    ) -> Result<Tensor> {
        self.model.forward_infer(ctx, x_local, rollout)
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.model.cfg
    }

    /// local (lat, lon, channel) extents — see [`DistModel::local_dims`]
    pub fn local_dims(&self) -> (usize, usize, usize) {
        self.model.local_dims()
    }

    /// global latitude offset of this rank's shard
    pub fn lat_offset(&self) -> usize {
        self.model.lat_offset()
    }

    /// global channel offset of this rank's shard
    pub fn ch_offset(&self) -> usize {
        self.model.ch_offset()
    }
}
