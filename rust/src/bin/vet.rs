//! `vet` — run the repo's static lint registry from the command line.
//!
//! ```text
//! vet [--json PATH] [--sarif PATH] [--format human|json|sarif]
//!     [--changed [BASE]] [--list] [--self-test DIR] [PATHS...]
//! ```
//!
//! With no `PATHS`, lints `rust/src`. `--changed` lints only the `.rs`
//! files that `git diff --name-only BASE` reports (default base
//! `HEAD`), while still building the cross-file lock-order call graph
//! over all of `rust/src` so an inversion whose other half lives in an
//! unchanged file is caught. Exit codes: 0 clean (or self-test pass),
//! 1 findings (or self-test failure), 2 usage error or unreadable
//! files — an unreadable file mid-walk is reported by path and the
//! remaining files still get linted before the run fails. `--json` /
//! `--sarif` additionally write the machine-readable reports (CI
//! uploads the SARIF as code-scanning annotations); `--self-test`
//! checks the seeded-bad fixture corpus instead of linting.

use std::path::PathBuf;
use std::process::ExitCode;

use jigsaw::vet;

fn main() -> ExitCode {
    let mut json_path: Option<PathBuf> = None;
    let mut sarif_path: Option<PathBuf> = None;
    let mut format = "human".to_string();
    let mut changed_base: Option<String> = None;
    let mut self_test_dir: Option<PathBuf> = None;
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut args = std::env::args().skip(1).peekable();
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => match args.next() {
                Some(p) => json_path = Some(PathBuf::from(p)),
                None => return usage("--json needs a path"),
            },
            "--sarif" => match args.next() {
                Some(p) => sarif_path = Some(PathBuf::from(p)),
                None => return usage("--sarif needs a path"),
            },
            "--format" => match args.next() {
                Some(f) if matches!(f.as_str(), "human" | "json" | "sarif") => format = f,
                Some(f) => return usage(&format!("unknown format `{f}`")),
                None => return usage("--format needs human|json|sarif"),
            },
            "--changed" => {
                // optional BASE operand: consume the next arg unless it
                // looks like another flag
                changed_base = Some(match args.peek() {
                    Some(n) if !n.starts_with('-') => {
                        args.next().unwrap_or_else(|| "HEAD".to_string())
                    }
                    _ => "HEAD".to_string(),
                });
            }
            "--self-test" => match args.next() {
                Some(p) => self_test_dir = Some(PathBuf::from(p)),
                None => return usage("--self-test needs a directory"),
            },
            "--list" => {
                for r in vet::RULES {
                    println!("{:24} {}", r.name, r.summary);
                }
                return ExitCode::SUCCESS;
            }
            "--help" | "-h" => return usage(""),
            a if a.starts_with('-') => return usage(&format!("unknown flag `{a}`")),
            p => paths.push(PathBuf::from(p)),
        }
    }

    if let Some(dir) = self_test_dir {
        return match vet::self_test(&dir) {
            Ok(results) if !results.is_empty() => {
                let mut ok = true;
                for r in &results {
                    let mark = if r.ok { "ok  " } else { "FAIL" };
                    println!("{mark} {} ({}): {}", r.file, r.expected_rule, r.detail);
                    ok &= r.ok;
                }
                if ok {
                    println!("vet self-test: {} fixture(s) pass", results.len());
                    ExitCode::SUCCESS
                } else {
                    ExitCode::FAILURE
                }
            }
            Ok(_) => {
                eprintln!("vet self-test: no fixtures found in {}", dir.display());
                ExitCode::from(2)
            }
            Err(e) => {
                eprintln!("vet self-test: {e}");
                ExitCode::from(2)
            }
        };
    }

    let res = if let Some(base) = changed_base {
        if !paths.is_empty() {
            return usage("--changed takes a git base, not explicit PATHS");
        }
        let changed = match changed_rs_files(&base) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("vet: --changed: {e}");
                return ExitCode::from(2);
            }
        };
        if changed.is_empty() {
            println!("vet: no changed .rs files vs {base}");
            return ExitCode::SUCCESS;
        }
        let graph = match vet::collect_rs_files(&[PathBuf::from("rust/src")]) {
            Ok(g) => g,
            Err(e) => {
                eprintln!("vet: {e}");
                return ExitCode::from(2);
            }
        };
        vet::analyze_file_set(&changed, &graph)
    } else {
        if paths.is_empty() {
            paths.push(PathBuf::from("rust/src"));
        }
        vet::analyze_paths(&paths)
    };

    match res {
        Ok(res) => {
            match format.as_str() {
                "json" => println!("{}", vet::report_json(&res)),
                "sarif" => println!("{}", vet::report_sarif(&res.findings)),
                _ => print!("{}", vet::report_human(&res)),
            }
            if let Some(p) = json_path {
                if let Err(e) = std::fs::write(&p, vet::report_json(&res)) {
                    eprintln!("vet: writing {}: {e}", p.display());
                    return ExitCode::from(2);
                }
            }
            if let Some(p) = sarif_path {
                if let Err(e) = std::fs::write(&p, vet::report_sarif(&res.findings)) {
                    eprintln!("vet: writing {}: {e}", p.display());
                    return ExitCode::from(2);
                }
            }
            if !res.errors.is_empty() {
                ExitCode::from(2)
            } else if res.findings.is_empty() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("vet: {e}");
            ExitCode::from(2)
        }
    }
}

/// `.rs` files changed vs `base`, per `git diff --name-only` (plus
/// untracked files via `git ls-files --others`), filtered to paths that
/// still exist — deletions lint nothing.
fn changed_rs_files(base: &str) -> Result<Vec<PathBuf>, String> {
    let mut names = git_lines(&["diff", "--name-only", base, "--"])?;
    names.extend(git_lines(&["ls-files", "--others", "--exclude-standard"])?);
    let mut out: Vec<PathBuf> = names
        .into_iter()
        .map(PathBuf::from)
        .filter(|p| p.extension().map_or(false, |e| e == "rs") && p.is_file())
        .collect();
    out.sort();
    out.dedup();
    Ok(out)
}

fn git_lines(args: &[&str]) -> Result<Vec<String>, String> {
    let out = std::process::Command::new("git")
        .args(args)
        .output()
        .map_err(|e| format!("running git: {e}"))?;
    if !out.status.success() {
        return Err(format!(
            "git {} failed: {}",
            args.join(" "),
            String::from_utf8_lossy(&out.stderr).trim()
        ));
    }
    Ok(String::from_utf8_lossy(&out.stdout)
        .lines()
        .map(str::to_string)
        .filter(|l| !l.is_empty())
        .collect())
}

fn usage(err: &str) -> ExitCode {
    if !err.is_empty() {
        eprintln!("vet: {err}");
    }
    eprintln!(
        "usage: vet [--json PATH] [--sarif PATH] [--format human|json|sarif] \
         [--changed [BASE]] [--list] [--self-test DIR] [PATHS...]"
    );
    if err.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(2)
    }
}
