//! `vet` — run the repo's static lint registry from the command line.
//!
//! ```text
//! vet [--json PATH] [--list] [--self-test DIR] [PATHS...]
//! ```
//!
//! With no `PATHS`, lints `rust/src`. Exit codes: 0 clean (or
//! self-test pass), 1 findings (or self-test failure), 2 usage / I/O
//! error. `--json` additionally writes the machine-readable report
//! (CI uploads it as an artifact); `--self-test` checks the seeded-bad
//! fixture corpus instead of linting.

use std::path::PathBuf;
use std::process::ExitCode;

use jigsaw::vet;

fn main() -> ExitCode {
    let mut json_path: Option<PathBuf> = None;
    let mut self_test_dir: Option<PathBuf> = None;
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => match args.next() {
                Some(p) => json_path = Some(PathBuf::from(p)),
                None => return usage("--json needs a path"),
            },
            "--self-test" => match args.next() {
                Some(p) => self_test_dir = Some(PathBuf::from(p)),
                None => return usage("--self-test needs a directory"),
            },
            "--list" => {
                for r in vet::RULES {
                    println!("{:24} {}", r.name, r.summary);
                }
                return ExitCode::SUCCESS;
            }
            "--help" | "-h" => return usage(""),
            a if a.starts_with('-') => return usage(&format!("unknown flag `{a}`")),
            p => paths.push(PathBuf::from(p)),
        }
    }

    if let Some(dir) = self_test_dir {
        return match vet::self_test(&dir) {
            Ok(results) if !results.is_empty() => {
                let mut ok = true;
                for r in &results {
                    let mark = if r.ok { "ok  " } else { "FAIL" };
                    println!("{mark} {} ({}): {}", r.file, r.expected_rule, r.detail);
                    ok &= r.ok;
                }
                if ok {
                    println!("vet self-test: {} fixture(s) pass", results.len());
                    ExitCode::SUCCESS
                } else {
                    ExitCode::FAILURE
                }
            }
            Ok(_) => {
                eprintln!("vet self-test: no fixtures found in {}", dir.display());
                ExitCode::from(2)
            }
            Err(e) => {
                eprintln!("vet self-test: {e}");
                ExitCode::from(2)
            }
        };
    }

    if paths.is_empty() {
        paths.push(PathBuf::from("rust/src"));
    }
    match vet::analyze_paths(&paths) {
        Ok((files, findings)) => {
            print!("{}", vet::report_human(files, &findings));
            if let Some(p) = json_path {
                if let Err(e) = std::fs::write(&p, vet::report_json(files, &findings)) {
                    eprintln!("vet: writing {}: {e}", p.display());
                    return ExitCode::from(2);
                }
            }
            if findings.is_empty() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("vet: {e}");
            ExitCode::from(2)
        }
    }
}

fn usage(err: &str) -> ExitCode {
    if !err.is_empty() {
        eprintln!("vet: {err}");
    }
    eprintln!("usage: vet [--json PATH] [--list] [--self-test DIR] [PATHS...]");
    if err.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(2)
    }
}
