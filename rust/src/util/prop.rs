//! In-repo property-testing helper (the offline registry has no proptest).
//!
//! `check(name, cases, f)` runs `f` against `cases` seeded generators and
//! reports the failing seed, so a failure reproduces with
//! `Gen::new(seed)`. Shrinking is by seed replay rather than structural
//! shrinking — adequate for the partition/comm/schedule invariants tested
//! here.

use super::rng::Rng;

/// A seeded random-value source handed to property bodies.
pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::seed_from(seed), seed }
    }

    /// Integer in [lo, hi] inclusive.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    /// Even integer in [lo, hi].
    pub fn even(&mut self, lo: usize, hi: usize) -> usize {
        let v = self.int(lo / 2, hi / 2);
        (v * 2).max(lo + lo % 2)
    }

    pub fn f32s(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal()).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Run `f` for `cases` seeds; panic with the seed on the first failure.
pub fn check<F: FnMut(&mut Gen) -> Result<(), String>>(
    name: &str,
    cases: u64,
    mut f: F,
) {
    for seed in 0..cases {
        let mut g = Gen::new(seed * 0x9E3779B9 + 1);
        if let Err(msg) = f(&mut g) {
            panic!("property '{name}' failed at seed {}: {msg}", g.seed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("addition commutes", 50, |g| {
            let (a, b) = (g.int(0, 100) as i64, g.int(0, 100) as i64);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn check_reports_failures() {
        check("always fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn even_is_even() {
        check("even gen", 100, |g| {
            let e = g.even(2, 64);
            if e % 2 == 0 && (2..=64).contains(&e) {
                Ok(())
            } else {
                Err(format!("bad even {e}"))
            }
        });
    }
}
