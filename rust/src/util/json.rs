//! Minimal JSON parser/serializer.
//!
//! The offline crate registry ships no serde, so the artifact contract
//! (`config.json`, `manifest.json`) and the bench result files are handled
//! by this small, dependency-free implementation. It supports the full
//! JSON grammar minus exotic escapes (\u surrogate pairs are passed
//! through unescaped).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // Exact round-trip: every finite f64 serializes to a
                // decimal that parses back to the identical bits (Rust's
                // `{}` Display emits shortest-round-trip digits). The
                // three cases Display alone gets wrong for a JSON
                // consumer: -0.0 would hit the integer path and lose its
                // sign, and NaN/±inf would print invalid JSON tokens —
                // checkpoint manifests carry loss scales and LRs that
                // must reload bit-identically.
                if n.is_nan() {
                    out.push_str("NaN");
                } else if *n == f64::INFINITY {
                    out.push_str("Infinity");
                } else if *n == f64::NEG_INFINITY {
                    out.push_str("-Infinity");
                } else if *n == 0.0 && n.is_sign_negative() {
                    out.push_str("-0.0");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            // non-finite tokens (our own serializer's extension — plain
            // JSON has no spelling for them)
            Some(b'N') => self.lit("NaN", Json::Num(f64::NAN)),
            Some(b'I') => self.lit("Infinity", Json::Num(f64::INFINITY)),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
            if self.peek() == Some(b'I') {
                return self.lit("Infinity", Json::Num(f64::NEG_INFINITY));
            }
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {}", start))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad hex")?,
                                16,
                            )
                            .map_err(|_| "bad hex")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn f64_roundtrip_is_bit_exact() {
        // every finite f64 must survive serialize -> parse with identical
        // bits: loss scales, LRs, and bench wall-clocks ride this path
        let vals: [f64; 14] = [
            0.0,
            -0.0,
            0.1,
            1.0 / 3.0,
            1e-3f32 as f64,     // an f32-origin LR widened to f64
            16384.0,            // a power-of-two loss scale
            2.5e-323,           // subnormal
            f64::MIN_POSITIVE,
            f64::MAX,
            -f64::MAX,
            9e15,               // just past the integer fast path
            9007199254740993.0, // 2^53 + 1 (rounds to 2^53; still exact)
            1.5e300,
            -7.123456789012345e-9,
        ];
        for v in vals {
            let s = Json::Num(v).to_string();
            let back = Json::parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(
                back.to_bits(),
                v.to_bits(),
                "{v:?} -> {s:?} -> {back:?}"
            );
        }
    }

    #[test]
    fn negative_zero_keeps_its_sign() {
        let s = Json::Num(-0.0).to_string();
        assert_eq!(s, "-0.0");
        let back = Json::parse(&s).unwrap().as_f64().unwrap();
        assert!(back == 0.0 && back.is_sign_negative());
    }

    #[test]
    fn non_finite_tokens_roundtrip() {
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "Infinity");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "-Infinity");
        assert_eq!(Json::Num(f64::NAN).to_string(), "NaN");
        assert_eq!(
            Json::parse("Infinity").unwrap().as_f64(),
            Some(f64::INFINITY)
        );
        assert_eq!(
            Json::parse("-Infinity").unwrap().as_f64(),
            Some(f64::NEG_INFINITY)
        );
        assert!(Json::parse("NaN").unwrap().as_f64().unwrap().is_nan());
        // inside containers too
        let v = Json::parse(r#"{"a":[NaN,-Infinity]}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert!(a[0].as_f64().unwrap().is_nan());
        assert_eq!(a[1].as_f64(), Some(f64::NEG_INFINITY));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".into())
        );
    }
}
