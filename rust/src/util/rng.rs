//! Deterministic PRNG (xoshiro256**) used by the synthetic-atmosphere
//! generator, parameter init, and the in-repo property-testing helper.
//!
//! The paper (Section 5, data loading) requires *the same random seed for
//! all model-parallel instances* and different seeds across data-parallel
//! groups — deterministic seeding is a correctness feature here, not a
//! convenience.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the full state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Raw generator state — the checkpoint subsystem persists it so a
    /// resumed run continues the exact stream (same draws, same order)
    /// instead of restarting from the seed.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator mid-stream from a captured [`state`](Rng::state).
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-7);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    pub fn fill_normal(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn state_roundtrip_continues_the_stream() {
        let mut a = Rng::seed_from(9);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::seed_from(7);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(11);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
