//! Dependency-free utilities: JSON, deterministic RNG, property testing,
//! small table/CSV writers for the bench harness, the shared
//! poison-tolerant lock helper, and the runtime lock-order witness.

pub mod json;
pub mod lockdep;
pub mod prop;
pub mod rng;
pub mod table;

use std::ops::{Deref, DerefMut};
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Poison-tolerant lock: a thread that panics while holding one of our
/// locks must not turn every peer's diagnosis into an opaque
/// `PoisonError` — the protected state (message queues, engine request
/// channels, ...) is plain data that stays valid across an unwind. This
/// is the only sanctioned way to take a `Mutex` in this crate; the
/// `raw-lock` vet rule flags `.lock().unwrap()`/`.expect(..)` anywhere
/// else.
pub fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// A [`plock`] guard whose acquisition is registered with the runtime
/// lock-order witness ([`lockdep`]) under a stable class name. Derefs
/// like a `MutexGuard`; dropping releases the mutex first and then pops
/// the class from the thread's held stack, so a woken peer never
/// observes the class still "held" here.
pub struct PlockGuard<'a, T> {
    g: Option<MutexGuard<'a, T>>,
    class: Option<lockdep::ClassId>,
}

impl<'a, T> PlockGuard<'a, T> {
    /// Hand the inner `MutexGuard` to `f` — e.g. a `Condvar` wait that
    /// consumes and returns it — while the lockdep class stays held.
    /// The thread never observably runs without the lock across a wait
    /// (the condvar re-acquires before returning), so keeping the class
    /// on the stack is what keeps the held-before graph truthful.
    pub fn map<F>(mut self, f: F) -> Self
    where
        F: FnOnce(MutexGuard<'a, T>) -> MutexGuard<'a, T>,
    {
        self.g = self.g.take().map(f);
        self
    }
}

impl<T> Deref for PlockGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.g.as_ref().expect("plock guard taken")
    }
}

impl<T> DerefMut for PlockGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.g.as_mut().expect("plock guard taken")
    }
}

impl<T> Drop for PlockGuard<'_, T> {
    fn drop(&mut self) {
        // release the OS lock before popping the class: if `f` in `map`
        // panicked the guard is already gone and only the class remains
        self.g = None;
        if let Some(c) = self.class {
            lockdep::release(c);
        }
    }
}

/// [`plock`] with a stable lock-class name for the runtime lock-order
/// witness: the long-lived locks (comm fabric, runtime engine) acquire
/// through this so every debug/test run soaks under [`lockdep`]. When
/// the witness is off this is `plock` plus one relaxed atomic load.
pub fn plock_named<'a, T>(m: &'a Mutex<T>, name: &'static str) -> PlockGuard<'a, T> {
    let class = if lockdep::enabled() {
        Some(lockdep::acquire(name))
    } else {
        None
    };
    PlockGuard { g: Some(plock(m)), class }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plock_named_derefs_and_releases() {
        let m = Mutex::new(7u32);
        {
            let mut g = plock_named(&m, "ut.util.m");
            *g += 1;
        }
        assert_eq!(*plock(&m), 8);
    }

    #[test]
    fn plock_guard_map_keeps_the_lock() {
        let m = Mutex::new(1u32);
        let g = plock_named(&m, "ut.util.map");
        let g = g.map(|inner| inner);
        assert_eq!(*g, 1);
    }
}
