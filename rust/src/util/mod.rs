//! Dependency-free utilities: JSON, deterministic RNG, property testing,
//! and small table/CSV writers for the bench harness.

pub mod json;
pub mod prop;
pub mod rng;
pub mod table;
