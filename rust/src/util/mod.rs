//! Dependency-free utilities: JSON, deterministic RNG, property testing,
//! small table/CSV writers for the bench harness, and the shared
//! poison-tolerant lock helper.

pub mod json;
pub mod prop;
pub mod rng;
pub mod table;

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Poison-tolerant lock: a thread that panics while holding one of our
/// locks must not turn every peer's diagnosis into an opaque
/// `PoisonError` — the protected state (message queues, engine request
/// channels, ...) is plain data that stays valid across an unwind. This
/// is the only sanctioned way to take a `Mutex` in this crate; the
/// `raw-lock` vet rule flags `.lock().unwrap()`/`.expect(..)` anywhere
/// else.
pub fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}
