//! Runtime lock-order witness ("lockdep"), the dynamic half of the
//! `lock-order` gate (the static half is `vet::callgraph`).
//!
//! Every [`crate::util::plock_named`] site registers its `Mutex` under a
//! stable *class* name (`"comm.queues"`, `"runtime.tx"`, ...). A
//! thread-local stack records the classes the current thread holds, and
//! a global held-before graph accumulates one edge per observed
//! `(held, acquired)` class pair — each edge remembering the acquisition
//! chain that first produced it. The first acquisition that would close
//! a cycle panics *immediately*, naming both lock classes and both
//! chains (the acquisition being attempted and the recorded one it
//! contradicts), instead of deadlocking two ranks at whatever later
//! interleaving actually exhibits the inversion.
//!
//! The check runs *before* blocking on the mutex, so a true inversion is
//! diagnosed even on the schedule where it would have hung. Classes are
//! per-name, not per-instance: two fabrics share the `"comm.queues"`
//! class, which is deliberately conservative — an order that is only
//! safe because the instances differ still deserves a hierarchy
//! conversation.
//!
//! Enablement mirrors the comm wait-graph detector: on by default in
//! debug builds (so `cargo test` soaks the whole suite), off in release;
//! `JIGSAW_LOCKDEP=1/0` overrides either way, and tests pin the process
//! default via [`set_lockdep_default`]. When off, the cost is one
//! relaxed atomic load per `plock_named`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{OnceLock, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Interned id of a lock class (a stable site name like `"comm.queues"`).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct ClassId(u16);

#[derive(Default)]
struct Graph {
    /// class id -> name (the id is the index)
    names: Vec<&'static str>,
    ids: HashMap<&'static str, u16>,
    /// (held, acquired) -> the acquisition chain that first observed it
    edges: HashMap<(u16, u16), String>,
}

impl Graph {
    /// Depth-first search for a held-before path `from ⇒* to`.
    fn path(&self, from: u16, to: u16) -> Option<Vec<u16>> {
        let mut stack = vec![vec![from]];
        let mut seen = vec![false; self.names.len()];
        while let Some(p) = stack.pop() {
            let last = *p.last().unwrap_or(&from);
            if last == to {
                return Some(p);
            }
            if seen[last as usize] {
                continue;
            }
            seen[last as usize] = true;
            for &(a, b) in self.edges.keys() {
                if a == last && !seen[b as usize] {
                    let mut next = p.clone();
                    next.push(b);
                    stack.push(next);
                }
            }
        }
        None
    }

    fn name(&self, id: u16) -> &'static str {
        self.names[id as usize]
    }
}

static GRAPH: OnceLock<RwLock<Graph>> = OnceLock::new();

fn read_graph() -> RwLockReadGuard<'static, Graph> {
    GRAPH
        .get_or_init(|| RwLock::new(Graph::default()))
        .read()
        .unwrap_or_else(PoisonError::into_inner)
}

fn write_graph() -> RwLockWriteGuard<'static, Graph> {
    GRAPH
        .get_or_init(|| RwLock::new(Graph::default()))
        .write()
        .unwrap_or_else(PoisonError::into_inner)
}

thread_local! {
    /// Classes this thread currently holds, oldest first. A recursive
    /// same-class acquisition panics before the push, so duplicates
    /// never land.
    static HELD: RefCell<Vec<u16>> = const { RefCell::new(Vec::new()) };
}

/// Process-wide override for the witness default: 0 = none (env / build
/// profile decides), 1 = force off, 2 = force on. Same shape as the
/// deadlock detector's `DETECT_OVERRIDE`.
static LOCKDEP_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Pin (or release, with `None`) the process default for the lockdep
/// witness — the test-suite analogue of
/// `comm::set_deadlock_detect_default`. Takes effect on the next
/// `plock_named`; classes a thread already holds stay held.
pub fn set_lockdep_default(v: Option<bool>) {
    LOCKDEP_OVERRIDE.store(
        match v {
            None => 0,
            Some(false) => 1,
            Some(true) => 2,
        },
        Ordering::SeqCst,
    );
}

/// Whether the witness is active: process override, else
/// `JIGSAW_LOCKDEP` (`0`/`off`/`false` disable, anything else enables),
/// else on in debug builds (= `cargo test`) and off in release.
pub fn enabled() -> bool {
    match LOCKDEP_OVERRIDE.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => match std::env::var("JIGSAW_LOCKDEP") {
            Ok(v) => !matches!(v.as_str(), "0" | "off" | "false" | ""),
            Err(_) => cfg!(debug_assertions),
        },
    }
}

fn intern(name: &'static str) -> u16 {
    {
        let g = read_graph();
        if let Some(&id) = g.ids.get(name) {
            return id;
        }
    }
    let mut g = write_graph();
    if let Some(&id) = g.ids.get(name) {
        return id;
    }
    assert!(g.names.len() < usize::from(u16::MAX), "lockdep: class table full");
    let id = g.names.len() as u16;
    g.names.push(name);
    g.ids.insert(name, id);
    id
}

fn chain_text(g: &Graph, held: &[u16], new: u16) -> String {
    let held_names: Vec<String> =
        held.iter().map(|&h| format!("`{}`", g.name(h))).collect();
    format!(
        "acquiring `{}` while holding [{}] (thread '{}')",
        g.name(new),
        held_names.join(" -> "),
        std::thread::current().name().unwrap_or("?"),
    )
}

/// Register an acquisition of class `name` by this thread, checking the
/// global held-before graph first. Called by `plock_named` *before*
/// blocking on the mutex, so an ordering cycle panics instead of ever
/// deadlocking. Returns the class id to hand back to [`release`].
///
/// Panics on (a) a recursive same-class acquisition, or (b) an edge that
/// closes a cycle in the held-before graph — naming both classes, this
/// thread's acquisition chain, and the previously recorded chain it
/// contradicts.
pub fn acquire(name: &'static str) -> ClassId {
    let new = intern(name);
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if held.contains(&new) {
            let msg = {
                let g = read_graph();
                format!(
                    "lockdep: recursive acquisition of lock class `{name}`: {}",
                    chain_text(&g, &held, new)
                )
            };
            panic!("{msg}");
        }
        check_and_record(&held, new, name);
        held.push(new);
    });
    ClassId(new)
}

/// Record held-before edges for acquiring `new` with `held` on the
/// stack; panic if any edge closes a cycle.
fn check_and_record(held: &[u16], new: u16, name: &'static str) {
    if held.is_empty() {
        return;
    }
    {
        // fast path: every (held, new) pair already observed and vetted
        let g = read_graph();
        if held.iter().all(|&h| g.edges.contains_key(&(h, new))) {
            return;
        }
    }
    let mut g = write_graph();
    for &h in held {
        if g.edges.contains_key(&(h, new)) {
            continue;
        }
        if let Some(path) = g.path(new, h) {
            // inserting h -> new would close `new ⇒* h -> new`
            let current = chain_text(&g, held, new);
            let prior: Vec<String> = path
                .windows(2)
                .map(|w| {
                    let witness = g
                        .edges
                        .get(&(w[0], w[1]))
                        .map(String::as_str)
                        .unwrap_or("?");
                    format!(
                        "  `{}` held before `{}`: first seen {}",
                        g.name(w[0]),
                        g.name(w[1]),
                        witness
                    )
                })
                .collect();
            panic!(
                "lockdep: lock-order cycle between `{}` and `{}`: {current}, \
                 but the held-before graph already orders them the other \
                 way:\n{}",
                g.name(h),
                name,
                prior.join("\n"),
            );
        }
        let witness = chain_text(&g, held, new);
        g.edges.insert((h, new), witness);
    }
}

/// Pop `class` from this thread's held stack (last occurrence). Safe
/// during unwinds and thread teardown (`try_with`); tolerant of a class
/// that is not on the stack (enablement flipped while held).
pub fn release(class: ClassId) {
    let _ = HELD.try_with(|h| {
        let mut held = h.borrow_mut();
        if let Some(pos) = held.iter().rposition(|&c| c == class.0) {
            held.remove(pos);
        }
    });
}

/// Named snapshot of the held-before edges observed so far (tests use
/// this to assert the witness actually watched a run).
pub fn observed_edges() -> Vec<(String, String)> {
    let g = read_graph();
    let mut v: Vec<(String, String)> = g
        .edges
        .keys()
        .map(|&(a, b)| (g.name(a).to_string(), g.name(b).to_string()))
        .collect();
    v.sort();
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn panic_text(e: &(dyn std::any::Any + Send)) -> String {
        if let Some(s) = e.downcast_ref::<String>() {
            s.clone()
        } else if let Some(s) = e.downcast_ref::<&str>() {
            (*s).to_string()
        } else {
            String::new()
        }
    }

    #[test]
    fn forward_order_records_edges_and_replays_silently() {
        for _ in 0..2 {
            let a = acquire("ut.fwd.outer");
            let b = acquire("ut.fwd.inner");
            release(b);
            release(a);
        }
        assert!(observed_edges()
            .contains(&("ut.fwd.outer".to_string(), "ut.fwd.inner".to_string())));
    }

    #[test]
    fn cycle_panics_naming_both_classes_and_chains() {
        let a = acquire("ut.cycle.alpha");
        let b = acquire("ut.cycle.beta");
        release(b);
        release(a);
        let b2 = acquire("ut.cycle.beta");
        let err = std::panic::catch_unwind(|| acquire("ut.cycle.alpha"))
            .expect_err("inverted order must panic");
        release(b2);
        let msg = panic_text(&*err);
        assert!(msg.contains("ut.cycle.alpha"), "missing class: {msg}");
        assert!(msg.contains("ut.cycle.beta"), "missing class: {msg}");
        assert!(msg.contains("while holding"), "missing current chain: {msg}");
        assert!(msg.contains("first seen"), "missing prior chain: {msg}");
    }

    #[test]
    fn recursive_acquisition_panics() {
        let a = acquire("ut.rec.same");
        let err = std::panic::catch_unwind(|| acquire("ut.rec.same"))
            .expect_err("recursive acquisition must panic");
        release(a);
        assert!(panic_text(&*err).contains("recursive acquisition"));
    }

    #[test]
    fn release_tolerates_unheld_class() {
        let a = acquire("ut.rel.only");
        release(a);
        release(a); // second pop is a no-op, not a panic
    }
}
