//! Aligned-table and CSV writers used by the bench harness to print the
//! paper's tables/figure series and persist them under `bench_results/`.

use std::fs;
use std::path::Path;

/// A simple column-aligned text table.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>w$}", c, w = width[i]));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
            out.push('\n');
        }
        out
    }

    /// Write CSV alongside printing; creates parent dirs.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = Path::new(path).parent() {
            fs::create_dir_all(dir)?;
        }
        let mut s = String::new();
        s.push_str(&self.header.join(","));
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.join(","));
            s.push('\n');
        }
        fs::write(path, s)
    }
}

/// Format a float with engineering-style precision for tables.
pub fn fmt(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 100.0 {
        format!("{:.0}", v)
    } else if v.abs() >= 1.0 {
        format!("{:.2}", v)
    } else {
        format!("{:.4}", v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "val"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("name"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only one".into()]);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(0.0), "0");
        assert_eq!(fmt(123.4), "123");
        assert_eq!(fmt(1.5), "1.50");
        assert_eq!(fmt(0.125), "0.1250");
    }
}
