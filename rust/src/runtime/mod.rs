//! Runtime: executes AOT-compiled HLO programs on the PJRT CPU client and
//! provides a native fallback backend.
//!
//! Python never runs here — artifacts were lowered once at build time
//! (`make artifacts`) and this module loads the HLO *text*, compiles it via
//! the `xla` crate (`PjRtClient::cpu` -> `HloModuleProto::from_text_file`
//! -> `compile` -> `execute`), and exchanges f32 host buffers with the
//! rest of the coordinator.
//!
//! The PJRT client is not thread-safe, so a dedicated engine thread owns
//! the client and the executable cache; rank threads talk to it through a
//! channel (a deliberate match for the single-core testbed — on a real
//! deployment each rank process owns its own device client).

pub mod engine;
pub mod native;

use anyhow::Result;

use crate::tensor::Tensor;

/// The three matmul primitive forms (paper Section 5: each permutation of
/// XW / XW^T / X^T W has its own communication pattern; the runtime keys
/// primitives the same way).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MatmulOp {
    /// y = x @ w.T   x:[M,K], w:[N,K]
    NT,
    /// y = x @ w     x:[M,K], w:[K,N]
    NN,
    /// y = x.T @ w   x:[K,M], w:[K,N]
    TN,
}

impl MatmulOp {
    pub fn tag(&self) -> &'static str {
        match self {
            MatmulOp::NT => "nt",
            MatmulOp::NN => "nn",
            MatmulOp::TN => "tn",
        }
    }

    /// The primitive key for operand shapes — must match
    /// python/compile/aot.py `mm_key_str`.
    pub fn key(&self, x: &Tensor, w: &Tensor) -> String {
        let (xr, xc) = x.dims2();
        let (wr, wc) = w.dims2();
        format!("{}_{}x{}_{}x{}", self.tag(), xr, xc, wr, wc)
    }

    /// Output shape [M, N].
    pub fn out_dims(&self, x: &Tensor, w: &Tensor) -> (usize, usize) {
        let (xr, xc) = x.dims2();
        let (wr, wc) = w.dims2();
        match self {
            MatmulOp::NT => {
                assert_eq!(xc, wc, "nt contraction");
                (xr, wr)
            }
            MatmulOp::NN => {
                assert_eq!(xc, wr, "nn contraction");
                (xr, wc)
            }
            MatmulOp::TN => {
                assert_eq!(xr, wr, "tn contraction");
                (xc, wc)
            }
        }
    }

    /// FLOPs of this matmul (2*M*K*N).
    pub fn flops(&self, x: &Tensor, w: &Tensor) -> u64 {
        let (xr, xc) = x.dims2();
        let (_, wc) = w.dims2();
        let (m, k, n) = match self {
            MatmulOp::NT => (xr, xc, w.dims2().0),
            MatmulOp::NN => (xr, xc, wc),
            MatmulOp::TN => (xc, xr, wc),
        };
        2 * (m as u64) * (k as u64) * (n as u64)
    }
}

/// Identity + version of a cacheable operand (a parameter block): the
/// runtime may keep its device buffer resident across calls and skip the
/// host->device upload until the version changes (i.e. until the
/// optimizer updates the shard). See EXPERIMENTS.md §Perf.
pub type CacheKey = (u64, u64);

/// Device compute abstraction: the jigsaw engine issues all heavy math
/// through this trait. `PjrtBackend` is the deployment path; `Native` is
/// the dependency-free fallback (tests, CI without artifacts).
pub trait Backend: Send + Sync {
    fn matmul(&self, op: MatmulOp, x: &Tensor, w: &Tensor) -> Result<Tensor>;

    /// Like `matmul`, with optional device-buffer caching of either
    /// operand (used for stationary weight blocks). Default: ignore keys.
    fn matmul_cached(
        &self,
        op: MatmulOp,
        x: &Tensor,
        xkey: Option<CacheKey>,
        w: &Tensor,
        wkey: Option<CacheKey>,
    ) -> Result<Tensor> {
        let _ = (xkey, wkey);
        self.matmul(op, x, w)
    }

    /// Out-parameter matmul: write (or, with `accumulate`, add) the
    /// product into a caller-owned tensor of the correct output shape.
    /// The jigsaw engine reduces partial sums through this entry so the
    /// native backend runs allocation-free; device backends fall back to
    /// `matmul_cached` plus a host-side combine (the old buffer is
    /// recycled into the pool).
    fn matmul_into(
        &self,
        op: MatmulOp,
        x: &Tensor,
        xkey: Option<CacheKey>,
        w: &Tensor,
        wkey: Option<CacheKey>,
        out: &mut Tensor,
        accumulate: bool,
    ) -> Result<()> {
        let p = self.matmul_cached(op, x, xkey, w, wkey)?;
        debug_assert_eq!(p.shape, out.shape, "matmul_into shape mismatch");
        if accumulate {
            crate::tensor::ops::add_assign(out, &p);
            p.recycle();
        } else {
            let old = std::mem::replace(out, p);
            old.recycle();
        }
        Ok(())
    }

    /// True when `matmul_into` computes directly into the output buffer
    /// (no intermediate tensor) — lets callers pick the cheaper schedule.
    fn supports_into(&self) -> bool {
        false
    }

    /// A short description for logs.
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_match_python_format() {
        let x = Tensor::zeros(&[32, 54]);
        let w = Tensor::zeros(&[48, 54]);
        assert_eq!(MatmulOp::NT.key(&x, &w), "nt_32x54_48x54");
    }

    #[test]
    fn out_dims() {
        let x = Tensor::zeros(&[3, 5]);
        assert_eq!(MatmulOp::NT.out_dims(&x, &Tensor::zeros(&[7, 5])), (3, 7));
        assert_eq!(MatmulOp::NN.out_dims(&x, &Tensor::zeros(&[5, 7])), (3, 7));
        let xt = Tensor::zeros(&[5, 3]);
        assert_eq!(MatmulOp::TN.out_dims(&xt, &Tensor::zeros(&[5, 7])), (3, 7));
    }

    #[test]
    fn flops_counts() {
        let x = Tensor::zeros(&[2, 3]);
        let w = Tensor::zeros(&[4, 3]);
        assert_eq!(MatmulOp::NT.flops(&x, &w), 2 * 2 * 3 * 4);
    }
}
