//! Native (pure-rust) backend: the fallback compute path and the reference
//! the PJRT path is differentially tested against.
//!
//! Since the kernel-layer rework this dispatches to the blocked, register-
//! tiled `_into` kernels in `tensor::ops` (thread-parallel under
//! `JIGSAW_KERNEL_THREADS`); output buffers come from the per-thread pool,
//! so a steady-state train step performs no matmul-sized allocations. The
//! seed's naive kernels survive as `tensor::ref_kernels`, the oracle the
//! property tests hold this backend to.

use anyhow::Result;

use super::{Backend, CacheKey, MatmulOp};
use crate::tensor::{ops, Tensor};

/// One blocked native matmul with a pooled output buffer. Shared by this
/// backend and the engine's no-artifact fallback path.
pub fn native_matmul(op: MatmulOp, x: &Tensor, w: &Tensor) -> Tensor {
    match op {
        MatmulOp::NT => ops::matmul_nt(x, w),
        MatmulOp::NN => ops::matmul_nn(x, w),
        MatmulOp::TN => ops::matmul_tn(x, w),
    }
}

/// Blocked native matmul into an existing buffer (optionally accumulating).
pub fn native_matmul_into(op: MatmulOp, x: &Tensor, w: &Tensor, out: &mut Tensor, acc: bool) {
    let ov = out.view2_mut();
    match op {
        MatmulOp::NT => ops::matmul_nt_into(ov, x.view2(), w.view2(), acc),
        MatmulOp::NN => ops::matmul_nn_into(ov, x.view2(), w.view2(), acc),
        MatmulOp::TN => ops::matmul_tn_into(ov, x.view2(), w.view2(), acc),
    }
}

#[derive(Default)]
pub struct NativeBackend;

impl Backend for NativeBackend {
    fn matmul(&self, op: MatmulOp, x: &Tensor, w: &Tensor) -> Result<Tensor> {
        Ok(native_matmul(op, x, w))
    }

    fn matmul_into(
        &self,
        op: MatmulOp,
        x: &Tensor,
        _xkey: Option<CacheKey>,
        w: &Tensor,
        _wkey: Option<CacheKey>,
        out: &mut Tensor,
        accumulate: bool,
    ) -> Result<()> {
        native_matmul_into(op, x, w, out, accumulate);
        Ok(())
    }

    fn supports_into(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_matmul_dispatch() {
        let b = NativeBackend;
        let x = Tensor::new(vec![1, 2], vec![1.0, 2.0]);
        let w = Tensor::new(vec![1, 2], vec![3.0, 4.0]);
        let y = b.matmul(MatmulOp::NT, &x, &w).unwrap();
        assert_eq!(y.data, vec![11.0]);
    }

    #[test]
    fn native_matmul_into_accumulates() {
        let b = NativeBackend;
        let x = Tensor::new(vec![1, 2], vec![1.0, 2.0]);
        let w = Tensor::new(vec![1, 2], vec![3.0, 4.0]);
        let mut out = Tensor::new(vec![1, 1], vec![100.0]);
        b.matmul_into(MatmulOp::NT, &x, None, &w, None, &mut out, true)
            .unwrap();
        assert_eq!(out.data, vec![111.0]);
        b.matmul_into(MatmulOp::NT, &x, None, &w, None, &mut out, false)
            .unwrap();
        assert_eq!(out.data, vec![11.0]);
        assert!(b.supports_into());
    }
}
