//! Native (pure-rust) backend: the fallback compute path and the reference
//! the PJRT path is differentially tested against.

use anyhow::Result;

use super::{Backend, MatmulOp};
use crate::tensor::{ops, Tensor};

#[derive(Default)]
pub struct NativeBackend;

impl Backend for NativeBackend {
    fn matmul(&self, op: MatmulOp, x: &Tensor, w: &Tensor) -> Result<Tensor> {
        Ok(match op {
            MatmulOp::NT => ops::matmul_nt(x, w),
            MatmulOp::NN => ops::matmul_nn(x, w),
            MatmulOp::TN => ops::matmul_tn(x, w),
        })
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_matmul_dispatch() {
        let b = NativeBackend;
        let x = Tensor::new(vec![1, 2], vec![1.0, 2.0]);
        let w = Tensor::new(vec![1, 2], vec![3.0, 4.0]);
        let y = b.matmul(MatmulOp::NT, &x, &w).unwrap();
        assert_eq!(y.data, vec![11.0]);
    }
}
