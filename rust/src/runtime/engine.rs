//! PJRT engine: a dedicated thread owning the `xla` PJRT CPU client and an
//! executable cache, serving matmul-primitive and monolithic-program
//! executions over a channel.
//!
//! Lookup order for a matmul: primitive HLO from the manifest (compile
//! once, cache forever) -> native fallback (counted; `JIGSAW_STRICT_PJRT=1`
//! turns a fallback into an error, used by the plan-coverage tests).
//!
//! §Perf: inputs go host->device via `buffer_from_host_buffer` (no literal
//! intermediate), and parameter blocks carry a (id, version) `CacheKey`
//! whose device buffer stays resident until the optimizer bumps the
//! version — weight bytes cross the host/device boundary once per
//! optimizer step instead of once per matmul (EXPERIMENTS.md §Perf).
//!
//! The whole PJRT path sits behind the `pjrt` cargo feature (the `xla`
//! crate needs a native XLA toolchain the offline build lacks). Without
//! the feature an API-identical in-process engine serves every matmul
//! from the blocked native kernel layer — counted as native fallbacks so
//! coverage stats stay honest — and `run_program` reports that monolithic
//! artifacts require the feature.

use std::sync::atomic::AtomicU64;
#[cfg(feature = "pjrt")]
use std::sync::atomic::Ordering;
use std::sync::Arc;
#[cfg(feature = "pjrt")]
use std::sync::{mpsc, Mutex};

#[cfg(feature = "pjrt")]
use std::collections::HashMap;

use anyhow::Result;
#[cfg(feature = "pjrt")]
use anyhow::{anyhow, Context};

use super::{Backend, CacheKey, MatmulOp};
use crate::config::Manifest;
use crate::tensor::Tensor;

/// Execution counters (observable from benches and tests).
#[derive(Default)]
pub struct EngineStats {
    pub pjrt_matmuls: AtomicU64,
    pub native_fallbacks: AtomicU64,
    pub programs_run: AtomicU64,
    pub compiles: AtomicU64,
    pub flops: AtomicU64,
    /// weight-buffer cache hits / uploads (the §Perf counter)
    pub buf_cache_hits: AtomicU64,
    pub buf_cache_uploads: AtomicU64,
}

fn strict_pjrt() -> bool {
    std::env::var("JIGSAW_STRICT_PJRT").map(|v| v == "1").unwrap_or(false)
}

// ---------------------------------------------------------------------------
// PJRT implementation (feature = "pjrt")
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
enum Req {
    Matmul {
        op: MatmulOp,
        x: Tensor,
        xkey: Option<CacheKey>,
        w: Tensor,
        wkey: Option<CacheKey>,
        resp: mpsc::Sender<Result<Tensor>>,
    },
    Program {
        tag: String,
        inputs: Vec<Tensor>,
        resp: mpsc::Sender<Result<Vec<Tensor>>>,
    },
    Shutdown,
}

/// Cloneable handle to the engine thread.
#[cfg(feature = "pjrt")]
pub struct Engine {
    tx: Mutex<mpsc::Sender<Req>>,
    stats: Arc<EngineStats>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Spawn the engine thread for one artifact preset.
    pub fn start(manifest: Manifest) -> Result<Arc<Engine>> {
        let (tx, rx) = mpsc::channel::<Req>();
        let stats = Arc::new(EngineStats::default());
        let stats2 = stats.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || run_engine(manifest, rx, ready_tx, stats2))
            .context("spawning engine thread")?;
        ready_rx
            .recv()
            .context("engine thread died during startup")??;
        Ok(Arc::new(Engine { tx: Mutex::new(tx), stats }))
    }

    fn send(&self, req: Req) {
        crate::util::plock_named(&self.tx, "runtime.tx")
            .send(req)
            .expect("engine thread gone");
    }

    /// Execute one matmul primitive (PJRT if the artifact exists).
    pub fn matmul(&self, op: MatmulOp, x: &Tensor, w: &Tensor) -> Result<Tensor> {
        self.matmul_cached(op, x, None, w, None)
    }

    /// Matmul with optional resident device buffers for either operand.
    pub fn matmul_cached(
        &self,
        op: MatmulOp,
        x: &Tensor,
        xkey: Option<CacheKey>,
        w: &Tensor,
        wkey: Option<CacheKey>,
    ) -> Result<Tensor> {
        let (resp, rx) = mpsc::channel();
        self.send(Req::Matmul {
            op,
            x: x.clone(),
            xkey,
            w: w.clone(),
            wkey,
            resp,
        });
        rx.recv().map_err(|_| anyhow!("engine dropped response"))?
    }

    /// Execute a monolithic program by manifest tag (`forward`,
    /// `loss_and_grad`, `train_step`, ...).
    pub fn run_program(&self, tag: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (resp, rx) = mpsc::channel();
        self.send(Req::Program { tag: tag.to_string(), inputs, resp });
        rx.recv().map_err(|_| anyhow!("engine dropped response"))?
    }

    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    pub fn shutdown(&self) {
        self.send(Req::Shutdown);
    }
}

#[cfg(feature = "pjrt")]
fn run_engine(
    manifest: Manifest,
    rx: mpsc::Receiver<Req>,
    ready: mpsc::Sender<Result<()>>,
    stats: Arc<EngineStats>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow!("PjRtClient::cpu failed: {e:?}")));
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    // resident weight buffers: id -> (version, device buffer)
    let mut buf_cache: HashMap<u64, (u64, xla::PjRtBuffer)> = HashMap::new();

    let compile = |cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
                   stats: &EngineStats,
                   key: &str,
                   path: &std::path::Path|
     -> Result<()> {
        if cache.contains_key(key) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        stats.compiles.fetch_add(1, Ordering::Relaxed);
        cache.insert(key.to_string(), exe);
        Ok(())
    };

    while let Ok(req) = rx.recv() {
        match req {
            Req::Shutdown => break,
            Req::Matmul { op, x, xkey, w, wkey, resp } => {
                let key = op.key(&x, &w);
                let result = (|| -> Result<Tensor> {
                    match manifest.primitive_path(&key) {
                        Some(path) => {
                            compile(&mut cache, &stats, &key, &path)?;
                            stats.pjrt_matmuls.fetch_add(1, Ordering::Relaxed);
                            stats.flops.fetch_add(op.flops(&x, &w), Ordering::Relaxed);
                            let exe = &cache[&key];
                            // resolve operands to device buffers (cached
                            // weights stay resident across calls)
                            let xb = operand_buffer(
                                &client, &mut buf_cache, &stats, &x, xkey,
                            )?;
                            let wb = operand_buffer(
                                &client, &mut buf_cache, &stats, &w, wkey,
                            )?;
                            let args: Vec<&xla::PjRtBuffer> = vec![
                                resolve(&buf_cache, &xb),
                                resolve(&buf_cache, &wb),
                            ];
                            let out = execute_buffers(exe, &args)?;
                            let (m, n) = op.out_dims(&x, &w);
                            out.into_iter()
                                .next()
                                .map(|t| t.reshape(&[m, n]))
                                .ok_or_else(|| anyhow!("primitive returned no output"))
                        }
                        None if strict_pjrt() => {
                            Err(anyhow!("primitive '{key}' missing from manifest (strict mode)"))
                        }
                        None => {
                            stats.native_fallbacks.fetch_add(1, Ordering::Relaxed);
                            Ok(super::native::native_matmul(op, &x, &w))
                        }
                    }
                })();
                let _ = resp.send(result);
            }
            Req::Program { tag, inputs, resp } => {
                let result = (|| -> Result<Vec<Tensor>> {
                    let path = manifest
                        .program_path(&tag)
                        .ok_or_else(|| anyhow!("program '{tag}' not in manifest"))?;
                    compile(&mut cache, &stats, &tag, &path)?;
                    stats.programs_run.fetch_add(1, Ordering::Relaxed);
                    let bufs = inputs
                        .iter()
                        .map(|t| upload(&client, t))
                        .collect::<Result<Vec<_>>>()?;
                    let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
                    execute_buffers(&cache[&tag], &refs)
                })();
                let _ = resp.send(result);
            }
        }
    }
}

/// Either a transient buffer or a reference into the resident cache.
#[cfg(feature = "pjrt")]
enum Operand {
    Transient(xla::PjRtBuffer),
    Cached(u64),
}

#[cfg(feature = "pjrt")]
fn resolve<'a>(
    buf_cache: &'a HashMap<u64, (u64, xla::PjRtBuffer)>,
    op: &'a Operand,
) -> &'a xla::PjRtBuffer {
    match op {
        Operand::Transient(b) => b,
        Operand::Cached(id) => &buf_cache[id].1,
    }
}

#[cfg(feature = "pjrt")]
fn upload(client: &xla::PjRtClient, t: &Tensor) -> Result<xla::PjRtBuffer> {
    let dims: Vec<usize> = if t.shape.is_empty() { vec![] } else { t.shape.clone() };
    client
        .buffer_from_host_buffer::<f32>(&t.data, &dims, None)
        .map_err(|e| anyhow!("buffer_from_host: {e:?}"))
}

#[cfg(feature = "pjrt")]
fn operand_buffer(
    client: &xla::PjRtClient,
    buf_cache: &mut HashMap<u64, (u64, xla::PjRtBuffer)>,
    stats: &EngineStats,
    t: &Tensor,
    key: Option<CacheKey>,
) -> Result<Operand> {
    match key {
        None => Ok(Operand::Transient(upload(client, t)?)),
        Some((id, version)) => {
            let fresh = matches!(buf_cache.get(&id), Some((v, _)) if *v == version);
            if fresh {
                stats.buf_cache_hits.fetch_add(1, Ordering::Relaxed);
            } else {
                let buf = upload(client, t)?;
                buf_cache.insert(id, (version, buf));
                stats.buf_cache_uploads.fetch_add(1, Ordering::Relaxed);
            }
            Ok(Operand::Cached(id))
        }
    }
}

#[cfg(feature = "pjrt")]
fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.shape().map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let dims: Vec<usize> = match shape {
        xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
        other => return Err(anyhow!("non-array literal output: {other:?}")),
    };
    let data = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
    Ok(Tensor::new(dims, data))
}

#[cfg(feature = "pjrt")]
fn execute_buffers(
    exe: &xla::PjRtLoadedExecutable,
    args: &[&xla::PjRtBuffer],
) -> Result<Vec<Tensor>> {
    let result = exe
        .execute_b::<&xla::PjRtBuffer>(args)
        .map_err(|e| anyhow!("execute_b: {e:?}"))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal: {e:?}"))?;
    // programs are lowered with return_tuple=True
    let parts = lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
    parts.iter().map(literal_to_tensor).collect()
}

// ---------------------------------------------------------------------------
// Featureless fallback (no `pjrt`): same API, blocked native kernels
// ---------------------------------------------------------------------------

/// In-process engine handle: every matmul runs on the blocked native
/// kernel layer (counted as a native fallback); programs need `pjrt`.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    manifest: Manifest,
    stats: Arc<EngineStats>,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    pub fn start(manifest: Manifest) -> Result<Arc<Engine>> {
        Ok(Arc::new(Engine { manifest, stats: Arc::new(EngineStats::default()) }))
    }

    pub fn matmul(&self, op: MatmulOp, x: &Tensor, w: &Tensor) -> Result<Tensor> {
        self.matmul_cached(op, x, None, w, None)
    }

    pub fn matmul_cached(
        &self,
        op: MatmulOp,
        x: &Tensor,
        xkey: Option<CacheKey>,
        w: &Tensor,
        wkey: Option<CacheKey>,
    ) -> Result<Tensor> {
        use std::sync::atomic::Ordering;
        let _ = (xkey, wkey);
        if strict_pjrt() {
            // Strict mode checks *plan coverage* — every primitive the
            // schedule asks for must exist in the manifest. Without the
            // 'pjrt' feature, a covered primitive still executes on the
            // blocked native kernels (counted as a fallback); only a key
            // the AOT export never produced is an error.
            let key = op.key(x, w);
            if self.manifest.primitive_path(&key).is_none() {
                return Err(anyhow::anyhow!(
                    "primitive '{key}' missing from manifest (strict mode)"
                ));
            }
        }
        self.stats.native_fallbacks.fetch_add(1, Ordering::Relaxed);
        self.stats.flops.fetch_add(op.flops(x, w), Ordering::Relaxed);
        Ok(super::native::native_matmul(op, x, w))
    }

    pub fn run_program(&self, tag: &str, _inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        Err(anyhow::anyhow!(
            "program '{tag}' ({}): monolithic HLO execution requires the \
             'pjrt' cargo feature",
            self.manifest.preset
        ))
    }

    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    pub fn shutdown(&self) {}
}

/// `Backend` impl backed by the engine (shared across rank threads).
pub struct PjrtBackend {
    pub engine: Arc<Engine>,
}

impl Backend for PjrtBackend {
    fn matmul(&self, op: MatmulOp, x: &Tensor, w: &Tensor) -> Result<Tensor> {
        self.engine.matmul(op, x, w)
    }

    fn matmul_cached(
        &self,
        op: MatmulOp,
        x: &Tensor,
        xkey: Option<CacheKey>,
        w: &Tensor,
        wkey: Option<CacheKey>,
    ) -> Result<Tensor> {
        self.engine.matmul_cached(op, x, xkey, w, wkey)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;

    fn empty_manifest() -> Manifest {
        Manifest {
            preset: "test".into(),
            dir: std::path::PathBuf::from("artifacts/test"),
            param_order: vec![],
            param_shapes: vec![],
            programs: vec![],
            primitives: vec![],
            adam_b1: 0.9,
            adam_b2: 0.999,
            adam_eps: 1e-8,
            grad_clip: 1.0,
        }
    }

    #[test]
    fn fallback_engine_serves_matmuls() {
        let e = Engine::start(empty_manifest()).unwrap();
        let x = Tensor::new(vec![1, 2], vec![1.0, 2.0]);
        let w = Tensor::new(vec![1, 2], vec![3.0, 4.0]);
        let y = e.matmul(MatmulOp::NT, &x, &w).unwrap();
        assert_eq!(y.data, vec![11.0]);
        assert_eq!(
            e.stats()
                .native_fallbacks
                .load(std::sync::atomic::Ordering::Relaxed),
            1
        );
    }

    #[test]
    fn fallback_engine_rejects_programs() {
        let e = Engine::start(empty_manifest()).unwrap();
        assert!(e.run_program("forward", vec![]).is_err());
    }
}
