//! Synthetic-atmosphere data substrate (the ERA5 stand-in) and the
//! jigsaw-partitioned loader.
//!
//! The paper trains on ERA5 0.25-degree reanalysis (69 channels) from
//! WeatherBench2 — not available here, so we build the closest synthetic
//! equivalent that exercises the same code paths (DESIGN.md §3):
//!
//!   * **SpectralAtmosphere** — a deterministic dynamical system: each
//!     channel is a sum of rotating spherical-ish Fourier modes with
//!     per-mode angular frequencies and cross-channel coupling. The map
//!     state(t) -> state(t + 6h) is smooth and learnable; more model
//!     capacity captures more modes, reproducing the scaling-law *shape*
//!     (paper Fig. 3).
//!   * **ShardedLoader** — each jigsaw rank reads only its domain
//!     partition (latitude x channel shard, plus an optional halo),
//!     the paper's domain-parallel data loading; per-variable Z-score
//!     normalization; identical seeds across a model-parallel group and
//!     distinct seeds across data-parallel groups (paper Section 5).

use crate::config::ModelConfig;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// One Fourier mode of the synthetic atmosphere.
#[derive(Clone, Debug)]
struct Mode {
    k_lat: f32,
    k_lon: f32,
    omega: f32,
    phase: f32,
    amp: f32,
}

/// Deterministic synthetic global atmosphere.
///
/// field(c, lat, lon, t) = sum_m A_cm sin(k_lat*phi + k_lon*lambda
///                                        + omega_m * t + phase_cm)
/// with a shared mode bank and per-channel amplitude/phase mixing, so
/// channels are correlated (like physical variables) and the temporal
/// evolution is a linear operator in mode space — learnable by an MLP
/// from grid-space snapshots.
pub struct SpectralAtmosphere {
    pub lat: usize,
    pub lon: usize,
    pub channels: usize,
    modes: Vec<Mode>,
    /// per-channel per-mode (amplitude, phase offset)
    mixing: Vec<Vec<(f32, f32)>>,
}

impl SpectralAtmosphere {
    pub fn new(lat: usize, lon: usize, channels: usize, n_modes: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from(seed ^ 0xA7A0_5E17);
        let modes = (0..n_modes)
            .map(|_| Mode {
                k_lat: (rng.below(4) + 1) as f32,
                k_lon: (rng.below(6) + 1) as f32,
                omega: rng.range(0.1, 1.2),
                phase: rng.range(0.0, std::f32::consts::TAU),
                amp: rng.range(0.3, 1.0),
            })
            .collect();
        let mixing = (0..channels)
            .map(|_| {
                (0..n_modes)
                    .map(|_| (rng.normal() * 0.8, rng.range(0.0, std::f32::consts::TAU)))
                    .collect()
            })
            .collect();
        SpectralAtmosphere { lat, lon, channels, modes, mixing }
    }

    /// Evaluate one channel over a latitude slice [lat_lo, lat_hi) at
    /// integer time-step t. This is the partitioned-read primitive: a
    /// rank only ever evaluates its own slice.
    pub fn channel_slice(&self, c: usize, lat_lo: usize, lat_hi: usize, t: f32) -> Tensor {
        let mut out = vec![0.0f32; (lat_hi - lat_lo) * self.lon];
        for (mi, m) in self.modes.iter().enumerate() {
            let (amp_c, ph_c) = self.mixing[c][mi];
            let a = m.amp * amp_c;
            if a == 0.0 {
                continue;
            }
            for (row, li) in (lat_lo..lat_hi).enumerate() {
                let phi = li as f32 / self.lat as f32 * std::f32::consts::PI;
                for lj in 0..self.lon {
                    let lam = lj as f32 / self.lon as f32 * std::f32::consts::TAU;
                    out[row * self.lon + lj] += a
                        * (m.k_lat * phi + m.k_lon * lam + m.omega * t + m.phase + ph_c)
                            .sin();
                }
            }
        }
        Tensor::new(vec![lat_hi - lat_lo, self.lon], out)
    }

    /// Full sample [lat, lon, channels] at time-step t (1-way path, tests).
    pub fn sample(&self, t: f32) -> Tensor {
        self.slice(0, self.lat, 0, self.channels, t)
    }

    /// Partitioned read: [lat_lo, lat_hi) x all lon x [c_lo, c_hi).
    pub fn slice(
        &self,
        lat_lo: usize,
        lat_hi: usize,
        c_lo: usize,
        c_hi: usize,
        t: f32,
    ) -> Tensor {
        let (lr, lc) = (lat_hi - lat_lo, c_hi - c_lo);
        let mut out = vec![0.0f32; lr * self.lon * lc];
        for (ci, c) in (c_lo..c_hi).enumerate() {
            let ch = self.channel_slice(c, lat_lo, lat_hi, t);
            for li in 0..lr {
                for lj in 0..self.lon {
                    out[(li * self.lon + lj) * lc + ci] = ch.data[li * self.lon + lj];
                }
            }
        }
        Tensor::new(vec![lr, self.lon, lc], out)
    }
}

/// Per-variable Z-score normalization statistics (paper Section 6).
#[derive(Clone, Debug)]
pub struct Normalizer {
    pub mean: Vec<f32>,
    pub std: Vec<f32>,
}

impl Normalizer {
    /// Estimate from a few sample times (the "climatology pass").
    pub fn fit(atmos: &SpectralAtmosphere, times: &[f32]) -> Self {
        let c = atmos.channels;
        let mut sum = vec![0.0f64; c];
        let mut sumsq = vec![0.0f64; c];
        let mut n = 0usize;
        for &t in times {
            let s = atmos.sample(t);
            n += atmos.lat * atmos.lon;
            for li in 0..atmos.lat {
                for lj in 0..atmos.lon {
                    for ci in 0..c {
                        let v = s.data[(li * atmos.lon + lj) * c + ci] as f64;
                        sum[ci] += v;
                        sumsq[ci] += v * v;
                    }
                }
            }
        }
        let mean: Vec<f32> = sum.iter().map(|s| (*s / n as f64) as f32).collect();
        let std = sumsq
            .iter()
            .zip(&mean)
            .map(|(sq, m)| {
                let var = (*sq / n as f64) - (*m as f64) * (*m as f64);
                (var.max(1e-12) as f32).sqrt()
            })
            .collect();
        Normalizer { mean, std }
    }

    pub fn apply_slice(&self, t: &mut Tensor, c_lo: usize) {
        let c_l = *t.shape.last().unwrap();
        let spatial = t.numel() / c_l;
        for s in 0..spatial {
            for ci in 0..c_l {
                let g = c_lo + ci;
                let idx = s * c_l + ci;
                t.data[idx] = (t.data[idx] - self.mean[g]) / self.std[g];
            }
        }
    }
}

/// One training item: this rank's (x, y) shards, zero-padded to the
/// padded channel count.
pub struct Item {
    pub x: Tensor,
    pub y: Tensor,
    /// global time index of x (y is t + lead)
    pub t: usize,
    /// bytes this rank read from "storage" for the item (domain-parallel
    /// I/O accounting: 1/n of the full sample under jigsaw)
    pub bytes_read: u64,
}

/// Resumable cursor state of a [`ShardedLoader`]: the shuffled epoch
/// order, the position within it, and the raw shuffle-RNG state. The
/// checkpoint subsystem persists one per data-parallel group (all MP
/// partners of a group hold identical state by construction), so a
/// resumed run continues the exact sample stream an uninterrupted run
/// would have seen.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LoaderState {
    pub order: Vec<usize>,
    pub cursor: usize,
    pub rng: [u64; 4],
}

/// Jigsaw-partitioned data loader for one rank.
///
/// `mp_seed` must be identical across the rank's model-parallel group and
/// distinct across data-parallel groups (paper Section 5) — it drives the
/// sample-time shuffling only, so MP partners always read the same sample.
pub struct ShardedLoader {
    pub atmos: SpectralAtmosphere,
    pub norm: Normalizer,
    pub lat_range: (usize, usize),
    pub ch_range: (usize, usize),
    pub ch_pad_to: usize,
    pub lead: usize,
    /// optional latitude halo rows on each side (boundary conditions for
    /// spatially-overlapping encoders; our patch conv needs none, but the
    /// substrate supports it — tests exercise coverage)
    pub halo: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
}

impl ShardedLoader {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        cfg: &ModelConfig,
        mesh: &crate::jigsaw::Mesh,
        rank: usize,
        n_times: usize,
        lead: usize,
        mp_seed: u64,
        n_modes: usize,
    ) -> Result<Self, crate::jigsaw::MeshError> {
        let atmos = SpectralAtmosphere::new(
            cfg.lat,
            cfg.lon,
            cfg.channels,
            n_modes,
            0xC11A_7E, // the *world* is shared by everyone
        );
        let norm = Normalizer::fit(&atmos, &[0.0, 3.5, 7.25, 11.75]);
        // the seed's Way::from_n panicked on unsupported degrees here; a
        // non-dividing mesh must not silently truncate the shard ranges
        mesh.validate_config(cfg)?;
        let l = crate::jigsaw::Planner::new(*mesh);
        let ts = mesh.tok();
        let cs = mesh.ch();
        let lat_l = cfg.lat / ts;
        let ti = l.tok_block_of(rank);
        let cj = l.ch_block_of(rank);
        // channel shard over the padded channel axis
        let cp_l = cfg.channels_padded / cs;
        let (c_lo, c_hi) = (cj * cp_l, (cj + 1) * cp_l);
        let mut rng = Rng::seed_from(mp_seed);
        let mut order: Vec<usize> = (0..n_times).collect();
        // Fisher-Yates with the MP-shared seed
        for i in (1..order.len()).rev() {
            let j = rng.below(i + 1);
            order.swap(i, j);
        }
        Ok(ShardedLoader {
            atmos,
            norm,
            lat_range: (ti * lat_l, (ti + 1) * lat_l),
            ch_range: (c_lo, c_hi),
            ch_pad_to: cp_l,
            lead,
            halo: 0,
            order,
            cursor: 0,
            rng,
        })
    }

    pub fn epoch_len(&self) -> usize {
        self.order.len()
    }

    /// Capture the resumable cursor state (see [`LoaderState`]).
    pub fn state(&self) -> LoaderState {
        LoaderState {
            order: self.order.clone(),
            cursor: self.cursor,
            rng: self.rng.state(),
        }
    }

    /// Restore a captured cursor state: subsequent [`next_item`]
    /// (ShardedLoader::next_item) calls continue the exact stream the
    /// saving loader would have produced. The shard geometry (mesh,
    /// rank) is not part of the state — it is reconstructed by
    /// [`ShardedLoader::new`] for whatever mesh the resumed run uses.
    pub fn restore_state(&mut self, s: &LoaderState) {
        self.order = s.order.clone();
        self.cursor = s.cursor;
        self.rng = Rng::from_state(s.rng);
    }

    /// Read this rank's shard of sample `t` (physical channels only are
    /// evaluated; padded channels are zeros — paper: "the data loader
    /// applies zero-padding where necessary").
    pub fn read_shard(&self, t: f32) -> (Tensor, u64) {
        let (la, lb) = self.lat_range;
        let (ca, cb) = self.ch_range;
        let phys_hi = cb.min(self.atmos.channels);
        let lat_lo = la.saturating_sub(self.halo);
        let lat_hi = (lb + self.halo).min(self.atmos.lat);
        let mut out = Tensor::zeros(&[lb - la, self.atmos.lon, self.ch_pad_to]);
        if phys_hi > ca {
            let mut phys = self.atmos.slice(lat_lo, lat_hi, ca, phys_hi, t);
            self.norm.apply_slice(&mut phys, ca);
            // drop halo rows into the core window
            let halo_top = la - lat_lo;
            let lc = phys_hi - ca;
            for li in 0..(lb - la) {
                for lj in 0..self.atmos.lon {
                    for ci in 0..lc {
                        out.data[(li * self.atmos.lon + lj) * self.ch_pad_to + ci] =
                            phys.data[((li + halo_top) * self.atmos.lon + lj) * lc + ci];
                    }
                }
            }
        }
        let bytes = ((lat_hi - lat_lo) * self.atmos.lon * (phys_hi.saturating_sub(ca)) * 4)
            as u64;
        (out, bytes)
    }

    /// Next (x, y) training pair for this rank.
    pub fn next_item(&mut self) -> Item {
        if self.cursor >= self.order.len() {
            self.cursor = 0;
            // reshuffle between epochs with the shared stream
            for i in (1..self.order.len()).rev() {
                let j = self.rng.below(i + 1);
                self.order.swap(i, j);
            }
        }
        let t = self.order[self.cursor];
        self.cursor += 1;
        let (x, bx) = self.read_shard(t as f32);
        let (y, by) = self.read_shard((t + self.lead) as f32);
        Item { x, y, t, bytes_read: bx + by }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jigsaw::Mesh;

    fn mesh(n: usize) -> Mesh {
        Mesh::from_degree(n).unwrap()
    }

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            lat: 8,
            lon: 16,
            channels: 6,
            channels_padded: 8,
            patch: 2,
            d_emb: 32,
            d_tok: 48,
            d_ch: 32,
            blocks: 2,
            tokens: 32,
            patch_dim: 32,
            param_count: 0,
            flops_forward: 0,
            channel_weights: vec![1.0; 6],
        }
    }

    #[test]
    fn atmosphere_is_deterministic_and_smooth() {
        let a = SpectralAtmosphere::new(8, 16, 4, 12, 1);
        let s1 = a.sample(0.0);
        let s2 = a.sample(0.0);
        assert_eq!(s1, s2);
        // temporal smoothness: small dt -> small change
        let s3 = a.sample(0.01);
        assert!(s1.max_abs_diff(&s3) < 0.1);
        // but distinct times differ
        let s4 = a.sample(3.0);
        assert!(s1.max_abs_diff(&s4) > 0.1);
    }

    #[test]
    fn slices_agree_with_full_sample() {
        let a = SpectralAtmosphere::new(8, 16, 6, 12, 2);
        let full = a.sample(1.5);
        let sl = a.slice(2, 6, 1, 4, 1.5);
        for li in 0..4 {
            for lj in 0..16 {
                for ci in 0..3 {
                    let want = full.data[((li + 2) * 16 + lj) * 6 + (ci + 1)];
                    let got = sl.data[(li * 16 + lj) * 3 + ci];
                    assert!((want - got).abs() < 1e-6);
                }
            }
        }
    }

    #[test]
    fn normalizer_zero_mean_unit_std() {
        let a = SpectralAtmosphere::new(8, 16, 4, 12, 3);
        let norm = Normalizer::fit(&a, &[0.0, 1.0, 2.0, 3.0]);
        let mut s = a.sample(1.0);
        norm.apply_slice(&mut s, 0);
        let c = 4;
        for ci in 0..c {
            let vals: Vec<f32> = (0..8 * 16).map(|i| s.data[i * c + ci]).collect();
            let mean: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
            assert!(mean.abs() < 1.0, "roughly centered, got {mean}");
        }
    }

    #[test]
    fn shards_are_disjoint_and_cover() {
        // 4-way shards partition the (lat, channel) plane
        let c = cfg();
        let loaders: Vec<ShardedLoader> =
            (0..4)
                .map(|r| ShardedLoader::new(&c, &mesh(4), r, 4, 1, 9, 8).unwrap())
                .collect();
        let mut covered = vec![false; c.lat * c.channels_padded];
        for l in &loaders {
            for li in l.lat_range.0..l.lat_range.1 {
                for ci in l.ch_range.0..l.ch_range.1 {
                    let idx = li * c.channels_padded + ci;
                    assert!(!covered[idx], "overlap at lat {li} ch {ci}");
                    covered[idx] = true;
                }
            }
        }
        assert!(covered.iter().all(|&v| v), "holes in coverage");
    }

    #[test]
    fn eight_way_mesh_shards_partition_the_plane() {
        // a 2x4 mesh partitions (lat, channel) into 8 disjoint tiles
        let c = cfg();
        let m = Mesh::new(2, 4).unwrap();
        let mut covered = vec![false; c.lat * c.channels_padded];
        for r in 0..m.n() {
            let l = ShardedLoader::new(&c, &m, r, 4, 1, 9, 8).unwrap();
            for li in l.lat_range.0..l.lat_range.1 {
                for ci in l.ch_range.0..l.ch_range.1 {
                    let idx = li * c.channels_padded + ci;
                    assert!(!covered[idx], "overlap at lat {li} ch {ci}");
                    covered[idx] = true;
                }
            }
        }
        assert!(covered.iter().all(|&v| v), "holes in 2x4 coverage");
    }

    #[test]
    fn mp_group_reads_same_sample_order() {
        let c = cfg();
        let mut l0 = ShardedLoader::new(&c, &mesh(2), 0, 10, 1, 42, 8).unwrap();
        let mut l1 = ShardedLoader::new(&c, &mesh(2), 1, 10, 1, 42, 8).unwrap();
        for _ in 0..10 {
            assert_eq!(l0.next_item().t, l1.next_item().t);
        }
        // different DP seed -> different order
        let mut l2 = ShardedLoader::new(&c, &mesh(2), 0, 10, 1, 43, 8).unwrap();
        let order_a: Vec<usize> = (0..10).map(|_| l0.next_item().t).collect();
        let order_b: Vec<usize> = (0..10).map(|_| l2.next_item().t).collect();
        assert_ne!(order_a, order_b);
    }

    #[test]
    fn domain_parallel_io_is_fraction_of_sample() {
        let c = cfg();
        let mut l1 = ShardedLoader::new(&c, &mesh(1), 0, 4, 1, 7, 8).unwrap();
        let mut l4 = ShardedLoader::new(&c, &mesh(4), 0, 4, 1, 7, 8).unwrap();
        let full = l1.next_item().bytes_read;
        let quarter = l4.next_item().bytes_read;
        // rank 0 of 4-way holds channels 0..4 (all physical) of lat half
        assert!(quarter < full, "domain parallelism must reduce I/O");
    }

    #[test]
    fn padded_channels_are_zero() {
        let c = cfg();
        let mut l = ShardedLoader::new(&c, &mesh(2), 1, 4, 1, 7, 8).unwrap();
        // rank 1 of 2-way holds channels 4..8; physical end at 6
        let item = l.next_item();
        let cl = l.ch_pad_to;
        for s in 0..(c.lat * c.lon) {
            assert_eq!(item.x.data[s * cl + (cl - 1)], 0.0);
            assert_eq!(item.x.data[s * cl + (cl - 2)], 0.0);
        }
    }

    #[test]
    fn halo_read_extends_rows() {
        let c = cfg();
        let mut l = ShardedLoader::new(&c, &mesh(4), 2, 4, 1, 7, 8).unwrap();
        l.halo = 1;
        // rank 2 (lat half 1) with halo: reads one extra row above
        let (_, bytes) = l.read_shard(0.0);
        let l0 = {
            let mut l2 = ShardedLoader::new(&c, &mesh(4), 2, 4, 1, 7, 8).unwrap();
            l2.halo = 0;
            l2.read_shard(0.0).1
        };
        assert!(bytes > l0);
    }
}
