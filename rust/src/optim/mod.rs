//! Optimizer: per-shard Adam with global-norm gradient clipping, and the
//! paper's learning-rate schedule (linear warm-up epoch, cosine decay to
//! 1e-5, separate encoder/decoder LR — Section 6).
//!
//! Each jigsaw rank's optimizer updates its own shard independently: "no
//! communication between the different model-parallel optimizers is
//! required" (paper Section 5). The only cross-rank step is the scalar
//! allreduce of the squared gradient norm for clipping, matching the
//! monolithic AOT `train_step`'s global clip.

use crate::comm::Comm;
use crate::model::params::PStore;

pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;
pub const GRAD_CLIP: f32 = 1.0;

/// Adam state for one rank's shards.
pub struct Adam {
    pub m: PStore,
    pub v: PStore,
    pub step: u64,
    pub lr: f32,
    /// learning-rate multiplier for encoder/decoder parameters (the paper
    /// trains enc/dec at 2e-5 vs 1e-4 body LR -> factor 0.2).
    pub encdec_lr_factor: f32,
}

impl Adam {
    pub fn new(params: &PStore, lr: f32) -> Self {
        Adam {
            m: params.zeros_like(),
            v: params.zeros_like(),
            step: 0,
            lr,
            encdec_lr_factor: 1.0,
        }
    }

    /// Rebuild an optimizer mid-run from checkpointed moment shards and
    /// step counter. The moment stores must be sharded for the *current*
    /// mesh (the checkpoint loader reshards them before calling this).
    pub fn from_state(m: PStore, v: PStore, step: u64, lr: f32) -> Self {
        Adam { m, v, step, lr, encdec_lr_factor: 1.0 }
    }

    /// Compute the global-clip scale factor. Replicated vectors are
    /// counted once (see `global_norm_sq_contrib`); the squared norm is
    /// group-reduced so every rank clips identically.
    pub fn clip_scale(grads: &PStore, comm: &mut Comm, group: &[usize]) -> f32 {
        let local = grads.global_norm_sq_contrib();
        let total = comm.allreduce_scalar(group, local);
        let gnorm = total.max(0.0).sqrt();
        (GRAD_CLIP / gnorm.max(1e-12)).min(1.0)
    }

    fn is_encdec(name: &str) -> bool {
        name.starts_with("enc_") || name.starts_with("dec_")
    }

    /// One Adam update over this rank's shards. `scale` folds in gradient
    /// clipping (and DP averaging). Mirrors python model.adam_step.
    pub fn update(&mut self, params: &mut PStore, grads: &PStore, scale: f32) {
        self.step += 1;
        let b1t = 1.0 - ADAM_B1.powi(self.step as i32);
        let b2t = 1.0 - ADAM_B2.powi(self.step as i32);
        let base_lr = self.lr;
        let f = self.encdec_lr_factor;

        for (name, pm) in params.mats.iter_mut() {
            let lr = if Self::is_encdec(name) { base_lr * f } else { base_lr };
            // invalidate the runtime's resident device buffers (§Perf)
            if let Some(c) = pm.cache.as_mut() {
                c.1 += 1;
            }
            let gm = &grads.mats[name];
            let mm = self.m.mats.get_mut(name).unwrap();
            let vm = self.v.mats.get_mut(name).unwrap();
            for (key, pb) in pm.blocks.iter_mut() {
                adam_inner(
                    &mut pb.data,
                    &gm.blocks[key].data,
                    &mut mm.blocks.get_mut(key).unwrap().data,
                    &mut vm.blocks.get_mut(key).unwrap().data,
                    scale,
                    lr,
                    b1t,
                    b2t,
                );
            }
        }
        for (name, pv) in params.vecs.iter_mut() {
            let lr = if Self::is_encdec(name) { base_lr * f } else { base_lr };
            adam_inner(
                &mut pv.local.data,
                &grads.vecs[name].local.data,
                &mut self.m.vecs.get_mut(name).unwrap().local.data,
                &mut self.v.vecs.get_mut(name).unwrap().local.data,
                scale,
                lr,
                b1t,
                b2t,
            );
        }
    }
}

#[allow(clippy::too_many_arguments)]
#[inline]
fn adam_inner(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    scale: f32,
    lr: f32,
    b1t: f32,
    b2t: f32,
) {
    for i in 0..p.len() {
        let gi = g[i] * scale;
        m[i] = ADAM_B1 * m[i] + (1.0 - ADAM_B1) * gi;
        v[i] = ADAM_B2 * v[i] + (1.0 - ADAM_B2) * gi * gi;
        let mhat = m[i] / b1t;
        let vhat = v[i] / b2t;
        p[i] -= lr * mhat / (vhat.sqrt() + ADAM_EPS);
    }
}

/// The paper's LR schedule: ramped linear warm-up from 1e-6 to `peak`
/// during epoch 1, cosine anneal to 1e-5 over epochs 2..=total.
pub struct LrSchedule {
    pub peak: f32,
    pub warmup_start: f32,
    pub floor: f32,
    pub steps_per_epoch: usize,
    pub total_epochs: usize,
}

impl LrSchedule {
    pub fn paper(peak: f32, steps_per_epoch: usize, total_epochs: usize) -> Self {
        LrSchedule {
            peak,
            warmup_start: 1e-6,
            floor: 1e-5,
            steps_per_epoch,
            total_epochs,
        }
    }

    /// LR at a global step (0-based).
    pub fn at(&self, step: usize) -> f32 {
        let spe = self.steps_per_epoch.max(1);
        if step < spe {
            // linear warm-up within the first epoch
            let t = step as f32 / spe as f32;
            self.warmup_start + t * (self.peak - self.warmup_start)
        } else {
            let total = spe * self.total_epochs.max(2);
            let t = ((step - spe) as f32 / (total - spe).max(1) as f32).min(1.0);
            let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
            self.floor + (self.peak - self.floor) * cos
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::jigsaw::Mesh;
    use crate::model::params::shard_params;
    use crate::model::init_global_params;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            lat: 8,
            lon: 16,
            channels: 6,
            channels_padded: 8,
            patch: 2,
            d_emb: 32,
            d_tok: 48,
            d_ch: 32,
            blocks: 1,
            tokens: 32,
            patch_dim: 32,
            param_count: 0,
            flops_forward: 0,
            channel_weights: vec![1.0; 6],
        }
    }

    #[test]
    fn adam_matches_closed_form_first_step() {
        // with m=v=0, step 1: update = lr * g/|g| elementwise sign-ish:
        // mhat = g, vhat = g^2, so delta = lr * g / (|g| + eps)
        let cfg = tiny_cfg();
        let global = init_global_params(&cfg, 0);
        let mut params = shard_params(&cfg, &Mesh::unit(), 0, &global).unwrap();
        let mut grads = params.zeros_like();
        let g0 = 0.5f32;
        grads.mats.get_mut("enc_w").unwrap().blocks.values_mut().for_each(|b| {
            b.data.iter_mut().for_each(|x| *x = g0);
        });
        let before = params.mats["enc_w"].blocks[&(0, 0)].data[0];
        let mut adam = Adam::new(&params, 1e-2);
        adam.update(&mut params, &grads, 1.0);
        let after = params.mats["enc_w"].blocks[&(0, 0)].data[0];
        let expect = before - 1e-2 * g0 / (g0 + ADAM_EPS);
        assert!((after - expect).abs() < 1e-6, "{after} vs {expect}");
    }

    #[test]
    fn encdec_lr_factor_applies() {
        let cfg = tiny_cfg();
        let global = init_global_params(&cfg, 0);
        let mut p1 = shard_params(&cfg, &Mesh::unit(), 0, &global).unwrap();
        let mut p2 = p1.clone();
        let mut grads = p1.zeros_like();
        for m in grads.mats.values_mut() {
            for b in m.blocks.values_mut() {
                b.data.iter_mut().for_each(|x| *x = 1.0);
            }
        }
        let mut a1 = Adam::new(&p1, 1e-2);
        let mut a2 = Adam::new(&p2, 1e-2);
        a2.encdec_lr_factor = 0.2;
        a1.update(&mut p1, &grads, 1.0);
        a2.update(&mut p2, &grads, 1.0);
        let d1 = (p1.mats["enc_w"].blocks[&(0, 0)].data[0]
            - p2.mats["enc_w"].blocks[&(0, 0)].data[0])
            .abs();
        assert!(d1 > 1e-4, "enc_w LRs should differ");
        let body1 = p1.mats["blk0_ch_w1"].blocks[&(0, 0)].data[0];
        let body2 = p2.mats["blk0_ch_w1"].blocks[&(0, 0)].data[0];
        assert!((body1 - body2).abs() < 1e-7, "body LR unchanged");
    }

    #[test]
    fn lr_schedule_shape() {
        let s = LrSchedule::paper(1e-4, 100, 10);
        assert!((s.at(0) - 1e-6).abs() < 1e-7);
        assert!(s.at(50) > 1e-5 && s.at(50) < 1e-4);
        assert!((s.at(100) - 1e-4).abs() < 2e-6);
        // decays monotonically after warm-up
        assert!(s.at(300) < s.at(150));
        // floor at the end
        assert!((s.at(100 * 10) - 1e-5).abs() < 2e-6);
    }

    #[test]
    fn clip_scale_unit_when_small() {
        use crate::comm::Network;
        let cfg = tiny_cfg();
        let global = init_global_params(&cfg, 0);
        let params = shard_params(&cfg, &Mesh::unit(), 0, &global).unwrap();
        let mut grads = params.zeros_like();
        grads.mats.get_mut("enc_w").unwrap().blocks.values_mut().for_each(|b| {
            b.data[0] = 0.1;
        });
        let net = Network::new(1);
        let mut comm = net.endpoint(0);
        let s = Adam::clip_scale(&grads, &mut comm, &[0]);
        assert_eq!(s, 1.0);
        // large grads clip to 1/|g|
        grads.mats.get_mut("enc_w").unwrap().blocks.values_mut().for_each(|b| {
            b.data.iter_mut().for_each(|x| *x = 10.0);
        });
        let s = Adam::clip_scale(&grads, &mut comm, &[0]);
        let n = grads.global_norm_sq_contrib().sqrt();
        assert!((s - 1.0 / n).abs() < 1e-6);
    }
}
