//! Baseline parallelization schemes the paper compares against:
//! Megatron-LM-style tensor parallelism and FSDP-style sharding.
//!
//! Both are implemented as analytic cost models over the same cluster
//! substrate (for the Fig-8 comparison and the ablation benches), plus an
//! executable Megatron-style TP linear layer over the real comm fabric
//! (column/row-parallel pair with a single forward allreduce) used in the
//! differential tests: jigsaw and Megatron-TP must produce identical math
//! with different communication patterns.

use anyhow::Result;

use crate::comm::Comm;
use crate::config::zoo::{ZooModel, PAPER_SAMPLE_BYTES};
use crate::perfmodel::{ClusterSpec, Precision, StepTime, PAPER_TOKENS, N_LINEAR};
use crate::runtime::{Backend, MatmulOp};
use crate::tensor::{ops, Tensor};

/// Megatron-LM tensor parallelism cost model (Shoeybi et al. 2020):
/// feed-forward pairs are column+row parallel with ONE allreduce of the
/// full activation per pair per pass; every rank loads the FULL sample
/// (no domain parallelism).
pub fn megatron_step(cluster: &ClusterSpec, m: ZooModel, way: usize, precision: Precision, dataload: bool) -> StepTime {
    let mut t = StepTime::default();
    let wayf = way as f64;
    if dataload {
        let ranks_per_node = cluster.gpus_per_node.min(way) as f64;
        // full sample per rank: no I/O division
        let bytes = 2.0 * PAPER_SAMPLE_BYTES;
        t.io = bytes / (cluster.storage_bw_node / ranks_per_node);
    }
    let eff_peak = precision.peak_flops() * precision.gemm_efficiency();
    t.compute = m.flops_step() / wayf / eff_peak;
    if way > 1 {
        // one full-activation allreduce per MLP pair per pass
        let act_bytes = PAPER_TOKENS * m.d_emb as f64 * 4.0;
        let pairs = N_LINEAR / 2.0;
        let passes = 2.0; // fwd + bwd (Megatron: one allreduce each)
        let ring = 2.0 * (wayf - 1.0) / wayf * act_bytes;
        t.mp_comm = passes * pairs * ring / cluster.mp_bw_2way;
        // Megatron exposes the allreduce (sync point between pair halves)
        t.mp_comm_exposed = 0.7 * t.mp_comm;
    }
    t.total = t.io.max(t.compute + t.mp_comm_exposed + cluster.step_overhead);
    t
}

/// FSDP cost model (Zhao et al. 2023): weights allgathered per layer in
/// forward and backward, gradients reduce-scattered; full sample per rank.
pub fn fsdp_step(cluster: &ClusterSpec, m: ZooModel, way: usize, precision: Precision, dataload: bool) -> StepTime {
    let mut t = StepTime::default();
    let wayf = way as f64;
    if dataload {
        let ranks_per_node = cluster.gpus_per_node.min(way) as f64;
        t.io = 2.0 * PAPER_SAMPLE_BYTES / (cluster.storage_bw_node / ranks_per_node);
    }
    let eff_peak = precision.peak_flops() * precision.gemm_efficiency();
    // FSDP does not split the math: each rank computes the full model
    t.compute = m.flops_step() / eff_peak;
    if way > 1 {
        // allgather full weights twice + reduce-scatter grads once
        let w_bytes = m.param_bytes();
        let ring = (wayf - 1.0) / wayf * w_bytes;
        t.mp_comm = 3.0 * ring / cluster.mp_bw_2way;
        // layer-wise prefetch overlaps much of it
        t.mp_comm_exposed = 0.3 * t.mp_comm;
    }
    t.total = t.io.max(t.compute + t.mp_comm_exposed + cluster.step_overhead);
    t
}

/// Paper-reported Megatron-LM reference numbers (Section 6.3.2/6.3.3)
/// for the comparison rows of Fig 8/9.
pub const MEGATRON_STRONG_2WAY: f64 = 1.6;
pub const MEGATRON_STRONG_4WAY: f64 = 2.3;
pub const MEGATRON_WEAK_EFF: f64 = 0.82;

// ---------------------------------------------------------------------------
// Executable Megatron-style TP linear pair (differential testing)
// ---------------------------------------------------------------------------

/// y = gelu(x W1^T) W2^T computed Megatron-style on `n` ranks:
/// W1 row-sharded (column-parallel), W2 column-sharded (row-parallel),
/// one allreduce of the partial outputs. `x` is replicated (Megatron has
/// no domain parallelism). Returns the full output on every rank.
pub fn megatron_mlp_forward(
    comm: &mut Comm,
    backend: &dyn Backend,
    group: &[usize],
    rank_in_group: usize,
    x: &Tensor,
    w1: &Tensor,
    w2: &Tensor,
) -> Result<Tensor> {
    let n = group.len();
    let (h, _k) = w1.dims2();
    let (out, h2) = w2.dims2();
    assert_eq!(h, h2);
    assert_eq!(h % n, 0, "hidden dim must divide TP degree");
    let hs = h / n;
    let w1_shard = w1.slice_rows(rank_in_group * hs, (rank_in_group + 1) * hs);
    let w2_shard = w2.slice_cols(rank_in_group * hs, (rank_in_group + 1) * hs);
    let part = backend.matmul(MatmulOp::NT, x, &w1_shard)?;
    let act = ops::gelu(&part);
    let partial = backend.matmul(MatmulOp::NT, &act, &w2_shard)?;
    let _ = out;
    Ok(comm.allreduce_sum(group, &partial))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Network;
    use crate::config::zoo::TABLE1;
    use crate::runtime::native::NativeBackend;
    use crate::util::rng::Rng;

    #[test]
    fn jigsaw_beats_megatron_in_io_bound_regime() {
        // domain parallelism divides I/O; Megatron cannot
        let c = ClusterSpec::horeka();
        let m = TABLE1[0];
        let meg = megatron_step(&c, m, 4, Precision::Tf32, true);
        let jig = crate::perfmodel::simulate_step(
            &c,
            &crate::perfmodel::Workload {
                model: m,
                mesh: crate::jigsaw::Mesh::from_degree(4).unwrap(),
                dp: 1,
                precision: Precision::Tf32,
                dataload: true,
            },
        );
        assert!(jig.total < meg.total, "jigsaw {jig:?} vs megatron {meg:?}");
    }

    #[test]
    fn fsdp_computes_full_model_per_rank() {
        let c = ClusterSpec::horeka();
        let m = TABLE1[6];
        let f = fsdp_step(&c, m, 4, Precision::Fp32, false);
        let meg = megatron_step(&c, m, 4, Precision::Fp32, false);
        assert!(f.compute > meg.compute * 3.0);
    }

    #[test]
    fn executable_megatron_mlp_matches_serial() {
        let mut rng = Rng::seed_from(5);
        let mut mk = |r: usize, c: usize| {
            let mut d = vec![0.0; r * c];
            rng.fill_normal(&mut d, 0.5);
            Tensor::new(vec![r, c], d)
        };
        let x = mk(6, 10);
        let w1 = mk(8, 10);
        let w2 = mk(10, 8);
        let serial = {
            let b = NativeBackend;
            let h = ops::gelu(&b.matmul(MatmulOp::NT, &x, &w1).unwrap());
            b.matmul(MatmulOp::NT, &h, &w2).unwrap()
        };
        let net = Network::new(2);
        let group = vec![0usize, 1];
        let mut handles = Vec::new();
        for r in 0..2 {
            let mut comm = net.endpoint(r);
            let (x, w1, w2, group) = (x.clone(), w1.clone(), w2.clone(), group.clone());
            handles.push(std::thread::spawn(move || {
                megatron_mlp_forward(&mut comm, &NativeBackend, &group, r, &x, &w1, &w2)
                    .unwrap()
            }));
        }
        for h in handles {
            let got = h.join().unwrap();
            assert!(got.max_abs_diff(&serial) < 1e-4);
        }
    }

    #[test]
    fn megatron_replicates_io() {
        let c = ClusterSpec::horeka();
        let m = TABLE1[2];
        let meg = megatron_step(&c, m, 4, Precision::Fp32, true);
        let jig = crate::perfmodel::simulate_step(
            &c,
            &crate::perfmodel::Workload {
                model: m,
                mesh: crate::jigsaw::Mesh::from_degree(4).unwrap(),
                dp: 1,
                precision: Precision::Fp32,
                dataload: true,
            },
        );
        assert!((meg.io / jig.io - 4.0).abs() < 0.1, "4x I/O: {} vs {}", meg.io, jig.io);
    }
}
