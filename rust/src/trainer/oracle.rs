//! Oracle validation: the rust jigsaw engine against the AOT-exported JAX
//! monolithic programs.
//!
//! The same global parameters and sample are fed to (a) the jax
//! `loss_and_grad` HLO program executed via PJRT and (b) the n-way rust
//! distributed engine; loss and every reassembled parameter gradient must
//! agree. `ln_groups=2` oracles account for the local-stats layer norm of
//! 2-/4-way jigsaw (paper Section 5).

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::comm::Network;
use crate::config::{artifacts_dir, Manifest, ModelConfig};
use crate::jigsaw::{Ctx, Mesh};
use crate::model::dist::DistModel;
use crate::model::params::{assemble_params, shard_params, PStore};
use crate::model::{init_global_params, param_order};
use crate::runtime::engine::{Engine, PjrtBackend};
use crate::runtime::Backend;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Comparison outcome.
pub struct OracleReport {
    pub preset: String,
    pub mesh: Mesh,
    pub loss_oracle: f32,
    pub loss_dist: f32,
    pub max_grad_err: f32,
    pub worst_param: String,
    pub per_param_err: Vec<(String, f32)>,
}

impl std::fmt::Display for OracleReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "oracle check: preset={} mesh={}\n  loss  oracle={:.6} dist={:.6} (diff {:.2e})\n  grads max err {:.3e} (worst: {})",
            self.preset,
            self.mesh,
            self.loss_oracle,
            self.loss_dist,
            (self.loss_oracle - self.loss_dist).abs(),
            self.max_grad_err,
            self.worst_param,
        )?;
        Ok(())
    }
}

impl OracleReport {
    pub fn passes(&self, tol: f32) -> bool {
        let loss_ok = (self.loss_oracle - self.loss_dist).abs()
            <= tol * self.loss_oracle.abs().max(1.0);
        loss_ok && self.max_grad_err <= tol
    }
}

/// Slice a [lat, lon, C] sample to one rank's (lat, channel) shard.
pub fn sample_shard(
    x: &Tensor,
    lat_range: (usize, usize),
    ch_range: (usize, usize),
) -> Tensor {
    let (lat, lon, c) = (x.shape[0], x.shape[1], x.shape[2]);
    assert_eq!(x.shape.len(), 3);
    let (la, lb) = lat_range;
    let (ca, cb) = ch_range;
    assert!(lb <= lat && cb <= c);
    let mut out = Tensor::zeros(&[lb - la, lon, cb - ca]);
    for li in la..lb {
        for lj in 0..lon {
            for ci in ca..cb {
                out.data[((li - la) * lon + lj) * (cb - ca) + (ci - ca)] =
                    x.data[(li * lon + lj) * c + ci];
            }
        }
    }
    out
}

/// Run the mesh-parallel rust engine for one (x, y) and reassemble
/// (loss, grads) across the whole group.
pub fn run_dist_loss_and_grad(
    cfg: &ModelConfig,
    mesh: &Mesh,
    global_params: &[(String, Tensor)],
    x: &Tensor,
    y: &Tensor,
    backend: Arc<dyn Backend>,
    rollout: usize,
) -> Result<(f32, Vec<(String, Tensor)>)> {
    run_dist_loss_and_grad_prec(
        cfg,
        mesh,
        global_params,
        x,
        y,
        backend,
        rollout,
        crate::tensor::Precision::F32,
    )
}

/// [`run_dist_loss_and_grad`] with an explicit storage/fabric precision —
/// the bf16-vs-f32 tolerance oracles in `precision_props` run through
/// this entry point.
#[allow(clippy::too_many_arguments)]
pub fn run_dist_loss_and_grad_prec(
    cfg: &ModelConfig,
    mesh: &Mesh,
    global_params: &[(String, Tensor)],
    x: &Tensor,
    y: &Tensor,
    backend: Arc<dyn Backend>,
    rollout: usize,
    precision: crate::tensor::Precision,
) -> Result<(f32, Vec<(String, Tensor)>)> {
    let mesh = *mesh;
    let net = Network::new(mesh.n());
    let mut handles = Vec::new();
    for r in 0..mesh.n() {
        let cfg = cfg.clone();
        let params = shard_params(&cfg, &mesh, r, global_params)?;
        let mut comm = net.endpoint(r);
        let backend = backend.clone();
        let (x, y) = (x.clone(), y.clone());
        handles.push(std::thread::spawn(move || -> Result<(f32, PStore)> {
            let model = DistModel::new(cfg, &mesh, r, params);
            let (la, ll, lc) = model.local_dims();
            let lat0 = model.lat_offset();
            let ch0 = model.ch_offset();
            let _ = ll;
            let xl = sample_shard(&x, (lat0, lat0 + la), (ch0, ch0 + lc));
            let yl = sample_shard(&y, (lat0, lat0 + la), (ch0, ch0 + lc));
            let mut ctx = Ctx::new(mesh, r, &mut comm, backend.as_ref());
            ctx.precision = precision;
            let (loss, grads) = model.loss_and_grad(&mut ctx, &xl, &yl, rollout)?;
            Ok((loss, grads))
        }));
    }
    let mut outs = Vec::new();
    for h in handles {
        outs.push(h.join().expect("rank panicked")?);
    }
    let loss = outs[0].0;
    let stores: Vec<&PStore> = outs.iter().map(|(_, g)| g).collect();
    Ok((loss, assemble_params(cfg, &stores)))
}

/// Execute the AOT oracle `loss_and_grad` (`ln_groups` must match the
/// mesh's channel split — the exported programs cover splits 1 and 2).
pub fn run_oracle_loss_and_grad(
    engine: &Engine,
    cfg: &ModelConfig,
    ln_groups: usize,
    global_params: &[(String, Tensor)],
    x: &Tensor,
    y: &Tensor,
) -> Result<(f32, Vec<(String, Tensor)>)> {
    let tag = match ln_groups {
        1 => "loss_and_grad".to_string(),
        2 => "loss_and_grad_g2".to_string(),
        n => {
            return Err(anyhow!(
                "no AOT oracle exported for ln_groups={n} (channel split); \
                 available: 1, 2"
            ))
        }
    };
    let mut inputs: Vec<Tensor> = global_params.iter().map(|(_, t)| t.clone()).collect();
    inputs.push(x.clone());
    inputs.push(y.clone());
    let outs = engine.run_program(&tag, inputs)?;
    let order = param_order(cfg);
    if outs.len() != order.len() + 1 {
        return Err(anyhow!(
            "oracle returned {} outputs, expected {}",
            outs.len(),
            order.len() + 1
        ));
    }
    let loss = outs[0].data[0];
    let grads = order
        .into_iter()
        .zip(outs.into_iter().skip(1))
        .collect();
    Ok((loss, grads))
}

/// Full oracle comparison for a preset/mesh (the `jigsaw validate`
/// command). The mesh's channel split selects the matching grouped-LN
/// oracle program.
pub fn validate_against_oracle(preset: &str, mesh: &Mesh) -> Result<OracleReport> {
    let dir = artifacts_dir();
    let cfg = ModelConfig::load(&dir, preset)?;
    let manifest = Manifest::load(&dir, preset)?;
    let engine = Engine::start(manifest)?;
    let backend: Arc<dyn Backend> = Arc::new(PjrtBackend { engine: engine.clone() });

    let global_params = init_global_params(&cfg, 0xBEEF);
    let mut rng = Rng::seed_from(0x5A11);
    let mut mk_sample = || {
        let mut d = vec![0.0; cfg.lat * cfg.lon * cfg.channels_padded];
        rng.fill_normal(&mut d, 1.0);
        Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d)
    };
    let x = mk_sample();
    let y = mk_sample();

    let (loss_o, grads_o) =
        run_oracle_loss_and_grad(&engine, &cfg, mesh.ch(), &global_params, &x, &y)?;
    let (loss_d, grads_d) =
        run_dist_loss_and_grad(&cfg, mesh, &global_params, &x, &y, backend, 1)?;

    let mut per_param_err = Vec::new();
    let mut max_err = 0.0f32;
    let mut worst = String::new();
    for ((n1, g1), (n2, g2)) in grads_o.iter().zip(&grads_d) {
        assert_eq!(n1, n2);
        let e = g1.max_abs_diff(g2);
        if e > max_err {
            max_err = e;
            worst = n1.clone();
        }
        per_param_err.push((n1.clone(), e));
    }
    Ok(OracleReport {
        preset: preset.to_string(),
        mesh: *mesh,
        loss_oracle: loss_o,
        loss_dist: loss_d,
        max_grad_err: max_err,
        worst_param: worst,
        per_param_err,
    })
}
