//! Distributed training loop: intra-group jigsaw model parallelism +
//! inter-group data parallelism (paper Sections 4.3 / 5 / 6.3.4).
//!
//! World layout: `world = dp * mesh.n()` ranks; global rank =
//! dp_idx * mesh.n() + mp_rank. Ranks with equal `r % mesh.n()` hold the
//! same parameter shard and form a DP gradient-reduction group — the
//! paper's rule, generalized to any `tok x ch` jigsaw mesh. Each rank
//! runs on its own thread over the simulated fabric; all heavy matmuls
//! go through the shared runtime backend.
//!
//! The DP gradient reduction runs *under* the backward pass: a
//! [`GradReduceScheduler`] receives each gradient tensor the moment the
//! backward pass finishes it (the `GradSink` hook through
//! `DistModel::loss_and_grad_with`), packs buckets in reverse-layer
//! order, and posts each bucket's non-blocking ring allreduce while
//! earlier layers are still differentiating. Each posted collective is
//! registered with a `comm::ProgressEngine` that the scheduler installs
//! as the rank's kernel-driver hook, so in-flight rings advance
//! *continuously* — between register-tile row groups of every matmul, at
//! the row-band barrier, and inside the `dist_matmul` dry-waits of the
//! remaining backward pass — not only when the next gradient happens to
//! be emitted. Before the optimizer step the scheduler drains: with most
//! ring hops already retired under compute, `finish` is a short tail
//! that polls the engine and unpacks each bucket the moment *it*
//! completes — no global barrier across buckets. The PR-4
//! emission-point-only behaviour survives as
//! [`GradReduceScheduler::new_emission_only`] (the §Progress bench
//! baseline), and the post-hoc path ([`dp_allreduce_grads`]) is retained
//! as the oracle; all three bucket in `PStore::grad_reduce_order` and
//! reduce through the same collective arithmetic, so their results are
//! bit-identical (pinned by `rust/tests/dp_overlap_props.rs` and
//! `rust/tests/progress_props.rs`).
//!
//! A failing rank thread no longer deadlocks the run: its closure
//! aborts both fabrics (waking any peer blocked in a receive), `train`
//! collects every rank's outcome, and the error names the rank that
//! actually failed rather than a secondary abort casualty. Abort
//! casualties are recognized *typed* — peers unwind with a
//! [`CommError::Aborted`] panic payload, not a string — so the
//! classification can't be fooled by error text, and the final error
//! carries a [`RankFailure`] marker that [`train_elastic`] downcasts to
//! drive recovery: tear both fabrics down, shrink the world (drop a DP
//! replica first, else [`Mesh::shrink_for`]), reload the newest valid
//! checkpoint, and keep training.
//!
//! Checkpointing (`TrainSpec::checkpoint`) rides the training loop:
//! every `every` steps each rank calls [`checkpoint::save_rank`] at the
//! same point in the step, which ends in a world barrier and an atomic
//! manifest publish — see the [`checkpoint`] module docs for the
//! crash-safety argument. Resume (`TrainSpec::resume`) reloads the
//! newest valid checkpoint, reshards it onto the (possibly different)
//! current mesh, and restores Adam moments, loss-scaler state, and each
//! DP group's loader cursor/RNG — making a resumed run bit-identical to
//! an uninterrupted run on the same mesh (pinned by
//! `rust/tests/checkpoint_props.rs`).
//!
//! Mixed precision (`TrainSpec::precision = Bf16`, CLI `--precision
//! bf16`): master weights, Adam state, and every accumulation stay f32;
//! activations quantize to bf16 at layer boundaries and every bulk
//! fabric payload (jigsaw mobile blocks, partial sums, DP ring chunks)
//! ships as 16-bit — half the bytes end to end. A [`GradScaler`]
//! applies dynamic loss scaling: gradients are packed pre-scaled into
//! the reduce buckets, unscaled together with the 1/dp mean, and a
//! per-step non-finite probe (agreed across the MP group — DP peers
//! hold bit-identical post-reduce shards, so group agreement is global
//! agreement) skips the optimizer step and halves the scale on
//! overflow, doubling it back after a run of good steps. Under the
//! default `F32` the scaler is inert (scale 1.0, no fabric probe) and
//! training is bit-identical to the pre-precision engine.

use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::checkpoint::{self, CheckpointSpec, GlobalState, RankSave};
use crate::comm::{
    Comm, CommError, Network, ProgressEngine, ProgressGuard, ProgressTicket,
};
use crate::config::ModelConfig;
use crate::data::{LoaderState, ShardedLoader};
use crate::jigsaw::{Ctx, DistMat, Mesh, MeshError};
use crate::model::dist::DistModel;
use crate::model::params::{shard_params, GradId, GradSink, PStore};
use crate::model::init_global_params;
use crate::optim::{Adam, LrSchedule};
use crate::runtime::Backend;
use crate::tensor::{Precision, Tensor};
use crate::util::rng::Rng;

/// Training-run specification.
#[derive(Clone, Debug)]
pub struct TrainSpec {
    /// jigsaw mesh shape of each model-parallel group
    pub mesh: Mesh,
    pub dp: usize,
    pub steps: usize,
    pub lr: f32,
    pub encdec_lr_factor: f32,
    /// dataset size (sample times per epoch)
    pub n_times: usize,
    /// forecast lead in time steps
    pub lead: usize,
    /// max randomized rollout length (1 = plain training; >1 enables the
    /// paper's randomized-rollout fine-tuning)
    pub max_rollout: usize,
    pub seed: u64,
    /// synthetic-atmosphere mode count (problem difficulty)
    pub n_modes: usize,
    /// validate every k steps (0 = never)
    pub val_every: usize,
    pub val_times: Vec<usize>,
    /// run the DP gradient reduce under the backward pass via the
    /// grad-ready scheduler (default); `false` falls back to the
    /// post-hoc [`dp_allreduce_grads`] oracle. Both produce bit-identical
    /// gradients — the switch exists for baselines and differential
    /// tests.
    pub overlap_dp: bool,
    /// storage/fabric precision (`--precision bf16`): bf16 activations at
    /// layer boundaries and 16-bit fabric payloads everywhere the mixed
    /// path ships data (jigsaw blocks, partial sums, DP ring chunks),
    /// with f32 master weights and f32 accumulation. `F32` (default)
    /// keeps training bit-identical to the pre-precision engine.
    pub precision: Precision,
    /// checkpoint destination + cadence (`--checkpoint-dir`,
    /// `--checkpoint-every`); `None` disables checkpointing entirely
    pub checkpoint: Option<CheckpointSpec>,
    /// start from the newest valid checkpoint under `checkpoint.dir`
    /// instead of from `seed` init (`--resume`); falls back to a fresh
    /// start when no valid checkpoint exists yet
    pub resume: bool,
}

impl TrainSpec {
    /// Quick spec from a total parallel degree (legacy `way` shorthand):
    /// the degree maps to its balanced mesh (2 -> 1x2, 4 -> 2x2, ...).
    /// An invalid degree (e.g. 0) is a typed [`MeshError`], not a panic.
    pub fn quick(way: usize, dp: usize, steps: usize) -> Result<Self, MeshError> {
        Ok(Self::with_mesh(Mesh::from_degree(way)?, dp, steps))
    }

    /// Quick spec from an explicit mesh shape.
    pub fn with_mesh(mesh: Mesh, dp: usize, steps: usize) -> Self {
        TrainSpec {
            mesh,
            dp,
            steps,
            lr: 1e-3,
            encdec_lr_factor: 1.0,
            n_times: 32,
            lead: 1,
            max_rollout: 1,
            seed: 0,
            n_modes: 12,
            val_every: 0,
            val_times: vec![40, 41, 42, 43],
            overlap_dp: true,
            precision: Precision::F32,
            checkpoint: None,
            resume: false,
        }
    }

    /// Model-parallel group size (the legacy "way").
    pub fn way(&self) -> usize {
        self.mesh.n()
    }
}

/// Per-step record (rank 0 of DP group 0's view).
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub lr: f32,
    pub rollout: usize,
    pub bytes_read: u64,
}

/// Result of a training run (`Debug` so `Result<TrainReport>` supports
/// `unwrap_err` in tests; the tensor payloads make full formatting
/// verbose — don't print one casually).
#[derive(Debug)]
pub struct TrainReport {
    pub steps: Vec<StepRecord>,
    pub val_loss: Vec<(usize, f32)>,
    /// per-channel validation RMSE at the final validation point
    pub final_val_rmse: Vec<f32>,
    /// total fabric bytes (jigsaw + DP traffic)
    pub comm_bytes: u64,
    /// final parameters, reassembled from MP group 0
    pub final_params: Vec<(String, Tensor)>,
    /// the checkpoint step this run resumed from (`None` = fresh start)
    pub resumed_from: Option<usize>,
}

/// Marker carried (as the anyhow source) by `train`'s rank-failure
/// error, naming the first rank whose failure was *not* a typed abort
/// casualty. [`train_elastic`] downcasts to it to decide recovery.
#[derive(Clone, Copy, Debug)]
pub struct RankFailure {
    pub dp: usize,
    pub mp: usize,
}

impl std::fmt::Display for RankFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "rank (dp {}, mp {}) failed", self.dp, self.mp)
    }
}

impl std::error::Error for RankFailure {}

/// Run distributed training. `backend` is shared by all rank threads.
/// With `spec.resume`, reloads the newest valid checkpoint under
/// `spec.checkpoint.dir` (resharding onto `spec.mesh` if it was saved
/// on a different mesh) and continues from its step; a missing or
/// empty checkpoint dir falls back to a fresh start.
pub fn train(
    cfg: &ModelConfig,
    spec: &TrainSpec,
    backend: Arc<dyn Backend>,
) -> Result<TrainReport> {
    let state = if spec.resume {
        let ck = spec
            .checkpoint
            .as_ref()
            .ok_or_else(|| anyhow!("resume requested without a checkpoint dir"))?;
        match checkpoint::latest(&ck.dir)? {
            Some(meta) => Some(checkpoint::load_state(cfg, &meta)?),
            None => None,
        }
    } else {
        None
    };
    train_from_state(cfg, spec, backend, state)
}

/// [`train`] from an explicit (possibly reloaded) global state. The
/// state is mesh-free — this is where resharding happens: parameters
/// and Adam moments are sharded onto `spec.mesh` regardless of the mesh
/// they were saved on.
pub fn train_from_state(
    cfg: &ModelConfig,
    spec: &TrainSpec,
    backend: Arc<dyn Backend>,
    state: Option<GlobalState>,
) -> Result<TrainReport> {
    let mesh = spec.mesh;
    mesh.validate_config(cfg)
        .with_context(|| format!("mesh {mesh} does not fit model '{}'", cfg.name))?;
    if let Some(st) = &state {
        if st.meta.precision != spec.precision {
            bail!(
                "checkpoint at step {} was saved with precision {}, refusing to resume at {}",
                st.meta.step,
                st.meta.precision,
                spec.precision
            );
        }
    }
    let mp = mesh.n();
    let world = mp * spec.dp;
    // one fabric for jigsaw traffic per MP group + one global for DP
    let mp_nets: Vec<Network> = (0..spec.dp).map(|_| Network::new(mp)).collect();
    let dp_net = Network::new(world);

    let global_params = match &state {
        Some(st) => st.params.clone(),
        None => init_global_params(cfg, spec.seed),
    };
    let resumed_from = state.as_ref().map(|st| st.meta.step);

    let mut handles = Vec::new();
    for g in 0..spec.dp {
        for r in 0..mp {
            let cfg = cfg.clone();
            let spec = spec.clone();
            let backend = backend.clone();
            let mut mp_comm = mp_nets[g].endpoint(r);
            let mut dp_comm = dp_net.endpoint(g * mp + r);
            let params = shard_params(&cfg, &mesh, r, &global_params)?;
            let init = match &state {
                Some(st) => {
                    // reshard the assembled Adam moments onto this mesh;
                    // moment stores carry no device-cache identity
                    let mut m = shard_params(&cfg, &mesh, r, &st.m)?;
                    let mut v = shard_params(&cfg, &mesh, r, &st.v)?;
                    for dm in m.mats.values_mut().chain(v.mats.values_mut()) {
                        dm.cache = None;
                    }
                    RankInit {
                        start_step: st.meta.step,
                        adam: Some((m, v, st.meta.adam_step)),
                        scaler: Some((st.meta.scaler_scale, st.meta.scaler_good_steps)),
                        // a DP group beyond the saved dp degree starts a
                        // fresh loader stream (its seed is new anyway)
                        loader: st.loaders.get(g).cloned(),
                    }
                }
                None => RankInit { start_step: 0, adam: None, scaler: None, loader: None },
            };
            let mp_net = mp_nets[g].clone();
            let dp_net = dp_net.clone();
            handles.push(std::thread::spawn(move || -> Result<RankOutput> {
                // catch panics so a dying rank can abort both fabrics —
                // otherwise peers block forever in `recv` and the join
                // loop below deadlocks
                let out =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        rank_main(
                            cfg, spec, g, r, params, init, backend, &mut mp_comm,
                            &mut dp_comm,
                        )
                    }))
                    .unwrap_or_else(|p| Err(rank_panic_error(&p)));
                if out.is_err() {
                    // record this rank as the abort origin (first writer
                    // wins, so a secondary casualty can't displace the
                    // true failer on an already-aborted fabric)
                    mp_net.abort_from(r);
                    dp_net.abort_from(g * mp + r);
                }
                out
            }));
        }
    }
    let mut outs: Vec<RankOutput> = Vec::new();
    let mut failures: Vec<(usize, usize, anyhow::Error)> = Vec::new();
    for (i, h) in handles.into_iter().enumerate() {
        let (g, r) = (i / mp, i % mp);
        match h.join() {
            Ok(Ok(out)) => outs.push(out),
            Ok(Err(e)) => failures.push((g, r, e)),
            // unreachable in practice (the closure catches), but a panic
            // between catch_unwind and return must not poison the report
            Err(p) => failures.push((g, r, rank_panic_error(&p))),
        }
    }
    if !failures.is_empty() {
        // secondary casualties unwound with a typed CommError::Aborted;
        // report the rank that actually failed. Other CommError kinds
        // (e.g. a detector-proven Deadlock) are primary findings, not
        // casualties.
        let n = failures.len();
        let idx = failures
            .iter()
            .position(|(_, _, e)| {
                !matches!(e.downcast_ref::<CommError>(), Some(CommError::Aborted { .. }))
            })
            .unwrap_or(0);
        let (pg, pr, pe) = failures.swap_remove(idx);
        return Err(anyhow::Error::new(RankFailure { dp: pg, mp: pr }).context(format!(
            "rank (dp {pg}, mp {pr}) failed: {pe:#} ({n}/{world} rank threads failed)"
        )));
    }
    let comm_bytes: u64 =
        mp_nets.iter().map(|n| n.total_bytes()).sum::<u64>() + dp_net.total_bytes();

    // reassemble final params from MP group 0
    let group0: Vec<&PStore> = outs[..mp].iter().map(|o| &o.params).collect();
    let final_params = crate::model::params::assemble_params(cfg, &group0);

    let r0 = &outs[0];
    Ok(TrainReport {
        steps: r0.steps.clone(),
        val_loss: r0.val_loss.clone(),
        final_val_rmse: r0.final_val_rmse.clone(),
        comm_bytes,
        final_params,
        resumed_from,
    })
}

/// Typed conversion of a rank thread's panic payload: a fabric-abort
/// unwind keeps its [`CommError`] identity (so the join loop can
/// classify it), anything else becomes an opaque panic report.
fn rank_panic_error(p: &(dyn std::any::Any + Send)) -> anyhow::Error {
    match CommError::from_panic(p) {
        Some(ce) => anyhow::Error::new(ce),
        None => anyhow!("rank thread panicked: {}", panic_message(p)),
    }
}

/// One recovery round taken by [`train_elastic`].
#[derive(Clone, Debug)]
pub struct RecoveryEvent {
    /// the failure that triggered this round, rendered
    pub failure: String,
    pub from_mesh: Mesh,
    pub from_dp: usize,
    pub to_mesh: Mesh,
    pub to_dp: usize,
    /// checkpoint step resumed from (`None` = no checkpoint existed
    /// yet; the shrunken world restarted from step 0)
    pub resumed_step: Option<usize>,
}

/// [`train`] result plus the recovery rounds it took to get there.
#[derive(Debug)]
pub struct ElasticReport {
    pub report: TrainReport,
    pub recoveries: Vec<RecoveryEvent>,
}

/// Elastic training: run [`train`], and on a typed rank failure shrink
/// the world and resume from the newest valid checkpoint instead of
/// giving up. The shrink policy drops a data-parallel replica first
/// (cheapest — no resharding of the surviving groups' layout), and only
/// when `dp == 1` shrinks the jigsaw mesh itself via
/// [`Mesh::shrink_for`]. Non-failure errors (bad spec, corrupt
/// checkpoint) and failures past `max_recoveries` propagate unchanged.
///
/// Fabric teardown is structural: `train` joins every rank thread
/// before returning its error, and both `Network`s drop with it, so
/// each retry starts on fresh fabrics.
pub fn train_elastic(
    cfg: &ModelConfig,
    spec: &TrainSpec,
    backend: Arc<dyn Backend>,
    max_recoveries: usize,
) -> Result<ElasticReport> {
    let mut spec = spec.clone();
    let mut recoveries: Vec<RecoveryEvent> = Vec::new();
    loop {
        match train(cfg, &spec, backend.clone()) {
            Ok(report) => return Ok(ElasticReport { report, recoveries }),
            Err(e) => {
                if e.downcast_ref::<RankFailure>().is_none() {
                    return Err(e);
                }
                let Some(ck) = spec.checkpoint.clone() else {
                    return Err(e.context(
                        "rank failed with no checkpointing configured; nothing to resume from",
                    ));
                };
                if recoveries.len() >= max_recoveries {
                    return Err(e.context(format!(
                        "rank failed after {} recoveries (limit {max_recoveries})",
                        recoveries.len()
                    )));
                }
                let (to_mesh, to_dp) = if spec.dp > 1 {
                    (spec.mesh, spec.dp - 1)
                } else {
                    match Mesh::shrink_for(cfg, spec.mesh.n()) {
                        Ok(m) => (m, 1),
                        Err(_) => {
                            return Err(e.context(
                                "rank failed on the smallest viable mesh; cannot shrink further",
                            ))
                        }
                    }
                };
                let resumed_step = checkpoint::latest(&ck.dir)?.map(|m| m.step);
                recoveries.push(RecoveryEvent {
                    failure: format!("{e:#}"),
                    from_mesh: spec.mesh,
                    from_dp: spec.dp,
                    to_mesh,
                    to_dp,
                    resumed_step,
                });
                spec.mesh = to_mesh;
                spec.dp = to_dp;
                spec.resume = true;
            }
        }
    }
}

/// Per-rank restored state handed to `rank_main` (all `None`/zero on a
/// fresh start).
struct RankInit {
    start_step: usize,
    /// resharded Adam moments + step counter
    adam: Option<(PStore, PStore, u64)>,
    /// (scale, good_steps) of the saved loss scaler
    scaler: Option<(f32, usize)>,
    loader: Option<LoaderState>,
}

struct RankOutput {
    steps: Vec<StepRecord>,
    val_loss: Vec<(usize, f32)>,
    final_val_rmse: Vec<f32>,
    params: PStore,
}

#[allow(clippy::too_many_arguments)]
fn rank_main(
    cfg: ModelConfig,
    spec: TrainSpec,
    dp_idx: usize,
    mp_rank: usize,
    params: PStore,
    init: RankInit,
    backend: Arc<dyn Backend>,
    mp_comm: &mut crate::comm::Comm,
    dp_comm: &mut crate::comm::Comm,
) -> Result<RankOutput> {
    let mesh = spec.mesh;
    let mut model = DistModel::new(cfg.clone(), &mesh, mp_rank, params);
    let mut loader = ShardedLoader::new(
        &cfg,
        &mesh,
        mp_rank,
        spec.n_times,
        spec.lead,
        spec.seed ^ (0xD1 + dp_idx as u64) << 8, // distinct per DP group
        spec.n_modes,
    )?;
    if let Some(ls) = &init.loader {
        loader.restore_state(ls);
    }
    let mut adam = match init.adam {
        Some((m, v, astep)) => Adam::from_state(m, v, astep, spec.lr),
        None => Adam::new(&model.params, spec.lr),
    };
    adam.encdec_lr_factor = spec.encdec_lr_factor;
    let sched = LrSchedule::paper(spec.lr, spec.n_times.max(1), 100);

    let mp_group = mesh.ranks();
    let dp_group = mesh.dp_group(spec.dp, mp_rank);
    let world_group: Vec<usize> = (0..spec.dp * mesh.n()).collect();

    let mut steps = Vec::new();
    let mut val_loss = Vec::new();
    let mut final_val_rmse = Vec::new();
    let mut scaler = GradScaler::new(spec.precision);
    if let Some((sc, good)) = init.scaler {
        scaler.restore(sc, good);
    }

    for step in init.start_step..spec.steps {
        // randomized rollout length, shared across *all* ranks by seed
        let rollout = if spec.max_rollout > 1 {
            let mut r = Rng::seed_from(spec.seed ^ 0x5EED ^ step as u64);
            1 + r.below(spec.max_rollout)
        } else {
            1
        };
        let item = loader.next_item();
        let mut ctx = Ctx::new(mesh, mp_rank, mp_comm, backend.as_ref());
        ctx.precision = spec.precision;
        let scale = scaler.scale();
        let (loss, grads) = if spec.dp > 1 && spec.overlap_dp {
            // grad-ready DP reduction (paper 4.3 / 6.3.4): bucket rings
            // launch while the backward pass still differentiates; the
            // drain below waits on outstanding buckets before Adam
            let mut sched = GradReduceScheduler::new_scaled(
                &mut *dp_comm,
                &dp_group,
                DP_BUCKET_ELEMS,
                scale,
                spec.precision,
            );
            let (loss, mut grads) = model.loss_and_grad_with(
                &mut ctx, &item.x, &item.y, rollout, &mut sched,
            )?;
            sched.finish(&mut grads);
            grads.scale_all(1.0 / (scale * spec.dp as f32));
            (loss, grads)
        } else {
            let (loss, mut grads) =
                model.loss_and_grad(&mut ctx, &item.x, &item.y, rollout)?;
            // post-hoc DP gradient reduction (the oracle/baseline path)
            if spec.dp > 1 {
                if scale != 1.0 {
                    grads.scale_all(scale);
                }
                dp_allreduce_grads_prec(
                    &mut grads,
                    dp_comm,
                    &dp_group,
                    spec.precision,
                );
                grads.scale_all(1.0 / (scale * spec.dp as f32));
            }
            (loss, grads)
        };

        // dynamic loss scaling (bf16): the group agrees on overflow, so
        // every rank skips (or takes) the step together. f32 mode keeps
        // the probe off the fabric entirely.
        let take_step = if scaler.active() {
            let flag = if grads.has_non_finite() { 1.0 } else { 0.0 };
            let nf = ctx.comm.allreduce_scalar(&mp_group, flag);
            scaler.update(nf > 0.0)
        } else {
            true
        };

        let lr = sched.at(step);
        if take_step {
            // global-norm clip (identical on every rank)
            let clip = Adam::clip_scale(&grads, ctx.comm, &mp_group);
            adam.lr = lr;
            adam.update(&mut model.params, &grads, clip);
        }

        if dp_idx == 0 && mp_rank == 0 {
            steps.push(StepRecord {
                step,
                loss,
                lr,
                rollout,
                bytes_read: item.bytes_read,
            });
        }

        // validation
        let at_val = spec.val_every > 0
            && (step % spec.val_every == spec.val_every - 1 || step + 1 == spec.steps);
        if at_val {
            let (vl, rmse) = validate(&model, &mut loader, &spec, mp_comm, &backend)?;
            if dp_idx == 0 && mp_rank == 0 {
                val_loss.push((step, vl));
                final_val_rmse = rmse;
            }
        }

        // sharded checkpoint: every rank calls save_rank at the same
        // step (it ends in a world barrier); the cadence is spec-driven,
        // so ranks can't disagree on whether a step checkpoints
        if let Some(ck) = &spec.checkpoint {
            if ck.every > 0 && (step + 1) % ck.every == 0 {
                let save = RankSave {
                    mesh: &mesh,
                    dp: spec.dp,
                    dp_idx,
                    mp_rank,
                    precision: spec.precision,
                    step: step + 1,
                    adam_step: adam.step,
                    lr: spec.lr,
                    encdec_lr_factor: spec.encdec_lr_factor,
                    scaler: scaler.state(),
                    config_name: &cfg.name,
                    config_hash: cfg.content_hash(),
                    params: &model.params,
                    m: &adam.m,
                    v: &adam.v,
                    loader: loader.state(),
                };
                checkpoint::save_rank(ck, &save, dp_comm, &world_group)
                    .with_context(|| format!("checkpoint at step {}", step + 1))?;
            }
        }
    }

    Ok(RankOutput { steps, val_loss, final_val_rmse, params: model.params })
}

/// Validation over the held-out times: group-reduced loss + per-channel
/// latitude-weighted RMSE.
fn validate(
    model: &DistModel,
    loader: &mut ShardedLoader,
    spec: &TrainSpec,
    mp_comm: &mut crate::comm::Comm,
    backend: &Arc<dyn Backend>,
) -> Result<(f32, Vec<f32>)> {
    let cfg = &model.cfg;
    let group = model.mesh.ranks();
    let mut loss_acc = 0.0f32;
    let mut sse = Tensor::zeros(&[cfg.channels_padded]);
    let wlat = crate::model::latitude_weights(cfg.lat);
    let (lat0, ch0) = (model.lat_offset(), model.ch_offset());
    for &t in &spec.val_times {
        let (x, _) = loader.read_shard(t as f32);
        let (y, _) = loader.read_shard((t + spec.lead) as f32);
        let mut ctx = Ctx::new(model.mesh, model.rank, mp_comm, backend.as_ref());
        ctx.precision = spec.precision;
        let (pred, _) = model.forward(&mut ctx, &x, 1)?;
        loss_acc += model.local_loss(&pred, &y);
        let (lat_l, lon_l, c_l) = model.local_dims();
        for li in 0..lat_l {
            for lj in 0..lon_l {
                for c in 0..c_l {
                    let idx = (li * lon_l + lj) * c_l + c;
                    let e = pred.data[idx] - y.data[idx];
                    sse.data[ch0 + c] += wlat[lat0 + li] * e * e;
                }
            }
        }
    }
    let loss =
        mp_comm.allreduce_scalar(&group, loss_acc) / spec.val_times.len() as f32;
    let sse = mp_comm.allreduce_sum(&group, &sse);
    let denom = (cfg.lat * cfg.lon * spec.val_times.len()) as f32;
    let rmse = sse.data.iter().map(|s| (s / denom).sqrt()).collect();
    Ok((loss, rmse))
}

/// Dynamic loss scaling for the bf16 path. Gradients are multiplied by
/// `scale` before they cross the DP fabric in 16 bits (lifting small
/// values out of bf16's underflow range) and divided back out — together
/// with the DP mean — after the reduce. Scales are powers of two, so in
/// f32 the multiply/divide pair is exact and only the wire quantization
/// differs from an unscaled run.
///
/// Backoff protocol (the standard AMP loop): if any rank sees a
/// non-finite gradient after the reduce, every rank halves the scale and
/// skips the optimizer step; after `growth_interval` consecutive good
/// steps the scale doubles, up to `max_scale`. In `F32` mode the scaler
/// is inert: `scale()` is 1, [`active`](GradScaler::active) is false,
/// and the trainer never probes for overflow — the f32 step stays
/// bit-identical to the pre-precision engine.
#[derive(Clone, Debug)]
pub struct GradScaler {
    scale: f32,
    enabled: bool,
    good_steps: usize,
    pub growth_interval: usize,
    pub min_scale: f32,
    pub max_scale: f32,
}

impl GradScaler {
    /// Scaler for a precision policy: active (scale 2^14) under `Bf16`,
    /// inert under `F32`.
    pub fn new(prec: Precision) -> Self {
        let enabled = prec == Precision::Bf16;
        GradScaler {
            scale: if enabled { 16384.0 } else { 1.0 },
            enabled,
            good_steps: 0,
            growth_interval: 200,
            min_scale: 1.0,
            max_scale: 65536.0,
        }
    }

    /// Current loss scale (1.0 when inert).
    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Whether the trainer should probe for overflow and call
    /// [`update`](GradScaler::update) each step.
    pub fn active(&self) -> bool {
        self.enabled
    }

    /// Resumable state: (current scale, good-step streak). Checkpoint
    /// manifests persist it so a resumed bf16 run continues the exact
    /// backoff/growth trajectory.
    pub fn state(&self) -> (f32, usize) {
        (self.scale, self.good_steps)
    }

    /// Restore a captured [`state`](GradScaler::state). A no-op when
    /// inert (f32 mode pins scale 1.0 regardless of what a — possibly
    /// bf16-saved — checkpoint recorded).
    pub fn restore(&mut self, scale: f32, good_steps: usize) {
        if self.enabled {
            self.scale = scale.clamp(self.min_scale, self.max_scale);
            self.good_steps = good_steps;
        }
    }

    /// Fold in one step's (group-agreed) overflow verdict. Returns
    /// whether the optimizer step should be taken: `false` means the
    /// gradients are non-finite, the scale has been halved, and the step
    /// must be skipped so training resumes cleanly at the smaller scale.
    pub fn update(&mut self, found_overflow: bool) -> bool {
        if !self.enabled {
            return !found_overflow;
        }
        if found_overflow {
            self.scale = (self.scale * 0.5).max(self.min_scale);
            self.good_steps = 0;
            false
        } else {
            self.good_steps += 1;
            if self.good_steps >= self.growth_interval {
                self.good_steps = 0;
                self.scale = (self.scale * 2.0).min(self.max_scale);
            }
            true
        }
    }
}

/// Default DP gradient bucket size, in f32 elements (1 MiB). Large enough
/// to amortize collective latency, small enough that the first ring
/// starts while most of the packing (and, on a real fabric, most of the
/// backward pass) is still in flight.
pub const DP_BUCKET_ELEMS: usize = 1 << 18;

/// Allreduce every grad shard across a DP group, bucketed: gradient
/// tensors are packed into flat buckets and each bucket is ring-reduced
/// as soon as it fills, instead of issuing one latency-bound collective
/// per parameter block. Because sends are non-blocking, bucket i's ring
/// traffic is in flight while bucket i+1 is still being packed — the
/// overlap-friendly shape the paper's Section 4.3 DP reduction wants.
pub fn dp_allreduce_grads(
    grads: &mut PStore,
    dp_comm: &mut crate::comm::Comm,
    group: &[usize],
) {
    dp_allreduce_grads_bucketed(grads, dp_comm, group, DP_BUCKET_ELEMS)
}

/// [`dp_allreduce_grads`] under a wire-precision policy: bf16 ships the
/// bucket rings' chunks in 16 bits (f32 accumulation at each hop).
pub fn dp_allreduce_grads_prec(
    grads: &mut PStore,
    dp_comm: &mut crate::comm::Comm,
    group: &[usize],
    prec: Precision,
) {
    dp_allreduce_grads_bucketed_prec(grads, dp_comm, group, DP_BUCKET_ELEMS, prec)
}

/// Bucketed DP gradient allreduce with an explicit bucket size (elements).
/// All ranks of `group` must use the same size; every bucket holds at
/// least one tensor, so a tensor larger than `bucket_elems` still
/// reduces, alone in its own bucket. Tensors are packed in the stable
/// `PStore::grad_reduce_order` — the same order (and therefore the same
/// bucket boundaries) the grad-ready scheduler emits, which is what
/// makes this the bit-exact oracle for the overlapped path.
pub fn dp_allreduce_grads_bucketed(
    grads: &mut PStore,
    dp_comm: &mut crate::comm::Comm,
    group: &[usize],
    bucket_elems: usize,
) {
    dp_allreduce_grads_bucketed_prec(grads, dp_comm, group, bucket_elems, Precision::F32)
}

/// [`dp_allreduce_grads_bucketed`] under a wire-precision policy.
pub fn dp_allreduce_grads_bucketed_prec(
    grads: &mut PStore,
    dp_comm: &mut crate::comm::Comm,
    group: &[usize],
    bucket_elems: usize,
    prec: Precision,
) {
    if group.len() <= 1 {
        return;
    }
    let bucket_elems = bucket_elems.max(1);
    let mut entries = grads.grad_tensors_reduce_order_mut();
    let mut start = 0usize;
    while start < entries.len() {
        let mut end = start;
        let mut elems = 0usize;
        while end < entries.len()
            && (end == start || elems + entries[end].numel() <= bucket_elems)
        {
            elems += entries[end].numel();
            end += 1;
        }
        dp_comm.allreduce_packed_prec(group, &mut entries[start..end], prec);
        start = end;
    }
}

/// Grad-ready DP reduce scheduler: the [`GradSink`] the trainer hands to
/// `DistModel::loss_and_grad_with`. As the backward pass emits finished
/// gradient tensors (reverse-layer order), they are packed into flat
/// buckets; the moment a bucket fills, its non-blocking ring allreduce
/// ([`Comm::allreduce_start`]) is posted on the DP fabric and registered
/// with a [`ProgressEngine`] — so bucket 0's ring traffic is in flight
/// while earlier layers are still differentiating, the overlap behind
/// the paper's Section 6.3.4 scaling efficiency.
///
/// [`new`](GradReduceScheduler::new) installs the engine as the rank's
/// kernel-driver hook for the scheduler's lifetime: posted rings advance
/// during every subsequent matmul (between register-tile row groups and
/// at the row-band barrier) and inside every blocking fabric wait of the
/// remaining backward pass, not only when the next gradient is emitted.
/// [`new_emission_only`](GradReduceScheduler::new_emission_only) skips
/// the hook — the PR-4 baseline that polls at emission points and in the
/// drain only, retained for the §Progress bench and differential tests.
///
/// Bucket boundaries use the same greedy rule, over the same stable
/// tensor order, as the post-hoc [`dp_allreduce_grads_bucketed`]
/// oracle, and the in-flight collectives share the blocking
/// collectives' arithmetic exactly — the reduced gradients are
/// bit-identical to the oracle's (and across both polling modes),
/// independent of fabric timing.
///
/// `finish` drains before the optimizer step: every outstanding bucket
/// is polled concurrently and unpacked into the gradient store the
/// moment *it* completes (no barrier across buckets), with
/// [`Comm::wait_any_ready`] parking the thread only when no bucket can
/// advance. With the engine hook the drain is a short tail — most hops
/// already retired under backward compute.
pub struct GradReduceScheduler<'a> {
    comm: &'a mut Comm,
    group: Vec<usize>,
    bucket_elems: usize,
    /// loss scale applied while packing (exact in f32 for powers of two);
    /// 1.0 packs by memcpy, keeping the f32 path bit-identical
    scale: f32,
    /// wire precision of the posted bucket rings
    prec: Precision,
    cur_ids: Vec<(GradId, usize)>,
    cur_data: Vec<f32>,
    buckets: Vec<Bucket>,
    engine: ProgressEngine,
    /// present in engine-driven mode: keeps the kernel-driver hook
    /// pointed at `engine` until the scheduler goes away (restored even
    /// on an abort unwind)
    _hook: Option<ProgressGuard>,
}

struct Bucket {
    ids: Vec<(GradId, usize)>,
    ticket: ProgressTicket,
    /// reduced payload already unpacked into the store
    done: bool,
}

impl<'a> GradReduceScheduler<'a> {
    /// Engine-driven scheduler (the trainer default): in-flight bucket
    /// rings advance from inside the kernel driver and every blocking
    /// wait, for the scheduler's whole lifetime.
    pub fn new(comm: &'a mut Comm, group: &[usize], bucket_elems: usize) -> Self {
        Self::with_engine_hook(comm, group, bucket_elems, true, 1.0, Precision::F32)
    }

    /// Engine-driven scheduler with a loss scale and wire precision —
    /// the bf16 trainer path: packed gradients are multiplied by `scale`
    /// (the caller divides it back out after `finish`) and the bucket
    /// rings ship their chunks at `prec`.
    pub fn new_scaled(
        comm: &'a mut Comm,
        group: &[usize],
        bucket_elems: usize,
        scale: f32,
        prec: Precision,
    ) -> Self {
        Self::with_engine_hook(comm, group, bucket_elems, true, scale, prec)
    }

    /// Emission-only scheduler: rings advance only when the backward
    /// pass emits a tensor (and in the drain) — the PR-4 behaviour, kept
    /// as the §Progress drain-tail baseline.
    pub fn new_emission_only(
        comm: &'a mut Comm,
        group: &[usize],
        bucket_elems: usize,
    ) -> Self {
        Self::with_engine_hook(comm, group, bucket_elems, false, 1.0, Precision::F32)
    }

    fn with_engine_hook(
        comm: &'a mut Comm,
        group: &[usize],
        bucket_elems: usize,
        hook: bool,
        scale: f32,
        prec: Precision,
    ) -> Self {
        let engine = ProgressEngine::new(comm);
        let _hook = hook.then(|| engine.install());
        GradReduceScheduler {
            comm,
            group: group.to_vec(),
            bucket_elems: bucket_elems.max(1),
            scale,
            prec,
            cur_ids: Vec::new(),
            cur_data: pack_buf(bucket_elems),
            buckets: Vec::new(),
            engine,
            _hook,
        }
    }

    /// Number of bucket collectives posted so far (benches/tests).
    pub fn buckets_started(&self) -> usize {
        self.buckets.len()
    }

    fn push(&mut self, id: GradId, t: &Tensor) {
        if self.group.len() <= 1 {
            return;
        }
        // same greedy boundary rule as the post-hoc oracle: never split a
        // tensor; an oversized tensor rides alone in its own bucket
        if !self.cur_ids.is_empty()
            && self.cur_data.len() + t.numel() > self.bucket_elems
        {
            self.seal();
        }
        self.cur_ids.push((id, t.numel()));
        if self.scale != 1.0 {
            self.cur_data.extend(t.data.iter().map(|x| x * self.scale));
        } else {
            self.cur_data.extend_from_slice(&t.data);
        }
        if self.cur_data.len() >= self.bucket_elems {
            self.seal();
        }
        // emission-point progress on everything already in flight (the
        // engine-driven mode additionally polls throughout the compute
        // between emissions, via the installed hook)
        self.engine.poll();
    }

    /// Post the current bucket's collective, register it with the
    /// progress engine, and start a fresh bucket. Pack buffers come from
    /// the tensor pool (and flow back via the drain's `recycle`), so
    /// steady-state steps reallocate nothing.
    fn seal(&mut self) {
        if self.cur_ids.is_empty() {
            return;
        }
        let data =
            std::mem::replace(&mut self.cur_data, pack_buf(self.bucket_elems));
        let ids = std::mem::take(&mut self.cur_ids);
        let payload = Tensor::new(vec![data.len()], data);
        let coll = self.comm.allreduce_start_prec(&self.group, payload, self.prec);
        let ticket = self.engine.register(coll);
        self.buckets.push(Bucket { ids, ticket, done: false });
    }

    /// Drain every outstanding bucket and write the reduced gradients
    /// back into `grads` — the wait-before-Adam step.
    pub fn finish(self, grads: &mut PStore) {
        let _ = self.finish_timed(grads);
    }

    /// [`finish`](GradReduceScheduler::finish), returning the wall-clock
    /// the drain actually took — the exposed tail the §Progress bench
    /// sizes against the emission-only baseline. Buckets unpack
    /// individually as they complete; the thread sleeps only when no
    /// in-flight collective can make progress.
    pub fn finish_timed(mut self, grads: &mut PStore) -> std::time::Duration {
        let t0 = std::time::Instant::now();
        if self.group.len() <= 1 {
            return t0.elapsed();
        }
        self.seal();
        // the post-seal pack buffer is unused from here on
        crate::tensor::pool::put(std::mem::take(&mut self.cur_data));
        debug_assert_eq!(
            self.buckets
                .iter()
                .flat_map(|b| b.ids.iter().map(|(id, _)| id.clone()))
                .collect::<Vec<_>>(),
            grads.grad_reduce_order(),
            "grad emission diverged from the stable reduce order"
        );
        loop {
            self.engine.poll();
            let mut open = false;
            for b in self.buckets.iter_mut().filter(|b| !b.done) {
                if let Some(reduced) = self.engine.try_take(&b.ticket) {
                    unpack_bucket(&b.ids, &reduced, grads);
                    reduced.recycle();
                    b.done = true;
                } else {
                    open = true;
                }
            }
            if !open {
                break;
            }
            let waiting = self.engine.awaited();
            if !waiting.is_empty() {
                // hook-aware wait: in engine mode this keeps polling the
                // engine between bounded sleeps (see Comm::await_any)
                self.comm.wait_any_ready(&waiting);
            }
        }
        t0.elapsed()
    }
}

impl Drop for GradReduceScheduler<'_> {
    /// Abort-unwind hygiene: the pack buffer returns to the pool (the
    /// engine's in-flight bucket payloads recycle via
    /// `PackedAllreduce`'s own drop, and the installed hook is restored
    /// by the guard), so a failed rank leaks nothing it took.
    fn drop(&mut self) {
        crate::tensor::pool::put(std::mem::take(&mut self.cur_data));
    }
}

impl GradSink for GradReduceScheduler<'_> {
    fn mat_ready(&mut self, name: &str, mat: &DistMat) {
        for (k, b) in &mat.blocks {
            self.push(GradId::Mat(name.to_string(), *k), b);
        }
    }

    fn vec_ready(&mut self, name: &str, v: &Tensor) {
        self.push(GradId::Vec(name.to_string()), v);
    }
}

/// Pooled, emptied pack buffer with capacity for one full bucket, so
/// per-bucket packing never pays doubling reallocations. Capped at the
/// default bucket size: callers may pass huge `bucket_elems` sentinels
/// (e.g. usize::MAX in tests) that must not translate into allocations.
fn pack_buf(bucket_elems: usize) -> Vec<f32> {
    let mut buf = crate::tensor::pool::take(bucket_elems.max(1).min(DP_BUCKET_ELEMS));
    buf.clear();
    buf
}

/// Scatter one reduced bucket payload back into the gradient store.
fn unpack_bucket(ids: &[(GradId, usize)], reduced: &Tensor, grads: &mut PStore) {
    let mut off = 0usize;
    for (id, numel) in ids {
        let dst = match id {
            GradId::Mat(name, key) => grads
                .mats
                .get_mut(name)
                .and_then(|m| m.blocks.get_mut(key))
                .expect("bucket id names a matrix block absent from the store"),
            GradId::Vec(name) => grads
                .vecs
                .get_mut(name)
                .map(|v| &mut v.local)
                .expect("bucket id names a vector absent from the store"),
        };
        dst.data.copy_from_slice(&reduced.data[off..off + numel]);
        off += numel;
    }
    debug_assert_eq!(off, reduced.numel(), "bucket payload size mismatch");
}

/// Best-effort panic payload text (rank threads report through this).
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else if let Some(ce) = CommError::from_panic(p) {
        ce.to_string()
    } else {
        "opaque panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::NativeBackend;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            lat: 8,
            lon: 16,
            channels: 6,
            channels_padded: 8,
            patch: 2,
            d_emb: 32,
            d_tok: 48,
            d_ch: 32,
            blocks: 2,
            tokens: 32,
            patch_dim: 32,
            param_count: 12904,
            flops_forward: 0,
            channel_weights: vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0],
        }
    }

    #[test]
    fn one_way_training_reduces_loss() {
        let spec = TrainSpec::quick(1, 1, 30).unwrap();
        let report = train(&cfg(), &spec, Arc::new(NativeBackend)).unwrap();
        let first = report.steps.first().unwrap().loss;
        let last = report.steps.last().unwrap().loss;
        assert!(last < first * 0.8, "loss {first} -> {last}");
    }

    #[test]
    fn two_way_matches_one_way_loss_trajectory_start() {
        // identical params + same sample order -> identical first-step loss
        // (LN stats differ between ways, so compare within tolerance)
        let c = cfg();
        let s1 = TrainSpec::quick(1, 1, 2).unwrap();
        let s2 = TrainSpec::quick(2, 1, 2).unwrap();
        let r1 = train(&c, &s1, Arc::new(NativeBackend)).unwrap();
        let r2 = train(&c, &s2, Arc::new(NativeBackend)).unwrap();
        let a = r1.steps[0].loss;
        let b = r2.steps[0].loss;
        assert!(
            (a - b).abs() / a.max(1e-6) < 0.3,
            "first-step losses far apart: {a} vs {b}"
        );
    }

    #[test]
    fn dp_training_runs_and_reduces() {
        let spec = TrainSpec::quick(2, 2, 6).unwrap();
        let report = train(&cfg(), &spec, Arc::new(NativeBackend)).unwrap();
        assert_eq!(report.steps.len(), 6);
        assert!(report.comm_bytes > 0);
    }

    #[test]
    fn eight_way_mesh_trains_end_to_end() {
        // the generalized regime the hand-written layouts could not reach:
        // a 2x4 mesh (8-way jigsaw) over the thread fabric
        let spec = TrainSpec::with_mesh(Mesh::new(2, 4).unwrap(), 1, 10);
        let report = train(&cfg(), &spec, Arc::new(NativeBackend)).unwrap();
        let first = report.steps.first().unwrap().loss;
        let last = report.steps.last().unwrap().loss;
        assert!(last < first, "8-way loss {first} -> {last}");
        assert!(report.comm_bytes > 0);
    }

    #[test]
    fn incompatible_mesh_is_a_clean_error() {
        // channels_padded = 8 cannot split 5 ways: typed error, no panic
        let spec = TrainSpec::with_mesh(Mesh::flat(5).unwrap(), 1, 2);
        let err = train(&cfg(), &spec, Arc::new(NativeBackend)).unwrap_err();
        assert!(err.to_string().contains("mesh 1x5"), "{err}");
    }

    #[test]
    fn domain_parallel_reads_fraction_of_bytes() {
        let c = cfg();
        let r1 = train(&c, &TrainSpec::quick(1, 1, 2).unwrap(), Arc::new(NativeBackend)).unwrap();
        let r2 = train(&c, &TrainSpec::quick(2, 1, 2).unwrap(), Arc::new(NativeBackend)).unwrap();
        let b1 = r1.steps[0].bytes_read;
        let b2 = r2.steps[0].bytes_read;
        assert!(b2 < b1, "jigsaw rank reads less: {b2} !< {b1}");
    }

    #[test]
    fn bucketed_grad_reduce_matches_expected_sum() {
        // integer-valued grads sum exactly, so the bucketed ring must
        // reproduce the per-element sum bit for bit, across bucket sizes
        // that split the store into many buckets or none.
        let cfg = crate::benchkit::synth_config("bucket-test", 32, 48, 2);
        let global = crate::model::init_global_params(&cfg, 0);
        for bucket_elems in [64usize, 1 << 20] {
            let net = crate::comm::Network::new(2);
            let mut handles = Vec::new();
            for r in 0..2usize {
                let mut comm = net.endpoint(r);
                let params = crate::model::params::shard_params(
                    &cfg,
                    &crate::jigsaw::Mesh::unit(),
                    0,
                    &global,
                )
                .unwrap();
                handles.push(std::thread::spawn(move || {
                    let mut grads = params.zeros_like();
                    for t in grads.grad_tensors_mut() {
                        for (i, x) in t.data.iter_mut().enumerate() {
                            *x = ((i % 11) + r) as f32;
                        }
                    }
                    dp_allreduce_grads_bucketed(
                        &mut grads,
                        &mut comm,
                        &[0, 1],
                        bucket_elems,
                    );
                    grads
                }));
            }
            let outs: Vec<_> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            for o in &outs {
                let mut g = o.clone();
                for t in g.grad_tensors_mut() {
                    for (i, x) in t.data.iter().enumerate() {
                        // sum over ranks of (i%11 + r) = 2*(i%11) + 1
                        let want = (2 * (i % 11) + 1) as f32;
                        assert_eq!(
                            *x, want,
                            "bucket_elems={bucket_elems}: elem {i} off"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn quick_zero_way_is_a_typed_error() {
        // the old path hit `expect("nonzero way")`; now it's a MeshError
        let err = TrainSpec::quick(0, 1, 1).unwrap_err();
        assert!(matches!(err, MeshError::Degree(0)), "{err}");
    }

    /// Backend that fails one matmul call partway through the run: the
    /// rank that draws it errors mid-step while its peers are blocked in
    /// `recv` waiting for its partials — the shape that used to deadlock
    /// `train()`'s join loop forever.
    struct FailingBackend {
        inner: NativeBackend,
        calls: std::sync::atomic::AtomicUsize,
        fail_at: usize,
    }

    impl crate::runtime::Backend for FailingBackend {
        fn matmul(
            &self,
            op: crate::runtime::MatmulOp,
            x: &Tensor,
            w: &Tensor,
        ) -> Result<Tensor> {
            use std::sync::atomic::Ordering;
            if self.calls.fetch_add(1, Ordering::SeqCst) == self.fail_at {
                anyhow::bail!("injected backend fault");
            }
            self.inner.matmul(op, x, w)
        }

        fn name(&self) -> &'static str {
            "failing"
        }
    }

    #[test]
    fn failing_rank_aborts_fabric_and_names_itself() {
        let backend = Arc::new(FailingBackend {
            inner: NativeBackend,
            calls: std::sync::atomic::AtomicUsize::new(0),
            fail_at: 9,
        });
        let spec = TrainSpec::quick(2, 2, 4).unwrap();
        let err = train(&cfg(), &spec, backend).unwrap_err().to_string();
        assert!(err.contains("failed"), "{err}");
        assert!(err.contains("injected backend fault"), "{err}");
        assert!(
            !err.contains(crate::comm::FABRIC_ABORTED),
            "must report the original failure, not an abort casualty: {err}"
        );
    }

    #[test]
    fn bucketed_reduce_boundary_cases() {
        // bucket_elems = 1 (every tensor its own bucket), an oversized
        // bucket limit, and a limit smaller than the largest tensor
        // (which must then ride alone): all reduce to the exact same
        // sums, and ranks can never disagree on boundaries because the
        // pack order is the stable registry order.
        let cfg = crate::benchkit::synth_config("bucket-edge", 32, 48, 2);
        let global = crate::model::init_global_params(&cfg, 0);
        let template = crate::model::params::shard_params(
            &cfg,
            &crate::jigsaw::Mesh::unit(),
            0,
            &global,
        )
        .unwrap();
        let largest = {
            let mut t = template.clone();
            t.grad_tensors_mut().iter().map(|x| x.numel()).max().unwrap()
        };
        for bucket_elems in [1usize, largest / 2, usize::MAX] {
            let net = crate::comm::Network::new(2);
            let mut handles = Vec::new();
            for r in 0..2usize {
                let mut comm = net.endpoint(r);
                let params = template.clone();
                handles.push(std::thread::spawn(move || {
                    let mut grads = params.zeros_like();
                    for t in grads.grad_tensors_mut() {
                        for (i, x) in t.data.iter_mut().enumerate() {
                            *x = ((i % 13) + r) as f32;
                        }
                    }
                    dp_allreduce_grads_bucketed(
                        &mut grads,
                        &mut comm,
                        &[0, 1],
                        bucket_elems,
                    );
                    grads
                }));
            }
            for h in handles {
                let mut out = h.join().unwrap();
                for t in out.grad_tensors_mut() {
                    for (i, x) in t.data.iter().enumerate() {
                        let want = (2 * (i % 13) + 1) as f32;
                        assert_eq!(*x, want, "bucket_elems={bucket_elems}");
                    }
                }
            }
        }
    }

    #[test]
    fn empty_store_bucketed_reduce_is_a_noop() {
        let net = crate::comm::Network::new(2);
        let mut handles = Vec::new();
        for r in 0..2usize {
            let mut comm = net.endpoint(r);
            handles.push(std::thread::spawn(move || {
                let mut grads = PStore::default();
                dp_allreduce_grads_bucketed(&mut grads, &mut comm, &[0, 1], 64);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(net.total_bytes(), 0, "no tensors, no collectives");
    }

    #[test]
    fn overlapped_training_matches_posthoc_bit_for_bit() {
        // same seed, same data: the grad-ready scheduler and the post-hoc
        // oracle must produce identical parameters after several steps
        // (both reduce through the same bucket boundaries and collective
        // arithmetic). 2-way mesh x 2 DP exercises MP + DP interleaving.
        let c = cfg();
        let mut s_overlap = TrainSpec::quick(2, 2, 4).unwrap();
        s_overlap.overlap_dp = true;
        let mut s_posthoc = s_overlap.clone();
        s_posthoc.overlap_dp = false;
        let a = train(&c, &s_overlap, Arc::new(NativeBackend)).unwrap();
        let b = train(&c, &s_posthoc, Arc::new(NativeBackend)).unwrap();
        for ((na, ta), (nb, tb)) in a.final_params.iter().zip(&b.final_params) {
            assert_eq!(na, nb);
            for (va, vb) in ta.data.iter().zip(&tb.data) {
                assert_eq!(
                    va.to_bits(),
                    vb.to_bits(),
                    "param {na} diverged between overlapped and post-hoc"
                );
            }
        }
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            assert_eq!(sa.loss.to_bits(), sb.loss.to_bits(), "step {}", sa.step);
        }
    }

    #[test]
    fn grad_scaler_overflow_backoff_and_regrowth() {
        let mut s = GradScaler::new(Precision::Bf16);
        assert!(s.active());
        assert_eq!(s.scale(), 16384.0);
        // overflow: the scale halves and the step is skipped
        assert!(!s.update(true));
        assert_eq!(s.scale(), 8192.0);
        // training resumes; after growth_interval good steps it doubles
        s.growth_interval = 3;
        assert!(s.update(false));
        assert!(s.update(false));
        assert_eq!(s.scale(), 8192.0);
        assert!(s.update(false));
        assert_eq!(s.scale(), 16384.0);
        // repeated overflow floors at min_scale instead of reaching zero
        for _ in 0..64 {
            assert!(!s.update(true));
        }
        assert_eq!(s.scale(), 1.0);
        // inert in f32 mode: scale pinned to 1, steps always taken
        let mut f = GradScaler::new(Precision::F32);
        assert!(!f.active());
        assert!(f.update(false));
        assert_eq!(f.scale(), 1.0);
    }

    #[test]
    fn failure_error_carries_the_rank_failure_marker() {
        let backend = Arc::new(FailingBackend {
            inner: NativeBackend,
            calls: std::sync::atomic::AtomicUsize::new(0),
            fail_at: 9,
        });
        let spec = TrainSpec::quick(2, 2, 4).unwrap();
        let err = train(&cfg(), &spec, backend).unwrap_err();
        let rf = err.downcast_ref::<RankFailure>().expect("RankFailure marker");
        assert!(rf.dp < 2 && rf.mp < 2, "{rf}");
    }

    #[test]
    fn bf16_rank_failure_is_contained_and_cleanup_is_complete() {
        // the PR-4/5 containment tests run f32 only; bf16 adds loss
        // scaling and u16 wire payloads to the abort-unwind path. Pin
        // that a bf16 peer death still produces the typed, primary-named
        // error — and that the same process immediately trains bf16
        // cleanly afterwards (nothing the unwind recycled was corrupted).
        let backend = Arc::new(FailingBackend {
            inner: NativeBackend,
            calls: std::sync::atomic::AtomicUsize::new(0),
            fail_at: 9,
        });
        let mut spec = TrainSpec::quick(2, 2, 4).unwrap();
        spec.precision = Precision::Bf16;
        let err = train(&cfg(), &spec, backend).unwrap_err();
        assert!(err.downcast_ref::<RankFailure>().is_some(), "{err:#}");
        let msg = err.to_string();
        assert!(msg.contains("injected backend fault"), "{msg}");
        assert!(
            !msg.contains(crate::comm::FABRIC_ABORTED),
            "primary failure, not an abort casualty: {msg}"
        );
        let mut clean = TrainSpec::quick(2, 2, 4).unwrap();
        clean.precision = Precision::Bf16;
        let report = train(&cfg(), &clean, Arc::new(NativeBackend)).unwrap();
        assert_eq!(report.steps.len(), 4);
        assert!(report.steps.iter().all(|s| s.loss.is_finite()));
    }

    #[test]
    fn elastic_recovery_survives_injected_rank_failure() {
        let dir = std::env::temp_dir()
            .join(format!("jigsaw-elastic-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let c = cfg();

        // calibrate: total matmul calls of a clean 6-step run on the 2x2
        // mesh (deterministic — per-step work is uniform, no validation)
        let probe = Arc::new(crate::benchkit::FlakyBackend::new(usize::MAX));
        let spec = TrainSpec::quick(4, 1, 6).unwrap();
        train(&c, &spec, probe.clone()).unwrap();
        let total = probe.calls();

        // fail ~3/4 through: after the step-4 checkpoint, before the end
        let backend = Arc::new(crate::benchkit::FlakyBackend::new(total * 3 / 4));
        let mut spec = TrainSpec::quick(4, 1, 6).unwrap();
        spec.checkpoint =
            Some(CheckpointSpec { dir: dir.clone(), every: 2, keep_last: 2 });
        let out = train_elastic(&c, &spec, backend, 3).unwrap();

        assert_eq!(out.recoveries.len(), 1, "{:?}", out.recoveries);
        let ev = &out.recoveries[0];
        assert!(ev.failure.contains("injected rank fault"), "{}", ev.failure);
        assert_eq!(ev.from_mesh.n(), 4);
        assert!(ev.to_mesh.n() < 4, "shrunk from {} to {}", ev.from_mesh, ev.to_mesh);
        assert_eq!(ev.resumed_step, Some(4), "resumed from the step-4 checkpoint");
        assert_eq!(out.report.resumed_from, Some(4));
        assert_eq!(out.report.steps.last().unwrap().step, 5, "ran to completion");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn elastic_propagates_non_rank_failures_unchanged() {
        // a spec error is not a rank death: no retry loop, same message
        let spec = TrainSpec::with_mesh(Mesh::flat(5).unwrap(), 1, 2);
        let err = train_elastic(&cfg(), &spec, Arc::new(NativeBackend), 3)
            .unwrap_err();
        assert!(err.to_string().contains("mesh 1x5"), "{err}");
    }

    #[test]
    fn resume_without_checkpoint_dir_is_a_clean_error() {
        let mut spec = TrainSpec::quick(1, 1, 2).unwrap();
        spec.resume = true;
        let err = train(&cfg(), &spec, Arc::new(NativeBackend)).unwrap_err();
        assert!(err.to_string().contains("without a checkpoint dir"), "{err}");
    }

    #[test]
    fn randomized_rollout_varies_lengths() {
        let mut spec = TrainSpec::quick(1, 1, 8).unwrap();
        spec.max_rollout = 3;
        let report = train(&cfg(), &spec, Arc::new(NativeBackend)).unwrap();
        let lens: std::collections::BTreeSet<usize> =
            report.steps.iter().map(|s| s.rollout).collect();
        assert!(lens.len() > 1, "rollout lengths all equal: {lens:?}");
        assert!(lens.iter().all(|&l| (1..=3).contains(&l)));
    }
}
pub mod oracle;
