//! Offline stub of the `xla` PJRT bindings.
//!
//! The jigsaw runtime's `pjrt` feature compiles against this API surface.
//! In an offline build there is no XLA toolchain, so every entry point
//! returns `Error::Stub` at runtime (`PjRtClient::cpu()` fails first, and
//! the engine reports it cleanly). A real deployment swaps this crate for
//! the actual bindings with a `[patch]` section or by replacing the path
//! dependency — the signatures below mirror the subset the engine uses.

use std::path::Path;

/// Error type for every stub operation.
#[derive(Debug, Clone)]
pub enum Error {
    /// The operation needs the real XLA/PJRT toolchain.
    Stub(&'static str),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Stub(what) => write!(
                f,
                "{what}: built against the offline xla stub (patch in the \
                 real `xla` crate for PJRT execution)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Stub("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Stub("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Stub("buffer_from_host_buffer"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Stub("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Stub("PjRtLoadedExecutable::execute_b"))
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::Stub("HloModuleProto::from_text_file"))
    }
}

/// Computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Host literal.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn shape(&self) -> Result<Shape> {
        Err(Error::Stub("Literal::shape"))
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        Err(Error::Stub("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::Stub("Literal::to_tuple"))
    }
}

/// Shape of a literal.
#[derive(Debug)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// Array shape with i64 dims (mirrors the real binding).
#[derive(Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}
