//! Paper Fig 4 (equivalent usage): a fixed compute budget of 8 ranks and
//! a fixed dataset, spent as 1-way (8 DP instances, global batch 8),
//! 2-way jigsaw (4 DP, batch 4), or 4-way jigsaw (2 DP, batch 2).
//!
//! Paper anchor: the MP configurations converge to *better* validation
//! RMSE because the smaller global batch takes more optimizer steps over
//! the same samples (large-batch-effect mitigation).

use std::sync::Arc;

use jigsaw::benchkit::{banner, csv_path, synth_config};
use jigsaw::runtime::native::NativeBackend;
use jigsaw::runtime::Backend;
use jigsaw::trainer::{train, TrainSpec};
use jigsaw::util::table::{fmt, Table};

fn main() {
    banner("Fig 4", "equivalent usage on a fixed 8-rank budget");
    let cfg = synth_config("wm-1b-analog", 96, 64, 2);
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);

    // fixed sample budget: every config sees the same number of samples;
    // optimizer steps = budget / global_batch.
    let sample_budget = 512usize;
    let mut t = Table::new(&[
        "config", "global batch", "optimizer steps", "final train loss", "val loss",
    ]);
    let mut vals = Vec::new();
    for (name, way, dp) in [("1-way x 8DP", 1usize, 8usize), ("2-way x 4DP", 2, 4), ("4-way x 2DP", 4, 2)] {
        let steps = sample_budget / dp;
        let mut spec = TrainSpec::quick(way, dp, steps).unwrap();
        spec.lr = 1.5e-3;
        spec.n_times = 32;
        spec.n_modes = 14;
        spec.val_every = steps;
        spec.seed = 2;
        let r = train(&cfg, &spec, backend.clone()).unwrap();
        let train_loss = r.steps.last().unwrap().loss;
        let val = r.val_loss.last().map(|(_, v)| *v).unwrap_or(f32::NAN);
        vals.push(val);
        t.row(&[
            name.to_string(),
            dp.to_string(),
            steps.to_string(),
            fmt(train_loss as f64),
            fmt(val as f64),
        ]);
    }
    println!("{}", t.render());
    t.write_csv(&csv_path("fig4_equivalent_usage")).unwrap();

    assert!(
        vals[1] < vals[0] && vals[2] < vals[0],
        "MP configs (more optimizer steps) must beat 1-way val loss: {vals:?}"
    );
    println!(
        "large-batch effect reproduced: 2-way {:.1}%, 4-way {:.1}% better than 1-way (paper: 2-9%) — OK",
        100.0 * (1.0 - vals[1] / vals[0]),
        100.0 * (1.0 - vals[2] / vals[0]),
    );
}
