//! Hot-path microbenches for the §Perf pass: matmul backends, jigsaw
//! dist_matmul overheads, tensor block algebra, comm round-trips, and the
//! Adam update. Prints ops/sec so before/after comparisons are direct.

use std::sync::Arc;

use jigsaw::benchkit::{banner, csv_path, time_best};
use jigsaw::comm::Network;
use jigsaw::jigsaw::{dist_matmul, BlockGrid, Ctx, DistMat, Site};
use jigsaw::runtime::native::NativeBackend;
use jigsaw::runtime::{Backend, MatmulOp};
use jigsaw::tensor::{ops, Tensor};
use jigsaw::util::rng::Rng;
use jigsaw::util::table::{fmt, Table};

fn rand_t(rng: &mut Rng, r: usize, c: usize) -> Tensor {
    let mut d = vec![0.0; r * c];
    rng.fill_normal(&mut d, 1.0);
    Tensor::new(vec![r, c], d)
}

fn main() {
    banner("hotpath", "microbenchmarks (single core)");
    let mut rng = Rng::seed_from(0);
    let mut t = Table::new(&["op", "size", "time (us)", "rate"]);

    // native matmul
    for n in [64usize, 128, 256] {
        let x = rand_t(&mut rng, n, n);
        let w = rand_t(&mut rng, n, n);
        let secs = time_best(5, || {
            std::hint::black_box(ops::matmul_nt(&x, &w));
        });
        let gflops = 2.0 * (n as f64).powi(3) / secs / 1e9;
        t.row(&[
            "native matmul_nt".into(),
            format!("{n}x{n}x{n}"),
            fmt(secs * 1e6),
            format!("{:.2} GF/s", gflops),
        ]);
    }

    // PJRT matmul (with artifacts)
    if let Ok(manifest) =
        jigsaw::config::Manifest::load(&jigsaw::config::artifacts_dir(), "tiny")
    {
        let engine = jigsaw::runtime::engine::Engine::start(manifest).unwrap();
        let x = rand_t(&mut rng, 32, 32);
        let w = rand_t(&mut rng, 32, 32);
        // warm the executable cache
        let _ = engine.matmul(MatmulOp::NT, &x, &w);
        let secs = time_best(20, || {
            std::hint::black_box(engine.matmul(MatmulOp::NT, &x, &w).unwrap());
        });
        t.row(&[
            "pjrt matmul_nt (tiny, cached)".into(),
            "32x32x32".into(),
            fmt(secs * 1e6),
            format!("{:.1} us dispatch", secs * 1e6),
        ]);
    }

    // dist_matmul 2-way over the thread fabric
    {
        let x = rand_t(&mut rng, 64, 128);
        let w = rand_t(&mut rng, 96, 128);
        let xg = BlockGrid::new(vec![vec![0, 1]]);
        let wg = BlockGrid::new(vec![vec![0, 1], vec![0, 1]]);
        let yg = BlockGrid::new(vec![vec![0, 1]]);
        let secs = time_best(5, || {
            let net = Network::new(2);
            let mut handles = Vec::new();
            for r in 0..2 {
                let mut comm = net.endpoint(r);
                let (xg, wg, yg) = (xg.clone(), wg.clone(), yg.clone());
                let (x, w) = (x.clone(), w.clone());
                handles.push(std::thread::spawn(move || {
                    let b = NativeBackend;
                    let mut ctx = Ctx::new(r, &mut comm, &b);
                    let xd = DistMat::from_global(&x, xg, r);
                    let wd = DistMat::from_global(&w, wg, r);
                    dist_matmul(&mut ctx, MatmulOp::NT, &xd, &wd, &yg, Site::WOwner)
                        .unwrap();
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
        });
        t.row(&[
            "dist_matmul 2-way (incl. thread spawn)".into(),
            "64x128x96".into(),
            fmt(secs * 1e6),
            "-".into(),
        ]);
    }

    // tensor block extraction / assembly
    {
        let big = rand_t(&mut rng, 512, 512);
        let secs = time_best(10, || {
            std::hint::black_box(big.block(1, 1, 2, 2));
        });
        t.row(&[
            "tensor block extract".into(),
            "512^2 / 2x2".into(),
            fmt(secs * 1e6),
            format!("{:.2} GB/s", (256.0 * 256.0 * 4.0) / secs / 1e9),
        ]);
    }

    // comm round trip
    {
        let net = Network::new(2);
        let payload = rand_t(&mut rng, 128, 128);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let secs = time_best(10, || {
            a.send(1, 1, payload.clone());
            let got = b.recv(0, 1);
            b.send(0, 2, got);
            std::hint::black_box(a.recv(1, 2));
        });
        t.row(&[
            "comm ping-pong".into(),
            "64 KiB".into(),
            fmt(secs * 1e6),
            format!("{:.2} GB/s", 2.0 * 65536.0 / secs / 1e9),
        ]);
    }

    // Adam update throughput
    {
        let cfg = jigsaw::benchkit::synth_config("adam-bench", 192, 96, 3);
        let global = jigsaw::model::init_global_params(&cfg, 0);
        let mut params = jigsaw::model::params::shard_params(
            &cfg,
            jigsaw::jigsaw::layouts::Way::One,
            0,
            &global,
        );
        let grads = params.zeros_like();
        let mut adam = jigsaw::optim::Adam::new(&params, 1e-3);
        let n = params.local_count();
        let secs = time_best(5, || {
            adam.update(&mut params, &grads, 1.0);
        });
        t.row(&[
            "adam update".into(),
            format!("{:.2}M params", n as f64 / 1e6),
            fmt(secs * 1e6),
            format!("{:.1} M param/s", n as f64 / secs / 1e6),
        ]);
    }

    println!("{}", t.render());
    t.write_csv(&csv_path("hotpath_micro")).unwrap();

    // smoke: a PJRT backend matmul equals native
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
    let x = rand_t(&mut rng, 8, 8);
    let w = rand_t(&mut rng, 8, 8);
    let a = backend.matmul(MatmulOp::NT, &x, &w).unwrap();
    assert!(a.max_abs_diff(&ops::matmul_nt(&x, &w)) < 1e-5);
    println!("hotpath_micro OK");
}
