//! Hot-path microbenches for the §Perf pass: matmul backends (blocked vs
//! the retained naive oracle), jigsaw dist_matmul overheads, DistMat
//! assemble/exchange, tensor block algebra, comm round-trips, the Adam
//! update, and steady-state allocation behaviour of the buffer pool —
//! plus the §Overlap pass: blocking vs ready-queue dist_matmul and
//! gather vs ring allreduce under fabric-injected per-message delays,
//! and per-block vs bucketed DP gradient reduction.
//! Prints ops/sec so before/after comparisons are direct, and persists
//! machine-readable perf records to BENCH_kernels.json and
//! BENCH_overlap.json for the trajectory.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use jigsaw::benchkit::{banner, csv_path, time_best};
use jigsaw::comm::{FabricSpec, Network};
use jigsaw::jigsaw::{dist_matmul, dist_matmul_blocking, BlockGrid, Ctx, DistMat, Mesh, Site};
use jigsaw::runtime::native::NativeBackend;
use jigsaw::runtime::{Backend, MatmulOp};
use jigsaw::tensor::{ops, pool, ref_kernels, Tensor};
use jigsaw::util::json::Json;
use jigsaw::util::rng::Rng;
use jigsaw::util::table::{fmt, Table};

fn rand_t(rng: &mut Rng, r: usize, c: usize) -> Tensor {
    let mut d = vec![0.0; r * c];
    rng.fill_normal(&mut d, 1.0);
    Tensor::new(vec![r, c], d)
}

fn jnum(v: f64) -> Json {
    Json::Num(v)
}

fn jobj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn main() {
    banner("hotpath", "microbenchmarks (single core)");
    let mut rng = Rng::seed_from(0);
    let mut t = Table::new(&["op", "size", "time (us)", "rate"]);
    let mut record: BTreeMap<String, Json> = BTreeMap::new();
    let mut matmul_records: Vec<Json> = Vec::new();

    // blocked vs naive matmul (the kernel-layer acceptance metric):
    // the naive seed kernels live on in tensor::ref_kernels as the oracle
    let mut min_nt_speedup_256plus = f64::INFINITY;
    for op in [MatmulOp::NT, MatmulOp::NN, MatmulOp::TN] {
        for n in [64usize, 256, 384] {
            // square operands are shape-valid for all three forms
            let x = rand_t(&mut rng, n, n);
            let w = rand_t(&mut rng, n, n);
            let reps = if n >= 384 { 3 } else { 5 };
            let naive_secs = time_best(reps, || {
                std::hint::black_box(match op {
                    MatmulOp::NT => ref_kernels::matmul_nt(&x, &w),
                    MatmulOp::NN => ref_kernels::matmul_nn(&x, &w),
                    MatmulOp::TN => ref_kernels::matmul_tn(&x, &w),
                });
            });
            // blocked kernel into a preallocated buffer: the steady-state
            // shape of the hot path (zero allocations per call)
            let mut out = Tensor::zeros(&[n, n]);
            let blocked_secs = time_best(reps * 2, || {
                let ov = out.view2_mut();
                match op {
                    MatmulOp::NT => ops::matmul_nt_into(ov, x.view2(), w.view2(), false),
                    MatmulOp::NN => ops::matmul_nn_into(ov, x.view2(), w.view2(), false),
                    MatmulOp::TN => ops::matmul_tn_into(ov, x.view2(), w.view2(), false),
                }
                std::hint::black_box(&out);
            });
            let flops = 2.0 * (n as f64).powi(3);
            let speedup = naive_secs / blocked_secs;
            if op == MatmulOp::NT && n >= 256 {
                min_nt_speedup_256plus = min_nt_speedup_256plus.min(speedup);
            }
            t.row(&[
                format!("matmul_{} blocked vs naive", op.tag()),
                format!("{n}x{n}x{n}"),
                fmt(blocked_secs * 1e6),
                format!(
                    "{:.2} GF/s ({:.1}x naive {:.2} GF/s)",
                    flops / blocked_secs / 1e9,
                    speedup,
                    flops / naive_secs / 1e9
                ),
            ]);
            matmul_records.push(jobj(vec![
                ("op", Json::Str(op.tag().to_string())),
                ("n", jnum(n as f64)),
                ("naive_us", jnum(naive_secs * 1e6)),
                ("blocked_us", jnum(blocked_secs * 1e6)),
                ("naive_gflops", jnum(flops / naive_secs / 1e9)),
                ("blocked_gflops", jnum(flops / blocked_secs / 1e9)),
                ("speedup", jnum(speedup)),
                ("threads", jnum(1.0)),
            ]));
        }
    }

    // thread-parallel driver (explicit band counts on a 512 NT matmul)
    {
        let n = 512usize;
        let x = rand_t(&mut rng, n, n);
        let w = rand_t(&mut rng, n, n);
        let mut out = Tensor::zeros(&[n, n]);
        let base = time_best(3, || {
            ops::matmul_nt_into_with(out.view2_mut(), x.view2(), w.view2(), false, 1);
            std::hint::black_box(&out);
        });
        for threads in [2usize, 4] {
            let secs = time_best(3, || {
                ops::matmul_nt_into_with(out.view2_mut(), x.view2(), w.view2(), false, threads);
                std::hint::black_box(&out);
            });
            let flops = 2.0 * (n as f64).powi(3);
            t.row(&[
                format!("matmul_nt {threads} threads"),
                format!("{n}x{n}x{n}"),
                fmt(secs * 1e6),
                format!("{:.2} GF/s ({:.2}x serial)", flops / secs / 1e9, base / secs),
            ]);
            matmul_records.push(jobj(vec![
                ("op", Json::Str("nt".into())),
                ("n", jnum(n as f64)),
                ("blocked_us", jnum(secs * 1e6)),
                ("blocked_gflops", jnum(flops / secs / 1e9)),
                ("serial_speedup", jnum(base / secs)),
                ("threads", jnum(threads as f64)),
            ]));
        }
    }

    // SIMD register tile vs the forced-scalar tile (simd builds only):
    // same blocked driver, same packing, only the innermost 4x8 tile
    // differs. The acceptance gate is >= 1.5x on large shapes; the
    // property suite separately proves the paths bit-identical.
    #[cfg(feature = "simd")]
    {
        let mut min_simd_speedup_256plus = f64::INFINITY;
        for n in [64usize, 256, 384] {
            let x = rand_t(&mut rng, n, n);
            let w = rand_t(&mut rng, n, n);
            let mut out = Tensor::zeros(&[n, n]);
            let reps = if n >= 384 { 5 } else { 8 };
            let prev = ops::set_force_scalar_tile(true);
            let scalar_secs = time_best(reps, || {
                ops::matmul_nt_into(out.view2_mut(), x.view2(), w.view2(), false);
                std::hint::black_box(&out);
            });
            ops::set_force_scalar_tile(false);
            let simd_secs = time_best(reps, || {
                ops::matmul_nt_into(out.view2_mut(), x.view2(), w.view2(), false);
                std::hint::black_box(&out);
            });
            ops::set_force_scalar_tile(prev);
            let flops = 2.0 * (n as f64).powi(3);
            let speedup = scalar_secs / simd_secs;
            if n >= 256 {
                min_simd_speedup_256plus = min_simd_speedup_256plus.min(speedup);
            }
            t.row(&[
                "matmul_nt simd vs scalar tile".into(),
                format!("{n}x{n}x{n}"),
                fmt(simd_secs * 1e6),
                format!(
                    "{:.2} GF/s ({:.1}x scalar {:.2} GF/s)",
                    flops / simd_secs / 1e9,
                    speedup,
                    flops / scalar_secs / 1e9
                ),
            ]);
            matmul_records.push(jobj(vec![
                ("op", Json::Str("nt_simd".into())),
                ("n", jnum(n as f64)),
                ("scalar_tile_us", jnum(scalar_secs * 1e6)),
                ("simd_us", jnum(simd_secs * 1e6)),
                ("simd_gflops", jnum(flops / simd_secs / 1e9)),
                ("simd_speedup", jnum(speedup)),
                ("threads", jnum(1.0)),
            ]));
        }
        record.insert(
            "min_simd_speedup_256plus".into(),
            jnum(min_simd_speedup_256plus),
        );
        assert!(
            min_simd_speedup_256plus >= 1.5,
            "SIMD tile must be >= 1.5x the scalar tile on large shapes, \
             got {min_simd_speedup_256plus:.2}x"
        );
    }

    // PJRT matmul (with artifacts)
    if let Ok(manifest) =
        jigsaw::config::Manifest::load(&jigsaw::config::artifacts_dir(), "tiny")
    {
        if let Ok(engine) = jigsaw::runtime::engine::Engine::start(manifest) {
            let x = rand_t(&mut rng, 32, 32);
            let w = rand_t(&mut rng, 32, 32);
            // warm the executable cache
            let _ = engine.matmul(MatmulOp::NT, &x, &w);
            let secs = time_best(20, || {
                std::hint::black_box(engine.matmul(MatmulOp::NT, &x, &w).unwrap());
            });
            t.row(&[
                "pjrt matmul_nt (tiny, cached)".into(),
                "32x32x32".into(),
                fmt(secs * 1e6),
                format!("{:.1} us dispatch", secs * 1e6),
            ]);
        }
    }

    // dist_matmul 2-way over the thread fabric (the exchange path: Arc
    // fan-out shipping + in-place partial reduction)
    {
        let x = rand_t(&mut rng, 64, 128);
        let w = rand_t(&mut rng, 96, 128);
        let xg = BlockGrid::new(vec![vec![0, 1]]);
        let wg = BlockGrid::new(vec![vec![0, 1], vec![0, 1]]);
        let yg = BlockGrid::new(vec![vec![0, 1]]);
        let secs = time_best(5, || {
            let net = Network::new(2);
            let mut handles = Vec::new();
            for r in 0..2 {
                let mut comm = net.endpoint(r);
                let (xg, wg, yg) = (xg.clone(), wg.clone(), yg.clone());
                let (x, w) = (x.clone(), w.clone());
                handles.push(std::thread::spawn(move || {
                    let b = NativeBackend;
                    let mut ctx = Ctx::new(Mesh::flat(2).unwrap(), r, &mut comm, &b);
                    let xd = DistMat::from_global(&x, xg, r);
                    let wd = DistMat::from_global(&w, wg, r);
                    dist_matmul(&mut ctx, MatmulOp::NT, &xd, &wd, &yg, Site::WOwner)
                        .unwrap();
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
        });
        t.row(&[
            "dist_matmul 2-way (incl. thread spawn)".into(),
            "64x128x96".into(),
            fmt(secs * 1e6),
            "-".into(),
        ]);
        record.insert("exchange_2way_us".into(), jnum(secs * 1e6));
    }

    // DistMat assemble: 2x2 grid of 256x256 blocks into a 512x512 global
    // (view-based single-copy path)
    {
        let big = rand_t(&mut rng, 512, 512);
        let grid = BlockGrid::new(vec![vec![0, 1], vec![2, 3]]);
        let parts: Vec<DistMat> = (0..4)
            .map(|r| DistMat::from_global(&big, grid.clone(), r))
            .collect();
        let refs: Vec<&DistMat> = parts.iter().collect();
        let secs = time_best(10, || {
            std::hint::black_box(DistMat::assemble(&refs));
        });
        t.row(&[
            "DistMat assemble".into(),
            "512^2 / 2x2".into(),
            fmt(secs * 1e6),
            format!("{:.2} GB/s", (512.0 * 512.0 * 4.0) / secs / 1e9),
        ]);
        record.insert("assemble_512_us".into(), jnum(secs * 1e6));
    }

    // tensor block extraction / assembly
    {
        let big = rand_t(&mut rng, 512, 512);
        let secs = time_best(10, || {
            std::hint::black_box(big.block(1, 1, 2, 2));
        });
        t.row(&[
            "tensor block extract".into(),
            "512^2 / 2x2".into(),
            fmt(secs * 1e6),
            format!("{:.2} GB/s", (256.0 * 256.0 * 4.0) / secs / 1e9),
        ]);
        let secs = time_best(20, || {
            std::hint::black_box(big.view2().block(1, 1, 2, 2).nrows());
        });
        t.row(&[
            "tensor block view (zero-copy)".into(),
            "512^2 / 2x2".into(),
            fmt(secs * 1e6),
            "O(1)".into(),
        ]);
    }

    // comm round trip
    {
        let net = Network::new(2);
        let payload = rand_t(&mut rng, 128, 128);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let secs = time_best(10, || {
            a.send(1, 1, payload.clone());
            let got = b.recv(0, 1);
            b.send(0, 2, got);
            std::hint::black_box(a.recv(1, 2));
        });
        t.row(&[
            "comm ping-pong".into(),
            "64 KiB".into(),
            fmt(secs * 1e6),
            format!("{:.2} GB/s", 2.0 * 65536.0 / secs / 1e9),
        ]);
    }

    // Adam update throughput
    {
        let cfg = jigsaw::benchkit::synth_config("adam-bench", 192, 96, 3);
        let global = jigsaw::model::init_global_params(&cfg, 0);
        let mut params = jigsaw::model::params::shard_params(
            &cfg,
            &Mesh::unit(),
            0,
            &global,
        )
        .unwrap();
        let grads = params.zeros_like();
        let mut adam = jigsaw::optim::Adam::new(&params, 1e-3);
        let n = params.local_count();
        let secs = time_best(5, || {
            adam.update(&mut params, &grads, 1.0);
        });
        t.row(&[
            "adam update".into(),
            format!("{:.2}M params", n as f64 / 1e6),
            fmt(secs * 1e6),
            format!("{:.1} M param/s", n as f64 / secs / 1e6),
        ]);
    }

    // steady-state allocation behaviour: pool misses per train step after
    // warm-up (two runs, subtract the cold first step). Misses are real
    // heap allocations; zero steady-state misses means the kernel layer
    // runs allocation-free once the per-thread pools converge.
    {
        let cfg = jigsaw::benchkit::synth_config("pool-bench", 96, 64, 2);
        let run = |steps: usize| -> (u64, u64) {
            let spec = jigsaw::trainer::TrainSpec::quick(1, 1, steps).unwrap();
            let before = pool::stats();
            jigsaw::trainer::train(&cfg, &spec, Arc::new(NativeBackend)).unwrap();
            let after = pool::stats();
            (after.0 - before.0, after.1 - before.1)
        };
        let (h1, m1) = run(1);
        let (h9, m9) = run(9);
        let steady_misses_per_step = (m9.saturating_sub(m1)) as f64 / 8.0;
        let steady_hits_per_step = (h9.saturating_sub(h1)) as f64 / 8.0;
        t.row(&[
            "pool steady-state".into(),
            "1-way x 8 steps".into(),
            format!("{steady_misses_per_step:.1}"),
            format!(
                "misses/step ({steady_hits_per_step:.0} hits/step, cold step: {m1} misses)"
            ),
        ]);
        record.insert(
            "steady_state".into(),
            jobj(vec![
                ("cold_step_misses", jnum(m1 as f64)),
                ("steady_misses_per_step", jnum(steady_misses_per_step)),
                ("steady_hits_per_step", jnum(steady_hits_per_step)),
            ]),
        );
    }

    // ================= §Overlap: ready-queue vs blocking =================
    // The fabric injector delays every message by latency + jitter +
    // bytes/bw with per-endpoint link serialization, so schedules that
    // hide communication win wall-clock even on the thread fabric.
    // Jittered delays make single runs noisy, so these cases report the
    // mean over reps rather than best-of.
    fn time_mean(reps: usize, mut f: impl FnMut()) -> f64 {
        let mut total = 0.0;
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            f();
            total += t0.elapsed().as_secs_f64();
        }
        total / reps as f64
    }
    let mut overlap: BTreeMap<String, Json> = BTreeMap::new();

    // blocking vs ready-queue dist_matmul: every term computes at rank 0,
    // all nine mobile x blocks arrive from ranks 1-3 with jittered delays
    // (a delay-spread chosen so arrival order is well scrambled). The
    // fixed-order schedule waits for block (0,0) even when later blocks
    // have landed; the ready queue computes in arrival order. Simulated
    // P(ready wins an 8-rep mean) ~ 1.0 at these parameters.
    {
        let n = 4usize;
        let x = rand_t(&mut rng, 192, 192);
        let w = rand_t(&mut rng, 192, 192);
        let xg = BlockGrid::new(vec![vec![1, 2, 3], vec![2, 3, 1], vec![3, 1, 2]]);
        let wg = BlockGrid::new(vec![vec![0; 3]; 3]);
        let yg = BlockGrid::new(vec![vec![0; 3]; 3]);
        let spec = FabricSpec {
            latency: Duration::from_micros(300),
            jitter: Duration::from_micros(3000),
            bytes_per_sec: 1e9,
        };
        let run = |blocking: bool| -> f64 {
            let (x, w) = (&x, &w);
            let (xg, wg, yg) = (&xg, &wg, &yg);
            time_mean(8, || {
                let net = Network::new(n);
                net.set_fabric(spec, 42);
                let mut handles = Vec::new();
                for r in 0..n {
                    let mut comm = net.endpoint(r);
                    let (xg, wg, yg) = (xg.clone(), wg.clone(), yg.clone());
                    let (x, w) = (x.clone(), w.clone());
                    handles.push(std::thread::spawn(move || {
                        let b = NativeBackend;
                        let mut ctx = Ctx::new(Mesh::flat(n).unwrap(), r, &mut comm, &b);
                        let xd = DistMat::from_global(&x, xg, r);
                        let wd = DistMat::from_global(&w, wg, r);
                        if blocking {
                            dist_matmul_blocking(
                                &mut ctx,
                                MatmulOp::NT,
                                &xd,
                                &wd,
                                &yg,
                                Site::WOwner,
                            )
                            .unwrap()
                        } else {
                            dist_matmul(
                                &mut ctx,
                                MatmulOp::NT,
                                &xd,
                                &wd,
                                &yg,
                                Site::WOwner,
                            )
                            .unwrap()
                        }
                    }));
                }
                for h in handles {
                    std::hint::black_box(h.join().unwrap());
                }
            })
        };
        let blocking_secs = run(true);
        let ready_secs = run(false);
        let speedup = blocking_secs / ready_secs;
        t.row(&[
            "dist_matmul ready-queue vs blocking (delayed fabric)".into(),
            "192^2 / 3x3 / 4 ranks".into(),
            fmt(ready_secs * 1e6),
            format!("{speedup:.2}x vs blocking {:.0} us", blocking_secs * 1e6),
        ]);
        overlap.insert(
            "dist_matmul".into(),
            jobj(vec![
                ("ranks", jnum(n as f64)),
                ("blocking_us", jnum(blocking_secs * 1e6)),
                ("ready_us", jnum(ready_secs * 1e6)),
                ("speedup", jnum(speedup)),
            ]),
        );
        assert!(
            speedup > 1.0,
            "ready-queue must beat the blocking schedule under injected \
             delays: {:.0} us vs {:.0} us",
            ready_secs * 1e6,
            blocking_secs * 1e6
        );
    }

    // gather-to-root vs ring allreduce: the root's ingress link serializes
    // n-1 full-size transfers; the ring moves 2(n-1)/n of the payload per
    // link, all links busy in parallel.
    {
        let numel = 256 * 256;
        let spec = FabricSpec {
            latency: Duration::from_micros(20),
            jitter: Duration::from_micros(5),
            bytes_per_sec: 1e9,
        };
        let mut rows: Vec<Json> = Vec::new();
        for n in [4usize, 8] {
            let run = |ring: bool| -> f64 {
                time_mean(5, || {
                    let net = Network::new(n);
                    net.set_fabric(spec, 7);
                    let group: Vec<usize> = (0..n).collect();
                    let mut handles = Vec::new();
                    for r in 0..n {
                        let mut c = net.endpoint(r);
                        let g = group.clone();
                        handles.push(std::thread::spawn(move || {
                            let t = Tensor::new(vec![numel], vec![r as f32; numel]);
                            if ring {
                                c.allreduce_sum_ring(&g, &t)
                            } else {
                                c.allreduce_sum_gather(&g, &t)
                            }
                        }));
                    }
                    for h in handles {
                        std::hint::black_box(h.join().unwrap());
                    }
                })
            };
            let gather_secs = run(false);
            let ring_secs = run(true);
            let speedup = gather_secs / ring_secs;
            t.row(&[
                format!("allreduce ring vs gather ({n} ranks, delayed fabric)"),
                format!("{} KiB", numel * 4 / 1024),
                fmt(ring_secs * 1e6),
                format!("{speedup:.2}x vs gather {:.0} us", gather_secs * 1e6),
            ]);
            rows.push(jobj(vec![
                ("ranks", jnum(n as f64)),
                ("numel", jnum(numel as f64)),
                ("gather_us", jnum(gather_secs * 1e6)),
                ("ring_us", jnum(ring_secs * 1e6)),
                ("speedup", jnum(speedup)),
            ]));
            assert!(
                speedup > 1.0,
                "ring must beat gather-to-root on {n} ranks: {:.0} us vs {:.0} us",
                ring_secs * 1e6,
                gather_secs * 1e6
            );
        }
        overlap.insert("allreduce".into(), Json::Arr(rows));
    }

    // per-parameter vs bucketed DP gradient reduction on 4 DP ranks: one
    // latency-bound collective per tensor vs a handful of flat buckets.
    {
        let n = 4usize;
        let cfg = jigsaw::benchkit::synth_config("dp-bench", 96, 64, 2);
        let global = jigsaw::model::init_global_params(&cfg, 0);
        let template = jigsaw::model::params::shard_params(
            &cfg,
            &Mesh::unit(),
            0,
            &global,
        )
        .unwrap();
        let spec = FabricSpec {
            latency: Duration::from_micros(50),
            jitter: Duration::from_micros(10),
            bytes_per_sec: 1e9,
        };
        let run = |bucketed: bool| -> f64 {
            let template = &template;
            time_mean(5, || {
                let net = Network::new(n);
                net.set_fabric(spec, 11);
                let group: Vec<usize> = (0..n).collect();
                let mut handles = Vec::new();
                for r in 0..n {
                    let mut comm = net.endpoint(r);
                    let g = group.clone();
                    let params = template.clone();
                    handles.push(std::thread::spawn(move || {
                        let mut grads = params.zeros_like();
                        for t in grads.grad_tensors_mut() {
                            for x in t.data.iter_mut() {
                                *x = (r + 1) as f32;
                            }
                        }
                        if bucketed {
                            jigsaw::trainer::dp_allreduce_grads(
                                &mut grads, &mut comm, &g,
                            );
                        } else {
                            for t in grads.grad_tensors_mut() {
                                *t = comm.allreduce_sum(&g, t);
                            }
                        }
                        grads
                    }));
                }
                for h in handles {
                    let mut out = h.join().unwrap();
                    // both paths must produce the exact sum 1+2+3+4
                    for t in out.grad_tensors_mut() {
                        assert!(t.data.iter().all(|&v| v == 10.0));
                    }
                    std::hint::black_box(&out);
                }
            })
        };
        let per_block_secs = run(false);
        let bucketed_secs = run(true);
        let speedup = per_block_secs / bucketed_secs;
        t.row(&[
            "dp grad reduce bucketed vs per-block (delayed fabric)".into(),
            format!(
                "{} tensors / 4 ranks",
                template.mats.values().map(|m| m.blocks.len()).sum::<usize>()
                    + template.vecs.len()
            ),
            fmt(bucketed_secs * 1e6),
            format!("{speedup:.2}x vs per-block {:.0} us", per_block_secs * 1e6),
        ]);
        overlap.insert(
            "dp_grads".into(),
            jobj(vec![
                ("ranks", jnum(n as f64)),
                ("per_block_us", jnum(per_block_secs * 1e6)),
                ("bucketed_us", jnum(bucketed_secs * 1e6)),
                ("speedup", jnum(speedup)),
            ]),
        );
    }

    // ================= §DpOverlap: grad-ready reduce under backward =====
    // The tentpole measurement: a dp=4 world (1x1 mesh, pure DP traffic)
    // runs one full loss_and_grad + DP gradient reduce per step under
    // injected fabric delays. The post-hoc baseline packs and rings every
    // bucket only *after* the backward pass returns, paying the ring
    // latency serially on the critical path; the grad-ready scheduler
    // posts each bucket's ring as it fills during backward, so that
    // latency elapses under compute. Writes BENCH_dp_overlap.json and
    // asserts the overlapped step wall beats the post-hoc one.
    {
        use jigsaw::model::dist::DistModel;
        use jigsaw::model::params::shard_params;
        use jigsaw::trainer::oracle::sample_shard;
        use jigsaw::trainer::{dp_allreduce_grads_bucketed, GradReduceScheduler};

        let dp = 4usize;
        let bucket_elems = 1usize << 16; // 256 KiB buckets -> ~10 rings
        // compute-heavy enough that the backward pass offers a real
        // window to hide ring latency under
        let cfg = jigsaw::benchkit::synth_config("dp-overlap-bench", 256, 192, 3);
        let global = jigsaw::model::init_global_params(&cfg, 3);
        let mesh = Mesh::unit();
        let spec = FabricSpec {
            latency: Duration::from_micros(400),
            jitter: Duration::from_micros(80),
            bytes_per_sec: 1e9,
        };
        let mut d = vec![0.0; cfg.lat * cfg.lon * cfg.channels_padded];
        rng.fill_normal(&mut d, 1.0);
        let x = Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d.clone());
        rng.fill_normal(&mut d, 1.0);
        let y = Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d);

        let run = |overlapped: bool| -> f64 {
            let (cfg, global, x, y) = (&cfg, &global, &x, &y);
            time_mean(5, || {
                let dp_net = Network::new(dp);
                dp_net.set_fabric(spec, 42);
                let group: Vec<usize> = (0..dp).collect();
                let mut handles = Vec::new();
                for g in 0..dp {
                    let cfg = cfg.clone();
                    let params = shard_params(&cfg, &mesh, 0, global).unwrap();
                    let mut dp_comm = dp_net.endpoint(g);
                    let mp_net = Network::new(1);
                    let mut mp_comm = mp_net.endpoint(0);
                    let grp = group.clone();
                    let (x, y) = (x.clone(), y.clone());
                    handles.push(std::thread::spawn(move || {
                        let b = NativeBackend;
                        let model = DistModel::new(cfg, &mesh, 0, params);
                        let (la, _, lc) = model.local_dims();
                        let xl = sample_shard(&x, (0, la), (0, lc));
                        let yl = sample_shard(&y, (0, la), (0, lc));
                        let mut ctx = Ctx::new(mesh, 0, &mut mp_comm, &b);
                        if overlapped {
                            let mut sched = GradReduceScheduler::new(
                                &mut dp_comm,
                                &grp,
                                bucket_elems,
                            );
                            let (_, mut grads) = model
                                .loss_and_grad_with(&mut ctx, &xl, &yl, 1, &mut sched)
                                .unwrap();
                            sched.finish(&mut grads);
                            grads
                        } else {
                            let (_, mut grads) =
                                model.loss_and_grad(&mut ctx, &xl, &yl, 1).unwrap();
                            dp_allreduce_grads_bucketed(
                                &mut grads,
                                &mut dp_comm,
                                &grp,
                                bucket_elems,
                            );
                            grads
                        }
                    }));
                }
                for h in handles {
                    std::hint::black_box(h.join().unwrap());
                }
            })
        };
        // warm pools/caches once per mode, then measure
        let _ = run(false);
        let posthoc_secs = run(false);
        let _ = run(true);
        let overlapped_secs = run(true);
        let speedup = posthoc_secs / overlapped_secs;
        let grad_elems: usize = {
            let mut s = shard_params(&cfg, &mesh, 0, &global).unwrap();
            s.grad_tensors_mut().iter().map(|t| t.numel()).sum()
        };
        t.row(&[
            "dp grad reduce grad-ready vs post-hoc (delayed fabric)".into(),
            format!("{:.1}M grads / {dp} DP ranks", grad_elems as f64 / 1e6),
            fmt(overlapped_secs * 1e6),
            format!("{speedup:.2}x vs post-hoc {:.0} us", posthoc_secs * 1e6),
        ]);
        let dp_overlap_record = jobj(vec![
            ("bench", Json::Str("dp_overlap".into())),
            ("dp", jnum(dp as f64)),
            ("bucket_elems", jnum(bucket_elems as f64)),
            ("grad_elems", jnum(grad_elems as f64)),
            ("fabric_latency_us", jnum(400.0)),
            ("posthoc_step_us", jnum(posthoc_secs * 1e6)),
            ("overlapped_step_us", jnum(overlapped_secs * 1e6)),
            ("speedup", jnum(speedup)),
        ]);
        std::fs::write(
            "BENCH_dp_overlap.json",
            dp_overlap_record.to_string() + "\n",
        )
        .unwrap();
        println!("BENCH_dp_overlap.json written");
        overlap.insert(
            "dp_grad_ready".into(),
            jobj(vec![
                ("posthoc_step_us", jnum(posthoc_secs * 1e6)),
                ("overlapped_step_us", jnum(overlapped_secs * 1e6)),
                ("speedup", jnum(speedup)),
            ]),
        );
        assert!(
            speedup > 1.0,
            "grad-ready DP reduce must beat the post-hoc reduce under \
             injected delays: {:.0} us vs {:.0} us",
            overlapped_secs * 1e6,
            posthoc_secs * 1e6
        );
    }

    // ================= §Progress: engine-driven vs emission-only drain ==
    // Isolates the tentpole mechanism under a 400us-latency dp=4 fabric:
    // each rank posts a burst of bucket rings, then spends a long
    // *emission-free* compute window in blocked matmuls — exactly the
    // shape where PR-4's emission-point polling leaves every posted ring
    // idle (no emission, no poll). The progress engine retires the rings
    // from inside the kernel driver during the window, so
    // GradReduceScheduler::finish is a short unpack; emission-only
    // polling pays every ring hop inside the drain. (In a full training
    // backward the bucket sealed *at* finish rings entirely inside the
    // drain either way and floors both modes — the discrete-event sim
    // shows the modes within ~20% there — so the drain-tail assertion
    // lives on this isolated window, where the effect is an order of
    // magnitude and timing-noise-proof.) Writes BENCH_progress.json.
    {
        use jigsaw::model::params::{GradId, GradSink, PStore};
        use jigsaw::trainer::GradReduceScheduler;

        let dp = 4usize;
        let n_buckets = 8usize;
        let side = 128usize;
        let bucket_elems = side * side; // every mat seals its own bucket:
                                        // nothing left to seal in finish
        let spec = FabricSpec {
            latency: Duration::from_micros(400),
            jitter: Duration::from_micros(20),
            bytes_per_sec: 1e9,
        };
        // synthetic grad store: n_buckets single-block mats of exactly one
        // bucket each, values varying per rank so the reduction is checked
        fn mk_store(r: usize, n_buckets: usize, side: usize) -> PStore {
            let mut s = PStore::default();
            for b in 0..n_buckets {
                let data: Vec<f32> =
                    (0..side * side).map(|i| (i % 17 + r) as f32).collect();
                let t = Tensor::new(vec![side, side], data);
                s.mats.insert(
                    format!("blk{b}_ch_w1"),
                    DistMat::from_global(&t, BlockGrid::single(), 0),
                );
            }
            s
        }
        let window = Duration::from_millis(10);
        let x = rand_t(&mut rng, 256, 256);
        let w = rand_t(&mut rng, 256, 256);
        // mean over reps of the slowest rank's finish() wall time
        let run = |engine: bool| -> f64 {
            let (x, w) = (&x, &w);
            let reps = 5usize;
            let mut drain_total = 0.0f64;
            for rep in 0..reps {
                let net = Network::new(dp);
                net.set_fabric(spec, 42 + rep as u64);
                let group: Vec<usize> = (0..dp).collect();
                let mut handles = Vec::new();
                for r in 0..dp {
                    let mut comm = net.endpoint(r);
                    let grp = group.clone();
                    let (x, w) = (x.clone(), w.clone());
                    handles.push(std::thread::spawn(move || {
                        let mut grads = mk_store(r, n_buckets, side);
                        let mut sched = if engine {
                            GradReduceScheduler::new(&mut comm, &grp, bucket_elems)
                        } else {
                            GradReduceScheduler::new_emission_only(
                                &mut comm,
                                &grp,
                                bucket_elems,
                            )
                        };
                        // emission burst: every bucket's ring posts now
                        let order = grads.grad_reduce_order();
                        for id in &order {
                            if let GradId::Mat(name, _) = id {
                                sched.mat_ready(name, &grads.mats[name]);
                            }
                        }
                        // long emission-free compute window (the serial
                        // kernels tick the engine between row groups)
                        let t0 = std::time::Instant::now();
                        let mut out = Tensor::zeros(&[256, 256]);
                        while t0.elapsed() < window {
                            ops::matmul_nt_into(
                                out.view2_mut(),
                                x.view2(),
                                w.view2(),
                                false,
                            );
                            std::hint::black_box(&out);
                        }
                        let drain = sched.finish_timed(&mut grads);
                        (grads, drain)
                    }));
                }
                let mut max_drain = 0.0f64;
                for h in handles {
                    let (mut grads, drain) = h.join().unwrap();
                    max_drain = max_drain.max(drain.as_secs_f64());
                    for t in grads.grad_tensors_mut() {
                        for (i, v) in t.data.iter().enumerate() {
                            // sum over ranks of (i%17 + r) = 4*(i%17) + 6
                            assert_eq!(
                                *v,
                                (4 * (i % 17) + 6) as f32,
                                "reduced grads wrong at elem {i}"
                            );
                        }
                    }
                }
                drain_total += max_drain;
            }
            drain_total / reps as f64
        };
        let _ = run(false); // warm pools
        let emission_drain = run(false);
        let _ = run(true);
        let engine_drain = run(true);
        let drain_speedup = emission_drain / engine_drain;
        t.row(&[
            "grad-reduce drain engine vs emission-only (400us fabric)".into(),
            format!("{n_buckets} rings / {dp} DP ranks"),
            fmt(engine_drain * 1e6),
            format!(
                "{drain_speedup:.2}x vs emission-only {:.0} us",
                emission_drain * 1e6
            ),
        ]);

        // injected rank failure: abort containment must not degrade the
        // pool's steady state — in-flight bucket payloads recycle on the
        // unwind (PackedAllreduce::drop + scheduler drop), so post-failure
        // steady-state misses stay at the pre-failure level
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct FailOnceBackend {
            calls: AtomicUsize,
            fail_at: usize,
        }
        impl Backend for FailOnceBackend {
            fn matmul(
                &self,
                op: MatmulOp,
                x: &Tensor,
                w: &Tensor,
            ) -> anyhow::Result<Tensor> {
                if self.calls.fetch_add(1, Ordering::SeqCst) == self.fail_at {
                    anyhow::bail!("injected rank fault");
                }
                NativeBackend.matmul(op, x, w)
            }
            fn name(&self) -> &'static str {
                "fail-once"
            }
        }
        let cfg = jigsaw::benchkit::synth_config("progress-pool", 96, 64, 2);
        let steady_misses = |cfg: &jigsaw::config::ModelConfig| -> f64 {
            let run_steps = |steps: usize| -> u64 {
                let spec = jigsaw::trainer::TrainSpec::quick(2, 2, steps).unwrap();
                let before = pool::stats();
                jigsaw::trainer::train(cfg, &spec, Arc::new(NativeBackend)).unwrap();
                pool::stats().1 - before.1
            };
            let m1 = run_steps(1);
            let m9 = run_steps(9);
            m9.saturating_sub(m1) as f64 / 8.0
        };
        let pre_misses = steady_misses(&cfg);
        let failing = Arc::new(FailOnceBackend {
            calls: AtomicUsize::new(0),
            fail_at: 40,
        });
        let spec = jigsaw::trainer::TrainSpec::quick(2, 2, 4).unwrap();
        let err = jigsaw::trainer::train(&cfg, &spec, failing).unwrap_err();
        assert!(err.to_string().contains("injected rank fault"), "{err}");
        let post_misses = steady_misses(&cfg);
        t.row(&[
            "pool steady-state after injected rank failure".into(),
            "2-way x dp 2".into(),
            format!("{post_misses:.1}"),
            format!("misses/step (pre-failure: {pre_misses:.1})"),
        ]);
        assert!(
            post_misses <= pre_misses + 0.51,
            "rank failure degraded steady-state pool behaviour: \
             {pre_misses:.2} -> {post_misses:.2} misses/step"
        );

        // ...and the recycling itself, observed on THIS thread (rank
        // threads die with their thread-local pools, so the train-level
        // comparison above is a health check, not a leak gate): rank 0 =
        // the bench main thread posts its buckets, the peer "dies"
        // (abort), the drain panics FABRIC_ABORTED, and the unwound
        // scheduler/engine must hand every in-flight bucket payload back
        // to this thread's pool. The free list is emptied first, so the
        // post-unwind probes can only HIT via that recycling.
        let held: Vec<Vec<f32>> = (0..32).map(|_| pool::take(1)).collect();
        let abort_net = Network::new(2);
        let mut abort_comm = abort_net.endpoint(0);
        let mut abort_grads = mk_store(0, n_buckets, side);
        let unwound = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut sched =
                GradReduceScheduler::new(&mut abort_comm, &[0, 1], bucket_elems);
            let order = abort_grads.grad_reduce_order();
            for id in &order {
                if let GradId::Mat(name, _) = id {
                    sched.mat_ready(name, &abort_grads.mats[name]);
                }
            }
            abort_net.abort(); // the peer rank dies mid-collective
            sched.finish(&mut abort_grads); // panics FABRIC_ABORTED
        }));
        assert!(unwound.is_err(), "finish must unwind on an aborted fabric");
        let (h0, m0) = pool::stats();
        let probes: Vec<Vec<f32>> =
            (0..n_buckets).map(|_| pool::take(side * side)).collect();
        let (h1, m1) = pool::stats();
        let abort_recycle_hits = h1 - h0;
        assert!(
            m1 == m0 && abort_recycle_hits >= n_buckets as u64,
            "abort unwind leaked in-flight bucket payloads instead of \
             recycling them (hits {h0}->{h1}, misses {m0}->{m1})"
        );
        for p in probes.into_iter().chain(held) {
            pool::put(p);
        }

        let progress_record = jobj(vec![
            ("bench", Json::Str("progress".into())),
            ("dp", jnum(dp as f64)),
            ("buckets", jnum(n_buckets as f64)),
            ("bucket_elems", jnum(bucket_elems as f64)),
            ("fabric_latency_us", jnum(400.0)),
            ("compute_window_ms", jnum(window.as_secs_f64() * 1e3)),
            ("emission_drain_us", jnum(emission_drain * 1e6)),
            ("engine_drain_us", jnum(engine_drain * 1e6)),
            ("drain_speedup", jnum(drain_speedup)),
            ("steady_misses_pre_failure", jnum(pre_misses)),
            ("steady_misses_post_failure", jnum(post_misses)),
            ("abort_unwind_recycle_hits", jnum(abort_recycle_hits as f64)),
        ]);
        std::fs::write("BENCH_progress.json", progress_record.to_string() + "\n")
            .unwrap();
        println!("BENCH_progress.json written");
        overlap.insert(
            "progress_drain".into(),
            jobj(vec![
                ("emission_drain_us", jnum(emission_drain * 1e6)),
                ("engine_drain_us", jnum(engine_drain * 1e6)),
                ("drain_speedup", jnum(drain_speedup)),
            ]),
        );
        assert!(
            engine_drain < emission_drain,
            "the progress engine must shrink the drain tail vs emission-only \
             polling: {:.0} us !< {:.0} us",
            engine_drain * 1e6,
            emission_drain * 1e6
        );
    }

    // receive-side backlog high-water mark under the ready-queue schedule
    {
        let net = Network::new(2);
        let a = net.endpoint(0);
        for i in 0..8 {
            a.send(1, 1, Tensor::scalar(i as f32));
        }
        let b = net.endpoint(1);
        for _ in 0..8 {
            let _ = b.recv(0, 1);
        }
        overlap.insert("max_queue_depth_probe".into(), jnum(net.max_queue_depth() as f64));
    }

    // what the cluster model predicts overlap is worth at paper scale
    {
        let c = jigsaw::perfmodel::ClusterSpec::horeka();
        let w = jigsaw::perfmodel::Workload {
            model: jigsaw::config::zoo::TABLE1[6],
            mesh: Mesh::from_degree(2).unwrap(),
            dp: 8,
            precision: jigsaw::perfmodel::Precision::Tf32,
            dataload: false,
        };
        let r = jigsaw::perfmodel::overlap_report(&c, &w);
        overlap.insert(
            "predicted_paper_scale".into(),
            jobj(vec![
                ("mp_hidden_s", jnum(r.mp_hidden)),
                ("dp_hidden_s", jnum(r.dp_hidden)),
                ("dp_drain_tail_s", jnum(r.dp_drain_tail)),
                ("blocking_total_s", jnum(r.blocking_total)),
                ("overlapped_total_s", jnum(r.overlapped_total)),
                ("predicted_speedup", jnum(r.predicted_speedup)),
            ]),
        );
    }

    overlap.insert("bench".into(), Json::Str("overlap".into()));
    std::fs::write("BENCH_overlap.json", Json::Obj(overlap).to_string() + "\n")
        .unwrap();
    println!("BENCH_overlap.json written");

    // ================= §Mesh: shape sweep through 8-/16-way ==============
    // The first-class mesh API: run the *real* engine's loss_and_grad
    // over every supported mesh shape of a fixed model and record
    // per-shape step wall time + fabric comm volume, next to what the
    // cluster model predicts for the same shapes at paper scale.
    {
        use jigsaw::model::dist::DistModel;
        use jigsaw::model::params::shard_params;
        use jigsaw::trainer::oracle::sample_shard;

        let cfg = jigsaw::benchkit::synth_config("mesh-bench", 64, 48, 2);
        let global = jigsaw::model::init_global_params(&cfg, 3);
        let mut d = vec![0.0; cfg.lat * cfg.lon * cfg.channels_padded];
        rng.fill_normal(&mut d, 1.0);
        let x = Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d.clone());
        rng.fill_normal(&mut d, 1.0);
        let y = Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d);

        // one full loss_and_grad over a fresh fabric: (wall s, bytes)
        let mesh_step = |mesh: Mesh| -> (f64, u64) {
            let net = Network::new(mesh.n());
            let t0 = std::time::Instant::now();
            let mut handles = Vec::new();
            for r in 0..mesh.n() {
                let cfg = cfg.clone();
                let params = shard_params(&cfg, &mesh, r, &global).unwrap();
                let mut comm = net.endpoint(r);
                let (x, y) = (x.clone(), y.clone());
                handles.push(std::thread::spawn(move || {
                    let b = NativeBackend;
                    let model = DistModel::new(cfg, &mesh, r, params);
                    let (la, _, lc) = model.local_dims();
                    let (lat0, ch0) = (model.lat_offset(), model.ch_offset());
                    let xl = sample_shard(&x, (lat0, lat0 + la), (ch0, ch0 + lc));
                    let yl = sample_shard(&y, (lat0, lat0 + la), (ch0, ch0 + lc));
                    let mut ctx = Ctx::new(mesh, r, &mut comm, &b);
                    model.loss_and_grad(&mut ctx, &xl, &yl, 1).unwrap();
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            (t0.elapsed().as_secs_f64(), net.total_bytes())
        };

        let shapes: Vec<Mesh> = [(1usize, 1usize), (1, 2), (2, 2), (2, 4), (4, 4)]
            .iter()
            .map(|&(tk, c)| Mesh::new(tk, c).unwrap())
            .collect();
        let cluster = jigsaw::perfmodel::ClusterSpec::horeka();
        let predicted = jigsaw::perfmodel::mesh_sweep(
            &cluster,
            jigsaw::config::zoo::TABLE1[6],
            jigsaw::perfmodel::Precision::Tf32,
            false,
            &shapes,
        );
        let mut mesh_rows: Vec<Json> = Vec::new();
        let mut bytes_by_n: Vec<(usize, u64)> = Vec::new();
        for (mesh, pred) in &predicted {
            mesh.validate_config(&cfg).unwrap();
            // warm the pools/caches once, then take the best of 3
            let _ = mesh_step(*mesh);
            let mut best = f64::INFINITY;
            let mut bytes = 0u64;
            for _ in 0..3 {
                let (secs, b) = mesh_step(*mesh);
                best = best.min(secs);
                bytes = b;
            }
            t.row(&[
                format!("loss_and_grad mesh {mesh} ({}-way)", mesh.n()),
                cfg.name.clone(),
                fmt(best * 1e6),
                format!("{} KiB fabric", bytes / 1024),
            ]);
            mesh_rows.push(jobj(vec![
                ("tok", jnum(mesh.tok() as f64)),
                ("ch", jnum(mesh.ch() as f64)),
                ("ranks", jnum(mesh.n() as f64)),
                ("step_us", jnum(best * 1e6)),
                ("fabric_bytes", jnum(bytes as f64)),
                ("predicted_step_s_16tf", jnum(pred.total)),
                ("predicted_mp_comm_s_16tf", jnum(pred.mp_comm)),
            ]));
            bytes_by_n.push((mesh.n(), bytes));
        }
        // sanity: 1x1 is comm-free; larger meshes communicate
        assert_eq!(bytes_by_n[0].1, 0, "1x1 mesh must not communicate");
        assert!(
            bytes_by_n.iter().skip(1).all(|&(_, b)| b > 0),
            "every multi-rank mesh exchanges blocks/partials"
        );
        let mesh_record = jobj(vec![
            ("bench", Json::Str("mesh".into())),
            ("config", Json::Str(cfg.name.clone())),
            ("shapes", Json::Arr(mesh_rows)),
        ]);
        std::fs::write("BENCH_mesh.json", mesh_record.to_string() + "\n").unwrap();
        println!("BENCH_mesh.json written");
    }

    // ================= §Precision: bf16 storage-and-fabric path ==========
    // The same 2x2-mesh x dp=2 training spec at both precisions: the byte
    // counters read the actual element size of every shipped payload, so
    // bf16 must land near half the f32 fabric volume with no special-
    // casing — only scalar reductions and tiny gather-to-root tensors
    // stay 4-byte. Steady-state pool behaviour must hold for the u16
    // buffers too: quantize-at-send / widen-at-receive recycles every
    // bf16 payload, so warm steps allocate nothing.
    {
        use jigsaw::tensor::Precision;

        let cfg = jigsaw::benchkit::synth_config("precision-bench", 96, 64, 2);
        let run = |prec: Precision, steps: usize| -> (u64, f64, u64) {
            let mut spec =
                jigsaw::trainer::TrainSpec::with_mesh(Mesh::new(2, 2).unwrap(), 2, steps);
            spec.precision = prec;
            let before = pool::stats();
            let t0 = std::time::Instant::now();
            let r = jigsaw::trainer::train(&cfg, &spec, Arc::new(NativeBackend)).unwrap();
            let wall = t0.elapsed().as_secs_f64();
            let after = pool::stats();
            (r.comm_bytes, wall, after.1 - before.1)
        };
        // warm pools per mode, then measure
        let _ = run(Precision::F32, 1);
        let (f32_bytes, f32_wall, _) = run(Precision::F32, 6);
        let (_, _, bf_cold_misses) = run(Precision::Bf16, 1);
        let (bf_bytes, bf_wall, bf_m9) = run(Precision::Bf16, 9);
        let bf_steady_misses = bf_m9.saturating_sub(bf_cold_misses) as f64 / 8.0;
        let ratio = bf_bytes as f64 / (f32_bytes as f64 / 6.0 * 9.0);
        t.row(&[
            "train fabric bytes bf16 vs f32".into(),
            "2x2 mesh x dp 2".into(),
            format!("{}", bf_bytes / 1024),
            format!("KiB ({ratio:.2}x of f32 volume)"),
        ]);
        t.row(&[
            "bf16 pool steady-state".into(),
            "2x2 mesh x dp 2".into(),
            format!("{bf_steady_misses:.1}"),
            format!("misses/step (cold step: {bf_cold_misses})"),
        ]);
        assert!(
            ratio > 0.45 && ratio < 0.65,
            "bf16 must ship about half the f32 fabric bytes, got {ratio:.3} \
             (bf16 {bf_bytes} B/9 steps vs f32 {f32_bytes} B/6 steps)"
        );
        assert!(
            bf_steady_misses < 1.0,
            "bf16 u16 payload buffers must recycle to a steady state, got \
             {bf_steady_misses:.1} misses/step"
        );
        let precision_record = jobj(vec![
            ("bench", Json::Str("precision".into())),
            ("mesh", Json::Str("2x2".into())),
            ("dp", jnum(2.0)),
            ("f32_bytes_per_step", jnum(f32_bytes as f64 / 6.0)),
            ("bf16_bytes_per_step", jnum(bf_bytes as f64 / 9.0)),
            ("byte_ratio", jnum(ratio)),
            ("f32_step_wall_us", jnum(f32_wall / 6.0 * 1e6)),
            ("bf16_step_wall_us", jnum(bf_wall / 9.0 * 1e6)),
            ("bf16_steady_misses_per_step", jnum(bf_steady_misses)),
            ("bf16_cold_step_misses", jnum(bf_cold_misses as f64)),
        ]);
        std::fs::write("BENCH_precision.json", precision_record.to_string() + "\n")
            .unwrap();
        println!("BENCH_precision.json written");
    }

    println!("{}", t.render());
    t.write_csv(&csv_path("hotpath_micro")).unwrap();

    // machine-readable perf record for the trajectory
    record.insert("bench".into(), Json::Str("kernels".into()));
    record.insert(
        "kernel_threads_env".into(),
        jnum(ops::kernel_threads() as f64),
    );
    record.insert("matmul".into(), Json::Arr(matmul_records));
    record.insert(
        "min_nt_speedup_256plus".into(),
        jnum(min_nt_speedup_256plus),
    );
    std::fs::write("BENCH_kernels.json", Json::Obj(record).to_string() + "\n").unwrap();
    println!(
        "BENCH_kernels.json written (min nt speedup @>=256: {:.1}x)",
        min_nt_speedup_256plus
    );

    // smoke: backend matmul equals the naive oracle
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
    let x = rand_t(&mut rng, 8, 8);
    let w = rand_t(&mut rng, 8, 8);
    let a = backend.matmul(MatmulOp::NT, &x, &w).unwrap();
    assert!(a.max_abs_diff(&ref_kernels::matmul_nt(&x, &w)) < 1e-5);
    println!("hotpath_micro OK");
}
