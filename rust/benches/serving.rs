//! Serving bench: queries/sec and tail latency of the forecast serving
//! engine versus trajectory-cache size, under injected fabric latency.
//!
//! Three measurements:
//!
//!   * **recompute** — the no-cache baseline: every regional query rolls
//!     its initial condition forward to the requested lead from scratch
//!     on the raw [`RolloutEngine`];
//!   * **cache sweep** — the same seeded query stream through a
//!     [`ServeEngine`] at several `--cache-states` capacities: one warm
//!     pass, then a measured pass reporting qps / p50 / p99 / hit rate;
//!   * **gate** — cached regional queries must be >10x faster than the
//!     recompute baseline at the largest cache size (the entire point of
//!     keying assembled states by `(init, lead)` and answering windows
//!     as O(1) views).
//!
//! Writes BENCH_serving.json.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use jigsaw::benchkit::{banner, synth_config, TrafficGen};
use jigsaw::comm::FabricSpec;
use jigsaw::jigsaw::Mesh;
use jigsaw::model::init_global_params;
use jigsaw::runtime::native::NativeBackend;
use jigsaw::serve::{RegionQuery, RolloutEngine, ServeEngine};
use jigsaw::tensor::{Precision, Tensor};
use jigsaw::util::json::Json;
use jigsaw::util::table::{fmt, Table};

const SEED: u64 = 0xCAFE;
const FABRIC_LATENCY_US: u64 = 200;
const N_INITS: usize = 2;
const MAX_LEAD: usize = 6;
const N_QUERIES: usize = 40;
const CACHE_SIZES: [usize; 3] = [2, 8, 32];

fn inits(cfg: &jigsaw::config::ModelConfig) -> Vec<(u64, Tensor)> {
    let mut rng = jigsaw::util::rng::Rng::seed_from(SEED ^ 0x5EED_1D);
    (0..N_INITS as u64)
        .map(|id| {
            let mut d = vec![0.0f32; cfg.lat * cfg.lon * cfg.channels_padded];
            rng.fill_normal(&mut d, 1.0);
            (id, Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d))
        })
        .collect()
}

fn engine(
    cfg: &jigsaw::config::ModelConfig,
    mesh: &Mesh,
    global: &[(String, Tensor)],
) -> RolloutEngine {
    let e = RolloutEngine::new(
        cfg,
        mesh,
        global,
        Arc::new(NativeBackend),
        Precision::F32,
        1,
    )
    .expect("rollout engine");
    e.set_fabric(
        FabricSpec::from_us(FABRIC_LATENCY_US, FABRIC_LATENCY_US / 4, 1.0),
        SEED,
    );
    e
}

fn queries(cfg: &jigsaw::config::ModelConfig) -> Vec<RegionQuery> {
    let mut gen =
        TrafficGen::new(SEED, N_INITS as u64, MAX_LEAD, cfg.lat, cfg.lon);
    (0..N_QUERIES).map(|_| gen.next_query()).collect()
}

fn percentile(sorted_us: &[f64], p: usize) -> f64 {
    sorted_us[(sorted_us.len() * p / 100).min(sorted_us.len() - 1)]
}

fn main() {
    banner("serving", "forecast serving qps/p99 vs trajectory-cache size");
    let cfg = synth_config("serving-bench", 64, 48, 2);
    let mesh = Mesh::new(1, 2).unwrap();
    let global = init_global_params(&cfg, SEED);
    let qs = queries(&cfg);

    let mut record: BTreeMap<String, Json> = BTreeMap::new();
    record.insert("config".into(), Json::Str(cfg.name.clone()));
    record.insert("mesh".into(), Json::Str(mesh.to_string()));
    record.insert("fabric_latency_us".into(), Json::Num(FABRIC_LATENCY_US as f64));
    record.insert("queries".into(), Json::Num(N_QUERIES as f64));
    record.insert("max_lead".into(), Json::Num(MAX_LEAD as f64));

    // --- recompute baseline: every query rolls from its init ---
    let mut eng = engine(&cfg, &mesh, &global);
    let init_states = inits(&cfg);
    let mut lat_us = Vec::with_capacity(qs.len());
    for q in &qs {
        let t0 = Instant::now();
        let mut state = init_states[q.init_id as usize].1.clone();
        for _ in 0..q.lead {
            state = eng.step(&state).expect("rollout step");
        }
        let (lat0, lon0) = (q.lat.0, q.lon.0);
        std::hint::black_box(
            state.data[(lat0 * cfg.lon + lon0) * cfg.channels_padded],
        );
        lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    drop(eng);
    let recompute_mean = lat_us.iter().sum::<f64>() / lat_us.len() as f64;
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut t = Table::new(&[
        "cache", "queries/s", "p50 (us)", "p99 (us)", "hit rate", "evict",
    ]);
    t.row(&[
        "recompute".into(),
        fmt(1e6 / recompute_mean),
        fmt(percentile(&lat_us, 50)),
        fmt(percentile(&lat_us, 99)),
        "-".into(),
        "-".into(),
    ]);
    record.insert("recompute_mean_us".into(), Json::Num(recompute_mean));

    // --- cache sweep: warm pass, then a measured pass over the same
    //     stream (lead-0 queries excluded from the latency stats so the
    //     gate measures cached *rollout* states, not init passthrough) ---
    let mut sweep = Vec::new();
    let mut largest_cached_mean = f64::INFINITY;
    for cache_states in CACHE_SIZES {
        let mut srv =
            ServeEngine::new(engine(&cfg, &mesh, &global), cache_states, MAX_LEAD, true);
        for (id, s) in inits(&cfg) {
            srv.add_init(id, s).expect("init");
        }
        for q in &qs {
            std::hint::black_box(srv.answer(*q).expect("warm query").view().at(0, 0));
        }
        srv.counters().reset();
        let mut lat_us = Vec::new();
        let t0 = Instant::now();
        for q in &qs {
            let qt = Instant::now();
            std::hint::black_box(srv.answer(*q).expect("query").view().at(0, 0));
            if q.lead > 0 {
                lat_us.push(qt.elapsed().as_secs_f64() * 1e6);
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = srv.stats();
        lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = lat_us.iter().sum::<f64>() / lat_us.len() as f64;
        let qps = qs.len() as f64 / wall;
        t.row(&[
            format!("{cache_states}"),
            fmt(qps),
            fmt(percentile(&lat_us, 50)),
            fmt(percentile(&lat_us, 99)),
            fmt(stats.hit_rate()),
            fmt(stats.evictions as f64),
        ]);
        let mut row: BTreeMap<String, Json> = BTreeMap::new();
        row.insert("cache_states".into(), Json::Num(cache_states as f64));
        row.insert("qps".into(), Json::Num(qps));
        row.insert("p50_us".into(), Json::Num(percentile(&lat_us, 50)));
        row.insert("p99_us".into(), Json::Num(percentile(&lat_us, 99)));
        row.insert("mean_us".into(), Json::Num(mean));
        row.insert("hit_rate".into(), Json::Num(stats.hit_rate()));
        row.insert("evictions".into(), Json::Num(stats.evictions as f64));
        row.insert("prefetches".into(), Json::Num(stats.prefetches as f64));
        sweep.push(Json::Obj(row));
        largest_cached_mean = mean;
    }
    record.insert("sweep".into(), Json::Arr(sweep));

    // --- gate: cached queries must beat recompute by >10x at the
    //     largest cache (every state the stream touches fits) ---
    let speedup = recompute_mean / largest_cached_mean;
    record.insert("speedup_at_largest_cache".into(), Json::Num(speedup));
    println!("{}", t.render());
    println!(
        "cached mean {largest_cached_mean:.1} us vs recompute mean {recompute_mean:.1} us -> {speedup:.1}x"
    );
    assert!(
        speedup > 10.0,
        "cached regional queries must be >10x the recompute baseline, got {speedup:.1}x"
    );

    std::fs::write("BENCH_serving.json", Json::Obj(record).to_string() + "\n").unwrap();
    println!("BENCH_serving.json written");
}
