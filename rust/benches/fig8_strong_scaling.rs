//! Paper Fig 8: strong scaling of 1/4/16-TFLOP models over 1/2/4-way
//! jigsaw, in the four quadrants {no data loading, full loop} x
//! {fp32, TF32}, with the Megatron-LM reference speedups, plus a
//! *measured* strong-scaling run of the real engine at `tiny`/`small`
//! scale (wallclock + comm bytes on this testbed).
//!
//! Paper anchors: fp32 no-dataload 1.4B speedups 1.9 / 2.7 vs
//! Megatron-LM's 1.6 / 2.3.

use std::sync::Arc;

use jigsaw::baselines::{MEGATRON_STRONG_2WAY, MEGATRON_STRONG_4WAY};
use jigsaw::benchkit::{banner, csv_path, time_best};
use jigsaw::config::zoo::{ZooModel, TABLE1};
use jigsaw::perfmodel::{strong_speedup, ClusterSpec, Precision};
use jigsaw::runtime::native::NativeBackend;
use jigsaw::runtime::Backend;
use jigsaw::tensor::Tensor;
use jigsaw::trainer::oracle::run_dist_loss_and_grad;
use jigsaw::util::rng::Rng;
use jigsaw::util::table::{fmt, Table};

fn main() {
    let cluster = ClusterSpec::horeka();
    let models: [ZooModel; 3] = [TABLE1[2], TABLE1[4], TABLE1[6]]; // 1/4/16 TF

    for (dataload, dl_name) in [(false, "no data loading"), (true, "full training loop")] {
        for precision in [Precision::Fp32, Precision::Tf32] {
            banner("Fig 8", &format!("strong scaling, {precision:?}, {dl_name}"));
            let mut t =
                Table::new(&["model TFLOPs", "2-way speedup", "4-way speedup"]);
            for m in models {
                t.row(&[
                    fmt(m.tflops_fwd),
                    fmt(strong_speedup(&cluster, m, 2, precision, dataload)),
                    fmt(strong_speedup(&cluster, m, 4, precision, dataload)),
                ]);
            }
            t.row(&[
                "Megatron-LM (1.2B, paper ref)".into(),
                fmt(MEGATRON_STRONG_2WAY),
                fmt(MEGATRON_STRONG_4WAY),
            ]);
            println!("{}", t.render());
            let tag = format!(
                "fig8_strong_{}_{}",
                if dataload { "full" } else { "nodata" },
                match precision {
                    Precision::Fp32 => "fp32",
                    Precision::Tf32 => "tf32",
                }
            );
            t.write_csv(&csv_path(&tag)).unwrap();
        }
    }

    // anchor: fp32 no-dataload 16TF beats Megatron on both ways
    let s2 = strong_speedup(&cluster, TABLE1[6], 2, Precision::Fp32, false);
    let s4 = strong_speedup(&cluster, TABLE1[6], 4, Precision::Fp32, false);
    assert!(s2 > MEGATRON_STRONG_2WAY && s4 > MEGATRON_STRONG_4WAY,
        "jigsaw must beat Megatron in compute-bound fp32: {s2} {s4}");

    // -- measured strong scaling on the real engine (CPU testbed) ---------
    banner("Fig 8 (measured)", "real jigsaw engine, tiny preset, native backend");
    let cfg = jigsaw::config::ModelConfig::load(
        &jigsaw::config::artifacts_dir(), "tiny").expect("artifacts");
    let global = jigsaw::model::init_global_params(&cfg, 0);
    let mut rng = Rng::seed_from(1);
    let mut d = vec![0.0; cfg.lat * cfg.lon * cfg.channels_padded];
    rng.fill_normal(&mut d, 1.0);
    let x = Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d.clone());
    rng.fill_normal(&mut d, 1.0);
    let y = Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d);
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
    let mut t = Table::new(&["way", "step wall (ms)", "note"]);
    for way in [1usize, 2, 4] {
        let secs = time_best(3, || {
            run_dist_loss_and_grad(&cfg, way, &global, &x, &y, backend.clone(), 1)
                .unwrap();
        });
        t.row(&[
            way.to_string(),
            fmt(secs * 1e3),
            "single-core: concurrency not parallelism".into(),
        ]);
    }
    println!("{}", t.render());
    t.write_csv(&csv_path("fig8_measured_cpu")).unwrap();
    println!("Fig 8 regenerated — OK");
}
