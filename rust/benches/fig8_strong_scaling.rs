//! Paper Fig 8: strong scaling of 1/4/16-TFLOP models over jigsaw
//! meshes, in the four quadrants {no data loading, full loop} x
//! {fp32, TF32}, with the Megatron-LM reference speedups, plus a
//! *measured* strong-scaling run of the real engine at `tiny`/`small`
//! scale (wallclock + comm bytes on this testbed).
//!
//! Paper anchors: fp32 no-dataload 1.4B speedups 1.9 / 2.7 vs
//! Megatron-LM's 1.6 / 2.3. Beyond the paper: the mesh API sweeps the
//! 8-way (2x4) and 16-way (4x4) regimes the hand-written layouts could
//! not express, including the flat-vs-square comparison at degree 4.

use std::sync::Arc;

use jigsaw::baselines::{MEGATRON_STRONG_2WAY, MEGATRON_STRONG_4WAY};
use jigsaw::benchkit::{banner, csv_path, time_best};
use jigsaw::config::zoo::{ZooModel, TABLE1};
use jigsaw::jigsaw::Mesh;
use jigsaw::perfmodel::{strong_speedup, ClusterSpec, Precision};
use jigsaw::runtime::native::NativeBackend;
use jigsaw::runtime::Backend;
use jigsaw::tensor::Tensor;
use jigsaw::trainer::oracle::run_dist_loss_and_grad;
use jigsaw::util::rng::Rng;
use jigsaw::util::table::{fmt, Table};

fn main() {
    let cluster = ClusterSpec::horeka();
    let models: [ZooModel; 3] = [TABLE1[2], TABLE1[4], TABLE1[6]]; // 1/4/16 TF
    let mesh2 = Mesh::from_degree(2).unwrap();
    let mesh4 = Mesh::from_degree(4).unwrap();

    for (dataload, dl_name) in [(false, "no data loading"), (true, "full training loop")] {
        for precision in [Precision::Fp32, Precision::Tf32] {
            banner("Fig 8", &format!("strong scaling, {precision:?}, {dl_name}"));
            let mut t =
                Table::new(&["model TFLOPs", "2-way speedup", "4-way speedup"]);
            for m in models {
                t.row(&[
                    fmt(m.tflops_fwd),
                    fmt(strong_speedup(&cluster, m, &mesh2, precision, dataload)),
                    fmt(strong_speedup(&cluster, m, &mesh4, precision, dataload)),
                ]);
            }
            t.row(&[
                "Megatron-LM (1.2B, paper ref)".into(),
                fmt(MEGATRON_STRONG_2WAY),
                fmt(MEGATRON_STRONG_4WAY),
            ]);
            println!("{}", t.render());
            let tag = format!(
                "fig8_strong_{}_{}",
                if dataload { "full" } else { "nodata" },
                match precision {
                    Precision::Fp32 => "fp32",
                    Precision::Tf32 => "tf32",
                }
            );
            t.write_csv(&csv_path(&tag)).unwrap();
        }
    }

    // anchor: fp32 no-dataload 16TF beats Megatron on both ways
    let s2 = strong_speedup(&cluster, TABLE1[6], &mesh2, Precision::Fp32, false);
    let s4 = strong_speedup(&cluster, TABLE1[6], &mesh4, Precision::Fp32, false);
    assert!(s2 > MEGATRON_STRONG_2WAY && s4 > MEGATRON_STRONG_4WAY,
        "jigsaw must beat Megatron in compute-bound fp32: {s2} {s4}");

    // -- mesh-shape sweep through 8-/16-way (beyond the paper) ------------
    banner("Fig 8 (mesh sweep)", "strong scaling over mesh shapes, fp32 no-dataload");
    let sweep_meshes: Vec<Mesh> = [(1usize, 2usize), (2, 2), (1, 4), (2, 4), (4, 4)]
        .iter()
        .map(|&(t, c)| Mesh::new(t, c).unwrap())
        .collect();
    let mut t = Table::new(&["model TFLOPs", "1x2", "2x2", "1x4", "2x4", "4x4"]);
    for m in models {
        let mut row = vec![fmt(m.tflops_fwd)];
        for mesh in &sweep_meshes {
            row.push(fmt(strong_speedup(&cluster, m, mesh, Precision::Fp32, false)));
        }
        t.row(&row);
    }
    println!("{}", t.render());
    t.write_csv(&csv_path("fig8_mesh_sweep")).unwrap();
    // larger meshes keep helping the biggest model in the compute-bound
    // regime even after the contention premium
    let s8 = strong_speedup(
        &cluster, TABLE1[6], &Mesh::new(2, 4).unwrap(), Precision::Fp32, false);
    assert!(s8 > s4, "8-way must extend the 16TF fp32 speedup: {s4} -> {s8}");

    // -- measured strong scaling on the real engine (CPU testbed) ---------
    banner("Fig 8 (measured)", "real jigsaw engine, tiny preset, native backend");
    let cfg = jigsaw::config::ModelConfig::load(
        &jigsaw::config::artifacts_dir(), "tiny").expect("artifacts");
    let global = jigsaw::model::init_global_params(&cfg, 0);
    let mut rng = Rng::seed_from(1);
    let mut d = vec![0.0; cfg.lat * cfg.lon * cfg.channels_padded];
    rng.fill_normal(&mut d, 1.0);
    let x = Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d.clone());
    rng.fill_normal(&mut d, 1.0);
    let y = Tensor::new(vec![cfg.lat, cfg.lon, cfg.channels_padded], d);
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
    let mut t = Table::new(&["mesh", "step wall (ms)", "note"]);
    for way in [1usize, 2, 4, 8] {
        let mesh = Mesh::from_degree(way).unwrap();
        if mesh.validate_config(&cfg).is_err() {
            continue;
        }
        let secs = time_best(3, || {
            run_dist_loss_and_grad(&cfg, &mesh, &global, &x, &y, backend.clone(), 1)
                .unwrap();
        });
        t.row(&[
            mesh.to_string(),
            fmt(secs * 1e3),
            "single-core: concurrency not parallelism".into(),
        ]);
    }
    println!("{}", t.render());
    t.write_csv(&csv_path("fig8_measured_cpu")).unwrap();
    println!("Fig 8 regenerated — OK");
}
