//! Paper Fig 5: one-step (6h) validation RMSE of the best WM model vs
//! reference forecasts. The paper compares against Pangu-Weather and IFS;
//! on the synthetic substrate the reference baselines are persistence and
//! climatology (the standard sanity references). Anchor: the trained
//! model must beat both for (nearly) all channels.

use std::sync::Arc;

use jigsaw::benchkit::{banner, csv_path, synth_config};
use jigsaw::comm::Network;
use jigsaw::data::ShardedLoader;
use jigsaw::jigsaw::{Ctx, Mesh};
use jigsaw::metrics::lat_weighted_rmse;
use jigsaw::model::dist::DistModel;
use jigsaw::model::params::shard_params;
use jigsaw::runtime::native::NativeBackend;
use jigsaw::runtime::Backend;
use jigsaw::tensor::ops;
use jigsaw::trainer::{train, TrainSpec};
use jigsaw::util::table::{fmt, Table};

fn main() {
    banner("Fig 5", "one-step RMSE vs persistence/climatology baselines");
    let cfg = synth_config("wm-best", 96, 64, 2);
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
    let mut spec = TrainSpec::quick(2, 1, 220).unwrap();
    spec.lr = 2e-3;
    spec.n_times = 48;
    spec.n_modes = 12;
    spec.seed = 4;
    let r = train(&cfg, &spec, backend.clone()).unwrap();
    println!(
        "trained 2-way WM: loss {:.4} -> {:.4}",
        r.steps.first().unwrap().loss,
        r.steps.last().unwrap().loss
    );

    // evaluate on 1 rank with the reassembled parameters
    let store = shard_params(&cfg, &Mesh::unit(), 0, &r.final_params).unwrap();
    let model = DistModel::new(cfg.clone(), &Mesh::unit(), 0, store);
    let mut loader =
        ShardedLoader::new(&cfg, &Mesh::unit(), 0, 8, 1, 77, spec.n_modes).unwrap();
    let net = Network::new(1);
    let mut comm = net.endpoint(0);

    let val_times = [300usize, 310, 320, 330];
    let mut rmse_model = vec![0.0f32; cfg.channels_padded];
    let mut rmse_persist = vec![0.0f32; cfg.channels_padded];
    let mut rmse_climo = vec![0.0f32; cfg.channels_padded];
    let climo_samples: Vec<_> = (0..8).map(|i| loader.read_shard(i as f32 * 13.0).0).collect();
    let climo = jigsaw::metrics::climatology_forecast(&climo_samples);
    for &t0 in &val_times {
        let (x, _) = loader.read_shard(t0 as f32);
        let (y, _) = loader.read_shard((t0 + 1) as f32);
        let mut ctx = Ctx::new(Mesh::unit(), 0, &mut comm, backend.as_ref());
        let (pred, _) = model.forward(&mut ctx, &x, 1).unwrap();
        for (acc, p) in [
            (&mut rmse_model, &pred),
            (&mut rmse_persist, &x),
            (&mut rmse_climo, &climo),
        ] {
            let r = lat_weighted_rmse(p, &y, cfg.lat, 0);
            for (a, v) in acc.iter_mut().zip(r) {
                *a += v / val_times.len() as f32;
            }
        }
    }

    let names = ["u10", "v10", "t2m", "msl", "z1000", "z925", "z850", "z700"];
    let mut t = Table::new(&["channel", "WM", "persistence", "climatology"]);
    for (c, name) in names.iter().enumerate() {
        t.row(&[
            name.to_string(),
            fmt(rmse_model[c] as f64),
            fmt(rmse_persist[c] as f64),
            fmt(rmse_climo[c] as f64),
        ]);
    }
    println!("{}", t.render());
    t.write_csv(&csv_path("fig5_onestep_rmse")).unwrap();

    let wins = (0..cfg.channels)
        .filter(|&c| rmse_model[c] < rmse_persist[c] && rmse_model[c] < rmse_climo[c])
        .count();
    assert!(
        wins * 10 >= cfg.channels * 8,
        "WM must beat both baselines on >=80% of channels (got {wins}/{})",
        cfg.channels
    );
    let _ = ops::sigmoid(0.0);
    println!("WM beats persistence+climatology on {wins}/{} channels — OK", cfg.channels);
}
