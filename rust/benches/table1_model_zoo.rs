//! Paper Table 1: the nine scaling-experiment architectures. Regenerates
//! the table from the config zoo and checks the workload-doubling rule.

use jigsaw::benchkit::{banner, csv_path};
use jigsaw::config::zoo::TABLE1;
use jigsaw::util::table::{fmt, Table};

fn main() {
    banner("Table 1", "model architectures in scaling experiments");
    let mut t = Table::new(&[
        "Model #", "TFLOPs", "Params (mil)", "d_emb", "d_tok", "d_ch",
        "step FLOPs (T)", "weights (GB)",
    ]);
    for m in TABLE1 {
        t.row(&[
            m.id.to_string(),
            fmt(m.tflops_fwd),
            fmt(m.params_mil),
            m.d_emb.to_string(),
            m.d_tok.to_string(),
            m.d_ch.to_string(),
            fmt(m.flops_step() / 1e12),
            fmt(m.param_bytes() / 1e9),
        ]);
    }
    println!("{}", t.render());
    t.write_csv(&csv_path("table1_model_zoo")).unwrap();

    // the paper's construction rules
    for w in TABLE1.windows(2) {
        assert!((w[1].tflops_fwd / w[0].tflops_fwd - 2.0).abs() < 1e-9);
    }
    // 40 GB A100 bound: the largest single-GPU model is #7 (~1.4B)
    assert!(TABLE1[6].param_bytes() < 6e9);
    println!("workload doubles per row; model 7 is the largest single-GPU fit — OK");
}
