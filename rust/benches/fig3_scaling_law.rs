//! Paper Fig 3: validation loss of three increasingly large WeatherMixers
//! on the same (synthetic-ERA5) dataset — the neural-scaling-law premise
//! that motivates jigsaw. Real training through the rust engine.

use std::sync::Arc;

use jigsaw::benchkit::{banner, csv_path, synth_config};
use jigsaw::runtime::native::NativeBackend;
use jigsaw::runtime::Backend;
use jigsaw::trainer::{train, TrainSpec};
use jigsaw::util::table::{fmt, Table};

fn main() {
    banner("Fig 3", "validation loss vs model size (scaled-down WM)");
    // ~250M : 500M : 1B in the paper -> three capacities in ratio here
    let sizes = [
        ("wm-250 analog", 48usize, 48usize, 2usize),
        ("wm-500 analog", 96, 64, 2),
        ("wm-1b analog", 192, 96, 3),
    ];
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);
    let mut t = Table::new(&["model", "params (M)", "val loss (mid)", "val loss (final)"]);
    let mut finals = Vec::new();
    for (name, d_emb, d_tok, blocks) in sizes {
        let cfg = synth_config(name, d_emb, d_tok, blocks);
        let mut spec = TrainSpec::quick(1, 1, 120).unwrap();
        spec.lr = 2e-3;
        spec.n_times = 40;
        spec.n_modes = 14;
        spec.val_every = 60;
        spec.seed = 5;
        let r = train(&cfg, &spec, backend.clone()).unwrap();
        let mid = r.val_loss.first().map(|(_, v)| *v).unwrap_or(f32::NAN);
        let fin = r.val_loss.last().map(|(_, v)| *v).unwrap_or(f32::NAN);
        finals.push(fin);
        t.row(&[
            name.to_string(),
            fmt(cfg.param_count as f64 / 1e6),
            fmt(mid as f64),
            fmt(fin as f64),
        ]);
    }
    println!("{}", t.render());
    t.write_csv(&csv_path("fig3_scaling_law")).unwrap();
    assert!(
        finals[0] > finals[1] && finals[1] > finals[2],
        "larger models must reach lower val loss: {finals:?}"
    );
    println!("scaling law reproduced: bigger WM -> lower val loss — OK");
}
