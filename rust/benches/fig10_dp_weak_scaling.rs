//! Paper Fig 10: system-wide weak scaling efficiency combining intra-node
//! jigsaw MP with inter-node DP, up to 256 GPUs.
//!
//! Anchors: at 256 GPUs the paper reports 51% (1-way), 68% (2-way), 72%
//! (4-way) efficiency and 11 / 9 PFLOPs aggregate for 2-/4-way — MP
//! shards the gradients, shrinking the DP allreduce volume, so the MP
//! configurations scale better across the system.

use jigsaw::benchkit::{banner, csv_path};
use jigsaw::cli::nearest_model;
use jigsaw::config::zoo::TABLE2;
use jigsaw::perfmodel::{simulate_step, ClusterSpec, Precision, Workload};
use jigsaw::util::table::{fmt, Table};

fn main() {
    banner("Fig 10", "DP weak scaling efficiency to 256 GPUs (TF32)");
    let cluster = ClusterSpec::horeka();
    let gpus = [1usize, 2, 4, 8, 16, 32, 64, 128, 256];

    let mut header: Vec<String> = vec!["way".into()];
    header.extend(gpus.iter().map(|g| format!("{g}")));
    header.push("PFLOPs@256".into());
    let refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&refs);

    let mut eff256 = Vec::new();
    for plan in TABLE2 {
        let model = nearest_model(plan);
        let base = Workload {
            model,
            mesh: plan.mesh().unwrap(),
            dp: 1,
            precision: Precision::Tf32,
            dataload: true,
        };
        let t_base = simulate_step(&cluster, &base).total;
        let mut row = vec![format!("{}-way", plan.way)];
        let mut last_eff = 0.0;
        let mut last_flops = 0.0;
        for g in gpus {
            match plan.dp_instances(g) {
                None => row.push("-".into()),
                Some(dp) => {
                    let w = Workload { dp, ..base.clone() };
                    let tt = simulate_step(&cluster, &w).total;
                    let eff = t_base / tt;
                    last_eff = eff;
                    last_flops = model.flops_step() * dp as f64 / tt;
                    row.push(fmt(eff));
                }
            }
        }
        row.push(fmt(last_flops / 1e15));
        eff256.push((plan.way, last_eff));
        t.row(&row);
    }
    println!("{}", t.render());
    t.write_csv(&csv_path("fig10_dp_weak_scaling")).unwrap();

    // anchor: MP configurations scale better than the native 1-way
    let e1 = eff256[0].1;
    let e2 = eff256[1].1;
    let e4 = eff256[2].1;
    assert!(e2 > e1 && e4 > e1,
        "MP must out-scale 1-way at 256 GPUs: {e1:.2} {e2:.2} {e4:.2}");
    println!(
        "efficiency at 256 GPUs: 1-way {:.0}%, 2-way {:.0}%, 4-way {:.0}% (paper: 51/68/72) — OK",
        e1 * 100.0, e2 * 100.0, e4 * 100.0
    );
}
