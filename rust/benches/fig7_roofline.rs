//! Paper Fig 7: roofline of training throughput over model workload for
//! 1-/2-/4-way jigsaw, uniform fp32 (left) and mixed TF32 (right).
//!
//! Shape anchors from the paper: fp32 crosses from the I/O-bound to the
//! compute-bound regime around 1 TFLOP/fwd; 2-way reaches near-unity
//! performance vs 1-way in the compute-bound regime; TF32 stays I/O-bound
//! far longer, and parallel models beat 1-way for small models because
//! domain parallelism divides the read volume.

use jigsaw::benchkit::{banner, csv_path};
use jigsaw::config::zoo::TABLE1;
use jigsaw::jigsaw::Mesh;
use jigsaw::perfmodel::{
    flops_per_gpu, simulate_step, ClusterSpec, Precision, Workload,
};
use jigsaw::util::table::{fmt, Table};

fn main() {
    let cluster = ClusterSpec::horeka();
    // x-axis is TFLOPs per forward pass PER GPU (paper Section 6.3): an
    // n-way point at x TF/GPU runs the Table-1 model with n*x total TF.
    let model_at = |tf: f64| TABLE1.iter().copied().find(|m| (m.tflops_fwd - tf).abs() < 1e-9);
    for precision in [Precision::Fp32, Precision::Tf32] {
        banner("Fig 7", &format!("roofline, {precision:?}, full training loop"));
        let mut t = Table::new(&[
            "TFLOPs/fwd/GPU", "1-way TF/s", "2-way TF/s", "4-way TF/s", "1-way regime",
        ]);
        for m in TABLE1.iter().take(7) {
            let perf = |way: usize| -> String {
                match model_at(m.tflops_fwd * way as f64) {
                    None => "-".into(),
                    Some(scaled) => {
                        let mesh = Mesh::from_degree(way).unwrap();
                        let w = Workload {
                            model: scaled, mesh, dp: 1, precision, dataload: true,
                        };
                        fmt(flops_per_gpu(&cluster, &w) / 1e12)
                    }
                }
            };
            let st = simulate_step(
                &cluster,
                &Workload { model: *m, mesh: Mesh::unit(), dp: 1, precision, dataload: true },
            );
            let regime = if st.io >= st.total { "I/O-bound" } else { "compute-bound" };
            t.row(&[
                fmt(m.tflops_fwd),
                perf(1),
                perf(2),
                perf(4),
                regime.to_string(),
            ]);
        }
        println!("{}", t.render());
        let tag = match precision {
            Precision::Fp32 => "fig7_roofline_fp32",
            Precision::Tf32 => "fig7_roofline_tf32",
        };
        t.write_csv(&csv_path(tag)).unwrap();
    }

    // -- anchor assertions -------------------------------------------------
    let frac = |m: usize, way: usize, p: Precision, dl: bool| {
        let mesh = Mesh::from_degree(way).unwrap();
        let w = Workload { model: TABLE1[m], mesh, dp: 1, precision: p, dataload: dl };
        flops_per_gpu(&cluster, &w) / p.peak_flops()
    };
    // fp32 1-way reaches ~81% of peak in the compute-bound regime
    let f = frac(6, 1, Precision::Fp32, false);
    assert!((f - 0.81).abs() < 0.02, "fp32 baseline {f}");
    // tf32 1-way ~43% at the largest single-GPU workload
    let f = frac(6, 1, Precision::Tf32, false);
    assert!((f - 0.43).abs() < 0.03, "tf32 baseline {f}");
    // 2-way reaches near-unity relative performance in compute-bound fp32
    let rel = frac(6, 2, Precision::Fp32, true) / frac(6, 1, Precision::Fp32, true);
    assert!(rel > 0.8, "2-way relative perf {rel}");
    // small per-GPU workloads: parallel beats 1-way under TF32 (I/O-bound,
    // Fig 7 right) — 4-way at 0.25 TF/GPU runs the 1-TF model
    let w1 = flops_per_gpu(&cluster, &Workload {
        model: TABLE1[0], mesh: Mesh::unit(), dp: 1,
        precision: Precision::Tf32, dataload: true });
    let w4 = flops_per_gpu(&cluster, &Workload {
        model: TABLE1[2], mesh: Mesh::from_degree(4).unwrap(), dp: 1,
        precision: Precision::Tf32, dataload: true });
    assert!(w4 > w1, "domain parallelism must win the I/O-bound regime: {w1} vs {w4}");
    println!("roofline anchors reproduced (81%/43% baselines, 2-way near-unity, I/O-bound wins) — OK");
}
