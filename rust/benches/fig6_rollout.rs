//! Paper Fig 6: validation RMSE over rolled-out lead times (up to 20
//! 6h-steps = 120h), after randomized-rollout fine-tuning.
//!
//! Shape anchors: RMSE grows with lead time; the fine-tuned model stays
//! stable (finite, beats persistence at short leads) over 20 steps — the
//! paper's point is that MP makes this fine-tuning *possible* at all
//! (memory), which the jigsaw run demonstrates.

use std::sync::Arc;

use jigsaw::benchkit::{banner, csv_path, synth_config};
use jigsaw::comm::Network;
use jigsaw::data::ShardedLoader;
use jigsaw::jigsaw::{Ctx, Mesh};
use jigsaw::metrics::lat_weighted_rmse;
use jigsaw::model::dist::DistModel;
use jigsaw::model::params::shard_params;
use jigsaw::optim::Adam;
use jigsaw::runtime::native::NativeBackend;
use jigsaw::runtime::Backend;
use jigsaw::trainer::{train, TrainSpec};
use jigsaw::util::rng::Rng;
use jigsaw::util::table::{fmt, Table};

fn mean(v: &[f32], n: usize) -> f32 {
    v.iter().take(n).sum::<f32>() / n as f32
}

fn main() {
    banner("Fig 6", "rolled-out RMSE after randomized-rollout fine-tuning (2-way MP)");
    let cfg = synth_config("wm-rollout", 96, 64, 2);
    let backend: Arc<dyn Backend> = Arc::new(NativeBackend);

    // pre-train with 2-way jigsaw (the paper: rollout fine-tuning is only
    // possible with MP)
    let mut spec = TrainSpec::quick(2, 1, 160).unwrap();
    spec.lr = 2e-3;
    spec.n_times = 48;
    spec.n_modes = 12;
    spec.seed = 6;
    let r = train(&cfg, &spec, backend.clone()).unwrap();

    // fine-tune on 1 rank with randomized rollout lengths
    let store = shard_params(&cfg, &Mesh::unit(), 0, &r.final_params).unwrap();
    let mut model = DistModel::new(cfg.clone(), &Mesh::unit(), 0, store);
    let mut loader =
        ShardedLoader::new(&cfg, &Mesh::unit(), 0, spec.n_times, 1, 42, spec.n_modes)
            .unwrap();
    let net = Network::new(1);
    let mut comm = net.endpoint(0);
    let mut adam = Adam::new(&model.params, 4e-4);
    let mut rng = Rng::seed_from(9);
    for _ in 0..60 {
        let item = loader.next_item();
        let rollout = 1 + rng.below(4);
        let mut ctx = Ctx::new(Mesh::unit(), 0, &mut comm, backend.as_ref());
        let (_, grads) = model
            .loss_and_grad(&mut ctx, &item.x, &item.y, rollout)
            .unwrap();
        let clip = Adam::clip_scale(&grads, &mut comm, &[0]);
        adam.update(&mut model.params, &grads, clip);
    }

    // rollout evaluation vs persistence over 20 leads
    let mut t = Table::new(&["lead", "WM RMSE (mean ch)", "persistence"]);
    let t0 = 400.0f32;
    let (x0, _) = loader.read_shard(t0);
    let mut prev = 0.0f32;
    let mut monotonic_violations = 0;
    for lead in 1..=20usize {
        let (y, _) = loader.read_shard(t0 + lead as f32);
        let mut ctx = Ctx::new(Mesh::unit(), 0, &mut comm, backend.as_ref());
        let (pred, _) = model.forward(&mut ctx, &x0, lead).unwrap();
        let rm = mean(&lat_weighted_rmse(&pred, &y, cfg.lat, 0), cfg.channels);
        let rp = mean(&lat_weighted_rmse(&x0, &y, cfg.lat, 0), cfg.channels);
        assert!(rm.is_finite(), "rollout diverged at lead {lead}");
        if lead > 1 && rm < prev * 0.7 {
            monotonic_violations += 1;
        }
        prev = rm;
        if lead <= 4 || lead % 4 == 0 {
            t.row(&[lead.to_string(), fmt(rm as f64), fmt(rp as f64)]);
        }
    }
    println!("{}", t.render());
    t.write_csv(&csv_path("fig6_rollout")).unwrap();
    assert!(
        monotonic_violations <= 4,
        "RMSE growth should be roughly monotone with lead"
    );
    println!("20-step rollout stable after randomized-rollout fine-tuning — OK");
}
