//! Paper Table 2: number of data-parallel model instances per jigsaw way
//! when scaling the system-wide experiment from 1 to 256 GPUs.

use jigsaw::benchkit::{banner, csv_path};
use jigsaw::config::zoo::TABLE2;
use jigsaw::util::table::Table;

fn main() {
    banner("Table 2", "data-parallel model instances");
    let gpus = [1usize, 2, 4, 8, 16, 32, 64, 128, 256];
    let mut header: Vec<String> = vec!["way".into(), "TFLOPs".into(), "Params (mil)".into()];
    header.extend(gpus.iter().map(|g| g.to_string()));
    let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);
    for plan in TABLE2 {
        let mut row = vec![
            format!("{}-way", plan.way),
            format!("{}", plan.tflops_fwd),
            format!("{}", plan.params_mil),
        ];
        for g in gpus {
            row.push(
                plan.dp_instances(g)
                    .map(|n| n.to_string())
                    .unwrap_or_else(|| "-".into()),
            );
        }
        t.row(&row);
    }
    println!("{}", t.render());
    t.write_csv(&csv_path("table2_dp_instances")).unwrap();

    assert_eq!(TABLE2[0].dp_instances(256), Some(256));
    assert_eq!(TABLE2[1].dp_instances(256), Some(128));
    assert_eq!(TABLE2[2].dp_instances(256), Some(64));
    println!("matches paper Table 2 at 256 GPUs — OK");
}
