//! Paper Table 3: energy usage and CO2-equivalents for the training and
//! scaling experiments, from the node power model over simulated runtime.
//!
//! Paper anchors (kWh): 1-way 579, 2-way 643, 4-way 855, scaling 445 —
//! the reproduced *shape* is the ordering and the CO2e = E * PUE * e_C
//! methodology; absolute joules depend on the simulated substrate.

use jigsaw::benchkit::{banner, csv_path};
use jigsaw::config::zoo::TABLE1;
use jigsaw::jigsaw::Mesh;
use jigsaw::energy::{training_energy, PowerModel};
use jigsaw::perfmodel::{ClusterSpec, Precision, Workload};
use jigsaw::util::table::{fmt, Table};

fn main() {
    banner("Table 3", "power draw for experiments (simulated sensors)");
    let cluster = ClusterSpec::horeka();
    let power = PowerModel::horeka();
    // the equivalent-usage experiments: 1B model, fixed 8-GPU budget,
    // fixed dataset (paper Section 6.2.1), 100 epochs
    let dataset = 2338usize; // 6h-subsampled ERA5 1979-2017 epoch steps at batch 8
    let epochs = 100usize;
    let model = TABLE1[5]; // ~1B params

    let mut t = Table::new(&["Experiment", "kWh", "CO2e (kg)", "GPUh", "paper kWh"]);
    let mut rows = Vec::new();
    for (name, way, dp, paper_kwh) in [
        ("1-way", 1usize, 8usize, 579.0),
        ("2-way", 2, 4, 643.0),
        ("4-way", 4, 2, 855.0),
    ] {
        let mesh = Mesh::from_degree(way).unwrap();
        let w = Workload { model, mesh, dp, precision: Precision::Tf32, dataload: true };
        let steps = epochs * dataset * 8 / (dp); // fixed sample budget
        let r = training_energy(&cluster, &power, &w, steps / 8);
        rows.push((name, r.kwh));
        t.row(&[
            name.to_string(),
            fmt(r.kwh),
            fmt(r.co2e_kg),
            fmt(r.gpu_hours),
            fmt(paper_kwh),
        ]);
    }
    // scaling experiments: the roofline + DP sweeps (short runs, many configs)
    let mut scaling_kwh = 0.0;
    for m in TABLE1.iter().take(7) {
        for way in [1usize, 2, 4] {
            for prec in [Precision::Fp32, Precision::Tf32] {
                let samples = if prec == Precision::Fp32 { 500 } else { 1250 };
                let mesh = Mesh::from_degree(way).unwrap();
                let w =
                    Workload { model: *m, mesh, dp: 1, precision: prec, dataload: true };
                scaling_kwh +=
                    training_energy(&cluster, &power, &w, 10 * samples).kwh;
            }
        }
    }
    t.row(&[
        "Scaling".into(),
        fmt(scaling_kwh),
        fmt(scaling_kwh * 1.05 * 0.381),
        "-".into(),
        fmt(445.0),
    ]);
    println!("{}", t.render());
    t.write_csv(&csv_path("table3_energy")).unwrap();

    // the paper's ordering: 1-way < 2-way < 4-way
    assert!(rows[0].1 < rows[1].1 && rows[1].1 < rows[2].1,
        "energy ordering violated: {rows:?}");
    println!("energy ordering 1-way < 2-way < 4-way reproduced — OK");
}
