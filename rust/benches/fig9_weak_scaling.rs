//! Paper Fig 9: weak scaling — per-GPU workload held constant (1/4/16
//! TFLOPs per forward pass per GPU), model grown with the way, in the
//! four quadrants {no data loading, full loop} x {fp32, TF32}.
//!
//! Shape anchors: superscalar efficiency for the small, purely
//! I/O-bandwidth-limited series; 4-way compute costs start dominating the
//! mid series; the largest series no longer superscales; 4-way
//! compute-bound weak efficiency ~86% surpasses Megatron-LM's 82%.

use jigsaw::baselines::MEGATRON_WEAK_EFF;
use jigsaw::benchkit::{banner, csv_path};
use jigsaw::config::zoo::{ZooModel, TABLE1};
use jigsaw::jigsaw::Mesh;
use jigsaw::perfmodel::{weak_efficiency, ClusterSpec, Precision};
use jigsaw::util::table::{fmt, Table};

/// the weak-scaling series: (base, 2x model, 4x model) triples with
/// constant FLOPs per GPU.
fn series() -> Vec<(&'static str, ZooModel, ZooModel, ZooModel)> {
    vec![
        ("0.25 TF/GPU", TABLE1[0], TABLE1[1], TABLE1[2]),
        ("1 TF/GPU", TABLE1[2], TABLE1[3], TABLE1[4]),
        ("4 TF/GPU", TABLE1[4], TABLE1[5], TABLE1[6]),
        ("16 TF/GPU", TABLE1[6], TABLE1[7], TABLE1[8]),
    ]
}

fn main() {
    let cluster = ClusterSpec::horeka();
    let mesh2 = Mesh::from_degree(2).unwrap();
    let mesh4 = Mesh::from_degree(4).unwrap();
    for (dataload, dl_name) in [(false, "no data loading"), (true, "full training loop")] {
        for precision in [Precision::Fp32, Precision::Tf32] {
            banner("Fig 9", &format!("weak scaling, {precision:?}, {dl_name}"));
            let mut t = Table::new(&["series", "2-way eff", "4-way eff"]);
            for (name, base, m2, m4) in series() {
                t.row(&[
                    name.to_string(),
                    fmt(weak_efficiency(&cluster, base, m2, &mesh2, precision, dataload)),
                    fmt(weak_efficiency(&cluster, base, m4, &mesh4, precision, dataload)),
                ]);
            }
            t.row(&["Megatron-LM ref".into(), "-".into(), fmt(MEGATRON_WEAK_EFF)]);
            println!("{}", t.render());
            let tag = format!(
                "fig9_weak_{}_{}",
                if dataload { "full" } else { "nodata" },
                match precision {
                    Precision::Fp32 => "fp32",
                    Precision::Tf32 => "tf32",
                }
            );
            t.write_csv(&csv_path(&tag)).unwrap();
        }
    }

    // anchors
    let small_super =
        weak_efficiency(&cluster, TABLE1[0], TABLE1[2], &mesh4, Precision::Tf32, true);
    assert!(small_super > 1.0, "small I/O-bound series must superscale: {small_super}");
    let big =
        weak_efficiency(&cluster, TABLE1[6], TABLE1[8], &mesh4, Precision::Tf32, true);
    assert!(big < 1.0, "largest series must not superscale: {big}");
    let fp32_2way =
        weak_efficiency(&cluster, TABLE1[2], TABLE1[3], &mesh2, Precision::Fp32, false);
    assert!(
        fp32_2way > MEGATRON_WEAK_EFF,
        "2-way compute-bound weak efficiency {fp32_2way} must beat Megatron 0.82"
    );
    println!("Fig 9 anchors reproduced (superscalar small series, big-series saturation) — OK");
}
